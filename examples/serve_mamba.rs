//! End-to-end serving driver: serve batched generation requests
//! through the Rust coordinator (router → continuous batcher →
//! mixed prefill/decode scheduler → recurrent-state manager → engine)
//! and report latency/throughput. Python is not involved.
//!
//! ## The continuous-batching tick loop
//!
//! Every scheduler tick is **one mixed engine invocation**: all running
//! sequences advance by one decode token, and waiting prompts
//! contribute *prefill chunks*, under a per-tick token budget. Two
//! knobs shape the loop:
//!
//! * `--chunk-tokens N` — max prompt tokens per chunk row (`0` =
//!   monolithic whole-prompt prefill). Small chunks bound how much
//!   prefill work rides in any one tick, so a long prompt cannot stall
//!   decoding sequences; the prompt's partial state is carried in the
//!   state manager across as many ticks as it needs.
//! * `--token-budget N` — total per-tick token cost (each decode row
//!   costs 1, each chunk its length). This caps tick latency and
//!   therefore the inter-token gap decoding requests observe.
//!
//! ## Plan selection
//!
//! `--plan {static:<name>|adaptive|table:<path>}` picks the fusion-plan
//! policy (default `adaptive`): the planner matches each tick's
//! prefill/decode mix to the analytically best fusion variant (or a
//! fixed plan, or an autotuned `PlanTable` from `mambalaya autotune`).
//! The per-run summary prints the switch count, the dwell-time
//! histogram and per-plan tick counts next to the `state traffic:`
//! line.
//!
//! ## Engine capability report
//!
//! At startup the driver prints a one-line `engine caps:` summary —
//! the backend's [`EngineCaps`](mambalaya::runtime::EngineCaps)
//! report: whether it has a fused varlen kernel, advances state in
//! place, honours buffer donation, and which fusion plans it can
//! execute. The scheduler and planner negotiate from the same report,
//! so the line shows operators exactly which fused paths the serving
//! process is actually using.
//!
//! ## Sharded state residency
//!
//! `--workers N` starts N workers, each owning one shard of the sharded
//! state arena; the router places new requests on the least-loaded
//! shard. With `--rebalance`, the router also runs slot-aware rebalance
//! passes that *migrate in-flight requests* between workers by moving
//! their resident state rows (`bytes_migrated` in the `migration:`
//! summary line) — never by re-prefilling.
//!
//! ## Session snapshot & fork
//!
//! `--sessions N` (with `--mock`) serves N multi-turn conversations
//! through the session snapshot cache: each completed turn's recurrent
//! state (one fixed-size arena row — the SSM analogue of a prefix
//! cache) is cached per session, so every follow-up turn prefills
//! **only its new tokens** (`prefill_tokens_skipped` in the
//! `snapshot:` summary line). `--fork K` additionally forks the first
//! session K ways copy-on-write — K best-of-N candidates decode from
//! one shared prefill, zero bytes copied at fork time.
//!
//! ## Fault injection
//!
//! `--faults <plan>` wraps every worker's engine in a deterministic
//! fault injector ([`FaultPlan`](mambalaya::runtime::FaultPlan)
//! spellings: `nth:N`, `every:K`, `once[:N]`, `construct[:N]`) so the
//! supervision machinery is drivable from the command line: a failing
//! launch poisons that worker, its salvageable flights re-route to
//! healthy shards (state-carrying rows resume in place, suspect rows
//! re-prefill), the worker respawns under a bounded restart cap, and
//! requests that exhaust their retry budget get one terminal error
//! `Response` instead of a hung channel. The per-run `resilience:`
//! line prints the recovery counters.
//!
//! ## Observability
//!
//! Every run ends with a `latency:` line — server-wide percentiles
//! pooled exactly across workers via the mergeable log2 histograms
//! ([`mambalaya::obs::Histogram`]), in wall milliseconds and in
//! deterministic scheduler ticks. `--trace-out trace.json` additionally
//! drains the request-lifecycle trace (submit → route → chunk →
//! launch → first token → migrate/salvage → complete, stamped with the
//! per-worker tick clock) and writes Chrome trace-event JSON: open it
//! in Perfetto / `chrome://tracing` to see one track per shard plus
//! one span per request.
//!
//! ## Network front-end
//!
//! `--listen ADDR` turns the process into a serving daemon: a TCP
//! accept loop speaking the length-prefixed framed protocol
//! ([`mambalaya::frontend::wire`]) with per-connection streaming token
//! responses, fronted by SLO-aware admission control. Knobs:
//!
//! * `--batch-share F` — batch-class fraction of each admission
//!   window's token capacity (`0` sheds all batch traffic, default `1`);
//! * `--window-ticks N` / `--max-queued-tokens N` — admission window
//!   length and the queued-prompt-token backstop;
//! * `--max-conns N` — serve exactly N connections then exit (default:
//!   serve forever).
//!
//! `--client ADDR` is the matching client: it handshakes (version-
//! checked Hello), pipelines `--requests N` submissions at
//! `--priority {interactive|standard|batch}`, and prints each
//! streamed reply. Every submitted id receives exactly one terminal
//! frame — a `Done` with the token count, or an `Error` carrying the
//! shed/failure reason.
//!
//! ## Modes
//!
//! * `--mock` — serve on the deterministic in-process mock engine
//!   (no artifacts needed); demonstrates chunked prefill with a mixed
//!   long/short-prompt workload.
//! * `--listen ADDR` / `--client ADDR` — network daemon / client over
//!   the framed TCP protocol (combine `--listen` with `--mock` for an
//!   artifact-free demo).
//! * default — load the AOT artifacts and serve via PJRT.
//!   Prereq: `make artifacts` (and a real `xla` binding crate — the
//!   vendored stub fails at load with a pointer here).
//!
//! Run: `cargo run --release --example serve_mamba -- --mock [--requests 32]`
//! Daemon: `cargo run --release --example serve_mamba -- --mock --listen 127.0.0.1:7070`
//! Client: `cargo run --release --example serve_mamba -- --client 127.0.0.1:7070 --priority interactive --requests 8`

use std::sync::mpsc::RecvTimeoutError;
use std::time::{Duration, Instant};

use mambalaya::bench_util::ServeScenario;
use mambalaya::coordinator::{BatchPolicy, Request, Response, Server, TrafficSnapshot, WorkloadGen};
use mambalaya::frontend::{self, AdmissionConfig, FrontendConfig, Priority, PROTOCOL_VERSION};
use mambalaya::planner::PlanSpec;
use mambalaya::runtime::{Executor, FaultInjector, FaultPlan, Golden, MambaEngine, Manifest, MockEngine};
use mambalaya::util::Args;

/// Receive one response while pumping [`Server::supervise`]: worker
/// deaths are only observed at supervision points, so a bare blocking
/// `recv` could wait forever on a re-route nobody has issued yet. A
/// disconnected sink is a supervision bug (every request is owed
/// exactly one terminal message) and reports as such.
fn recv_supervised(
    server: &mut Server,
    rx: &std::sync::mpsc::Receiver<Response>,
) -> anyhow::Result<Response> {
    loop {
        server.supervise();
        match rx.recv_timeout(Duration::from_millis(2)) {
            Ok(r) => return Ok(r),
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => {
                anyhow::bail!("response channel dropped without a terminal message")
            }
        }
    }
}

/// Print the server-wide latency line — percentiles pooled exactly
/// across workers by merging each worker's log2 histograms — and, when
/// `--trace-out` was given, drain the request-lifecycle trace and write
/// Chrome trace-event JSON. Call before `shutdown`: both queries go
/// through the live worker channels.
fn report_observability(server: &mut Server, trace_out: Option<&str>) -> anyhow::Result<()> {
    let lat = server.latency();
    println!(
        "latency: ttft p50={:.2}ms p99={:.2}ms total p50={:.2}ms p99={:.2}ms \
         | ticks: ttft p50={} p99={} inter_token p50={} p99={}",
        lat.ttft_us.percentile(0.50) as f64 / 1e3,
        lat.ttft_us.percentile(0.99) as f64 / 1e3,
        lat.total_us.percentile(0.50) as f64 / 1e3,
        lat.total_us.percentile(0.99) as f64 / 1e3,
        lat.ttft_ticks.percentile(0.50),
        lat.ttft_ticks.percentile(0.99),
        lat.inter_token_ticks.percentile(0.50),
        lat.inter_token_ticks.percentile(0.99),
    );
    if let Some(path) = trace_out {
        let events = server.trace();
        std::fs::write(path, mambalaya::obs::chrome_trace(&events).to_string())?;
        println!(
            "trace: wrote {} lifecycle events to {path} (open in Perfetto / chrome://tracing)",
            events.len()
        );
    }
    Ok(())
}

/// Serve `reqs` through the server (one worker per factory) and print
/// the outcome. With `rebalance`, the router runs slot-aware rebalance
/// passes while the workload drains, migrating in-flight requests off
/// hot shards by moving their resident state (watch the `migration:`
/// line — `bytes_migrated` per move, zero re-prefills).
fn drive<E, F>(
    factories: Vec<F>,
    policy: BatchPolicy,
    spec: PlanSpec,
    reqs: Vec<Request>,
    rebalance: bool,
    faults: Option<FaultInjector>,
    trace_out: Option<&str>,
) -> anyhow::Result<()>
where
    E: Executor,
    F: FnMut() -> anyhow::Result<E> + Send + 'static,
{
    let n_requests = reqs.len();
    let mut expected_tokens: usize = reqs.iter().map(|r| r.max_new_tokens).sum();
    let spec_name = spec.name();
    let t0 = Instant::now();
    let mut server = Server::start_planned(factories, policy, spec);
    // What the backend actually advertises — which fused paths exist,
    // whether state may be donated, and which plans are executable
    // (the scheduler/planner negotiated from this same report).
    if let Some(caps) = server.caps().first() {
        println!("engine caps: {}", caps.summary());
    }
    let rxs: Vec<_> = reqs.iter().map(|r| server.submit(r.clone())).collect();
    let mut migration_passes = 0u32;
    if rebalance {
        // Router passes while the workload is in flight (a production
        // loop would run this on a timer): skew only develops as
        // requests complete unevenly, so keep rebalancing until the
        // workers drain rather than stopping at the first empty plan.
        for _ in 0..10_000 {
            let in_flight: usize =
                server.loads().iter().map(|l| l.running + l.waiting).sum();
            if in_flight == 0 {
                break;
            }
            server.rebalance();
            migration_passes += 1;
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
    }
    let mut total_tokens = 0usize;
    let mut worst_latency = 0f64;
    let mut failed = 0usize;
    for (rx, req) in rxs.iter().zip(&reqs) {
        let resp = recv_supervised(&mut server, rx)?;
        if resp.is_error() {
            // A terminal error is the contract under injected faults
            // (retry budget exhausted / no healthy worker) — the sink
            // got exactly one message, just not a token stream. Its
            // generation budget leaves the expected total.
            failed += 1;
            expected_tokens -= req.max_new_tokens;
            println!("request {} failed terminally: {}", resp.id, resp.error.as_deref().unwrap_or("?"));
        } else {
            total_tokens += resp.tokens.len();
        }
        worst_latency = worst_latency.max(resp.total);
    }
    let wall = t0.elapsed().as_secs_f64();
    for r in server.reports() {
        println!("{r}");
    }
    // Deterministic state-traffic accounting (also embedded in each
    // report line next to budget_use): zero gathered/scattered on a
    // fused engine in steady state — state lives resident in the arena.
    let t = server.traffic();
    // Plan-selection summary: which fusion plans the ticks ran under,
    // how often the planner switched, and how long plans dwelt.
    let dwell: Vec<String> = t.plan_dwell_hist.iter().map(|d| d.to_string()).collect();
    println!(
        "plan: spec={spec_name} switches={} ticks=[{}] dwell_hist=[{}] predicted={}cyc modeled={}cyc err={:.2}x",
        t.plan_switches,
        t.plans_summary(),
        dwell.join(","),
        t.predicted_cycles,
        t.modeled_cycles,
        t.prediction_error(),
    );
    println!(
        "state traffic: gathered={}B scattered={}B resident={}B padded_rows={}",
        t.bytes_gathered, t.bytes_scattered, t.state_bytes_resident, t.padded_rows
    );
    println!(
        "migration: migrations={} migrated={}B reprefills_avoided={} reprefill_tokens={} \
         (rebalance passes: {migration_passes})",
        t.migrations, t.bytes_migrated, t.reprefills_avoided, t.reprefill_tokens
    );
    // Fault-recovery accounting: how the supervisor handled worker
    // deaths — salvaged rows resumed from moved state, suspect rows
    // re-prefilled, respawns burned, and requests that hit a terminal
    // error. All zeros on a fault-free run.
    let res = server.resilience();
    println!(
        "resilience: faults_injected={} workers_down={} worker_restarts={} \
         requests_salvaged={} requests_reprefilled_on_fault={} requests_failed={}",
        faults.as_ref().map_or(0, |i| i.faults_injected()),
        res.workers_down,
        res.worker_restarts,
        res.requests_salvaged,
        res.requests_reprefilled_on_fault,
        res.requests_failed,
    );
    print_snapshot_line(&t);
    report_observability(&mut server, trace_out)?;
    server.shutdown();

    println!(
        "\nserved {n_requests} requests / {total_tokens} tokens in {wall:.2}s \
         ({:.1} tok/s end-to-end, worst request {worst_latency:.3}s{})",
        total_tokens as f64 / wall,
        if failed > 0 {
            format!(", {failed} terminal errors under injected faults")
        } else {
            String::new()
        },
    );
    anyhow::ensure!(
        faults.is_some() || failed == 0,
        "requests failed without fault injection"
    );
    anyhow::ensure!(total_tokens == expected_tokens, "token count mismatch");
    println!("serve_mamba OK");
    Ok(())
}

/// The deterministic snapshot-cache accounting (the session analogue
/// of the `state traffic:` line): stores/hits/forks, the one-copy
/// restore bytes, the prompt tokens follow-up turns did *not* replay,
/// and the cache's unique-bytes gauge.
fn print_snapshot_line(t: &TrafficSnapshot) {
    println!(
        "snapshot: stored={} hits={} forks={} restored={}B skipped_prefill_tokens={} \
         cached={}B evictions={}",
        t.snapshots_stored,
        t.snapshot_hits,
        t.snapshot_forks,
        t.snapshot_bytes_restored,
        t.prefill_tokens_skipped,
        t.snapshot_bytes_cached,
        t.snapshot_evictions
    );
}

/// The `--sessions` demo: N multi-turn conversations served through
/// the session snapshot cache, plus `--fork K` copy-on-write
/// candidates decoding from the first session's shared prefill. Every
/// follow-up turn prefills only its new tokens — the skipped history
/// shows up in the `snapshot:` line, and the turn/candidate replies
/// print so the skip is visibly not changing outputs.
fn drive_sessions<E, F>(
    factories: Vec<F>,
    policy: BatchPolicy,
    spec: PlanSpec,
    n_sessions: usize,
    fork: usize,
    vocab: usize,
    trace_out: Option<&str>,
) -> anyhow::Result<()>
where
    E: Executor,
    F: FnMut() -> anyhow::Result<E> + Send + 'static,
{
    let fresh = ServeScenario::MULTI_TURN_NEW_TOKENS;
    let t0 = Instant::now();
    let mut server = Server::start_planned(factories, policy, spec);
    if let Some(caps) = server.caps().first() {
        println!("engine caps: {}", caps.summary());
    }

    // Turn 1: one opener per session (submitted together — the ticks
    // batch across sessions as usual).
    let openers: Vec<Request> = (0..n_sessions as u64)
        .map(|i| Request {
            id: i,
            prompt: (0..24).map(|x| (x * 11 + i as i32 * 3 + 1) % vocab as i32).collect(),
            max_new_tokens: 8,
        })
        .collect();
    let rxs: Vec<_> =
        openers.iter().map(|r| server.submit_session(r.clone(), r.id)).collect();
    let replies: Vec<Vec<i32>> =
        rxs.into_iter().map(|rx| rx.recv().map(|r| r.tokens)).collect::<Result<_, _>>()?;

    // Turn 2: each prompt resubmits its conversation plus fresh tokens;
    // the cache skips the shared history.
    let follow_ups: Vec<Request> = openers
        .iter()
        .zip(&replies)
        .map(|(r, reply)| Request {
            id: 1000 + r.id,
            prompt: ServeScenario::follow_up_prompt(&r.prompt, reply, fresh, vocab),
            max_new_tokens: 8,
        })
        .collect();
    let rxs: Vec<_> = follow_ups
        .iter()
        .zip(&openers)
        .map(|(r, opener)| server.submit_session(r.clone(), opener.id))
        .collect();
    let replies2: Vec<Vec<i32>> =
        rxs.into_iter().map(|rx| rx.recv().map(|r| r.tokens)).collect::<Result<_, _>>()?;
    for (i, (r1, r2)) in replies.iter().zip(&replies2).enumerate() {
        println!("session {i}: turn1 reply {r1:?} → turn2 reply {r2:?}");
    }

    // Fork: K best-of-N candidates off session 0's cached state.
    let mut candidates = 0usize;
    if fork > 0 {
        for k in 0..fork as u64 {
            anyhow::ensure!(server.fork_session(0, 10_000 + k), "fork {k} refused");
        }
        let rxs: Vec<_> = (0..fork as u64)
            .map(|k| {
                let r = Request {
                    id: 2000 + k,
                    prompt: ServeScenario::follow_up_prompt(
                        &follow_ups[0].prompt,
                        &replies2[0],
                        2,
                        vocab,
                    ),
                    max_new_tokens: 8,
                };
                server.submit_session(r, 10_000 + k)
            })
            .collect();
        for (k, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv()?;
            println!("candidate {k}: {:?}", resp.tokens);
            candidates += 1;
        }
    }

    let wall = t0.elapsed().as_secs_f64();
    for r in server.reports() {
        println!("{r}");
    }
    let t = server.traffic();
    print_snapshot_line(&t);
    report_observability(&mut server, trace_out)?;
    server.shutdown();

    let turns = n_sessions * 2 + candidates;
    anyhow::ensure!(
        t.snapshot_hits as usize == n_sessions + candidates,
        "every follow-up and candidate should hit the cache"
    );
    anyhow::ensure!(t.prefill_tokens_skipped > 0, "no history was skipped");
    println!(
        "\nserved {turns} session turns ({n_sessions} sessions, {candidates} forked candidates) \
         in {wall:.2}s — follow-ups prefilled only their new tokens \
         ({} history tokens skipped)",
        t.prefill_tokens_skipped
    );
    println!("serve_mamba OK");
    Ok(())
}

/// Daemon mode: hand a started [`Server`] to [`frontend::serve`] on
/// `addr` with admission knobs from the command line, then print the
/// front-end stats and the usual observability lines when the accept
/// loop returns (it returns after `--max-conns` connections; without
/// that flag it serves until the process is killed).
fn run_daemon(addr: &str, server: Server, args: &Args) -> anyhow::Result<()> {
    let listener = std::net::TcpListener::bind(addr)
        .map_err(|e| anyhow::anyhow!("bind {addr}: {e}"))?;
    let batch_share = args
        .get("batch-share")
        .map(|s| s.parse::<f64>())
        .transpose()
        .map_err(|e| anyhow::anyhow!("--batch-share: {e}"))?
        .unwrap_or(1.0);
    let mut admission = AdmissionConfig::default();
    admission.shares[Priority::Batch.index()] = batch_share;
    if let Some(w) = args.get("window-ticks") {
        admission.window_ticks = w.parse().map_err(|e| anyhow::anyhow!("--window-ticks: {e}"))?;
    }
    if let Some(q) = args.get("max-queued-tokens") {
        admission.max_queued_tokens =
            q.parse().map_err(|e| anyhow::anyhow!("--max-queued-tokens: {e}"))?;
    }
    let max_connections = args
        .get("max-conns")
        .map(|s| s.parse::<usize>())
        .transpose()
        .map_err(|e| anyhow::anyhow!("--max-conns: {e}"))?;
    println!(
        "frontend: listening on {} (protocol v{PROTOCOL_VERSION}, batch_share={batch_share}, \
         max_conns={max_connections:?})",
        listener.local_addr()?
    );
    let cfg = FrontendConfig { admission, max_connections };
    let (mut server, stats) = frontend::serve(listener, server, cfg)?;
    println!(
        "frontend: connections={} requests={} admitted={:?} shed={:?} error_frames={}",
        stats.connections, stats.requests, stats.admitted, stats.shed, stats.errors
    );
    for r in server.reports() {
        println!("{r}");
    }
    print_snapshot_line(&server.traffic());
    report_observability(&mut server, args.get("trace-out"))?;
    server.shutdown();
    println!("serve_mamba OK");
    Ok(())
}

/// Client mode: handshake with a `--listen` daemon at `addr`, pipeline
/// `--requests` submissions at `--priority`, and print every streamed
/// reply. Each submitted id gets exactly one terminal frame: a `Done`
/// (token count + latency stamps) or an `Error` with the shed reason.
fn run_client_mode(addr: &str, args: &Args) -> anyhow::Result<()> {
    let n = args.get_u64("requests", 8);
    let prio_s = args.get_or("priority", "interactive");
    let prio = Priority::parse(prio_s).ok_or_else(|| {
        anyhow::anyhow!("--priority must be interactive|standard|batch, got {prio_s:?}")
    })?;
    let reqs: Vec<(Request, Priority)> = (0..n)
        .map(|k| {
            let req = Request {
                id: k,
                prompt: (0..8 + (k % 5) as i32).map(|x| (x * 7 + k as i32 + 1) % 97).collect(),
                max_new_tokens: 4 + (k % 4) as usize,
            };
            (req, prio)
        })
        .collect();
    println!("client: {n} {prio} requests → {addr} (protocol v{PROTOCOL_VERSION})");
    let replies = frontend::run_client(addr, &reqs, Some(Duration::from_secs(120)))
        .map_err(|e| anyhow::anyhow!("client: {e}"))?;
    let (mut served, mut shed) = (0usize, 0usize);
    for r in &replies {
        match &r.error {
            None => {
                served += 1;
                println!(
                    "request {}: {} tokens (ttft {:.2}ms): {:?}",
                    r.id,
                    r.tokens.len(),
                    r.ttft_us as f64 / 1e3,
                    r.tokens
                );
            }
            Some(e) => {
                shed += 1;
                println!("request {}: terminal error: {e}", r.id);
            }
        }
    }
    println!("\nclient done: {served} served, {shed} terminal errors");
    println!("serve_mamba OK");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let n_requests = args.get_u64("requests", 24) as usize;
    let workers = (args.get_u64("workers", 1) as usize).max(1);
    let rebalance = args.flag("rebalance");
    let sessions = args.get_u64("sessions", 0) as usize;
    let fork = args.get_u64("fork", 0) as usize;
    let policy = BatchPolicy::from_args(&args);
    let spec = PlanSpec::parse(args.get_or("plan", "adaptive"))?;
    let faults = args.get("faults").map(FaultPlan::parse).transpose()?.map(FaultInjector::new);
    let trace_out = args.get("trace-out");
    anyhow::ensure!(
        faults.is_none() || sessions == 0,
        "--faults drives the request workload; combine it with --mock/--requests, not --sessions"
    );

    if let Some(addr) = args.get("client") {
        return run_client_mode(addr, &args);
    }
    if let Some(addr) = args.get("listen") {
        anyhow::ensure!(
            faults.is_none() && sessions == 0,
            "--listen serves network requests; --faults/--sessions apply to the batch drivers"
        );
        let server = if args.flag("mock") {
            fn mock_factory() -> anyhow::Result<MockEngine> {
                Ok(MockEngine::new())
            }
            let factories: Vec<fn() -> anyhow::Result<MockEngine>> = (0..workers)
                .map(|_| mock_factory as fn() -> anyhow::Result<MockEngine>)
                .collect();
            Server::start_planned(factories, policy, spec)
        } else {
            let dir = args.get_or("artifacts", "artifacts").to_string();
            Manifest::load(&dir)?; // fail fast before binding the socket
            let factories: Vec<_> = (0..workers)
                .map(|_| {
                    let d = dir.clone();
                    move || MambaEngine::load(&d)
                })
                .collect();
            Server::start_planned(factories, policy, spec)
        };
        return run_daemon(addr, server, &args);
    }

    if args.flag("mock") {
        // Mixed traffic on the mock engine (the shared scenario
        // builder): mostly short prompts, with every fourth request a
        // long prompt that spans many chunk ticks — decode keeps
        // advancing throughout (watch max_tick_tokens vs the token
        // budget in the report line).
        let probe = MockEngine::new();
        let vocab = probe.manifest().vocab;
        println!(
            "mock serving: chunk_tokens={} token_budget={} plan={} workers={workers} rebalance={rebalance}",
            policy.chunk_tokens,
            policy.token_budget,
            spec.name()
        );
        if let Some(inj) = faults {
            // Every worker's engine is wrapped by the same injector, so
            // plan state (`once`, `construct` counters) is shared
            // across shards and respawned replacements.
            let factories: Vec<_> = (0..workers)
                .map(|_| {
                    let inj = inj.clone();
                    move || inj.wrap(MockEngine::new())
                })
                .collect();
            let reqs = ServeScenario::mixed_traffic(n_requests, vocab);
            return drive(factories, policy, spec, reqs, rebalance, Some(inj), trace_out);
        }
        fn mock_factory() -> anyhow::Result<MockEngine> {
            Ok(MockEngine::new())
        }
        let factories: Vec<fn() -> anyhow::Result<MockEngine>> =
            (0..workers).map(|_| mock_factory as fn() -> anyhow::Result<MockEngine>).collect();
        if sessions > 0 {
            return drive_sessions(factories, policy, spec, sessions, fork, vocab, trace_out);
        }
        let reqs = ServeScenario::mixed_traffic(n_requests, vocab);
        return drive(factories, policy, spec, reqs, rebalance, None, trace_out);
    }

    let dir = args.get_or("artifacts", "artifacts").to_string();
    let manifest = Manifest::load(&dir)?;
    println!(
        "model {}: {} layers, E={}, D={}, N={}, vocab={}, prefill_len={}",
        manifest.model,
        manifest.n_layer,
        manifest.d_model,
        manifest.d_inner,
        manifest.d_state,
        manifest.vocab,
        manifest.prefill_len
    );

    // Correctness gate first: the engine must reproduce the golden
    // vectors produced at AOT time (catches artifact drift).
    {
        let engine = MambaEngine::load(&dir)?;
        let golden = Golden::load(&dir)?;
        let out = engine.prefill(2, &golden.prefill_tokens)?;
        let am = mambalaya::runtime::argmax_rows(&out.logits, manifest.vocab);
        anyhow::ensure!(
            am.iter().map(|&x| x as i64).collect::<Vec<_>>() == golden.prefill_logits_argmax,
            "golden prefill mismatch — artifacts out of date?"
        );
        println!("golden check: OK (platform {})", engine.platform());
    }

    // Serve a mixed workload: prompts up to 2× the compiled prefill
    // length (the chunked scheduler handles any length), generations
    // short and long.
    let mut gen = WorkloadGen::new(7, manifest.vocab, manifest.prefill_len, 2, 24)
        .with_prompt_range(1, 2 * manifest.prefill_len);
    let reqs: Vec<Request> = (0..n_requests).map(|_| gen.next_request()).collect();
    if let Some(inj) = faults {
        let factories: Vec<_> = (0..workers)
            .map(|_| {
                let d = dir.clone();
                let inj = inj.clone();
                move || inj.wrap(MambaEngine::load(&d)?)
            })
            .collect();
        return drive(factories, policy, spec, reqs, rebalance, Some(inj), trace_out);
    }
    let factories: Vec<_> = (0..workers)
        .map(|_| {
            let d = dir.clone();
            move || MambaEngine::load(&d)
        })
        .collect();
    drive(factories, policy, spec, reqs, rebalance, None, trace_out)
}
