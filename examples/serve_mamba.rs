//! End-to-end serving driver (the brief's required E2E validation):
//! load the AOT-compiled tiny Mamba model, serve batched generation
//! requests through the Rust coordinator (router → dynamic batcher →
//! prefill/decode scheduler → recurrent-state manager → PJRT engine),
//! and report latency/throughput. Python is not involved.
//!
//! Prereq: `make artifacts`
//! Run:    `cargo run --release --example serve_mamba [-- --requests 32]`

use std::time::Instant;

use mambalaya::coordinator::{BatchPolicy, Server, WorkloadGen};
use mambalaya::runtime::{Executor, Golden, MambaEngine, Manifest};
use mambalaya::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let dir = args.get_or("artifacts", "artifacts").to_string();
    let n_requests = args.get_u64("requests", 24) as usize;

    let manifest = Manifest::load(&dir)?;
    println!(
        "model {}: {} layers, E={}, D={}, N={}, vocab={}, prefill_len={}",
        manifest.model,
        manifest.n_layer,
        manifest.d_model,
        manifest.d_inner,
        manifest.d_state,
        manifest.vocab,
        manifest.prefill_len
    );

    // Correctness gate first: the engine must reproduce the golden
    // vectors produced at AOT time (catches artifact drift).
    {
        let engine = MambaEngine::load(&dir)?;
        let golden = Golden::load(&dir)?;
        let out = engine.prefill(2, &golden.prefill_tokens)?;
        let am = mambalaya::runtime::argmax_rows(&out.logits, manifest.vocab);
        anyhow::ensure!(
            am.iter().map(|&x| x as i64).collect::<Vec<_>>() == golden.prefill_logits_argmax,
            "golden prefill mismatch — artifacts out of date?"
        );
        println!("golden check: OK (platform {})", engine.platform());
    }

    // Serve a mixed workload: some short generations, some long.
    let mut gen = WorkloadGen::new(7, manifest.vocab, manifest.prefill_len, 2, 24);
    let reqs: Vec<_> = (0..n_requests).map(|_| gen.next_request()).collect();
    let expected_tokens: usize = reqs.iter().map(|r| r.max_new_tokens).sum();

    let policy = BatchPolicy::default();
    let t0 = Instant::now();
    let mut server = Server::start(vec![move || MambaEngine::load(&dir)], policy);
    let rxs: Vec<_> = reqs.into_iter().map(|r| server.submit(r)).collect();
    let mut total_tokens = 0usize;
    let mut worst_latency = 0f64;
    for rx in rxs {
        let resp = rx.recv()?;
        total_tokens += resp.tokens.len();
        worst_latency = worst_latency.max(resp.total);
    }
    let wall = t0.elapsed().as_secs_f64();
    for r in server.reports() {
        println!("{r}");
    }
    server.shutdown();

    println!(
        "\nserved {n_requests} requests / {total_tokens} tokens in {wall:.2}s \
         ({:.1} tok/s end-to-end, worst request {worst_latency:.3}s)",
        total_tokens as f64 / wall
    );
    anyhow::ensure!(total_tokens == expected_tokens, "token count mismatch");
    println!("serve_mamba OK");
    Ok(())
}
