//! Quickstart: the public API in ~60 lines.
//!
//! Builds the paper's Mamba-1 cascade, classifies a fusion pair, runs
//! greedy stitching for every variant, and evaluates the layer on the
//! Mambalaya architecture model.
//!
//! Run: `cargo run --release --example quickstart`

use mambalaya::arch::ArchSpec;
use mambalaya::cascade::{mamba1, ModelConfig};
use mambalaya::fusion::{classify_pair, stitch, FusionVariant};
use mambalaya::model::{evaluate, ExecOptions};

fn main() -> anyhow::Result<()> {
    // 1. Build the 24-Einsum Mamba-1 cascade (paper Figure 1) for
    //    mamba-370m at a 4096-token prefill.
    let cfg = ModelConfig::mamba_370m();
    let cascade = mamba1::build(&cfg, 4096, 1);
    cascade.validate()?;
    println!(
        "cascade: {} einsums, {} GEMM-like, {} intermediates\n",
        cascade.len(),
        cascade.gemm_count(),
        cascade.intermediate_tensors().len()
    );

    // 2. Classify one producer→consumer pair (paper §III-C).
    let up = cascade.by_id(21).unwrap(); // S  = Σ_n C·H
    let down = cascade.by_id(22).unwrap(); // SD = S + D⊙LEX
    let pair = classify_pair(up, down).unwrap();
    println!(
        "pair #21→#22 via {}: class {} (stationary {})\n",
        pair.intermediate, pair.class, pair.stationary
    );

    // 3. Greedy stitching (paper Algorithm 1) under each variant.
    for v in FusionVariant::all() {
        let plan = stitch(&cascade, v);
        println!("{:<12} → {:>2} fusion groups", v.name(), plan.groups.len());
    }
    println!("(paper Figure 9: 24 → 12 → 8 → 3 → 1)\n");

    // 4. Evaluate on the Mambalaya architecture (paper Table III).
    let arch = ArchSpec::mambalaya();
    let opts = ExecOptions::default();
    let base = evaluate(&cascade, &stitch(&cascade, FusionVariant::Unfused), &arch, &opts);
    for v in FusionVariant::fused() {
        let cost = evaluate(&cascade, &stitch(&cascade, v), &arch, &opts);
        println!(
            "{:<12} layer latency {:>8.3} ms  speedup {:>5.2}×  DRAM {:>6} MiB",
            v.name(),
            cost.latency_secs(&arch) * 1e3,
            base.latency as f64 / cost.latency as f64,
            cost.traffic.total() >> 20
        );
    }
    Ok(())
}
