//! Fusion explorer: sweep sequence lengths and models, mapping how the
//! best fusion strategy shifts between decode-dominated and
//! prefill-dominated regimes (the crossover structure behind paper
//! Figure 12), and run the taxonomy over Mamba-2 and a Transformer to
//! show the framework is workload-generic (Table II's "TA+").
//!
//! Run: `cargo run --release --example fusion_explorer`

use mambalaya::arch::ArchSpec;
use mambalaya::cascade::{mamba1, mamba2, transformer, ModelConfig};
use mambalaya::fusion::{stitch, FusionVariant};
use mambalaya::model::{evaluate, ExecOptions};

fn main() {
    let arch = ArchSpec::mambalaya();
    let opts = ExecOptions::default();

    println!("== best variant vs sequence length (mamba-370m, batch 16) ==");
    println!("{:<10} {:>12} {:>14} {:>10}", "seq", "unfused(ms)", "best", "speedup");
    for exp in [0u32, 2, 4, 6, 8, 10, 12, 14] {
        let seq = 1u64 << exp;
        let c = mamba1::build(&ModelConfig::mamba_370m(), seq, 16);
        let base = evaluate(&c, &stitch(&c, FusionVariant::Unfused), &arch, &opts);
        let (best_v, best) = FusionVariant::fused()
            .into_iter()
            .map(|v| (v, evaluate(&c, &stitch(&c, v), &arch, &opts)))
            .min_by_key(|(_, c)| c.latency)
            .unwrap();
        println!(
            "{:<10} {:>12.3} {:>14} {:>9.2}x",
            seq,
            base.latency_secs(&arch) * 1e3,
            best_v.name(),
            base.latency as f64 / best.latency as f64
        );
    }

    println!("\n== taxonomy generality: group counts per workload ==");
    for (name, cascade) in [
        ("mamba1/370m", mamba1::build(&ModelConfig::mamba_370m(), 1024, 1)),
        ("mamba2/370m", mamba2::build(&ModelConfig::mamba_370m(), 1024, 1)),
        ("mamba1/2.8b", mamba1::build(&ModelConfig::mamba_2_8b(), 1024, 1)),
        (
            "transformer",
            transformer::build(&transformer::TransformerConfig::medium(1024)),
        ),
    ] {
        print!("{name:<14}");
        for v in FusionVariant::all() {
            print!(" {}={:<3}", v.name(), stitch(&cascade, v).groups.len());
        }
        println!();
    }

    println!("\n== model-size scaling (fully-fused speedup over unfused, prefill 16384) ==");
    for cfg in [
        ModelConfig::mamba_130m(),
        ModelConfig::mamba_370m(),
        ModelConfig::mamba_1_4b(),
        ModelConfig::mamba_2_8b(),
    ] {
        let c = mamba1::build(&cfg, 16384, 1);
        let base = evaluate(&c, &stitch(&c, FusionVariant::Unfused), &arch, &opts);
        let ff = evaluate(&c, &stitch(&c, FusionVariant::FullyFused), &arch, &opts);
        println!(
            "{:<12} {:>5.2}x  (layer: {:.3} ms -> {:.3} ms)",
            cfg.name,
            base.latency as f64 / ff.latency as f64,
            base.latency_secs(&arch) * 1e3,
            ff.latency_secs(&arch) * 1e3
        );
    }
}
