//! Reproduce every table and figure of the paper's evaluation in one
//! run, writing CSVs to `results/` and a summary to stdout.
//!
//! Run: `cargo run --release --example reproduce_paper [-- --model 370m --out-dir results]`
//!
//! The paper-vs-measured record derived from this output lives in
//! EXPERIMENTS.md.

use std::io::Write as _;

use mambalaya::cascade::ModelConfig;
use mambalaya::report;
use mambalaya::util::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let cfg = ModelConfig::by_name(args.get_or("model", "370m"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let out_dir = args.get_or("out-dir", "results").to_string();
    let seq = args.get_u64("seq", 16384);
    let batch = args.get_u64("batch", 64);
    std::fs::create_dir_all(&out_dir)?;

    // `+ '_`: the closures borrow the local `cfg`, so the trait objects
    // must not default to 'static.
    let experiments: Vec<(&str, Box<dyn Fn() -> (String, String) + '_>)> = vec![
        ("table1", Box::new(|| report::table1_report(&cfg, seq, batch))),
        ("table2", Box::new(report::table2_report)),
        ("table3", Box::new(report::table3_report)),
        ("fig2", Box::new(|| report::fig2_report(&cfg, seq, batch))),
        ("fig9", Box::new(|| report::fig9_report(&cfg, seq))),
        ("fig10", Box::new(|| report::fig10_report(&cfg, seq, batch))),
        ("fig12", Box::new(|| report::fig12_report(&cfg))),
        ("fig13", Box::new(|| report::fig13_report(&cfg))),
        ("fig14", Box::new(|| report::fig14_report(&cfg, seq, batch))),
        ("fig15", Box::new(|| report::fig15_report(&cfg, seq, batch))),
    ];

    for (name, run) in experiments {
        let t0 = std::time::Instant::now();
        let (text, csv) = run();
        println!("{text}");
        let path = format!("{out_dir}/{name}.csv");
        let mut f = std::fs::File::create(&path)?;
        f.write_all(csv.as_bytes())?;
        println!("  → {path} ({:.2}s)\n{}", t0.elapsed().as_secs_f64(), "=".repeat(78));
    }
    println!("all experiments regenerated into {out_dir}/");
    Ok(())
}
