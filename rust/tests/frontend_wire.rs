//! Adversarial property coverage for the front-end wire protocol:
//! round-trip equality over randomized frames, and totality of the
//! decoder — truncation at every prefix length, corrupt/oversized/
//! misaligned length prefixes, unknown kinds, version skew, bad magic
//! and raw random bytes must all return a typed [`WireError`], never
//! panic and never mis-decode.

use mambalaya::frontend::{
    decode_frame, encode_frame, read_frame, Frame, WireError, MAX_FRAME_LEN, PROTOCOL_VERSION,
};
use mambalaya::util::XorShift;

/// A randomized valid frame of every kind.
fn random_frame(rng: &mut XorShift) -> Frame {
    match rng.below(5) {
        0 => Frame::Hello { version: PROTOCOL_VERSION },
        1 => {
            let n = rng.below(64) as usize;
            Frame::Submit {
                id: rng.next_u64(),
                priority: rng.below(3) as u32,
                max_new_tokens: rng.below(512) as u32,
                prompt: (0..n).map(|_| rng.next_u64() as i32).collect(),
            }
        }
        2 => Frame::Token { id: rng.next_u64(), token: rng.next_u64() as i32 },
        3 => Frame::Done {
            id: rng.next_u64(),
            n_tokens: rng.below(1024) as u32,
            ttft_us: rng.next_u64() as u32,
            total_us: rng.next_u64() as u32,
        },
        _ => {
            let n = rng.below(40) as usize;
            let reason: String =
                (0..n).map(|_| char::from(b'a' + rng.below(26) as u8)).collect();
            Frame::Error { id: rng.next_u64(), reason }
        }
    }
}

#[test]
fn randomized_frames_round_trip() {
    let mut rng = XorShift::new(0xF0A7);
    for _ in 0..500 {
        let f = random_frame(&mut rng);
        let bytes = encode_frame(&f);
        assert_eq!(bytes.len() % 4, 0, "alignment invariant: {f:?}");
        let (got, used) = decode_frame(&bytes).expect("valid frame decodes");
        assert_eq!(got, f);
        assert_eq!(used, bytes.len());
        let mut cursor = std::io::Cursor::new(&bytes);
        assert_eq!(read_frame(&mut cursor).expect("stream decode"), f);
    }
}

#[test]
fn concatenated_frames_decode_in_sequence() {
    let mut rng = XorShift::new(0xBEEF);
    let frames: Vec<Frame> = (0..32).map(|_| random_frame(&mut rng)).collect();
    let mut stream = Vec::new();
    for f in &frames {
        stream.extend_from_slice(&encode_frame(f));
    }
    let mut pos = 0;
    for f in &frames {
        let (got, used) = decode_frame(&stream[pos..]).expect("frame at offset");
        assert_eq!(&got, f);
        pos += used;
    }
    assert_eq!(pos, stream.len(), "no trailing bytes");
}

#[test]
fn truncation_at_every_prefix_length_errors_cleanly() {
    let mut rng = XorShift::new(0x7A11);
    for _ in 0..40 {
        let f = random_frame(&mut rng);
        let bytes = encode_frame(&f);
        for cut in 0..bytes.len() {
            match decode_frame(&bytes[..cut]) {
                Err(_) => {}
                Ok((got, used)) => {
                    panic!("truncated {f:?} at {cut}/{} decoded as {got:?} ({used}B)", bytes.len())
                }
            }
        }
    }
}

#[test]
fn corrupt_length_prefixes_never_panic() {
    let f = Frame::Submit { id: 1, priority: 0, max_new_tokens: 2, prompt: vec![1, 2, 3] };
    let good = encode_frame(&f);
    for len in [
        0u32,
        1,
        2,
        3,
        5,
        7,
        10,
        MAX_FRAME_LEN - 1,
        MAX_FRAME_LEN + 1,
        MAX_FRAME_LEN + 4,
        u32::MAX,
        u32::MAX - 3,
    ] {
        let mut b = good.clone();
        b[..4].copy_from_slice(&len.to_le_bytes());
        let err = decode_frame(&b).expect_err("corrupt prefix must be rejected");
        match err {
            WireError::Oversized { .. }
            | WireError::Misaligned { .. }
            | WireError::Truncated => {}
            other => panic!("prefix {len}: unexpected error class {other:?}"),
        }
    }
    // A large-but-valid prefix over a short buffer truncates rather
    // than allocating.
    let mut b = good.clone();
    b[..4].copy_from_slice(&(MAX_FRAME_LEN - (MAX_FRAME_LEN % 4)).to_le_bytes());
    assert_eq!(decode_frame(&b).unwrap_err(), WireError::Truncated);
}

#[test]
fn unknown_kind_and_version_skew_are_typed_errors() {
    // Unknown kind word.
    let mut b = Vec::new();
    b.extend_from_slice(&8u32.to_le_bytes());
    b.extend_from_slice(&99u32.to_le_bytes());
    b.extend_from_slice(&0u32.to_le_bytes());
    assert_eq!(decode_frame(&b).unwrap_err(), WireError::UnknownKind(99));

    // Version skew in Hello.
    let mut hello = encode_frame(&Frame::Hello { version: PROTOCOL_VERSION });
    let n = hello.len();
    hello[n - 4..].copy_from_slice(&(PROTOCOL_VERSION + 7).to_le_bytes());
    assert_eq!(
        decode_frame(&hello).unwrap_err(),
        WireError::VersionMismatch { got: PROTOCOL_VERSION + 7, want: PROTOCOL_VERSION }
    );

    // Bad magic in Hello (kind says Hello, magic says otherwise).
    let mut bad = encode_frame(&Frame::Hello { version: PROTOCOL_VERSION });
    bad[8..12].copy_from_slice(&0xDEAD_BEEFu32.to_le_bytes());
    assert_eq!(decode_frame(&bad).unwrap_err(), WireError::BadMagic(0xDEAD_BEEF));
}

#[test]
fn submit_payload_validation() {
    // Out-of-range priority class.
    let f = Frame::Submit { id: 3, priority: 0, max_new_tokens: 4, prompt: vec![1] };
    let mut b = encode_frame(&f);
    // Layout: [len][kind][id u64][priority][max_new][n][tokens...]
    b[16..20].copy_from_slice(&9u32.to_le_bytes());
    assert!(matches!(decode_frame(&b).unwrap_err(), WireError::BadPayload(_)));

    // Prompt-count word claiming more tokens than the frame carries.
    let mut b = encode_frame(&f);
    b[24..28].copy_from_slice(&1_000u32.to_le_bytes());
    assert_eq!(decode_frame(&b).unwrap_err(), WireError::Truncated);

    // Error-reason length claiming more bytes than the frame carries.
    let e = Frame::Error { id: 1, reason: "abc".into() };
    let mut b = encode_frame(&e);
    // Layout: [len][kind][id u64][reason_len][bytes...]
    b[16..20].copy_from_slice(&10_000u32.to_le_bytes());
    assert_eq!(decode_frame(&b).unwrap_err(), WireError::Truncated);
}

#[test]
fn random_bytes_never_panic_the_decoder() {
    let mut rng = XorShift::new(0xFACE);
    for _ in 0..2_000 {
        let n = rng.below(96) as usize;
        let bytes: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
        // Must return — any Ok must account for its consumed bytes.
        if let Ok((_, used)) = decode_frame(&bytes) {
            assert!(used <= bytes.len());
            assert!(used >= 8, "a frame is at least prefix + kind");
        }
    }
    // Bit-flip corruption of valid frames: decode must stay total.
    for i in 0..400 {
        let f = random_frame(&mut rng);
        let mut bytes = encode_frame(&f);
        let flips = 1 + (i % 4);
        for _ in 0..flips {
            let pos = rng.below(bytes.len() as u64) as usize;
            bytes[pos] ^= 1 << rng.below(8);
        }
        let _ = decode_frame(&bytes); // Ok or Err both fine; no panic
    }
}
