//! Cross-worker sharding conformance, tested hermetically against
//! `runtime::mock` (mirroring the resident ≡ reference suite in
//! `state_residency.rs`):
//!
//! * **Differential property**: a sharded pair of workers with
//!   randomized *forced migrations* emits bit-identical tokens to a
//!   single-worker baseline across randomized policies and workloads —
//!   migrating a request's resident state rows never changes a sampled
//!   token, and never re-prefills.
//! * **Conservation laws**, checked at every migration: the transfer
//!   payload is exactly `state_bytes_per_seq`; the *global* resident
//!   gauge (summed over shards, both the arenas and the metrics
//!   gauges) is invariant across the move; `bytes_migrated` grows by
//!   exactly one payload per move; `reprefills_avoided` equals the
//!   decode-phase migration count.
//! * **Re-prefill baseline**: `MigrationMode::Reprefill` produces the
//!   same tokens while paying in `reprefill_tokens` instead of
//!   `bytes_migrated` — the deterministic counter pair the sharding
//!   bench gate prices migration against.
//! * **End-to-end**: the threaded `Server` migrates in-flight requests
//!   over its channels (`force_migrate`, `rebalance`) without losing a
//!   response.
//! * **Salvage conformance**: killing one worker of a pair mid-run with
//!   a randomized injected fault plan, salvaging the poisoned
//!   scheduler, and re-routing the wreck to the survivor emits
//!   bit-identical tokens to a fault-free single worker — with the
//!   salvage conservation laws (suspect rows never export state, every
//!   state payload is exactly `state_bytes_per_seq`, the survivor's
//!   resident gauge grows by exactly one payload per state attach).
//! * **Reconciliation property**: under randomized migrations and under
//!   a fault-storm worker kill, the drained request-lifecycle trace
//!   ([`mambalaya::obs`]) accounts for the independent traffic counters
//!   exactly, every request span carries exactly one terminal event,
//!   and a migrated span records every shard it crossed.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::{Duration, Instant};

use mambalaya::coordinator::{
    BatchPolicy, MigrationMode, Request, Response, Scheduler, Server, TrafficSnapshot,
    WorkloadGen,
};
use mambalaya::obs::{assemble_spans, reconcile, TraceEvent};
use mambalaya::prop::check;
use mambalaya::runtime::{Executor, FaultInjector, FaultPlan, MockEngine};
use mambalaya::util::XorShift;

fn run_single(policy: &BatchPolicy, reqs: &[Request]) -> BTreeMap<u64, Vec<i32>> {
    let mut s = Scheduler::new(MockEngine::new(), policy.clone());
    for r in reqs {
        s.submit(r.clone()).unwrap();
    }
    s.run_until_drained()
        .unwrap()
        .into_iter()
        .map(|r| (r.id, r.tokens))
        .collect()
}

#[derive(Debug, Default)]
struct MigrationStats {
    migrations: u64,
    decode_migrations: u64,
}

/// Serve `reqs` on two shards, forcing a random migration between
/// random tick pairs, asserting the conservation laws at every move.
fn run_sharded_with_forced_migrations(
    policy: &BatchPolicy,
    reqs: &[Request],
    rng: &mut XorShift,
) -> (BTreeMap<u64, Vec<i32>>, MigrationStats) {
    let mut shards =
        vec![Scheduler::new(MockEngine::new(), policy.clone()), Scheduler::new(MockEngine::new(), policy.clone())];
    shards[0].set_shard(0);
    shards[1].set_shard(1);
    let bytes_per_seq = shards[0].state_arena().bytes_per_seq() as u64;

    let mut placement: BTreeMap<u64, usize> = BTreeMap::new();
    for (i, r) in reqs.iter().enumerate() {
        let to = i % 2;
        placement.insert(r.id, to);
        shards[to].submit(r.clone()).unwrap();
    }

    let mut out = BTreeMap::new();
    let mut stats = MigrationStats::default();
    let mut guard = 0u32;
    while shards.iter().map(|s| s.pending()).sum::<usize>() > 0 {
        guard += 1;
        assert!(guard < 100_000, "sharded serve did not drain");
        for s in shards.iter_mut() {
            for resp in s.tick().unwrap().0 {
                placement.remove(&resp.id);
                out.insert(resp.id, resp.tokens);
            }
        }

        // A forced migration between random tick pairs: pick any live
        // request and move it to the other shard (a no-op when it holds
        // no state yet — detach refuses, exactly like the server path).
        if guard % 2 == 0 && !placement.is_empty() {
            let live: Vec<u64> = placement.keys().copied().collect();
            let seq = live[rng.below(live.len() as u64) as usize];
            let from = placement[&seq];
            let to = 1 - from;

            let arena_gauge = |shards: &[Scheduler<MockEngine>]| -> u64 {
                shards.iter().map(|s| s.state_arena().resident_bytes()).sum()
            };
            let metric_gauge = |shards: &[Scheduler<MockEngine>]| -> u64 {
                shards.iter().map(|s| s.metrics().state_bytes_resident).sum()
            };
            let migrated_bytes = |shards: &[Scheduler<MockEngine>]| -> u64 {
                shards.iter().map(|s| s.metrics().bytes_migrated).sum()
            };
            let gauges_before = (arena_gauge(&shards), metric_gauge(&shards));
            let bytes_before = migrated_bytes(&shards);

            if let Some(p) = shards[from].detach(seq) {
                // Conservation: the payload is exactly one sequence.
                assert_eq!(p.state_bytes(), bytes_per_seq, "payload != state_bytes_per_seq");
                assert_eq!(p.from.shard, from, "handle provenance");
                let decode_phase = p.decode_phase();
                shards[to].attach(p).expect("well-formed packet attaches");
                placement.insert(seq, to);
                stats.migrations += 1;
                if decode_phase {
                    stats.decode_migrations += 1;
                }
                // Conservation: the global gauge (arena truth and the
                // metrics view of it) is invariant across the move, and
                // bytes_migrated grew by exactly one payload.
                assert_eq!(
                    (arena_gauge(&shards), metric_gauge(&shards)),
                    gauges_before,
                    "global resident gauge not conserved across a migration"
                );
                assert_eq!(migrated_bytes(&shards), bytes_before + bytes_per_seq);
                assert_eq!(
                    shards[to].slot_of(seq).map(|h| h.shard),
                    Some(to),
                    "migrated handle must point at the target shard"
                );
            }
        }
    }

    // Exactly-once accounting over the whole run.
    let migrations: u64 = shards.iter().map(|s| s.metrics().migrations).sum();
    let outs: u64 = shards.iter().map(|s| s.metrics().migrations_out).sum();
    let avoided: u64 = shards.iter().map(|s| s.metrics().reprefills_avoided).sum();
    let migrated: u64 = shards.iter().map(|s| s.metrics().bytes_migrated).sum();
    assert_eq!(migrations, stats.migrations);
    assert_eq!(outs, stats.migrations);
    assert_eq!(migrated, stats.migrations * bytes_per_seq);
    assert_eq!(
        avoided, stats.decode_migrations,
        "every decode-phase migration avoids exactly one re-prefill"
    );
    (out, stats)
}

#[test]
fn prop_sharded_with_forced_migrations_matches_single_worker() {
    let probe = MockEngine::new();
    let (vocab, plen) = (probe.manifest().vocab, probe.manifest().prefill_len);
    let mut total_migrations = 0u64;
    let mut total_decode_migrations = 0u64;
    check("sharded + migrations ≡ single worker", 20, |rng| {
        let policy = BatchPolicy {
            chunk_tokens: rng.range(0, 6) as usize,
            token_budget: rng.range(1, 24) as usize,
            max_chunk_rows: rng.range(1, 5) as usize,
            max_running: rng.range(1, 8) as usize,
            decode_priority_threshold: rng.range(1, 10) as usize,
        };
        let mut gen = WorkloadGen::new(rng.next_u64(), vocab, plen, 2, 12)
            .with_prompt_range(1, 3 * plen);
        let reqs: Vec<Request> =
            (0..rng.range(2, 8)).map(|_| gen.next_request()).collect();

        let want = run_single(&policy, &reqs);
        let (got, stats) = run_sharded_with_forced_migrations(&policy, &reqs, rng);
        total_migrations += stats.migrations;
        total_decode_migrations += stats.decode_migrations;
        if got != want {
            return Err(format!("tokens diverged under migration: {got:?} vs {want:?}"));
        }
        Ok(())
    });
    // The suite must actually exercise the machinery it claims to
    // verify — including whole-history (decode-phase) moves.
    assert!(total_migrations > 0, "no forced migration ever landed");
    assert!(total_decode_migrations > 0, "no decode-phase migration ever landed");
}

#[test]
fn reprefill_baseline_is_token_identical_but_pays_in_replayed_tokens() {
    // The same forced hot→cold move, realized both ways. The state
    // move transfers one payload; the re-prefill baseline replays the
    // whole processed history through the engine. Identical tokens,
    // disjoint counters — the pair the sharding bench gate prices.
    let probe = MockEngine::new();
    let plen = probe.manifest().prefill_len;
    let run = |reprefill: bool| {
        let mut a = Scheduler::new(MockEngine::new(), BatchPolicy::default());
        let mut b = Scheduler::new(MockEngine::new(), BatchPolicy::default());
        a.set_shard(0);
        b.set_shard(1);
        let prompt: Vec<i32> = (0..2 * plen as i32).map(|x| x % 17).collect();
        a.submit(Request { id: 1, prompt, max_new_tokens: 24 }).unwrap();
        for _ in 0..12 {
            a.tick().unwrap();
        }
        assert_eq!(a.running(), 1, "decode-phase at the migration point");
        let p = a.detach(1).expect("running request detaches");
        if reprefill {
            b.attach_reprefill(p);
        } else {
            b.attach(p).expect("well-formed packet attaches");
        }
        let out = b.run_until_drained().unwrap();
        (
            out[0].tokens.clone(),
            b.metrics().bytes_migrated,
            b.metrics().reprefill_tokens,
            b.metrics().reprefills_avoided,
        )
    };
    let (moved_tokens, moved_bytes, moved_replay, moved_avoided) = run(false);
    let (replay_tokens, replay_bytes, replay_replay, replay_avoided) = run(true);
    assert_eq!(moved_tokens, replay_tokens, "re-prefill baseline diverged");
    assert!(moved_bytes > 0);
    assert_eq!(moved_replay, 0, "a state move replays nothing");
    assert_eq!(moved_avoided, 1);
    assert_eq!(replay_bytes, 0, "the baseline moves no state");
    assert!(
        replay_replay as usize >= 2 * plen,
        "the baseline must replay at least the whole prompt ({replay_replay} tokens)"
    );
    assert_eq!(replay_avoided, 0);
}

#[test]
fn prop_detach_attach_round_trip_survives_arena_growth() {
    // The scheduler sizes its arena to `max_running`, so a migration
    // *into* a worker whose arena is full is exactly the case that
    // forces `grow()` — a doubling that re-strides every layer-major
    // stripe. The round-trip law: detach → attach-into-full-arena →
    // detach must hand back a bit-identical payload, growth must not
    // disturb any other resident row, and the resident gauge (arena
    // truth and the metrics view) must track exactly attach − detach.
    let probe = MockEngine::new();
    let (vocab, plen) = (probe.manifest().vocab, probe.manifest().prefill_len);
    check("detach→attach round-trip under grow()", 12, |rng| {
        let policy = BatchPolicy {
            chunk_tokens: rng.range(0, 6) as usize,
            token_budget: rng.range(8, 24) as usize,
            max_chunk_rows: rng.range(1, 5) as usize,
            max_running: rng.range(1, 4) as usize,
            decode_priority_threshold: rng.range(1, 10) as usize,
        };

        // Source worker: long generations so a detachable (state-
        // holding) flight always exists after a few ticks.
        let mut a = Scheduler::new(MockEngine::new(), policy.clone());
        a.set_shard(0);
        let n = rng.range(1, 4);
        for id in 0..n {
            let len = rng.range(1, 2 * plen as u64) as usize;
            a.submit(Request {
                id,
                prompt: (0..len as i32).map(|x| (x * 3 + id as i32 + 1) % vocab as i32).collect(),
                max_new_tokens: 500,
            })
            .unwrap();
        }
        for _ in 0..rng.range(1, 20) {
            a.tick().unwrap();
        }
        let mut p = (0..n).find_map(|id| a.detach(id));
        let mut guard = 0;
        while p.is_none() {
            guard += 1;
            assert!(guard < 1000, "no flight ever held detachable state");
            a.tick().unwrap();
            p = (0..n).find_map(|id| a.detach(id));
        }
        let p = p.unwrap();
        let seq = p.seq();
        let bytes_per_seq = a.state_arena().bytes_per_seq() as u64;
        let (want_conv, want_ssm) = (p.conv.clone(), p.ssm.clone());

        // Target worker: fill its arena to capacity with resident
        // decoders, so the attach has no free row and must grow().
        let mut b = Scheduler::new(MockEngine::new(), policy.clone());
        b.set_shard(1);
        let fillers: Vec<u64> = (0..policy.max_running as u64).map(|i| 1000 + i).collect();
        for &id in &fillers {
            b.submit(Request {
                id,
                prompt: vec![(id % 7) as i32 + 1; 4],
                max_new_tokens: 2000,
            })
            .unwrap();
        }
        let mut guard = 0;
        while !fillers.iter().all(|&id| b.state_arena().contains(id)) {
            guard += 1;
            assert!(guard < 1000, "fillers never filled the target arena");
            b.tick().unwrap();
        }
        let cap_before = b.state_arena().capacity();
        let resident_before = b.state_arena().resident_bytes();
        assert_eq!(resident_before, cap_before as u64 * bytes_per_seq, "arena full before attach");
        let filler_snaps: Vec<_> =
            fillers.iter().map(|&id| b.state_arena().snapshot(id).unwrap()).collect();

        b.attach(p).expect("well-formed packet attaches");
        if b.state_arena().capacity() <= cap_before {
            return Err("attach into a full arena did not grow()".into());
        }
        if b.state_arena().resident_bytes() != resident_before + bytes_per_seq
            || b.metrics().state_bytes_resident != resident_before + bytes_per_seq
        {
            return Err("resident gauge did not track the attach".into());
        }
        for (&id, snap) in fillers.iter().zip(&filler_snaps) {
            if b.state_arena().snapshot(id).unwrap() != *snap {
                return Err(format!("grow() re-striding corrupted resident row {id}"));
            }
        }

        // Round-trip back out before any tick: bit-identity.
        let p2 = b.detach(seq).expect("attached flight detaches");
        if p2.conv != want_conv || p2.ssm != want_ssm {
            return Err("payload not bit-identical across detach→attach→detach".into());
        }
        if b.state_arena().resident_bytes() != resident_before
            || b.metrics().state_bytes_resident != resident_before
        {
            return Err("resident gauge did not return after detach".into());
        }
        Ok(())
    });
}

/// Long-generation requests pinned to one worker, so forced migrations
/// have a wide in-flight window to land in.
fn pinned_requests(n: u64, vocab: usize, plen: usize) -> Vec<Request> {
    (0..n)
        .map(|id| Request {
            id,
            prompt: (0..plen as i32).map(|x| (x + id as i32) % vocab as i32).collect(),
            max_new_tokens: 4000,
        })
        .collect()
}

#[test]
fn server_force_migrate_end_to_end() {
    let probe = MockEngine::new();
    let (vocab, plen) = (probe.manifest().vocab, probe.manifest().prefill_len);
    let bytes_per_seq =
        Scheduler::new(MockEngine::new(), BatchPolicy::default()).state_arena().bytes_per_seq()
            as u64;
    let factories: Vec<fn() -> anyhow::Result<MockEngine>> =
        vec![|| Ok(MockEngine::new()), || Ok(MockEngine::new())];
    let mut server = Server::start(factories, BatchPolicy::default());
    let reqs = pinned_requests(6, vocab, plen);
    let rxs: Vec<_> = reqs.into_iter().map(|r| server.submit_to(r, 0)).collect();
    assert_eq!(server.shard_map().loads(), &[6, 0], "pinned skew");

    // Keep forcing migrations until at least one whole-history
    // (decode-phase) move lands; the 4000-token generations leave an
    // enormous window, so this converges almost immediately.
    let mut landed = 0u64;
    'outer: for attempt in 0..1_000_000u64 {
        let seq = attempt % 6;
        if let Some(from) = server.shard_map().shard_of(seq) {
            if server.force_migrate(seq, 1 - from) {
                landed += 1;
                if server.traffic().reprefills_avoided >= 1 {
                    break 'outer;
                }
            }
        }
        std::thread::yield_now();
    }
    assert!(landed >= 1, "no forced migration ever landed");

    for rx in rxs {
        assert_eq!(rx.recv().unwrap().tokens.len(), 4000, "a migrated response was lost");
    }
    let t = server.traffic();
    assert!(t.migrations >= landed, "every landed move is counted (attach side)");
    assert_eq!(t.bytes_migrated, t.migrations * bytes_per_seq);
    assert!(t.reprefills_avoided >= 1, "a decode-phase move avoided a re-prefill");
    server.shutdown();
}

#[test]
fn server_rebalance_moves_load_off_the_hot_worker() {
    let probe = MockEngine::new();
    let (vocab, plen) = (probe.manifest().vocab, probe.manifest().prefill_len);
    let factories: Vec<fn() -> anyhow::Result<MockEngine>> =
        vec![|| Ok(MockEngine::new()), || Ok(MockEngine::new())];
    let mut server = Server::start(factories, BatchPolicy::default());
    let reqs = pinned_requests(8, vocab, plen);
    let rxs: Vec<_> = reqs.into_iter().map(|r| server.submit_to(r, 0)).collect();

    // 8-vs-0 skew with the default threshold (2): rebalance keeps
    // planning until the tracked gap closes. Misses (pre-state
    // requests) are deferred, so retry a few rounds.
    let mut migrated = 0usize;
    for _ in 0..100_000 {
        migrated += server.rebalance().migrated;
        let loads = server.shard_map().loads().to_vec();
        if loads[0].abs_diff(loads[1]) <= 2 && migrated >= 1 {
            break;
        }
        std::thread::yield_now();
    }
    assert!(migrated >= 1, "rebalance never landed a migration");
    let loads = server.shard_map().loads().to_vec();
    assert!(
        loads[0].abs_diff(loads[1]) <= 2,
        "rebalance left the tracked load unbalanced: {loads:?}"
    );

    for rx in rxs {
        assert_eq!(rx.recv().unwrap().tokens.len(), 4000);
    }
    let t = server.traffic();
    assert!(t.migrations as usize >= migrated);
    assert!(t.bytes_migrated > 0);
    server.shutdown();
}

#[test]
fn prop_salvaged_worker_death_matches_fault_free_single_worker() {
    // One worker of a pair dies mid-run under a randomized injected
    // fault plan; its poisoned scheduler is salvaged and the wreck
    // re-routed to the survivor — state-carrying packets resume in
    // place, suspect/stateless packets re-prefill. The law: the final
    // token streams are bit-identical to a fault-free single worker,
    // and the salvage never launders untrusted state.
    let probe = MockEngine::new();
    let (vocab, plen) = (probe.manifest().vocab, probe.manifest().prefill_len);
    let mut total_faults = 0u64;
    let mut total_state_salvages = 0u64;
    let mut total_reprefill_salvages = 0u64;
    check("worker death + salvage ≡ fault-free single worker", 24, |rng| {
        let policy = BatchPolicy {
            chunk_tokens: rng.range(0, 6) as usize,
            token_budget: rng.range(1, 24) as usize,
            max_chunk_rows: rng.range(1, 5) as usize,
            max_running: rng.range(1, 8) as usize,
            decode_priority_threshold: rng.range(1, 10) as usize,
        };
        let mut gen = WorkloadGen::new(rng.next_u64(), vocab, plen, 2, 12)
            .with_prompt_range(1, 3 * plen);
        let reqs: Vec<Request> =
            (0..rng.range(2, 8)).map(|_| gen.next_request()).collect();
        let want = run_single(&policy, &reqs);

        // A randomized deterministic fault plan; large `n` values mean
        // some iterations never fire, which must also be harmless.
        let n = rng.range(1, 40);
        let plan = if rng.below(2) == 0 { FaultPlan::Nth(n) } else { FaultPlan::Every(n) };
        let inj = FaultInjector::new(plan);
        let mut healthy = Scheduler::new(MockEngine::new(), policy.clone());
        healthy.set_shard(1);
        let bytes_per_seq = healthy.state_arena().bytes_per_seq() as u64;
        let mut faulty =
            Some(Scheduler::new(inj.wrap(MockEngine::new()).unwrap(), policy.clone()));
        faulty.as_mut().unwrap().set_shard(0);

        // Alternate placement; `live` tracks what is still on the
        // doomed shard when it dies.
        let mut live: BTreeSet<u64> = BTreeSet::new();
        for (i, r) in reqs.iter().enumerate() {
            if i % 2 == 0 {
                live.insert(r.id);
                faulty.as_mut().unwrap().submit(r.clone()).unwrap();
            } else {
                healthy.submit(r.clone()).unwrap();
            }
        }

        let mut out = BTreeMap::new();
        let mut guard = 0u32;
        loop {
            guard += 1;
            assert!(guard < 100_000, "salvage scenario did not drain");
            if let Some(f) = faulty.as_mut() {
                match f.tick() {
                    Ok((done, _)) => {
                        for resp in done {
                            live.remove(&resp.id);
                            out.insert(resp.id, resp.tokens);
                        }
                    }
                    Err(e) => {
                        total_faults += 1;
                        if !e.to_string().contains("injected launch fault") {
                            return Err(format!("unexpected engine error: {e:#}"));
                        }
                        let wreck = faulty.take().unwrap();
                        if !wreck.poisoned() {
                            return Err("failed tick did not poison the scheduler".into());
                        }
                        let suspect: BTreeSet<u64> =
                            wreck.suspect_rows().iter().copied().collect();
                        if suspect.is_empty() {
                            return Err("poisoning launch recorded no suspect rows".into());
                        }
                        if !suspect.is_subset(&live) {
                            return Err(format!(
                                "suspect rows {suspect:?} not all in flight {live:?}"
                            ));
                        }
                        let packets = wreck.salvage();
                        if packets.len() != live.len() {
                            return Err(format!(
                                "salvage exported {} packets for {} in-flight rows",
                                packets.len(),
                                live.len()
                            ));
                        }
                        let resident_before = healthy.state_arena().resident_bytes();
                        let mut moved = 0u64;
                        for p in packets {
                            let id = p.seq();
                            if suspect.contains(&id) && p.state_bytes() != 0 {
                                return Err(format!("suspect row {id} exported state"));
                            }
                            if p.state_bytes() > 0 {
                                if p.state_bytes() != bytes_per_seq {
                                    return Err("payload != state_bytes_per_seq".into());
                                }
                                moved += 1;
                                total_state_salvages += 1;
                                if healthy.attach(p).is_err() {
                                    return Err(format!("salvaged packet {id} refused"));
                                }
                            } else {
                                total_reprefill_salvages += 1;
                                healthy.attach_reprefill(p);
                            }
                        }
                        if healthy.state_arena().resident_bytes()
                            != resident_before + moved * bytes_per_seq
                        {
                            return Err(
                                "survivor gauge did not track salvage attaches".into()
                            );
                        }
                        live.clear();
                    }
                }
            }
            for resp in healthy.tick().unwrap().0 {
                out.insert(resp.id, resp.tokens);
            }
            let pending =
                faulty.as_ref().map_or(0, |f| f.pending()) + healthy.pending();
            if pending == 0 {
                break;
            }
        }

        if out != want {
            return Err(format!(
                "tokens diverged across worker death + salvage: {out:?} vs {want:?}"
            ));
        }
        Ok(())
    });
    // The suite must actually exercise the machinery it claims to
    // verify — deaths, state-carrying salvage, and the re-prefill
    // fallback for suspect/stateless rows.
    assert!(total_faults > 0, "no injected fault ever fired");
    assert!(total_state_salvages > 0, "no salvage ever carried state");
    assert!(total_reprefill_salvages > 0, "no salvage ever fell back to re-prefill");
}

#[test]
fn server_reprefill_mode_serves_identically_with_replay_counters() {
    let probe = MockEngine::new();
    let (vocab, plen) = (probe.manifest().vocab, probe.manifest().prefill_len);
    let serve = |mode: MigrationMode| {
        let factories: Vec<fn() -> anyhow::Result<MockEngine>> =
            vec![|| Ok(MockEngine::new()), || Ok(MockEngine::new())];
        let mut server = Server::start(factories, BatchPolicy::default());
        server.set_migration_mode(mode);
        let reqs = pinned_requests(4, vocab, plen);
        let rxs: Vec<_> = reqs.into_iter().map(|r| server.submit_to(r, 0)).collect();
        let mut landed = false;
        for attempt in 0..1_000_000u64 {
            let seq = attempt % 4;
            if let Some(from) = server.shard_map().shard_of(seq) {
                if server.force_migrate(seq, 1 - from) {
                    landed = true;
                    break;
                }
            }
            std::thread::yield_now();
        }
        assert!(landed, "no migration landed");
        let mut tokens: Vec<(u64, Vec<i32>)> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap())
            .map(|r| (r.id, r.tokens))
            .collect();
        tokens.sort();
        let t = server.traffic();
        server.shutdown();
        (tokens, t)
    };
    let (moved_tokens, moved) = serve(MigrationMode::Move);
    let (replay_tokens, replayed) = serve(MigrationMode::Reprefill);
    assert_eq!(moved_tokens, replay_tokens, "migration mode changed tokens");
    assert!(moved.bytes_migrated > 0);
    assert_eq!(moved.reprefill_tokens, 0);
    assert_eq!(replayed.bytes_migrated, 0);
    assert!(replayed.reprefill_tokens > 0);
}

#[test]
fn prop_trace_reconciles_under_randomized_migrations() {
    // The reconciliation property from `mambalaya::obs`, under the
    // nastiest scheduler-level churn this suite can produce: random
    // policies, random workloads, and forced cross-shard moves at
    // random ticks. Per-shard the books are lopsided by design (a
    // migrated span starts hot and terminates cold), so the law is
    // stated over the *combined* trace and the *accumulated*
    // counters: every launch's device calls and staged bytes, every
    // migration, every completion — accounted exactly, with one
    // terminal event per request span, and every landed move visible
    // as a shard-crossing in its assembled span.
    let probe = MockEngine::new();
    let (vocab, plen) = (probe.manifest().vocab, probe.manifest().prefill_len);
    let mut total_migrations = 0u64;
    check("trace/counter reconciliation under migration churn", 16, |rng| {
        let policy = BatchPolicy {
            chunk_tokens: rng.range(0, 6) as usize,
            token_budget: rng.range(1, 24) as usize,
            max_chunk_rows: rng.range(1, 5) as usize,
            max_running: rng.range(1, 8) as usize,
            decode_priority_threshold: rng.range(1, 10) as usize,
        };
        let mut gen = WorkloadGen::new(rng.next_u64(), vocab, plen, 2, 12)
            .with_prompt_range(1, 3 * plen);
        let reqs: Vec<Request> =
            (0..rng.range(2, 8)).map(|_| gen.next_request()).collect();

        let mut shards = vec![
            Scheduler::new(MockEngine::new(), policy.clone()),
            Scheduler::new(MockEngine::new(), policy.clone()),
        ];
        shards[0].set_shard(0);
        shards[1].set_shard(1);
        let mut placement: BTreeMap<u64, usize> = BTreeMap::new();
        for (i, r) in reqs.iter().enumerate() {
            placement.insert(r.id, i % 2);
            shards[i % 2].submit(r.clone()).unwrap();
        }

        let mut migrated: BTreeSet<u64> = BTreeSet::new();
        let mut done = 0u64;
        let mut guard = 0u32;
        while shards.iter().map(|s| s.pending()).sum::<usize>() > 0 {
            guard += 1;
            assert!(guard < 100_000, "sharded serve did not drain");
            for s in shards.iter_mut() {
                for resp in s.tick().unwrap().0 {
                    placement.remove(&resp.id);
                    done += 1;
                }
            }
            if guard % 2 == 0 && !placement.is_empty() {
                let live: Vec<u64> = placement.keys().copied().collect();
                let seq = live[rng.below(live.len() as u64) as usize];
                let from = placement[&seq];
                if let Some(p) = shards[from].detach(seq) {
                    shards[1 - from].attach(p).expect("well-formed packet attaches");
                    placement.insert(seq, 1 - from);
                    migrated.insert(seq);
                }
            }
        }
        total_migrations += migrated.len() as u64;

        // The law is cross-shard: combine the traces, accumulate the
        // counters, then reconcile.
        let mut trace = Vec::new();
        let mut combined = TrafficSnapshot::default();
        for s in shards.iter_mut() {
            assert_eq!(s.trace_dropped(), 0, "trace ring overflowed");
            trace.extend(s.take_trace());
            combined.accumulate(&s.metrics().traffic_snapshot());
        }
        reconcile(&trace, &combined)
            .map_err(|e| format!("reconciliation failed under churn: {e}"))?;

        let spans = assemble_spans(&trace);
        if spans.len() != reqs.len() {
            return Err(format!("{} spans for {} requests", spans.len(), reqs.len()));
        }
        if combined.requests_completed != done {
            return Err(format!(
                "counted {} completions, drained {done}",
                combined.requests_completed
            ));
        }
        for span in &spans {
            if migrated.contains(&span.seq) && span.shards.len() < 2 {
                return Err(format!(
                    "seq {} migrated but its span never crossed a shard: {:?}",
                    span.seq, span.shards
                ));
            }
        }
        Ok(())
    });
    assert!(total_migrations > 0, "no forced migration ever landed");
}

/// Pump `supervise` while waiting on a sink, so a worker death gets
/// detected and recovered instead of stalling the receive forever.
fn recv_supervised(server: &mut Server, rx: &Receiver<Response>) -> Response {
    let deadline = Instant::now() + Duration::from_secs(30);
    while Instant::now() < deadline {
        server.supervise();
        match rx.recv_timeout(Duration::from_millis(2)) {
            Ok(r) => return r,
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => {
                panic!("sink dropped without a terminal response")
            }
        }
    }
    panic!("no response within 30s of supervised pumping");
}

#[test]
fn prop_trace_reconciles_across_fault_storm_worker_kill() {
    // The same law across the kill path: a randomized fail-once fault
    // takes a worker down mid-flight, the supervisor salvages the
    // wreck and respawns within the restart cap. The dead
    // incarnation's trace and counters must ride into the server
    // totals — so reconciliation holds across the death, the Fault
    // (and, when flights carried state, Salvaged) records survive,
    // and every request span still ends in exactly one terminal
    // event.
    let probe = MockEngine::new();
    let (vocab, plen) = (probe.manifest().vocab, probe.manifest().prefill_len);
    let mut total_salvaged = 0u64;
    check("trace/counter reconciliation across a worker kill", 10, |rng| {
        let n_reqs = rng.range(3, 8) as usize;
        let mut gen = WorkloadGen::new(rng.next_u64(), vocab, plen, 8, 24)
            .with_prompt_range(1, 3 * plen);
        let reqs: Vec<Request> = (0..n_reqs).map(|_| gen.next_request()).collect();

        // Fail the k-th device call, once: early enough that flights
        // are still in the air, recoverable so every request finishes.
        let k = rng.range(1, 8);
        let inj = FaultInjector::new(FaultPlan::parse(&format!("once:{k}")).unwrap());
        let factory = {
            let inj = inj.clone();
            move || inj.wrap(MockEngine::new())
        };
        let mut server = Server::start(vec![factory], BatchPolicy::default());
        let rxs: Vec<_> = reqs.iter().map(|r| server.submit(r.clone())).collect();
        let responses: Vec<Response> =
            rxs.iter().map(|rx| recv_supervised(&mut server, rx)).collect();
        for r in &responses {
            if r.is_error() {
                return Err(format!("recoverable request {} failed: {:?}", r.id, r.error));
            }
        }

        let recover = server.resilience();
        if recover.workers_down != 1 || recover.worker_restarts != 1 {
            return Err(format!(
                "fail-once must kill and respawn exactly once: down={} restarts={}",
                recover.workers_down, recover.worker_restarts
            ));
        }
        total_salvaged += recover.requests_salvaged;

        let events = server.trace();
        if !events.iter().any(|r| matches!(r.event, TraceEvent::Fault)) {
            return Err("dead worker's Fault record lost".into());
        }
        if recover.requests_salvaged > 0
            && !events.iter().any(|r| matches!(r.event, TraceEvent::Salvaged { .. }))
        {
            return Err("salvaged flights left no Salvaged record".into());
        }
        let snap = server.traffic();
        reconcile(&events, &snap)
            .map_err(|e| format!("reconciliation failed across the kill: {e}"))?;
        let spans = assemble_spans(&events);
        if spans.len() != n_reqs {
            return Err(format!("{} spans for {n_reqs} requests", spans.len()));
        }
        if snap.requests_completed != n_reqs as u64 {
            return Err(format!(
                "counted {} completions for {n_reqs} requests",
                snap.requests_completed
            ));
        }
        server.shutdown();
        Ok(())
    });
    assert!(total_salvaged > 0, "the storm never salvaged an in-flight request");
}
