//! Property tests for the `planner/` subsystem — the bridge from the
//! analytical fusion model into the serving loop.
//!
//! * **Monotonicity** (exchange property): as the prefill share of a
//!   tick grows, every plan switch wins its bucket, never sacrifices
//!   prefill beyond its decode gain, and walks monotonically toward
//!   relatively prefill-better plans — the argmin's exchange
//!   inequalities, checked over the autotune grid.
//! * **Hysteresis**: a workload alternating between buckets with
//!   different argmins thrashes a dwell-1 planner but not a dwell-4
//!   planner, and the executed plan is always a recently-optimal one.
//! * **Adaptive ≡ static**: plan choice must never change sampled
//!   tokens — the full scheduler serves bit-identical streams under
//!   every plan spec, including a table loaded from disk.
//! * **Golden `PlanTable`**: the quick autotune grid is byte-stable
//!   (blessed on first run, compared forever after — same protocol as
//!   the fusion-plan golden).
//! * **Predictor sanity**: on the mock engine, modeled cost stays
//!   within 2× of predicted (CI's predictor-sanity gate), and the
//!   adaptive planner's counters are never worse than any static
//!   plan's on the interference scenario.

use std::path::PathBuf;

use mambalaya::arch::ArchSpec;
use mambalaya::bench_util::ServeScenario;
use mambalaya::cascade::ModelConfig;
use mambalaya::coordinator::{Scheduler, StatePath, TrafficSnapshot};
use mambalaya::fusion::FusionVariant;
use mambalaya::planner::{
    autotune, CostModel, PlanBucket, PlanChoice, Planner, PlanSpec, PlanTable, WorkloadFeatures,
};
use mambalaya::runtime::MockEngine;

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden/plan_table_quick.json")
}

fn quick_table() -> PlanTable {
    autotune(&ModelConfig::mamba_370m(), &ArchSpec::mambalaya(), true)
}

#[test]
fn monotonicity_growing_prefill_never_trades_against_prefill() {
    // The sound (exchange-argument) form of "growing prefill share
    // never selects a strictly decode-better variant": let v1 be the
    // choice at (D, P1) and v2 at (D, P2) with P2 > P1. Optimality at
    // both points forces, for every switch v1 → v2:
    //
    //  (a) v2 actually wins the new bucket (argmin is implemented
    //      right): dc2 + pc2(P2) ≤ dc1 + pc1(P2);
    //  (b) if v2 is strictly decode-better, any prefill-cost regression
    //      it brings is bounded by the decode gain:
    //      pc2(P2) − pc1(P2) ≤ dc1 − dc2 — the switch can never be a
    //      pure prefill sacrifice;
    //  (c) the prefill-cost gap of v2 vs v1 shrinks as P grows
    //      (v2 is relatively more prefill-efficient at the larger
    //      share) — so repeated growth can only walk toward
    //      prefill-better plans, never oscillate away from them.
    let mut m = CostModel::default_serving();
    let prefills = [0usize, 16, 64, 256, 1024, 4096];
    for d in [0usize, 1, 4, 8, 16] {
        let mut prev: Option<(PlanChoice, usize)> = None;
        for &p in &prefills {
            let bucket = PlanBucket { decode_rows: d, prefill_tokens: p };
            let (v2, _) = m.best(bucket);
            if let Some((v1, p1)) = prev {
                if v2 != v1 {
                    let dc1 = m.decode_cost(v1, d).cycles as i128;
                    let dc2 = m.decode_cost(v2, d).cycles as i128;
                    let pc1 = m.prefill_cost(v1, p).cycles as i128;
                    let pc2 = m.prefill_cost(v2, p).cycles as i128;
                    // (a) the switch wins the bucket.
                    assert!(
                        dc2 + pc2 <= dc1 + pc1,
                        "at D={d} P={p}: chosen {} loses to previous {}",
                        v2.name(),
                        v1.name()
                    );
                    // (b) decode gain bounds any prefill regression.
                    if dc2 < dc1 {
                        assert!(
                            pc2 - pc1 <= dc1 - dc2,
                            "at D={d} P={p1}→{p}: {}→{} sacrificed prefill \
                             beyond its decode gain",
                            v1.name(),
                            v2.name()
                        );
                    }
                    // (c) gap-shrink across the growth step.
                    let pc1_old = m.prefill_cost(v1, p1).cycles as i128;
                    let pc2_old = m.prefill_cost(v2, p1).cycles as i128;
                    assert!(
                        pc2 - pc1 <= pc2_old - pc1_old,
                        "at D={d}: prefill-cost gap of {} vs {} grew with P",
                        v2.name(),
                        v1.name()
                    );
                }
            }
            prev = Some((v2, p));
        }
    }
}

#[test]
fn phase_flip_is_observable_in_selection() {
    // Prefill-heavy picks the fully-fused mapping (the paper's prefill
    // winner, pinned by the model-layer tests); batched decode does
    // not — the RD bridge's per-token H round-trip scales with batch.
    let mut m = CostModel::default_serving();
    let (pre, _) = m.best(PlanBucket { decode_rows: 0, prefill_tokens: 4096 });
    let (dec, _) = m.best(PlanBucket { decode_rows: 8, prefill_tokens: 0 });
    assert_eq!(pre, PlanChoice::Variant(FusionVariant::FullyFused));
    assert_ne!(dec, pre);
}

#[test]
fn hysteresis_prevents_thrashing_on_alternating_workload() {
    let decode_tick = WorkloadFeatures::from_tick(&[], 8, 0, 16);
    let prefill_tick = WorkloadFeatures::from_tick(&[4096], 0, 0, 4096);
    // Sanity: the two buckets genuinely want different plans.
    {
        let mut m = CostModel::default_serving();
        assert_ne!(m.best(decode_tick.bucket()).0, m.best(prefill_tick.bucket()).0);
    }
    let run = |dwell: u64| -> (u64, Vec<PlanChoice>) {
        let mut p = Planner::with_dwell(PlanSpec::Adaptive, dwell);
        let mut switches = 0;
        let mut executed = Vec::new();
        for i in 0..100 {
            let f = if i % 2 == 0 { decode_tick } else { prefill_tick };
            let d = p.decide(&f);
            switches += d.switched as u64;
            executed.push(d.choice);
        }
        (switches, executed)
    };
    let (free, _) = run(1);
    let (damped, executed) = run(4);
    assert!(free >= 50, "dwell-1 must thrash on an alternating workload: {free} switches");
    assert!(damped <= 100 / 4 + 1, "dwell-4 must cap switching: {damped} switches");
    // The damped planner still only ever executes plans that are
    // optimal for one of the two alternating buckets.
    let mut m = CostModel::default_serving();
    let ok = [m.best(decode_tick.bucket()).0, m.best(prefill_tick.bucket()).0];
    assert!(executed.iter().all(|c| ok.contains(c)));
}

/// Serve the interference scenario under a plan policy; return sorted
/// token streams and the counter snapshot.
fn serve_interference(planner: Planner) -> (Vec<Vec<i32>>, TrafficSnapshot) {
    let sc = ServeScenario::interference();
    let vocab = MockEngine::new().manifest().vocab;
    let mut s = Scheduler::with_planner(
        MockEngine::new(),
        sc.policy.clone(),
        StatePath::Resident,
        planner,
    );
    for r in sc.requests(vocab) {
        s.submit(r).unwrap();
    }
    let mut resps = s.run_until_drained().unwrap();
    resps.sort_by_key(|r| r.id);
    (resps.into_iter().map(|r| r.tokens).collect(), s.metrics().traffic_snapshot())
}

#[test]
fn adaptive_equals_static_token_outputs_including_table() {
    let (adaptive_tokens, _) = serve_interference(Planner::new(PlanSpec::Adaptive));
    for choice in PlanChoice::candidates() {
        let (tokens, snap) = serve_interference(Planner::new(PlanSpec::Static(choice)));
        assert_eq!(
            adaptive_tokens,
            tokens,
            "static:{} changed sampled tokens",
            choice.name()
        );
        // A static run executes exactly one plan, never switches.
        assert_eq!(snap.plan_switches, 0);
        assert_eq!(
            snap.ticks_per_plan.iter().sum::<u64>(),
            snap.ticks_per_plan[choice.index()]
        );
    }
    // Table mode too: freeze the quick grid to disk, load it back,
    // serve from it.
    let dir = std::env::temp_dir().join(format!("mambalaya_planner_test_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("plan_table.json");
    quick_table().save(path.to_str().unwrap()).unwrap();
    let spec = PlanSpec::parse(&format!("table:{}", path.display())).unwrap();
    let (tokens, snap) = serve_interference(Planner::new(spec));
    assert_eq!(adaptive_tokens, tokens, "table mode changed sampled tokens");
    assert!(snap.ticks_per_plan.iter().sum::<u64>() > 0);
}

#[test]
fn adaptive_counters_never_worse_than_any_static() {
    // The acceptance gate, in test form: on the mixed interference
    // scenario, a dwell-1 adaptive planner (pure per-bucket argmin)
    // has modeled cycles ≤ every static plan — the per-tick argmin of
    // the same deterministic counter can never lose to a fixed choice.
    let (_, adaptive) = serve_interference(Planner::with_dwell(PlanSpec::Adaptive, 1));
    assert!(adaptive.modeled_cycles > 0);
    for choice in PlanChoice::candidates() {
        let (_, snap) = serve_interference(Planner::new(PlanSpec::Static(choice)));
        assert!(
            adaptive.modeled_cycles <= snap.modeled_cycles,
            "adaptive {} > static:{} {}",
            adaptive.modeled_cycles,
            choice.name(),
            snap.modeled_cycles
        );
    }
}

#[test]
fn predictor_within_2x_of_modeled_on_mock() {
    // CI's predictor-sanity gate: the planner's per-tick predictions
    // and the mock's modeled charges come from the same analytical
    // model at the same bucket granularity, so the totals must agree
    // well within the 2× bound (they differ only through dwell-lag
    // ticks and engine-side classification).
    for planner in [
        Planner::new(PlanSpec::Adaptive),
        Planner::with_dwell(PlanSpec::Adaptive, 1),
    ] {
        let (_, snap) = serve_interference(planner);
        assert!(snap.predicted_cycles > 0 && snap.modeled_cycles > 0);
        let err = snap.prediction_error();
        assert!((0.5..=2.0).contains(&err), "prediction error {err:.3} outside 2x");
        let byte_err = snap.modeled_bytes as f64 / snap.predicted_bytes.max(1) as f64;
        assert!((0.5..=2.0).contains(&byte_err), "byte error {byte_err:.3} outside 2x");
    }
}

#[test]
fn plan_table_quick_grid_is_byte_stable() {
    // Golden snapshot of the autotuned quick PlanTable — the frozen
    // form of the adaptive policy. Blessed on first run (or with
    // UPDATE_GOLDEN=1); any cost-model drift fails with a diff hint.
    let rendered = format!("{}\n", quick_table().to_json());
    let path = golden_path();
    let bless = std::env::var("UPDATE_GOLDEN").is_ok() || !path.exists();
    if bless {
        std::fs::create_dir_all(path.parent().unwrap()).expect("mkdir golden");
        std::fs::write(&path, &rendered).expect("write golden");
        eprintln!(
            "blessed golden plan table at {} — COMMIT this file; ci.sh re-runs this test \
             and fails while it is untracked",
            path.display()
        );
        return;
    }
    let want = std::fs::read_to_string(&path).expect("read golden");
    assert_eq!(
        rendered,
        want,
        "autotuned plan table drifted vs {} (rerun with UPDATE_GOLDEN=1 to rebless)",
        path.display()
    );
    // And the blessed artifact must round-trip through the loader.
    let loaded = PlanTable::load(path.to_str().unwrap()).unwrap();
    assert_eq!(loaded, quick_table());
}
