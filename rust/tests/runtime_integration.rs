//! Integration: the real PJRT engine must load the AOT artifacts,
//! execute them, and reproduce the golden vectors exported by
//! python/compile/aot.py — proving the three layers compose with
//! python absent at runtime.
//!
//! Skipped (with a note) when `artifacts/` has not been built.

use std::path::PathBuf;

use mambalaya::coordinator::{serve_all, BatchPolicy, WorkloadGen};
use mambalaya::runtime::{argmax_rows, Executor, Golden, MambaEngine, Manifest};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

#[test]
fn engine_reproduces_golden_prefill_and_decode() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let dir = artifacts_dir();
    let engine = MambaEngine::load(&dir).expect("engine load");
    let golden = Golden::load(&dir).expect("golden load");
    let m = engine.manifest().clone();

    // Prefill the golden 2×L prompt batch.
    let out = engine.prefill(2, &golden.prefill_tokens).expect("prefill");
    assert_eq!(out.logits.len(), 2 * m.vocab);
    // Logits sample (first 8 per row).
    for row in 0..2 {
        for k in 0..8 {
            let got = out.logits[row * m.vocab + k];
            let want = golden.prefill_logits_sample[row * 8 + k];
            assert!(
                (got - want).abs() < 1e-3 + want.abs() * 1e-3,
                "prefill logits[{row},{k}]: got {got}, want {want}"
            );
        }
    }
    // Argmax agreement.
    let am = argmax_rows(&out.logits, m.vocab);
    assert_eq!(
        am.iter().map(|&x| x as i64).collect::<Vec<_>>(),
        golden.prefill_logits_argmax
    );

    // Decode one golden step from the prefilled state.
    let out2 = engine
        .decode(2, &golden.decode_token, &out.conv_state, &out.ssm_state)
        .expect("decode");
    for row in 0..2 {
        for k in 0..8 {
            let got = out2.logits[row * m.vocab + k];
            let want = golden.decode_logits_sample[row * 8 + k];
            assert!(
                (got - want).abs() < 1e-3 + want.abs() * 1e-3,
                "decode logits[{row},{k}]: got {got}, want {want}"
            );
        }
    }
    let am2 = argmax_rows(&out2.logits, m.vocab);
    assert_eq!(
        am2.iter().map(|&x| x as i64).collect::<Vec<_>>(),
        golden.decode_logits_argmax
    );
    // State checksum.
    let sum: f64 = out2.ssm_state.iter().map(|&x| x as f64).sum();
    assert!(
        (sum - golden.ssm_state_sum).abs() < 1e-2 + golden.ssm_state_sum.abs() * 1e-4,
        "ssm state sum: got {sum}, want {}",
        golden.ssm_state_sum
    );
}

#[test]
fn serving_through_real_engine_is_batch_invariant() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(artifacts_dir()).unwrap();
    let (vocab, plen) = (manifest.vocab, manifest.prefill_len);
    let mut gen = WorkloadGen::new(77, vocab, plen, 3, 3);
    let reqs: Vec<_> = (0..3).map(|_| gen.next_request()).collect();

    // Solo generation per request.
    let mut solo = Vec::new();
    for r in &reqs {
        let (resp, _) = serve_all(
            || MambaEngine::load(artifacts_dir()),
            BatchPolicy::default(),
            vec![r.clone()],
        )
        .unwrap();
        solo.push(resp[0].tokens.clone());
    }

    // Batched generation.
    let (mut batched, report) = serve_all(
        || MambaEngine::load(artifacts_dir()),
        BatchPolicy::default(),
        reqs,
    )
    .unwrap();
    batched.sort_by_key(|r| r.id);
    for (resp, want) in batched.iter().zip(&solo) {
        assert_eq!(&resp.tokens, want, "request {} diverged under batching", resp.id);
    }
    assert!(report.contains("requests=3"), "{report}");
}
