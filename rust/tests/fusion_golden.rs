//! Golden snapshot of the `fusion::stitch` plans for the Mamba-1
//! prefill and generation cascades — the paper-reproduction path the
//! coordinator work must not disturb.
//!
//! The canonical [`FusionPlan`] rendering (its `Display` impl) for
//! every fusion variant is compared byte-for-byte against
//! `rust/tests/golden/mamba1_fusion_plans.txt`. On the first run (or
//! with `UPDATE_GOLDEN=1`) the snapshot is (re)blessed; afterwards any
//! change to stitching, class assignment, stationarity or
//! internal-tensor analysis fails with a diff hint. Structural facts
//! from the paper (§IV group counts 24/12/8/3/1) are asserted
//! unconditionally so the test has teeth even while blessing.

use std::path::PathBuf;

use mambalaya::cascade::{mamba1, ModelConfig};
use mambalaya::fusion::{stitch, FusionVariant};

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("rust/tests/golden/mamba1_fusion_plans.txt")
}

/// Render every (cascade, variant) plan deterministically.
fn render_all() -> String {
    let cfg = ModelConfig::mamba_370m();
    let mut out = String::new();
    // Prefill (long sequence) and generation (seq 1, batched) — the
    // paper's two serving regimes (Figure 12).
    for (label, seq, batch) in [("prefill", 4096u64, 1u64), ("generation", 1, 64)] {
        let c = mamba1::build(&cfg, seq, batch);
        out.push_str(&format!("== mamba1/{label} seq={seq} batch={batch} ==\n"));
        for v in FusionVariant::all() {
            let plan = stitch(&c, v);
            plan.validate(&c).expect("plan must validate");
            out.push_str(&plan.to_string());
        }
        out.push('\n');
    }
    out
}

#[test]
fn mamba1_plan_group_counts_match_paper() {
    // §IV: 24 (unfused) → 12 (RI) → 8 (RI+RSb) → 3 (RI+RSb+RSp) → 1
    // (fully fused), for the prefill cascade.
    let c = mamba1::build(&ModelConfig::mamba_370m(), 4096, 1);
    let counts: Vec<usize> =
        FusionVariant::all().iter().map(|&v| stitch(&c, v).groups.len()).collect();
    assert_eq!(counts, vec![24, 12, 8, 3, 1]);
}

#[test]
fn mamba1_fusion_plans_are_byte_stable() {
    let rendered = render_all();
    let path = golden_path();
    let bless = std::env::var("UPDATE_GOLDEN").is_ok() || !path.exists();
    if bless {
        std::fs::create_dir_all(path.parent().unwrap()).expect("mkdir golden");
        std::fs::write(&path, &rendered).expect("write golden");
        eprintln!(
            "blessed golden snapshot at {} — COMMIT this file; ci.sh re-runs this test \
             and fails while it is untracked",
            path.display()
        );
        return;
    }
    let want = std::fs::read_to_string(&path).expect("read golden");
    if rendered != want {
        // Point at the first diverging line for a usable failure.
        for (i, (a, b)) in rendered.lines().zip(want.lines()).enumerate() {
            assert_eq!(
                a,
                b,
                "fusion plan drifted at line {} of {} (rerun with UPDATE_GOLDEN=1 to rebless)",
                i + 1,
                path.display()
            );
        }
        panic!(
            "fusion plan length drifted: {} vs {} lines (rerun with UPDATE_GOLDEN=1 to rebless)",
            rendered.lines().count(),
            want.lines().count()
        );
    }
}
