//! Chunked-prefill continuous batching, tested hermetically against
//! `runtime::mock`:
//!
//! * chunked and monolithic prefill produce **identical tokens**;
//! * no sequence starves under a long-prompt flood (decode advances
//!   every tick that has running sequences, the per-tick token cost
//!   stays within budget, and everything completes);
//! * metrics counters (TTFT count, queue depth samples, token/chunk
//!   counters) are monotone and consistent with the served workload;
//! * `Batcher` invariants, property-tested in `prop.rs` style: the
//!   token budget is never exceeded, admission is strict-FIFO (always
//!   a prefix of the waiting queue), at most one chunk per sequence
//!   per tick, and every committed chunk advances its cursor.

use mambalaya::coordinator::{
    Action, Batcher, BatchPolicy, Request, Scheduler, WorkloadGen,
};
use mambalaya::prop::check;
use mambalaya::runtime::MockEngine;
use mambalaya::util::XorShift;

fn run_tokens(policy: BatchPolicy, reqs: &[Request]) -> Vec<Vec<i32>> {
    let mut s = Scheduler::new(MockEngine::new(), policy);
    for r in reqs {
        s.submit(r.clone()).unwrap();
    }
    let mut out = s.run_until_drained().unwrap();
    out.sort_by_key(|r| r.id);
    out.into_iter().map(|r| r.tokens).collect()
}

#[test]
fn chunked_prefill_is_token_identical_to_monolithic() {
    // The tentpole equivalence: splitting prompts into chunks (any
    // chunk size, any budget) must not change a single sampled token
    // relative to whole-prompt prefill.
    let probe = MockEngine::new();
    let (vocab, plen) = (probe.manifest().vocab, probe.manifest().prefill_len);
    let mut gen = WorkloadGen::new(2025, vocab, plen, 1, 8).with_prompt_range(1, 4 * plen);
    let reqs: Vec<Request> = (0..12).map(|_| gen.next_request()).collect();

    let monolithic = BatchPolicy {
        chunk_tokens: 0,
        token_budget: 1 << 20,
        ..BatchPolicy::default()
    };
    let reference = run_tokens(monolithic, &reqs);

    for chunk_tokens in [1usize, 2, 3, 5, 8] {
        let chunked = BatchPolicy {
            chunk_tokens,
            token_budget: 12,
            max_chunk_rows: 3,
            ..BatchPolicy::default()
        };
        let got = run_tokens(chunked, &reqs);
        assert_eq!(
            got, reference,
            "tokens diverged between chunk_tokens={chunk_tokens} and monolithic prefill"
        );
    }
}

#[test]
fn no_starvation_under_long_prompt_flood() {
    let policy = BatchPolicy {
        chunk_tokens: 4,
        token_budget: 12,
        max_chunk_rows: 2,
        max_running: 6,
        decode_priority_threshold: 6,
    };
    let mut s = Scheduler::new(MockEngine::new(), policy.clone());

    // Three short-prompt long-generation requests get running first.
    for id in 0..3u64 {
        s.submit(Request { id, prompt: vec![1 + id as i32; 2], max_new_tokens: 25 }).unwrap();
    }
    s.tick().unwrap();

    // Then a flood of long prompts arrives.
    for id in 10..16u64 {
        let prompt: Vec<i32> = (0..60).map(|x| (x + id as i32) % 17).collect();
        s.submit(Request { id, prompt, max_new_tokens: 2 }).unwrap();
    }

    // Drive to completion: whenever sequences are running, decode must
    // advance every tick — the flood can never stall generation for a
    // full tick.
    let mut completed = 0usize;
    let mut guard = 0usize;
    while s.pending() > 0 {
        let running_before = s.running();
        let tokens_before = s.metrics().tokens_generated;
        let (done, progressed) = s.tick().unwrap();
        assert!(progressed, "scheduler stalled with work pending");
        if running_before > 0 {
            assert!(
                s.metrics().tokens_generated > tokens_before,
                "decode starved while {running_before} sequences were running"
            );
        }
        completed += done.len();
        guard += 1;
        assert!(guard < 10_000, "runaway tick loop");
    }
    assert_eq!(completed, 9);
    // The per-tick token cost respected the budget throughout.
    assert!(
        s.metrics().max_tick_tokens <= policy.token_budget as u64,
        "tick exceeded budget: {} > {}",
        s.metrics().max_tick_tokens,
        policy.token_budget
    );
}

#[test]
fn metrics_are_monotone_and_consistent() {
    let policy = BatchPolicy {
        chunk_tokens: 3,
        token_budget: 10,
        max_chunk_rows: 2,
        max_running: 4,
        decode_priority_threshold: 4,
    };
    let probe = MockEngine::new();
    let (vocab, plen) = (probe.manifest().vocab, probe.manifest().prefill_len);
    let mut gen = WorkloadGen::new(77, vocab, plen, 1, 6).with_prompt_range(1, 3 * plen);
    let reqs: Vec<Request> = (0..10).map(|_| gen.next_request()).collect();
    let want_prompt: u64 = reqs.iter().map(|r| r.prompt.len() as u64).sum();
    let want_tokens: u64 = reqs.iter().map(|r| r.max_new_tokens as u64).sum();

    let mut s = Scheduler::new(MockEngine::new(), policy);
    for r in &reqs {
        s.submit(r.clone()).unwrap();
    }

    let snapshot = |s: &Scheduler<MockEngine>| -> Vec<u64> {
        let m = s.metrics();
        vec![
            m.tokens_generated,
            m.prefill_chunks,
            m.prefill_tokens,
            m.decode_steps,
            m.ticks,
            m.max_tick_tokens,
            m.requests_completed,
            m.ttft_count() as u64,
        ]
    };

    let mut prev = snapshot(&s);
    let mut guard = 0usize;
    while s.pending() > 0 {
        s.tick().unwrap();
        let cur = snapshot(&s);
        for (i, (a, b)) in prev.iter().zip(&cur).enumerate() {
            assert!(b >= a, "metric #{i} decreased: {a} -> {b}");
        }
        prev = cur;
        guard += 1;
        assert!(guard < 10_000, "runaway tick loop");
    }

    let m = s.metrics();
    assert_eq!(m.prefill_tokens, want_prompt, "every prompt token prefilled exactly once");
    assert_eq!(m.tokens_generated, want_tokens, "every requested token generated");
    assert_eq!(m.requests_completed, 10);
    assert_eq!(m.ttft_count(), 10);
    assert!(m.max_tick_tokens <= 10);
    assert!(m.mean_queue_depth() >= 0.0);
    assert!(m.report().contains("requests=10"));
}

// ---------------------------------------------------------------------
// Batcher property tests (prop.rs style).

fn random_policy(rng: &mut XorShift) -> BatchPolicy {
    BatchPolicy {
        chunk_tokens: rng.range(0, 6) as usize,
        token_budget: rng.range(1, 24) as usize,
        max_chunk_rows: rng.range(1, 5) as usize,
        max_running: rng.range(1, 8) as usize,
        decode_priority_threshold: rng.range(1, 10) as usize,
    }
}

/// Build a batcher with some jobs, some mid-prefill (via committed
/// rounds), and return it plus the in-order waiting ids.
fn random_batcher(rng: &mut XorShift) -> Batcher {
    let mut b = Batcher::new(random_policy(rng));
    for id in 0..rng.range(0, 8) {
        b.enqueue(id, rng.range(1, 40) as usize);
    }
    // A few committed rounds leave realistic mid-prefill cursors.
    for _ in 0..rng.range(0, 4) {
        if let Action::Mixed { chunks, .. } = b.next_action(rng.range(0, 6) as usize) {
            b.commit(&chunks);
        }
    }
    b
}

#[test]
fn prop_batcher_token_budget_never_exceeded() {
    check("batcher budget", 200, |rng| {
        let b = random_batcher(rng);
        let running = rng.range(0, 12) as usize;
        if let Action::Mixed { chunks, decode } = b.next_action(running) {
            let cost = decode + chunks.iter().map(|c| c.len).sum::<usize>();
            let budget = b.policy().token_budget;
            if cost > budget {
                return Err(format!("cost {cost} > budget {budget}"));
            }
            if chunks.len() > b.policy().max_chunk_rows {
                return Err(format!("{} chunk rows > cap", chunks.len()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_batcher_admission_is_fifo_prefix() {
    check("batcher fifo", 200, |rng| {
        let b = random_batcher(rng);
        // Reconstruct queue order from cursors: ids were enqueued in
        // increasing order and never reordered, so the waiting ids in
        // ascending order are the FIFO order.
        let fifo: Vec<u64> = (0..64).filter(|id| b.cursor(*id).is_some()).collect();
        let running = rng.range(0, 12) as usize;
        if let Action::Mixed { chunks, .. } = b.next_action(running) {
            // Strict FIFO: admitted ids are exactly the queue prefix.
            let admitted: Vec<u64> = chunks.iter().map(|c| c.id).collect();
            if admitted.as_slice() != &fifo[..admitted.len()] {
                return Err(format!("admitted {admitted:?} is not a prefix of {fifo:?}"));
            }
            // At most one chunk per sequence per tick.
            let mut ids = admitted.clone();
            ids.dedup();
            if ids.len() != admitted.len() {
                return Err(format!("duplicate sequence in one tick: {admitted:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_batcher_chunks_advance_cursors() {
    check("batcher cursor advance", 200, |rng| {
        let mut b = random_batcher(rng);
        for _ in 0..6 {
            let running = rng.range(0, 6) as usize;
            match b.next_action(running) {
                Action::Mixed { chunks, .. } => {
                    let before: Vec<(u64, usize, usize, bool)> = chunks
                        .iter()
                        .map(|c| (c.id, b.cursor(c.id).unwrap_or(usize::MAX), c.len, c.last))
                        .collect();
                    for (c, (_, cur, _, _)) in chunks.iter().zip(&before) {
                        if c.len == 0 {
                            return Err("zero-length chunk admitted".into());
                        }
                        if c.start != *cur {
                            return Err(format!(
                                "chunk start {} != cursor {} for seq {}",
                                c.start, cur, c.id
                            ));
                        }
                    }
                    b.commit(&chunks);
                    for (id, cur, len, last) in before {
                        match b.cursor(id) {
                            // Completed prompts leave the queue.
                            None => {
                                if !last {
                                    return Err(format!(
                                        "seq {id} left the queue before its last chunk"
                                    ));
                                }
                            }
                            Some(now) => {
                                if now != cur + len {
                                    return Err(format!(
                                        "cursor for seq {id} advanced {cur} -> {now}, want {}",
                                        cur + len
                                    ));
                                }
                            }
                        }
                    }
                }
                Action::Idle => break,
            }
        }
        Ok(())
    });
}
