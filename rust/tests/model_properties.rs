//! Property tests on the analytical model: conservation, bounds, and
//! monotonicity invariants the cost model must obey for any workload
//! configuration.

use mambalaya::arch::{baseline_plan, ArchSpec, Baseline, Binding, Staging};
use mambalaya::cascade::{mamba1, ModelConfig};
use mambalaya::fusion::{stitch, FusionVariant};
use mambalaya::model::{evaluate, ideal_cost, ExecOptions};
use mambalaya::prop::check;
use mambalaya::util::XorShift;

fn random_workload(rng: &mut XorShift) -> (ModelConfig, u64, u64) {
    let cfg = match rng.below(4) {
        0 => ModelConfig::mamba_130m(),
        1 => ModelConfig::mamba_370m(),
        2 => ModelConfig::mamba_1_4b(),
        _ => ModelConfig::mamba_2_8b(),
    };
    let seq = 1u64 << rng.range(0, 14);
    let batch = 1u64 << rng.range(0, 6);
    (cfg, seq, batch)
}

#[test]
fn prop_flops_invariant_under_fusion() {
    // Fusion moves data, not math: total FLOPs must be identical across
    // all variants for the same workload.
    check("flops invariant", 40, |rng| {
        let (cfg, seq, batch) = random_workload(rng);
        let c = mamba1::build(&cfg, seq, batch);
        let arch = ArchSpec::mambalaya();
        let opts = ExecOptions::default();
        let base = evaluate(&c, &stitch(&c, FusionVariant::Unfused), &arch, &opts).flops;
        for v in FusionVariant::fused() {
            let f = evaluate(&c, &stitch(&c, v), &arch, &opts).flops;
            if f != base {
                return Err(format!("{v}: flops {f} != {base}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_latency_bounded_by_compute_and_memory() {
    // Latency ≥ both the pure-compute and pure-memory lower bounds, and
    // ≤ their sum per phase (the max/overlap model).
    check("latency bounds", 40, |rng| {
        let (cfg, seq, batch) = random_workload(rng);
        let c = mamba1::build(&cfg, seq, batch);
        let arch = ArchSpec::mambalaya();
        for v in FusionVariant::all() {
            let cost = evaluate(&c, &stitch(&c, v), &arch, &ExecOptions::default());
            for p in &cost.phases {
                let lower = p.cycles_2d.max(p.cycles_small).max(p.mem_cycles);
                let upper = p.cycles_2d + p.cycles_small + p.mem_cycles;
                if p.latency < lower || p.latency > upper {
                    return Err(format!(
                        "{v}: phase latency {} outside [{lower},{upper}]",
                        p.latency
                    ));
                }
            }
            let sum: u64 = cost.phases.iter().map(|p| p.latency).sum();
            if cost.latency != sum {
                return Err(format!("{v}: layer latency {} != Σ phases {sum}", cost.latency));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_ideal_never_slower() {
    check("ideal is a lower bound", 30, |rng| {
        let (cfg, seq, batch) = random_workload(rng);
        let c = mamba1::build(&cfg, seq, batch);
        let arch = ArchSpec::mambalaya();
        let opts = ExecOptions::default();
        for v in FusionVariant::all() {
            let plan = stitch(&c, v);
            let real = evaluate(&c, &plan, &arch, &opts);
            let ideal = ideal_cost(&c, &plan, &arch, &opts);
            if ideal.latency > real.latency {
                return Err(format!("{v}: ideal {} > real {}", ideal.latency, real.latency));
            }
            if ideal.traffic.inter() != 0 {
                return Err(format!("{v}: ideal keeps inter traffic"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pipelining_never_hurts() {
    check("pipelining helps", 30, |rng| {
        let (cfg, seq, batch) = random_workload(rng);
        let c = mamba1::build(&cfg, seq, batch);
        let arch = ArchSpec::mambalaya();
        for v in FusionVariant::all() {
            let plan = stitch(&c, v);
            let seqv = evaluate(&c, &plan, &arch, &ExecOptions::default());
            let pipe = evaluate(
                &c,
                &plan,
                &arch,
                &ExecOptions { pipelined: true, ..Default::default() },
            );
            if pipe.latency > seqv.latency {
                return Err(format!("{v}: pipelined {} > {}", pipe.latency, seqv.latency));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_traffic_scales_with_sequence() {
    // Longer sequences never reduce traffic (same variant, same model).
    check("traffic monotone in seq", 25, |rng| {
        let cfg = ModelConfig::mamba_370m();
        let s1 = 1u64 << rng.range(1, 8);
        let s2 = s1 * 2;
        let arch = ArchSpec::mambalaya();
        for v in FusionVariant::all() {
            let c1 = mamba1::build(&cfg, s1, 1);
            let c2 = mamba1::build(&cfg, s2, 1);
            let t1 = evaluate(&c1, &stitch(&c1, v), &arch, &ExecOptions::default()).traffic;
            let t2 = evaluate(&c2, &stitch(&c2, v), &arch, &ExecOptions::default()).traffic;
            if t2.total() < t1.total() {
                return Err(format!("{v}: traffic shrank {} → {}", t1.total(), t2.total()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_geens_never_slower_than_marca() {
    // Unit-tile staging dominates full-extent staging at any size.
    check("geens ≤ marca", 25, |rng| {
        let (cfg, seq, batch) = random_workload(rng);
        let c = mamba1::build(&cfg, seq, batch);
        let arch = ArchSpec::mambalaya();
        let marca = evaluate(
            &c,
            &baseline_plan(&c, Baseline::MarcaLike),
            &arch,
            &ExecOptions { staging: Staging::FullExtent, ..Default::default() },
        );
        let geens = evaluate(
            &c,
            &baseline_plan(&c, Baseline::GeensLike),
            &arch,
            &ExecOptions::default(),
        );
        if geens.latency > marca.latency {
            return Err(format!("geens {} > marca {}", geens.latency, marca.latency));
        }
        Ok(())
    });
}

#[test]
fn prop_utilization_in_unit_interval() {
    check("utilization ∈ [0,1]", 30, |rng| {
        let (cfg, seq, batch) = random_workload(rng);
        let c = mamba1::build(&cfg, seq, batch);
        let arch = ArchSpec::mambalaya();
        for v in FusionVariant::all() {
            let cost = evaluate(&c, &stitch(&c, v), &arch, &ExecOptions::default());
            for p in &cost.phases {
                let u = p.utilization(&arch);
                if !(0.0..=1.0 + 1e-9).contains(&u) {
                    return Err(format!("{v}: utilization {u}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn arch_bindings_are_consistent() {
    // Sanity over the Table III spec used throughout.
    let a = ArchSpec::mambalaya();
    assert!(a.pes(Binding::Mode2D) > a.pes(Binding::Wide1D));
    assert!(a.pes(Binding::Wide1D) > a.pes(Binding::Small1D));
    assert!(a.machine_balance() > 1.0);
}
