//! Session snapshot/fork cache conformance, plus regression coverage
//! for the three attach/admit hardening fixes that shipped with it:
//!
//! * **Skip law**: a follow-up turn whose prompt extends its session's
//!   stored history prefills *only* the new tokens, restores exactly
//!   one `state_bytes_per_seq` payload, and emits tokens bit-identical
//!   to a full re-prefill of the same prompt.
//! * **Fork law**: N best-of-N decodes forked from one session share a
//!   single prefill and a single refcounted payload — zero new cached
//!   bytes at fork time, one counted copy per attach.
//! * **Regressions** (each fails on the pre-fix code):
//!   `attach_reprefill` underflowed on a decode-phase packet with
//!   nothing generated; a duplicate in-flight submit silently re-zeroed
//!   the original's resident state row; a malformed migration packet
//!   panicked the receiving worker instead of being rejected.

use mambalaya::bench_util::ServeScenario;
use mambalaya::coordinator::{
    BatchPolicy, InFlight, MigrationPacket, Request, Scheduler, Server, SlotHandle,
};
use mambalaya::runtime::{Executor, MockEngine};

fn prompt_of(len: usize, salt: i32, vocab: usize) -> Vec<i32> {
    (0..len as i32).map(|x| (x * 11 + salt * 3 + 1) % vocab as i32).collect()
}

fn solo_tokens(req: &Request, policy: &BatchPolicy) -> Vec<i32> {
    let mut s = Scheduler::new(MockEngine::new(), policy.clone());
    s.submit(req.clone()).unwrap();
    s.run_until_drained().unwrap().remove(0).tokens
}

/// Hand-build a migration packet (the regression tests need packets no
/// healthy worker would produce).
fn packet(req: Request, prefill_pos: usize, generated: Vec<i32>, conv: Vec<f32>, ssm: Vec<f32>) -> MigrationPacket {
    let mut flight = InFlight::new(req);
    flight.prefill_pos = prefill_pos;
    flight.generated = generated;
    MigrationPacket { flight, from: SlotHandle { shard: 0, row: 0 }, conv, ssm }
}

#[test]
fn multi_turn_follow_up_prefills_only_new_tokens() {
    let vocab = MockEngine::new().manifest().vocab;
    let policy = ServeScenario::multi_turn().policy;
    let mut s = Scheduler::new(MockEngine::new(), policy.clone());
    let bytes_per_seq = s.state_arena().bytes_per_seq() as u64;

    let turn1 = Request { id: 1, prompt: prompt_of(24, 0, vocab), max_new_tokens: 8 };
    s.submit_session(turn1.clone(), Some(5)).unwrap();
    let reply = s.run_until_drained().unwrap().remove(0).tokens;
    assert_eq!(s.metrics().snapshots_stored, 1);
    assert_eq!(
        s.snapshot_cache().history(5).unwrap(),
        &ServeScenario::session_history(&turn1.prompt, &reply)[..],
        "stored history = prompt + fed-back reply (last sampled token excluded)"
    );
    let prefill_1 = s.metrics().prefill_tokens;
    assert_eq!(prefill_1, 24);

    let fresh = 6usize;
    let turn2 = Request {
        id: 2,
        prompt: ServeScenario::follow_up_prompt(&turn1.prompt, &reply, fresh, vocab),
        max_new_tokens: 8,
    };
    s.submit_session(turn2.clone(), Some(5)).unwrap();
    let out = s.run_until_drained().unwrap().remove(0).tokens;

    let met = s.metrics();
    assert_eq!(met.prefill_tokens - prefill_1, (fresh + 1) as u64, "only new tokens prefilled");
    assert_eq!(met.snapshot_hits, 1);
    assert_eq!(met.prefill_tokens_skipped, (turn1.prompt.len() + reply.len() - 1) as u64);
    assert_eq!(met.snapshot_bytes_restored, bytes_per_seq, "one counted copy per attach");

    // Conformance: bit-identical to paying for the whole prompt.
    assert_eq!(out, solo_tokens(&turn2, &policy), "snapshot attach changed tokens");
}

#[test]
fn three_turn_chain_keeps_skipping_with_one_entry_per_session() {
    let vocab = MockEngine::new().manifest().vocab;
    let policy = ServeScenario::multi_turn().policy;
    let mut s = Scheduler::new(MockEngine::new(), policy.clone());
    let fresh = 4usize;

    let mut req = Request { id: 10, prompt: prompt_of(16, 2, vocab), max_new_tokens: 6 };
    let mut prev_prefill = 0u64;
    for turn in 0..3u64 {
        s.submit_session(req.clone(), Some(77)).unwrap();
        let reply = s.run_until_drained().unwrap().remove(0).tokens;
        let spent = s.metrics().prefill_tokens - prev_prefill;
        prev_prefill = s.metrics().prefill_tokens;
        if turn == 0 {
            assert_eq!(spent, req.prompt.len() as u64);
        } else {
            assert_eq!(spent, (fresh + 1) as u64, "turn {turn} prefilled more than its new tokens");
        }
        assert_eq!(s.snapshot_cache().len(), 1, "store replaces, never accumulates");
        assert_eq!(reply, solo_tokens(&req, &policy), "turn {turn} diverged from full prefill");
        if turn < 2 {
            req = Request {
                id: req.id + 1,
                prompt: ServeScenario::follow_up_prompt(&req.prompt, &reply, fresh, vocab),
                max_new_tokens: 6,
            };
        }
    }
    assert_eq!(s.metrics().snapshot_hits, 2);
    assert_eq!(s.metrics().snapshots_stored, 3);
}

#[test]
fn fork_serves_n_decodes_from_one_prefill() {
    let vocab = MockEngine::new().manifest().vocab;
    let policy = ServeScenario::best_of_n().policy;
    let mut s = Scheduler::new(MockEngine::new(), policy.clone());

    let parent = Request { id: 0, prompt: prompt_of(32, 1, vocab), max_new_tokens: 1 };
    s.submit_session(parent.clone(), Some(7)).unwrap();
    let g1 = s.run_until_drained().unwrap().remove(0).tokens[0];
    let prefill_shared = s.metrics().prefill_tokens;
    assert_eq!(prefill_shared, 32);

    let cached = s.snapshot_cache().resident_bytes();
    for child in 0..3u64 {
        assert!(s.fork_session(7, 100 + child));
    }
    assert!(!s.fork_session(7, 100), "taken child key refuses");
    assert!(!s.fork_session(999, 200), "unknown parent refuses");
    assert_eq!(s.snapshot_cache().resident_bytes(), cached, "CoW fork adds zero cached bytes");
    assert_eq!(s.metrics().snapshot_forks, 3);

    let mut child_prompt = parent.prompt.clone();
    child_prompt.push(g1);
    let mut outs = Vec::new();
    for child in 0..3u64 {
        let r = Request { id: 50 + child, prompt: child_prompt.clone(), max_new_tokens: 6 };
        s.submit_session(r, Some(100 + child)).unwrap();
        outs.push(s.run_until_drained().unwrap().remove(0).tokens);
    }
    assert_eq!(
        s.metrics().prefill_tokens - prefill_shared,
        3,
        "each candidate prefills exactly its 1 new token"
    );
    assert_eq!(s.metrics().snapshot_hits, 3);
    let solo = solo_tokens(
        &Request { id: 9000, prompt: child_prompt, max_new_tokens: 6 },
        &policy,
    );
    for out in outs {
        assert_eq!(out, solo, "forked candidate diverged from full re-prefill");
    }
}

#[test]
fn fork_payload_outlives_parent_snapshot_replacement() {
    // The parent keeps chatting (its entry is replaced), but a child
    // forked from turn 1 still hits against the old refcounted payload.
    let vocab = MockEngine::new().manifest().vocab;
    let policy = BatchPolicy::default();
    let mut s = Scheduler::new(MockEngine::new(), policy.clone());

    let turn1 = Request { id: 1, prompt: prompt_of(12, 3, vocab), max_new_tokens: 5 };
    s.submit_session(turn1.clone(), Some(1)).unwrap();
    let reply1 = s.run_until_drained().unwrap().remove(0).tokens;
    assert!(s.fork_session(1, 2));

    // Parent turn 2 replaces session 1's snapshot.
    let turn2 = Request {
        id: 3,
        prompt: ServeScenario::follow_up_prompt(&turn1.prompt, &reply1, 3, vocab),
        max_new_tokens: 5,
    };
    s.submit_session(turn2.clone(), Some(1)).unwrap();
    s.run_until_drained().unwrap();
    let prefill_before = s.metrics().prefill_tokens;

    // The child extends the *old* history and still skips it.
    let child = Request {
        id: 4,
        prompt: ServeScenario::follow_up_prompt(&turn1.prompt, &reply1, 2, vocab),
        max_new_tokens: 5,
    };
    s.submit_session(child.clone(), Some(2)).unwrap();
    let out = s.run_until_drained().unwrap().remove(0).tokens;
    assert_eq!(s.metrics().prefill_tokens - prefill_before, 3, "2 fresh + the un-fed reply token");
    assert_eq!(out, solo_tokens(&child, &policy));
}

#[test]
fn lru_eviction_falls_back_to_full_prefill_and_stays_correct() {
    let vocab = MockEngine::new().manifest().vocab;
    let policy = ServeScenario::multi_turn().policy;
    let mut s = Scheduler::new(MockEngine::new(), policy.clone());
    let bytes_per_seq = s.state_arena().bytes_per_seq() as u64;
    // Budget for exactly one payload: the second store evicts the
    // first-stored (LRU) session.
    s.set_snapshot_budget(bytes_per_seq);

    let a1 = Request { id: 1, prompt: prompt_of(10, 0, vocab), max_new_tokens: 4 };
    s.submit_session(a1.clone(), Some(1)).unwrap();
    let reply_a = s.run_until_drained().unwrap().remove(0).tokens;
    let b1 = Request { id: 2, prompt: prompt_of(10, 1, vocab), max_new_tokens: 4 };
    s.submit_session(b1.clone(), Some(2)).unwrap();
    let reply_b = s.run_until_drained().unwrap().remove(0).tokens;

    assert_eq!(s.snapshot_cache().len(), 1, "byte budget holds one payload");
    assert!(!s.snapshot_cache().contains(1) && s.snapshot_cache().contains(2));
    assert_eq!(s.metrics().snapshot_evictions, 1);
    assert_eq!(s.metrics().snapshot_bytes_cached, bytes_per_seq);

    // Surviving session first: a hit. (Its completion re-stores within
    // budget; checking it before session 1's fallback matters, because
    // that fallback's own completion stores session 1 again and evicts
    // session 2 in turn.)
    let prefill_before = s.metrics().prefill_tokens;
    let b2 = Request {
        id: 4,
        prompt: ServeScenario::follow_up_prompt(&b1.prompt, &reply_b, 3, vocab),
        max_new_tokens: 4,
    };
    s.submit_session(b2.clone(), Some(2)).unwrap();
    let out_b = s.run_until_drained().unwrap().remove(0).tokens;
    assert_eq!(s.metrics().snapshot_hits, 1);
    assert_eq!(s.metrics().prefill_tokens - prefill_before, 4, "3 fresh + the un-fed reply token");
    assert_eq!(out_b, solo_tokens(&b2, &policy));

    // Evicted session: miss → full prefill, still token-correct.
    let prefill_before = s.metrics().prefill_tokens;
    let a2 = Request {
        id: 3,
        prompt: ServeScenario::follow_up_prompt(&a1.prompt, &reply_a, 3, vocab),
        max_new_tokens: 4,
    };
    s.submit_session(a2.clone(), Some(1)).unwrap();
    let out_a = s.run_until_drained().unwrap().remove(0).tokens;
    assert_eq!(s.metrics().snapshot_hits, 1, "the evicted session must not hit");
    assert_eq!(s.metrics().prefill_tokens - prefill_before, a2.prompt.len() as u64);
    assert_eq!(out_a, solo_tokens(&a2, &policy));
}

#[test]
fn misses_pay_full_prefill_and_stay_correct() {
    let vocab = MockEngine::new().manifest().vocab;
    let policy = BatchPolicy::default();
    let mut s = Scheduler::new(MockEngine::new(), policy.clone());

    let turn1 = Request { id: 1, prompt: prompt_of(10, 4, vocab), max_new_tokens: 4 };
    s.submit_session(turn1.clone(), Some(3)).unwrap();
    let reply = s.run_until_drained().unwrap().remove(0).tokens;
    let history = ServeScenario::session_history(&turn1.prompt, &reply);
    let prefill_before = s.metrics().prefill_tokens;

    // (a) prompt == stored history: nothing left to prefill — a miss.
    let equal = Request { id: 2, prompt: history.clone(), max_new_tokens: 4 };
    s.submit_session(equal.clone(), Some(3)).unwrap();
    let out = s.run_until_drained().unwrap().remove(0).tokens;
    assert_eq!(out, solo_tokens(&equal, &policy));

    // (b) divergent prompt (same length, different content): a miss.
    let mut diverged_prompt = history.clone();
    diverged_prompt[2] = (diverged_prompt[2] + 1) % vocab as i32;
    diverged_prompt.push(1);
    let diverged = Request { id: 3, prompt: diverged_prompt, max_new_tokens: 4 };
    s.submit_session(diverged.clone(), Some(3)).unwrap();
    let out = s.run_until_drained().unwrap().remove(0).tokens;
    assert_eq!(out, solo_tokens(&diverged, &policy));

    // (c) unknown session: a miss.
    let unknown = Request { id: 4, prompt: prompt_of(8, 5, vocab), max_new_tokens: 4 };
    s.submit_session(unknown.clone(), Some(42)).unwrap();
    let out = s.run_until_drained().unwrap().remove(0).tokens;
    assert_eq!(out, solo_tokens(&unknown, &policy));

    assert_eq!(s.metrics().snapshot_hits, 0, "no miss case may attach");
    let full: u64 = [&equal, &diverged, &unknown].iter().map(|r| r.prompt.len() as u64).sum();
    assert_eq!(s.metrics().prefill_tokens - prefill_before, full);
    assert_eq!(s.metrics().prefill_tokens_skipped, 0);
}

#[test]
fn reprefill_attach_with_zero_generated_decode_packet_recovers() {
    // Regression (pre-fix: usize underflow panic): a decode-phase
    // packet whose cursor sits at the prompt end with *nothing*
    // generated yet — the first token is pending — has no tokens to
    // fold back; `generated[prompt_replayed..k - 1]` underflowed.
    let vocab = MockEngine::new().manifest().vocab;
    let policy = BatchPolicy::default();
    let req = Request { id: 4, prompt: prompt_of(20, 6, vocab), max_new_tokens: 6 };
    let want = solo_tokens(&req, &policy);

    let mut b = Scheduler::new(MockEngine::new(), policy.clone());
    let p = packet(req.clone(), req.prompt.len(), Vec::new(), Vec::new(), Vec::new());
    assert!(p.decode_phase());
    assert_eq!(p.reprefill_cost_tokens(), req.prompt.len());
    b.attach_reprefill(p);
    let out = b.run_until_drained().unwrap().remove(0);
    assert_eq!(out.tokens, want, "re-prefilled request must replay to the same stream");
    assert_eq!(b.metrics().reprefill_tokens, req.prompt.len() as u64);
}

#[test]
fn duplicate_submit_is_rejected_and_resident_state_survives() {
    // Regression (pre-fix: silent state corruption): submitting a
    // request id already in flight reached `StateArena::admit`, which
    // re-zeroes a resident row — wiping the original's mid-flight
    // state. The scheduler now rejects the duplicate before any state
    // is touched.
    let vocab = MockEngine::new().manifest().vocab;
    let policy = BatchPolicy::default();
    let req = Request { id: 1, prompt: prompt_of(8, 7, vocab), max_new_tokens: 64 };
    let want = solo_tokens(&req, &policy);

    let mut s = Scheduler::new(MockEngine::new(), policy.clone());
    s.submit(req.clone()).unwrap();
    let mut guard = 0;
    while !s.state_arena().contains(1) {
        guard += 1;
        assert!(guard < 1000, "request never admitted");
        s.tick().unwrap();
    }
    let before = s.state_arena().snapshot(1).unwrap();

    let err = s.submit(Request { id: 1, prompt: vec![1, 2, 3], max_new_tokens: 4 });
    assert!(err.is_err(), "duplicate in-flight id must be rejected");
    assert_eq!(
        s.state_arena().snapshot(1).unwrap(),
        before,
        "rejection must not touch the resident row"
    );

    let out = s.run_until_drained().unwrap().remove(0);
    assert_eq!(out.tokens, want, "original stream corrupted by the duplicate submit");
}

#[test]
fn attach_rejects_malformed_packets_without_touching_state() {
    // Regression (pre-fix: panic): a malformed packet off the migration
    // channel either tripped `Batcher::enqueue_at`'s cursor assert or —
    // for a decode-phase packet with an empty `generated` buffer —
    // panicked mid-tick at the running set's `generated.last()`.
    // `attach` now validates first and hands the packet back untouched.
    let vocab = MockEngine::new().manifest().vocab;
    let policy = BatchPolicy::default();
    let mut s = Scheduler::new(MockEngine::new(), policy.clone());
    let (conv_len, ssm_len) = s.state_arena().payload_shape();
    let good_payload = || (vec![0.25f32; conv_len], vec![0.5f32; ssm_len]);
    let req = Request { id: 30, prompt: prompt_of(12, 8, vocab), max_new_tokens: 4 };

    let assert_rejected = |s: &mut Scheduler<MockEngine>, p: MigrationPacket, why: &str| {
        let seq = p.seq();
        let resident = s.state_arena().resident_bytes();
        let pending = s.pending();
        let back = s.attach(p).expect_err(why);
        assert_eq!(back.seq(), seq, "rejected packet returned intact");
        assert_eq!(s.state_arena().resident_bytes(), resident, "{why}: state touched");
        assert_eq!(s.pending(), pending, "{why}: bookkeeping touched");
    };

    // (a) cursor past the prompt end.
    let (conv, ssm) = good_payload();
    assert_rejected(
        &mut s,
        packet(req.clone(), req.prompt.len() + 3, vec![7], conv, ssm),
        "cursor past prompt end must be rejected",
    );
    // (b) decode phase with nothing generated (the mid-tick panic).
    let (conv, ssm) = good_payload();
    assert_rejected(
        &mut s,
        packet(req.clone(), req.prompt.len(), Vec::new(), conv, ssm),
        "decode-phase packet with empty generated must be rejected",
    );
    // (c) wrong payload shape.
    let (conv, _) = good_payload();
    assert_rejected(
        &mut s,
        packet(req.clone(), 4, Vec::new(), conv, vec![0.5f32; ssm_len + 1]),
        "wrong-shape payload must be rejected",
    );
    // (d) id already in flight here.
    s.submit(req.clone()).unwrap();
    let (conv, ssm) = good_payload();
    assert_rejected(
        &mut s,
        packet(req.clone(), 4, Vec::new(), conv, ssm),
        "duplicate in-flight id must be rejected",
    );
    let out = s.run_until_drained().unwrap().remove(0);
    assert_eq!(out.tokens, solo_tokens(&req, &policy), "survivor must be unharmed");

    // Recovery: the server-side fallback — `attach_reprefill` on the
    // rejected packet — rebuilds by replay and stays token-identical.
    let mut fresh = Scheduler::new(MockEngine::new(), policy.clone());
    let req2 = Request { id: 31, prompt: prompt_of(12, 9, vocab), max_new_tokens: 4 };
    let (conv, _) = good_payload();
    let bad = packet(req2.clone(), 4, Vec::new(), conv, vec![0.5f32; ssm_len + 1]);
    let back = fresh.attach(bad).expect_err("wrong-shape payload must be rejected");
    fresh.attach_reprefill(back);
    let out = fresh.run_until_drained().unwrap().remove(0);
    assert_eq!(out.tokens, solo_tokens(&req2, &policy));
}

#[test]
fn server_sessions_route_and_skip_across_turns() {
    let vocab = MockEngine::new().manifest().vocab;
    let factories: Vec<fn() -> anyhow::Result<MockEngine>> =
        vec![|| Ok(MockEngine::new()), || Ok(MockEngine::new())];
    let mut server = Server::start(factories, BatchPolicy::default());

    let turn1 = Request { id: 1, prompt: prompt_of(16, 0, vocab), max_new_tokens: 6 };
    let reply = server.submit_session(turn1.clone(), 9).recv().unwrap().tokens;
    assert_eq!(reply.len(), 6);

    // The follow-up routes to the same shard (the only worker whose
    // cache holds session 9) and skips the shared history.
    let turn2 = Request {
        id: 2,
        prompt: ServeScenario::follow_up_prompt(&turn1.prompt, &reply, 5, vocab),
        max_new_tokens: 6,
    };
    let out = server.submit_session(turn2.clone(), 9).recv().unwrap().tokens;
    let t = server.traffic();
    assert_eq!(t.snapshots_stored, 2);
    assert_eq!(t.snapshot_hits, 1);
    assert_eq!(
        t.prefill_tokens_skipped,
        (turn1.prompt.len() + reply.len() - 1) as u64
    );
    assert!(t.snapshot_bytes_restored > 0);

    // Forks ride the same routing: the child session pins to the
    // parent's shard and its next submit attaches the shared payload.
    assert!(server.fork_session(9, 10));
    assert!(!server.fork_session(999, 11), "unknown parent refuses");
    let child = Request {
        id: 3,
        prompt: ServeScenario::follow_up_prompt(&turn2.prompt, &out, 4, vocab),
        max_new_tokens: 6,
    };
    let child_out = server.submit_session(child.clone(), 10).recv().unwrap().tokens;
    let t = server.traffic();
    assert_eq!(t.snapshot_forks, 1);
    assert_eq!(t.snapshot_hits, 2);
    server.shutdown();

    // Conformance against a solo scheduler for both follow-ups.
    assert_eq!(out, solo_tokens(&turn2, &BatchPolicy::default()));
    assert_eq!(child_out, solo_tokens(&child, &BatchPolicy::default()));
}
