//! Slot-aware router properties, in the style of
//! `planner_properties.rs`: the [`ShardMap`] + [`RouterPolicy`]
//! migration planner is pure bookkeeping, so its affinity,
//! no-starvation and hysteresis guarantees are checked directly over
//! randomized load sequences — no threads, no engine.
//!
//! * **Slot affinity**: balanced load plans zero migrations — resident
//!   state never moves without a reason.
//! * **Convergence**: any skew rebalances to within the policy
//!   threshold, moving requests only from the hottest toward the
//!   coldest shard, each at most once per round.
//! * **Hysteresis**: ±1 load wiggles (one arrival / one completion)
//!   never trigger a move with the default threshold, and under
//!   adversarial alternating skew the per-request migration count is
//!   bounded by the cooldown — no state ping-pong.
//! * **No starvation**: under sustained single-shard arrival skew,
//!   every shard ends up with work and no request migrates more than
//!   its cooldown-bounded share.

use mambalaya::coordinator::{Migration, RouterPolicy, ShardMap};
use mambalaya::prop::check;

fn pol(threshold: usize, max_moves: usize, cooldown: u64) -> RouterPolicy {
    RouterPolicy {
        migrate_threshold: threshold,
        max_moves_per_rebalance: max_moves,
        cooldown_rounds: cooldown,
    }
}

#[test]
fn prop_balanced_loads_plan_no_migrations() {
    // Slot affinity: whenever every pair of shards is within the
    // threshold, the planner must not move anything — regardless of
    // how the requests got there.
    check("balanced ⇒ no migration", 50, |rng| {
        let shards = rng.range(1, 6) as usize;
        let pol = pol(rng.range(1, 5) as usize, rng.range(1, 8) as usize, rng.range(0, 4));
        let mut m = ShardMap::new(shards);
        // Place via the router itself: least-load keeps every gap ≤ 1,
        // which is within any threshold ≥ 1.
        for seq in 0..rng.range(0, 40) {
            m.place(seq);
        }
        let max = m.loads().iter().max().copied().unwrap_or(0);
        let min = m.loads().iter().min().copied().unwrap_or(0);
        if max - min > pol.migrate_threshold {
            return Err(format!("place() left a gap of {}", max - min));
        }
        let plan = m.plan_rebalance(&pol);
        if !plan.is_empty() {
            return Err(format!("balanced loads planned {plan:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_skew_converges_hot_to_cold_within_threshold() {
    check("skew converges", 50, |rng| {
        let shards = rng.range(2, 5) as usize;
        let pol = pol(rng.range(1, 4) as usize, rng.range(1, 6) as usize, 0);
        let mut m = ShardMap::new(shards);
        // Adversarial placement: pile everything wherever the rng says.
        let n = rng.range(1, 40);
        for seq in 0..n {
            m.assign(seq, rng.below(shards as u64) as usize);
        }
        // Rebalance rounds until quiescent (cooldown 0: every request
        // is always movable, so quiescence means balance).
        let mut rounds = 0;
        loop {
            rounds += 1;
            if rounds > 200 {
                return Err("rebalance did not converge".to_string());
            }
            let plan = m.plan_rebalance(&pol);
            if plan.is_empty() {
                break;
            }
            let loads_before = m.loads().to_vec();
            let mut seen = std::collections::BTreeSet::new();
            for mv in &plan {
                // Moves go from the current hottest side toward the
                // coldest: strictly downhill.
                if loads_before[mv.from] <= loads_before[mv.to] {
                    return Err(format!("uphill move {mv:?} with loads {loads_before:?}"));
                }
                if !seen.insert(mv.seq) {
                    return Err(format!("seq {} planned twice in one round", mv.seq));
                }
                m.apply(mv, &pol);
            }
        }
        let max = m.loads().iter().max().copied().unwrap();
        let min = m.loads().iter().min().copied().unwrap();
        if max - min > pol.migrate_threshold {
            return Err(format!(
                "converged loads {:?} exceed threshold {}",
                m.loads(),
                pol.migrate_threshold
            ));
        }
        Ok(())
    });
}

#[test]
fn one_arrival_one_completion_wiggle_never_migrates() {
    // The ±1 hysteresis guarantee: with the default threshold (2), an
    // alternating arrival/completion pattern that keeps the gap at ≤ 1
    // in-flight request never moves resident state.
    let pol = RouterPolicy::default();
    let mut m = ShardMap::new(2);
    for seq in 0..8u64 {
        m.place(seq);
    }
    assert_eq!(m.loads(), &[4, 4]);
    let mut next = 100u64;
    for round in 0..200u64 {
        // Alternate: one shard momentarily one request ahead.
        let shard = (round % 2) as usize;
        m.assign(next, shard);
        assert!(
            m.plan_rebalance(&pol).is_empty(),
            "±1 wiggle triggered a migration on round {round}"
        );
        m.complete(next);
        next += 1;
    }
}

#[test]
fn prop_alternating_skew_migrations_bounded_by_cooldown() {
    // Adversarial thrash: flip a large load imbalance back and forth
    // every round. The cooldown pins each migrated request, so the
    // per-request migration count over R rounds is bounded by
    // R / (cooldown + 1) + 1 — no request ping-pongs every round.
    check("no thrash under alternating skew", 25, |rng| {
        let cooldown = rng.range(1, 6);
        let pol = pol(2, 2, cooldown);
        let mut m = ShardMap::new(2);
        for seq in 0..6u64 {
            m.assign(seq, 0);
        }
        let mut moves_per_seq = std::collections::BTreeMap::<u64, u64>::new();
        let rounds = 60u64;
        // Ballast ids (≥ 1000) flip sides each round to fake the skew;
        // they are deliberately kept un-movable by deferring them, so
        // the planner only ever moves the six real requests.
        let mut ballast = 1000u64;
        for round in 0..rounds {
            let hot = (round % 2) as usize;
            for _ in 0..8 {
                m.assign(ballast, hot);
                m.defer(ballast, &pol);
                ballast += 1;
            }
            for mv in m.plan_rebalance(&pol) {
                if mv.seq < 1000 {
                    *moves_per_seq.entry(mv.seq).or_default() += 1;
                }
                m.apply(&mv, &pol);
            }
            // The fake skew drains before the next flip.
            for b in ballast - 8..ballast {
                m.complete(b);
            }
        }
        let bound = rounds / (cooldown + 1) + 1;
        for (seq, moves) in &moves_per_seq {
            if *moves > bound {
                return Err(format!(
                    "seq {seq} migrated {moves}x in {rounds} rounds (cooldown {cooldown}, bound {bound})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn skewed_arrivals_do_not_starve_cold_shards() {
    // Sustained skew: every arrival is pinned to shard 0 (a sticky
    // client), completions drain slowly. Rebalance must keep feeding
    // the cold shards — and the router's own placement would do even
    // better — while the cooldown keeps any single request from
    // migrating round after round.
    let pol = RouterPolicy::default();
    let mut m = ShardMap::new(3);
    let mut moves_per_seq = std::collections::BTreeMap::<u64, u64>::new();
    let mut next = 0u64;
    let mut oldest = 0u64;
    for _round in 0..100 {
        // Three skewed arrivals, one completion (oldest in-flight).
        for _ in 0..3 {
            m.assign(next, 0);
            next += 1;
        }
        if oldest < next {
            m.complete(oldest);
            oldest += 1;
        }
        for mv in m.plan_rebalance(&pol) {
            *moves_per_seq.entry(mv.seq).or_default() += 1;
            m.apply(&mv, &pol);
        }
    }
    let loads = m.loads().to_vec();
    assert!(loads[1] > 0 && loads[2] > 0, "cold shards starved: {loads:?}");
    // Hysteresis bound: nobody thrashes (100 rounds, cooldown 2).
    for (seq, moves) in &moves_per_seq {
        assert!(*moves <= 100 / 3 + 1, "seq {seq} migrated {moves}x");
    }
    // Rebalance keeps the system near-balanced despite 3:0:0 skew.
    let max = loads.iter().max().unwrap();
    let min = loads.iter().min().unwrap();
    assert!(
        max - min <= pol.migrate_threshold + 3,
        "sustained skew left {loads:?} unbalanced"
    );
}

#[test]
fn plan_is_pure_and_apply_is_exact() {
    // Planning twice without applying yields the same plan (modulo the
    // round clock used only for cooldowns); applying records exactly
    // the planned move.
    let pol = pol(1, 8, 0);
    let mut m = ShardMap::new(2);
    for seq in 0..5u64 {
        m.assign(seq, 0);
    }
    let p1 = m.plan_rebalance(&pol);
    let p2 = m.plan_rebalance(&pol);
    assert_eq!(p1, p2, "pure planning must be repeatable");
    assert_eq!(p1, vec![
        Migration { seq: 0, from: 0, to: 1 },
        Migration { seq: 1, from: 0, to: 1 },
    ]);
    for mv in &p1 {
        m.apply(mv, &pol);
    }
    assert_eq!(m.loads(), &[3, 2]);
    assert_eq!(m.shard_of(0), Some(1));
    assert_eq!(m.shard_of(1), Some(1));
}
