//! Property-based tests of the fusion machinery over *random cascades*:
//! the invariants hold for any Einsum DAG, not just Mamba (the paper's
//! "TA+" claim in Table II).

use mambalaya::cascade::{mamba1, mamba2, ModelConfig};
use mambalaya::einsum::{
    Cascade, DType, EinsumSpec, IterSpace, OpKind, Operand, Rank, TensorClass, TensorSpec,
    UnaryFn,
};
use mambalaya::fusion::{classify_pair, stitch, FusionClass, FusionVariant};
use mambalaya::prop::check;
use mambalaya::util::XorShift;

/// Generate a random, valid, sequential cascade: each Einsum consumes
/// the previous output (and sometimes an older one), with random rank
/// structure drawn from a small rank universe.
fn random_cascade(rng: &mut XorShift) -> Cascade {
    let universe: Vec<Rank> = ["M", "N", "K", "P", "Q", "R"]
        .iter()
        .map(|n| Rank::new(*n, 1 << rng.range(2, 6)))
        .collect();
    let n_einsums = rng.range(2, 10) as usize;

    let pick_ranks = |rng: &mut XorShift, min: u64| -> Vec<Rank> {
        let k = rng.range(min, 3.max(min));
        let mut out: Vec<Rank> = Vec::new();
        while (out.len() as u64) < k {
            let r = rng.pick(&universe).clone();
            if !out.iter().any(|x| x.name == r.name) {
                out.push(r);
            }
        }
        out
    };

    let mut einsums: Vec<EinsumSpec> = Vec::new();
    let in0 = TensorSpec::new("T0", pick_ranks(rng, 1), DType::F16, TensorClass::Input);
    let mut prev = in0.clone();
    for i in 1..=n_einsums {
        let out_ranks = pick_ranks(rng, 1);
        let out = TensorSpec::new(
            format!("T{i}"),
            out_ranks.clone(),
            DType::F16,
            if i == n_einsums { TensorClass::Output } else { TensorClass::Intermediate },
        );
        // Reduction ranks: ranks of prev not in the output.
        let reduction: Vec<Rank> = prev
            .ranks
            .iter()
            .filter(|r| !out_ranks.iter().any(|o| o.name == r.name))
            .cloned()
            .collect();
        let mut inputs = vec![Operand::plain(prev.clone())];
        // Occasionally read an older intermediate too.
        if i >= 2 && rng.below(3) == 0 {
            let older = einsums[rng.below(einsums.len() as u64) as usize].output.clone();
            if older.name != prev.name {
                inputs.push(Operand::plain(older));
            }
        }
        let op = match rng.below(4) {
            0 => OpKind::MulAcc,
            1 => OpKind::Mul,
            2 => OpKind::Add,
            _ => OpKind::Unary(UnaryFn::Exp),
        };
        // Give contractions a weight operand spanning their space.
        if matches!(op, OpKind::MulAcc) {
            let w_ranks: Vec<Rank> =
                reduction.iter().chain(out_ranks.iter()).cloned().collect();
            if !w_ranks.is_empty() {
                inputs.push(Operand::plain(TensorSpec::new(
                    format!("W{i}"),
                    w_ranks,
                    DType::F16,
                    TensorClass::Weight,
                )));
            }
        }
        einsums.push(EinsumSpec::new(i, format!("T{i}"), out.clone(), inputs, reduction, op));
        prev = out;
    }
    Cascade::new("random", einsums)
}

#[test]
fn prop_random_cascades_validate() {
    check("random cascades validate", 200, |rng| {
        let c = random_cascade(rng);
        c.validate().map_err(|e| format!("{e}"))
    });
}

#[test]
fn prop_plans_partition_the_cascade() {
    check("plans partition", 200, |rng| {
        let c = random_cascade(rng);
        for v in FusionVariant::all() {
            let plan = stitch(&c, v);
            plan.validate(&c).map_err(|e| format!("{v}: {e}"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_group_counts_monotone_in_variant_power() {
    // More permissive variants never produce *more* groups.
    check("group counts monotone", 200, |rng| {
        let c = random_cascade(rng);
        let counts: Vec<usize> =
            FusionVariant::all().iter().map(|&v| stitch(&c, v).groups.len()).collect();
        for w in counts.windows(2) {
            if w[1] > w[0] {
                return Err(format!("counts not monotone: {counts:?}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_classification_consistent_with_privates() {
    // The class must agree with the private-rank structure relative to
    // the intermediate (the paper's Figure 3 semantics).
    check("classification consistency", 300, |rng| {
        let c = random_cascade(rng);
        for (i, up) in c.einsums().iter().enumerate() {
            for down in &c.einsums()[i + 1..] {
                if let Some(p) = classify_pair(up, down) {
                    let t = down.operand(&p.intermediate).unwrap().tensor.clone();
                    let t_space = IterSpace::new(t.ranks.clone());
                    let up_priv = !up.iteration_space().difference(&t_space).is_empty();
                    let dn_priv = !down.iteration_space().difference(&t_space).is_empty();
                    let want = match (up_priv, dn_priv) {
                        (false, false) => FusionClass::RI,
                        (true, false) => FusionClass::RSb,
                        (false, true) => FusionClass::RSp,
                        (true, true) => FusionClass::RD,
                    };
                    if p.class != want {
                        return Err(format!("{}→{}: {} vs {}", up.id, down.id, p.class, want));
                    }
                    // Stationary ranks always lie inside the intermediate.
                    if !p.stationary.is_subset_of(&t_space) {
                        return Err(format!(
                            "stationary {} escapes intermediate {}",
                            p.stationary, t_space
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_internal_tensors_never_escape() {
    check("internal tensors stay internal", 200, |rng| {
        let c = random_cascade(rng);
        let consumers = c.consumers();
        for v in FusionVariant::fused() {
            let plan = stitch(&c, v);
            for g in &plan.groups {
                for t in &g.internal_tensors {
                    if let Some(cs) = consumers.get(t.as_str()) {
                        for cid in cs {
                            if !g.einsums.contains(cid) {
                                return Err(format!("{v}: {t} consumed outside its group"));
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_fully_fused_never_more_groups_than_rsp() {
    check("fully-fused ≤ rsp groups", 150, |rng| {
        let c = random_cascade(rng);
        let rsp = stitch(&c, FusionVariant::RIRSbRSp).groups.len();
        let ff = stitch(&c, FusionVariant::FullyFused).groups.len();
        if ff > rsp {
            return Err(format!("ff {ff} > rsp {rsp}"));
        }
        Ok(())
    });
}

#[test]
fn mamba_cascades_satisfy_all_properties_at_many_sizes() {
    // Determinized sweep over real cascade families and sizes.
    for cfg in [ModelConfig::mamba_130m(), ModelConfig::mamba_370m(), ModelConfig::mamba_2_8b()]
    {
        for seq in [1u64, 2, 64, 4096] {
            for batch in [1u64, 64] {
                let c1 = mamba1::build(&cfg, seq, batch);
                c1.validate().unwrap();
                let c2 = mamba2::build(&cfg, seq, batch);
                c2.validate().unwrap();
                for v in FusionVariant::all() {
                    stitch(&c1, v).validate(&c1).unwrap();
                    stitch(&c2, v).validate(&c2).unwrap();
                }
                // Group structure is size-independent (fusion classes
                // depend on rank *names*, not extents).
                let g_small = stitch(&mamba1::build(&cfg, 1, 1), FusionVariant::RIRSbRSp);
                let g_here = stitch(&c1, FusionVariant::RIRSbRSp);
                assert_eq!(
                    g_small.groups.iter().map(|g| g.einsums.clone()).collect::<Vec<_>>(),
                    g_here.groups.iter().map(|g| g.einsums.clone()).collect::<Vec<_>>(),
                );
            }
        }
    }
}
