//! Coordinator integration tests on the mock engine: batching
//! invariance under randomized workloads, failure injection, and
//! policy edge cases.

use mambalaya::coordinator::{serve_all, BatchPolicy, Request, Scheduler, WorkloadGen};
use mambalaya::prop::check;
use mambalaya::runtime::engine::{Executor, StepOutput};
use mambalaya::runtime::MockEngine;

#[test]
fn prop_generation_invariant_under_policy() {
    // The generated tokens for a request must not depend on the batching
    // policy (chunk size, token budget, slot count, admission order of
    // others) — chunked and monolithic prefill included.
    check("policy invariance", 12, |rng| {
        let probe = MockEngine::new();
        let (vocab, plen) = (probe.manifest().vocab, probe.manifest().prefill_len);
        let mut gen = WorkloadGen::new(rng.next_u64(), vocab, plen, 1, 6)
            .with_prompt_range(1, 2 * plen);
        let reqs: Vec<Request> = (0..rng.range(1, 9)).map(|_| gen.next_request()).collect();

        let policies = [
            BatchPolicy::default(),
            // Tiny everything: serializes requests almost completely.
            BatchPolicy {
                chunk_tokens: 1,
                token_budget: 2,
                max_chunk_rows: 1,
                max_running: 2,
                decode_priority_threshold: 1,
            },
            // Mid-size chunks, modest budget.
            BatchPolicy {
                chunk_tokens: 3,
                token_budget: 8,
                max_chunk_rows: 2,
                max_running: 4,
                decode_priority_threshold: 3,
            },
            // Monolithic prefill (whole prompt as one chunk).
            BatchPolicy {
                chunk_tokens: 0,
                token_budget: 1 << 20,
                ..BatchPolicy::default()
            },
        ];
        let mut reference: Option<Vec<Vec<i32>>> = None;
        for policy in policies {
            let (mut resps, _) =
                serve_all(|| Ok(MockEngine::new()), policy, reqs.clone()).unwrap();
            resps.sort_by_key(|r| r.id);
            let tokens: Vec<Vec<i32>> = resps.into_iter().map(|r| r.tokens).collect();
            match &reference {
                None => reference = Some(tokens),
                Some(want) => {
                    if want != &tokens {
                        return Err("tokens depend on batch policy".into());
                    }
                }
            }
        }
        Ok(())
    });
}

/// An engine that fails every Nth decode call — exercises the worker's
/// fail-stop path without hanging clients.
struct FlakyEngine {
    inner: MockEngine,
    calls: std::cell::Cell<u32>,
    fail_every: u32,
}

impl Executor for FlakyEngine {
    fn manifest(&self) -> &mambalaya::runtime::Manifest {
        self.inner.manifest()
    }

    fn prefill(&self, batch: usize, tokens: &[i32]) -> anyhow::Result<StepOutput> {
        self.inner.prefill(batch, tokens)
    }

    fn decode(
        &self,
        batch: usize,
        tokens: &[i32],
        conv: &[f32],
        ssm: &[f32],
    ) -> anyhow::Result<StepOutput> {
        let n = self.calls.get() + 1;
        self.calls.set(n);
        if n % self.fail_every == 0 {
            anyhow::bail!("injected decode failure #{n}");
        }
        self.inner.decode(batch, tokens, conv, ssm)
    }
}

#[test]
fn scheduler_surfaces_engine_errors() {
    let engine =
        FlakyEngine { inner: MockEngine::new(), calls: Default::default(), fail_every: 3 };
    let (vocab, plen) = (engine.manifest().vocab, engine.manifest().prefill_len);
    let mut s = Scheduler::new(engine, BatchPolicy::default());
    let mut gen = WorkloadGen::new(1, vocab, plen, 8, 8);
    s.submit(gen.next_request()).unwrap();
    // Ticking must eventually return the injected error, not panic or
    // silently drop the request.
    let mut saw_error = false;
    for _ in 0..64 {
        match s.tick() {
            Err(e) => {
                assert!(e.to_string().contains("injected decode failure"));
                saw_error = true;
                break;
            }
            Ok(_) => {}
        }
    }
    assert!(saw_error, "error was swallowed");
    // After an engine error the scheduler is poisoned: the resident
    // path may have advanced arena rows in place, so a retried tick
    // would feed consumed tokens to already-advanced state. It must
    // refuse to run instead.
    let err = s.tick().expect_err("poisoned scheduler must not tick again");
    assert!(err.to_string().contains("poisoned"), "unexpected error: {err}");
}

#[test]
fn zero_max_new_tokens_is_rejected() {
    let mut s = Scheduler::new(MockEngine::new(), BatchPolicy::default());
    let plen = s.manifest().prefill_len;
    let req = Request { id: 1, prompt: vec![0; plen], max_new_tokens: 0 };
    assert!(s.submit(req).is_err());
}

#[test]
fn arbitrary_prompt_lengths_are_served() {
    // Chunked prefill frees the coordinator from the compiled prefill
    // length: 1-token, odd-length and multi-chunk prompts all serve.
    let mut s = Scheduler::new(MockEngine::new(), BatchPolicy::default());
    for (id, plen) in [(1u64, 1usize), (2, 5), (3, 23)] {
        let req = Request { id, prompt: vec![2; plen], max_new_tokens: 2 };
        s.submit(req).unwrap();
    }
    let out = s.run_until_drained().unwrap();
    assert_eq!(out.len(), 3);
    for r in &out {
        assert_eq!(r.tokens.len(), 2);
    }
    assert_eq!(s.metrics().prefill_tokens, 1 + 5 + 23);
}

#[test]
fn many_more_requests_than_slots_all_complete() {
    let probe = MockEngine::new();
    let (vocab, plen) = (probe.manifest().vocab, probe.manifest().prefill_len);
    let policy = BatchPolicy { max_running: 3, ..Default::default() };
    let mut gen = WorkloadGen::new(4, vocab, plen, 2, 7);
    let reqs: Vec<Request> = (0..40).map(|_| gen.next_request()).collect();
    let want: Vec<usize> = reqs.iter().map(|r| r.max_new_tokens).collect();
    let (mut resps, report) = serve_all(|| Ok(MockEngine::new()), policy, reqs).unwrap();
    resps.sort_by_key(|r| r.id);
    assert_eq!(resps.len(), 40);
    for (r, n) in resps.iter().zip(want) {
        assert_eq!(r.tokens.len(), n);
    }
    assert!(report.contains("requests=40"));
}

#[test]
fn single_token_requests_complete_at_prefill() {
    // max_new_tokens = 1 finishes on the prompt's final chunk (no
    // decode round-trip; any partial-prefill state is released).
    let probe = MockEngine::new();
    let (vocab, plen) = (probe.manifest().vocab, probe.manifest().prefill_len);
    let mut gen = WorkloadGen::new(5, vocab, plen, 1, 1);
    let reqs: Vec<Request> = (0..4).map(|_| gen.next_request()).collect();
    let (resps, _) =
        serve_all(|| Ok(MockEngine::new()), BatchPolicy::default(), reqs).unwrap();
    for r in resps {
        assert_eq!(r.tokens.len(), 1);
    }
}
