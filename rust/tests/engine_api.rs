//! The typed engine-API surface, tested hermetically against
//! `runtime::mock`:
//!
//! * **wrapper equivalence** (the api_redesign acceptance bar): calling
//!   the deprecated `step_mixed` / `step_mixed_into` /
//!   `step_planned_into` wrappers is bit-identical — logits, slab
//!   states, traffic / padded / device-call / modeled counters — to
//!   building the `LaunchSpec` directly, across randomized batches,
//!   sparse row plans, carried/zero states, and both the fused varlen
//!   engine and the caps-off default decomposition;
//! * the same equivalence at the **scheduler** level, on both state
//!   paths, via a shim engine whose `launch` round-trips every call
//!   through the deprecated seven-slice convention;
//! * the **distinct-rows contract** is enforced (aliased slab rows are
//!   a construction error, not a silent state corruption);
//! * **capability negotiation**: a plan the engine's caps disclaim is
//!   never dispatched, and the caps toggle (fused vs decomposition)
//!   changes counters but never tokens;
//! * the [`Donation`] annotation is observability-only on host
//!   engines: `DonateInPlace` and `Retain` launches are bit-identical.

#![allow(deprecated)] // the legacy wrappers are the subject under test

use mambalaya::coordinator::{BatchPolicy, Request, Scheduler, StatePath, WorkloadGen};
use mambalaya::planner::{PlanChoice, Planner, PlanSpec};
use mambalaya::prop::check;
use mambalaya::runtime::{
    Donation, EngineCaps, Executor, LaunchSpec, Manifest, MixedBatch, MockEngine, Phase,
    Segment, StateSlabs, StepOutput, Workspace,
};
use mambalaya::util::XorShift;

/// Everything one engine call observably produced: outputs plus every
/// workspace counter.
#[derive(Debug, Clone, PartialEq)]
struct CallOutcome {
    logits: Vec<f32>,
    conv: Vec<f32>,
    ssm: Vec<f32>,
    gathered: u64,
    scattered: u64,
    padded: u64,
    device_calls: u64,
    modeled: (u64, u64),
}

fn drain(ws: &mut Workspace, conv: &[f32], ssm: &[f32]) -> CallOutcome {
    let t = ws.take_traffic();
    CallOutcome {
        logits: ws.logits.clone(),
        conv: conv.to_vec(),
        ssm: ssm.to_vec(),
        gathered: t.bytes_gathered,
        scattered: t.bytes_scattered,
        padded: ws.take_padded_rows(),
        device_calls: ws.take_device_calls(),
        modeled: ws.take_modeled(),
    }
}

/// One randomized engine-level case: lens, sparse distinct rows, flat
/// tokens, and slabs whose planned rows are randomly carried-state or
/// zeroed (so every phase classification is exercised).
struct Case {
    lens: Vec<usize>,
    rows: Vec<usize>,
    tokens: Vec<i32>,
    stride: usize,
    conv: Vec<f32>,
    ssm: Vec<f32>,
}

fn random_case(rng: &mut XorShift, m: &Manifest) -> Case {
    let (nl, plen) = (m.n_layer, m.prefill_len);
    let cp = m.d_inner * (m.d_conv - 1);
    let sp = m.d_inner * m.d_state;
    let batch = rng.range(1, 5) as usize;
    let stride = batch + rng.range(0, 3) as usize;
    // Distinct rows: shuffle 0..stride, take the first `batch`.
    let mut all_rows: Vec<usize> = (0..stride).collect();
    for i in (1..all_rows.len()).rev() {
        let j = rng.below(i as u64 + 1) as usize;
        all_rows.swap(i, j);
    }
    let rows = all_rows[..batch].to_vec();
    let lens: Vec<usize> = (0..batch)
        .map(|_| {
            match rng.below(4) {
                0 => 1,                              // decode row
                1 => plen,                           // full-length row
                _ => rng.range(2, 2 * plen as u64) as usize, // odd chunk
            }
        })
        .collect();
    let tokens: Vec<i32> =
        (0..lens.iter().sum::<usize>()).map(|_| rng.below(m.vocab as u64) as i32).collect();
    let mut conv = vec![0f32; nl * stride * cp];
    let mut ssm = vec![0f32; nl * stride * sp];
    for x in conv.iter_mut() {
        *x = (rng.f64() as f32) - 0.5;
    }
    for x in ssm.iter_mut() {
        *x = (rng.f64() as f32) - 0.5;
    }
    // Randomly zero some planned rows (fresh sequences) so the
    // PrefillFirst classification and the compiled-prefill bucket of
    // the decomposition both get exercised.
    for &row in &rows {
        if rng.below(3) == 0 {
            for l in 0..nl {
                conv[(l * stride + row) * cp..(l * stride + row + 1) * cp].fill(0.0);
                ssm[(l * stride + row) * sp..(l * stride + row + 1) * sp].fill(0.0);
            }
        }
    }
    Case { lens, rows, tokens, stride, conv, ssm }
}

/// The wrapper's phase classification, reproduced for direct
/// `LaunchSpec` construction: unit rows decode, `prefill_len` rows
/// `PrefillFirst` iff their slab state is all-zero (other lengths go
/// to the lockstep scan regardless, so the wrapper skips their scan
/// and declares `PrefillCont`).
fn classify(case: &Case, m: &Manifest) -> Vec<Segment> {
    let (nl, cp, sp) = (
        m.n_layer,
        m.d_inner * (m.d_conv - 1),
        m.d_inner * m.d_state,
    );
    case.lens
        .iter()
        .zip(&case.rows)
        .map(|(&len, &row)| {
            let zero = || {
                (0..nl).all(|l| {
                    case.conv[(l * case.stride + row) * cp..(l * case.stride + row + 1) * cp]
                        .iter()
                        .all(|&x| x == 0.0)
                        && case.ssm
                            [(l * case.stride + row) * sp..(l * case.stride + row + 1) * sp]
                            .iter()
                            .all(|&x| x == 0.0)
                })
            };
            let phase = if len == 1 {
                Phase::Decode
            } else if len == m.prefill_len && zero() {
                Phase::PrefillFirst
            } else {
                Phase::PrefillCont
            };
            Segment { len, row, phase }
        })
        .collect()
}

/// Run one case through the deprecated wrapper surface.
fn via_wrapper(e: &MockEngine, case: &Case, plan: Option<PlanChoice>) -> CallOutcome {
    let mut conv = case.conv.clone();
    let mut ssm = case.ssm.clone();
    let mut ws = Workspace::new();
    match plan {
        Some(choice) => e
            .step_planned_into(
                choice, &case.lens, &case.tokens, &case.rows, &mut conv, &mut ssm, case.stride,
                &mut ws,
            )
            .unwrap(),
        None => e
            .step_mixed_into(
                &case.lens, &case.tokens, &case.rows, &mut conv, &mut ssm, case.stride, &mut ws,
            )
            .unwrap(),
    }
    drain(&mut ws, &conv, &ssm)
}

/// Run one case through a directly-built `LaunchSpec`.
fn via_launch(e: &MockEngine, case: &Case, plan: Option<PlanChoice>) -> CallOutcome {
    let segs = classify(case, e.manifest());
    let mut conv = case.conv.clone();
    let mut ssm = case.ssm.clone();
    let mut ws = Workspace::new();
    e.launch(LaunchSpec {
        batch: MixedBatch::new(&segs, &case.tokens).unwrap(),
        state: StateSlabs::new(&mut conv, &mut ssm, case.stride, Donation::Retain),
        plan,
        ws: &mut ws,
    })
    .unwrap();
    drain(&mut ws, &conv, &ssm)
}

#[test]
fn prop_wrappers_equal_direct_launch() {
    // The acceptance bar: every deprecated wrapper is a *pure
    // repackaging* of a LaunchSpec — bit-identical logits, states and
    // counters — on both the fused engine and the caps-off
    // decomposition, planned and unplanned.
    let candidates = PlanChoice::candidates();
    check("wrappers ≡ launch", 30, |rng| {
        let fused = MockEngine::new();
        let decomp =
            MockEngine::with_caps(EngineCaps { varlen_kernel: false, ..EngineCaps::full() });
        let case = random_case(rng, fused.manifest());
        let plan = if rng.below(2) == 0 {
            Some(candidates[rng.below(candidates.len() as u64) as usize])
        } else {
            None
        };
        for e in [&fused, &decomp] {
            let a = via_wrapper(e, &case, plan);
            let b = via_launch(e, &case, plan);
            if a != b {
                return Err(format!(
                    "wrapper != direct (varlen={}, plan={:?}): {:?} vs {:?}",
                    e.caps().varlen_kernel,
                    plan,
                    (a.gathered, a.scattered, a.padded, a.device_calls, a.modeled),
                    (b.gathered, b.scattered, b.padded, b.device_calls, b.modeled),
                ));
            }
        }
        // And fused vs decomposition agree on outputs (not counters).
        let f = via_launch(&fused, &case, plan);
        let d = via_launch(&decomp, &case, plan);
        if f.logits != d.logits || f.conv != d.conv || f.ssm != d.ssm {
            return Err("fused and decomposition outputs diverged".into());
        }
        if f.device_calls != 1 {
            return Err(format!("fused launch made {} device calls", f.device_calls));
        }
        Ok(())
    });
}

#[test]
fn prop_step_mixed_value_wrapper_equals_launch() {
    // The allocating value-semantics wrapper: identity rows, packed
    // slabs (stride == batch), returned StepOutput — still just a
    // LaunchSpec underneath.
    check("step_mixed ≡ launch", 20, |rng| {
        let e = MockEngine::new();
        let m = e.manifest().clone();
        let (nl, plen) = (m.n_layer, m.prefill_len);
        let cpl = m.d_inner * (m.d_conv - 1);
        let spl = m.d_inner * m.d_state;
        let batch = rng.range(1, 4) as usize;
        let lens: Vec<usize> = (0..batch)
            .map(|_| match rng.below(3) {
                0 => 1,
                1 => plen,
                _ => rng.range(2, plen as u64 + 3) as usize,
            })
            .collect();
        let tokens: Vec<i32> = (0..lens.iter().sum::<usize>())
            .map(|_| rng.below(m.vocab as u64) as i32)
            .collect();
        // Packed layer-major slabs [nl, batch, per]; random carried
        // state, some rows zeroed (fresh).
        let mut conv = vec![0f32; nl * batch * cpl];
        let mut ssm = vec![0f32; nl * batch * spl];
        for x in conv.iter_mut() {
            *x = (rng.f64() as f32) - 0.5;
        }
        for x in ssm.iter_mut() {
            *x = (rng.f64() as f32) - 0.5;
        }
        for b in 0..batch {
            if rng.below(3) == 0 {
                for l in 0..nl {
                    conv[(l * batch + b) * cpl..(l * batch + b + 1) * cpl].fill(0.0);
                    ssm[(l * batch + b) * spl..(l * batch + b + 1) * spl].fill(0.0);
                }
            }
        }
        let case = Case {
            lens,
            rows: (0..batch).collect(),
            tokens,
            stride: batch,
            conv,
            ssm,
        };

        let out: StepOutput =
            e.step_mixed(&case.lens, &case.tokens, &case.conv, &case.ssm).unwrap();
        let direct = via_launch(&e, &case, None);
        if out.logits != direct.logits {
            return Err("logits diverged".into());
        }
        if out.conv_state != direct.conv || out.ssm_state != direct.ssm {
            return Err("states diverged".into());
        }
        Ok(())
    });
}

/// An engine whose `launch` flattens the typed spec back onto the
/// deprecated seven-slice wrapper of a wrapped mock — so a scheduler
/// running on it exercises the full legacy round-trip
/// (spec → slices → spec → fused launch) every tick.
struct LegacyShim(MockEngine);

impl Executor for LegacyShim {
    fn manifest(&self) -> &Manifest {
        self.0.manifest()
    }

    fn caps(&self) -> EngineCaps {
        self.0.caps()
    }

    fn prefill(&self, batch: usize, tokens: &[i32]) -> anyhow::Result<StepOutput> {
        self.0.prefill(batch, tokens)
    }

    fn decode(
        &self,
        batch: usize,
        tokens: &[i32],
        conv: &[f32],
        ssm: &[f32],
    ) -> anyhow::Result<StepOutput> {
        self.0.decode(batch, tokens, conv, ssm)
    }

    fn launch(&self, spec: LaunchSpec<'_>) -> anyhow::Result<()> {
        let LaunchSpec { batch, mut state, plan, ws } = spec;
        let lens: Vec<usize> = batch.segments().iter().map(|s| s.len).collect();
        let rows: Vec<usize> = batch.segments().iter().map(|s| s.row).collect();
        let stride = state.stride();
        let (conv, ssm) = state.slabs_mut();
        match plan {
            Some(c) => self
                .0
                .step_planned_into(c, &lens, batch.tokens(), &rows, conv, ssm, stride, ws),
            None => self.0.step_mixed_into(&lens, batch.tokens(), &rows, conv, ssm, stride, ws),
        }
    }
}

#[test]
fn prop_scheduler_on_wrappers_matches_direct_engine_on_both_paths() {
    // Serve randomized workloads through the scheduler with the engine
    // surface round-tripped through the deprecated wrappers every tick:
    // tokens and every traffic/plan counter must be bit-identical to
    // the direct engine, on both scheduler state paths.
    check("scheduler wrapper-shim ≡ direct", 12, |rng| {
        let probe = MockEngine::new();
        let (vocab, plen) = (probe.manifest().vocab, probe.manifest().prefill_len);
        let policy = BatchPolicy {
            chunk_tokens: rng.range(0, 6) as usize,
            token_budget: rng.range(1, 24) as usize,
            max_chunk_rows: rng.range(1, 5) as usize,
            max_running: rng.range(1, 8) as usize,
            decode_priority_threshold: rng.range(1, 10) as usize,
        };
        let seed = rng.next_u64();
        let n_reqs = rng.range(1, 6);
        let make_reqs = |seed: u64| {
            let mut gen =
                WorkloadGen::new(seed, vocab, plen, 1, 6).with_prompt_range(1, 3 * plen);
            (0..n_reqs).map(|_| gen.next_request()).collect::<Vec<Request>>()
        };
        for path in [StatePath::Resident, StatePath::Reference] {
            let run = |shim: bool| {
                let mut out;
                let metrics;
                if shim {
                    let mut s =
                        Scheduler::with_path(LegacyShim(MockEngine::new()), policy.clone(), path);
                    for r in make_reqs(seed) {
                        s.submit(r).unwrap();
                    }
                    out = s.run_until_drained().unwrap();
                    metrics = s.metrics().traffic_snapshot();
                } else {
                    let mut s = Scheduler::with_path(MockEngine::new(), policy.clone(), path);
                    for r in make_reqs(seed) {
                        s.submit(r).unwrap();
                    }
                    out = s.run_until_drained().unwrap();
                    metrics = s.metrics().traffic_snapshot();
                }
                out.sort_by_key(|r| r.id);
                let tokens: Vec<Vec<i32>> = out.into_iter().map(|r| r.tokens).collect();
                (tokens, metrics)
            };
            let (direct_tokens, direct) = run(false);
            let (shim_tokens, shim) = run(true);
            if direct_tokens != shim_tokens {
                return Err(format!("{path:?}: tokens diverged through the wrappers"));
            }
            if direct != shim {
                return Err(format!(
                    "{path:?}: counters diverged: {direct:?} vs {shim:?}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn aliased_rows_are_rejected_not_corrupting() {
    // The regression the legacy surface could not catch: two batch rows
    // sharing one slab row. Before the typed batch this was only a doc
    // comment — an in-place engine would advance the shared row twice
    // and silently corrupt both sequences' outputs. Now it is an error
    // at every entry point.
    let e = MockEngine::new();
    let m = e.manifest().clone();
    let cp = m.conv_state_elems();
    let sp = m.ssm_state_elems();
    let mut conv = vec![0f32; 2 * cp];
    let mut ssm = vec![0f32; 2 * sp];
    let mut ws = Workspace::new();

    // Direct construction fails…
    let segs = [
        Segment { len: 1, row: 1, phase: Phase::Decode },
        Segment { len: 1, row: 1, phase: Phase::Decode },
    ];
    let err = MixedBatch::new(&segs, &[3, 4]).unwrap_err();
    assert!(err.to_string().contains("aliased slab row 1"), "{err}");

    // …and so does the legacy wrapper that used to let it through.
    let err = e
        .step_mixed_into(&[1, 1], &[3, 4], &[1, 1], &mut conv, &mut ssm, 2, &mut ws)
        .unwrap_err();
    assert!(err.to_string().contains("aliased"), "{err}");
    // Nothing ran: no device calls, no logits.
    assert_eq!(ws.device_calls(), 0);

    // Distinct rows on the same engine still work.
    e.step_mixed_into(&[1, 1], &[3, 4], &[0, 1], &mut conv, &mut ssm, 2, &mut ws).unwrap();
    assert_eq!(ws.take_device_calls(), 1);
}

#[test]
fn caps_disallowed_plan_is_never_dispatched() {
    // An engine that cannot execute fully-fused: the planner must mask
    // it out at construction and never dispatch it — and the served
    // tokens are identical to a fully-capable engine's (plan choice
    // can never change outputs).
    let ff = PlanChoice::candidates()[0];
    let mut limited = EngineCaps::full();
    limited.plans[ff.index()] = false;

    let serve = |caps: EngineCaps| {
        // The bundled prefill-heavy scenario: pure 4096-token prefill
        // ticks, the bucket where fully-fused is the pinned argmin.
        let sc = mambalaya::bench_util::ServeScenario::prefill_heavy();
        let vocab = MockEngine::new().manifest().vocab;
        let mut s = Scheduler::with_planner(
            MockEngine::with_caps(caps),
            sc.policy.clone(),
            StatePath::Resident,
            Planner::with_dwell(PlanSpec::Adaptive, 1),
        );
        for r in sc.requests(vocab) {
            s.submit(r).unwrap();
        }
        let mut out = s.run_until_drained().unwrap();
        out.sort_by_key(|r| r.id);
        let tokens: Vec<Vec<i32>> = out.into_iter().map(|r| r.tokens).collect();
        (tokens, s.metrics().ticks_per_plan)
    };

    let (full_tokens, full_plans) = serve(EngineCaps::full());
    let (lim_tokens, lim_plans) = serve(limited);
    assert_eq!(full_tokens, lim_tokens, "capability masking changed tokens");
    assert!(
        full_plans[ff.index()] > 0,
        "scenario must make fully-fused attractive for the unrestricted engine"
    );
    assert_eq!(lim_plans[ff.index()], 0, "disallowed plan was dispatched");
}

#[test]
fn donation_annotation_is_observability_only_on_host_engines() {
    // Retain vs DonateInPlace: for in-process engines the annotation
    // changes nothing observable (a PJRT backend would read it to set
    // input/output aliasing); it must not change outputs or counters.
    let e = MockEngine::new();
    let m = e.manifest().clone();
    let segs = [
        Segment { len: 4, row: 0, phase: Phase::PrefillFirst },
        Segment { len: 1, row: 1, phase: Phase::Decode },
    ];
    let tokens = [5i32, 6, 7, 8, 9];
    let run = |donation: Donation| {
        let mut conv = vec![0f32; 2 * m.conv_state_elems()];
        let mut ssm = vec![0f32; 2 * m.ssm_state_elems()];
        let mut ws = Workspace::new();
        e.launch(LaunchSpec {
            batch: MixedBatch::new(&segs, &tokens).unwrap(),
            state: StateSlabs::new(&mut conv, &mut ssm, 2, donation),
            plan: None,
            ws: &mut ws,
        })
        .unwrap();
        drain(&mut ws, &conv, &ssm)
    };
    assert_eq!(run(Donation::Retain), run(Donation::DonateInPlace));
}
