//! Property and mutation tests for the static verifier.
//!
//! Clean direction: every `PlanChoice` on every cascade verifies with
//! zero Error findings, every plan is donation-safe, and the recomputed
//! live-set traffic matches `model::evaluate` within the documented
//! tolerance. Mutation direction: corrupt a plan in a specific way
//! (non-convex split, back-edge, reordered execution, phantom join,
//! escaping internal tensor, use-after-overwrite donation hazard) and
//! assert the verifier reports exactly the planted kind of Finding.
//! The source lint is unit-tested on synthetic sources.

use mambalaya::arch::ArchSpec;
use mambalaya::cascade::{mamba1, mamba2, ModelConfig};
use mambalaya::einsum::Cascade;
use mambalaya::fusion::{stitch, FusionPlan, FusionVariant};
use mambalaya::model::ExecOptions;
use mambalaya::planner::PlanChoice;
use mambalaya::runtime::EngineCaps;
use mambalaya::verify::{self, DataflowGraph, FindingCode, Severity};

fn prefill() -> Cascade {
    mamba1::build(&ModelConfig::mamba_370m(), 512, 1)
}

fn decode() -> Cascade {
    mamba1::build(&ModelConfig::mamba_370m(), 1, 64)
}

/// The RI+RSb+RSp plan: three groups ([1..8], [9..13], [14..24]) — the
/// richest structure to mutate.
fn three_group_plan(c: &Cascade) -> FusionPlan {
    let plan = stitch(c, FusionVariant::RIRSbRSp);
    assert_eq!(plan.groups.len(), 3, "mutation tests assume the paper's 3-group plan");
    plan
}

fn codes(findings: &[verify::Finding]) -> Vec<FindingCode> {
    findings.iter().map(|f| f.code).collect()
}

// ---------------------------------------------------------------- clean

#[test]
fn all_plans_on_all_cascades_verify_clean() {
    let report = verify::verify_cascades();
    let errors: Vec<_> =
        report.findings.iter().filter(|f| f.severity == Severity::Error).collect();
    assert!(errors.is_empty(), "shipped plans must verify clean, got: {errors:#?}");
    // 7 PlanChoices × 4 scenario cascades (mamba1 prefill+decode,
    // mamba2, transformer).
    assert_eq!(report.plans.len(), 4 * PlanChoice::COUNT);
    assert!(
        report.plans.iter().all(|p| p.donation_safe),
        "every shipped plan must carry a donation_safe verdict of true"
    );
}

#[test]
fn traffic_audit_matches_model_for_all_mamba1_plans() {
    let arch = ArchSpec::mambalaya();
    for (c, decode_state_io) in [(prefill(), false), (decode(), true)] {
        for point in PlanChoice::all() {
            let plan = point.plan(&c);
            let opts = ExecOptions {
                staging: point.staging(),
                pipelined: false,
                decode_state_io,
            };
            let audit = verify::audit_plan(&c, &plan, &arch, &opts, "test");
            assert!(
                audit.findings.is_empty(),
                "plan {} diverged: {:#?}",
                point.name(),
                audit.findings
            );
            assert!(
                audit.evaluated_inter >= audit.min_inter,
                "plan {}: evaluate ({}) below the liveness minimum ({})",
                point.name(),
                audit.evaluated_inter,
                audit.min_inter
            );
            let drift = (audit.evaluated_inter as f64 - audit.expected_inter as f64).abs()
                / audit.expected_inter.max(1) as f64;
            assert!(
                drift <= verify::TRAFFIC_TOLERANCE,
                "plan {}: drift {drift} exceeds tolerance",
                point.name()
            );
        }
    }
}

#[test]
fn dataflow_graph_separates_generational_edges() {
    let c = prefill();
    let g = DataflowGraph::build(&c);
    // The H[i-1] recurrence is a generational edge, never a
    // same-generation dependency for its lagged reader...
    assert!(
        g.generational.iter().any(|e| e.tensor == "H" && e.from != e.to),
        "H recurrence should be a generational edge"
    );
    // ...while the conv's forward windowed access (TX window includes
    // offset 0) is a real dependency.
    assert!(
        g.deps.iter().any(|e| e.tensor == "TX"),
        "windowed TX access should be a same-generation dependency"
    );
    // Same-generation dependencies always point forward in id order.
    assert!(g.deps.iter().all(|e| e.from < e.to));

    // Mamba-2's Hs recurrence is a self-loop (read-modify-write).
    let c2 = mamba2::build(&ModelConfig::mamba_370m(), 512, 1);
    let g2 = DataflowGraph::build(&c2);
    assert!(g2.generational.iter().any(|e| e.from == e.to), "Hs self-recurrence");
}

// ------------------------------------------------------------ mutations

#[test]
fn mutation_non_convex_split_is_caught() {
    let c = prefill();
    let g = DataflowGraph::build(&c);
    let mut plan = three_group_plan(&c);
    // Steal one middle member of group 1 into group 0: the path through
    // the remaining group-1 members now leaves group 0 and re-enters.
    let stolen = plan.groups[1].einsums[1];
    plan.groups[1].einsums.retain(|&id| id != stolen);
    plan.groups[1].joins.retain(|j| j.einsum != stolen);
    plan.groups[0].einsums.push(stolen);
    let findings = verify::check_plan(&c, &g, &plan, "mutation");
    assert!(
        codes(&findings).contains(&FindingCode::NonConvexGroup),
        "expected NonConvexGroup, got {findings:#?}"
    );
}

#[test]
fn mutation_back_edge_creates_group_cycle() {
    let c = prefill();
    let g = DataflowGraph::build(&c);
    let mut plan = three_group_plan(&c);
    // Pull the last einsum of the cascade into the first group: its
    // inputs come from the last group, whose inputs come from the
    // first — a condensed-graph cycle.
    let last = *plan.groups[2].einsums.last().expect("non-empty group");
    plan.groups[2].einsums.retain(|&id| id != last);
    plan.groups[2].joins.retain(|j| j.einsum != last);
    plan.groups[0].einsums.push(last);
    let findings = verify::check_plan(&c, &g, &plan, "mutation");
    assert!(
        codes(&findings).contains(&FindingCode::GroupCycle),
        "expected GroupCycle, got {findings:#?}"
    );
}

#[test]
fn mutation_reordered_groups_violate_execution_order() {
    let c = prefill();
    let g = DataflowGraph::build(&c);
    let mut plan = three_group_plan(&c);
    plan.groups.swap(0, 1);
    let findings = verify::check_plan(&c, &g, &plan, "mutation");
    assert!(
        codes(&findings).contains(&FindingCode::ExecOrder),
        "expected ExecOrder, got {findings:#?}"
    );
    // Groups stay individually convex and the condensation stays
    // acyclic — only the chosen order is unlawful.
    assert!(!codes(&findings).contains(&FindingCode::NonConvexGroup));
    assert!(!codes(&findings).contains(&FindingCode::GroupCycle));
}

#[test]
fn mutation_phantom_join_is_caught() {
    let c = prefill();
    let g = DataflowGraph::build(&c);

    // (a) Claimed link via an einsum outside the group.
    let mut plan = three_group_plan(&c);
    plan.groups[0].joins[1].via = Some(*plan.groups[2].einsums.last().expect("member"));
    let findings = verify::check_plan(&c, &g, &plan, "mutation");
    assert!(
        codes(&findings).contains(&FindingCode::PhantomJoin),
        "expected PhantomJoin (outside via), got {findings:#?}"
    );

    // (b) Claimed intermediate tensor that does not flow on the link.
    let mut plan = three_group_plan(&c);
    let j = plan.groups[0]
        .joins
        .iter_mut()
        .find(|j| j.via.is_some())
        .expect("a recorded fusion link");
    j.tensor = Some("NotATensor".to_string());
    let findings = verify::check_plan(&c, &g, &plan, "mutation");
    assert!(
        codes(&findings).contains(&FindingCode::PhantomJoin),
        "expected PhantomJoin (wrong tensor), got {findings:#?}"
    );
}

#[test]
fn mutation_escaping_internal_tensor_is_caught() {
    let c = prefill();
    let g = DataflowGraph::build(&c);
    let mut plan = three_group_plan(&c);
    // LEX escapes group 1 (consumed by the SSM region downstream), so
    // marking it internal is a lie the cost model would act on.
    plan.groups[1].internal_tensors.push("LEX".to_string());
    let findings = verify::check_plan(&c, &g, &plan, "mutation");
    assert!(
        findings
            .iter()
            .any(|f| f.code == FindingCode::InternalTensors && f.severity == Severity::Error),
        "expected InternalTensors error, got {findings:#?}"
    );
}

#[test]
fn mutation_state_reorder_is_donation_unsafe() {
    let c = decode();
    let mut plan = three_group_plan(&c);
    // Clean plan: safe.
    assert!(verify::analyze_donation(&c, &plan, "clean").safe);
    // Swap the H[i-1] reader and the H writer inside the SSM group: the
    // lagged reader now runs after the in-place update commits.
    let (reader, writer) = {
        let grp = &plan.groups[2];
        let h_writer = c
            .einsums()
            .iter()
            .find(|e| e.output.name == "H")
            .expect("H producer")
            .id;
        let h_reader = c
            .einsums()
            .iter()
            .find(|e| e.id != h_writer && e.operand("H").is_some())
            .expect("H lagged reader")
            .id;
        assert!(grp.einsums.contains(&h_writer) && grp.einsums.contains(&h_reader));
        (h_reader, h_writer)
    };
    let grp = &mut plan.groups[2];
    let ri = grp.einsums.iter().position(|&id| id == reader).expect("reader pos");
    let wi = grp.einsums.iter().position(|&id| id == writer).expect("writer pos");
    grp.einsums.swap(ri, wi);
    let verdict = verify::analyze_donation(&c, &plan, "mutation");
    assert!(!verdict.safe, "reordered plan must be donation-unsafe");
    assert!(
        codes(&verdict.findings).contains(&FindingCode::DonationUnsafe),
        "expected DonationUnsafe, got {:#?}",
        verdict.findings
    );
}

#[test]
fn donation_caps_consistency() {
    let all_safe = [true; PlanChoice::COUNT];
    let mut one_unsafe = all_safe;
    one_unsafe[0] = false;

    // A donation-advertising caps is sound only over safe plans.
    assert!(EngineCaps::full().donation_sound(&all_safe));
    assert!(!EngineCaps::full().donation_sound(&one_unsafe));
    // Masking the unsafe plan out restores soundness.
    let mut masked = EngineCaps::full();
    masked.plans[0] = false;
    assert!(masked.donation_sound(&one_unsafe));
    // Without donation there is nothing to be unsound about.
    assert!(EngineCaps::baseline().donation_sound(&one_unsafe));
}

// ----------------------------------------------------------------- lint

#[test]
fn lint_flags_wall_clock_outside_allowlist_only() {
    let src = "use std::time::Instant;\nfn f() -> Instant { Instant::now() }\n";
    let findings = verify::lint_file("coordinator/admission.rs", src);
    assert!(findings.iter().any(|f| f.code == FindingCode::LintWallClock));
    // Allowlisted file: same content, no wall-clock finding.
    let findings = verify::lint_file("coordinator/metrics.rs", src);
    assert!(findings.iter().all(|f| f.code != FindingCode::LintWallClock));
}

#[test]
fn lint_word_boundary_does_not_match_substrings() {
    let src = "fn f() { let x = InstantaneousRate::default(); }\n";
    assert!(verify::lint_file("coordinator/foo.rs", src).is_empty());
}

#[test]
fn lint_skips_cfg_test_regions_and_comments() {
    let src = "\
fn shipped() {}
// a comment mentioning Instant and .unwrap() is fine
#[cfg(test)]
mod tests {
    use std::time::Instant;
    #[test]
    fn t() {
        let _ = Instant::now();
        let _ = Some(1).unwrap();
    }
}
";
    assert!(verify::lint_file("coordinator/foo.rs", src).is_empty());
}

#[test]
fn lint_flags_bare_unwrap_in_hot_paths_only() {
    let src = "fn f() { Some(1).unwrap(); }\n";
    assert!(verify::lint_file("runtime/foo.rs", src)
        .iter()
        .any(|f| f.code == FindingCode::LintHotPathUnwrap));
    assert!(verify::lint_file("coordinator/foo.rs", src)
        .iter()
        .any(|f| f.code == FindingCode::LintHotPathUnwrap));
    // Analytical-layer code is not a hot path.
    assert!(verify::lint_file("model/foo.rs", src).is_empty());
}

#[test]
fn lint_counts_hot_path_expects_as_warn() {
    let src = "fn f() { a.expect(\"x\"); b.expect(\"y\"); }\n";
    let findings = verify::lint_file("runtime/foo.rs", src);
    let warn = findings
        .iter()
        .find(|f| f.code == FindingCode::LintHotPathExpect)
        .expect("expect() warn");
    assert_eq!(warn.severity, Severity::Warn);
    assert!(warn.message.starts_with("2 "), "counts both calls: {}", warn.message);
}

#[test]
fn lint_flags_deprecated_executor_calls_outside_engine() {
    let src = "fn f(e: &dyn Executor) { e.step_mixed(&a, &b, &c, &d).ok(); }\n";
    assert!(verify::lint_file("coordinator/foo.rs", src)
        .iter()
        .any(|f| f.code == FindingCode::LintDeprecatedCall));
    // The wrapper definitions live in runtime/engine.rs — exempt.
    let findings = verify::lint_file("runtime/engine.rs", src);
    assert!(findings.iter().all(|f| f.code != FindingCode::LintDeprecatedCall));
}

#[test]
fn shipped_tree_lints_clean_of_errors() {
    let root = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let lint = verify::lint_tree(&root);
    assert!(lint.files_scanned > 50, "walker should see the whole tree");
    let errors: Vec<_> =
        lint.findings.iter().filter(|f| f.severity == Severity::Error).collect();
    assert!(errors.is_empty(), "shipped tree must lint clean: {errors:#?}");
}

#[test]
fn baseline_plans_cover_every_cascade() {
    // The verifier's coverage pass caught `baseline_plan` dropping a
    // pending SSM group on cascades holding only a prefix of the
    // region ids (Mamba-2 has einsum 16 but not 21) — pin the fix.
    let c = mamba2::build(&ModelConfig::mamba_370m(), 512, 1);
    for point in PlanChoice::all() {
        let plan = point.plan(&c);
        plan.validate(&c).unwrap_or_else(|e| {
            panic!("plan {} must cover mamba2: {e}", point.name());
        });
    }
}
