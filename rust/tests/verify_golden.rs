//! Golden snapshot of the static verifier's analytic report — every
//! (scenario cascade × `PlanChoice`) record: group counts, donation
//! verdicts and the three inter-traffic figures (liveness minimum,
//! recomputed expectation, `model::evaluate`), plus any findings.
//!
//! The text rendering deliberately excludes the source lint (its
//! output depends on the working tree, not the analytical layer) so
//! this snapshot only drifts when the cascades, fusion plans, cost
//! model or verifier semantics change. On the first run (or with
//! `UPDATE_GOLDEN=1`) the snapshot is (re)blessed; afterwards any
//! change fails with a diff hint, same as `fusion_golden`.

use std::path::PathBuf;

use mambalaya::verify;

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden/verify_report.txt")
}

#[test]
fn verify_report_is_byte_stable() {
    let report = verify::verify_cascades();
    // Teeth while blessing: the shipped tree must verify clean.
    assert_eq!(report.errors(), 0, "shipped plans must verify clean: {:#?}", report.findings);
    let rendered = report.render_text();
    let path = golden_path();
    let bless = std::env::var("UPDATE_GOLDEN").is_ok() || !path.exists();
    if bless {
        std::fs::create_dir_all(path.parent().unwrap()).expect("mkdir golden");
        std::fs::write(&path, &rendered).expect("write golden");
        eprintln!(
            "blessed golden snapshot at {} — COMMIT this file; ci.sh re-runs this test \
             and fails while it is untracked",
            path.display()
        );
        return;
    }
    let want = std::fs::read_to_string(&path).expect("read golden");
    if rendered != want {
        // Point at the first diverging line for a usable failure.
        for (i, (a, b)) in rendered.lines().zip(want.lines()).enumerate() {
            assert_eq!(
                a,
                b,
                "verify report drifted at line {} of {} (rerun with UPDATE_GOLDEN=1 to rebless)",
                i + 1,
                path.display()
            );
        }
        panic!(
            "verify report length drifted: {} vs {} lines (rerun with UPDATE_GOLDEN=1 to rebless)",
            rendered.lines().count(),
            want.lines().count()
        );
    }
}
