//! Socket conformance for the network front-end: N concurrent TCP
//! clients through [`mambalaya::frontend::serve`] must be
//! bit-identical to in-process [`serve_all`], every submitted id must
//! receive exactly one terminal frame (sheds included), and the
//! server-side trace must reconcile with shed requests as terminal
//! `Failed` spans.

use std::net::TcpListener;
use std::time::Duration;

use mambalaya::coordinator::{serve_all, BatchPolicy, Request, Server};
use mambalaya::frontend::{
    run_client, serve, AdmissionConfig, FrontendConfig, Priority, PROTOCOL_VERSION,
};
use mambalaya::frontend::{write_frame, Frame};
use mambalaya::obs::{assemble_spans, reconcile, TraceEvent};
use mambalaya::runtime::MockEngine;

fn requests_for(client: usize, vocab: usize) -> Vec<(Request, Priority)> {
    let v = vocab as i32;
    (0..5u64)
        .map(|k| {
            let id = 500 * client as u64 + k;
            let class = match k % 3 {
                0 => Priority::Interactive,
                1 => Priority::Standard,
                _ => Priority::Batch,
            };
            (
                Request {
                    id,
                    prompt: (0..(4 + k as i32 + client as i32))
                        .map(|x| (x * 3 + id as i32 + 1) % v)
                        .collect(),
                    max_new_tokens: 2 + (k as usize % 4),
                },
                class,
            )
        })
        .collect()
}

/// Permissive admission: all classes fully shared, no backstops — the
/// wire path itself is what's under test.
fn open_frontend(max_connections: usize) -> FrontendConfig {
    FrontendConfig {
        admission: AdmissionConfig::default(),
        max_connections: Some(max_connections),
    }
}

#[test]
fn concurrent_clients_match_serve_all_bit_for_bit() {
    let vocab = MockEngine::new().manifest().vocab;
    let n_clients = 4;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server = Server::start(vec![|| Ok(MockEngine::new())], BatchPolicy::default());
    let srv = std::thread::spawn(move || {
        serve(listener, server, open_frontend(n_clients)).expect("serve loop")
    });

    let handles: Vec<_> = (0..n_clients)
        .map(|c| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let reqs = requests_for(c, vocab);
                let replies =
                    run_client(&addr, &reqs, Some(Duration::from_secs(60))).expect("client");
                (reqs, replies)
            })
        })
        .collect();

    let mut all_reqs: Vec<Request> = Vec::new();
    let mut wire: Vec<(u64, Vec<i32>)> = Vec::new();
    for h in handles {
        let (reqs, replies) = h.join().expect("client thread");
        assert_eq!(replies.len(), reqs.len(), "exactly one terminal per submitted id");
        for ((req, _), reply) in reqs.into_iter().zip(replies) {
            assert_eq!(req.id, reply.id);
            assert!(reply.error.is_none(), "request {} errored: {:?}", req.id, reply.error);
            assert_eq!(reply.tokens.len(), req.max_new_tokens, "full stream for {}", req.id);
            wire.push((req.id, reply.tokens.clone()));
            all_reqs.push(req);
        }
    }
    let (mut server, stats) = srv.join().expect("serve thread");
    assert_eq!(stats.connections as usize, n_clients);
    assert_eq!(stats.requests as usize, all_reqs.len());
    assert_eq!(stats.shed, [0, 0, 0], "permissive config sheds nothing");
    assert_eq!(stats.errors, 0);
    assert_eq!(
        stats.admitted.iter().sum::<u64>() as usize,
        all_reqs.len(),
        "every submit admitted"
    );

    let events = server.trace();
    reconcile(&events, &server.traffic()).expect("socket-served trace reconciles");
    let spans = assemble_spans(&events);
    assert_eq!(spans.len(), all_reqs.len(), "one span per request");
    server.shutdown();

    // The in-process baseline on identical requests: identical tokens.
    let (resps, _) =
        serve_all(|| Ok(MockEngine::new()), BatchPolicy::default(), all_reqs).unwrap();
    let baseline: std::collections::HashMap<u64, Vec<i32>> =
        resps.into_iter().map(|r| (r.id, r.tokens)).collect();
    for (id, tokens) in &wire {
        assert_eq!(
            baseline.get(id),
            Some(tokens),
            "request {id}: socket stream diverged from serve_all"
        );
    }
}

#[test]
fn shed_requests_get_exactly_one_error_frame() {
    let vocab = MockEngine::new().manifest().vocab;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server = Server::start(vec![|| Ok(MockEngine::new())], BatchPolicy::default());
    let cfg = FrontendConfig {
        admission: AdmissionConfig {
            shares: [1.0, 1.0, 0.0], // batch always sheds
            ..AdmissionConfig::default()
        },
        max_connections: Some(1),
    };
    let srv = std::thread::spawn(move || serve(listener, server, cfg).expect("serve loop"));

    let reqs: Vec<(Request, Priority)> = (0..6u64)
        .map(|k| {
            (
                Request {
                    id: k,
                    prompt: (0..6).map(|x| (x * 5 + k as i32 + 1) % vocab as i32).collect(),
                    max_new_tokens: 3,
                },
                if k % 2 == 0 { Priority::Interactive } else { Priority::Batch },
            )
        })
        .collect();
    let replies = run_client(&addr, &reqs, Some(Duration::from_secs(60))).expect("client");
    assert_eq!(replies.len(), reqs.len());
    for ((req, prio), reply) in reqs.iter().zip(&replies) {
        if *prio == Priority::Batch {
            let err = reply.error.as_deref().expect("batch request shed");
            assert!(err.contains("shed"), "wire carries the shed reason: {err}");
            assert!(reply.tokens.is_empty());
        } else {
            assert!(reply.error.is_none(), "interactive request {} failed", req.id);
            assert_eq!(reply.tokens.len(), req.max_new_tokens);
        }
    }

    let (mut server, stats) = srv.join().expect("serve thread");
    assert_eq!(stats.shed, [0, 0, 3]);
    assert_eq!(stats.errors, 3, "one Error frame per shed request");
    let events = server.trace();
    let traffic = server.traffic();
    assert_eq!(traffic.requests_shed, 3);
    reconcile(&events, &traffic).expect("shed spans reconcile");
    let spans = assemble_spans(&events);
    let failed = spans
        .iter()
        .filter(|sp| matches!(sp.terminal(), Some(TraceEvent::Failed)))
        .count();
    assert_eq!(failed, 3, "every shed request is a terminal Failed span");
    server.shutdown();
}

#[test]
fn malformed_handshake_is_answered_and_closed() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server = Server::start(vec![|| Ok(MockEngine::new())], BatchPolicy::default());
    let srv = std::thread::spawn(move || {
        serve(listener, server, open_frontend(1)).expect("serve loop")
    });

    // Speak the wrong first frame: a Token instead of Hello. The
    // server must answer with an Error frame and close — not hang,
    // not crash the serve loop.
    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    write_frame(&mut stream, &Frame::Token { id: 1, token: 2 }).unwrap();
    match mambalaya::frontend::read_frame(&mut stream).expect("server answers") {
        Frame::Error { reason, .. } => {
            assert!(reason.contains("Hello"), "names the handshake violation: {reason}")
        }
        other => panic!("expected Error frame, got {other:?}"),
    }
    drop(stream);

    let (server, stats) = srv.join().expect("serve loop survives bad client");
    assert_eq!(stats.connections, 1);
    assert_eq!(stats.requests, 0, "nothing reached the coordinator");
    server.shutdown();
    // PROTOCOL_VERSION is pinned by the wire suite; referenced here so
    // handshake coverage fails loudly if the constant moves crates.
    assert_eq!(PROTOCOL_VERSION, 1);
}
