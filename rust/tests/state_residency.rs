//! Zero-copy state residency, tested hermetically against
//! `runtime::mock`:
//!
//! * the arena-backed resident scheduler emits **bit-identical tokens
//!   and per-request counter metrics** to the fresh-allocation
//!   reference path across randomized mixed workloads (the tentpole
//!   equivalence);
//! * a steady-state decode tick (unchanged batch membership) moves
//!   **zero** state bytes and ships zero padded rows;
//! * in the chunked long-prompt interference scenario the resident
//!   path's deterministic traffic counters are ≥ 10× lower than the
//!   reference (pre-refactor) path's — the PR's acceptance bar;
//! * `StateArena` slot reuse: release → re-admit reuses the row
//!   (LIFO free-list) and the counters stay consistent throughout.

use mambalaya::coordinator::{
    BatchPolicy, Request, Scheduler, StateArena, StatePath, WorkloadGen,
};
use mambalaya::prop::check;
use mambalaya::runtime::MockEngine;
use mambalaya::util::XorShift;

/// Serve `reqs` to completion on one path; returns (sorted per-request
/// token streams, counter-metric vector, traffic totals as
/// (gathered, scattered, padded)).
fn run_path(
    path: StatePath,
    policy: BatchPolicy,
    reqs: &[Request],
) -> (Vec<Vec<i32>>, Vec<u64>, (u64, u64, u64)) {
    let mut s = Scheduler::with_path(MockEngine::new(), policy, path);
    for r in reqs {
        s.submit(r.clone()).unwrap();
    }
    let mut out = s.run_until_drained().unwrap();
    out.sort_by_key(|r| r.id);
    let tokens = out.into_iter().map(|r| r.tokens).collect();
    let m = s.metrics();
    let counters = vec![
        m.tokens_generated,
        m.prefill_chunks,
        m.prefill_tokens,
        m.decode_steps,
        m.ticks,
        m.max_tick_tokens,
        m.requests_completed,
        m.ttft_count() as u64,
    ];
    (tokens, counters, (m.bytes_gathered, m.bytes_scattered, m.padded_rows))
}

fn random_policy(rng: &mut XorShift) -> BatchPolicy {
    BatchPolicy {
        chunk_tokens: rng.range(0, 6) as usize,
        token_budget: rng.range(1, 24) as usize,
        max_chunk_rows: rng.range(1, 5) as usize,
        max_running: rng.range(1, 8) as usize,
        decode_priority_threshold: rng.range(1, 10) as usize,
    }
}

#[test]
fn prop_resident_equals_reference_across_random_workloads() {
    // The tentpole equivalence: keeping state resident in the arena
    // (in-place engine updates, zero-copy row plans) must not change a
    // single sampled token or counter metric relative to the
    // pre-refactor gather/step/scatter reference — across random
    // policies, prompt lengths, and admission interleavings.
    check("resident ≡ reference", 25, |rng| {
        let probe = MockEngine::new();
        let (vocab, plen) = (probe.manifest().vocab, probe.manifest().prefill_len);
        let policy = random_policy(rng);
        let mut gen = WorkloadGen::new(rng.next_u64(), vocab, plen, 1, 6)
            .with_prompt_range(1, 3 * plen);
        let reqs: Vec<Request> =
            (0..rng.range(1, 8)).map(|_| gen.next_request()).collect();

        let (tok_a, cnt_a, traffic_a) = run_path(StatePath::Resident, policy.clone(), &reqs);
        let (tok_b, cnt_b, traffic_b) = run_path(StatePath::Reference, policy, &reqs);
        if tok_a != tok_b {
            return Err(format!("tokens diverged: {tok_a:?} vs {tok_b:?}"));
        }
        if cnt_a != cnt_b {
            return Err(format!("counter metrics diverged: {cnt_a:?} vs {cnt_b:?}"));
        }
        // The resident path may never move more bytes than the
        // reference (on the fused mock it moves none at all).
        let (ga, sa, _) = traffic_a;
        let (gb, sb, _) = traffic_b;
        if ga + sa > gb + sb {
            return Err(format!(
                "resident path moved more bytes than reference: {} > {}",
                ga + sa,
                gb + sb
            ));
        }
        Ok(())
    });
}

#[test]
fn steady_state_decode_ticks_move_zero_bytes() {
    // Once every prompt is prefilled and the batch membership stops
    // changing, each tick must gather nothing, scatter nothing, pad
    // nothing — state stays resident and the engine advances it in
    // place.
    let policy = BatchPolicy {
        chunk_tokens: 4,
        token_budget: 16,
        max_chunk_rows: 4,
        max_running: 8,
        decode_priority_threshold: 8,
    };
    let mut s = Scheduler::new(MockEngine::new(), policy);
    for id in 0..4u64 {
        s.submit(Request {
            id,
            prompt: vec![1 + id as i32; 3],
            max_new_tokens: 64,
        })
        .unwrap();
    }
    // Drive until all four are running (prefill finished).
    let mut guard = 0;
    while s.waiting() > 0 {
        s.tick().unwrap();
        guard += 1;
        assert!(guard < 100, "prefill never drained");
    }
    assert_eq!(s.running(), 4);

    let m = s.metrics();
    let (g0, s0, p0) = (m.bytes_gathered, m.bytes_scattered, m.padded_rows);
    let resident = m.state_bytes_resident;
    assert_eq!(resident, 4 * s.state_arena().bytes_per_seq() as u64);

    // Ten steady-state decode ticks: membership unchanged, zero bytes.
    for _ in 0..10 {
        let before = s.metrics().tokens_generated;
        s.tick().unwrap();
        assert_eq!(s.metrics().tokens_generated, before + 4);
    }
    let m = s.metrics();
    assert_eq!(m.bytes_gathered, g0, "steady-state tick gathered bytes");
    assert_eq!(m.bytes_scattered, s0, "steady-state tick scattered bytes");
    assert_eq!(m.padded_rows, p0, "steady-state tick shipped padded rows");
    assert_eq!(m.state_bytes_resident, resident, "residency changed");
}

/// The hotpath-bench interference scenario, shrunk: six short-prompt
/// decoders ride along while one long prompt prefills in chunks.
fn interference_reqs(vocab: usize) -> Vec<Request> {
    let mut reqs: Vec<Request> = (0..6)
        .map(|i| Request {
            id: i,
            prompt: vec![(i % 7) as i32 + 1; 4],
            max_new_tokens: 32,
        })
        .collect();
    reqs.push(Request {
        id: 99,
        prompt: (0..256).map(|x| x % vocab as i32).collect(),
        max_new_tokens: 4,
    });
    reqs
}

#[test]
fn interference_traffic_at_least_10x_lower_on_resident_path() {
    // The acceptance criterion: in the chunked-interference scenario
    // the deterministic bytes-moved counters drop by ≥ 10× (on the
    // fused mock they drop to zero; max(1) keeps the ratio finite).
    let policy = BatchPolicy {
        chunk_tokens: 16,
        token_budget: 32,
        max_chunk_rows: 2,
        max_running: 8,
        decode_priority_threshold: 8,
    };
    let vocab = MockEngine::new().manifest().vocab;
    let reqs = interference_reqs(vocab);
    let (tok_res, _, (gr, sr, _)) = run_path(StatePath::Resident, policy.clone(), &reqs);
    let (tok_ref, _, (gf, sf, _)) = run_path(StatePath::Reference, policy, &reqs);
    assert_eq!(tok_res, tok_ref, "paths diverged in the interference scenario");
    let resident = gr + sr;
    let reference = gf + sf;
    assert!(
        reference >= 10 * resident.max(1),
        "traffic ratio too small: reference {reference}B vs resident {resident}B"
    );
}

#[test]
fn arena_slot_reuse_through_scheduler_lifecycle() {
    // Serve two waves through one scheduler: the second wave must reuse
    // the freed arena rows (free-list), never growing the slab.
    let mut s = Scheduler::new(MockEngine::new(), BatchPolicy::default());
    let m = s.manifest();
    let mut gen = WorkloadGen::new(9, m.vocab, m.prefill_len, 2, 4);
    for _ in 0..4 {
        s.submit(gen.next_request()).unwrap();
    }
    s.run_until_drained().unwrap();
    let cap_after_wave1 = s.state_arena().capacity();
    let peak1 = s.state_arena().peak();
    assert!(s.state_arena().is_empty(), "wave 1 released every slot");

    for _ in 0..4 {
        s.submit(gen.next_request()).unwrap();
    }
    s.run_until_drained().unwrap();
    assert_eq!(
        s.state_arena().capacity(),
        cap_after_wave1,
        "second wave must reuse freed rows, not grow the arena"
    );
    assert!(s.state_arena().peak() >= peak1);
    assert!(s.state_arena().is_empty());
}

#[test]
fn release_then_admit_reuses_row_and_counters_stay_consistent() {
    let mut a = StateArena::new(2, 6, 8, 4);
    let r1 = a.admit(10);
    let r2 = a.admit(20);
    assert_ne!(r1, r2);
    assert!(a.release(10));
    let r3 = a.admit(30);
    assert_eq!(r3, r1, "freed row must be reused (LIFO free-list)");
    assert_eq!(a.len(), 2);
    assert_eq!(a.peak(), 2);
    // Pure admit/release cycles move no state bytes.
    assert_eq!(a.traffic().total(), 0);
    // An install counts; the counter drains exactly once.
    let conv = vec![1.0f32; 2 * 6];
    let ssm = vec![2.0f32; 2 * 8];
    a.install_from_batch(20, 1, 0, &conv, &ssm);
    let t = a.take_traffic();
    assert_eq!(t.bytes_scattered, a.bytes_per_seq() as u64);
    assert_eq!(a.traffic().total(), 0);
}
