//! Inter-Einsum fusion: the paper's core contribution (§III–§IV).
//!
//! * [`classify`] — pairwise RI/RSb/RSp/RD classification;
//! * [`merge`] — shared-input tensor merging (packed GEMMs);
//! * [`stitch`] — greedy stitching (Algorithm 1) under variant gates;
//! * [`group`] — fusion groups and plans;
//! * [`variant`] — the RI / RI+RSb / RI+RSb+RSp / Fully-Fused strategies;
//! * [`generational`] — iterative-rank partitioning analysis (§IV-E).

pub mod classify;
pub mod generational;
pub mod group;
pub mod merge;
pub mod stitch;
pub mod variant;

pub use classify::{classify_cascade, classify_pair, FusionClass, PairFusion};
pub use group::{FusionGroup, FusionPlan, JoinRecord};
pub use stitch::{stitch, unfused_plan};
pub use variant::FusionVariant;
