//! Pairwise fusion classification (paper §III-C).
//!
//! Given an upstream Einsum whose output feeds a downstream Einsum, the
//! fusion class is determined by the relation between their iteration
//! spaces:
//!
//! | relation                | class | canonical pattern            |
//! |-------------------------|-------|------------------------------|
//! | `IS_up ≡ IS_dwn`        | RI    | elementwise→elementwise/red. |
//! | `IS_up ⊃ IS_dwn`        | RSb   | reduction→elementwise        |
//! | `IS_up ⊂ IS_dwn`        | RSp   | elementwise→broadcast/GEMM   |
//! | `IS_up ⊥ IS_dwn`        | RD    | matmul→matmul                |
//!
//! We evaluate the relation *relative to the intermediate tensor*: the
//! upstream's private ranks are `IS_up \ ranks(T)` (what it reduces away
//! to produce T) and the downstream's are `IS_dwn \ ranks(T)` (what it
//! broadcasts T over). This is equivalent to the paper's set relation
//! whenever rank names don't collide across roles, and resolves the
//! collision case correctly — e.g. Mamba's `TTD→DT` (Einsums 13→14),
//! where `D` is Einsum 13's reduction rank *and* Einsum 14's output
//! rank: a back-to-back matmul, hence RD, even though the raw name sets
//! are equal.
//!
//! Every class guarantees a minimum intermediate-tensor footprint (ITF)
//! of one element under an upstream-output-stationary /
//! downstream-input-stationary dataflow; the stationary ranks are the
//! intersection of the two spaces restricted to the intermediate.

use std::fmt;

use crate::einsum::{EinsumSpec, IterSpace, SpaceRelation};

/// The four fusion classes of the paper's taxonomy.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum FusionClass {
    /// Rank-Isomorphic: identical iteration spaces.
    RI,
    /// Rank-Subsetted: upstream ⊃ downstream (reduction upstream).
    RSb,
    /// Rank-Supersetted: upstream ⊂ downstream (broadcast downstream).
    RSp,
    /// Rank-Disjointed: both sides have private ranks (reduction *and*
    /// broadcast on the intermediate).
    RD,
}

impl fmt::Display for FusionClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            FusionClass::RI => "RI",
            FusionClass::RSb => "RSb",
            FusionClass::RSp => "RSp",
            FusionClass::RD => "RD",
        };
        write!(f, "{s}")
    }
}

impl FusionClass {
    /// Map an iteration-space relation (upstream vs downstream) to the
    /// fusion class.
    pub fn from_relation(rel: SpaceRelation) -> FusionClass {
        match rel {
            SpaceRelation::Equal => FusionClass::RI,
            SpaceRelation::Superset => FusionClass::RSb,
            SpaceRelation::Subset => FusionClass::RSp,
            SpaceRelation::Disjoint => FusionClass::RD,
        }
    }
}

/// The result of classifying one producer→consumer pair.
#[derive(Debug, Clone)]
pub struct PairFusion {
    /// Upstream Einsum id.
    pub up: usize,
    /// Downstream Einsum id.
    pub down: usize,
    /// The shared (intermediate) tensor.
    pub intermediate: String,
    /// Fusion class.
    pub class: FusionClass,
    /// Ranks that must be stationary (outermost, shared) in the fused
    /// traversal: `IS_up ∩ IS_dwn`.
    pub stationary: IterSpace,
    /// Minimum intermediate-tensor footprint in *elements* under the
    /// class's dataflow (always 1 per the taxonomy; kept explicit so
    /// partitioned/tiled variants can report tile sizes).
    pub min_itf: u64,
}

/// Classify fusion for a producer→consumer pair.
///
/// Preconditions: `up.output` must be an input of `down` (the
/// *intermediate tensor* requirement at the Einsum level, §III-A).
/// Returns `None` if the pair shares no output→input tensor.
pub fn classify_pair(up: &EinsumSpec, down: &EinsumSpec) -> Option<PairFusion> {
    let shared = down.operand(&up.output.name)?;
    let t_ranks = IterSpace::new(shared.tensor.ranks.clone());
    let is_up = up.iteration_space();
    let is_dwn = down.iteration_space();
    // Private ranks relative to the intermediate (see module docs).
    let up_private = !is_up.difference(&t_ranks).is_empty();
    let down_private = !is_dwn.difference(&t_ranks).is_empty();
    let class = match (up_private, down_private) {
        (false, false) => FusionClass::RI,
        (true, false) => FusionClass::RSb,
        (false, true) => FusionClass::RSp,
        (true, true) => FusionClass::RD,
    };
    Some(PairFusion {
        up: up.id,
        down: down.id,
        intermediate: shared.tensor.name.clone(),
        class,
        stationary: is_up.intersect(&is_dwn).intersect(&t_ranks),
        min_itf: 1,
    })
}

/// Classify *all* producer→consumer pairs in a cascade, in cascade order.
pub fn classify_cascade(c: &crate::einsum::Cascade) -> Vec<PairFusion> {
    let mut out = Vec::new();
    for (ai, up) in c.einsums().iter().enumerate() {
        for down in &c.einsums()[ai + 1..] {
            if let Some(p) = classify_pair(up, down) {
                out.push(p);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cascade::examples;

    fn first_pair(c: &crate::einsum::Cascade) -> PairFusion {
        classify_pair(&c.einsums()[0], &c.einsums()[1]).unwrap()
    }

    #[test]
    fn figure4_is_ri() {
        let p = first_pair(&examples::fig4_ri(8, 16));
        assert_eq!(p.class, FusionClass::RI);
        assert_eq!(p.stationary.rank_names(), vec!["K", "M"]);
        assert_eq!(p.min_itf, 1);
    }

    #[test]
    fn figure5_is_rsb() {
        let p = first_pair(&examples::fig5_rsb(8, 16));
        assert_eq!(p.class, FusionClass::RSb);
        // MK-stationary mapping required: stationary ranks = {M}.
        assert_eq!(p.stationary.rank_names(), vec!["M"]);
    }

    #[test]
    fn figure6_is_rsp() {
        let p = first_pair(&examples::fig6_rsp(8, 4, 2));
        assert_eq!(p.class, FusionClass::RSp);
        assert_eq!(p.stationary.rank_names(), vec!["M"]);
    }

    #[test]
    fn figure7_is_rd() {
        let p = first_pair(&examples::fig7_rd(8, 4, 16, 2));
        assert_eq!(p.class, FusionClass::RD);
        // "the mapping must be MN or NM-stationary".
        assert_eq!(p.stationary.rank_names(), vec!["M", "N"]);
    }

    #[test]
    fn non_adjacent_pairs_are_found() {
        // In Figure 8, X (E3) also feeds E4 directly.
        let c = examples::fig8_five(4, 5, 6, 3, 2);
        let all = classify_cascade(&c);
        assert!(all.iter().any(|p| p.up == 3 && p.down == 4));
        // And no pair is invented where no tensor flows.
        assert!(!all.iter().any(|p| p.up == 1 && p.down == 5));
    }

    #[test]
    fn mamba_ssm_region_classes() {
        let c = crate::cascade::mamba1::build(&crate::cascade::ModelConfig::mamba_370m(), 64, 1);
        let all = classify_cascade(&c);
        let class_of = |up: usize, down: usize| {
            all.iter().find(|p| p.up == up && p.down == down).map(|p| p.class)
        };
        // 16 (AB{I,D,N}) → 19 (HH{I,D,N}): RI.
        assert_eq!(class_of(16, 19), Some(FusionClass::RI));
        // 20 (H{I,D,N}) → 21 (S: {I,D,N} with N reduced): RI (same space).
        assert_eq!(class_of(20, 21), Some(FusionClass::RI));
        // 21 (S: {I,D,N}) → 22 (SD: {I,D}): RSb — the paper's
        // SSM→post-processing handoff enabled by adding RSb.
        assert_eq!(class_of(21, 22), Some(FusionClass::RSb));
        // 15 (DL{I,D}) → 16 (AB{I,D,N}): RSp (broadcast over N).
        assert_eq!(class_of(15, 16), Some(FusionClass::RSp));
        // 10 (LEX{I,D}) → 11 (XB iterates {I,N,D}: output {I,N} plus
        // reduction D): RSp — LEX broadcast into the skinny GEMM.
        assert_eq!(class_of(10, 11), Some(FusionClass::RSp));
    }

    #[test]
    fn norm_region_classes() {
        let c = crate::cascade::mamba1::build(&crate::cascade::ModelConfig::mamba_370m(), 64, 1);
        let all = classify_cascade(&c);
        let class_of = |up: usize, down: usize| {
            all.iter().find(|p| p.up == up && p.down == down).map(|p| p.class)
        };
        // NUM (#3, {I,E}) → ISR (#4, {I}): RSb (reduction upstream).
        assert_eq!(class_of(3, 4), Some(FusionClass::RSb));
        // ISR (#4, {I}) → NEX (#5, {I,E}): RSp (broadcast downstream).
        assert_eq!(class_of(4, 5), Some(FusionClass::RSp));
        // GX (#6, {I,E}) → TX (#7, {I,E,D}): RSp — elementwise feeding a GEMM.
        assert_eq!(class_of(6, 7), Some(FusionClass::RSp));
    }
}
