//! Shared-input tensor merging (paper §IV).
//!
//! "This is a common optimization strategy often used to pack multiple
//! GEMM operations into a single, larger GEMM computation. We apply
//! shared-input merging on (A) NEX to produce both TX and RX
//! simultaneously (Einsums 7–8), (B) X to produce B, C, and TTΔ
//! (Einsums 11–13), and (C) Δ to produce Ā and B̄ (Einsums 16–17)."
//!
//! A merged unit is a set of Einsums that read the same input tensor and
//! execute as one packed operation; stitching then operates on units.

use std::collections::BTreeMap;

use crate::einsum::{Cascade, EinsumSpec, IterSpace};

/// A unit of stitching: one Einsum, or several shared-input-merged ones.
#[derive(Debug, Clone)]
pub struct Unit {
    /// Member Einsum ids (cascade order).
    pub members: Vec<usize>,
    /// Union of the members' iteration spaces (the packed op iterates
    /// the concatenated output columns).
    pub space: IterSpace,
}

impl Unit {
    pub fn single(e: &EinsumSpec) -> Self {
        Unit { members: vec![e.id], space: e.iteration_space() }
    }

    pub fn is_merged(&self) -> bool {
        self.members.len() > 1
    }

    /// Representative (first) member id, used for display.
    pub fn head(&self) -> usize {
        self.members[0]
    }
}

/// Find shared-input merge sets in a cascade: maximal runs of
/// *consecutive* Einsums that (a) share an input tensor produced inside
/// the cascade or given as workload input, and (b) are all GEMM-like
/// contractions of that tensor with per-Einsum weights (the "packed
/// GEMM" pattern), or all elementwise ops on it (the Ā/B̄ pattern —
/// Einsum 16 is `exp(Δ⊗A)` and 17 is `Δ⊗B`, elementwise in Δ).
///
/// Returns the merge sets in cascade order.
pub fn find_shared_input_merges(c: &Cascade) -> Vec<Vec<usize>> {
    let es = c.einsums();
    let mut out: Vec<Vec<usize>> = Vec::new();
    let mut idx = 0;
    while idx < es.len() {
        let e = &es[idx];
        // Candidate shared inputs: non-weight operands.
        let mut best: Option<(String, usize)> = None; // (tensor, run length)
        for op in &e.inputs {
            if op.tensor.class == crate::einsum::TensorClass::Weight {
                continue;
            }
            let name = &op.tensor.name;
            // Extend the run: consecutive Einsums consuming `name` with
            // the same broad kind (all GEMM-like or all non-GEMM).
            let mut len = 1;
            while idx + len < es.len() {
                let nxt = &es[idx + len];
                let consumes = nxt.operand(name).is_some();
                let same_kind = nxt.is_gemm_like() == e.is_gemm_like();
                // The packed op must not depend on an earlier member's
                // output (that would serialize it).
                let depends = es[idx..idx + len]
                    .iter()
                    .any(|m| nxt.operand(&m.output.name).is_some());
                if consumes && same_kind && !depends {
                    len += 1;
                } else {
                    break;
                }
            }
            if len > 1 && best.as_ref().map(|(_, l)| len > *l).unwrap_or(true) {
                best = Some((name.clone(), len));
            }
        }
        if let Some((_, len)) = best {
            out.push(es[idx..idx + len].iter().map(|m| m.id).collect());
            idx += len;
        } else {
            idx += 1;
        }
    }
    out.retain(|s| s.len() > 1);
    out
}

/// Partition a cascade into stitching units using the given merge sets.
/// Einsums not covered by a merge set become singleton units.
pub fn to_units(c: &Cascade, merges: &[Vec<usize>]) -> Vec<Unit> {
    let merged_of: BTreeMap<usize, usize> = merges
        .iter()
        .enumerate()
        .flat_map(|(mi, set)| set.iter().map(move |&id| (id, mi)))
        .collect();
    let mut units: Vec<Unit> = Vec::new();
    let mut done: Vec<bool> = vec![false; merges.len()];
    for e in c.einsums() {
        match merged_of.get(&e.id) {
            Some(&mi) => {
                if !done[mi] {
                    done[mi] = true;
                    let members = merges[mi].clone();
                    let mut space = IterSpace::empty();
                    for &id in &members {
                        space = space.union(&c.by_id(id).expect("merge member").iteration_space());
                    }
                    units.push(Unit { members, space });
                }
            }
            None => units.push(Unit::single(e)),
        }
    }
    units
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cascade::{mamba1, ModelConfig};

    #[test]
    fn mamba_merge_sets_match_paper() {
        let c = mamba1::build(&ModelConfig::mamba_370m(), 64, 1);
        let merges = find_shared_input_merges(&c);
        // Paper §IV: {TX,RX} = 7–8, {XB,XC,TTD} = 11–13, {AB,BB} = 16–17.
        assert!(merges.contains(&vec![7, 8]), "merges = {merges:?}");
        assert!(merges.contains(&vec![11, 12, 13]), "merges = {merges:?}");
        assert!(merges.contains(&vec![16, 17]), "merges = {merges:?}");
    }

    #[test]
    fn units_cover_all_einsums_once() {
        let c = mamba1::build(&ModelConfig::mamba_370m(), 64, 1);
        let merges = find_shared_input_merges(&c);
        let units = to_units(&c, &merges);
        let mut ids: Vec<usize> = units.iter().flat_map(|u| u.members.clone()).collect();
        ids.sort();
        assert_eq!(ids, (1..=24).collect::<Vec<_>>());
    }

    #[test]
    fn merged_unit_space_is_union() {
        let c = mamba1::build(&ModelConfig::mamba_370m(), 64, 1);
        let units = to_units(&c, &find_shared_input_merges(&c));
        let u78 = units.iter().find(|u| u.members == vec![7, 8]).unwrap();
        assert_eq!(u78.space.rank_names(), vec!["D", "E", "I"]);
    }

    #[test]
    fn dependent_consumers_do_not_merge() {
        // In the norm chain, SQ (#2) and NEX (#5) both consume X but are
        // separated by dependent Einsums, so no merge may bridge them.
        let c = mamba1::build(&ModelConfig::mamba_370m(), 64, 1);
        let merges = find_shared_input_merges(&c);
        for m in &merges {
            assert!(!(m.contains(&2) && m.contains(&5)), "bad merge {m:?}");
        }
    }
}
