//! Fusing with generational ranks (paper §IV-E).
//!
//! "If we are I-stationary on H, we must store a B×D×N partition
//! on-chip. However, if we are B-D-N-stationary, only a unit-sized
//! element of H stays on-chip with a guarantee that there will be no
//! spills to main memory. Partitioning along the iterative rank (I) can
//! aid in keeping larger tiles of the iterative rank on-chip."
//!
//! This module computes, for a cascade with a generational rank, the
//! on-chip footprint required by each stationarity choice and the
//! largest iterative-rank tile that fits a given buffer — the analysis
//! Mambalaya's fully-fused binding uses.

use crate::einsum::{Cascade, TensorClass};

/// The on-chip footprint consequences of a stationarity choice for the
/// recurrent state tensor(s).
#[derive(Debug, Clone, PartialEq)]
pub struct GenerationalAnalysis {
    /// The generational rank name (e.g. "I").
    pub rank: String,
    /// Its extent in this cascade instance.
    pub extent: u64,
    /// Bytes that must stay on-chip if the mapping is stationary on the
    /// generational rank (one full generation of every recurrent
    /// tensor): the "I-stationary" option.
    pub gen_stationary_bytes: u64,
    /// Bytes on-chip if stationary on all *other* ranks: unit element
    /// per recurrent tensor (the "B-D-N-stationary" option).
    pub elem_stationary_bytes: u64,
    /// Maximum lookback window any Einsum needs (1 for `H[i-1]`, J-1
    /// for the conv): generations that must remain live regardless.
    pub max_lookback: u64,
}

impl GenerationalAnalysis {
    /// Largest tile of the iterative rank whose recurrent state fits in
    /// `budget_bytes` of on-chip storage. Partitioning along I trades
    /// buffer space for dataflow freedom (§IV-E).
    pub fn max_i_tile(&self, budget_bytes: u64) -> u64 {
        if self.gen_stationary_bytes == 0 {
            return self.extent;
        }
        let per_gen = self.gen_stationary_bytes;
        (budget_bytes / per_gen).clamp(self.max_lookback.max(1), self.extent.max(1))
    }
}

/// Analyze the generational structure of a cascade. Returns `None` when
/// the cascade has no generational rank in use.
pub fn analyze(c: &Cascade) -> Option<GenerationalAnalysis> {
    let mut rank: Option<(String, u64)> = None;
    let mut gen_bytes = 0u64;
    let mut elem_bytes = 0u64;
    let mut max_lookback = 0u64;

    for e in c.einsums() {
        for op in &e.inputs {
            for (r, acc) in op.tensor.ranks.iter().zip(&op.accesses) {
                if acc.is_recurrent() && r.is_generational() {
                    rank = Some((r.name.clone(), r.extent));
                    max_lookback = max_lookback.max(acc.lookback());
                }
            }
        }
    }
    let (rname, extent) = rank?;

    // Recurrent tensors: class Recurrent, or any tensor read with a
    // recurrent access (the conv window on TX).
    let mut counted: Vec<&str> = Vec::new();
    for e in c.einsums() {
        for op in &e.inputs {
            let rec_here = op
                .tensor
                .ranks
                .iter()
                .zip(&op.accesses)
                .any(|(r, a)| r.name == rname && a.is_recurrent());
            let is_state = op.tensor.class == TensorClass::Recurrent || rec_here;
            if is_state && !counted.contains(&op.tensor.name.as_str()) {
                counted.push(op.tensor.name.as_str());
                let per_gen = op.tensor.generation_bytes(&rname);
                let window = op
                    .tensor
                    .ranks
                    .iter()
                    .zip(&op.accesses)
                    .find(|(r, _)| r.name == rname)
                    .map(|(_, a)| a.lookback() + 1)
                    .unwrap_or(1);
                gen_bytes += per_gen * window;
                elem_bytes += op.tensor.dtype.bytes() * window;
            }
        }
    }

    Some(GenerationalAnalysis {
        rank: rname,
        extent,
        gen_stationary_bytes: gen_bytes,
        elem_stationary_bytes: elem_bytes,
        max_lookback,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cascade::{mamba1, ModelConfig};

    #[test]
    fn mamba_generational_analysis() {
        let cfg = ModelConfig::mamba_370m();
        let c = mamba1::build(&cfg, 1024, 1);
        let ga = analyze(&c).expect("mamba has a generational rank");
        assert_eq!(ga.rank, "I");
        assert_eq!(ga.extent, 1024);
        // H is D×N per generation (f16), window 2 (i and i-1);
        // TX window is J=4 generations of D.
        let h_bytes = 2 * cfg.d_inner * cfg.d_state * 2;
        let tx_bytes = 4 * cfg.d_inner * 2;
        assert_eq!(ga.gen_stationary_bytes, h_bytes + tx_bytes);
        assert_eq!(ga.max_lookback, 3); // conv window 4 → lookback 3
    }

    #[test]
    fn i_tile_scales_with_budget() {
        let cfg = ModelConfig::mamba_370m();
        let c = mamba1::build(&cfg, 1 << 20, 1);
        let ga = analyze(&c).unwrap();
        let small = ga.max_i_tile(1 << 20); // 1 MiB
        let large = ga.max_i_tile(32 << 20); // 32 MiB
        assert!(large >= small);
        assert!(small >= ga.max_lookback);
        assert!(large <= 1 << 20);
    }

    #[test]
    fn non_generational_cascade_returns_none() {
        let c = crate::cascade::examples::fig4_ri(8, 8);
        assert!(analyze(&c).is_none());
    }

    #[test]
    fn unit_elem_footprint_is_tiny() {
        let c = mamba1::build(&ModelConfig::mamba_370m(), 1024, 1);
        let ga = analyze(&c).unwrap();
        // B-D-N-stationary keeps only a few elements live.
        assert!(ga.elem_stationary_bytes < 64);
    }
}
