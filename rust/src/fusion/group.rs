//! Fusion groups: the output of stitching.
//!
//! A fusion group is a set of Einsums whose intermediate tensors stay
//! on-chip (paper §III-D). Each group records which tensors cross its
//! boundary (must touch the backing store) and which stay internal, plus
//! the stationarity constraint the group imposes on the mapper.
//!
//! [`FusionPlan::validate`] checks partition shape; the deeper legality
//! properties — group convexity over the dataflow DAG, acyclicity of
//! the condensed inter-group graph, join provenance, internal-tensor
//! honesty — are proven per plan by [`crate::verify::legality`] and
//! gated in CI via `mambalaya verify`.

use std::collections::BTreeSet;
use std::fmt;

use crate::einsum::{Cascade, IterSpace};

use super::classify::FusionClass;

/// How an Einsum joined its group (provenance for reports/debugging).
#[derive(Debug, Clone)]
pub struct JoinRecord {
    /// The joining Einsum.
    pub einsum: usize,
    /// The in-group producer it fused with (None for the group seed).
    pub via: Option<usize>,
    /// The fusion class of that link (None for the seed).
    pub class: Option<FusionClass>,
    /// The intermediate tensor carried by the link.
    pub tensor: Option<String>,
}

/// A fusion group.
#[derive(Debug, Clone)]
pub struct FusionGroup {
    /// Einsum ids, in cascade order.
    pub einsums: Vec<usize>,
    /// How each member joined.
    pub joins: Vec<JoinRecord>,
    /// Ranks that must sit at stationary (outer) loop levels for the
    /// whole group: the running pairwise intersection of Algorithm 1.
    pub stationary: IterSpace,
    /// Intermediates produced *and fully consumed* inside the group —
    /// these never touch the backing store.
    pub internal_tensors: Vec<String>,
    /// True when an RD link inside this group forces partial-product
    /// spills (the Fully-Fused strategy, paper §IV-D).
    pub rd_bridged: bool,
}

impl FusionGroup {
    pub fn contains(&self, id: usize) -> bool {
        self.einsums.contains(&id)
    }

    pub fn len(&self) -> usize {
        self.einsums.len()
    }

    pub fn is_empty(&self) -> bool {
        self.einsums.is_empty()
    }

    /// Fusion classes used by this group's internal links.
    pub fn classes_used(&self) -> BTreeSet<FusionClass> {
        self.joins.iter().filter_map(|j| j.class).collect()
    }
}

/// A complete fusion plan for a cascade.
#[derive(Debug, Clone)]
pub struct FusionPlan {
    pub cascade_name: String,
    pub variant_name: String,
    pub groups: Vec<FusionGroup>,
}

impl FusionPlan {
    /// Group index containing the given Einsum.
    pub fn group_of(&self, id: usize) -> Option<usize> {
        self.groups.iter().position(|g| g.contains(id))
    }

    /// Are two Einsums co-located in one group?
    pub fn fused_together(&self, a: usize, b: usize) -> bool {
        match (self.group_of(a), self.group_of(b)) {
            (Some(x), Some(y)) => x == y,
            _ => false,
        }
    }

    /// Tensor names that stay on-chip under this plan (internal to some
    /// group).
    pub fn internal_tensors(&self) -> BTreeSet<&str> {
        self.groups
            .iter()
            .flat_map(|g| g.internal_tensors.iter().map(|s| s.as_str()))
            .collect()
    }

    /// Validate the plan against its cascade: every Einsum in exactly
    /// one group, groups in cascade order, internal tensors really are
    /// internal (no consumer outside the group).
    pub fn validate(&self, c: &Cascade) -> anyhow::Result<()> {
        let mut seen = BTreeSet::new();
        let mut last = 0usize;
        for g in &self.groups {
            for &id in &g.einsums {
                if !seen.insert(id) {
                    anyhow::bail!("einsum #{id} appears in two groups");
                }
                if id < last {
                    anyhow::bail!("groups out of cascade order at #{id}");
                }
                last = id;
            }
        }
        for e in c.einsums() {
            if !seen.contains(&e.id) {
                anyhow::bail!("einsum #{} not covered by any group", e.id);
            }
        }
        let consumers = c.consumers();
        for g in &self.groups {
            for t in &g.internal_tensors {
                if let Some(cs) = consumers.get(t.as_str()) {
                    for &cid in cs {
                        if !g.contains(cid) {
                            anyhow::bail!(
                                "tensor {t} marked internal to a group but consumed by #{cid} outside it"
                            );
                        }
                    }
                }
            }
        }
        Ok(())
    }
}

/// Canonical plan rendering (used by the `fusion_golden` snapshot
/// test): deterministic line-per-group, so any change to stitching,
/// class assignment or internal-tensor analysis shows up as a diff.
impl fmt::Display for FusionPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "plan {} / {}: {} groups",
            self.cascade_name,
            self.variant_name,
            self.groups.len()
        )?;
        for (i, g) in self.groups.iter().enumerate() {
            let ids: Vec<String> = g.einsums.iter().map(|x| x.to_string()).collect();
            let classes: Vec<String> =
                g.classes_used().iter().map(|c| c.to_string()).collect();
            writeln!(
                f,
                "  group {i}: [{}] stationary {} classes {{{}}} internal [{}]{}",
                ids.join(","),
                g.stationary,
                classes.join(","),
                g.internal_tensors.join(","),
                if g.rd_bridged { " (RD-bridged)" } else { "" }
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_display_is_deterministic_and_complete() {
        let plan = FusionPlan {
            cascade_name: "c".into(),
            variant_name: "v".into(),
            groups: vec![FusionGroup {
                einsums: vec![1, 2],
                joins: vec![],
                stationary: IterSpace::empty(),
                internal_tensors: vec!["Z".into()],
                rd_bridged: true,
            }],
        };
        let a = plan.to_string();
        assert_eq!(a, plan.to_string());
        assert!(a.contains("plan c / v: 1 groups"));
        assert!(a.contains("[1,2]"));
        assert!(a.contains("internal [Z]"));
        assert!(a.contains("(RD-bridged)"));
    }

    #[test]
    fn plan_queries() {
        let plan = FusionPlan {
            cascade_name: "x".into(),
            variant_name: "test".into(),
            groups: vec![
                FusionGroup {
                    einsums: vec![1, 2],
                    joins: vec![],
                    stationary: IterSpace::empty(),
                    internal_tensors: vec!["Z".into()],
                    rd_bridged: false,
                },
                FusionGroup {
                    einsums: vec![3],
                    joins: vec![],
                    stationary: IterSpace::empty(),
                    internal_tensors: vec![],
                    rd_bridged: false,
                },
            ],
        };
        assert_eq!(plan.group_of(2), Some(0));
        assert!(plan.fused_together(1, 2));
        assert!(!plan.fused_together(2, 3));
        assert!(plan.internal_tensors().contains("Z"));
    }
}
