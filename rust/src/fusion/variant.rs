//! The paper's fusion-strategy variants (§IV-A..D, Figures 9/10/12).

use std::fmt;

use super::classify::FusionClass;

/// A fusion strategy: which classes the stitcher may use for links.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FusionVariant {
    /// Every Einsum its own group (the Best-Unfused baseline).
    Unfused,
    /// §IV-A: rank-isomorphic links only (24 → 12 groups for Mamba-1).
    RIOnly,
    /// §IV-B: RI + rank-subsetted (→ 8 groups).
    RIRSb,
    /// §IV-C: RI + RSb + rank-supersetted — the full greedy Algorithm 1
    /// (→ 3 groups).
    RIRSbRSp,
    /// §IV-D: additionally bridge RD boundaries with partial-product
    /// spill/trigger (→ 1 group, "fully fused").
    FullyFused,
}

impl FusionVariant {
    /// All variants in the paper's presentation order.
    pub fn all() -> [FusionVariant; 5] {
        [
            FusionVariant::Unfused,
            FusionVariant::RIOnly,
            FusionVariant::RIRSb,
            FusionVariant::RIRSbRSp,
            FusionVariant::FullyFused,
        ]
    }

    /// The fused variants (everything but the baseline).
    pub fn fused() -> [FusionVariant; 4] {
        [
            FusionVariant::RIOnly,
            FusionVariant::RIRSb,
            FusionVariant::RIRSbRSp,
            FusionVariant::FullyFused,
        ]
    }

    /// May the stitcher use `class` for an in-group link?
    pub fn allows(&self, class: FusionClass) -> bool {
        match self {
            FusionVariant::Unfused => false,
            FusionVariant::RIOnly => class == FusionClass::RI,
            FusionVariant::RIRSb => matches!(class, FusionClass::RI | FusionClass::RSb),
            FusionVariant::RIRSbRSp => {
                matches!(class, FusionClass::RI | FusionClass::RSb | FusionClass::RSp)
            }
            FusionVariant::FullyFused => true,
        }
    }

    /// Does this variant bridge RD boundaries by spilling partial
    /// products (rather than keeping intermediates strictly on-chip)?
    pub fn bridges_rd(&self) -> bool {
        matches!(self, FusionVariant::FullyFused)
    }

    /// CLI/report name.
    pub fn name(&self) -> &'static str {
        match self {
            FusionVariant::Unfused => "unfused",
            FusionVariant::RIOnly => "ri",
            FusionVariant::RIRSb => "ri+rsb",
            FusionVariant::RIRSbRSp => "ri+rsb+rsp",
            FusionVariant::FullyFused => "fully-fused",
        }
    }

    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<FusionVariant> {
        match s.to_ascii_lowercase().as_str() {
            "unfused" | "none" => Some(FusionVariant::Unfused),
            "ri" | "ri-only" => Some(FusionVariant::RIOnly),
            "ri+rsb" | "rsb" => Some(FusionVariant::RIRSb),
            "ri+rsb+rsp" | "rsp" => Some(FusionVariant::RIRSbRSp),
            "fully-fused" | "full" | "fused" => Some(FusionVariant::FullyFused),
            _ => None,
        }
    }
}

impl fmt::Display for FusionVariant {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allowance_lattice() {
        use FusionClass::*;
        assert!(FusionVariant::RIOnly.allows(RI));
        assert!(!FusionVariant::RIOnly.allows(RSb));
        assert!(FusionVariant::RIRSb.allows(RSb));
        assert!(!FusionVariant::RIRSb.allows(RSp));
        assert!(FusionVariant::RIRSbRSp.allows(RSp));
        assert!(!FusionVariant::RIRSbRSp.allows(RD));
        assert!(FusionVariant::FullyFused.allows(RD));
        for c in [RI, RSb, RSp, RD] {
            assert!(!FusionVariant::Unfused.allows(c));
        }
    }

    #[test]
    fn parse_roundtrip() {
        for v in FusionVariant::all() {
            assert_eq!(FusionVariant::parse(v.name()), Some(v));
        }
        assert_eq!(FusionVariant::parse("bogus"), None);
    }
}
