//! Greedy stitching (paper Algorithm 1 + the §IV variant gates).
//!
//! Stitching walks the cascade in order (after shared-input merging,
//! §IV) and greedily grows a fusion group. A unit joins the current
//! group iff:
//!
//! 1. **Einsum level** — it consumes at least one intermediate tensor
//!    produced *inside* the group (fusion requires an output→input
//!    tensor, §III-A). Operands produced outside the group are charged
//!    as backing-store traffic instead (e.g. LEX's second pass, RX).
//! 2. **Class gate** — every in-group link's fusion class is allowed by
//!    the active [`FusionVariant`] (§IV-A..D).
//! 3. **Algorithm-1 chain** — the pairwise intersection of consecutive
//!    units' iteration spaces must be equal to, a subset of, or a
//!    superset of the previous pairwise intersection (lines 10–12):
//!    ranks surviving intersection must appear at stationary loop
//!    levels, so the chain must nest.
//!
//! Recurrent (generational) self-links such as `H[i-1]` are not
//! stitching edges: they are handled by partitioning along the iterative
//! rank (§IV-E, [`super::generational`]).
//!
//! The Fully-Fused variant bridges RD boundaries instead of breaking:
//! partial products of the upstream intermediate spill to main memory
//! and the downstream Einsum triggers on each *final* write (§IV-D), so
//! the chain condition is waived across the bridge.

use crate::einsum::{Cascade, IterSpace};

use super::classify::{classify_pair, FusionClass};
use super::group::{FusionGroup, FusionPlan, JoinRecord};
use super::merge::{find_shared_input_merges, to_units, Unit};
use super::variant::FusionVariant;

/// Stitch a cascade under a fusion variant. Shared-input merging is
/// applied first (for any fused variant), per §IV.
pub fn stitch(c: &Cascade, variant: FusionVariant) -> FusionPlan {
    if variant == FusionVariant::Unfused {
        return unfused_plan(c);
    }
    let merges = find_shared_input_merges(c);
    let units = to_units(c, &merges);
    stitch_units(c, &units, variant)
}

/// The Best-Unfused baseline: every Einsum its own group.
pub fn unfused_plan(c: &Cascade) -> FusionPlan {
    let groups = c
        .einsums()
        .iter()
        .map(|e| FusionGroup {
            einsums: vec![e.id],
            joins: vec![JoinRecord { einsum: e.id, via: None, class: None, tensor: None }],
            stationary: e.iteration_space(),
            internal_tensors: vec![],
            rd_bridged: false,
        })
        .collect();
    FusionPlan {
        cascade_name: c.name.clone(),
        variant_name: FusionVariant::Unfused.name().to_string(),
        groups,
    }
}

/// One candidate link from an in-group producer to a joining Einsum.
#[derive(Debug, Clone)]
struct Link {
    via: usize,
    class: FusionClass,
    tensor: String,
}

/// Find all in-group links for a unit: for each member, classify it
/// against every in-group producer of one of its operands.
///
/// True back-edges (producer later in the cascade, i.e. the `H[i-1]`
/// generational self-loop) are not links; *forward* windowed accesses
/// (the conv reading `TX[i-j]`, producer #7 → consumer #9) are.
fn in_group_links(c: &Cascade, group: &[usize], unit: &Unit) -> Vec<(usize, Link)> {
    let mut links = Vec::new();
    for &mid in &unit.members {
        let m = c.by_id(mid).expect("unit member");
        for op in &m.inputs {
            if let Some(p) = c.by_name(&op.tensor.name) {
                if p.id < mid && group.contains(&p.id) {
                    if let Some(pf) = classify_pair(p, m) {
                        links.push((
                            mid,
                            Link { via: p.id, class: pf.class, tensor: pf.intermediate },
                        ));
                    }
                }
            }
        }
    }
    links
}

/// Algorithm-1 chain condition, per variant:
/// * RI-only: `I_curr == I_prev` (line 12 only, §IV-A);
/// * RI+RSb: `I_curr ⊆ I_prev` (lines 10 + 12, §IV-B);
/// * full greedy / fully-fused: subset, superset, or equal (lines
///   10–12, §III-D).
fn chain_ok(variant: FusionVariant, prev: &IterSpace, curr: &IterSpace) -> bool {
    match variant {
        FusionVariant::Unfused => false,
        FusionVariant::RIOnly => prev == curr,
        FusionVariant::RIRSb => curr.is_subset_of(prev),
        FusionVariant::RIRSbRSp | FusionVariant::FullyFused => {
            curr.is_subset_of(prev) || curr.is_superset_of(prev) || prev == curr
        }
    }
}

/// Is this variant's *seed pair* unconditional? Algorithm 1 line 2
/// fuses the first two Einsums of a group outright ("given two Einsums,
/// fusion is always possible", §III-D.1); the RI-only and RI+RSb modes
/// restrict every link, including the seed pair (§IV-A/B).
fn seed_unconditional(variant: FusionVariant) -> bool {
    matches!(variant, FusionVariant::RIRSbRSp | FusionVariant::FullyFused)
}

fn stitch_units(c: &Cascade, units: &[Unit], variant: FusionVariant) -> FusionPlan {
    let mut groups: Vec<FusionGroup> = Vec::new();

    // Group under construction.
    let mut g_einsums: Vec<usize> = Vec::new();
    let mut g_joins: Vec<JoinRecord> = Vec::new();
    let mut g_units: usize = 0;
    let mut g_station: IterSpace = IterSpace::empty();
    let mut g_rd = false;
    // Algorithm-1 chain state: the previous pairwise intersection.
    let mut i_prev: Option<IterSpace> = None;
    let mut last_space: Option<IterSpace> = None;

    let mut flush =
        |einsums: &mut Vec<usize>, joins: &mut Vec<JoinRecord>, station: &mut IterSpace, rd: &mut bool| {
            if !einsums.is_empty() {
                groups.push(FusionGroup {
                    einsums: std::mem::take(einsums),
                    joins: std::mem::take(joins),
                    stationary: std::mem::replace(station, IterSpace::empty()),
                    internal_tensors: vec![],
                    rd_bridged: std::mem::replace(rd, false),
                });
            }
        };

    for unit in units {
        let links = in_group_links(c, &g_einsums, unit);
        let mut bridged = false;
        let joinable = if g_einsums.is_empty() || links.is_empty() {
            // Fusion requires an intermediate tensor flowing from the
            // group into this unit (§III-A).
            false
        } else {
            let is_seed_pair = g_units == 1;
            let classes_ok = links.iter().all(|(_, l)| variant.allows(l.class));
            let chain = match (&i_prev, &last_space) {
                (Some(prev), Some(last)) => {
                    chain_ok(variant, prev, &last.intersect(&unit.space))
                }
                _ => true,
            };
            if is_seed_pair && seed_unconditional(variant) {
                true
            } else if variant.bridges_rd() {
                // Fully-fused: always joinable; a link that violates the
                // class/chain gates becomes an RD-style bridge (partial
                // products spill, downstream triggers on final writes).
                bridged = !(classes_ok && chain)
                    || links.iter().any(|(_, l)| l.class == FusionClass::RD);
                true
            } else {
                classes_ok && chain
            }
        };

        if joinable {
            if bridged || links.iter().any(|(_, l)| l.class == FusionClass::RD) {
                g_rd = true;
            }
            for &mid in &unit.members {
                g_einsums.push(mid);
                let best = links.iter().find(|(m, _)| *m == mid);
                g_joins.push(JoinRecord {
                    einsum: mid,
                    via: best.map(|(_, l)| l.via),
                    class: best.map(|(_, l)| l.class),
                    tensor: best.map(|(_, l)| l.tensor.clone()),
                });
            }
            if let Some(last) = &last_space {
                i_prev = Some(last.intersect(&unit.space));
            }
            g_station = g_station.intersect(&unit.space);
            g_units += 1;
        } else {
            flush(&mut g_einsums, &mut g_joins, &mut g_station, &mut g_rd);
            for &mid in &unit.members {
                g_einsums.push(mid);
                g_joins.push(JoinRecord { einsum: mid, via: None, class: None, tensor: None });
            }
            g_station = unit.space.clone();
            g_units = 1;
            i_prev = None;
        }
        last_space = Some(unit.space.clone());
    }
    flush(&mut g_einsums, &mut g_joins, &mut g_station, &mut g_rd);

    let mut plan = FusionPlan {
        cascade_name: c.name.clone(),
        variant_name: variant.name().to_string(),
        groups,
    };
    fill_internal_tensors(c, &mut plan);
    plan
}

/// Mark tensors internal to each group: produced by a member, with at
/// least one consumer, and *all* consumers inside the group.
fn fill_internal_tensors(c: &Cascade, plan: &mut FusionPlan) {
    let consumers = c.consumers();
    for g in &mut plan.groups {
        let mut internal = Vec::new();
        for &id in &g.einsums {
            let e = c.by_id(id).expect("group member");
            if let Some(cs) = consumers.get(e.output.name.as_str()) {
                if !cs.is_empty() && cs.iter().all(|cid| g.einsums.contains(cid)) {
                    internal.push(e.output.name.clone());
                }
            }
        }
        g.internal_tensors = internal;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cascade::{examples, mamba1, transformer, ModelConfig};

    fn mamba_groups(variant: FusionVariant) -> Vec<Vec<usize>> {
        let c = mamba1::build(&ModelConfig::mamba_370m(), 64, 1);
        let plan = stitch(&c, variant);
        plan.validate(&c).expect("plan must validate");
        plan.groups.iter().map(|g| g.einsums.clone()).collect()
    }

    #[test]
    fn unfused_is_24_groups() {
        assert_eq!(mamba_groups(FusionVariant::Unfused).len(), 24);
    }

    #[test]
    fn ri_only_is_12_groups() {
        // Paper §IV-A: "we reduce the number of fusion groups from 24
        // ... to 12", with the SSM region (16–21) one group.
        let gs = mamba_groups(FusionVariant::RIOnly);
        assert_eq!(gs.len(), 12, "groups = {gs:?}");
        assert!(gs.contains(&vec![16, 17, 18, 19, 20, 21]), "groups = {gs:?}");
    }

    #[test]
    fn ri_rsb_is_8_groups() {
        // Paper §IV-B: "The total number of fusion groups is now eight",
        // and the SSM passes its output S directly to 22–23.
        let gs = mamba_groups(FusionVariant::RIRSb);
        assert_eq!(gs.len(), 8, "groups = {gs:?}");
        assert!(gs.contains(&vec![16, 17, 18, 19, 20, 21, 22, 23]), "groups = {gs:?}");
        // "GEMM followed by an elementwise" (14–15) fuse.
        assert!(gs.contains(&vec![14, 15]), "groups = {gs:?}");
    }

    #[test]
    fn ri_rsb_rsp_is_3_groups() {
        // Paper §IV-C: "Adding RSp reduces the number of fusion groups
        // to three."
        let gs = mamba_groups(FusionVariant::RIRSbRSp);
        assert_eq!(gs.len(), 3, "groups = {gs:?}");
        assert_eq!(gs[0], (1..=8).collect::<Vec<_>>());
        assert_eq!(gs[1], (9..=13).collect::<Vec<_>>());
        assert_eq!(gs[2], (14..=24).collect::<Vec<_>>());
    }

    #[test]
    fn fully_fused_is_1_group() {
        // Paper §IV-D: one fusion group across the entire cascade, with
        // RD bridges between the three RSp-groups.
        let c = mamba1::build(&ModelConfig::mamba_370m(), 64, 1);
        let plan = stitch(&c, FusionVariant::FullyFused);
        plan.validate(&c).unwrap();
        assert_eq!(plan.groups.len(), 1, "groups = {:?}", plan.groups);
        assert!(plan.groups[0].rd_bridged);
    }

    #[test]
    fn rd_bridges_are_at_conv_and_dtproj() {
        // §IV-D: RD opportunities between RSp-groups 1↔2 and 2↔3 —
        // i.e. at TX→TTX (7→9) and TTD→DT (13→14).
        let c = mamba1::build(&ModelConfig::mamba_370m(), 64, 1);
        let plan = stitch(&c, FusionVariant::FullyFused);
        let joins = &plan.groups[0].joins;
        let rd_edges: Vec<(usize, usize)> = joins
            .iter()
            .filter(|j| j.class == Some(FusionClass::RD))
            .map(|j| (j.via.unwrap(), j.einsum))
            .collect();
        assert!(rd_edges.contains(&(7, 9)), "rd edges = {rd_edges:?}");
        assert!(rd_edges.contains(&(13, 14)), "rd edges = {rd_edges:?}");
    }

    #[test]
    fn figure8_two_groups() {
        // Paper Figure 8: greedy (full Algorithm 1) over the 5-Einsum
        // cascade yields groups {E1,E2,E3} and {E4,E5}.
        let c = examples::fig8_five(4, 5, 6, 3, 2);
        let plan = stitch(&c, FusionVariant::RIRSbRSp);
        plan.validate(&c).unwrap();
        let gs: Vec<Vec<usize>> = plan.groups.iter().map(|g| g.einsums.clone()).collect();
        assert_eq!(gs, vec![vec![1, 2, 3], vec![4, 5]]);
        // Group stationarity: N is shared across all Einsums.
        assert!(plan.groups[0].stationary.contains("N"));
        assert!(plan.groups[1].stationary.contains("N"));
    }

    #[test]
    fn pair_examples_fuse_only_when_variant_allows() {
        // A lone RD pair: under RI-only/RI+RSb the class gate applies to
        // the seed pair and splits it; under full Algorithm 1 the seed
        // pair is unconditional ("given two Einsums, fusion is always
        // possible", §III-D.1 — exactly how Figure 8 fuses E1–E2).
        let rd = examples::fig7_rd(8, 4, 16, 2);
        assert_eq!(stitch(&rd, FusionVariant::RIOnly).groups.len(), 2);
        assert_eq!(stitch(&rd, FusionVariant::RIRSb).groups.len(), 2);
        assert_eq!(stitch(&rd, FusionVariant::RIRSbRSp).groups.len(), 1);
        assert_eq!(stitch(&rd, FusionVariant::FullyFused).groups.len(), 1);
        let rsb = examples::fig5_rsb(8, 16);
        assert_eq!(stitch(&rsb, FusionVariant::RIOnly).groups.len(), 2);
        assert_eq!(stitch(&rsb, FusionVariant::RIRSb).groups.len(), 1);
    }

    #[test]
    fn transformer_stitches() {
        // The Transformer's simpler cascade fuses heavily under full
        // greedy stitching (QK→softmax→AV chains are RSb/RSp).
        let c = transformer::build(&transformer::TransformerConfig::medium(256));
        let plan = stitch(&c, FusionVariant::RIRSbRSp);
        plan.validate(&c).unwrap();
        assert!(plan.groups.len() < c.len());
    }

    #[test]
    fn internal_tensors_exclude_multi_group_consumers() {
        let c = mamba1::build(&ModelConfig::mamba_370m(), 64, 1);
        let plan = stitch(&c, FusionVariant::RIRSbRSp);
        // LEX is produced in group 2 but consumed in group 3 (BX, SD) —
        // never internal. RX is produced in group 1 but consumed at #23.
        let internal = plan.internal_tensors();
        assert!(!internal.contains("LEX"));
        assert!(!internal.contains("RX"));
        // NEX/SQ/HH live and die inside their group.
        assert!(internal.contains("SQ"));
        assert!(internal.contains("HH"));
    }

    #[test]
    fn mamba2_group_counts_decrease_monotonically() {
        let c = crate::cascade::mamba2::build(&ModelConfig::mamba_370m(), 64, 1);
        let mut counts = Vec::new();
        for v in FusionVariant::all() {
            let plan = stitch(&c, v);
            plan.validate(&c).unwrap();
            counts.push(plan.groups.len());
        }
        for w in counts.windows(2) {
            assert!(w[1] <= w[0], "counts = {counts:?}");
        }
        assert_eq!(counts[0], c.len());
    }
}
