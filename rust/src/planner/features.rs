//! Per-tick workload features: the summary of one mixed batch the
//! planner selects a fusion plan from.
//!
//! The scheduler extracts a [`WorkloadFeatures`] from every
//! `Action::Mixed` before the engine call: how many rows advance one
//! token (decode rows plus single-token chunks — indistinguishable at
//! the engine, which only sees `lens`), how many prompt tokens ride in
//! multi-token prefill chunks (with a chunk-length histogram), how much
//! recurrent state is resident, and how much of the per-tick token
//! budget the batch uses. Selection itself happens on the
//! [`WorkloadFeatures::bucket`] projection — power-of-two shape buckets,
//! mirroring how the runtime compiles one executable per padded batch
//! size — so the cost model is evaluated once per bucket, not per tick,
//! and the steady-state tick stays allocation-free.

use crate::runtime::MixedBatch;

/// Chunk-length histogram buckets: `1..=2`, `3..=8`, `9..=32`, `33+`.
pub const CHUNK_HIST_BUCKETS: usize = 4;

/// Summary of one scheduler tick's mixed batch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkloadFeatures {
    /// Rows advancing exactly one token this tick (decode rows plus
    /// single-token prefill chunks — the engine-visible decode set).
    pub decode_rows: usize,
    /// Multi-token prefill chunk rows.
    pub prefill_chunks: usize,
    /// Prompt tokens carried by those multi-token chunks.
    pub prefill_tokens: usize,
    /// Longest chunk in the tick (0 when decode-only).
    pub max_chunk: usize,
    /// Chunk-length histogram over [`CHUNK_HIST_BUCKETS`] buckets.
    pub chunk_hist: [u32; CHUNK_HIST_BUCKETS],
    /// Bytes of recurrent state resident at decision time — the
    /// **server-wide** gauge under the sharded arena (this worker's
    /// shard plus the router-synced remote shards; see
    /// [`crate::coordinator::Scheduler::global_resident_bytes`]), so
    /// admission-aware policies see total residency, not one slice.
    pub resident_state_bytes: u64,
    /// Tick token cost over the policy's token budget (0.0..=1.0-ish).
    pub budget_utilization: f64,
}

impl WorkloadFeatures {
    fn empty(decode_rows: usize, resident_state_bytes: u64) -> WorkloadFeatures {
        WorkloadFeatures {
            decode_rows,
            prefill_chunks: 0,
            prefill_tokens: 0,
            max_chunk: 0,
            chunk_hist: [0; CHUNK_HIST_BUCKETS],
            resident_state_bytes,
            budget_utilization: 0.0,
        }
    }

    /// Account one multi-token prefill chunk.
    fn add_chunk(&mut self, len: usize) {
        self.prefill_chunks += 1;
        self.prefill_tokens += len;
        self.max_chunk = self.max_chunk.max(len);
        let b = match len {
            0..=2 => 0,
            3..=8 => 1,
            9..=32 => 2,
            _ => 3,
        };
        self.chunk_hist[b] += 1;
    }

    /// Build features from a tick's chunk lengths and decode-row count
    /// (the same classification the engine applies to `lens`:
    /// single-token chunks count as decode rows).
    pub fn from_tick(
        chunk_lens: &[usize],
        decode_rows: usize,
        resident_state_bytes: u64,
        token_budget: usize,
    ) -> WorkloadFeatures {
        let mut f = WorkloadFeatures::empty(decode_rows, resident_state_bytes);
        let mut tokens = decode_rows;
        for &len in chunk_lens {
            tokens += len;
            if len <= 1 {
                f.decode_rows += 1;
                continue;
            }
            f.add_chunk(len);
        }
        f.budget_utilization = tokens as f64 / token_budget.max(1) as f64;
        f
    }

    /// Build features straight from the validated [`MixedBatch`] the
    /// engine will launch — the scheduler's per-tick path, so planner
    /// and engine classify the batch from the *same* typed view:
    /// single-token segments are the decode set, multi-token segments
    /// the prefill chunks. Equivalent to [`WorkloadFeatures::from_tick`]
    /// on the batch's raw lengths (the planner property tests pin it).
    pub fn from_batch(
        batch: &MixedBatch<'_>,
        resident_state_bytes: u64,
        token_budget: usize,
    ) -> WorkloadFeatures {
        let mut f = WorkloadFeatures::empty(0, resident_state_bytes);
        for seg in batch.segments() {
            if seg.len == 1 {
                f.decode_rows += 1;
            } else {
                f.add_chunk(seg.len);
            }
        }
        f.budget_utilization = batch.total_tokens() as f64 / token_budget.max(1) as f64;
        f
    }

    /// The shape bucket selection happens on.
    pub fn bucket(&self) -> PlanBucket {
        PlanBucket::of(self.decode_rows, self.prefill_tokens)
    }
}

/// A power-of-two shape bucket: the representative (rounded-up) decode
/// row count and prefill token count the cost model is evaluated at.
/// Rounding *up* keeps the prediction a conservative bound: the model's
/// costs are monotone in both coordinates, so the representative never
/// under-predicts a point inside its bucket (and the near-linear cost
/// components keep it close to the bucket floor).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PlanBucket {
    /// Rounded-up decode rows (0 or a power of two).
    pub decode_rows: usize,
    /// Rounded-up prefill tokens (0 or a power of two).
    pub prefill_tokens: usize,
}

impl PlanBucket {
    pub fn of(decode_rows: usize, prefill_tokens: usize) -> PlanBucket {
        PlanBucket {
            decode_rows: pow2_ceil(decode_rows),
            prefill_tokens: pow2_ceil(prefill_tokens),
        }
    }
}

/// Smallest power of two ≥ `n` (0 stays 0).
pub fn pow2_ceil(n: usize) -> usize {
    if n == 0 {
        0
    } else {
        n.next_power_of_two()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classifies_unit_chunks_as_decode() {
        // 6 decode rows + chunks [1, 4, 16]: the unit chunk joins the
        // decode set, exactly as the engine's `lens` classification.
        let f = WorkloadFeatures::from_tick(&[1, 4, 16], 6, 1024, 32);
        assert_eq!(f.decode_rows, 7);
        assert_eq!(f.prefill_chunks, 2);
        assert_eq!(f.prefill_tokens, 20);
        assert_eq!(f.max_chunk, 16);
        assert_eq!(f.chunk_hist, [0, 1, 1, 0]);
        assert_eq!(f.resident_state_bytes, 1024);
        // (6 + 1 + 4 + 16) / 32
        assert!((f.budget_utilization - 27.0 / 32.0).abs() < 1e-12);
    }

    #[test]
    fn bucket_rounds_up_to_pow2() {
        assert_eq!(pow2_ceil(0), 0);
        assert_eq!(pow2_ceil(1), 1);
        assert_eq!(pow2_ceil(3), 4);
        assert_eq!(pow2_ceil(8), 8);
        let f = WorkloadFeatures::from_tick(&[5, 6], 6, 0, 32);
        assert_eq!(f.bucket(), PlanBucket { decode_rows: 8, prefill_tokens: 16 });
        let d = WorkloadFeatures::from_tick(&[], 8, 0, 32);
        assert_eq!(d.bucket(), PlanBucket { decode_rows: 8, prefill_tokens: 0 });
    }

    #[test]
    fn from_batch_matches_from_tick_classification() {
        use crate::runtime::{Phase, Segment};
        // Segments [3, 1, 16, 1, 1] — unit segments are the decode set
        // whatever their origin, exactly like the raw-lens view.
        let segs = [
            Segment { len: 3, row: 0, phase: Phase::PrefillFirst },
            Segment { len: 1, row: 1, phase: Phase::Decode },
            Segment { len: 16, row: 2, phase: Phase::PrefillCont },
            Segment { len: 1, row: 3, phase: Phase::Decode },
            Segment { len: 1, row: 4, phase: Phase::Decode },
        ];
        let tokens = vec![7i32; 22];
        let batch = MixedBatch::new(&segs, &tokens).unwrap();
        let via_batch = WorkloadFeatures::from_batch(&batch, 2048, 32);
        let via_lens = WorkloadFeatures::from_tick(&[3, 1, 16], 2, 2048, 32);
        assert_eq!(via_batch, via_lens);
        assert_eq!(via_batch.decode_rows, 3);
        assert_eq!(via_batch.prefill_tokens, 19);
        assert_eq!(via_batch.bucket(), via_lens.bucket());
    }

    #[test]
    fn decode_only_has_empty_histogram() {
        let f = WorkloadFeatures::from_tick(&[], 4, 0, 16);
        assert_eq!(f.prefill_chunks, 0);
        assert_eq!(f.prefill_tokens, 0);
        assert_eq!(f.max_chunk, 0);
        assert_eq!(f.chunk_hist, [0; CHUNK_HIST_BUCKETS]);
    }
}
