//! The selection policy: turn per-tick [`WorkloadFeatures`] into a
//! [`PlanChoice`], with shape-bucketed caching (the cost model runs
//! once per bucket, then every tick of that shape is a map lookup) and
//! dwell-tick hysteresis (a noisy mix oscillating between two buckets
//! must not flip the executed plan every tick — real engines pay
//! occupancy/recompilation costs on a switch even though the analytical
//! model does not).

use crate::runtime::EngineCaps;

use super::autotune::PlanTable;
use super::cost::{CostModel, TickEstimate};
use super::features::WorkloadFeatures;
use super::PlanChoice;

/// How the scheduler picks its per-tick plan. Parsed from
/// `--plan {static:<name>|adaptive|table:<path>}`.
#[derive(Debug, Clone)]
pub enum PlanSpec {
    /// One fixed plan for every tick.
    Static(PlanChoice),
    /// Per-bucket argmin of the analytical cost model, evaluated
    /// lazily and cached.
    Adaptive,
    /// Zero-cost fast path: look the plan up in an autotuned
    /// [`PlanTable`] loaded at server start.
    Table(PlanTable),
}

impl Default for PlanSpec {
    fn default() -> Self {
        PlanSpec::Adaptive
    }
}

impl PlanSpec {
    /// Parse a CLI spec: `adaptive`, `static:<plan-name>`,
    /// `table:<path>` (the path is loaded eagerly so a bad table fails
    /// at startup, not mid-serve).
    pub fn parse(s: &str) -> anyhow::Result<PlanSpec> {
        if s == "adaptive" {
            return Ok(PlanSpec::Adaptive);
        }
        if let Some(name) = s.strip_prefix("static:") {
            return PlanChoice::parse(name)
                .map(PlanSpec::Static)
                .ok_or_else(|| anyhow::anyhow!("unknown plan name {name:?}"));
        }
        if let Some(path) = s.strip_prefix("table:") {
            return Ok(PlanSpec::Table(PlanTable::load(path)?));
        }
        anyhow::bail!("bad plan spec {s:?} (want static:<name>|adaptive|table:<path>)")
    }

    /// Display name for reports.
    pub fn name(&self) -> String {
        match self {
            PlanSpec::Static(c) => format!("static:{}", c.name()),
            PlanSpec::Adaptive => "adaptive".to_string(),
            PlanSpec::Table(_) => "table".to_string(),
        }
    }
}

/// One tick's planning outcome, for the scheduler's metrics.
#[derive(Debug, Clone, Copy)]
pub struct PlanDecision {
    /// The plan the engine should execute this tick.
    pub choice: PlanChoice,
    /// True when the executed plan changed from the previous tick.
    pub switched: bool,
    /// When `switched`, how many ticks the previous plan dwelt.
    pub ended_dwell: Option<u64>,
    /// Predicted cost of the tick (the selection-time estimate).
    pub predicted: TickEstimate,
}

/// Default minimum dwell: a freshly adopted plan runs at least this
/// many ticks before the planner may switch again.
pub const DEFAULT_MIN_DWELL: u64 = 4;

/// The per-scheduler planner.
#[derive(Debug)]
pub struct Planner {
    spec: PlanSpec,
    cost: CostModel,
    /// Bucket → (argmin choice, its estimate). For `Static`, the
    /// estimate of the fixed choice per bucket (the prediction still
    /// tracks shape).
    cache: std::collections::BTreeMap<super::features::PlanBucket, (PlanChoice, TickEstimate)>,
    /// Adaptive selection mask, indexed by [`PlanChoice::index`]: a
    /// candidate the engine rejected at registration is never selected.
    allowed: [bool; PlanChoice::COUNT],
    current: Option<PlanChoice>,
    /// Ticks the current plan has been executing.
    dwell: u64,
    min_dwell: u64,
}

impl Planner {
    pub fn new(spec: PlanSpec) -> Planner {
        Planner::with_dwell(spec, DEFAULT_MIN_DWELL)
    }

    /// Construct with an explicit hysteresis dwell. `min_dwell = 1`
    /// disables hysteresis (the planner tracks the per-bucket argmin
    /// exactly — the configuration the counter gates compare against
    /// static plans, where pointwise-argmin ≤ any-static is exact).
    pub fn with_dwell(spec: PlanSpec, min_dwell: u64) -> Planner {
        Planner {
            spec,
            cost: CostModel::default_serving(),
            cache: std::collections::BTreeMap::new(),
            allowed: [true; PlanChoice::COUNT],
            current: None,
            dwell: 0,
            min_dwell: min_dwell.max(1),
        }
    }

    /// Exclude a candidate from adaptive selection (a plan the engine's
    /// capability report marks unavailable, so a startup-detectable
    /// misconfiguration never dispatches mid-serve). The last remaining
    /// candidate cannot be excluded — selection must always have
    /// something to pick.
    pub fn disallow(&mut self, choice: PlanChoice) {
        let remaining = self.allowed.iter().filter(|&&a| a).count();
        if remaining > 1 || !self.allowed[choice.index()] {
            self.allowed[choice.index()] = false;
            self.cache.clear();
        }
    }

    /// Seed the disallow set from an engine's capability report: every
    /// plan whose [`EngineCaps::plans`] bit is off is excluded from
    /// selection. The scheduler calls this once at construction —
    /// capability *negotiation* replaces the legacy `register_variant`
    /// trial-and-error (announce every candidate, treat `Err` as
    /// unavailable). As with [`Planner::disallow`], the last remaining
    /// candidate is irremovable: a degenerate report that masks out
    /// *every* plan leaves one selectable so the scheduler can still
    /// construct, but the contradiction is loudly reported here, at
    /// startup — not discovered as a mid-serve engine failure.
    pub fn apply_caps(&mut self, caps: &EngineCaps) {
        for choice in PlanChoice::candidates() {
            if !caps.plans[choice.index()] {
                eprintln!(
                    "planner: engine caps mark plan {} unavailable (excluded from selection)",
                    choice.name()
                );
                self.disallow(choice);
            }
        }
        // The irremovable-last-candidate rule can contradict a
        // degenerate all-masked report; surface it instead of silently
        // dispatching a plan the engine disclaimed.
        for choice in PlanChoice::candidates() {
            if !caps.plans[choice.index()] && self.is_allowed(choice) {
                eprintln!(
                    "planner: WARNING: engine caps disallow every candidate; keeping plan {} \
                     selectable so serving can proceed — the engine's capability report is \
                     inconsistent and should be fixed",
                    choice.name()
                );
            }
        }
    }

    /// Whether a candidate is currently selectable (tests/diagnostics).
    pub fn is_allowed(&self, choice: PlanChoice) -> bool {
        self.allowed[choice.index()]
    }

    pub fn spec(&self) -> &PlanSpec {
        &self.spec
    }

    /// The plan currently executing (None before the first tick).
    pub fn current(&self) -> Option<PlanChoice> {
        self.current
    }

    /// Mutable cost-model access (autotune, tests).
    pub fn cost_model(&mut self) -> &mut CostModel {
        &mut self.cost
    }

    /// Decide the plan for one tick. Steady-state (cache-hit, no
    /// switch) this is a map lookup — no allocation, no model
    /// evaluation.
    pub fn decide(&mut self, f: &WorkloadFeatures) -> PlanDecision {
        let bucket = f.bucket();
        let cached = self.cache.get(&bucket).copied();
        let (target, target_est) = match cached {
            Some(hit) => hit,
            None => {
                let hit = match &self.spec {
                    PlanSpec::Static(c) => {
                        let c = *c;
                        (c, self.cost.tick_cost(c, bucket))
                    }
                    PlanSpec::Adaptive => self
                        .cost
                        .best_allowed(bucket, &self.allowed)
                        .expect("disallow keeps at least one candidate"),
                    PlanSpec::Table(t) => {
                        let cell = t.lookup(bucket.decode_rows, bucket.prefill_tokens);
                        (cell.choice, TickEstimate { cycles: cell.cycles, bytes: cell.bytes })
                    }
                };
                self.cache.insert(bucket, hit);
                hit
            }
        };

        match self.current {
            None => {
                self.current = Some(target);
                self.dwell = 1;
                PlanDecision { choice: target, switched: false, ended_dwell: None, predicted: target_est }
            }
            Some(cur) if cur == target => {
                self.dwell += 1;
                PlanDecision { choice: cur, switched: false, ended_dwell: None, predicted: target_est }
            }
            Some(cur) => {
                if self.dwell < self.min_dwell {
                    // Hysteresis: keep the current plan until it has
                    // dwelt long enough. Predict what actually runs —
                    // except in table mode, which stays evaluation-free
                    // in the serving process: there the bucket's table
                    // estimate stands in for the few lag ticks.
                    let predicted = match &self.spec {
                        PlanSpec::Table(_) => target_est,
                        _ => self.cost.tick_cost(cur, bucket),
                    };
                    self.dwell += 1;
                    PlanDecision { choice: cur, switched: false, ended_dwell: None, predicted }
                } else {
                    let ended = self.dwell;
                    self.current = Some(target);
                    self.dwell = 1;
                    PlanDecision {
                        choice: target,
                        switched: true,
                        ended_dwell: Some(ended),
                        predicted: target_est,
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fusion::FusionVariant;

    fn decode_tick() -> WorkloadFeatures {
        WorkloadFeatures::from_tick(&[], 8, 0, 16)
    }

    fn prefill_tick() -> WorkloadFeatures {
        WorkloadFeatures::from_tick(&[4096], 0, 0, 4096)
    }

    #[test]
    fn parse_specs() {
        assert!(matches!(PlanSpec::parse("adaptive").unwrap(), PlanSpec::Adaptive));
        match PlanSpec::parse("static:ri").unwrap() {
            PlanSpec::Static(PlanChoice::Variant(FusionVariant::RIOnly)) => {}
            other => panic!("{other:?}"),
        }
        assert!(PlanSpec::parse("static:bogus").is_err());
        assert!(PlanSpec::parse("nonsense").is_err());
        assert!(PlanSpec::parse("table:/nonexistent/tbl.json").is_err());
    }

    #[test]
    fn static_never_switches() {
        let mut p = Planner::new(PlanSpec::Static(PlanChoice::Variant(FusionVariant::RIOnly)));
        for _ in 0..8 {
            let d = p.decide(&decode_tick());
            assert_eq!(d.choice, PlanChoice::Variant(FusionVariant::RIOnly));
            assert!(!d.switched);
            let d = p.decide(&prefill_tick());
            assert_eq!(d.choice, PlanChoice::Variant(FusionVariant::RIOnly));
            assert!(!d.switched);
        }
    }

    #[test]
    fn adaptive_switches_between_phases() {
        // Long phases: hysteresis expires, the plan follows the phase.
        let mut p = Planner::new(PlanSpec::Adaptive);
        let mut first = None;
        for _ in 0..8 {
            first = Some(p.decide(&prefill_tick()).choice);
        }
        let mut second = None;
        for _ in 0..8 {
            second = Some(p.decide(&decode_tick()).choice);
        }
        assert_eq!(first.unwrap(), PlanChoice::Variant(FusionVariant::FullyFused));
        assert_ne!(first.unwrap(), second.unwrap());
    }

    #[test]
    fn hysteresis_bounds_switches_on_alternating_mix() {
        // A workload alternating decode-only and prefill-only ticks
        // wants a different plan every tick; dwell-4 hysteresis caps
        // switching at once per 4 ticks, where a dwell-1 planner flips
        // (nearly) every tick.
        let run = |dwell: u64| {
            let mut p = Planner::with_dwell(PlanSpec::Adaptive, dwell);
            let mut switches = 0u64;
            for i in 0..64 {
                let f = if i % 2 == 0 { decode_tick() } else { prefill_tick() };
                if p.decide(&f).switched {
                    switches += 1;
                }
            }
            switches
        };
        let free = run(1);
        let damped = run(4);
        assert!(free >= 32, "alternating argmins must thrash without hysteresis: {free}");
        assert!(damped <= 64 / 4 + 1, "dwell-4 lets {damped} switches through");
        assert!(damped < free);
    }

    #[test]
    fn dwell_one_tracks_argmin_exactly() {
        let mut p = Planner::with_dwell(PlanSpec::Adaptive, 1);
        let mut m = CostModel::default_serving();
        for f in [decode_tick(), prefill_tick(), decode_tick()] {
            let d = p.decide(&f);
            let (want, want_est) = m.best(f.bucket());
            assert_eq!(d.choice, want);
            assert_eq!(d.predicted, want_est);
        }
    }

    #[test]
    fn disallow_excludes_candidate_from_adaptive_selection() {
        // Prefill-heavy normally picks fully-fused; with it rejected
        // (as an engine would at registration), the planner falls back
        // to the best remaining plan and never dispatches it.
        let mut p = Planner::with_dwell(PlanSpec::Adaptive, 1);
        let ff = PlanChoice::Variant(FusionVariant::FullyFused);
        assert_eq!(p.decide(&prefill_tick()).choice, ff);
        p.disallow(ff);
        let d = p.decide(&prefill_tick());
        assert_ne!(d.choice, ff);
        // The last remaining candidate cannot be excluded.
        for c in PlanChoice::candidates() {
            p.disallow(c);
        }
        let d = p.decide(&decode_tick());
        let _ = d.choice; // selection still yields a plan
    }

    #[test]
    fn apply_caps_masks_unavailable_plans() {
        // A capability report with fully-fused unavailable: the planner
        // never selects it, even where it would win (prefill-heavy).
        let mut caps = EngineCaps::full();
        let ff = PlanChoice::Variant(FusionVariant::FullyFused);
        caps.plans[ff.index()] = false;
        let mut p = Planner::with_dwell(PlanSpec::Adaptive, 1);
        p.apply_caps(&caps);
        assert!(!p.is_allowed(ff));
        assert_ne!(p.decide(&prefill_tick()).choice, ff);
        // An all-available report masks nothing.
        let mut q = Planner::with_dwell(PlanSpec::Adaptive, 1);
        q.apply_caps(&EngineCaps::full());
        for c in PlanChoice::candidates() {
            assert!(q.is_allowed(c));
        }
        assert_eq!(q.decide(&prefill_tick()).choice, ff);
    }

    #[test]
    fn switch_reports_ended_dwell() {
        let mut p = Planner::with_dwell(PlanSpec::Adaptive, 2);
        for _ in 0..5 {
            p.decide(&prefill_tick());
        }
        // First decode tick: dwell 5 ≥ 2 → switch, ending a 5-tick dwell.
        let d = p.decide(&decode_tick());
        assert!(d.switched);
        assert_eq!(d.ended_dwell, Some(5));
    }
}
