//! Offline autotuning: sweep the (decode rows × prefill tokens) shape
//! grid once, record the analytical argmin plan per cell, and emit the
//! result as a JSON [`PlanTable`] artifact (via the in-tree
//! `util::json` emitter). Loading the table at server start gives the
//! planner its zero-cost fast path: per-tick selection becomes a pure
//! lookup with no model evaluation in the serving process at all.
//!
//! `mambalaya autotune [--model 370m] [--quick] [--out FILE]` runs the
//! sweep from the CLI; `ci.sh` runs the `--quick` grid and the golden
//! snapshot under `rust/tests/golden/` pins the quick table
//! byte-for-byte.

use anyhow::{anyhow, Context, Result};

use crate::arch::ArchSpec;
use crate::cascade::ModelConfig;
use crate::util::JsonValue;

use super::cost::{CostModel, TickEstimate};
use super::features::pow2_ceil;
use super::PlanChoice;

/// The quick (CI / golden) grid axes.
pub const QUICK_DECODE_AXIS: [usize; 4] = [0, 1, 4, 8];
pub const QUICK_PREFILL_AXIS: [usize; 4] = [0, 16, 256, 4096];

/// The full grid axes.
pub const FULL_DECODE_AXIS: [usize; 8] = [0, 1, 2, 4, 8, 16, 32, 64];
pub const FULL_PREFILL_AXIS: [usize; 8] = [0, 8, 32, 128, 512, 2048, 4096, 8192];

/// One tuned grid cell: the winning plan and its predicted cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlanCell {
    pub choice: PlanChoice,
    pub cycles: u64,
    pub bytes: u64,
}

/// An autotuned plan table: `cells[d][p]` is the best plan at
/// `decode_axis[d]` decode rows and `prefill_axis[p]` prefill tokens.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanTable {
    /// Model the table was tuned for (sanity check at load).
    pub model: String,
    pub decode_axis: Vec<usize>,
    pub prefill_axis: Vec<usize>,
    pub cells: Vec<Vec<PlanCell>>,
}

impl PlanTable {
    /// Look up the cell covering a shape: each coordinate snaps to the
    /// smallest axis value ≥ the (already pow2-bucketed) query, so the
    /// cell is a conservative cover; queries past the last axis clamp
    /// to it.
    pub fn lookup(&self, decode_rows: usize, prefill_tokens: usize) -> PlanCell {
        let idx = |axis: &[usize], v: usize| {
            axis.iter().position(|&a| a >= v).unwrap_or(axis.len() - 1)
        };
        self.cells[idx(&self.decode_axis, decode_rows)][idx(&self.prefill_axis, prefill_tokens)]
    }

    /// Render as the JSON artifact (stable key order via the BTreeMap
    /// emitter — byte-stable for the golden snapshot).
    pub fn to_json(&self) -> JsonValue {
        let axis = |a: &[usize]| {
            JsonValue::Arr(a.iter().map(|&v| JsonValue::from(v)).collect())
        };
        let mut cells = JsonValue::Arr(vec![]);
        for (d, row) in self.cells.iter().enumerate() {
            for (p, cell) in row.iter().enumerate() {
                let mut o = JsonValue::obj();
                o.set("decode_rows", self.decode_axis[d])
                    .set("prefill_tokens", self.prefill_axis[p])
                    .set("plan", cell.choice.name())
                    .set("cycles", cell.cycles)
                    .set("bytes", cell.bytes);
                cells.push(o);
            }
        }
        let mut doc = JsonValue::obj();
        doc.set("artifact", "mambalaya-plan-table")
            .set("model", self.model.as_str())
            .set("decode_axis", axis(&self.decode_axis))
            .set("prefill_axis", axis(&self.prefill_axis))
            .set("cells", cells);
        doc
    }

    /// Parse the JSON artifact back.
    pub fn from_json(doc: &JsonValue) -> Result<PlanTable> {
        let axis = |key: &str| -> Result<Vec<usize>> {
            doc.get(key)
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("plan table missing {key}"))?
                .iter()
                .map(|v| {
                    v.as_i64()
                        .filter(|&x| x >= 0)
                        .map(|x| x as usize)
                        .ok_or_else(|| anyhow!("bad {key} entry"))
                })
                .collect()
        };
        let model = doc
            .get("model")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("plan table missing model"))?
            .to_string();
        let decode_axis = axis("decode_axis")?;
        let prefill_axis = axis("prefill_axis")?;
        anyhow::ensure!(
            !decode_axis.is_empty() && !prefill_axis.is_empty(),
            "plan table axes empty"
        );
        // `lookup` scans for the first axis value ≥ the query, which
        // silently misroutes on unsorted axes — reject them at load.
        let ascending = |a: &[usize]| a.windows(2).all(|w| w[0] < w[1]);
        anyhow::ensure!(
            ascending(&decode_axis) && ascending(&prefill_axis),
            "plan table axes must be strictly ascending"
        );
        let mut cells =
            vec![
                vec![PlanCell { choice: PlanChoice::candidates()[0], cycles: 0, bytes: 0 };
                    prefill_axis.len()];
                decode_axis.len()
            ];
        let mut seen = vec![vec![false; prefill_axis.len()]; decode_axis.len()];
        let raw = doc
            .get("cells")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("plan table missing cells"))?;
        for c in raw {
            let pos = |key: &str, axis: &[usize]| -> Result<usize> {
                let v = c
                    .get(key)
                    .and_then(|v| v.as_i64())
                    .ok_or_else(|| anyhow!("cell missing {key}"))? as usize;
                axis.iter().position(|&a| a == v).ok_or_else(|| anyhow!("cell {key}={v} off-axis"))
            };
            let d = pos("decode_rows", &decode_axis)?;
            let p = pos("prefill_tokens", &prefill_axis)?;
            let name = c
                .get("plan")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("cell missing plan"))?;
            let choice =
                PlanChoice::parse(name).ok_or_else(|| anyhow!("unknown plan {name:?}"))?;
            let num = |key: &str| -> Result<u64> {
                c.get(key)
                    .and_then(|v| v.as_i64())
                    .filter(|&x| x >= 0)
                    .map(|x| x as u64)
                    .ok_or_else(|| anyhow!("cell missing {key}"))
            };
            cells[d][p] = PlanCell { choice, cycles: num("cycles")?, bytes: num("bytes")? };
            seen[d][p] = true;
        }
        anyhow::ensure!(
            seen.iter().all(|row| row.iter().all(|&s| s)),
            "plan table has missing cells"
        );
        Ok(PlanTable { model, decode_axis, prefill_axis, cells })
    }

    /// Write the artifact (trailing newline so the golden file is
    /// editor-friendly).
    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .with_context(|| format!("writing plan table {path}"))
    }

    /// Load an artifact written by [`PlanTable::save`].
    pub fn load(path: &str) -> Result<PlanTable> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading plan table {path}"))?;
        let doc = JsonValue::parse(text.trim_end())
            .map_err(|e| anyhow!("parsing plan table {path}: {e}"))?;
        PlanTable::from_json(&doc)
    }
}

/// Run the sweep: evaluate every candidate at every grid cell and keep
/// the argmin (most-fused-first tie-break, same as the live planner).
pub fn autotune(cfg: &ModelConfig, arch: &ArchSpec, quick: bool) -> PlanTable {
    let (decode_axis, prefill_axis): (Vec<usize>, Vec<usize>) = if quick {
        (QUICK_DECODE_AXIS.to_vec(), QUICK_PREFILL_AXIS.to_vec())
    } else {
        (FULL_DECODE_AXIS.to_vec(), FULL_PREFILL_AXIS.to_vec())
    };
    let mut cost = CostModel::new(cfg.clone(), arch.clone());
    let mut cells = Vec::with_capacity(decode_axis.len());
    for &d in &decode_axis {
        let mut row = Vec::with_capacity(prefill_axis.len());
        for &p in &prefill_axis {
            // Axis points are already the bucket representatives.
            debug_assert_eq!(pow2_ceil(d), d);
            let bucket = super::features::PlanBucket { decode_rows: d, prefill_tokens: p };
            let (choice, est): (PlanChoice, TickEstimate) = cost.best(bucket);
            row.push(PlanCell { choice, cycles: est.cycles, bytes: est.bytes });
        }
        cells.push(row);
    }
    PlanTable { model: cfg.name.clone(), decode_axis, prefill_axis, cells }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fusion::FusionVariant;

    #[test]
    fn quick_table_shape_and_lookup() {
        let t = autotune(&ModelConfig::mamba_370m(), &ArchSpec::mambalaya(), true);
        assert_eq!(t.cells.len(), QUICK_DECODE_AXIS.len());
        assert!(t.cells.iter().all(|r| r.len() == QUICK_PREFILL_AXIS.len()));
        // Lookup snaps up to the covering cell and clamps past the end.
        assert_eq!(t.lookup(2, 0), t.cells[2][0]);
        assert_eq!(t.lookup(0, 17), t.cells[0][2]);
        assert_eq!(t.lookup(999, 1 << 20), t.cells[3][3]);
        // The all-zero cell exists and is deterministic (first
        // candidate by tie-break).
        assert_eq!(t.cells[0][0].choice, PlanChoice::candidates()[0]);
    }

    #[test]
    fn table_cells_match_live_cost_model() {
        // The table is exactly the frozen form of the adaptive policy.
        let t = autotune(&ModelConfig::mamba_370m(), &ArchSpec::mambalaya(), true);
        let mut m = CostModel::default_serving();
        for (d, &rows) in t.decode_axis.iter().enumerate() {
            for (p, &toks) in t.prefill_axis.iter().enumerate() {
                let (choice, est) = m.best(super::super::features::PlanBucket {
                    decode_rows: rows,
                    prefill_tokens: toks,
                });
                assert_eq!(t.cells[d][p].choice, choice);
                assert_eq!(t.cells[d][p].cycles, est.cycles);
            }
        }
    }

    #[test]
    fn json_roundtrip_is_lossless() {
        let t = autotune(&ModelConfig::mamba_370m(), &ArchSpec::mambalaya(), true);
        let doc = t.to_json();
        let back = PlanTable::from_json(&doc).unwrap();
        assert_eq!(t, back);
        // Emit → parse → emit is byte-stable (golden-snapshot property).
        let text = doc.to_string();
        assert_eq!(JsonValue::parse(&text).unwrap().to_string(), text);
    }

    #[test]
    fn from_json_rejects_malformed_tables() {
        let t = autotune(&ModelConfig::mamba_370m(), &ArchSpec::mambalaya(), true);
        let mut doc = t.to_json();
        doc.set("cells", JsonValue::Arr(vec![]));
        assert!(PlanTable::from_json(&doc).is_err(), "missing cells must fail");
        let bad = JsonValue::parse(r#"{"model":"x"}"#).unwrap();
        assert!(PlanTable::from_json(&bad).is_err());
        // Unsorted axes would silently misroute lookup — rejected.
        let mut unsorted = t.clone();
        unsorted.decode_axis.reverse();
        assert!(PlanTable::from_json(&unsorted.to_json()).is_err());
    }

    #[test]
    fn prefill_heavy_cells_prefer_fully_fused() {
        // The paper's prefill result survives the freeze: the largest
        // pure-prefill cell is fully fused, and it differs from the
        // pure-decode column's plan at the batched end.
        let t = autotune(&ModelConfig::mamba_370m(), &ArchSpec::mambalaya(), true);
        let pre = t.lookup(0, 4096);
        let dec = t.lookup(8, 0);
        assert_eq!(pre.choice, PlanChoice::Variant(FusionVariant::FullyFused));
        assert_ne!(pre.choice, dec.choice);
    }
}
