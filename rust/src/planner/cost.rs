//! Per-tick cost prediction: evaluate each candidate plan against a
//! tick's workload features using the repo's analytical accelerator
//! model (the same `model::evaluate` that reproduces the paper's
//! figures), and pick the cheapest.
//!
//! A tick's cost decomposes by phase, the way the serving engine
//! executes it:
//!
//! * **decode part** — one token for each of `decode_rows` sequences:
//!   the Mamba-1 cascade at `seq = 1, batch = decode_rows` with
//!   per-step recurrent-state I/O charged (`decode_state_io`). The
//!   batch dimension matters: the RD-bridged fully-fused mapping pays a
//!   per-token DRAM round-trip of the `H` state and K-partial GEMM
//!   spills that *scale with batch*, which is exactly why the paper's
//!   best decode mapping is not the best prefill mapping.
//! * **prefill part** — `prefill_tokens` prompt tokens: the cascade at
//!   `seq = prefill_tokens, batch = 1`, where fused traversals amortize
//!   inter-Einsum traffic over the whole chunk.
//!
//! Evaluations are cached per (plan, size) — sizes arrive already
//! power-of-two bucketed from [`super::features::PlanBucket`] — so the
//! serving hot path performs a pure map lookup after the first tick of
//! a given shape. Selection minimizes predicted *latency cycles*
//! (traffic alone would not reproduce the paper's phase flip: the
//! fused-most variant has the least inter-Einsum traffic in both
//! phases, but loses decode latency to its RD-bridge round-trips).

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

use crate::arch::ArchSpec;
use crate::cascade::{mamba1, ModelConfig};
use crate::model::{evaluate, ExecOptions};

use super::features::PlanBucket;
use super::PlanChoice;

/// Process-wide L2 cache of analytical evaluations, keyed by
/// (model name, d_model, layers, arch name, plan index, decode?,
/// size). Every scheduler, mock engine and autotune run in a process
/// shares one evaluation per point — the per-instance map in
/// [`CostModel`] stays the lock-free hot path.
type EvalKey = (String, u64, u64, String, usize, bool, usize);

fn global_cache() -> &'static Mutex<BTreeMap<EvalKey, TickEstimate>> {
    static CACHE: OnceLock<Mutex<BTreeMap<EvalKey, TickEstimate>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(BTreeMap::new()))
}

/// Predicted cost of one scheduler tick under a plan.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TickEstimate {
    /// Predicted device latency (cycles, all layers).
    pub cycles: u64,
    /// Predicted DRAM traffic (bytes, all layers).
    pub bytes: u64,
}

impl TickEstimate {
    pub fn add(&self, other: TickEstimate) -> TickEstimate {
        TickEstimate { cycles: self.cycles + other.cycles, bytes: self.bytes + other.bytes }
    }
}

/// Analytical per-tick cost model over a fixed candidate set.
#[derive(Debug)]
pub struct CostModel {
    cfg: ModelConfig,
    arch: ArchSpec,
    /// (plan index, decode rows) → per-tick decode-part estimate.
    decode_cache: BTreeMap<(usize, usize), TickEstimate>,
    /// (plan index, prefill tokens) → per-tick prefill-part estimate.
    prefill_cache: BTreeMap<(usize, usize), TickEstimate>,
}

impl CostModel {
    pub fn new(cfg: ModelConfig, arch: ArchSpec) -> CostModel {
        CostModel { cfg, arch, decode_cache: BTreeMap::new(), prefill_cache: BTreeMap::new() }
    }

    /// The serving default: the paper's primary model (mamba-370m) on
    /// the Mambalaya architecture. Shared by the scheduler's planner
    /// and the mock engine's traffic profiles, so predicted and modeled
    /// counters are directly comparable.
    pub fn default_serving() -> CostModel {
        CostModel::new(ModelConfig::mamba_370m(), ArchSpec::mambalaya())
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    /// One analytical evaluation (L2-cached process-wide): the Mamba-1
    /// cascade at `(seq, batch)` under the plan, with `decode`
    /// selecting the per-step state-I/O regime.
    fn eval(&self, choice: PlanChoice, decode: bool, size: usize) -> TickEstimate {
        let key: EvalKey = (
            self.cfg.name.clone(),
            self.cfg.d_model,
            self.cfg.layers,
            self.arch.name.clone(),
            choice.index(),
            decode,
            size,
        );
        if let Some(&e) = global_cache().lock().unwrap().get(&key) {
            return e;
        }
        let (seq, batch) = if decode { (1, size as u64) } else { (size as u64, 1) };
        let c = mamba1::build(&self.cfg, seq, batch);
        let opts = ExecOptions {
            staging: choice.staging(),
            pipelined: false,
            decode_state_io: decode,
        };
        let cost = evaluate(&c, &choice.plan(&c), &self.arch, &opts);
        let e = TickEstimate {
            cycles: cost.latency * self.cfg.layers,
            bytes: cost.traffic.total() * self.cfg.layers,
        };
        global_cache().lock().unwrap().insert(key, e);
        e
    }

    /// Decode-part estimate: `rows` sequences advancing one token.
    pub fn decode_cost(&mut self, choice: PlanChoice, rows: usize) -> TickEstimate {
        if rows == 0 {
            return TickEstimate::default();
        }
        let key = (choice.index(), rows);
        if let Some(&e) = self.decode_cache.get(&key) {
            return e;
        }
        let e = self.eval(choice, true, rows);
        self.decode_cache.insert(key, e);
        e
    }

    /// Prefill-part estimate: `tokens` prompt tokens in chunk rows.
    pub fn prefill_cost(&mut self, choice: PlanChoice, tokens: usize) -> TickEstimate {
        if tokens == 0 {
            return TickEstimate::default();
        }
        let key = (choice.index(), tokens);
        if let Some(&e) = self.prefill_cache.get(&key) {
            return e;
        }
        let e = self.eval(choice, false, tokens);
        self.prefill_cache.insert(key, e);
        e
    }

    /// Full tick estimate at a shape bucket.
    pub fn tick_cost(&mut self, choice: PlanChoice, bucket: PlanBucket) -> TickEstimate {
        self.decode_cost(choice, bucket.decode_rows)
            .add(self.prefill_cost(choice, bucket.prefill_tokens))
    }

    /// The candidate whose predicted cycles are lowest at this bucket.
    ///
    /// Candidates are visited most-fused-first and replaced only on a
    /// *strict* improvement, so ties resolve toward the more aggressive
    /// fusion — deterministic, and aligned with the paper's preference
    /// when two mappings model identically.
    pub fn best(&mut self, bucket: PlanBucket) -> (PlanChoice, TickEstimate) {
        self.best_among(bucket, |_| true).expect("non-empty candidate set")
    }

    /// [`CostModel::best`] restricted to candidates `allow` accepts
    /// (e.g. plans the engine actually registered). `None` when the
    /// filter rejects everything.
    pub fn best_among<F: Fn(PlanChoice) -> bool>(
        &mut self,
        bucket: PlanBucket,
        allow: F,
    ) -> Option<(PlanChoice, TickEstimate)> {
        let mut best: Option<(PlanChoice, TickEstimate)> = None;
        for choice in PlanChoice::candidates() {
            if !allow(choice) {
                continue;
            }
            let e = self.tick_cost(choice, bucket);
            best = match best {
                Some((_, b)) if e.cycles >= b.cycles => best,
                _ => Some((choice, e)),
            };
        }
        best
    }

    /// [`CostModel::best`] restricted to an [`PlanChoice::index`]-ed
    /// availability mask — the shape a capability report
    /// ([`crate::runtime::EngineCaps::plans`]) and the planner's
    /// disallow set both take, so capability-negotiated selection needs
    /// no closure plumbing. `None` when the mask rejects everything.
    pub fn best_allowed(
        &mut self,
        bucket: PlanBucket,
        allowed: &[bool; PlanChoice::COUNT],
    ) -> Option<(PlanChoice, TickEstimate)> {
        self.best_among(bucket, |c| allowed[c.index()])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fusion::FusionVariant;

    #[test]
    fn zero_shapes_cost_nothing() {
        let mut m = CostModel::default_serving();
        let ff = PlanChoice::Variant(FusionVariant::FullyFused);
        assert_eq!(m.decode_cost(ff, 0), TickEstimate::default());
        assert_eq!(m.prefill_cost(ff, 0), TickEstimate::default());
        assert_eq!(
            m.tick_cost(ff, PlanBucket { decode_rows: 0, prefill_tokens: 0 }),
            TickEstimate::default()
        );
    }

    #[test]
    fn costs_are_monotone_in_shape() {
        // Rounding a shape *up* to its bucket representative must never
        // under-predict: every cost component (compute work, traffic,
        // state I/O, spills, pass reloads) is non-decreasing in both
        // batch and sequence length.
        let mut m = CostModel::default_serving();
        for choice in [
            PlanChoice::Variant(FusionVariant::RIOnly),
            PlanChoice::Variant(FusionVariant::FullyFused),
        ] {
            for rows in [2usize, 4, 8] {
                let a = m.decode_cost(choice, rows);
                let b = m.decode_cost(choice, rows * 2);
                assert!(b.cycles >= a.cycles, "{choice:?} decode not monotone");
                assert!(b.bytes >= a.bytes, "{choice:?} decode bytes not monotone");
            }
            for toks in [64usize, 256, 1024] {
                let a = m.prefill_cost(choice, toks);
                let b = m.prefill_cost(choice, toks * 2);
                assert!(b.cycles >= a.cycles, "{choice:?} prefill not monotone");
                assert!(b.bytes >= a.bytes, "{choice:?} prefill bytes not monotone");
            }
        }
    }

    #[test]
    fn cache_returns_identical_estimates() {
        let mut m = CostModel::default_serving();
        let rsp = PlanChoice::Variant(FusionVariant::RIRSbRSp);
        let a = m.decode_cost(rsp, 8);
        let b = m.decode_cost(rsp, 8);
        assert_eq!(a, b);
        let p = m.prefill_cost(rsp, 512);
        assert_eq!(p, m.prefill_cost(rsp, 512));
    }

    #[test]
    fn phase_flip_fully_fused_wins_prefill_not_decode() {
        // The paper's central serving observation: the best mapping
        // depends on the phase. Prefill at the reference length is won
        // by the fully-fused mapping (pinned independently by
        // model::exec's `fused_variants_speed_up_prefill`); batched
        // decode is not — the RD bridge's per-token H round-trip and
        // K-partial spills scale with batch.
        let mut m = CostModel::default_serving();
        let (pre, _) = m.best(PlanBucket { decode_rows: 0, prefill_tokens: 4096 });
        let (dec, _) = m.best(PlanBucket { decode_rows: 8, prefill_tokens: 0 });
        assert_eq!(pre, PlanChoice::Variant(FusionVariant::FullyFused));
        assert_ne!(dec, PlanChoice::Variant(FusionVariant::FullyFused));
        assert_ne!(pre, dec);
    }

    #[test]
    fn best_is_argmin_over_candidates() {
        let mut m = CostModel::default_serving();
        let bucket = PlanBucket { decode_rows: 4, prefill_tokens: 64 };
        let (choice, est) = m.best(bucket);
        for c in PlanChoice::all() {
            assert!(
                m.tick_cost(c, bucket).cycles >= est.cycles,
                "{c:?} beats the reported best"
            );
        }
        assert_eq!(m.tick_cost(choice, bucket), est);
    }
}
