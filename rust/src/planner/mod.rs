//! Workload-adaptive fusion-plan selection: the bridge from the
//! paper's analytical model into the serving loop.
//!
//! The paper's central serving observation is that the *best* fusion
//! mapping depends on the phase mix — fully-fused wins prefill, while
//! batched decode is won by a non-RD-bridged variant (Figure 12's
//! context:generation sweep). The repo models exactly that tradeoff;
//! this subsystem makes the live scheduler act on it:
//!
//! * [`features`] — a per-tick [`features::WorkloadFeatures`] summary of
//!   the mixed batch (decode rows, chunk-length histogram, resident
//!   state bytes, budget utilization) plus the power-of-two
//!   [`features::PlanBucket`] projection selection happens on;
//! * [`cost`] — [`cost::CostModel`]: per-bucket evaluation of every
//!   candidate plan through `model::evaluate` (decode part at the
//!   tick's batch with per-step state I/O, prefill part at the chunk
//!   token count), cached so steady state never re-evaluates;
//! * [`policy`] — [`policy::Planner`]: static / adaptive / table modes
//!   ([`policy::PlanSpec`]) with dwell-tick hysteresis against plan
//!   thrashing on noisy mixes;
//! * [`autotune`] — the offline grid sweep emitting the JSON
//!   [`autotune::PlanTable`] artifact, the zero-cost serving fast path.
//!
//! The selected [`PlanChoice`] (a re-export of
//! [`crate::workload::DesignPoint`]: the five fusion variants plus the
//! MARCA-like / Geens-like baselines) rides in each tick's
//! [`crate::runtime::LaunchSpec`]; engines that compile one executable
//! per variant dispatch on it, and the mock engine charges each tick
//! with the chosen plan's analytical cost so the deterministic
//! `modeled_cycles` / `modeled_bytes` counters make plan quality
//! observable in tests, benches and CI gates. Which plans are
//! *selectable* is negotiated up front: the engine declares per-plan
//! availability in [`crate::runtime::EngineCaps`] and the scheduler
//! seeds [`Planner::apply_caps`] from the report.
//!
//! Every [`PlanChoice`] the planner can pick is statically verified by
//! [`crate::verify`] (legality against the Einsum dataflow DAG,
//! liveness-exact traffic cross-check, per-plan `donation_safe`
//! verdict) — a plan that reaches this subsystem has already been
//! proven executable, so selection is purely a cost decision.

pub mod autotune;
pub mod cost;
pub mod features;
pub mod policy;

pub use crate::workload::DesignPoint as PlanChoice;

pub use autotune::{autotune, PlanCell, PlanTable};
pub use cost::{CostModel, TickEstimate};
pub use features::{PlanBucket, WorkloadFeatures};
pub use policy::{PlanDecision, Planner, PlanSpec, DEFAULT_MIN_DWELL};

impl PlanChoice {
    /// Candidate visiting order for selection: most-fused-first, so
    /// cost ties resolve toward the more aggressive fusion
    /// deterministically.
    pub fn candidates() -> [PlanChoice; PlanChoice::COUNT] {
        use crate::arch::Baseline;
        use crate::fusion::FusionVariant;
        [
            PlanChoice::Variant(FusionVariant::FullyFused),
            PlanChoice::Variant(FusionVariant::RIRSbRSp),
            PlanChoice::Variant(FusionVariant::RIRSb),
            PlanChoice::Variant(FusionVariant::RIOnly),
            PlanChoice::Variant(FusionVariant::Unfused),
            PlanChoice::Baseline(Baseline::GeensLike),
            PlanChoice::Baseline(Baseline::MarcaLike),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn candidates_cover_all_indices_once() {
        let mut seen = [false; PlanChoice::COUNT];
        for c in PlanChoice::candidates() {
            assert!(!seen[c.index()], "{c:?} repeated");
            seen[c.index()] = true;
        }
        assert!(seen.iter().all(|&s| s));
        // And they round-trip through the parser.
        for c in PlanChoice::candidates() {
            assert_eq!(PlanChoice::parse(&c.name()), Some(c));
        }
    }
}
