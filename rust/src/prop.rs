//! Mini property-testing harness (no proptest in the vendored crate
//! set): run a closure over many seeded random cases; on failure,
//! report the seed so the case replays deterministically.

use crate::util::XorShift;

/// Run `cases` property checks. The closure receives a fresh
/// deterministic RNG per case and returns `Err(msg)` on violation.
///
/// Panics with the failing seed embedded, so
/// `check_one(seed, f)` replays it.
pub fn check<F>(name: &str, cases: u32, mut f: F)
where
    F: FnMut(&mut XorShift) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = 0x9E37_79B9_7F4A_7C15u64 ^ (case as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        let mut rng = XorShift::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property `{name}` failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay a single seed (debugging aid).
pub fn check_one<F>(seed: u64, mut f: F)
where
    F: FnMut(&mut XorShift) -> Result<(), String>,
{
    let mut rng = XorShift::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("property failed on replay (seed {seed:#x}): {msg}");
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return Err(format!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("trivial", 25, |rng| {
            count += 1;
            let x = rng.range(0, 10);
            if x <= 10 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_reports_seed() {
        check("fails", 5, |rng| Err(format!("x = {}", rng.range(0, 1000))));
    }
}
