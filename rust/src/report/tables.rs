//! Table emitters: Table I (Best-Unfused traffic breakdown), Table II
//! (fusion taxonomy of related work), Table III (configuration).

use std::fmt::Write as _;

use crate::arch::{ArchSpec, Binding};
use crate::cascade::{mamba1, ModelConfig};
use crate::fusion::{stitch, FusionVariant};
use crate::model::{evaluate, ExecOptions};
use crate::util::CsvWriter;

/// Table I result: traffic breakdowns of the Best-Unfused design.
#[derive(Debug, Clone, Copy)]
pub struct Table1 {
    pub read_pct: f64,
    pub write_pct: f64,
    pub inter_pct: f64,
    pub intra_pct: f64,
}

/// Compute Table I for one layer of Best-Unfused at the given sequence
/// length.
pub fn table1(cfg: &ModelConfig, seq: u64, batch: u64) -> Table1 {
    let c = mamba1::build(cfg, seq, batch);
    let arch = ArchSpec::mambalaya();
    let cost =
        evaluate(&c, &stitch(&c, FusionVariant::Unfused), &arch, &ExecOptions::default());
    let t = cost.traffic;
    let total = t.total().max(1) as f64;
    Table1 {
        read_pct: 100.0 * t.reads() as f64 / total,
        write_pct: 100.0 * t.writes() as f64 / total,
        inter_pct: 100.0 * t.inter() as f64 / total,
        intra_pct: 100.0 * t.intra() as f64 / total,
    }
}

/// Render Table I as text + CSV.
pub fn table1_report(cfg: &ModelConfig, seq: u64, batch: u64) -> (String, String) {
    let t = table1(cfg, seq, batch);
    let mut s = String::new();
    let _ = writeln!(s, "Table I — Best-Unfused traffic breakdown ({}, I={}×{})", cfg.name, seq, batch);
    let _ = writeln!(s, "  Read Traffic  {:>6.1}%   Inter-Einsum {:>6.1}%", t.read_pct, t.inter_pct);
    let _ = writeln!(s, "  Write Traffic {:>6.1}%   Intra-Einsum {:>6.1}%", t.write_pct, t.intra_pct);
    let _ = writeln!(s, "  (paper: reads 99.3%, writes 0.7%; inter 99.1%, intra 0.9%)");
    let mut csv = CsvWriter::new();
    csv.header(&["metric", "percent"])
        .row(["read", &format!("{:.2}", t.read_pct)])
        .row(["write", &format!("{:.2}", t.write_pct)])
        .row(["inter", &format!("{:.2}", t.inter_pct)])
        .row(["intra", &format!("{:.2}", t.intra_pct)]);
    (s, csv.finish())
}

/// Table II: which fusion classes each related work supports. The rows
/// for prior work are capability summaries taken from the paper; the
/// Mambalaya row is *derived* by probing our own stitcher with the four
/// canonical pair cascades (Figures 4–7).
pub fn table2_report() -> (String, String) {
    // Derive this work's supported classes by classification probes.
    use crate::cascade::examples;
    use crate::fusion::{classify_pair, FusionClass};
    let probes = [
        (examples::fig4_ri(8, 64), FusionClass::RI),
        (examples::fig5_rsb(8, 64), FusionClass::RSb),
        (examples::fig6_rsp(8, 64, 4), FusionClass::RSp),
        (examples::fig7_rd(8, 4, 64, 4), FusionClass::RD),
    ];
    let mut ours = Vec::new();
    for (c, expect) in &probes {
        let p = classify_pair(&c.einsums()[0], &c.einsums()[1]).unwrap();
        assert_eq!(p.class, *expect);
        ours.push(p.class);
    }
    let yes = |b: bool| if b { "yes" } else { "-" };

    // (work, ri, rsb, rsp, rd, stitching, min-ITF, workloads)
    let rows: Vec<(&str, bool, bool, bool, bool, &str, &str, &str)> = vec![
        ("XLA-like", true, false, false, false, "RI", "unit", "DL"),
        ("TVM/AStitch", true, false, true, false, "RI", "unit,tile", "DL"),
        ("PyTorch-like", true, true, true, false, "RI+RSb+RSp", "unit,tile", "DL"),
        ("APOLLO", true, true, true, true, "RI+RSb+RSp", "unit,tile", "DL"),
        ("CNN DSAs", true, false, true, false, "RI+RSp,recompute", "tile", "CNN"),
        ("TileFlow", true, true, true, false, "RI+RSb+RSp,recompute", "tile", "DL"),
        ("LoopTree", true, true, true, true, "RI,recompute", "tile", "DL,TA"),
        ("MARCA", true, false, false, false, "RI", "tile", "Mamba-1"),
        ("Geens et al.", true, false, false, false, "RI", "unit,tile", "Mamba-1"),
        (
            "Mambalaya (derived)",
            ours.contains(&FusionClass::RI),
            ours.contains(&FusionClass::RSb),
            ours.contains(&FusionClass::RSp),
            ours.contains(&FusionClass::RD),
            "all combos",
            "unit,tile(RD)",
            "Mamba-1/2,TA+",
        ),
    ];

    let mut s = String::new();
    let _ = writeln!(s, "Table II — fusion support matrix");
    let _ = writeln!(
        s,
        "{:<22} {:<4} {:<4} {:<4} {:<4} {:<22} {:<14} {}",
        "work", "RI", "RSb", "RSp", "RD", "stitching", "min ITF", "workloads"
    );
    let mut csv = CsvWriter::new();
    csv.header(&["work", "ri", "rsb", "rsp", "rd", "stitching", "min_itf", "workloads"]);
    for (w, ri, rsb, rsp, rd, st, itf, wl) in rows {
        let _ = writeln!(
            s,
            "{:<22} {:<4} {:<4} {:<4} {:<4} {:<22} {:<14} {}",
            w,
            yes(ri),
            yes(rsb),
            yes(rsp),
            yes(rd),
            st,
            itf,
            wl
        );
        csv.row([w, yes(ri), yes(rsb), yes(rsp), yes(rd), st, itf, wl]);
    }
    (s, csv.finish())
}

/// Table III: Mambalaya configuration vs the H100 reference.
pub fn table3_report() -> (String, String) {
    let a = ArchSpec::mambalaya();
    let mut s = String::new();
    let _ = writeln!(s, "Table III — configuration (vs H100 reference)");
    let _ = writeln!(s, "{:<28} {:<14} {}", "feature", "H100", "Mambalaya");
    let rows: Vec<(&str, String, String)> = vec![
        ("FP16 CUDA cores", "14592".into(), "-".into()),
        ("Tensor cores", "456".into(), "-".into()),
        (
            "Total PEs",
            "-".into(),
            format!("{} + {}", a.pes(Binding::Mode2D), a.pes(Binding::Small1D)),
        ),
        ("1D PE config (of 2D)", "-".into(), format!("{}x1", a.pe_1d_wide)),
        ("2D PE config", "-".into(), format!("{}x{}", a.pe_2d_rows, a.pe_2d_cols)),
        ("Clock (GHz)", format!("{}", a.freq_ghz), format!("{}", a.freq_ghz)),
        ("Memory BW (GB/s)", format!("{}", a.dram_gbps), format!("{}", a.dram_gbps)),
        ("L2 / global buffer (MB)", "50".into(), format!("{}", a.buffer_bytes >> 20)),
        (
            "Register file (MB)",
            "~33".into(),
            format!("{:.2}", a.reg_bytes as f64 / (1 << 20) as f64),
        ),
    ];
    let mut csv = CsvWriter::new();
    csv.header(&["feature", "h100", "mambalaya"]);
    for (f, h, m) in rows {
        let _ = writeln!(s, "{:<28} {:<14} {}", f, h, m);
        csv.row([f.to_string(), h, m]);
    }
    (s, csv.finish())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper_shape() {
        // Paper Table I: inter 99.1%, intra 0.9% — intermediates dwarf
        // weights once sequence-scaled activations dominate. We
        // reproduce that split. (The paper's read/write split of
        // 99.3%/0.7% is not derivable from a consistent unfused
        // accounting — every written intermediate is read back at least
        // once, bounding reads below ~75% — so we assert only that
        // reads exceed writes; see EXPERIMENTS.md.)
        let t = table1(&ModelConfig::mamba_370m(), 2048, 1);
        assert!(t.read_pct > 50.0, "read {}", t.read_pct);
        assert!(t.inter_pct > 90.0, "inter {}", t.inter_pct);
        assert!((t.read_pct + t.write_pct - 100.0).abs() < 1e-6);
        assert!((t.inter_pct + t.intra_pct - 100.0).abs() < 1e-6);
    }

    #[test]
    fn table2_mambalaya_row_supports_all() {
        let (text, csv) = table2_report();
        assert!(text.contains("Mambalaya"));
        let row = csv.lines().find(|l| l.contains("Mambalaya")).unwrap();
        assert_eq!(row.matches("yes").count(), 4);
    }

    #[test]
    fn table3_renders() {
        let (text, csv) = table3_report();
        assert!(text.contains("65536 + 256"));
        assert!(csv.contains("256x256"));
    }
}
