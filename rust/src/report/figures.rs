//! Figure emitters: each function regenerates the data series of one
//! paper figure (text + CSV), using the analytical model.

use std::fmt::Write as _;

use crate::arch::{ArchSpec, Baseline};
use crate::cascade::{mamba1, ModelConfig, Scenario};
use crate::fusion::{stitch, FusionVariant};
use crate::roofline::{ascii_chart, timeline};
use crate::util::CsvWriter;
use crate::workload::{
    decode_layer, ideal_layer, prefill_layer, scenario_cost, DesignPoint,
};

/// Figure 2 — overall roofline + unfused-vs-ideal utilization over time
/// for prefill and generation.
pub fn fig2_report(cfg: &ModelConfig, seq: u64, batch: u64) -> (String, String) {
    let arch = ArchSpec::mambalaya();
    let mut s = String::new();
    let mut csv = CsvWriter::new();
    csv.header(&["phase", "design", "latency_cycles", "flops", "bytes", "intensity", "speedup_vs_unfused"]);

    let _ = writeln!(s, "Figure 2 — roofline: unfused vs ideal fusion ({})", cfg.name);
    for (phase, seqlen, b, decode) in
        [("prefill", seq, batch, false), ("generate", 1, batch, true)]
    {
        let point = DesignPoint::Variant(FusionVariant::Unfused);
        let unf = if decode {
            decode_layer(cfg, b, point, &arch)
        } else {
            prefill_layer(cfg, seqlen, b, point, &arch, false)
        };
        let ideal = ideal_layer(cfg, seqlen, b, &arch, decode);
        let speedup = unf.latency as f64 / ideal.latency.max(1) as f64;
        let _ = writeln!(
            s,
            "  {phase}: unfused OI = {:.1} flop/B (machine balance {:.1}) → memory-bound: {}",
            unf.intensity(),
            arch.machine_balance(),
            unf.intensity() < arch.machine_balance(),
        );
        let _ = writeln!(
            s,
            "  {phase}: ideal-fusion speedup = {speedup:.2}× (paper: {} )",
            if decode { "3.8×" } else { "5.79×" }
        );
        for (design, cost) in [("unfused", &unf), ("ideal", &ideal)] {
            csv.row([
                phase.to_string(),
                design.to_string(),
                cost.latency.to_string(),
                cost.flops.to_string(),
                cost.traffic.total().to_string(),
                format!("{:.3}", cost.intensity()),
                format!("{:.3}", unf.latency as f64 / cost.latency.max(1) as f64),
            ]);
        }
        let _ = writeln!(s, "{}", ascii_chart(&timeline(&unf, &arch), 72));
    }
    (s, csv.finish())
}

/// Figure 9 — fusion-group structure per variant (group count and
/// membership).
pub fn fig9_report(cfg: &ModelConfig, seq: u64) -> (String, String) {
    let c = mamba1::build(cfg, seq, 1);
    let mut s = String::new();
    let mut csv = CsvWriter::new();
    csv.header(&["variant", "groups", "membership"]);
    let _ = writeln!(s, "Figure 9 — fusion groups per variant ({})", cfg.name);
    for v in FusionVariant::all() {
        let plan = stitch(&c, v);
        let groups: Vec<String> = plan
            .groups
            .iter()
            .map(|g| {
                let ids: Vec<String> = g.einsums.iter().map(|i| i.to_string()).collect();
                format!("[{}]", ids.join(","))
            })
            .collect();
        let _ = writeln!(s, "  {:<12} {:>2} groups: {}", v.name(), plan.groups.len(), groups.join(" "));
        csv.row([v.name().to_string(), plan.groups.len().to_string(), groups.join(" ")]);
    }
    let _ = writeln!(s, "  (paper: 24 → 12 → 8 → 3 → 1)");
    (s, csv.finish())
}

/// Figure 10 — utilization-over-time per fusion variant, one prefill
/// layer.
pub fn fig10_report(cfg: &ModelConfig, seq: u64, batch: u64) -> (String, String) {
    let arch = ArchSpec::mambalaya();
    let mut s = String::new();
    let mut csv = CsvWriter::new();
    csv.header(&["variant", "phase_start", "phase_end", "utilization", "intensity", "memory_bound", "einsums"]);
    let _ = writeln!(s, "Figure 10 — utilization over time per variant ({}, I={}×{})", cfg.name, seq, batch);
    for v in [FusionVariant::RIOnly, FusionVariant::RIRSb, FusionVariant::RIRSbRSp, FusionVariant::FullyFused] {
        let cost = prefill_layer(cfg, seq, batch, DesignPoint::Variant(v), &arch, false);
        let tl = timeline(&cost, &arch);
        let _ = writeln!(s, "{}", ascii_chart(&tl, 72));
        for span in &tl.spans {
            csv.row([
                v.name().to_string(),
                span.start.to_string(),
                span.end.to_string(),
                format!("{:.4}", span.utilization),
                format!("{:.3}", span.intensity),
                span.memory_bound.to_string(),
                span.einsums.iter().map(|i| i.to_string()).collect::<Vec<_>>().join(" "),
            ]);
        }
    }
    (s, csv.finish())
}

/// Figure 12 — end-to-end performance across context:generation ratios,
/// all variants, with and without parallel pipelining, plus the ideal.
pub fn fig12_report(cfg: &ModelConfig) -> (String, String) {
    let arch = ArchSpec::mambalaya();
    let mut s = String::new();
    let mut csv = CsvWriter::new();
    csv.header(&["scenario", "design", "pipelined", "total_cycles", "speedup_vs_unfused"]);
    let _ = writeln!(s, "Figure 12 — end-to-end performance ({})", cfg.name);
    for sc in Scenario::paper_suite() {
        let base = scenario_cost(cfg, &sc, DesignPoint::Variant(FusionVariant::Unfused), &arch, false);
        let _ = writeln!(s, "  scenario {} (prefill {} decode {}):", sc.name, sc.prefill, sc.decode);
        for v in FusionVariant::all() {
            for pipelined in [false, true] {
                let cost = scenario_cost(cfg, &sc, DesignPoint::Variant(v), &arch, pipelined);
                let speedup = base.total_cycles() as f64 / cost.total_cycles() as f64;
                if !pipelined {
                    let _ = writeln!(s, "    {:<12} {speedup:.2}×", v.name());
                } else {
                    let _ = writeln!(s, "    {:<12} {speedup:.2}× (pipelined)", v.name());
                }
                csv.row([
                    sc.name.clone(),
                    v.name().to_string(),
                    pipelined.to_string(),
                    cost.total_cycles().to_string(),
                    format!("{:.3}", speedup),
                ]);
            }
        }
        // Ideal red line: per-phase algorithmic minimum.
        let ideal_pf = ideal_layer(cfg, sc.prefill, sc.batch, &arch, false);
        let ideal_dc = ideal_layer(cfg, 1, sc.batch, &arch, true);
        let ideal_total = ideal_pf.latency * cfg.layers + ideal_dc.latency * cfg.layers * sc.decode;
        let _ = writeln!(
            s,
            "    {:<12} {:.2}× (red line)",
            "ideal",
            base.total_cycles() as f64 / ideal_total as f64
        );
        csv.row([
            sc.name.clone(),
            "ideal".to_string(),
            "true".to_string(),
            ideal_total.to_string(),
            format!("{:.3}", base.total_cycles() as f64 / ideal_total as f64),
        ]);
    }
    let _ = writeln!(s, "  (paper prefill-heavy: RI 2.72×, +RSb 2.99×, +RSp 3.35×, fully-fused 4.9×;");
    let _ = writeln!(s, "   pipelined: 3.9×, 4.7×, 5.9×, 6×; decode-heavy: RI best at 2.23×)");
    (s, csv.finish())
}

/// Figure 13 — best Mambalaya variant vs MARCA-like and Geens-like.
pub fn fig13_report(cfg: &ModelConfig) -> (String, String) {
    let arch = ArchSpec::mambalaya();
    let mut s = String::new();
    let mut csv = CsvWriter::new();
    csv.header(&["scenario", "design", "total_cycles", "speedup_vs_unfused"]);
    let _ = writeln!(s, "Figure 13 — Mambalaya vs prior state of the art ({})", cfg.name);
    let mut geo_marca = 1.0f64;
    let mut geo_geens = 1.0f64;
    let mut n = 0u32;
    for sc in Scenario::paper_suite() {
        let base =
            scenario_cost(cfg, &sc, DesignPoint::Variant(FusionVariant::Unfused), &arch, false);
        // "Best Mambalaya variant": min over fused variants.
        let best = FusionVariant::fused()
            .into_iter()
            .map(|v| scenario_cost(cfg, &sc, DesignPoint::Variant(v), &arch, false))
            .min_by_key(|c| c.total_cycles())
            .unwrap();
        let marca = scenario_cost(cfg, &sc, DesignPoint::Baseline(Baseline::MarcaLike), &arch, false);
        let geens = scenario_cost(cfg, &sc, DesignPoint::Baseline(Baseline::GeensLike), &arch, false);
        let _ = writeln!(
            s,
            "  {}: best-Mambalaya {:.2}× | MARCA-like {:.2}× | Geens-like {:.2}× (vs unfused)",
            sc.name,
            base.total_cycles() as f64 / best.total_cycles() as f64,
            base.total_cycles() as f64 / marca.total_cycles() as f64,
            base.total_cycles() as f64 / geens.total_cycles() as f64,
        );
        for (d, cost) in [("best-mambalaya", &best), ("marca-like", &marca), ("geens-like", &geens)] {
            csv.row([
                sc.name.clone(),
                d.to_string(),
                cost.total_cycles().to_string(),
                format!("{:.3}", base.total_cycles() as f64 / cost.total_cycles() as f64),
            ]);
        }
        geo_marca *= marca.total_cycles() as f64 / best.total_cycles() as f64;
        geo_geens *= geens.total_cycles() as f64 / best.total_cycles() as f64;
        n += 1;
    }
    let _ = writeln!(
        s,
        "  geomean speedup: {:.2}× vs MARCA-like (paper 3×), {:.2}× vs Geens-like (paper 1.3×)",
        geo_marca.powf(1.0 / n as f64),
        geo_geens.powf(1.0 / n as f64)
    );
    (s, csv.finish())
}

/// Figure 14 — inter-/intra-Einsum traffic per variant, prefill and
/// decode, with the RI best-case as the baselines' ideal.
pub fn fig14_report(cfg: &ModelConfig, seq: u64, batch: u64) -> (String, String) {
    let arch = ArchSpec::mambalaya();
    let mut s = String::new();
    let mut csv = CsvWriter::new();
    csv.header(&["phase", "design", "inter_bytes", "intra_bytes"]);
    let _ = writeln!(s, "Figure 14 — inter/intra-Einsum traffic per variant ({})", cfg.name);
    let mut points: Vec<DesignPoint> = vec![
        DesignPoint::Baseline(Baseline::MarcaLike),
        DesignPoint::Baseline(Baseline::GeensLike),
    ];
    points.extend(FusionVariant::all().into_iter().map(DesignPoint::Variant));
    for (phase, decode) in [("prefill", false), ("decode", true)] {
        let _ = writeln!(s, "  {phase}:");
        let mut unfused_inter = 0u64;
        for p in &points {
            let cost = if decode {
                decode_layer(cfg, batch, *p, &arch)
            } else {
                prefill_layer(cfg, seq, batch, *p, &arch, false)
            };
            if p == &DesignPoint::Variant(FusionVariant::Unfused) {
                unfused_inter = cost.traffic.inter();
            }
            let _ = writeln!(
                s,
                "    {:<14} inter {:>12} B  intra {:>12} B",
                p.name(),
                cost.traffic.inter(),
                cost.traffic.intra()
            );
            csv.row([
                phase.to_string(),
                p.name(),
                cost.traffic.inter().to_string(),
                cost.traffic.intra().to_string(),
            ]);
        }
        // Paper: fused variants reduce inter traffic by 4×–34×.
        let best_inter = points
            .iter()
            .filter(|p| !matches!(p, DesignPoint::Variant(FusionVariant::Unfused)))
            .map(|p| {
                let cost = if decode {
                    decode_layer(cfg, batch, *p, &arch)
                } else {
                    prefill_layer(cfg, seq, batch, *p, &arch, false)
                };
                cost.traffic.inter().max(1)
            })
            .min()
            .unwrap_or(1);
        let _ = writeln!(
            s,
            "    inter-traffic reduction range up to {:.1}× (paper: 4×–34×)",
            unfused_inter as f64 / best_inter as f64
        );
    }
    (s, csv.finish())
}

/// Figure 15 — roofline-utilization over time for baselines + variants,
/// prefill and generation, with speedups vs MARCA-like.
pub fn fig15_report(cfg: &ModelConfig, seq: u64, batch: u64) -> (String, String) {
    let arch = ArchSpec::mambalaya();
    let mut s = String::new();
    let mut csv = CsvWriter::new();
    csv.header(&["phase", "design", "latency_cycles", "speedup_vs_marca"]);
    let _ = writeln!(s, "Figure 15 — utilization over time, baselines vs variants ({})", cfg.name);
    let mut points: Vec<DesignPoint> = vec![
        DesignPoint::Baseline(Baseline::MarcaLike),
        DesignPoint::Baseline(Baseline::GeensLike),
    ];
    points.extend(FusionVariant::fused().into_iter().map(DesignPoint::Variant));
    for (phase, decode) in [("prefill", false), ("generate", true)] {
        let marca = if decode {
            decode_layer(cfg, batch, DesignPoint::Baseline(Baseline::MarcaLike), &arch)
        } else {
            prefill_layer(cfg, seq, batch, DesignPoint::Baseline(Baseline::MarcaLike), &arch, false)
        };
        let _ = writeln!(s, "  {phase} (speedups vs MARCA-like):");
        for p in &points {
            let cost = if decode {
                decode_layer(cfg, batch, *p, &arch)
            } else {
                prefill_layer(cfg, seq, batch, *p, &arch, false)
            };
            let speedup = marca.latency as f64 / cost.latency as f64;
            let _ = writeln!(s, "    {:<14} {speedup:.2}×", p.name());
            csv.row([
                phase.to_string(),
                p.name(),
                cost.latency.to_string(),
                format!("{:.3}", speedup),
            ]);
            if !decode {
                let _ = writeln!(s, "{}", ascii_chart(&timeline(&cost, &arch), 72));
            }
        }
    }
    let _ = writeln!(s, "  (paper prefill: Geens-like 3.35×, +RSp 4.76×, fully-fused 4.89× vs MARCA-like)");
    (s, csv.finish())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ModelConfig {
        ModelConfig::mamba_130m() // smaller = faster tests
    }

    #[test]
    fn fig2_reports_memory_bound_unfused() {
        let (text, csv) = fig2_report(&cfg(), 1024, 4);
        assert!(text.contains("memory-bound: true"));
        assert!(csv.lines().count() >= 5);
    }

    #[test]
    fn fig9_counts() {
        let (text, _) = fig9_report(&ModelConfig::mamba_370m(), 1024);
        assert!(text.contains("24 groups") || text.contains("24 "));
        assert!(text.contains(" 1 groups") || text.contains("1 group"));
    }

    #[test]
    fn fig12_has_all_variants_and_scenarios() {
        let (_, csv) = fig12_report(&cfg());
        // 3 scenarios × (5 variants × 2 pipelining + ideal) = 33 rows + header.
        assert_eq!(csv.lines().count(), 1 + 3 * 11);
    }

    #[test]
    fn fig13_mambalaya_beats_baselines() {
        let (text, csv) = fig13_report(&cfg());
        assert!(text.contains("geomean"));
        // Best Mambalaya ≥ baselines in the prefill-heavy scenario.
        let lines: Vec<&str> = csv.lines().collect();
        let val = |design: &str, scenario_frag: &str| -> f64 {
            lines
                .iter()
                .find(|l| l.contains(design) && l.contains(scenario_frag))
                .and_then(|l| l.rsplit(',').next())
                .and_then(|v| v.parse().ok())
                .unwrap()
        };
        let best = val("best-mambalaya", "summarize");
        let marca = val("marca-like", "summarize");
        let geens = val("geens-like", "summarize");
        assert!(best > marca, "best {best} vs marca {marca}");
        assert!(best > geens, "best {best} vs geens {geens}");
    }

    #[test]
    fn fig14_traffic_reduction_in_paper_band() {
        let (text, _) = fig14_report(&ModelConfig::mamba_370m(), 4096, 1);
        assert!(text.contains("inter-traffic reduction"));
    }

    #[test]
    fn fig15_runs() {
        let (text, csv) = fig15_report(&cfg(), 1024, 4);
        assert!(text.contains("vs MARCA-like"));
        assert!(csv.lines().count() > 8);
    }
}
