//! Experiment report emitters: one function per paper table/figure (see
//! DESIGN.md §6 for the index). Each returns (human text, CSV).

pub mod figures;
pub mod tables;

pub use figures::{
    fig10_report, fig12_report, fig13_report, fig14_report, fig15_report, fig2_report,
    fig9_report,
};
pub use tables::{table1, table1_report, table2_report, table3_report};
