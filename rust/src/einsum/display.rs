//! Figure-1-style cascade dumps: tabular and Graphviz-dot renderings of
//! a cascade, used by the CLI (`mambalaya cascade --dump`) and examples.

use std::fmt::Write as _;

use super::cascade::Cascade;
use super::spec::Intensity;
use super::tensor::TensorClass;

/// Render the cascade as an aligned table (one row per Einsum).
pub fn cascade_table(c: &Cascade) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:<4} {:<6} {:<28} {:<10} {:<9} {}",
        "#", "name", "output", "kind", "intensity", "inputs"
    );
    for e in c.einsums() {
        let kind = if e.is_gemm_like() {
            "GEMM"
        } else if e.is_recurrent() {
            "recurrent"
        } else {
            match e.op {
                super::spec::OpKind::Unary(_) => "unary",
                _ => "elemwise",
            }
        };
        let intensity = match e.intensity() {
            Intensity::High => "high",
            Intensity::Low => "low",
        };
        let inputs = e
            .inputs
            .iter()
            .map(|o| o.to_string())
            .collect::<Vec<_>>()
            .join(", ");
        let _ = writeln!(
            out,
            "{:<4} {:<6} {:<28} {:<10} {:<9} {}",
            e.id,
            e.name,
            e.output.to_string(),
            kind,
            intensity,
            inputs
        );
    }
    out
}

/// Render the cascade as Graphviz dot, with the paper's color scheme:
/// blue inputs, green GEMM weights, purple recurrent edges (dashed),
/// light-orange elementwise, grey unary.
pub fn cascade_dot(c: &Cascade) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "digraph \"{}\" {{", c.name);
    let _ = writeln!(out, "  rankdir=TB; node [shape=box, style=rounded];");
    for e in c.einsums() {
        let color = if e.is_gemm_like() {
            "#a8d5a2" // green: GEMM with weight
        } else if e.is_recurrent() {
            "#c9b3e6" // purple: recurrent access
        } else if matches!(e.op, super::spec::OpKind::Unary(_)) {
            "#b8b8b8" // grey: unary/nonlinear
        } else {
            "#ffd9a8" // light orange: elementwise/broadcast
        };
        let _ = writeln!(
            out,
            "  e{} [label=\"{} {}\", fillcolor=\"{}\", style=\"rounded,filled\"];",
            e.id, e.id, e.output, color
        );
    }
    for t in c.input_tensors() {
        if t.class == TensorClass::Input {
            let _ = writeln!(
                out,
                "  \"{}\" [shape=box, fillcolor=\"#a8c8e8\", style=\"rounded,filled\"];",
                t.name
            );
        }
    }
    let producers = c.producers();
    for e in c.einsums() {
        for name in e.input_names() {
            if !producers.contains_key(name) {
                // external input or weight: draw only true inputs
                if let Some(op) = e.operand(name) {
                    if op.tensor.class == TensorClass::Input {
                        let _ = writeln!(out, "  \"{}\" -> e{};", name, e.id);
                    }
                }
            }
        }
    }
    for edge in c.edges() {
        let style = if edge.recurrent { " [style=dashed]" } else { "" };
        let _ = writeln!(out, "  e{} -> e{}{};", edge.from, edge.to, style);
    }
    let _ = writeln!(out, "}}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cascade::mamba1;
    use crate::cascade::config::ModelConfig;

    #[test]
    fn table_has_all_rows() {
        let c = mamba1::build(&ModelConfig::mamba_370m(), 64, 1);
        let table = cascade_table(&c);
        // Header + 24 rows.
        assert_eq!(table.lines().count(), 25);
        assert!(table.contains("LEX"));
    }

    #[test]
    fn dot_is_wellformed() {
        let c = mamba1::build(&ModelConfig::mamba_370m(), 64, 1);
        let dot = cascade_dot(&c);
        assert!(dot.starts_with("digraph"));
        assert!(dot.trim_end().ends_with('}'));
        assert!(dot.contains("style=dashed")); // recurrent H edge
    }
}
