//! Iteration-space algebra.
//!
//! Fusion classification (paper §III-C) is purely a relation between the
//! upstream and downstream Einsums' iteration spaces:
//!
//! * `IS_up ≡ IS_dwn`  → Rank-Isomorphic (RI)
//! * `IS_up ⊃ IS_dwn`  → Rank-Subsetted (RSb)
//! * `IS_up ⊂ IS_dwn`  → Rank-Supersetted (RSp)
//! * otherwise (⊥)      → Rank-Disjointed (RD)
//!
//! An iteration space here is the *set of rank names* (with extents)
//! spanned by an Einsum — output ranks plus reduction ranks. Set
//! semantics over rank names match the paper's usage ("the downstream
//! contains a rank (P) absent from the upstream").

use std::collections::BTreeSet;
use std::fmt;

use super::rank::Rank;

/// An iteration space: a set of named ranks.
///
/// Internally kept sorted by rank name for canonical comparisons and
/// deterministic display.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct IterSpace {
    ranks: Vec<Rank>,
}

/// Relation between two iteration spaces (paper Figure 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SpaceRelation {
    /// Identical rank sets.
    Equal,
    /// `self ⊃ other` (proper superset).
    Superset,
    /// `self ⊂ other` (proper subset).
    Subset,
    /// Each has ranks absent from the other (the paper writes `⊥`).
    Disjoint,
}

impl fmt::Display for SpaceRelation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            SpaceRelation::Equal => "≡",
            SpaceRelation::Superset => "⊃",
            SpaceRelation::Subset => "⊂",
            SpaceRelation::Disjoint => "⊥",
        };
        write!(f, "{s}")
    }
}

impl IterSpace {
    /// Build from a rank list; deduplicates by name and sorts.
    pub fn new(mut ranks: Vec<Rank>) -> Self {
        ranks.sort_by(|a, b| a.name.cmp(&b.name));
        ranks.dedup_by(|a, b| a.name == b.name);
        IterSpace { ranks }
    }

    /// The empty iteration space.
    pub fn empty() -> Self {
        IterSpace { ranks: Vec::new() }
    }

    pub fn is_empty(&self) -> bool {
        self.ranks.is_empty()
    }

    /// Ranks, sorted by name.
    pub fn ranks(&self) -> &[Rank] {
        &self.ranks
    }

    /// Sorted rank-name set.
    pub fn names(&self) -> BTreeSet<&str> {
        self.ranks.iter().map(|r| r.name.as_str()).collect()
    }

    /// Rank names as a plain Vec (sorted), convenient for asserts.
    pub fn rank_names(&self) -> Vec<&str> {
        self.ranks.iter().map(|r| r.name.as_str()).collect()
    }

    /// Number of points = product of extents (1 for the empty space).
    pub fn points(&self) -> u64 {
        self.ranks.iter().map(|r| r.extent).product()
    }

    /// Does this space contain the named rank?
    pub fn contains(&self, name: &str) -> bool {
        self.ranks.iter().any(|r| r.name == name)
    }

    /// Look up a rank by name.
    pub fn rank(&self, name: &str) -> Option<&Rank> {
        self.ranks.iter().find(|r| r.name == name)
    }

    /// Set intersection (by rank name; extents taken from `self`).
    pub fn intersect(&self, other: &IterSpace) -> IterSpace {
        let theirs = other.names();
        IterSpace::new(
            self.ranks.iter().filter(|r| theirs.contains(r.name.as_str())).cloned().collect(),
        )
    }

    /// Set union (extents from `self` win on collision).
    pub fn union(&self, other: &IterSpace) -> IterSpace {
        let mut ranks = self.ranks.clone();
        let mine = self.names();
        for r in &other.ranks {
            if !mine.contains(r.name.as_str()) {
                ranks.push(r.clone());
            }
        }
        IterSpace::new(ranks)
    }

    /// Ranks in `self` but not in `other`.
    pub fn difference(&self, other: &IterSpace) -> IterSpace {
        let theirs = other.names();
        IterSpace::new(
            self.ranks.iter().filter(|r| !theirs.contains(r.name.as_str())).cloned().collect(),
        )
    }

    /// `self ⊆ other` (non-strict).
    pub fn is_subset_of(&self, other: &IterSpace) -> bool {
        self.names().is_subset(&other.names())
    }

    /// `self ⊇ other` (non-strict).
    pub fn is_superset_of(&self, other: &IterSpace) -> bool {
        other.is_subset_of(self)
    }

    /// Classify the relation of `self` (upstream) to `other` (downstream).
    pub fn relation(&self, other: &IterSpace) -> SpaceRelation {
        let a = self.names();
        let b = other.names();
        if a == b {
            SpaceRelation::Equal
        } else if b.is_subset(&a) {
            SpaceRelation::Superset
        } else if a.is_subset(&b) {
            SpaceRelation::Subset
        } else {
            SpaceRelation::Disjoint
        }
    }

    /// True if any rank is generational.
    pub fn has_generational(&self) -> bool {
        self.ranks.iter().any(|r| r.is_generational())
    }
}

impl fmt::Display for IterSpace {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let names: Vec<String> = self.ranks.iter().map(|r| r.to_string()).collect();
        write!(f, "{{{}}}", names.join(", "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sp(names: &[(&str, u64)]) -> IterSpace {
        IterSpace::new(names.iter().map(|(n, e)| Rank::new(*n, *e)).collect())
    }

    #[test]
    fn relations_match_paper_figure3() {
        let mk = sp(&[("M", 4), ("K", 8)]);
        let m = sp(&[("M", 4)]);
        let mp = sp(&[("M", 4), ("P", 2)]);
        // RI: identical
        assert_eq!(mk.relation(&mk), SpaceRelation::Equal);
        // RSb: upstream {M,K} ⊃ downstream {M}
        assert_eq!(mk.relation(&m), SpaceRelation::Superset);
        // RSp: upstream {M} ⊂ downstream {M,P}
        assert_eq!(m.relation(&mp), SpaceRelation::Subset);
        // RD: {M,K} vs {M,P}
        assert_eq!(mk.relation(&mp), SpaceRelation::Disjoint);
    }

    #[test]
    fn intersect_union_difference() {
        let a = sp(&[("M", 4), ("N", 5), ("K", 8)]);
        let b = sp(&[("M", 4), ("N", 5), ("P", 3)]);
        assert_eq!(a.intersect(&b).rank_names(), vec!["M", "N"]);
        assert_eq!(a.union(&b).rank_names(), vec!["K", "M", "N", "P"]);
        assert_eq!(a.difference(&b).rank_names(), vec!["K"]);
        assert_eq!(a.points(), 4 * 5 * 8);
    }

    #[test]
    fn dedup_and_canonical_order() {
        let s = IterSpace::new(vec![Rank::new("B", 2), Rank::new("A", 3), Rank::new("B", 2)]);
        assert_eq!(s.rank_names(), vec!["A", "B"]);
    }

    #[test]
    fn empty_space() {
        let e = IterSpace::empty();
        assert!(e.is_empty());
        assert_eq!(e.points(), 1);
        let a = sp(&[("M", 4)]);
        // Empty ⊂ anything non-empty.
        assert_eq!(e.relation(&a), SpaceRelation::Subset);
        assert_eq!(a.relation(&e), SpaceRelation::Superset);
    }

    #[test]
    fn generational_flag() {
        let g = IterSpace::new(vec![Rank::generational("I", 7), Rank::new("D", 3)]);
        assert!(g.has_generational());
        assert!(!sp(&[("D", 3)]).has_generational());
    }
}
