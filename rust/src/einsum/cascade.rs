//! Cascades: ordered DAGs of extended Einsums connected by
//! producer→consumer tensor edges (paper Figure 1 / Figure 9).

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;

use anyhow::{bail, Result};

use super::spec::EinsumSpec;
use super::tensor::{TensorClass, TensorSpec};

/// A producer→consumer dependency edge: Einsum `from` produces tensor
/// `tensor`, Einsum `to` consumes it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Edge {
    pub from: usize,
    pub to: usize,
    pub tensor: String,
    /// True when the consumer reads a previous generation (`H[i-1]`) or
    /// a window — drawn dashed in paper Figure 9.
    pub recurrent: bool,
}

/// An ordered cascade of Einsums (a sequential DAG, as Algorithm 1
/// assumes).
#[derive(Debug, Clone)]
pub struct Cascade {
    pub name: String,
    einsums: Vec<EinsumSpec>,
}

impl Cascade {
    pub fn new(name: impl Into<String>, einsums: Vec<EinsumSpec>) -> Self {
        Cascade { name: name.into(), einsums }
    }

    pub fn einsums(&self) -> &[EinsumSpec] {
        &self.einsums
    }

    pub fn len(&self) -> usize {
        self.einsums.len()
    }

    pub fn is_empty(&self) -> bool {
        self.einsums.is_empty()
    }

    /// Einsum by cascade id (the paper's yellow number).
    pub fn by_id(&self, id: usize) -> Option<&EinsumSpec> {
        self.einsums.iter().find(|e| e.id == id)
    }

    /// Einsum by output-tensor name.
    pub fn by_name(&self, name: &str) -> Option<&EinsumSpec> {
        self.einsums.iter().find(|e| e.name == name)
    }

    /// Map tensor-name → producing Einsum id.
    pub fn producers(&self) -> BTreeMap<&str, usize> {
        self.einsums.iter().map(|e| (e.output.name.as_str(), e.id)).collect()
    }

    /// Map tensor-name → consuming Einsum ids (in cascade order).
    pub fn consumers(&self) -> BTreeMap<&str, Vec<usize>> {
        let mut map: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
        for e in &self.einsums {
            for name in e.input_names() {
                map.entry(name).or_default().push(e.id);
            }
        }
        map
    }

    /// All producer→consumer edges.
    pub fn edges(&self) -> Vec<Edge> {
        let producers = self.producers();
        let mut edges = Vec::new();
        for e in &self.einsums {
            for op in &e.inputs {
                if let Some(&from) = producers.get(op.tensor.name.as_str()) {
                    // Recurrent self-edges (H consumed at i-1 by the same
                    // or an earlier Einsum) are kept: they are the dashed
                    // edges of Figure 9.
                    let recurrent = op.is_recurrent();
                    if from != e.id || recurrent {
                        edges.push(Edge {
                            from,
                            to: e.id,
                            tensor: op.tensor.name.clone(),
                            recurrent,
                        });
                    }
                }
            }
        }
        edges
    }

    /// Tensors read by some Einsum but produced by none, excluding
    /// weights: the cascade's true inputs (blue in Figure 1).
    pub fn input_tensors(&self) -> Vec<&TensorSpec> {
        let produced: BTreeSet<&str> =
            self.einsums.iter().map(|e| e.output.name.as_str()).collect();
        let mut seen = BTreeSet::new();
        let mut out = Vec::new();
        for e in &self.einsums {
            for op in &e.inputs {
                let t = &op.tensor;
                if !produced.contains(t.name.as_str())
                    && t.class != TensorClass::Weight
                    && seen.insert(t.name.as_str())
                {
                    out.push(t);
                }
            }
        }
        out
    }

    /// All weight tensors (deduplicated).
    pub fn weight_tensors(&self) -> Vec<&TensorSpec> {
        let mut seen = BTreeSet::new();
        let mut out = Vec::new();
        for e in &self.einsums {
            for op in &e.inputs {
                if op.tensor.class == TensorClass::Weight && seen.insert(op.tensor.name.as_str())
                {
                    out.push(&op.tensor);
                }
            }
        }
        out
    }

    /// Intermediate tensors: produced by one Einsum and consumed by at
    /// least one other.
    pub fn intermediate_tensors(&self) -> Vec<&TensorSpec> {
        let consumers = self.consumers();
        self.einsums
            .iter()
            .filter(|e| consumers.contains_key(e.output.name.as_str()))
            .map(|e| &e.output)
            .collect()
    }

    /// Liveness distance of each intermediate: (tensor, producer id,
    /// last-consumer id). Long distances (e.g. RX: 8 → 23) are the
    /// fusion-hostile intermediates the paper calls out.
    pub fn liveness(&self) -> Vec<(String, usize, usize)> {
        let consumers = self.consumers();
        let mut out = Vec::new();
        for e in &self.einsums {
            if let Some(cs) = consumers.get(e.output.name.as_str()) {
                if let Some(&last) = cs.iter().max() {
                    out.push((e.output.name.clone(), e.id, last));
                }
            }
        }
        out
    }

    /// Count of GEMM-like Einsums (paper: 7 of 24 for Mamba-1).
    pub fn gemm_count(&self) -> usize {
        self.einsums.iter().filter(|e| e.is_gemm_like()).count()
    }

    /// Validate structural invariants:
    /// * ids are unique and match cascade order (sequential DAG);
    /// * every non-recurrent intermediate operand is produced earlier;
    /// * recurrent operands reference generational ranks only;
    /// * output names are unique;
    /// * rank extents agree everywhere a rank name appears.
    pub fn validate(&self) -> Result<()> {
        let mut seen_out: BTreeSet<&str> = BTreeSet::new();
        let mut extents: BTreeMap<&str, u64> = BTreeMap::new();
        let mut prev_id = 0usize;
        for e in &self.einsums {
            if e.id <= prev_id {
                bail!("einsum ids must be strictly increasing: #{} after #{}", e.id, prev_id);
            }
            prev_id = e.id;
            if !seen_out.insert(e.output.name.as_str()) {
                bail!("duplicate output tensor {}", e.output.name);
            }
            for r in e.output.ranks.iter().chain(e.reduction_ranks.iter()) {
                if let Some(&ex) = extents.get(r.name.as_str()) {
                    if ex != r.extent {
                        bail!("rank {} has conflicting extents {} vs {}", r.name, ex, r.extent);
                    }
                } else {
                    extents.insert(r.name.as_str(), r.extent);
                }
            }
        }
        // Dataflow: non-recurrent intermediates must be produced by an
        // earlier Einsum; recurrent reads may reference later producers
        // (previous-generation values).
        let producers = self.producers();
        for e in &self.einsums {
            for op in &e.inputs {
                let t = &op.tensor;
                match producers.get(t.name.as_str()) {
                    Some(&pid) => {
                        if pid >= e.id && !op.is_recurrent() {
                            bail!(
                                "einsum #{} reads {} produced later (#{}) without recurrence",
                                e.id,
                                t.name,
                                pid
                            );
                        }
                    }
                    None => {
                        if t.class == TensorClass::Intermediate {
                            bail!(
                                "einsum #{} reads intermediate {} with no producer",
                                e.id,
                                t.name
                            );
                        }
                    }
                }
                for (rank, acc) in t.ranks.iter().zip(&op.accesses) {
                    if acc.is_recurrent() && !rank.is_generational() {
                        bail!(
                            "einsum #{} has recurrent access on non-generational rank {}",
                            e.id,
                            rank.name
                        );
                    }
                }
            }
        }
        Ok(())
    }
}

/// Precomputed lookup structures over a cascade.
///
/// `Cascade::producers()`/`consumers()` rebuild maps on every call;
/// the analytical model's inner loop (one `evaluate` per design point ×
/// thousands of design points in a DSE sweep) needs them memoized —
/// build once per cascade and share (§Perf, EXPERIMENTS.md).
#[derive(Debug, Clone)]
pub struct CascadeIndex {
    /// tensor name → producing Einsum id.
    pub producers: BTreeMap<String, usize>,
    /// tensor name → consuming Einsum ids (cascade order).
    pub consumers: BTreeMap<String, Vec<usize>>,
    /// Tensors shared between Einsums (produced in-cascade, or consumed
    /// by more than one Einsum) — the Table-I "inter-Einsum" set.
    pub shared: BTreeSet<String>,
}

impl CascadeIndex {
    pub fn new(c: &Cascade) -> CascadeIndex {
        let producers: BTreeMap<String, usize> =
            c.producers().into_iter().map(|(k, v)| (k.to_string(), v)).collect();
        let consumers: BTreeMap<String, Vec<usize>> =
            c.consumers().into_iter().map(|(k, v)| (k.to_string(), v)).collect();
        let mut shared: BTreeSet<String> = producers.keys().cloned().collect();
        for (name, cs) in &consumers {
            if cs.len() > 1 {
                shared.insert(name.clone());
            }
        }
        CascadeIndex { producers, consumers, shared }
    }

    /// Is this tensor inter-Einsum ("shared") in the Table-I sense?
    pub fn is_shared(&self, name: &str) -> bool {
        self.shared.contains(name)
    }

    /// Consumers of a tensor (empty slice when none).
    pub fn consumers_of(&self, name: &str) -> &[usize] {
        self.consumers.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }
}

impl fmt::Display for Cascade {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "cascade {} ({} einsums, {} GEMM-like)", self.name, self.len(), self.gemm_count())?;
        for e in &self.einsums {
            writeln!(f, "  {e}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::rank::{Rank, RankAccess};
    use crate::einsum::spec::{OpKind, UnaryFn};
    use crate::einsum::tensor::{DType, Operand, TensorClass};

    fn tiny_cascade() -> Cascade {
        let i = Rank::new("I", 8);
        let k = Rank::new("K", 64);
        let x = TensorSpec::new("X", vec![i.clone(), k.clone()], DType::F16, TensorClass::Input);
        let w = TensorSpec::new("W", vec![k.clone()], DType::F16, TensorClass::Weight);
        let z = TensorSpec::new("Z", vec![i.clone()], DType::F16, TensorClass::Intermediate);
        let y = TensorSpec::new("Y", vec![i.clone()], DType::F16, TensorClass::Output);
        let e1 = EinsumSpec::new(
            1,
            "Z",
            z.clone(),
            vec![Operand::plain(x), Operand::plain(w)],
            vec![k],
            OpKind::MulAcc,
        );
        let e2 = EinsumSpec::new(
            2,
            "Y",
            y,
            vec![Operand::plain(z)],
            vec![],
            OpKind::Unary(UnaryFn::Exp),
        );
        Cascade::new("tiny", vec![e1, e2])
    }

    #[test]
    fn edges_and_maps() {
        let c = tiny_cascade();
        assert!(c.validate().is_ok());
        let edges = c.edges();
        assert_eq!(edges.len(), 1);
        assert_eq!(edges[0].from, 1);
        assert_eq!(edges[0].to, 2);
        assert_eq!(edges[0].tensor, "Z");
        assert_eq!(c.producers().get("Z"), Some(&1));
        assert_eq!(c.consumers().get("Z"), Some(&vec![2]));
    }

    #[test]
    fn classification() {
        let c = tiny_cascade();
        assert_eq!(c.gemm_count(), 1);
        assert_eq!(c.input_tensors().len(), 1);
        assert_eq!(c.intermediate_tensors().len(), 1);
        assert_eq!(c.liveness(), vec![("Z".to_string(), 1, 2)]);
    }

    #[test]
    fn validation_rejects_missing_producer() {
        let i = Rank::new("I", 8);
        let ghost =
            TensorSpec::new("G", vec![i.clone()], DType::F16, TensorClass::Intermediate);
        let y = TensorSpec::new("Y", vec![i], DType::F16, TensorClass::Output);
        let e = EinsumSpec::new(1, "Y", y, vec![Operand::plain(ghost)], vec![], OpKind::Mul);
        let c = Cascade::new("bad", vec![e]);
        assert!(c.validate().is_err());
    }

    #[test]
    fn validation_rejects_conflicting_extents() {
        let ia = Rank::new("I", 8);
        let ib = Rank::new("I", 16);
        let x = TensorSpec::new("X", vec![ia.clone()], DType::F16, TensorClass::Input);
        let z = TensorSpec::new("Z", vec![ia], DType::F16, TensorClass::Intermediate);
        let y = TensorSpec::new("Y", vec![ib], DType::F16, TensorClass::Output);
        let e1 = EinsumSpec::new(1, "Z", z.clone(), vec![Operand::plain(x)], vec![], OpKind::Mul);
        let e2 = EinsumSpec::new(2, "Y", y, vec![Operand::plain(z)], vec![], OpKind::Mul);
        let c = Cascade::new("bad", vec![e1, e2]);
        assert!(c.validate().is_err());
    }

    #[test]
    fn recurrent_access_needs_generational_rank() {
        let i = Rank::new("I", 8); // spatial, not generational
        let h = TensorSpec::new("H", vec![i.clone()], DType::F16, TensorClass::Recurrent);
        let hh = TensorSpec::new("HH", vec![i], DType::F16, TensorClass::Intermediate);
        let e = EinsumSpec::new(
            1,
            "HH",
            hh,
            vec![Operand::with_access(h, "I", RankAccess::Lagged { offset: 1 })],
            vec![],
            OpKind::Mul,
        );
        let c = Cascade::new("bad", vec![e]);
        assert!(c.validate().is_err());
    }
}
