//! Einsum specifications: one extended Einsum = output tensor, operand
//! list, compute kind, and (derived) iteration space.
//!
//! The compute kinds mirror the paper's Figure 1 legend: GEMM-like
//! (green), elementwise/broadcast (light orange), unary nonlinearities
//! (dark grey), recurrent updates (purple edges).

use std::fmt;

use super::iterspace::IterSpace;
use super::rank::Rank;
use super::tensor::{Operand, TensorSpec};

/// The scalar operation applied inside an Einsum.
///
/// Extended Einsums (EDGE) allow arbitrary user-defined per-element
/// functions in addition to the (×, +) semiring; Mamba needs exp, log,
/// sqrt/rsqrt, SiLU, softplus, sigmoid (paper §II-A).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// Multiply-accumulate over reduction ranks (GEMM/GEMV/dot).
    MulAcc,
    /// Pure elementwise multiply (Hadamard / broadcast scaling).
    Mul,
    /// Elementwise add.
    Add,
    /// Fused multiply-add of two operands into the output (`a*b + c`).
    MulAdd,
    /// A user-defined unary nonlinearity applied elementwise.
    Unary(UnaryFn),
    /// Elementwise multiply followed by a unary on one operand
    /// (e.g. `SD * SiLU(RX)`), counted as two pipeline ops.
    MulUnary(UnaryFn),
}

/// User-defined unary functions used by Mamba (paper §II-A.a).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UnaryFn {
    Exp,
    Log,
    Sqrt,
    Rsqrt,
    SiLU,
    Softplus,
    Sigmoid,
    Square,
    Recip,
    Identity,
}

impl fmt::Display for UnaryFn {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            UnaryFn::Exp => "exp",
            UnaryFn::Log => "log",
            UnaryFn::Sqrt => "sqrt",
            UnaryFn::Rsqrt => "rsqrt",
            UnaryFn::SiLU => "silu",
            UnaryFn::Softplus => "softplus",
            UnaryFn::Sigmoid => "sigmoid",
            UnaryFn::Square => "square",
            UnaryFn::Recip => "recip",
            UnaryFn::Identity => "id",
        };
        write!(f, "{s}")
    }
}

impl OpKind {
    /// True for GEMM-like Einsums: a MulAcc with at least one
    /// non-trivial reduction rank (checked at the [`EinsumSpec`] level;
    /// here we just classify the scalar op).
    pub fn is_mulacc(&self) -> bool {
        matches!(self, OpKind::MulAcc)
    }

    /// Scalar ops per output point contributed by the op itself
    /// (excluding reduction): used by the cost model for the
    /// low-intensity functional units.
    pub fn elementwise_ops(&self) -> u64 {
        match self {
            OpKind::MulAcc => 0, // counted via reduction MACs
            OpKind::Mul | OpKind::Add => 1,
            OpKind::MulAdd => 2,
            OpKind::Unary(_) => 1,
            OpKind::MulUnary(_) => 2,
        }
    }
}

/// Intensity class used for binding decisions (paper §V: PEs contain
/// both high-intensity MACC units and low-intensity nonlinear units).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Intensity {
    /// GEMM-like: maps to the 2D systolic mode.
    High,
    /// Elementwise / broadcast / unary: maps to 1D modes.
    Low,
}

/// One extended Einsum in a cascade.
#[derive(Debug, Clone, PartialEq)]
pub struct EinsumSpec {
    /// Position in the cascade (paper Figure 1 yellow number, 1-based).
    pub id: usize,
    /// Human name, e.g. `"TX"` — matches the output tensor name.
    pub name: String,
    /// Output tensor.
    pub output: TensorSpec,
    /// Operand tensors with access patterns.
    pub inputs: Vec<Operand>,
    /// Ranks reduced over (present in inputs, absent from output).
    pub reduction_ranks: Vec<Rank>,
    /// Scalar operation.
    pub op: OpKind,
}

impl EinsumSpec {
    pub fn new(
        id: usize,
        name: impl Into<String>,
        output: TensorSpec,
        inputs: Vec<Operand>,
        reduction_ranks: Vec<Rank>,
        op: OpKind,
    ) -> Self {
        EinsumSpec { id, name: name.into(), output, inputs, reduction_ranks, op }
    }

    /// The full iteration space: output ranks ∪ reduction ranks.
    pub fn iteration_space(&self) -> IterSpace {
        let mut ranks = self.output.ranks.clone();
        for r in &self.reduction_ranks {
            if !ranks.iter().any(|x| x.name == r.name) {
                ranks.push(r.clone());
            }
        }
        IterSpace::new(ranks)
    }

    /// Minimum reduction extent for a contraction to count as GEMM-like.
    /// Smaller reductions (the N=16 SSM readout, the 4-tap conv) never
    /// reach the compute-bound region and are treated as low-intensity
    /// work, matching both the paper's "7 of 24 GEMM-like" Mamba count
    /// and FuseMax's "6 of 8" Transformer count.
    pub const GEMM_MIN_REDUCTION: u64 = 32;

    /// GEMM-like: a true tensor *contraction* — multiply-accumulate of
    /// at least two operands over a sufficiently large reduction rank,
    /// with no recurrent/windowed access.
    ///
    /// Excludes single-operand reductions (NUM, Einsum 3), the depthwise
    /// causal conv (Einsum 9, windowed 4-tap filter) and the skinny N=16
    /// SSM readout (Einsum 21).
    pub fn is_gemm_like(&self) -> bool {
        self.op.is_mulacc()
            && self.inputs.len() >= 2
            && self.reduction_ranks.iter().any(|r| r.extent >= Self::GEMM_MIN_REDUCTION)
            && !self.is_recurrent()
    }

    /// Intensity class for binding (paper §V).
    pub fn intensity(&self) -> Intensity {
        if self.is_gemm_like() { Intensity::High } else { Intensity::Low }
    }

    /// True if any operand access is recurrent along a generational rank.
    pub fn is_recurrent(&self) -> bool {
        self.inputs.iter().any(|o| o.is_recurrent())
    }

    /// Total scalar operations (for roofline FLOP counts).
    ///
    /// GEMM-like: 2 × (points in the full iteration space) — one mul +
    /// one add per MAC. Elementwise: `elementwise_ops` per output point.
    /// Nonlinear unaries count 1 op/point (they occupy the pipelined
    /// functional unit for one issue slot; paper §V-A).
    pub fn flops(&self) -> u64 {
        if self.op.is_mulacc() {
            2 * self.iteration_space().points()
        } else {
            self.op.elementwise_ops() * self.output.elements()
        }
    }

    /// Names of input tensors (deduplicated, in order).
    pub fn input_names(&self) -> Vec<&str> {
        let mut out: Vec<&str> = Vec::new();
        for op in &self.inputs {
            let n = op.tensor.name.as_str();
            if !out.contains(&n) {
                out.push(n);
            }
        }
        out
    }

    /// Find an operand by tensor name.
    pub fn operand(&self, name: &str) -> Option<&Operand> {
        self.inputs.iter().find(|o| o.tensor.name == name)
    }
}

impl fmt::Display for EinsumSpec {
    /// `#id Out[ranks] = op(inputs) / Σ red-ranks`
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let ins: Vec<String> = self.inputs.iter().map(|o| o.to_string()).collect();
        write!(f, "#{:<2} {} = {:?}({})", self.id, self.output, self.op, ins.join(", "))?;
        if !self.reduction_ranks.is_empty() {
            let rr: Vec<&str> = self.reduction_ranks.iter().map(|r| r.name.as_str()).collect();
            write!(f, "  / Σ {}", rr.join(","))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::rank::Rank;
    use crate::einsum::tensor::{DType, TensorClass};

    fn gemm() -> EinsumSpec {
        let i = Rank::new("I", 32);
        let e = Rank::new("E", 64);
        let d = Rank::new("D", 128);
        let out =
            TensorSpec::new("TX", vec![i.clone(), d.clone()], DType::F16, TensorClass::Intermediate);
        let a = TensorSpec::new("GX", vec![i, e.clone()], DType::F16, TensorClass::Intermediate);
        let w = TensorSpec::new("W", vec![e.clone(), d], DType::F16, TensorClass::Weight);
        EinsumSpec::new(
            7,
            "TX",
            out,
            vec![Operand::plain(a), Operand::plain(w)],
            vec![e],
            OpKind::MulAcc,
        )
    }

    #[test]
    fn gemm_classification() {
        let e = gemm();
        assert!(e.is_gemm_like());
        assert_eq!(e.intensity(), Intensity::High);
        assert!(!e.is_recurrent());
    }

    #[test]
    fn iteration_space_includes_reduction() {
        let e = gemm();
        let is = e.iteration_space();
        // IterSpace is canonically name-sorted.
        assert_eq!(is.rank_names(), vec!["D", "E", "I"]);
        assert_eq!(is.points(), 32 * 128 * 64);
    }

    #[test]
    fn flop_count() {
        let e = gemm();
        assert_eq!(e.flops(), 2 * 32 * 64 * 128);
    }

    #[test]
    fn elementwise_flops() {
        let i = Rank::new("I", 8);
        let out = TensorSpec::new("Y", vec![i.clone()], DType::F16, TensorClass::Intermediate);
        let a = TensorSpec::new("A", vec![i], DType::F16, TensorClass::Intermediate);
        let e = EinsumSpec::new(
            1,
            "Y",
            out,
            vec![Operand::plain(a)],
            vec![],
            OpKind::Unary(UnaryFn::SiLU),
        );
        assert!(!e.is_gemm_like());
        assert_eq!(e.flops(), 8);
        assert_eq!(e.intensity(), Intensity::Low);
    }
}
