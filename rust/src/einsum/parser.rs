//! A small text format for extended-Einsum cascades — lets users apply
//! the fusion taxonomy to *their own* workloads (Table II's "any
//! workload expressible as an EDGE cascade"), from the CLI:
//! `mambalaya fuse --cascade my_workload.einsum`.
//!
//! Grammar (one statement per line; `#` comments):
//!
//! ```text
//! rank I* = 1024          # '*' marks a generational rank
//! rank E  = 512
//! input  X[I,E]           # workload input tensor
//! weight W[E,D]
//! Z[I,D] = X[I,E] * W[E,D] / sum E          # contraction
//! Y[I,D] = exp(Z[I,D])                      # unary op
//! H[I,D] = A[I,D] * H[I-1,D]                # lagged (recurrent) access
//! C[I,D] = T[I-j:4,D] * K[D]                # windowed access (window 4)
//! ```
//!
//! The op between operands is always elementwise multiply-accumulate
//! semantics: `* ... / sum R1,R2` is a contraction over the listed
//! ranks; without `/ sum` it is an elementwise/broadcast product; a
//! single operand wrapped in a function name is a unary op; `+` products
//! are adds.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use super::cascade::Cascade;
use super::rank::{Rank, RankAccess};
use super::spec::{EinsumSpec, OpKind, UnaryFn};
use super::tensor::{DType, Operand, TensorClass, TensorSpec};

/// Parse a cascade from the text format.
pub fn parse_cascade(name: &str, text: &str) -> Result<Cascade> {
    let mut ranks: BTreeMap<String, Rank> = BTreeMap::new();
    let mut declared: BTreeMap<String, TensorClass> = BTreeMap::new();
    let mut produced: BTreeMap<String, TensorSpec> = BTreeMap::new();
    let mut einsums: Vec<EinsumSpec> = Vec::new();

    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let ctx = || format!("line {}: `{}`", lineno + 1, raw.trim());

        if let Some(rest) = line.strip_prefix("rank ") {
            let (lhs, rhs) = rest.split_once('=').ok_or_else(|| anyhow!("{}: expected `rank NAME = extent`", ctx()))?;
            let mut rname = lhs.trim().to_string();
            let generational = rname.ends_with('*');
            if generational {
                rname.pop();
            }
            let extent: u64 = rhs.trim().parse().with_context(ctx)?;
            let rank = if generational {
                Rank::generational(rname.trim(), extent)
            } else {
                Rank::new(rname.trim(), extent)
            };
            ranks.insert(rank.name.clone(), rank);
        } else if let Some(rest) = line.strip_prefix("input ") {
            let t = parse_tensor_decl(rest.trim(), &ranks, TensorClass::Input).with_context(ctx)?;
            declared.insert(t.name.clone(), TensorClass::Input);
            produced.insert(t.name.clone(), t);
        } else if let Some(rest) = line.strip_prefix("weight ") {
            let t =
                parse_tensor_decl(rest.trim(), &ranks, TensorClass::Weight).with_context(ctx)?;
            declared.insert(t.name.clone(), TensorClass::Weight);
            produced.insert(t.name.clone(), t);
        } else {
            // Einsum statement: `Out[ranks] = expr [/ sum R,...]`
            let (lhs, rhs) =
                line.split_once('=').ok_or_else(|| anyhow!("{}: expected `=`", ctx()))?;
            let (expr, sums) = match rhs.split_once("/ sum") {
                Some((e, s)) => (e.trim(), Some(s.trim())),
                None => (rhs.trim(), None),
            };
            let reduction_ranks: Vec<Rank> = match sums {
                None => Vec::new(),
                Some(list) => list
                    .split(',')
                    .map(|r| {
                        ranks
                            .get(r.trim())
                            .cloned()
                            .ok_or_else(|| anyhow!("{}: unknown rank {}", ctx(), r.trim()))
                    })
                    .collect::<Result<_>>()?,
            };

            // Unary form: f(T[...]) ?
            let (op_kind, operand_texts): (OpKind, Vec<&str>) =
                if let Some((fname, inner)) = expr.split_once('(') {
                    let fname = fname.trim();
                    if !fname.is_empty() && !fname.contains(['[', '*', '+']) {
                        let inner = inner.trim().strip_suffix(')').ok_or_else(|| {
                            anyhow!("{}: unterminated function call", ctx())
                        })?;
                        (OpKind::Unary(parse_unary(fname).with_context(ctx)?), vec![inner])
                    } else {
                        parse_product(expr, !reduction_ranks.is_empty())?
                    }
                } else {
                    parse_product(expr, !reduction_ranks.is_empty())?
                };

            // Output tensor: ranks from the bracket list.
            let out_name = lhs.trim();
            let out = parse_tensor_ref(out_name, &ranks)
                .with_context(ctx)?
                .0;
            let out = TensorSpec::new(
                out.name.clone(),
                out.ranks.clone(),
                DType::F16,
                TensorClass::Intermediate,
            );

            let mut inputs = Vec::new();
            for otext in operand_texts {
                let (mut t, accesses) = parse_tensor_ref(otext.trim(), &ranks).with_context(ctx)?;
                // Classification: declared inputs/weights keep their
                // class; self-reference (recurrent) keeps Recurrent.
                t.class = if t.name == out.name {
                    TensorClass::Recurrent
                } else if let Some(&c) = declared.get(&t.name) {
                    c
                } else if produced.contains_key(&t.name) {
                    TensorClass::Intermediate
                } else {
                    bail!("{}: tensor {} neither declared nor produced", ctx(), t.name);
                };
                inputs.push(Operand { tensor: t, accesses });
            }
            // A self-referential output is a Recurrent tensor.
            let out_class = if inputs.iter().any(|o| o.tensor.name == out.name) {
                TensorClass::Recurrent
            } else {
                TensorClass::Intermediate
            };
            let out = TensorSpec::new(out.name.clone(), out.ranks, DType::F16, out_class);

            produced.insert(out.name.clone(), out.clone());
            let id = einsums.len() + 1;
            einsums.push(EinsumSpec::new(
                id,
                out.name.clone(),
                out,
                inputs,
                reduction_ranks,
                op_kind,
            ));
        }
    }
    let c = Cascade::new(name, einsums);
    c.validate()?;
    Ok(c)
}

fn parse_unary(name: &str) -> Result<UnaryFn> {
    Ok(match name {
        "exp" => UnaryFn::Exp,
        "log" => UnaryFn::Log,
        "sqrt" => UnaryFn::Sqrt,
        "rsqrt" => UnaryFn::Rsqrt,
        "silu" => UnaryFn::SiLU,
        "softplus" => UnaryFn::Softplus,
        "sigmoid" => UnaryFn::Sigmoid,
        "square" => UnaryFn::Square,
        "recip" => UnaryFn::Recip,
        "id" => UnaryFn::Identity,
        other => bail!("unknown unary function {other}"),
    })
}

/// Split a product expression into operands; decide the op kind.
fn parse_product(expr: &str, has_reduction: bool) -> Result<(OpKind, Vec<&str>)> {
    if expr.contains('+') {
        let parts: Vec<&str> = expr.split('+').map(|s| s.trim()).collect();
        return Ok((OpKind::Add, parts));
    }
    let parts: Vec<&str> = expr.split('*').map(|s| s.trim()).collect();
    let kind = if has_reduction { OpKind::MulAcc } else { OpKind::Mul };
    Ok((kind, parts))
}

/// Parse `Name[R1,R2-1,R3-j:4]` → (tensor spec, accesses).
fn parse_tensor_ref(
    text: &str,
    ranks: &BTreeMap<String, Rank>,
) -> Result<(TensorSpec, Vec<RankAccess>)> {
    let (name, rest) =
        text.split_once('[').ok_or_else(|| anyhow!("expected `Name[ranks]`, got `{text}`"))?;
    let inner = rest.strip_suffix(']').ok_or_else(|| anyhow!("missing `]` in `{text}`"))?;
    let mut rlist = Vec::new();
    let mut accesses = Vec::new();
    for item in inner.split(',') {
        let item = item.trim();
        // Windowed: `R-j:W`; lagged: `R-k`; plain: `R`.
        if let Some((base, w)) = item.split_once("-j:") {
            let rank = ranks
                .get(base.trim())
                .cloned()
                .ok_or_else(|| anyhow!("unknown rank {base}"))?;
            accesses.push(RankAccess::Windowed { window: w.trim().parse()? });
            rlist.push(rank);
        } else if let Some((base, k)) = item.split_once('-') {
            let rank = ranks
                .get(base.trim())
                .cloned()
                .ok_or_else(|| anyhow!("unknown rank {base}"))?;
            accesses.push(RankAccess::Lagged { offset: k.trim().parse()? });
            rlist.push(rank);
        } else {
            let rank =
                ranks.get(item).cloned().ok_or_else(|| anyhow!("unknown rank {item}"))?;
            accesses.push(RankAccess::Current);
            rlist.push(rank);
        }
    }
    Ok((
        TensorSpec::new(name.trim(), rlist, DType::F16, TensorClass::Intermediate),
        accesses,
    ))
}

fn parse_tensor_decl(
    text: &str,
    ranks: &BTreeMap<String, Rank>,
    class: TensorClass,
) -> Result<TensorSpec> {
    let (mut t, _) = parse_tensor_ref(text, ranks)?;
    t.class = class;
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fusion::{stitch, FusionVariant};

    const FIG8: &str = r#"
# Paper Figure 8, in the text format.
rank M = 4
rank N = 5
rank K = 64
rank P = 3
rank Q = 2
input  A[M,K]
input  B[K,N]
input  C[P]
input  W[Q]
input  D[Q]
Z[M,N]   = A[M,K] * B[K,N]    / sum K
Y[M,N,P] = Z[M,N] * C[P]
X[M,N,Q] = Y[M,N,P] * W[Q]    / sum P
V[N]     = X[M,N,Q] * D[Q]    / sum M,Q
U[N]     = exp(V[N])
"#;

    #[test]
    fn parses_figure8_and_stitches_to_two_groups() {
        let c = parse_cascade("fig8-text", FIG8).unwrap();
        assert_eq!(c.len(), 5);
        let plan = stitch(&c, FusionVariant::RIRSbRSp);
        let groups: Vec<Vec<usize>> = plan.groups.iter().map(|g| g.einsums.clone()).collect();
        assert_eq!(groups, vec![vec![1, 2, 3], vec![4, 5]]);
    }

    #[test]
    fn parses_recurrence_and_window() {
        let text = r#"
rank I* = 16
rank D  = 8
rank J  = 4
input  U[I,D]
weight K[D,J]
weight A[I,D]
T[I,D] = U[I-j:4,D] * K[D,J] / sum J
H[I,D] = A[I,D] * H[I-1,D]
"#;
        let c = parse_cascade("rec", text).unwrap();
        assert!(c.by_id(1).unwrap().is_recurrent()); // windowed conv
        let h = c.by_id(2).unwrap();
        assert!(h.is_recurrent());
        assert_eq!(h.output.class, TensorClass::Recurrent);
    }

    #[test]
    fn rejects_undeclared_tensors_and_bad_ranks() {
        assert!(parse_cascade("bad", "Z[M] = Ghost[M]").is_err());
        let text = "rank M = 4\nZ[M] = Q[Nope]";
        assert!(parse_cascade("bad", text).is_err());
    }

    #[test]
    fn add_and_unary_ops() {
        let text = r#"
rank M = 8
input A[M]
input B[M]
S[M] = A[M] + B[M]
E[M] = silu(S[M])
"#;
        let c = parse_cascade("ops", text).unwrap();
        assert_eq!(c.by_id(1).unwrap().op, OpKind::Add);
        assert_eq!(c.by_id(2).unwrap().op, OpKind::Unary(UnaryFn::SiLU));
    }

    #[test]
    fn comments_and_blank_lines_ignored() {
        let text = "# just a comment\n\nrank M = 2\ninput A[M]\nZ[M] = square(A[M])\n";
        let c = parse_cascade("c", text).unwrap();
        assert_eq!(c.len(), 1);
    }
}
