//! Ranks: named tensor dimensions in the extended-Einsum (EDGE) sense.
//!
//! A rank is a named index space (e.g. `I`, `E`, `D`, `N`). Extended
//! Einsums add *generational* ranks: ranks along which the cascade
//! iterates, where an Einsum may reference a tensor at a previous point
//! (`H[i-1]`) or with a non-unit stride window (the causal-conv access
//! `TX[i-j]`). Those recurrent/windowed accesses are what make the SSM
//! a recurrence rather than plain tensor algebra (paper §II-A).

use std::fmt;

/// A named rank with a concrete shape (extent).
///
/// Shapes are concrete because the analysis in this crate is always run
/// against a specific workload configuration (a model size and sequence
/// length); the cascade *builders* in [`crate::cascade`] instantiate the
/// symbolic paper ranks with real extents.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Rank {
    /// Rank name, e.g. `"I"`, `"E"`, `"D"`, `"N"`.
    pub name: String,
    /// Extent (number of points along this rank).
    pub extent: u64,
    /// Kind of rank: spatial (plain) or generational (iterative).
    pub kind: RankKind,
}

/// Classification of a rank.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum RankKind {
    /// An ordinary tensor-algebra rank.
    #[default]
    Spatial,
    /// A generational rank (EDGE): the cascade iterates along it and
    /// Einsums may access previous generations (e.g. `H[i-1]`).
    Generational,
}

impl Rank {
    /// New spatial rank.
    pub fn new(name: impl Into<String>, extent: u64) -> Self {
        Rank { name: name.into(), extent, kind: RankKind::Spatial }
    }

    /// New generational (iterative) rank.
    pub fn generational(name: impl Into<String>, extent: u64) -> Self {
        Rank { name: name.into(), extent, kind: RankKind::Generational }
    }

    /// True if this rank is generational.
    pub fn is_generational(&self) -> bool {
        self.kind == RankKind::Generational
    }
}

impl fmt::Display for Rank {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.kind {
            RankKind::Spatial => write!(f, "{}:{}", self.name, self.extent),
            RankKind::Generational => write!(f, "{}*:{}", self.name, self.extent),
        }
    }
}

/// How an Einsum operand accesses a rank.
///
/// Plain accesses read the current point. Generational accesses read a
/// *previous* generation (`offset` back), and windowed accesses read a
/// window (`i - j` for `j in 0..window`), which is how the causal conv
/// (Einsum 9) and the `TX → TTX` non-unit-step pattern are expressed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum RankAccess {
    /// `T[.., i, ..]` — the current point along the rank.
    #[default]
    Current,
    /// `T[.., i - offset, ..]` — a fixed look-back along a generational
    /// rank (`H[i-1]` has `offset = 1`).
    Lagged { offset: u64 },
    /// `T[.., i - j, ..]` for `j in 0..window` — a sliding window along
    /// a generational rank (causal conv with kernel size `window`).
    Windowed { window: u64 },
}

impl RankAccess {
    /// True for any access that reaches back along a generational rank.
    pub fn is_recurrent(&self) -> bool {
        !matches!(self, RankAccess::Current)
    }

    /// How many previous generations must stay live for this access.
    pub fn lookback(&self) -> u64 {
        match self {
            RankAccess::Current => 0,
            RankAccess::Lagged { offset } => *offset,
            RankAccess::Windowed { window } => window.saturating_sub(1),
        }
    }
}

impl fmt::Display for RankAccess {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RankAccess::Current => write!(f, "i"),
            RankAccess::Lagged { offset } => write!(f, "i-{offset}"),
            RankAccess::Windowed { window } => write!(f, "i-j[0..{window})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_display() {
        assert_eq!(Rank::new("E", 1024).to_string(), "E:1024");
        assert_eq!(Rank::generational("I", 512).to_string(), "I*:512");
    }

    #[test]
    fn rank_kinds() {
        assert!(!Rank::new("E", 8).is_generational());
        assert!(Rank::generational("I", 8).is_generational());
    }

    #[test]
    fn access_lookback() {
        assert_eq!(RankAccess::Current.lookback(), 0);
        assert_eq!(RankAccess::Lagged { offset: 1 }.lookback(), 1);
        assert_eq!(RankAccess::Windowed { window: 4 }.lookback(), 3);
        assert!(!RankAccess::Current.is_recurrent());
        assert!(RankAccess::Lagged { offset: 1 }.is_recurrent());
        assert!(RankAccess::Windowed { window: 4 }.is_recurrent());
    }
}
