//! Tensor specifications: a named tensor with a rank list, a dtype, and
//! a class matching the paper's Figure 1 color coding (input / weight /
//! recurrent / intermediate).

use std::fmt;

use super::rank::{Rank, RankAccess};

/// Element datatype. The paper's datapath is fp16 with fp32 accumulation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum DType {
    #[default]
    F16,
    BF16,
    F32,
}

impl DType {
    /// Bytes per element.
    pub fn bytes(&self) -> u64 {
        match self {
            DType::F16 | DType::BF16 => 2,
            DType::F32 => 4,
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DType::F16 => write!(f, "f16"),
            DType::BF16 => write!(f, "bf16"),
            DType::F32 => write!(f, "f32"),
        }
    }
}

/// Tensor class, mirroring the color legend of paper Figure 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TensorClass {
    /// Blue: workload input (token embeddings, residual stream).
    Input,
    /// Green edge: a trained weight tensor (unique to one Einsum).
    Weight,
    /// Purple: tensor with recurrent accesses across the generational
    /// rank (the hidden state `H`).
    Recurrent,
    /// Produced by one Einsum, consumed by other Einsum(s).
    Intermediate,
    /// Final output of the cascade.
    Output,
}

impl fmt::Display for TensorClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            TensorClass::Input => "input",
            TensorClass::Weight => "weight",
            TensorClass::Recurrent => "recurrent",
            TensorClass::Intermediate => "intermediate",
            TensorClass::Output => "output",
        };
        write!(f, "{s}")
    }
}

/// A tensor specification: name + ordered rank list + dtype + class.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct TensorSpec {
    pub name: String,
    pub ranks: Vec<Rank>,
    pub dtype: DType,
    pub class: TensorClass,
}

impl TensorSpec {
    pub fn new(
        name: impl Into<String>,
        ranks: Vec<Rank>,
        dtype: DType,
        class: TensorClass,
    ) -> Self {
        TensorSpec { name: name.into(), ranks, dtype, class }
    }

    /// Number of elements (product of rank extents). A scalar (rank-0
    /// tensor) has one element.
    pub fn elements(&self) -> u64 {
        self.ranks.iter().map(|r| r.extent).product()
    }

    /// Footprint in bytes.
    pub fn bytes(&self) -> u64 {
        self.elements() * self.dtype.bytes()
    }

    /// Footprint in bytes of a single generation (all ranks except the
    /// named generational rank). This is what must stay live per step of
    /// the iterative rank — e.g. one `(D, N)` slice of `H`.
    pub fn generation_bytes(&self, gen_rank: &str) -> u64 {
        let elems: u64 = self
            .ranks
            .iter()
            .filter(|r| r.name != gen_rank)
            .map(|r| r.extent)
            .product();
        elems * self.dtype.bytes()
    }

    /// Rank names in order.
    pub fn rank_names(&self) -> Vec<&str> {
        self.ranks.iter().map(|r| r.name.as_str()).collect()
    }

    /// Does this tensor carry the named rank?
    pub fn has_rank(&self, name: &str) -> bool {
        self.ranks.iter().any(|r| r.name == name)
    }
}

impl fmt::Display for TensorSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let ranks: Vec<String> = self.ranks.iter().map(|r| r.to_string()).collect();
        write!(f, "{}[{}]", self.name, ranks.join(","))
    }
}

/// An operand: a tensor reference plus per-rank access patterns.
///
/// `accesses` is parallel to the tensor's rank list; non-`Current`
/// entries encode recurrences (`H[i-1]`) and windows (`TX[i-j]`).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Operand {
    pub tensor: TensorSpec,
    pub accesses: Vec<RankAccess>,
}

impl Operand {
    /// Plain operand: every rank accessed at the current point.
    pub fn plain(tensor: TensorSpec) -> Self {
        let accesses = vec![RankAccess::Current; tensor.ranks.len()];
        Operand { tensor, accesses }
    }

    /// Operand with a custom access on one named rank.
    pub fn with_access(tensor: TensorSpec, rank: &str, access: RankAccess) -> Self {
        let accesses = tensor
            .ranks
            .iter()
            .map(|r| if r.name == rank { access } else { RankAccess::Current })
            .collect();
        Operand { tensor, accesses }
    }

    /// True if any rank access is recurrent (lagged or windowed).
    pub fn is_recurrent(&self) -> bool {
        self.accesses.iter().any(|a| a.is_recurrent())
    }
}

impl fmt::Display for Operand {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_recurrent() {
            let idx: Vec<String> = self
                .tensor
                .ranks
                .iter()
                .zip(&self.accesses)
                .map(|(r, a)| match a {
                    RankAccess::Current => r.name.to_lowercase(),
                    _ => format!("{a}"),
                })
                .collect();
            write!(f, "{}[{}]", self.tensor.name, idx.join(","))
        } else {
            write!(f, "{}", self.tensor)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t() -> TensorSpec {
        TensorSpec::new(
            "H",
            vec![Rank::generational("I", 128), Rank::new("D", 64), Rank::new("N", 16)],
            DType::F16,
            TensorClass::Recurrent,
        )
    }

    #[test]
    fn sizes() {
        let h = t();
        assert_eq!(h.elements(), 128 * 64 * 16);
        assert_eq!(h.bytes(), 128 * 64 * 16 * 2);
        assert_eq!(h.generation_bytes("I"), 64 * 16 * 2);
    }

    #[test]
    fn operand_access() {
        let h = t();
        let lagged = Operand::with_access(h.clone(), "I", RankAccess::Lagged { offset: 1 });
        assert!(lagged.is_recurrent());
        assert!(!Operand::plain(h).is_recurrent());
    }

    #[test]
    fn rank_queries() {
        let h = t();
        assert!(h.has_rank("D"));
        assert!(!h.has_rank("Q"));
        assert_eq!(h.rank_names(), vec!["I", "D", "N"]);
    }

    #[test]
    fn dtype_bytes() {
        assert_eq!(DType::F16.bytes(), 2);
        assert_eq!(DType::BF16.bytes(), 2);
        assert_eq!(DType::F32.bytes(), 4);
    }
}
