//! Extended-Einsum intermediate representation (EDGE/TeAAL-style).
//!
//! The paper's analysis rests on expressing Mamba as a *cascade of
//! extended Einsums*: tensor-algebra operations over named ranks, with
//! EDGE's two extensions — user-defined per-element operations and
//! generational (iterative) ranks — used to express the SSM recurrence
//! and the nonlinearities (paper §II-A).
//!
//! This module is the IR everything else consumes:
//! * [`rank`] — named ranks, generational ranks, access patterns;
//! * [`tensor`] — tensor specs + operand access patterns;
//! * [`spec`] — one extended Einsum (output, operands, reduction, op);
//! * [`iterspace`] — iteration-space set algebra (fusion's foundation);
//! * [`cascade`] — ordered DAGs of Einsums with validation;
//! * [`display`] — Figure-1-style dumps (table, Graphviz).

pub mod cascade;
pub mod display;
pub mod iterspace;
pub mod parser;
pub mod rank;
pub mod spec;
pub mod tensor;

pub use cascade::{Cascade, Edge};
pub use iterspace::{IterSpace, SpaceRelation};
pub use parser::parse_cascade;
pub use rank::{Rank, RankAccess, RankKind};
pub use spec::{EinsumSpec, Intensity, OpKind, UnaryFn};
pub use tensor::{DType, Operand, TensorClass, TensorSpec};
