//! The dataflow DAG of a cascade: Einsums as nodes, tensors as edges.
//!
//! Edges are split by generation semantics (the distinction every other
//! verify pass leans on):
//!
//! * **Same-generation dependencies** (`deps`) — the consumer reads the
//!   producer's value for the *current* generation `i`, so the producer
//!   must execute first within one launch. `Current` accesses qualify,
//!   and so do `Windowed{w}` accesses (the window `T[i-j], j in 0..w`
//!   includes offset 0 — the conv reading `TX` needs the fresh column).
//! * **Generational edges** (`generational`) — the consumer reads only
//!   *previous* generations (`Lagged{o}`, e.g. `H[i-1]`). These are the
//!   recurrence back-edges: they impose no same-generation ordering
//!   (the old value already exists when the launch starts) but they are
//!   exactly what the donation analysis must protect from in-place
//!   overwrites.

use std::collections::{BTreeMap, BTreeSet};

use crate::einsum::{Cascade, RankAccess};

/// One tensor-carried edge between two Einsums.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DepEdge {
    /// Producer Einsum id.
    pub from: usize,
    /// Consumer Einsum id.
    pub to: usize,
    /// The tensor flowing along the edge.
    pub tensor: String,
}

/// Producer/consumer dataflow graph over a cascade's Einsums.
#[derive(Debug)]
pub struct DataflowGraph {
    /// All Einsum ids, in cascade order.
    pub nodes: Vec<usize>,
    /// Same-generation dependency edges (must-order within a launch).
    pub deps: Vec<DepEdge>,
    /// Previous-generation (recurrence) edges: `from` produces the new
    /// generation, `to` reads an older one. Includes self-loops
    /// (`Hs = ABar·Hs[i-1] + BX`).
    pub generational: Vec<DepEdge>,
    succ: BTreeMap<usize, Vec<usize>>,
    pred: BTreeMap<usize, Vec<usize>>,
}

impl DataflowGraph {
    /// Rebuild the graph from the Einsums' operands (independently of
    /// `Cascade::edges`, so the verifier does not trust the structure
    /// it is checking).
    pub fn build(c: &Cascade) -> DataflowGraph {
        let producers = c.producers();
        let mut deps: Vec<DepEdge> = Vec::new();
        let mut generational: Vec<DepEdge> = Vec::new();
        for e in c.einsums() {
            for op in &e.inputs {
                let name = op.tensor.name.as_str();
                let Some(&pid) = producers.get(name) else {
                    continue; // pure input / weight
                };
                // An operand with any lagged access reads only previous
                // generations of the tensor; everything else (Current,
                // Windowed) needs the current generation too.
                let lagged =
                    op.accesses.iter().any(|a| matches!(a, RankAccess::Lagged { .. }));
                let edge =
                    DepEdge { from: pid, to: e.id, tensor: name.to_string() };
                let sink = if lagged || pid == e.id { &mut generational } else { &mut deps };
                if !sink.contains(&edge) {
                    sink.push(edge);
                }
            }
        }
        let mut succ: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        let mut pred: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
        for d in &deps {
            succ.entry(d.from).or_default().push(d.to);
            pred.entry(d.to).or_default().push(d.from);
        }
        DataflowGraph {
            nodes: c.einsums().iter().map(|e| e.id).collect(),
            deps,
            generational,
            succ,
            pred,
        }
    }

    fn bfs(adj: &BTreeMap<usize, Vec<usize>>, seeds: &[usize]) -> BTreeSet<usize> {
        let mut seen: BTreeSet<usize> = BTreeSet::new();
        let mut queue: Vec<usize> = seeds.to_vec();
        while let Some(n) = queue.pop() {
            for &m in adj.get(&n).map(|v| v.as_slice()).unwrap_or(&[]) {
                if seen.insert(m) {
                    queue.push(m);
                }
            }
        }
        seen
    }

    /// Every node reachable from any seed via same-generation
    /// dependencies (seeds themselves only if re-reached).
    pub fn reachable_from(&self, seeds: &[usize]) -> BTreeSet<usize> {
        Self::bfs(&self.succ, seeds)
    }

    /// Every node from which some seed is reachable (reverse
    /// reachability; seeds themselves only if re-reached).
    pub fn reaching(&self, seeds: &[usize]) -> BTreeSet<usize> {
        Self::bfs(&self.pred, seeds)
    }
}
