//! Static verification of the cascade-of-Einsums layer (offline, CI-wired).
//!
//! The paper's claim is that fusion mappings are *derived* from the
//! Einsum dependency structure rather than asserted. This module makes
//! that a machine-checked invariant, entirely over the analytical layer
//! (no engine, no device):
//!
//! * [`graph`] — the producer/consumer dataflow DAG of a cascade
//!   (Einsums as nodes, tensors as edges), with generational
//!   (`H[i-1]`-style) edges separated from same-generation dependencies;
//! * [`legality`] — proves every [`crate::planner::PlanChoice`]'s
//!   grouping is a convex partition of that DAG with an acyclic
//!   condensed inter-group graph, a dependency-respecting execution
//!   order, and honest join provenance (no phantom fusions);
//! * [`traffic`] — recomputes per-group live-in/live-out sets and the
//!   minimal inter-group off-chip traffic, then cross-checks
//!   [`crate::model::evaluate`]'s byte accounting per design point
//!   (the cost-model drift detector);
//! * [`donation`] — use-after-overwrite analysis for
//!   [`crate::runtime::Donation::DonateInPlace`]: per plan, no Einsum
//!   may consume pre-update conv/ssm state after the in-place update
//!   writes it; emits per-plan `donation_safe` verdicts that
//!   [`crate::runtime::EngineCaps::donation_sound`] consults;
//! * [`lint`] — a std-only source walker over `rust/src/` enforcing
//!   repo invariants (no wall clock in tick-stamped code outside an
//!   allowlist, no bare `unwrap()` in coordinator/runtime hot paths, no
//!   deprecated legacy executor calls outside tests, every
//!   `rust/tests/*.rs` registered in `Cargo.toml`).
//!
//! Everything lands in a [`VerifyReport`] (`mambalaya verify` writes it
//! as `VERIFY_report.json`); any Error-severity finding fails CI.

pub mod donation;
pub mod graph;
pub mod legality;
pub mod lint;
pub mod traffic;

pub use donation::{analyze_plan as analyze_donation, DonationVerdict};
pub use graph::{DataflowGraph, DepEdge};
pub use legality::check_plan;
pub use lint::{lint_file, lint_tree, LintReport, WALLCLOCK_ALLOWLIST};
pub use traffic::{audit_plan, TrafficAudit, TRAFFIC_TOLERANCE};

use crate::arch::ArchSpec;
use crate::cascade::{mamba1, mamba2, transformer, ModelConfig};
use crate::einsum::Cascade;
use crate::model::ExecOptions;
use crate::planner::PlanChoice;
use crate::runtime::EngineCaps;
use crate::util::json::JsonValue;

/// How bad a finding is. Any `Error` fails `mambalaya verify` (and CI).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    /// Invariant violated — the tree must not ship like this.
    Error,
    /// Suspicious but not provably wrong; surfaced for review.
    Warn,
    /// Deliberate, documented deviation worth recording.
    Info,
}

impl Severity {
    pub fn as_str(&self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warn => "warn",
            Severity::Info => "info",
        }
    }
}

/// What kind of invariant a finding is about (stable, machine-readable).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FindingCode {
    /// Plan is not a partition of the cascade (missing/duplicated
    /// Einsums, groups out of cascade order, internal tensor escaping).
    Coverage,
    /// A fusion group is not a convex subgraph: a dependency path leaves
    /// the group and re-enters it through a non-member.
    NonConvexGroup,
    /// The condensed inter-group graph has a dependency cycle.
    GroupCycle,
    /// The plan's linearized execution order runs a consumer before its
    /// producer.
    ExecOrder,
    /// A `JoinRecord` claims a fusion link with no real tensor flowing
    /// between the two Einsums (a phantom fusion).
    PhantomJoin,
    /// A tensor marked internal to a group is not actually private to it
    /// (or an actually-private tensor was not marked — Warn).
    InternalTensors,
    /// `model::evaluate` claims less inter-group traffic than the
    /// liveness-exact minimum — an impossible cost.
    TrafficUnderMin,
    /// `model::evaluate` diverges from the independently recomputed
    /// traffic beyond [`TRAFFIC_TOLERANCE`].
    TrafficDrift,
    /// In-place state donation would let an Einsum read pre-update state
    /// after the update overwrote it, under this plan's execution order.
    DonationUnsafe,
    /// An `EngineCaps` advertises donation while enabling a plan whose
    /// donation verdict is unsafe.
    DonationCapsMismatch,
    /// Wall-clock use (`Instant`/`SystemTime`) outside the allowlist.
    LintWallClock,
    /// Bare `.unwrap()` in a non-test coordinator/runtime hot path.
    LintHotPathUnwrap,
    /// `.expect(...)` count in a hot path (documented-invariant style;
    /// surfaced as Warn so new ones get reviewed).
    LintHotPathExpect,
    /// Call to one of the four deprecated legacy executor methods
    /// outside tests / the wrapper definitions.
    LintDeprecatedCall,
    /// A `rust/tests/*.rs` file not registered as a `[[test]]` target.
    LintUnregisteredTest,
}

impl FindingCode {
    pub fn as_str(&self) -> &'static str {
        match self {
            FindingCode::Coverage => "coverage",
            FindingCode::NonConvexGroup => "non-convex-group",
            FindingCode::GroupCycle => "group-cycle",
            FindingCode::ExecOrder => "exec-order",
            FindingCode::PhantomJoin => "phantom-join",
            FindingCode::InternalTensors => "internal-tensors",
            FindingCode::TrafficUnderMin => "traffic-under-min",
            FindingCode::TrafficDrift => "traffic-drift",
            FindingCode::DonationUnsafe => "donation-unsafe",
            FindingCode::DonationCapsMismatch => "donation-caps-mismatch",
            FindingCode::LintWallClock => "lint-wall-clock",
            FindingCode::LintHotPathUnwrap => "lint-hot-path-unwrap",
            FindingCode::LintHotPathExpect => "lint-hot-path-expect",
            FindingCode::LintDeprecatedCall => "lint-deprecated-call",
            FindingCode::LintUnregisteredTest => "lint-unregistered-test",
        }
    }
}

/// One typed, located verification finding.
#[derive(Debug, Clone)]
pub struct Finding {
    pub severity: Severity,
    pub code: FindingCode,
    /// Where: `cascade/mode/plan[/group N]` for analytic findings,
    /// `path:line` for lint findings.
    pub location: String,
    pub message: String,
}

impl Finding {
    pub fn new(
        severity: Severity,
        code: FindingCode,
        location: impl Into<String>,
        message: impl Into<String>,
    ) -> Self {
        Finding { severity, code, location: location.into(), message: message.into() }
    }

    pub fn error(code: FindingCode, loc: impl Into<String>, msg: impl Into<String>) -> Self {
        Finding::new(Severity::Error, code, loc, msg)
    }

    pub fn warn(code: FindingCode, loc: impl Into<String>, msg: impl Into<String>) -> Self {
        Finding::new(Severity::Warn, code, loc, msg)
    }

    pub fn info(code: FindingCode, loc: impl Into<String>, msg: impl Into<String>) -> Self {
        Finding::new(Severity::Info, code, loc, msg)
    }

    fn to_json(&self) -> JsonValue {
        let mut o = JsonValue::obj();
        o.set("severity", self.severity.as_str());
        o.set("code", self.code.as_str());
        o.set("location", self.location.as_str());
        o.set("message", self.message.as_str());
        o
    }
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "[{}] {} @ {}: {}",
            self.severity.as_str(),
            self.code.as_str(),
            self.location,
            self.message
        )
    }
}

/// Per-(scenario, plan) verification record — one row of the report.
#[derive(Debug, Clone)]
pub struct PlanRecord {
    /// `cascade/mode`, e.g. `mamba1/prefill`.
    pub scenario: String,
    /// Plan name (`PlanChoice::name()`).
    pub plan: String,
    pub groups: usize,
    pub donation_safe: bool,
    /// Inter-group traffic cross-check numbers (bytes).
    pub min_inter: u64,
    pub expected_inter: u64,
    pub evaluated_inter: u64,
}

impl PlanRecord {
    fn to_json(&self) -> JsonValue {
        let mut o = JsonValue::obj();
        o.set("scenario", self.scenario.as_str());
        o.set("plan", self.plan.as_str());
        o.set("groups", self.groups as u64);
        o.set("donation_safe", self.donation_safe);
        o.set("min_inter_bytes", self.min_inter);
        o.set("expected_inter_bytes", self.expected_inter);
        o.set("evaluated_inter_bytes", self.evaluated_inter);
        o
    }
}

/// The full verifier output: analytic findings + per-plan records, plus
/// (when the lint pass ran) lint findings kept separately so the golden
/// snapshot of the analytic results does not churn with source edits.
#[derive(Debug, Default)]
pub struct VerifyReport {
    pub plans: Vec<PlanRecord>,
    /// Findings from the analytic passes (legality/traffic/donation).
    pub findings: Vec<Finding>,
    /// Findings from the source lint (empty when lint was not run).
    pub lint_findings: Vec<Finding>,
    /// Files scanned by the lint pass.
    pub lint_files: usize,
}

impl VerifyReport {
    fn count(&self, s: Severity) -> usize {
        self.findings
            .iter()
            .chain(self.lint_findings.iter())
            .filter(|f| f.severity == s)
            .count()
    }

    pub fn errors(&self) -> usize {
        self.count(Severity::Error)
    }

    pub fn warns(&self) -> usize {
        self.count(Severity::Warn)
    }

    pub fn infos(&self) -> usize {
        self.count(Severity::Info)
    }

    /// Deterministic text rendering of the *analytic* results — the
    /// golden-snapshot surface (`rust/tests/golden/verify_report.txt`).
    /// Lint findings are excluded on purpose: they track the source
    /// tree, not the model, and would churn the golden on every edit.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        let mut scenario = "";
        for r in &self.plans {
            if r.scenario != scenario {
                if !scenario.is_empty() {
                    out.push('\n');
                }
                out.push_str(&format!("== {} ==\n", r.scenario));
                scenario = &r.scenario;
            }
            out.push_str(&format!(
                "plan {}: groups={} donation={} inter bytes min={} expected={} evaluated={}\n",
                r.plan,
                r.groups,
                if r.donation_safe { "safe" } else { "UNSAFE" },
                r.min_inter,
                r.expected_inter,
                r.evaluated_inter,
            ));
        }
        out.push_str("\n== findings ==\n");
        if self.findings.is_empty() {
            out.push_str("(none)\n");
        } else {
            for f in &self.findings {
                out.push_str(&format!("{f}\n"));
            }
        }
        out
    }

    /// Machine-readable report (`VERIFY_report.json`).
    pub fn to_json(&self) -> JsonValue {
        let mut o = JsonValue::obj();
        o.set("version", 1u64);
        o.set("errors", self.errors() as u64);
        o.set("warns", self.warns() as u64);
        o.set("infos", self.infos() as u64);
        o.set("lint_files_scanned", self.lint_files as u64);
        let mut plans = Vec::new();
        for r in &self.plans {
            plans.push(r.to_json());
        }
        o.set("plans", JsonValue::Arr(plans));
        let mut fs = Vec::new();
        for f in self.findings.iter().chain(self.lint_findings.iter()) {
            fs.push(f.to_json());
        }
        o.set("findings", JsonValue::Arr(fs));
        o
    }
}

/// One verification scenario: a concrete cascade instance plus the
/// execution-option shape it is costed under.
struct VerifyScenario {
    label: String,
    cascade: Cascade,
    decode_state_io: bool,
}

fn scenarios(seq: u64, batch: u64) -> Vec<VerifyScenario> {
    let cfg = ModelConfig::mamba_370m();
    vec![
        VerifyScenario {
            label: format!("mamba1/prefill seq={seq} batch={batch}"),
            cascade: mamba1::build(&cfg, seq, batch),
            decode_state_io: false,
        },
        VerifyScenario {
            label: "mamba1/decode seq=1 batch=64".to_string(),
            cascade: mamba1::build(&cfg, 1, 64),
            decode_state_io: true,
        },
        VerifyScenario {
            label: format!("mamba2/prefill seq={seq} batch={batch}"),
            cascade: mamba2::build(&cfg, seq, batch),
            decode_state_io: false,
        },
        VerifyScenario {
            label: "transformer/prefill seq=256 batch=1".to_string(),
            cascade: transformer::build(&transformer::TransformerConfig::medium(256)),
            decode_state_io: false,
        },
    ]
}

/// Run the analytic battery (legality + traffic + donation) over every
/// [`PlanChoice`] on every scenario cascade, with `seq`/`batch` sizing
/// the prefill instances. Deterministic; this is what the golden
/// snapshot pins.
pub fn verify_cascades_with(seq: u64, batch: u64) -> VerifyReport {
    let arch = ArchSpec::mambalaya();
    let mut report = VerifyReport::default();
    for sc in scenarios(seq, batch) {
        let g = DataflowGraph::build(&sc.cascade);
        // Per-plan donation verdicts, indexed by `PlanChoice::index()`,
        // in the layout `EngineCaps::donation_sound` consumes.
        let mut verdicts = [true; PlanChoice::COUNT];
        for point in PlanChoice::all() {
            let plan = point.plan(&sc.cascade);
            let loc = format!("{}/{}", sc.label, point.name());
            report.findings.extend(check_plan(&sc.cascade, &g, &plan, &loc));
            let verdict = analyze_donation(&sc.cascade, &plan, &loc);
            verdicts[point.index()] = verdict.safe;
            report.findings.extend(verdict.findings);
            let opts = ExecOptions {
                staging: point.staging(),
                pipelined: false,
                decode_state_io: sc.decode_state_io,
            };
            let audit = audit_plan(&sc.cascade, &plan, &arch, &opts, &loc);
            report.findings.extend(audit.findings);
            report.plans.push(PlanRecord {
                scenario: sc.label.clone(),
                plan: point.name(),
                groups: plan.groups.len(),
                donation_safe: verdict.safe,
                min_inter: audit.min_inter,
                expected_inter: audit.expected_inter,
                evaluated_inter: audit.evaluated_inter,
            });
        }
        // Capability consistency: every caps preset the runtime ships
        // must only advertise donation over plans proven safe on this
        // cascade (the gate for the PJRT buffer-donation ROADMAP item).
        for (name, caps) in [("baseline", EngineCaps::baseline()), ("full", EngineCaps::full())] {
            if !caps.donation_sound(&verdicts) {
                report.findings.push(Finding::error(
                    FindingCode::DonationCapsMismatch,
                    format!("{}/EngineCaps::{name}", sc.label),
                    format!(
                        "caps advertise donation over a plan whose donation_safe verdict \
                         is false (verdicts {:?})",
                        verdicts
                    ),
                ));
            }
        }
    }
    report
}

/// [`verify_cascades_with`] at the default sizing (prefill 512×1).
pub fn verify_cascades() -> VerifyReport {
    verify_cascades_with(512, 1)
}

/// Full verification: the analytic battery plus the source lint rooted
/// at `repo_root` (the directory holding `Cargo.toml` and `rust/`).
pub fn verify_all(repo_root: &std::path::Path, seq: u64, batch: u64) -> VerifyReport {
    let mut report = verify_cascades_with(seq, batch);
    let lint = lint_tree(repo_root);
    report.lint_files = lint.files_scanned;
    report.lint_findings = lint.findings;
    report
}
