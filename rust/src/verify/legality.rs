//! Fusion-plan legality: is a grouping a lawful schedule of the DAG?
//!
//! For every plan the verifier proves, independently of how the plan
//! was constructed (greedy stitch, baseline builder, golden file):
//!
//! 1. **Coverage** — the groups partition the cascade
//!    (`FusionPlan::validate`), each Einsum exactly once, in order.
//! 2. **Convexity** — no dependency path leaves a group and re-enters
//!    it through a non-member. A non-convex group cannot be executed as
//!    one phase: the outside node needs group outputs *and* feeds group
//!    inputs.
//! 3. **Condensation acyclicity** — contracting each group to one node
//!    leaves the inter-group dependency graph acyclic (the phase
//!    schedule exists). Convexity violations usually imply a condensed
//!    cycle; both are reported so a mutation is located either way.
//! 4. **Execution order** — the plan's linearization (groups in order,
//!    members in listed order) is a topological order of the
//!    same-generation dependency edges.
//! 5. **Join provenance** — every `JoinRecord` that claims a fusion
//!    link (`via`) names an earlier member of the same group whose
//!    output really is an operand of the joining Einsum (and the
//!    recorded tensor matches). Rejects phantom fusions.
//! 6. **Internal tensors** — a tensor marked internal must be produced
//!    in-group with every consumer in-group (Error if it escapes), and
//!    an actually-private tensor missing from the list is flagged Warn
//!    (the cost model would over-charge it).

use std::collections::BTreeMap;

use crate::einsum::Cascade;
use crate::fusion::FusionPlan;

use super::graph::DataflowGraph;
use super::{Finding, FindingCode};

/// Run every legality check on one plan. `loc` prefixes finding
/// locations (`cascade/mode/plan`).
pub fn check_plan(
    c: &Cascade,
    g: &DataflowGraph,
    plan: &FusionPlan,
    loc: &str,
) -> Vec<Finding> {
    let mut findings = Vec::new();

    // 1. Coverage (partition, ordering, internal-tensor escape).
    if let Err(e) = plan.validate(c) {
        findings.push(Finding::error(FindingCode::Coverage, loc, e.to_string()));
    }

    // Membership map (first occurrence wins; duplicates are already a
    // coverage error).
    let mut group_of: BTreeMap<usize, usize> = BTreeMap::new();
    for (gi, grp) in plan.groups.iter().enumerate() {
        for &id in &grp.einsums {
            group_of.entry(id).or_insert(gi);
        }
    }

    // 2. Convexity.
    for (gi, grp) in plan.groups.iter().enumerate() {
        if grp.einsums.len() < 2 {
            continue;
        }
        let down = g.reachable_from(&grp.einsums);
        let up = g.reaching(&grp.einsums);
        for x in down.intersection(&up) {
            if grp.einsums.contains(x) {
                continue;
            }
            let name = c.by_id(*x).map(|e| e.name.as_str()).unwrap_or("?");
            findings.push(Finding::error(
                FindingCode::NonConvexGroup,
                format!("{loc}/group {gi}"),
                format!(
                    "einsum #{x} ({name}) lies on a dependency path through the group \
                     but is not a member — the group is not a convex subgraph"
                ),
            ));
        }
    }

    // 3. Condensed inter-group graph must be acyclic.
    let n_groups = plan.groups.len();
    let mut cond: Vec<Vec<usize>> = vec![Vec::new(); n_groups];
    for d in &g.deps {
        if let (Some(&a), Some(&b)) = (group_of.get(&d.from), group_of.get(&d.to)) {
            if a != b && !cond[a].contains(&b) {
                cond[a].push(b);
            }
        }
    }
    if let Some(cycle) = find_cycle(&cond) {
        findings.push(Finding::error(
            FindingCode::GroupCycle,
            loc.to_string(),
            format!(
                "condensed inter-group dependency graph has a cycle through groups {:?} — \
                 no phase order can satisfy the dataflow",
                cycle
            ),
        ));
    }

    // 4. Linearized execution order respects every dependency edge.
    let mut pos: BTreeMap<usize, usize> = BTreeMap::new();
    for (p, &id) in plan.groups.iter().flat_map(|grp| grp.einsums.iter()).enumerate() {
        pos.entry(id).or_insert(p);
    }
    for d in &g.deps {
        if let (Some(&pa), Some(&pb)) = (pos.get(&d.from), pos.get(&d.to)) {
            if pa > pb {
                findings.push(Finding::error(
                    FindingCode::ExecOrder,
                    loc.to_string(),
                    format!(
                        "tensor {} is produced by einsum #{} at position {} but consumed \
                         by #{} at position {} — the plan runs the consumer first",
                        d.tensor, d.from, pa, d.to, pb
                    ),
                ));
            }
        }
    }

    // 5. Join provenance.
    for (gi, grp) in plan.groups.iter().enumerate() {
        for j in &grp.joins {
            let Some(via) = j.via else { continue };
            let jloc = format!("{loc}/group {gi}");
            let member_pos = grp.einsums.iter().position(|&id| id == j.einsum);
            let via_pos = grp.einsums.iter().position(|&id| id == via);
            let (Some(mp), Some(vp)) = (member_pos, via_pos) else {
                findings.push(Finding::error(
                    FindingCode::PhantomJoin,
                    jloc,
                    format!(
                        "join for einsum #{} claims link via #{via}, which is not a \
                         member of the group",
                        j.einsum
                    ),
                ));
                continue;
            };
            if vp >= mp {
                findings.push(Finding::error(
                    FindingCode::PhantomJoin,
                    jloc,
                    format!(
                        "join for einsum #{} claims link via #{via}, which does not \
                         precede it in the group",
                        j.einsum
                    ),
                ));
                continue;
            }
            let (Some(p), Some(m)) = (c.by_id(via), c.by_id(j.einsum)) else { continue };
            if m.operand(&p.output.name).is_none() {
                findings.push(Finding::error(
                    FindingCode::PhantomJoin,
                    jloc,
                    format!(
                        "join for einsum #{} ({}) claims link via #{via} ({}), but no \
                         tensor flows between them — a phantom fusion",
                        j.einsum, m.name, p.name
                    ),
                ));
                continue;
            }
            if let Some(t) = &j.tensor {
                if *t != p.output.name {
                    findings.push(Finding::error(
                        FindingCode::PhantomJoin,
                        jloc,
                        format!(
                            "join for einsum #{} records intermediate tensor {}, but \
                             #{via} produces {}",
                            j.einsum, t, p.output.name
                        ),
                    ));
                }
            }
        }
    }

    // 6. Internal-tensor honesty (mirrors `fill_internal_tensors`).
    let consumers = c.consumers();
    for (gi, grp) in plan.groups.iter().enumerate() {
        let gloc = format!("{loc}/group {gi}");
        for t in &grp.internal_tensors {
            let produced = grp
                .einsums
                .iter()
                .any(|&id| c.by_id(id).map(|e| e.output.name == *t).unwrap_or(false));
            let cs = consumers.get(t.as_str()).map(|v| v.as_slice()).unwrap_or(&[]);
            let private = produced
                && !cs.is_empty()
                && cs.iter().all(|cid| grp.einsums.contains(cid));
            if !private {
                findings.push(Finding::error(
                    FindingCode::InternalTensors,
                    gloc.clone(),
                    format!(
                        "tensor {t} is marked internal but is not private to the group \
                         (produced in-group: {produced}, consumers: {cs:?})"
                    ),
                ));
            }
        }
        // Actually-private tensors the plan failed to mark: the cost
        // model would charge off-chip traffic that never happens.
        for &id in &grp.einsums {
            let Some(e) = c.by_id(id) else { continue };
            let out = e.output.name.as_str();
            if grp.internal_tensors.iter().any(|t| t == out) {
                continue;
            }
            let cs = consumers.get(out).map(|v| v.as_slice()).unwrap_or(&[]);
            if !cs.is_empty() && cs.iter().all(|cid| grp.einsums.contains(cid)) {
                findings.push(Finding::warn(
                    FindingCode::InternalTensors,
                    gloc.clone(),
                    format!(
                        "tensor {out} is private to the group but not marked internal — \
                         the cost model over-charges its traffic"
                    ),
                ));
            }
        }
    }

    findings
}

/// First cycle in a small adjacency-list digraph (DFS, three colors),
/// as the group-index path along the cycle.
fn find_cycle(adj: &[Vec<usize>]) -> Option<Vec<usize>> {
    #[derive(Clone, Copy, PartialEq)]
    enum Color {
        White,
        Gray,
        Black,
    }
    fn dfs(
        u: usize,
        adj: &[Vec<usize>],
        color: &mut [Color],
        stack: &mut Vec<usize>,
    ) -> Option<Vec<usize>> {
        color[u] = Color::Gray;
        stack.push(u);
        for &v in &adj[u] {
            match color[v] {
                Color::Gray => {
                    let start = stack.iter().position(|&x| x == v).unwrap_or(0);
                    let mut cycle = stack[start..].to_vec();
                    cycle.push(v);
                    return Some(cycle);
                }
                Color::White => {
                    if let Some(c) = dfs(v, adj, color, stack) {
                        return Some(c);
                    }
                }
                Color::Black => {}
            }
        }
        stack.pop();
        color[u] = Color::Black;
        None
    }
    let mut color = vec![Color::White; adj.len()];
    for u in 0..adj.len() {
        if color[u] == Color::White {
            if let Some(c) = dfs(u, adj, &mut color, &mut Vec::new()) {
                return Some(c);
            }
        }
    }
    None
}
