//! Std-only source lint over `rust/src/` — repo invariants the type
//! system cannot express.
//!
//! Rules (all skip `#[cfg(test)]` regions and comment lines):
//!
//! * **Wall clock** — `Instant` / `SystemTime` may only appear in the
//!   files on [`WALLCLOCK_ALLOWLIST`] (each with a one-line
//!   justification). Everything gated in CI is stamped with the
//!   deterministic tick clock; wall time leaking into tick-stamped
//!   trace or decision logic makes gates flaky. Error elsewhere.
//! * **Hot-path `unwrap`** — bare `.unwrap()` in non-test
//!   `coordinator/` / `runtime/` code is an Error; the sanctioned form
//!   is `.expect("invariant ...")` documenting why the value exists.
//!   `.expect(` itself is surfaced as one Warn per file (with a count)
//!   so new ones get reviewed, not banned.
//! * **Deprecated executor calls** — the four legacy step methods
//!   (`step_mixed`, `step_mixed_into`, `step_planned_into`,
//!   `register_variant`) are wrappers kept for the equivalence suite;
//!   calling them from non-test code outside `runtime/engine.rs` is an
//!   Error — new code goes through `launch(LaunchSpec)`.
//! * **Test registration** — every `rust/tests/*.rs` file must appear
//!   as a `[[test]]` path in `Cargo.toml`, else it silently never runs
//!   (Warn).

use std::path::Path;

use super::{Finding, FindingCode};

/// Files allowed to read the wall clock, with why. Suffix-matched
/// against the path relative to `rust/src/`. To extend: add the file
/// and a one-line justification here — the lint output quotes it.
pub const WALLCLOCK_ALLOWLIST: &[(&str, &str)] = &[
    (
        "coordinator/request.rs",
        "wall-clock submit/first-token stamps feed operator-facing latency reports; gates use tick clocks",
    ),
    (
        "coordinator/metrics.rs",
        "wall elapsed appears in human-readable report lines only; every gated metric is a counter",
    ),
    (
        "coordinator/scheduler.rs",
        "wall TTFT sampled at first token for reporting histograms; trace stamps use metrics.ticks",
    ),
    (
        "bench_util.rs",
        "bench harness wall timing for operator output; CI gates compare deterministic counters",
    ),
    (
        "verify/lint.rs",
        "names the banned tokens in its own rule table; contains no timing code",
    ),
];

/// The deprecated legacy executor methods (lint matches `.name(` call
/// syntax, so the wrapper *definitions* in `runtime/engine.rs` — which
/// is exempt anyway — and doc mentions don't trip it).
const DEPRECATED_CALLS: &[&str] =
    &["step_mixed(", "step_mixed_into(", "step_planned_into(", "register_variant("];

/// Result of a lint run.
#[derive(Debug, Default)]
pub struct LintReport {
    pub files_scanned: usize,
    pub findings: Vec<Finding>,
}

/// Mark which lines of a source file are inside `#[cfg(test)]` items
/// (brace-balance heuristic — good enough for rustfmt-shaped code).
fn test_mask(lines: &[&str]) -> Vec<bool> {
    let mut mask = vec![false; lines.len()];
    let mut i = 0;
    while i < lines.len() {
        if !lines[i].trim_start().starts_with("//") && lines[i].contains("#[cfg(test)]") {
            let mut depth: i64 = 0;
            let mut started = false;
            let mut j = i;
            while j < lines.len() {
                mask[j] = true;
                for ch in lines[j].chars() {
                    match ch {
                        '{' => {
                            depth += 1;
                            started = true;
                        }
                        '}' => depth -= 1,
                        _ => {}
                    }
                }
                if started && depth <= 0 {
                    break;
                }
                // `#[cfg(test)]` on a braceless item (a `use`): stop at
                // the statement end.
                if !started && lines[j].trim_end().ends_with(';') {
                    break;
                }
                j += 1;
            }
            i = j + 1;
        } else {
            i += 1;
        }
    }
    mask
}

/// Does `line` contain `token` as a standalone identifier?
fn has_word(line: &str, token: &str) -> bool {
    let mut from = 0;
    while let Some(p) = line[from..].find(token) {
        let at = from + p;
        let before_ok = at == 0
            || !line[..at].chars().next_back().map(|c| c.is_alphanumeric() || c == '_').unwrap_or(false);
        let after = at + token.len();
        let after_ok = after >= line.len()
            || !line[after..].chars().next().map(|c| c.is_alphanumeric() || c == '_').unwrap_or(false);
        if before_ok && after_ok {
            return true;
        }
        from = after;
    }
    false
}

/// Lint one source file's content. `rel` is the path relative to
/// `rust/src/` (forward slashes). Pure (unit-testable on synthetic
/// sources).
pub fn lint_file(rel: &str, text: &str) -> Vec<Finding> {
    let lines: Vec<&str> = text.lines().collect();
    let mask = test_mask(&lines);
    let allowed_clock = WALLCLOCK_ALLOWLIST.iter().any(|(f, _)| rel.ends_with(f));
    let hot_path = rel.starts_with("coordinator/") || rel.starts_with("runtime/");
    let engine_file = rel.ends_with("runtime/engine.rs") || rel == "runtime/engine.rs";

    let mut findings = Vec::new();
    let mut expects = 0usize;
    for (n, line) in lines.iter().enumerate() {
        if mask[n] || line.trim_start().starts_with("//") {
            continue;
        }
        let loc = format!("rust/src/{rel}:{}", n + 1);
        if !allowed_clock && (has_word(line, "Instant") || has_word(line, "SystemTime")) {
            findings.push(Finding::error(
                FindingCode::LintWallClock,
                loc.clone(),
                "wall-clock use outside the allowlist — tick-stamped code must stay \
                 deterministic (see verify::lint::WALLCLOCK_ALLOWLIST to annotate a \
                 legitimate reporting site)"
                    .to_string(),
            ));
        }
        if hot_path {
            if line.contains(".unwrap()") {
                findings.push(Finding::error(
                    FindingCode::LintHotPathUnwrap,
                    loc.clone(),
                    "bare .unwrap() in a coordinator/runtime hot path — use \
                     .expect(\"invariant ...\") documenting why the value exists"
                        .to_string(),
                ));
            }
            expects += line.matches(".expect(").count();
        }
        if !engine_file {
            for dep in DEPRECATED_CALLS {
                if line.contains(&format!(".{dep}")) {
                    findings.push(Finding::error(
                        FindingCode::LintDeprecatedCall,
                        loc.clone(),
                        format!(
                            "call to deprecated legacy executor method `{}` outside tests \
                             — go through launch(LaunchSpec)",
                            dep.trim_end_matches('(')
                        ),
                    ));
                }
            }
        }
    }
    if expects > 0 {
        findings.push(Finding::warn(
            FindingCode::LintHotPathExpect,
            format!("rust/src/{rel}"),
            format!(
                "{expects} .expect() call(s) in a hot path (documented-invariant style is \
                 sanctioned; review when touching)"
            ),
        ));
    }
    findings
}

fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out);
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
}

/// Walk `repo_root/rust/src` applying [`lint_file`], then check that
/// every `repo_root/rust/tests/*.rs` is registered in `Cargo.toml`.
pub fn lint_tree(repo_root: &Path) -> LintReport {
    let mut report = LintReport::default();
    let src = repo_root.join("rust/src");
    let mut files = Vec::new();
    collect_rs(&src, &mut files);
    files.sort();
    for path in &files {
        let rel = path
            .strip_prefix(&src)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let Ok(text) = std::fs::read_to_string(path) else { continue };
        report.files_scanned += 1;
        report.findings.extend(lint_file(&rel, &text));
    }
    // Unregistered integration tests never run — a silent coverage hole.
    let manifest =
        std::fs::read_to_string(repo_root.join("Cargo.toml")).unwrap_or_default();
    let tests_dir = repo_root.join("rust/tests");
    let mut tests = Vec::new();
    if let Ok(entries) = std::fs::read_dir(&tests_dir) {
        for entry in entries.flatten() {
            let path = entry.path();
            if path.is_file() && path.extension().and_then(|e| e.to_str()) == Some("rs") {
                tests.push(path);
            }
        }
    }
    tests.sort();
    for t in tests {
        let name = t.file_name().map(|n| n.to_string_lossy().into_owned()).unwrap_or_default();
        if !manifest.contains(&format!("rust/tests/{name}")) {
            report.findings.push(Finding::warn(
                FindingCode::LintUnregisteredTest,
                format!("rust/tests/{name}"),
                "not registered as a [[test]] target in Cargo.toml — it never runs under \
                 `cargo test`"
                    .to_string(),
            ));
        }
    }
    report
}
