//! Liveness-exact inter-group traffic audit — the cost-model drift
//! detector.
//!
//! For every plan the audit derives two numbers per design point and
//! compares them against `model::evaluate`'s byte accounting:
//!
//! * **`min_inter`** — the liveness minimum: each group's live-in set
//!   (shared tensors read by members but not produced in-group) enters
//!   once, its live-out set (tensors produced in-group with consumers
//!   outside it, or model outputs) leaves once. No pass reloads, no
//!   spills, no state I/O. Any evaluation below this floor is an
//!   impossible cost ([`super::FindingCode::TrafficUnderMin`]).
//! * **`expected_inter`** — an independent recomputation of the
//!   accounting `model::exec::eval_group` is *supposed* to perform:
//!   singleton groups at best-unfused cost; fused groups charging each
//!   non-internal tensor once per pass (FuseMax pass analysis), spilled
//!   internal multi-pass outputs, MARCA-style full-extent staging
//!   spills, fully-fused RD-bridge round-trips, and decode state I/O.
//!   Divergence beyond [`TRAFFIC_TOLERANCE`] is a drift
//!   ([`super::FindingCode::TrafficDrift`]).
//!
//! Deliberate spill costs (the FF RD-bridge round-trip, MARCA
//! full-extent spills, X/LEX pass reloads) are exactly the gap between
//! `min_inter` and `expected_inter` — the audit keeps them visible
//! instead of hiding them in a fudge factor.

use crate::arch::{ArchSpec, Staging};
use crate::einsum::cascade::CascadeIndex;
use crate::einsum::Cascade;
use crate::fusion::{FusionClass, FusionGroup, FusionPlan};
use crate::model::cost::weight_bytes;
use crate::model::passes::analyze_scope_with;
use crate::model::{evaluate, ExecOptions};

use super::{Finding, FindingCode};

/// Allowed fractional divergence between the recomputed accounting and
/// `model::evaluate` before [`FindingCode::TrafficDrift`] fires. The
/// recomputation mirrors the model's documented semantics, so the
/// expected delta is zero; 2% is headroom for benign refactors (e.g.
/// rounding a tile boundary) without letting a dropped term ship.
pub const TRAFFIC_TOLERANCE: f64 = 0.02;

/// Audit result for one (cascade, plan, options) triple. Byte counts
/// are inter-group (off-chip) traffic only — intra-Einsum staging is
/// the mapper's business, not the fusion plan's.
#[derive(Debug)]
pub struct TrafficAudit {
    pub min_inter: u64,
    pub expected_inter: u64,
    pub evaluated_inter: u64,
    pub findings: Vec<Finding>,
}

/// Cross-check one plan's traffic. `loc` prefixes finding locations.
pub fn audit_plan(
    c: &Cascade,
    plan: &FusionPlan,
    arch: &ArchSpec,
    opts: &ExecOptions,
    loc: &str,
) -> TrafficAudit {
    let idx = CascadeIndex::new(c);
    let mut min_inter = 0u64;
    let mut expected_inter = 0u64;
    for g in &plan.groups {
        min_inter += live_set_min(c, &idx, g);
        expected_inter += expected_group(c, &idx, g, arch, opts);
    }
    let cost = evaluate(c, plan, arch, opts);
    let evaluated_inter = cost.traffic.inter();

    let mut findings = Vec::new();
    if evaluated_inter < min_inter {
        findings.push(Finding::error(
            FindingCode::TrafficUnderMin,
            loc.to_string(),
            format!(
                "model::evaluate claims {evaluated_inter} inter bytes, below the \
                 liveness-exact minimum {min_inter} — an impossible cost"
            ),
        ));
    }
    let denom = expected_inter.max(1) as f64;
    let drift = (evaluated_inter as f64 - expected_inter as f64).abs() / denom;
    if drift > TRAFFIC_TOLERANCE {
        findings.push(Finding::error(
            FindingCode::TrafficDrift,
            loc.to_string(),
            format!(
                "model::evaluate reports {evaluated_inter} inter bytes but the \
                 recomputed accounting expects {expected_inter} ({:.2}% drift, \
                 tolerance {:.0}%)",
                drift * 100.0,
                TRAFFIC_TOLERANCE * 100.0
            ),
        ));
    }
    TrafficAudit { min_inter, expected_inter, evaluated_inter, findings }
}

/// The liveness minimum for one group: live-ins enter once, live-outs
/// leave once, nothing else moves off-chip.
fn live_set_min(c: &Cascade, idx: &CascadeIndex, g: &FusionGroup) -> u64 {
    let produced: Vec<&str> = g
        .einsums
        .iter()
        .filter_map(|&id| c.by_id(id))
        .map(|e| e.output.name.as_str())
        .collect();
    let mut bytes = 0u64;
    // Live-in: shared tensors read by a member but produced elsewhere.
    let mut seen: Vec<&str> = Vec::new();
    for &id in &g.einsums {
        let Some(e) = c.by_id(id) else { continue };
        for op in &e.inputs {
            let name = op.tensor.name.as_str();
            if produced.contains(&name) || seen.contains(&name) || !idx.is_shared(name) {
                continue;
            }
            seen.push(name);
            bytes += op.tensor.bytes();
        }
        // Live-out: produced in-group, needed afterwards (an outside
        // consumer, or a model output with no consumer at all).
        let out = e.output.name.as_str();
        let consumers = idx.consumers_of(out);
        let escapes = consumers.iter().any(|cid| !g.einsums.contains(cid))
            || (consumers.is_empty() && idx.is_shared(out));
        if escapes {
            bytes += e.output.bytes();
        }
    }
    bytes
}

/// Recompute the inter-group bytes `eval_group` should charge for one
/// group under `opts` (see module docs; this mirrors the *documented*
/// semantics, so a dropped or double-counted term in the model shows up
/// as drift).
fn expected_group(
    c: &Cascade,
    idx: &CascadeIndex,
    g: &FusionGroup,
    arch: &ArchSpec,
    opts: &ExecOptions,
) -> u64 {
    let mut inter = 0u64;
    if g.einsums.len() == 1 {
        // Best-unfused: every distinct input in, the output out; shared
        // tensors are the off-chip ones.
        let Some(e) = c.by_id(g.einsums[0]) else { return 0 };
        let mut seen: Vec<&str> = Vec::new();
        for op in &e.inputs {
            let name = op.tensor.name.as_str();
            if !seen.contains(&name) {
                seen.push(name);
                if idx.is_shared(name) {
                    inter += op.tensor.bytes();
                }
            }
        }
        if idx.is_shared(&e.output.name) {
            inter += e.output.bytes();
        }
    } else {
        let passes = analyze_scope_with(c, idx, &g.einsums);
        let internal: Vec<&str> = g.internal_tensors.iter().map(|s| s.as_str()).collect();
        let mut charged: Vec<&str> = Vec::new();
        for &id in &g.einsums {
            let Some(e) = c.by_id(id) else { continue };
            for op in &e.inputs {
                let name = op.tensor.name.as_str();
                if internal.contains(&name) || charged.contains(&name) {
                    continue;
                }
                charged.push(name);
                if idx.is_shared(name) {
                    inter += op.tensor.bytes() * passes.passes_of(name) as u64;
                }
            }
            let out = e.output.name.as_str();
            if !internal.contains(&out) {
                if idx.is_shared(out) {
                    inter += e.output.bytes();
                }
            } else {
                // A multi-pass internal tensor spills at the pass
                // boundary and reloads once per later pass (§VI-C.1).
                let n = passes.passes_of(out) as u64;
                if n > 1 {
                    inter += e.output.bytes() * n; // 1 write + (n-1) reads
                }
            }
        }
        inter += staging_spills(c, idx, g, arch, opts);
        if g.rd_bridged {
            // Each RD bridge round-trips the upstream intermediate
            // through DRAM (partial products out, final values back).
            for j in &g.joins {
                if j.class == Some(FusionClass::RD) {
                    if let Some(up) = j.via.and_then(|via| c.by_id(via)) {
                        inter += 2 * up.output.bytes();
                    }
                }
            }
        }
    }
    if opts.decode_state_io {
        inter += state_io(c, g);
    }
    inter
}

/// MARCA-style full-extent staging: walk members in order tracking live
/// full-extent internal outputs; past the buffer budget (minus resident
/// weights) the largest live tensor round-trips DRAM.
fn staging_spills(
    c: &Cascade,
    idx: &CascadeIndex,
    g: &FusionGroup,
    arch: &ArchSpec,
    opts: &ExecOptions,
) -> u64 {
    if opts.staging != Staging::FullExtent {
        return 0;
    }
    let weights: u64 = g
        .einsums
        .iter()
        .filter_map(|&id| c.by_id(id))
        .map(weight_bytes)
        .sum();
    let budget = arch.buffer_bytes.saturating_sub(weights);
    let mut inter = 0u64;
    let mut live: Vec<(u64, usize)> = Vec::new(); // (bytes, last consumer)
    for &id in &g.einsums {
        let Some(e) = c.by_id(id) else { continue };
        live.retain(|(_, last)| *last >= id);
        if g.internal_tensors.iter().any(|t| t == &e.output.name) {
            let last = idx.consumers_of(&e.output.name).iter().max().copied().unwrap_or(id);
            live.push((e.output.bytes(), last));
        }
        if live.iter().map(|(b, _)| *b).sum::<u64>() > budget {
            live.sort_by_key(|(b, _)| std::cmp::Reverse(*b));
            while live.iter().map(|(b, _)| *b).sum::<u64>() > budget && !live.is_empty() {
                let (bytes, _) = live.remove(0);
                inter += 2 * bytes; // write now, read back at the consumer
            }
        }
    }
    inter
}

/// Decode-step state I/O: each distinct recurrent/windowed operand's
/// live window loads at step start and stores at step end.
fn state_io(c: &Cascade, g: &FusionGroup) -> u64 {
    let mut inter = 0u64;
    let mut seen: Vec<&str> = Vec::new();
    for &id in &g.einsums {
        let Some(e) = c.by_id(id) else { continue };
        for op in &e.inputs {
            if !op.is_recurrent() || seen.contains(&op.tensor.name.as_str()) {
                continue;
            }
            seen.push(&op.tensor.name);
            for (rank, acc) in op.tensor.ranks.iter().zip(&op.accesses) {
                if acc.is_recurrent() && rank.is_generational() {
                    let bytes =
                        op.tensor.generation_bytes(&rank.name) * acc.lookback() * rank.extent;
                    inter += 2 * bytes; // load + store
                }
            }
        }
    }
    inter
}
