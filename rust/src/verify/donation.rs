//! Use-after-overwrite analysis for in-place state donation.
//!
//! With [`crate::runtime::Donation::DonateInPlace`] the engine aliases
//! the [`crate::runtime::StateSlabs`] rows input→output (PJRT buffer
//! donation): the new generation of a state tensor is written over the
//! old one instead of into a fresh buffer. That is only sound if, under
//! the plan's execution order, nothing still needs the pre-update value
//! once the update has committed. Per state tensor `T` (any tensor some
//! Einsum reads through a recurrent access):
//!
//! * **Lagged readers** (`T[i-o]`, the `H[i-1]` recurrence input) read
//!   *only* previous generations. The in-place update commits when the
//!   producer of `T` executes, so every lagged reader must be
//!   positioned strictly *before* the producer. The self-recurrence
//!   (`Hs = ABar·Hs[i-1] + BX`, producer == reader) is safe: the update
//!   is an element-wise read-modify-write of generation `i-1` into `i`.
//! * **Windowed readers** (`T[i-j], j in 0..w`, the conv tail) need the
//!   current column *and* the pre-launch window tail. The runtime
//!   commits the window shift (evicting the oldest column) at the end
//!   of the launch, so the reader only has to come *after* the producer
//!   of the fresh column — the tail it reads is still the pre-launch
//!   slab either way.
//!
//! In prefill (generational extent > 1) the launch iterates generation
//! by generation (§IV-E partitioning), so the same per-generation
//! ordering argument applies unchanged.
//!
//! The verdicts — one `bool` per [`crate::planner::PlanChoice`] — are
//! what [`crate::runtime::EngineCaps::donation_sound`] checks a
//! donation-advertising engine against.

use std::collections::BTreeMap;

use crate::einsum::{Cascade, RankAccess};
use crate::fusion::FusionPlan;

use super::{Finding, FindingCode};

/// The donation-safety verdict for one plan.
#[derive(Debug)]
pub struct DonationVerdict {
    pub safe: bool,
    pub findings: Vec<Finding>,
}

/// Prove (or refute) donation safety of one plan. `loc` prefixes
/// finding locations.
pub fn analyze_plan(c: &Cascade, plan: &FusionPlan, loc: &str) -> DonationVerdict {
    let producers = c.producers();
    let mut pos: BTreeMap<usize, usize> = BTreeMap::new();
    for (p, &id) in plan.groups.iter().flat_map(|g| g.einsums.iter()).enumerate() {
        pos.entry(id).or_insert(p);
    }

    let mut findings = Vec::new();
    for e in c.einsums() {
        for op in &e.inputs {
            if !op.is_recurrent() {
                continue;
            }
            let name = op.tensor.name.as_str();
            let Some(&writer) = producers.get(name) else {
                // Pure-input state: nothing in this launch overwrites it.
                continue;
            };
            let (Some(&pr), Some(&pw)) = (pos.get(&e.id), pos.get(&writer)) else {
                continue; // coverage error, reported by legality
            };
            let lagged = op.accesses.iter().any(|a| matches!(a, RankAccess::Lagged { .. }));
            if lagged {
                if e.id == writer {
                    continue; // element-wise in-place recurrence
                }
                if pr >= pw {
                    findings.push(Finding::error(
                        FindingCode::DonationUnsafe,
                        loc.to_string(),
                        format!(
                            "einsum #{} ({}) reads pre-update state {} at position {pr}, \
                             but the in-place update (#{writer}) commits at position {pw} \
                             — donation would overwrite the value before it is consumed",
                            e.id, e.name, name
                        ),
                    ));
                }
            } else if e.id != writer && pr <= pw {
                findings.push(Finding::error(
                    FindingCode::DonationUnsafe,
                    loc.to_string(),
                    format!(
                        "einsum #{} ({}) reads the windowed state {} at position {pr}, \
                         before its current column is produced (#{writer} at position \
                         {pw}) — the window cannot be completed in place",
                        e.id, e.name, name
                    ),
                ));
            }
        }
    }
    DonationVerdict { safe: findings.is_empty(), findings }
}
