//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute on
//! the request path (adapting /opt/xla-example/load_hlo).

pub mod artifact;
pub mod engine;
pub mod mock;

pub use artifact::{Golden, Manifest};
pub use engine::{argmax_rows, Executor, MambaEngine, StepOutput};
pub use mock::MockEngine;
