//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute on
//! the request path (adapting /opt/xla-example/load_hlo).
//!
//! The engine API is the typed launch surface of [`spec`]: engines
//! implement [`Executor::launch`] over a validated [`LaunchSpec`]
//! (varlen [`MixedBatch`] + [`StateSlabs`] with a [`Donation`]
//! annotation + optional plan + [`Workspace`]) and *declare* what they
//! can fuse in [`EngineCaps`]; the legacy step methods are deprecated
//! wrappers. See [`engine`] for the trait and the default
//! decomposition, [`mock`] for the hermetic fused reference engine.

#![deny(missing_docs)]

pub mod artifact;
pub mod engine;
pub mod fault;
pub mod mock;
pub mod spec;

pub use artifact::{Golden, Manifest};
pub use fault::{FaultInjector, FaultPlan, FaultyEngine};
pub use engine::{
    argmax_rows, argmax_rows_into, Executor, MambaEngine, StepOutput, TrafficCounters, Workspace,
};
pub use mock::MockEngine;
pub use spec::{Donation, EngineCaps, LaunchSpec, MixedBatch, Phase, Segment, StateSlabs};
