//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute on
//! the request path (adapting /opt/xla-example/load_hlo).

pub mod artifact;
pub mod engine;
pub mod mock;

pub use artifact::{Golden, Manifest};
pub use engine::{
    argmax_rows, argmax_rows_into, Executor, MambaEngine, StepOutput, TrafficCounters, Workspace,
};
pub use mock::MockEngine;
