//! Deterministic fault injection for executors.
//!
//! A [`FaultyEngine`] wraps any [`Executor`] and fails launches (or
//! construction) according to a [`FaultPlan`], so every engine failure
//! mode the serving stack must survive — a transient device error, a
//! permanently wedged kernel, an engine that cannot even be built — is
//! a *replayable test input* instead of a hope. The wrapper is driven
//! by a [`FaultInjector`], a cloneable handle that doubles as the
//! observer: tests and benches read [`FaultInjector::faults_injected`]
//! to assert exactly how many faults actually fired.
//!
//! Plans are deterministic by construction: launch counting is
//! per-engine-instance (a respawned worker gets a fresh count), while
//! the `Once` recovery latch and the `Construct` budget are shared
//! across every engine built from the same injector — that is what
//! makes "fail once, then recover" and "fail the first N
//! constructions" meaningful under supervised respawn.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Result};

use super::artifact::Manifest;
use super::engine::{Executor, StepOutput};
use super::spec::{EngineCaps, LaunchSpec};

/// A deterministic engine-failure schedule.
///
/// Launch indices are 1-based and counted **per engine instance**;
/// construction indices are 1-based and counted **per injector**
/// (shared across respawns, which is what lets a construction-retry
/// succeed).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultPlan {
    /// Fail exactly the `n`th launch of every engine instance built
    /// from this injector. A respawned instance fails again at its own
    /// `n`th launch — this is the "permanently faulty shard" plan that
    /// exercises the restart cap.
    Nth(u64),
    /// Fail every `k`th launch (launches `k, 2k, 3k, …`) of each
    /// instance. `Every(0)` never fires.
    Every(u64),
    /// Fail the first launch at index `>= n`, once, across **all**
    /// instances sharing this injector — fail-once-then-recover. The
    /// replacement engine (or any later launch) runs clean.
    Once(u64),
    /// Fail the first `n` constructions ([`FaultInjector::wrap`]),
    /// shared across the injector; construction `n + 1` succeeds. With
    /// `n = u64::MAX` the engine can never be built.
    Construct(u64),
}

impl FaultPlan {
    /// Parse a plan from its CLI spelling: `nth:N`, `every:K`,
    /// `once[:N]` (default `N = 1`), `construct[:N]` (default `N = 1`).
    pub fn parse(s: &str) -> Result<FaultPlan> {
        let (kind, arg) = match s.split_once(':') {
            Some((k, a)) => (k, Some(a)),
            None => (s, None),
        };
        let num = |default: Option<u64>| -> Result<u64> {
            match (arg, default) {
                (Some(a), _) => a
                    .parse::<u64>()
                    .map_err(|e| anyhow::anyhow!("bad fault plan count {a:?}: {e}")),
                (None, Some(d)) => Ok(d),
                (None, None) => bail!("fault plan {kind:?} needs a count, e.g. {kind}:3"),
            }
        };
        match kind {
            "nth" => {
                let n = num(None)?;
                if n == 0 {
                    bail!("nth:0 is meaningless (launches are 1-based)");
                }
                Ok(FaultPlan::Nth(n))
            }
            "every" => Ok(FaultPlan::Every(num(None)?)),
            "once" => Ok(FaultPlan::Once(num(Some(1))?.max(1))),
            "construct" => Ok(FaultPlan::Construct(num(Some(1))?)),
            other => bail!("unknown fault plan {other:?} (want nth:N | every:K | once[:N] | construct[:N])"),
        }
    }
}

/// State shared by every engine built from one injector.
#[derive(Debug, Default)]
struct FaultShared {
    /// Latch for [`FaultPlan::Once`]: set by the single firing.
    fired: AtomicBool,
    /// Constructions attempted via [`FaultInjector::wrap`].
    constructions: AtomicU64,
    /// Faults actually injected (construction + launch).
    injected: AtomicU64,
}

/// Factory-and-observer handle for fault injection.
///
/// Clone it freely: clones share the same counters and `Once` latch,
/// so a test can keep one clone while a worker factory moves another
/// into its thread, and both see the same truth.
#[derive(Debug, Clone)]
pub struct FaultInjector {
    plan: FaultPlan,
    shared: Arc<FaultShared>,
}

impl FaultInjector {
    /// Build an injector for `plan`.
    pub fn new(plan: FaultPlan) -> FaultInjector {
        FaultInjector {
            plan,
            shared: Arc::new(FaultShared::default()),
        }
    }

    /// The plan this injector executes.
    pub fn plan(&self) -> FaultPlan {
        self.plan
    }

    /// Wrap `engine`, applying construction faults.
    ///
    /// Every call counts as one construction attempt; under
    /// [`FaultPlan::Construct(n)`] the first `n` attempts fail (and
    /// count as injected faults), later ones succeed.
    pub fn wrap<E: Executor>(&self, engine: E) -> Result<FaultyEngine<E>> {
        let attempt = self.shared.constructions.fetch_add(1, Ordering::SeqCst) + 1;
        if let FaultPlan::Construct(n) = self.plan {
            if attempt <= n {
                self.shared.injected.fetch_add(1, Ordering::SeqCst);
                bail!("injected construction fault (construction {attempt} of first {n})");
            }
        }
        Ok(FaultyEngine {
            inner: engine,
            injector: self.clone(),
            launches: Cell::new(0),
        })
    }

    /// Total faults injected so far (construction + launch), across
    /// every engine built from this injector.
    pub fn faults_injected(&self) -> u64 {
        self.shared.injected.load(Ordering::SeqCst)
    }

    /// Construction attempts so far (successful or not).
    pub fn constructions(&self) -> u64 {
        self.shared.constructions.load(Ordering::SeqCst)
    }
}

/// An [`Executor`] wrapper that fails launches on a [`FaultPlan`]
/// schedule and otherwise delegates everything to the inner engine.
///
/// Failures are injected only at the [`Executor::launch`] boundary —
/// exactly where the scheduler's poisoning/salvage machinery observes
/// real engine errors — so a `FaultyEngine<MockEngine>` run exercises
/// the same recovery code paths a real device fault would.
#[derive(Debug)]
pub struct FaultyEngine<E> {
    inner: E,
    injector: FaultInjector,
    // `launch` takes `&self`, so the per-instance counter is a Cell.
    launches: Cell<u64>,
}

impl<E> FaultyEngine<E> {
    /// Launches attempted on this instance (including the failing one).
    pub fn launches(&self) -> u64 {
        self.launches.get()
    }
}

impl<E: Executor> Executor for FaultyEngine<E> {
    fn manifest(&self) -> &Manifest {
        self.inner.manifest()
    }

    fn caps(&self) -> EngineCaps {
        self.inner.caps()
    }

    fn prefill(&self, batch: usize, tokens: &[i32]) -> Result<StepOutput> {
        self.inner.prefill(batch, tokens)
    }

    fn decode(
        &self,
        batch: usize,
        tokens: &[i32],
        conv_state: &[f32],
        ssm_state: &[f32],
    ) -> Result<StepOutput> {
        self.inner.decode(batch, tokens, conv_state, ssm_state)
    }

    fn launch(&self, spec: LaunchSpec<'_>) -> Result<()> {
        let n = self.launches.get() + 1;
        self.launches.set(n);
        let fail = match self.injector.plan {
            FaultPlan::Nth(k) => n == k,
            FaultPlan::Every(k) => k > 0 && n % k == 0,
            // Short-circuit keeps the latch untouched until the
            // threshold is reached; the first swap wins.
            FaultPlan::Once(k) => n >= k && !self.injector.shared.fired.swap(true, Ordering::SeqCst),
            FaultPlan::Construct(_) => false,
        };
        if fail {
            self.injector.shared.injected.fetch_add(1, Ordering::SeqCst);
            bail!(
                "injected launch fault (launch {n} under plan {:?})",
                self.injector.plan
            );
        }
        self.inner.launch(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::super::mock::MockEngine;
    use super::super::spec::{Donation, MixedBatch, Phase, Segment, StateSlabs};
    use super::super::Workspace;
    use super::*;

    /// One single-row decode launch from zero state — the smallest
    /// valid `LaunchSpec`, enough to tick the launch counter.
    fn try_launch<E: Executor>(engine: &E, ws: &mut Workspace) -> Result<()> {
        let (nl, cp, sp) = {
            let m = engine.manifest();
            (
                m.n_layer,
                m.d_inner * (m.d_conv - 1),
                m.d_inner * m.d_state,
            )
        };
        let segs = [Segment {
            row: 0,
            len: 1,
            phase: Phase::Decode,
        }];
        let tokens = [3i32];
        let mut conv = vec![0.0f32; nl * cp];
        let mut ssm = vec![0.0f32; nl * sp];
        engine.launch(LaunchSpec {
            batch: MixedBatch::new(&segs, &tokens).unwrap(),
            state: StateSlabs::new(&mut conv, &mut ssm, 1, Donation::Retain),
            plan: None,
            ws,
        })
    }

    #[test]
    fn parse_accepts_the_documented_spellings() {
        assert_eq!(FaultPlan::parse("nth:4").unwrap(), FaultPlan::Nth(4));
        assert_eq!(FaultPlan::parse("every:7").unwrap(), FaultPlan::Every(7));
        assert_eq!(FaultPlan::parse("once").unwrap(), FaultPlan::Once(1));
        assert_eq!(FaultPlan::parse("once:9").unwrap(), FaultPlan::Once(9));
        assert_eq!(FaultPlan::parse("construct").unwrap(), FaultPlan::Construct(1));
        assert_eq!(FaultPlan::parse("construct:2").unwrap(), FaultPlan::Construct(2));
        assert!(FaultPlan::parse("nth:0").is_err());
        assert!(FaultPlan::parse("nth").is_err());
        assert!(FaultPlan::parse("sometimes:3").is_err());
    }

    #[test]
    fn nth_plan_fails_exactly_the_nth_launch_per_instance() {
        let inj = FaultInjector::new(FaultPlan::Nth(3));
        let mut ws = Workspace::default();
        for instance in 0..2 {
            let engine = inj.wrap(MockEngine::new()).unwrap();
            for n in 1..=5u64 {
                let r = try_launch(&engine, &mut ws);
                assert_eq!(r.is_err(), n == 3, "instance {instance} launch {n}");
            }
        }
        assert_eq!(inj.faults_injected(), 2, "each instance fails its own 3rd launch");
    }

    #[test]
    fn once_plan_recovers_on_the_replacement_instance() {
        let inj = FaultInjector::new(FaultPlan::Once(2));
        let mut ws = Workspace::default();
        let first = inj.wrap(MockEngine::new()).unwrap();
        assert!(try_launch(&first, &mut ws).is_ok());
        assert!(try_launch(&first, &mut ws).is_err());
        // The replacement never faults: the shared latch has fired.
        let second = inj.wrap(MockEngine::new()).unwrap();
        for _ in 0..4 {
            assert!(try_launch(&second, &mut ws).is_ok());
        }
        assert_eq!(inj.faults_injected(), 1);
    }

    #[test]
    fn every_plan_fires_on_multiples() {
        let inj = FaultInjector::new(FaultPlan::Every(2));
        let engine = inj.wrap(MockEngine::new()).unwrap();
        let mut ws = Workspace::default();
        let pattern: Vec<bool> = (1..=6).map(|_| try_launch(&engine, &mut ws).is_err()).collect();
        assert_eq!(pattern, [false, true, false, true, false, true]);
        assert_eq!(inj.faults_injected(), 3);
    }

    #[test]
    fn construct_plan_fails_first_n_then_builds() {
        let inj = FaultInjector::new(FaultPlan::Construct(2));
        assert!(inj.wrap(MockEngine::new()).is_err());
        assert!(inj.wrap(MockEngine::new()).is_err());
        let engine = inj.wrap(MockEngine::new()).unwrap();
        let mut ws = Workspace::default();
        assert!(try_launch(&engine, &mut ws).is_ok(), "construct plan never faults launches");
        assert_eq!(inj.constructions(), 3);
        assert_eq!(inj.faults_injected(), 2);
    }
}
