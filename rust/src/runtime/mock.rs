//! A deterministic pure-Rust mock executor with the same interface,
//! state layout (`[layers, batch, …]`, layer-major) and state-carrying
//! semantics as the PJRT engine. Lets the coordinator's batching,
//! scheduling and state-management logic be tested hermetically (no
//! artifacts, no PJRT), including the recurrence-consistency invariant:
//! prefill(t[..k]) + decode over t[k..] ≡ prefill(t).
//!
//! The mock plays the role of a **fused varlen kernel**: its default
//! [`EngineCaps`] declare `varlen_kernel` (plus in-place state and
//! donation), and its [`Executor::launch`] override advances every row
//! in place inside the caller's state slab, computes logits only for
//! each row's *final* position, performs **zero heap allocation**, and
//! records exactly **one device call per launch** — the behaviour a
//! real fused engine (and the paper's resident-intermediate fusion)
//! provides, which the default trait decomposition merely emulates
//! through compiled prefill/decode staging. Construct it with
//! [`MockEngine::with_caps`] and `varlen_kernel: false` to force that
//! same engine through the default decomposition — the toggle the
//! engine-API tests and the `BENCH_engine_api.json` gate flip to price
//! fused-vs-emulated on deterministic counters (1 device call per tick
//! vs `max(chunk)`-ish, zero staged bytes vs gather/scatter per
//! group).
//!
//! The mock also plays the role of a **multi-variant engine** for the
//! planner: whatever the executed plan, `launch` runs the same
//! bit-identical math (so token outputs can never depend on plan
//! choice) but charges the tick with the chosen plan's cost from the
//! analytical accelerator model — at the same power-of-two shape
//! granularity the planner buckets on, mirroring how a real engine
//! pads to compiled batch shapes. Variant choice is thereby observable
//! in the deterministic `modeled_cycles` / `modeled_bytes` workspace
//! counters, which is what the planner gates in tests, benches and CI
//! compare. Unplanned launches (`spec.plan == None`, i.e. the legacy
//! unplanned wrappers) charge nothing, exactly like the legacy
//! surface.

use std::cell::RefCell;

use anyhow::Result;

use crate::planner::{CostModel, PlanBucket, PlanChoice};

use super::artifact::Manifest;
use super::engine::{decompose_launch, Executor, StepOutput, Workspace};
use super::spec::{EngineCaps, LaunchSpec};

/// Mock model: per-layer decaying recurrences over tiny state vectors;
/// logits depend on the whole history through the states.
pub struct MockEngine {
    manifest: Manifest,
    /// Analytical per-plan cost profiles (lazily evaluated, cached) —
    /// the same default model the serving planner predicts with, so
    /// predicted and modeled counters are directly comparable.
    profile: RefCell<CostModel>,
    /// The capability report [`Executor::caps`] returns (defaults to
    /// [`EngineCaps::full`]; see [`MockEngine::with_caps`]).
    caps: EngineCaps,
}

impl MockEngine {
    /// A fully-capable mock: fused varlen launches, in-place state,
    /// donation honoured, every plan executable.
    pub fn new() -> MockEngine {
        MockEngine::with_caps(EngineCaps::full())
    }

    /// A mock with an explicit capability report — the test toggle.
    /// With `varlen_kernel: false` the engine's `launch` delegates to
    /// the default trait decomposition (compiled prefill/decode
    /// staging), so the *same* engine can be priced fused vs emulated
    /// on the same workload; with a restricted `plans` mask the
    /// planner's capability negotiation is exercised end to end.
    pub fn with_caps(caps: EngineCaps) -> MockEngine {
        MockEngine {
            manifest: Manifest {
                model: "mock".into(),
                vocab: 17,
                d_model: 4,
                d_inner: 8,
                d_state: 2,
                d_conv: 4,
                n_layer: 2,
                prefill_len: 8,
                prefill_batches: vec![1, 2, 4],
                decode_batches: vec![1, 2, 4, 8],
                dir: std::path::PathBuf::from("/nonexistent"),
            },
            profile: RefCell::new(CostModel::default_serving()),
            caps,
        }
    }

    /// Conv-state elements per (layer, sequence).
    fn conv_per_layer(&self) -> usize {
        self.manifest.d_inner * (self.manifest.d_conv - 1)
    }

    /// SSM-state elements per (layer, sequence).
    fn ssm_per_layer(&self) -> usize {
        self.manifest.d_inner * self.manifest.d_state
    }

    /// Advance one token for slab row `row` of layer-major state
    /// buffers with `stride` rows per layer, updating the state in
    /// place. Returns the state summary the logits depend on — no
    /// allocation, no logits work (callers materialize logits only for
    /// final positions via [`MockEngine::logits_into`]).
    fn advance(
        &self,
        stride: usize,
        row: usize,
        token: i32,
        conv: &mut [f32],
        ssm: &mut [f32],
    ) -> f32 {
        let t = token as f32;
        let (cp, sp) = (self.conv_per_layer(), self.ssm_per_layer());
        let mut summary = 0f32;
        for l in 0..self.manifest.n_layer {
            let c = &mut conv[(l * stride + row) * cp..(l * stride + row + 1) * cp];
            c.rotate_left(1);
            c[cp - 1] = (t * 0.01 + l as f32).sin();
            summary += c.iter().sum::<f32>();
            let s = &mut ssm[(l * stride + row) * sp..(l * stride + row + 1) * sp];
            for (i, x) in s.iter_mut().enumerate() {
                *x = 0.5 * *x + ((t + i as f32 + l as f32) * 0.1).cos();
            }
            summary += s.iter().sum::<f32>();
        }
        summary
    }

    /// Write the logits row for a position whose post-update state
    /// summary is `summary` and whose input token was `token`.
    fn logits_into(&self, summary: f32, token: i32, out: &mut [f32]) {
        let t = token as f32;
        for (v, x) in out.iter_mut().enumerate() {
            *x = ((v as f32) * 0.3 + summary + t * 0.07).sin();
        }
    }
}

impl Default for MockEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl Executor for MockEngine {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn caps(&self) -> EngineCaps {
        self.caps
    }

    fn prefill(&self, batch: usize, tokens: &[i32]) -> Result<StepOutput> {
        let l = self.manifest.prefill_len;
        anyhow::ensure!(tokens.len() == batch * l, "token shape");
        let vocab = self.manifest.vocab;
        let mut conv = vec![0f32; batch * self.manifest.conv_state_elems()];
        let mut ssm = vec![0f32; batch * self.manifest.ssm_state_elems()];
        let mut logits = vec![0f32; batch * vocab];
        for b in 0..batch {
            let row = &tokens[b * l..(b + 1) * l];
            let mut summary = 0f32;
            for &t in row {
                summary = self.advance(batch, b, t, &mut conv, &mut ssm);
            }
            // Only the last position's logits are observable — earlier
            // positions advance state without materializing a row.
            let last = *row.last().expect("prefill_len >= 1");
            self.logits_into(summary, last, &mut logits[b * vocab..(b + 1) * vocab]);
        }
        Ok(StepOutput { logits, conv_state: conv, ssm_state: ssm })
    }

    fn decode(
        &self,
        batch: usize,
        tokens: &[i32],
        conv_state: &[f32],
        ssm_state: &[f32],
    ) -> Result<StepOutput> {
        anyhow::ensure!(tokens.len() == batch, "token shape");
        let vocab = self.manifest.vocab;
        let mut conv = conv_state.to_vec();
        let mut ssm = ssm_state.to_vec();
        let mut logits = vec![0f32; batch * vocab];
        for b in 0..batch {
            let summary = self.advance(batch, b, tokens[b], &mut conv, &mut ssm);
            self.logits_into(summary, tokens[b], &mut logits[b * vocab..(b + 1) * vocab]);
        }
        Ok(StepOutput { logits, conv_state: conv, ssm_state: ssm })
    }

    /// Native fused varlen launch over caller-owned state slabs: one
    /// scan over all rows, advancing each row **in place** at its
    /// segment's slab row, logits computed only for final positions,
    /// zero heap allocation, one recorded device call — the fused
    /// kernel the default trait decomposition emulates (tests pin the
    /// two bit-identical). When this engine's caps say
    /// `varlen_kernel: false`, the launch delegates to that default
    /// decomposition instead, so fused-vs-emulated is a caps toggle on
    /// the same engine. Planned launches additionally charge the
    /// chosen plan's analytical cost: single-token rows as a batched
    /// decode step with per-step state I/O, multi-token rows as a
    /// prefill of their total token count, both at power-of-two
    /// compiled-shape granularity.
    fn launch(&self, mut spec: LaunchSpec<'_>) -> Result<()> {
        spec.validate(self.manifest())?;
        // Price the plan before executing (the estimate only depends on
        // the batch shape; the charge lands only on success, below).
        let est = spec.plan.map(|choice| {
            let decode_rows = spec.batch.decode_rows();
            let prefill_tokens: usize =
                spec.batch.segments().iter().map(|s| s.len).filter(|&l| l > 1).sum();
            let bucket = PlanBucket::of(decode_rows, prefill_tokens);
            self.profile.borrow_mut().tick_cost(choice, bucket)
        });
        if self.caps.varlen_kernel {
            let batch = spec.batch;
            let vocab = self.manifest.vocab;
            let stride = spec.state.stride();
            let ws = &mut *spec.ws;
            let (conv, ssm) = spec.state.slabs_mut();
            ws.reset_logits(batch.rows(), vocab);
            for (b, seg, toks) in batch.iter() {
                let mut summary = 0f32;
                let mut last = 0i32;
                for &t in toks {
                    summary = self.advance(stride, seg.row, t, conv, ssm);
                    last = t;
                }
                self.logits_into(summary, last, &mut ws.logits[b * vocab..(b + 1) * vocab]);
            }
            ws.record_device_call();
        } else {
            decompose_launch(self, &mut spec)?;
        }
        if let Some(est) = est {
            spec.ws.record_modeled(est.cycles, est.bytes);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // the legacy wrappers are exercised on purpose

    use super::*;
    use crate::runtime::engine::argmax_rows;
    use crate::runtime::spec::{Donation, MixedBatch, Phase, Segment, StateSlabs};

    #[test]
    fn prefill_then_decode_matches_manual_stepping() {
        let e = MockEngine::new();
        let l = e.manifest().prefill_len;
        let tokens: Vec<i32> = (0..l as i32).collect();
        let out = e.prefill(1, &tokens).unwrap();
        let out2 = e.decode(1, &[99], &out.conv_state, &out.ssm_state).unwrap();

        let mut conv = vec![0f32; e.manifest().conv_state_elems()];
        let mut ssm = vec![0f32; e.manifest().ssm_state_elems()];
        let mut summary = 0f32;
        let mut last = 0i32;
        for &t in tokens.iter().chain([99].iter()) {
            summary = e.advance(1, 0, t, &mut conv, &mut ssm);
            last = t;
        }
        let mut logits = vec![0f32; e.manifest().vocab];
        e.logits_into(summary, last, &mut logits);
        assert_eq!(out2.logits, logits);
        assert_eq!(out2.ssm_state, ssm);
    }

    #[test]
    fn batch_rows_independent() {
        // Sequence 0's outputs/states must not depend on sequence 1.
        let e = MockEngine::new();
        let l = e.manifest().prefill_len;
        let t1: Vec<i32> = (0..l as i32).collect();
        let t2: Vec<i32> = (10..10 + l as i32).collect();
        let solo = e.prefill(1, &t1).unwrap();
        let both = e.prefill(2, &[t1.clone(), t2].concat()).unwrap();
        assert_eq!(&both.logits[..e.manifest().vocab], &solo.logits[..]);
        // Layer-major: sequence 0 of layer l sits at offset l*2*per.
        let sp = e.ssm_per_layer();
        for l in 0..e.manifest().n_layer {
            assert_eq!(
                &both.ssm_state[l * 2 * sp..l * 2 * sp + sp],
                &solo.ssm_state[l * sp..(l + 1) * sp],
            );
        }
    }

    #[test]
    fn deterministic_argmax() {
        let e = MockEngine::new();
        let l = e.manifest().prefill_len;
        let t: Vec<i32> = (3..3 + l as i32).collect();
        let a = e.prefill(1, &t).unwrap();
        let b = e.prefill(1, &t).unwrap();
        assert_eq!(
            argmax_rows(&a.logits, e.manifest().vocab),
            argmax_rows(&b.logits, e.manifest().vocab)
        );
    }

    #[test]
    fn step_mixed_fresh_full_rows_equal_prefill() {
        // A mixed batch of full-length zero-state rows IS a prefill
        // (exercised through the deprecated value-semantics wrapper).
        let e = MockEngine::new();
        let l = e.manifest().prefill_len;
        let toks: Vec<i32> = (0..2 * l as i32).collect();
        let zeros_c = vec![0f32; 2 * e.manifest().conv_state_elems()];
        let zeros_s = vec![0f32; 2 * e.manifest().ssm_state_elems()];
        let mixed = e.step_mixed(&[l, l], &toks, &zeros_c, &zeros_s).unwrap();
        let pre = e.prefill(2, &toks).unwrap();
        assert_eq!(mixed.logits, pre.logits);
        assert_eq!(mixed.conv_state, pre.conv_state);
        assert_eq!(mixed.ssm_state, pre.ssm_state);
    }

    #[test]
    fn chunked_scan_carries_state_exactly() {
        // Splitting a prompt into chunks, carrying the packed state
        // between step_mixed calls, lands bit-identical to one
        // monolithic pass — the recurrence-consistency invariant the
        // chunked-prefill scheduler depends on.
        let e = MockEngine::new();
        let l = e.manifest().prefill_len;
        let toks: Vec<i32> = (5..5 + l as i32).collect();
        let mono = e.prefill(1, &toks).unwrap();

        let mut conv = vec![0f32; e.manifest().conv_state_elems()];
        let mut ssm = vec![0f32; e.manifest().ssm_state_elems()];
        let mut last = StepOutput { logits: vec![], conv_state: vec![], ssm_state: vec![] };
        for chunk in toks.chunks(3) {
            last = e.step_mixed(&[chunk.len()], chunk, &conv, &ssm).unwrap();
            conv = last.conv_state.clone();
            ssm = last.ssm_state.clone();
        }
        assert_eq!(last.logits, mono.logits);
        assert_eq!(last.conv_state, mono.conv_state);
        assert_eq!(last.ssm_state, mono.ssm_state);
    }

    #[test]
    fn launch_respects_row_plan_and_stride() {
        // A direct LaunchSpec with a sparse row plan (stride wider than
        // the batch, rows out of order) must agree bit-exactly with the
        // packed step_mixed wrapper, touch exactly the planned rows,
        // and leave every other slab row untouched.
        let e = MockEngine::new();
        let m = e.manifest().clone();
        let (cp, sp) = (e.conv_per_layer(), e.ssm_per_layer());
        let (nl, stride) = (m.n_layer, 5usize);
        let lens = [3usize, 1, 2];
        let tokens = [4i32, 5, 6, 7, 8, 9];
        let rows = [4usize, 0, 2];

        // Seed distinct states for the three sequences via prefill.
        let seed_toks: Vec<i32> = (0..3 * m.prefill_len as i32).collect();
        let seeded = e.prefill(3, &seed_toks).unwrap();

        // Packed reference through the deprecated wrapper.
        let want = e
            .step_mixed(&lens, &tokens, &seeded.conv_state[..], &seeded.ssm_state[..])
            .unwrap();

        // Slab layout: scatter seeded rows 0..3 to slab rows 4, 0, 2;
        // poison the unused rows so silent clobbering is caught.
        let mut conv = vec![-9.0f32; nl * stride * cp];
        let mut ssm = vec![-9.0f32; nl * stride * sp];
        for (src, &row) in rows.iter().enumerate() {
            crate::runtime::engine::copy_state_row(
                nl, cp, &seeded.conv_state, 3, src, &mut conv, stride, row,
            );
            crate::runtime::engine::copy_state_row(
                nl, sp, &seeded.ssm_state, 3, src, &mut ssm, stride, row,
            );
        }
        let segs = [
            Segment { len: 3, row: 4, phase: Phase::PrefillCont },
            Segment { len: 1, row: 0, phase: Phase::Decode },
            Segment { len: 2, row: 2, phase: Phase::PrefillCont },
        ];
        let mut ws = Workspace::new();
        e.launch(LaunchSpec {
            batch: MixedBatch::new(&segs, &tokens).unwrap(),
            state: StateSlabs::new(&mut conv, &mut ssm, stride, Donation::DonateInPlace),
            plan: None,
            ws: &mut ws,
        })
        .unwrap();
        assert_eq!(ws.logits, want.logits);
        // Planned rows carry the final states; unused rows keep poison.
        for (src, &row) in rows.iter().enumerate() {
            for l in 0..nl {
                assert_eq!(
                    &conv[(l * stride + row) * cp..(l * stride + row + 1) * cp],
                    &want.conv_state[(l * 3 + src) * cp..(l * 3 + src + 1) * cp],
                );
                assert_eq!(
                    &ssm[(l * stride + row) * sp..(l * stride + row + 1) * sp],
                    &want.ssm_state[(l * 3 + src) * sp..(l * 3 + src + 1) * sp],
                );
            }
        }
        for untouched in [1usize, 3] {
            for l in 0..nl {
                assert!(conv[(l * stride + untouched) * cp..(l * stride + untouched + 1) * cp]
                    .iter()
                    .all(|&x| x == -9.0));
            }
        }
        // The fused launch stages nothing and runs one device call.
        assert_eq!(ws.traffic().total(), 0);
        assert_eq!(ws.padded_rows(), 0);
        assert_eq!(ws.take_device_calls(), 1);
        // Unplanned launch: no modeled charge.
        assert_eq!(ws.take_modeled(), (0, 0));
    }

    #[test]
    fn default_step_mixed_matches_native_override() {
        // The trait's default decomposition (compiled prefill/decode
        // calls, forced via a caps toggle on the same engine type) and
        // the mock's fused varlen launch must agree bit-exactly on a
        // batch mixing every row kind: a fresh full-length prefill, a
        // mid-prompt chunk with carried state, and two decode rows.
        let native = MockEngine::new();
        let deflt = MockEngine::with_caps(EngineCaps {
            varlen_kernel: false,
            ..EngineCaps::full()
        });
        let m = native.manifest().clone();
        let l = m.prefill_len;
        let (cp, sp) = (m.conv_state_elems() / m.n_layer, m.ssm_state_elems() / m.n_layer);

        // Build carried states for three sequences via a prefill.
        let seed_toks: Vec<i32> = (0..3 * l as i32).collect();
        let seeded = native.prefill(3, &seed_toks).unwrap();

        // Mixed batch rows: [full fresh (l), chunk of 3 carried, decode, decode].
        let lens = [l, 3, 1, 1];
        let mut tokens: Vec<i32> = (40..40 + l as i32).collect();
        tokens.extend_from_slice(&[7, 8, 9]); // chunk row
        tokens.extend_from_slice(&[1, 2]); // decode rows
        let batch = lens.len();
        let mut conv = vec![0f32; m.n_layer * batch * cp];
        let mut ssm = vec![0f32; m.n_layer * batch * sp];
        // Row 0 stays zero (fresh); rows 1..4 carry seeded states 0..3.
        for (row, src) in [(1usize, 0usize), (2, 1), (3, 2)] {
            crate::runtime::engine::copy_state_row(
                m.n_layer, cp, &seeded.conv_state, 3, src, &mut conv, batch, row,
            );
            crate::runtime::engine::copy_state_row(
                m.n_layer, sp, &seeded.ssm_state, 3, src, &mut ssm, batch, row,
            );
        }

        let a = native.step_mixed(&lens, &tokens, &conv, &ssm).unwrap();
        let b = deflt.step_mixed(&lens, &tokens, &conv, &ssm).unwrap();
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.conv_state, b.conv_state);
        assert_eq!(a.ssm_state, b.ssm_state);
    }

    #[test]
    fn default_decomposition_counts_staging_traffic_and_device_calls() {
        // The default path stages through compiled entry points, so its
        // traffic counters must be non-zero for a batch that carries
        // state — the quantity the resident hot path eliminates — and
        // its device-call count exposes the compiled-group structure.
        let deflt =
            MockEngine::with_caps(EngineCaps { varlen_kernel: false, ..EngineCaps::full() });
        let m = deflt.manifest().clone();
        let (cp, sp) = (m.conv_state_elems() / m.n_layer, m.ssm_state_elems() / m.n_layer);
        let batch = 2usize;
        let seeded =
            deflt.prefill(2, &(0..2 * m.prefill_len as i32).collect::<Vec<_>>()).unwrap();
        let mut conv = seeded.conv_state.clone();
        let mut ssm = seeded.ssm_state.clone();
        let rows: Vec<usize> = (0..batch).collect();
        let mut ws = Workspace::new();
        deflt
            .step_mixed_into(&[1, 1], &[3, 4], &rows, &mut conv, &mut ssm, batch, &mut ws)
            .unwrap();
        let t = ws.traffic();
        // Two decode rows fit a compiled batch of 2: gather 2 rows in,
        // scatter 2 rows out, one compiled decode call.
        let row_bytes = (m.n_layer * (cp + sp) * 4) as u64;
        assert_eq!(t.bytes_gathered, 2 * row_bytes);
        assert_eq!(t.bytes_scattered, 2 * row_bytes);
        assert_eq!(ws.padded_rows(), 0);
        assert_eq!(ws.take_device_calls(), 1);

        // Three decode rows pad up to the compiled batch of 4.
        let seeded3 =
            deflt.prefill(3, &(0..3 * m.prefill_len as i32).collect::<Vec<_>>()).unwrap();
        let mut conv3 = seeded3.conv_state.clone();
        let mut ssm3 = seeded3.ssm_state.clone();
        let rows3: Vec<usize> = (0..3).collect();
        let mut ws3 = Workspace::new();
        deflt
            .step_mixed_into(&[1, 1, 1], &[3, 4, 5], &rows3, &mut conv3, &mut ssm3, 3, &mut ws3)
            .unwrap();
        assert_eq!(ws3.padded_rows(), 1);
        assert_eq!(ws3.traffic().bytes_gathered, 4 * row_bytes);
        assert_eq!(ws3.traffic().bytes_scattered, 3 * row_bytes);
        assert_eq!(ws3.take_device_calls(), 1);
    }

    #[test]
    fn decomposition_lockstep_costs_max_chunk_device_calls() {
        // One mid-prompt chunk of length L plus decode rows: the
        // decomposition pays max(chunk) lockstep decode calls for the
        // scan plus one call for the decode group, where the fused
        // launch pays exactly 1 — the engine-API gate's core claim.
        let fused = MockEngine::new();
        let deflt =
            MockEngine::with_caps(EngineCaps { varlen_kernel: false, ..EngineCaps::full() });
        let m = fused.manifest().clone();
        let (cp, sp) = (m.conv_state_elems() / m.n_layer, m.ssm_state_elems() / m.n_layer);
        let seeded =
            fused.prefill(4, &(0..4 * m.prefill_len as i32).collect::<Vec<_>>()).unwrap();
        let chunk_len = 5usize;
        let lens = [chunk_len, 1, 1, 1];
        let tokens: Vec<i32> = (0..(chunk_len + 3) as i32).collect();
        let rows: Vec<usize> = (0..4).collect();
        let run = |e: &MockEngine| {
            let mut conv = seeded.conv_state.clone();
            let mut ssm = seeded.ssm_state.clone();
            let mut ws = Workspace::new();
            e.step_mixed_into(&lens, &tokens, &rows, &mut conv, &mut ssm, 4, &mut ws)
                .unwrap();
            (ws.logits.clone(), conv, ssm, ws.take_device_calls())
        };
        let (fl, fc, fs, f_calls) = run(&fused);
        let (dl, dc, ds, d_calls) = run(&deflt);
        assert_eq!(fl, dl);
        assert_eq!(fc, dc);
        assert_eq!(fs, ds);
        assert_eq!(f_calls, 1, "fused varlen launch is one device call");
        // Scan: chunk_len lockstep positions × one group of 1; decode
        // group: 3 rows fit compiled batch 4 in one call.
        assert_eq!(d_calls, chunk_len as u64 + 1);
        let _ = (cp, sp);
    }

    #[test]
    fn planned_launch_is_bit_identical_across_plans_but_charges_differently() {
        use crate::fusion::FusionVariant;
        let m = MockEngine::new().manifest().clone();
        let lens = [1usize, 1, 5];
        let tokens = [3i32, 4, 5, 6, 7, 8, 9];
        let (cp, sp) = (m.conv_state_elems() / m.n_layer, m.ssm_state_elems() / m.n_layer);
        let run = |choice: PlanChoice| {
            let e = MockEngine::new();
            let mut conv = vec![0f32; m.n_layer * 3 * cp];
            let mut ssm = vec![0f32; m.n_layer * 3 * sp];
            let mut ws = Workspace::new();
            e.step_planned_into(choice, &lens, &tokens, &[0, 1, 2], &mut conv, &mut ssm, 3, &mut ws)
                .unwrap();
            let modeled = ws.take_modeled();
            (ws.logits.clone(), conv, ssm, modeled)
        };
        let ri = run(PlanChoice::Variant(FusionVariant::RIOnly));
        let ff = run(PlanChoice::Variant(FusionVariant::FullyFused));
        // Tokens and state are independent of the plan...
        assert_eq!(ri.0, ff.0);
        assert_eq!(ri.1, ff.1);
        assert_eq!(ri.2, ff.2);
        // ...but the modeled device cost is plan-specific and non-zero.
        assert!(ri.3 .0 > 0 && ff.3 .0 > 0);
        assert_ne!(ri.3, ff.3, "plan choice must be observable in the counters");
    }

    #[test]
    fn planned_launch_charges_at_bucket_granularity() {
        // 5, 6 and 8 decode rows share the pow2 bucket (8): identical
        // modeled charge — the compiled-shape semantics the planner's
        // predictions assume.
        let probe = MockEngine::new();
        let m = probe.manifest().clone();
        let (cp, sp) = (m.conv_state_elems() / m.n_layer, m.ssm_state_elems() / m.n_layer);
        let choice = PlanChoice::Variant(crate::fusion::FusionVariant::RIRSbRSp);
        let charge = |n: usize| {
            let e = MockEngine::new();
            let lens = vec![1usize; n];
            let tokens = vec![2i32; n];
            let rows: Vec<usize> = (0..n).collect();
            let mut conv = vec![0f32; m.n_layer * n * cp];
            let mut ssm = vec![0f32; m.n_layer * n * sp];
            let mut ws = Workspace::new();
            e.step_planned_into(choice, &lens, &tokens, &rows, &mut conv, &mut ssm, n, &mut ws)
                .unwrap();
            ws.take_modeled()
        };
        let c5 = charge(5);
        let c6 = charge(6);
        let c8 = charge(8);
        assert_eq!(c5, c6);
        assert_eq!(c6, c8);
        assert_ne!(charge(4), c8, "different buckets must charge differently");
    }

    #[test]
    fn caps_toggle_reports_what_launch_does() {
        let fused = MockEngine::new();
        assert!(fused.caps().varlen_kernel);
        assert!(fused.caps().in_place_state);
        assert!(fused.caps().donation);
        assert_eq!(fused.caps().plans_available(), PlanChoice::COUNT);

        let mut limited = EngineCaps::full();
        let ff = PlanChoice::candidates()[0];
        limited.plans[ff.index()] = false;
        let e = MockEngine::with_caps(limited);
        assert!(!e.caps().plans[ff.index()]);
        assert_eq!(e.caps().plans_available(), PlanChoice::COUNT - 1);
    }

    #[test]
    fn step_mixed_rejects_bad_shapes() {
        let e = MockEngine::new();
        let zeros_c = vec![0f32; e.manifest().conv_state_elems()];
        let zeros_s = vec![0f32; e.manifest().ssm_state_elems()];
        assert!(e.step_mixed(&[], &[], &[], &[]).is_err());
        assert!(e.step_mixed(&[0], &[], &zeros_c, &zeros_s).is_err());
        assert!(e.step_mixed(&[2], &[1], &zeros_c, &zeros_s).is_err());
        // Row plan out of range / wrong length.
        let mut ws = Workspace::new();
        let mut c = zeros_c.clone();
        let mut s = zeros_s.clone();
        assert!(e.step_mixed_into(&[1], &[1], &[1], &mut c, &mut s, 1, &mut ws).is_err());
        assert!(e.step_mixed_into(&[1], &[1], &[], &mut c, &mut s, 1, &mut ws).is_err());
        // Aliased rows — the contract the typed batch enforces.
        let mut c2 = vec![0f32; 2 * e.manifest().conv_state_elems()];
        let mut s2 = vec![0f32; 2 * e.manifest().ssm_state_elems()];
        let err = e
            .step_mixed_into(&[1, 1], &[1, 2], &[0, 0], &mut c2, &mut s2, 2, &mut ws)
            .unwrap_err();
        assert!(err.to_string().contains("aliased"), "{err}");
    }
}
