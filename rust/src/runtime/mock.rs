//! A deterministic pure-Rust mock executor with the same interface,
//! state layout (`[layers, batch, …]`, layer-major) and state-carrying
//! semantics as the PJRT engine. Lets the coordinator's batching,
//! scheduling and state-management logic be tested hermetically (no
//! artifacts, no PJRT), including the recurrence-consistency invariant:
//! prefill(t[..k]) + decode over t[k..] ≡ prefill(t).

use anyhow::Result;

use super::artifact::Manifest;
use super::engine::{Executor, StepOutput};

/// Mock model: per-layer decaying recurrences over tiny state vectors;
/// logits depend on the whole history through the states.
pub struct MockEngine {
    manifest: Manifest,
}

impl MockEngine {
    pub fn new() -> MockEngine {
        MockEngine {
            manifest: Manifest {
                model: "mock".into(),
                vocab: 17,
                d_model: 4,
                d_inner: 8,
                d_state: 2,
                d_conv: 4,
                n_layer: 2,
                prefill_len: 8,
                prefill_batches: vec![1, 2, 4],
                decode_batches: vec![1, 2, 4, 8],
                dir: std::path::PathBuf::from("/nonexistent"),
            },
        }
    }

    /// Conv-state elements per (layer, sequence).
    fn conv_per_layer(&self) -> usize {
        self.manifest.d_inner * (self.manifest.d_conv - 1)
    }

    /// SSM-state elements per (layer, sequence).
    fn ssm_per_layer(&self) -> usize {
        self.manifest.d_inner * self.manifest.d_state
    }

    /// Advance one token for sequence `b` of `batch`, updating the
    /// layer-major state buffers in place. Returns the logits row.
    fn step_one(
        &self,
        batch: usize,
        b: usize,
        token: i32,
        conv: &mut [f32],
        ssm: &mut [f32],
    ) -> Vec<f32> {
        let t = token as f32;
        let (cp, sp) = (self.conv_per_layer(), self.ssm_per_layer());
        let mut summary = 0f32;
        for l in 0..self.manifest.n_layer {
            let c = &mut conv[(l * batch + b) * cp..(l * batch + b + 1) * cp];
            c.rotate_left(1);
            c[cp - 1] = (t * 0.01 + l as f32).sin();
            summary += c.iter().sum::<f32>();
            let s = &mut ssm[(l * batch + b) * sp..(l * batch + b + 1) * sp];
            for (i, x) in s.iter_mut().enumerate() {
                *x = 0.5 * *x + ((t + i as f32 + l as f32) * 0.1).cos();
            }
            summary += s.iter().sum::<f32>();
        }
        (0..self.manifest.vocab)
            .map(|v| ((v as f32) * 0.3 + summary + t * 0.07).sin())
            .collect()
    }
}

impl Default for MockEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl Executor for MockEngine {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn prefill(&self, batch: usize, tokens: &[i32]) -> Result<StepOutput> {
        let l = self.manifest.prefill_len;
        anyhow::ensure!(tokens.len() == batch * l, "token shape");
        let mut conv = vec![0f32; batch * self.manifest.conv_state_elems()];
        let mut ssm = vec![0f32; batch * self.manifest.ssm_state_elems()];
        let mut logits = Vec::with_capacity(batch * self.manifest.vocab);
        for b in 0..batch {
            let mut last = Vec::new();
            for &t in &tokens[b * l..(b + 1) * l] {
                last = self.step_one(batch, b, t, &mut conv, &mut ssm);
            }
            logits.extend(last);
        }
        Ok(StepOutput { logits, conv_state: conv, ssm_state: ssm })
    }

    fn decode(
        &self,
        batch: usize,
        tokens: &[i32],
        conv_state: &[f32],
        ssm_state: &[f32],
    ) -> Result<StepOutput> {
        anyhow::ensure!(tokens.len() == batch, "token shape");
        let mut conv = conv_state.to_vec();
        let mut ssm = ssm_state.to_vec();
        let mut logits = Vec::with_capacity(batch * self.manifest.vocab);
        for b in 0..batch {
            logits.extend(self.step_one(batch, b, tokens[b], &mut conv, &mut ssm));
        }
        Ok(StepOutput { logits, conv_state: conv, ssm_state: ssm })
    }

    /// Native varlen mixed batch: one scan over all rows, no padding
    /// and no decomposition — the "fused kernel" the default trait
    /// implementation emulates (tests pin the two bit-identical).
    fn step_mixed(
        &self,
        lens: &[usize],
        tokens: &[i32],
        conv_state: &[f32],
        ssm_state: &[f32],
    ) -> Result<StepOutput> {
        let batch = lens.len();
        let vocab = self.manifest.vocab;
        anyhow::ensure!(batch > 0, "empty mixed batch");
        anyhow::ensure!(lens.iter().all(|&l| l >= 1), "zero-length mixed row");
        anyhow::ensure!(tokens.len() == lens.iter().sum::<usize>(), "token shape");
        anyhow::ensure!(
            conv_state.len() == batch * self.manifest.conv_state_elems()
                && ssm_state.len() == batch * self.manifest.ssm_state_elems(),
            "state shape"
        );
        let mut conv = conv_state.to_vec();
        let mut ssm = ssm_state.to_vec();
        let mut logits = vec![0f32; batch * vocab];
        let mut off = 0usize;
        for (b, &len) in lens.iter().enumerate() {
            let mut last = Vec::new();
            for &t in &tokens[off..off + len] {
                last = self.step_one(batch, b, t, &mut conv, &mut ssm);
            }
            logits[b * vocab..(b + 1) * vocab].copy_from_slice(&last);
            off += len;
        }
        Ok(StepOutput { logits, conv_state: conv, ssm_state: ssm })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::engine::argmax_rows;

    #[test]
    fn prefill_then_decode_matches_manual_stepping() {
        let e = MockEngine::new();
        let l = e.manifest().prefill_len;
        let tokens: Vec<i32> = (0..l as i32).collect();
        let out = e.prefill(1, &tokens).unwrap();
        let out2 = e.decode(1, &[99], &out.conv_state, &out.ssm_state).unwrap();

        let mut conv = vec![0f32; e.manifest().conv_state_elems()];
        let mut ssm = vec![0f32; e.manifest().ssm_state_elems()];
        let mut logits = Vec::new();
        for &t in tokens.iter().chain([99].iter()) {
            logits = e.step_one(1, 0, t, &mut conv, &mut ssm);
        }
        assert_eq!(out2.logits, logits);
        assert_eq!(out2.ssm_state, ssm);
    }

    #[test]
    fn batch_rows_independent() {
        // Sequence 0's outputs/states must not depend on sequence 1.
        let e = MockEngine::new();
        let l = e.manifest().prefill_len;
        let t1: Vec<i32> = (0..l as i32).collect();
        let t2: Vec<i32> = (10..10 + l as i32).collect();
        let solo = e.prefill(1, &t1).unwrap();
        let both = e.prefill(2, &[t1.clone(), t2].concat()).unwrap();
        assert_eq!(&both.logits[..e.manifest().vocab], &solo.logits[..]);
        // Layer-major: sequence 0 of layer l sits at offset l*2*per.
        let sp = e.ssm_per_layer();
        for l in 0..e.manifest().n_layer {
            assert_eq!(
                &both.ssm_state[l * 2 * sp..l * 2 * sp + sp],
                &solo.ssm_state[l * sp..(l + 1) * sp],
            );
        }
    }

    #[test]
    fn deterministic_argmax() {
        let e = MockEngine::new();
        let l = e.manifest().prefill_len;
        let t: Vec<i32> = (3..3 + l as i32).collect();
        let a = e.prefill(1, &t).unwrap();
        let b = e.prefill(1, &t).unwrap();
        assert_eq!(
            argmax_rows(&a.logits, e.manifest().vocab),
            argmax_rows(&b.logits, e.manifest().vocab)
        );
    }

    #[test]
    fn step_mixed_fresh_full_rows_equal_prefill() {
        // A mixed batch of full-length zero-state rows IS a prefill.
        let e = MockEngine::new();
        let l = e.manifest().prefill_len;
        let toks: Vec<i32> = (0..2 * l as i32).collect();
        let zeros_c = vec![0f32; 2 * e.manifest().conv_state_elems()];
        let zeros_s = vec![0f32; 2 * e.manifest().ssm_state_elems()];
        let mixed = e.step_mixed(&[l, l], &toks, &zeros_c, &zeros_s).unwrap();
        let pre = e.prefill(2, &toks).unwrap();
        assert_eq!(mixed.logits, pre.logits);
        assert_eq!(mixed.conv_state, pre.conv_state);
        assert_eq!(mixed.ssm_state, pre.ssm_state);
    }

    #[test]
    fn chunked_scan_carries_state_exactly() {
        // Splitting a prompt into chunks, carrying the packed state
        // between step_mixed calls, lands bit-identical to one
        // monolithic pass — the recurrence-consistency invariant the
        // chunked-prefill scheduler depends on.
        let e = MockEngine::new();
        let l = e.manifest().prefill_len;
        let toks: Vec<i32> = (5..5 + l as i32).collect();
        let mono = e.prefill(1, &toks).unwrap();

        let mut conv = vec![0f32; e.manifest().conv_state_elems()];
        let mut ssm = vec![0f32; e.manifest().ssm_state_elems()];
        let mut last = StepOutput { logits: vec![], conv_state: vec![], ssm_state: vec![] };
        for chunk in toks.chunks(3) {
            last = e.step_mixed(&[chunk.len()], chunk, &conv, &ssm).unwrap();
            conv = last.conv_state.clone();
            ssm = last.ssm_state.clone();
        }
        assert_eq!(last.logits, mono.logits);
        assert_eq!(last.conv_state, mono.conv_state);
        assert_eq!(last.ssm_state, mono.ssm_state);
    }

    /// Delegates everything except `step_mixed`, so calls fall through
    /// to the Executor trait's default decomposition.
    struct DefaultMixed(MockEngine);

    impl Executor for DefaultMixed {
        fn manifest(&self) -> &Manifest {
            self.0.manifest()
        }
        fn prefill(&self, batch: usize, tokens: &[i32]) -> Result<StepOutput> {
            self.0.prefill(batch, tokens)
        }
        fn decode(
            &self,
            batch: usize,
            tokens: &[i32],
            conv: &[f32],
            ssm: &[f32],
        ) -> Result<StepOutput> {
            self.0.decode(batch, tokens, conv, ssm)
        }
    }

    #[test]
    fn default_step_mixed_matches_native_override() {
        // The trait's default decomposition (compiled prefill/decode
        // calls) and the mock's fused varlen override must agree
        // bit-exactly on a batch mixing every row kind: a fresh
        // full-length prefill, a mid-prompt chunk with carried state,
        // and two decode rows.
        let native = MockEngine::new();
        let deflt = DefaultMixed(MockEngine::new());
        let m = native.manifest().clone();
        let l = m.prefill_len;
        let (cp, sp) = (m.conv_state_elems() / m.n_layer, m.ssm_state_elems() / m.n_layer);

        // Build carried states for three sequences via a prefill.
        let seed_toks: Vec<i32> = (0..3 * l as i32).collect();
        let seeded = native.prefill(3, &seed_toks).unwrap();

        // Mixed batch rows: [full fresh (l), chunk of 3 carried, decode, decode].
        let lens = [l, 3, 1, 1];
        let mut tokens: Vec<i32> = (40..40 + l as i32).collect();
        tokens.extend_from_slice(&[7, 8, 9]); // chunk row
        tokens.extend_from_slice(&[1, 2]); // decode rows
        let batch = lens.len();
        let mut conv = vec![0f32; m.n_layer * batch * cp];
        let mut ssm = vec![0f32; m.n_layer * batch * sp];
        // Row 0 stays zero (fresh); rows 1..4 carry seeded states 0..3.
        for (row, src) in [(1usize, 0usize), (2, 1), (3, 2)] {
            crate::runtime::engine::copy_state_row(
                m.n_layer, cp, &seeded.conv_state, 3, src, &mut conv, batch, row,
            );
            crate::runtime::engine::copy_state_row(
                m.n_layer, sp, &seeded.ssm_state, 3, src, &mut ssm, batch, row,
            );
        }

        let a = native.step_mixed(&lens, &tokens, &conv, &ssm).unwrap();
        let b = deflt.step_mixed(&lens, &tokens, &conv, &ssm).unwrap();
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.conv_state, b.conv_state);
        assert_eq!(a.ssm_state, b.ssm_state);
    }

    #[test]
    fn step_mixed_rejects_bad_shapes() {
        let e = MockEngine::new();
        let zeros_c = vec![0f32; e.manifest().conv_state_elems()];
        let zeros_s = vec![0f32; e.manifest().ssm_state_elems()];
        assert!(e.step_mixed(&[], &[], &[], &[]).is_err());
        assert!(e.step_mixed(&[0], &[], &zeros_c, &zeros_s).is_err());
        assert!(e.step_mixed(&[2], &[1], &zeros_c, &zeros_s).is_err());
    }
}
