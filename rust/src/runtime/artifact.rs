//! Artifact manifest: what `make artifacts` (python/compile/aot.py)
//! produced and how to drive it.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::JsonValue;

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Model name (e.g. `mamba_tiny`).
    pub model: String,
    /// Vocabulary size (logits row width).
    pub vocab: usize,
    /// Model embedding width `d_model`.
    pub d_model: usize,
    /// Inner (expanded) width `D = E·d_model`.
    pub d_inner: usize,
    /// Recurrent state width `N` per channel.
    pub d_state: usize,
    /// Causal-conv kernel width `J` (the conv state carries `J−1` taps).
    pub d_conv: usize,
    /// Number of layers.
    pub n_layer: usize,
    /// Sequence length the prefill executables were compiled for.
    pub prefill_len: usize,
    /// Batch sizes with a compiled prefill executable.
    pub prefill_batches: Vec<usize>,
    /// Batch sizes with a compiled decode executable.
    pub decode_batches: Vec<usize>,
    /// The artifacts directory the manifest was loaded from.
    pub dir: PathBuf,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let v = JsonValue::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
        let get_usize = |k: &str| -> Result<usize> {
            v.get(k)
                .and_then(|x| x.as_i64())
                .map(|x| x as usize)
                .ok_or_else(|| anyhow!("manifest missing {k}"))
        };
        let get_vec = |k: &str| -> Result<Vec<usize>> {
            Ok(v.get(k)
                .and_then(|x| x.as_arr())
                .ok_or_else(|| anyhow!("manifest missing {k}"))?
                .iter()
                .filter_map(|x| x.as_i64())
                .map(|x| x as usize)
                .collect())
        };
        Ok(Manifest {
            model: v
                .get("model")
                .and_then(|x| x.as_str())
                .unwrap_or("unknown")
                .to_string(),
            vocab: get_usize("vocab")?,
            d_model: get_usize("d_model")?,
            d_inner: get_usize("d_inner")?,
            d_state: get_usize("d_state")?,
            d_conv: get_usize("d_conv")?,
            n_layer: get_usize("n_layer")?,
            prefill_len: get_usize("prefill_len")?,
            prefill_batches: get_vec("prefill_batches")?,
            decode_batches: get_vec("decode_batches")?,
            dir,
        })
    }

    /// Path of the prefill HLO for a batch size.
    pub fn prefill_path(&self, batch: usize) -> PathBuf {
        self.dir.join(format!("mamba_tiny_prefill_b{batch}.hlo.txt"))
    }

    /// Path of the decode HLO for a batch size.
    pub fn decode_path(&self, batch: usize) -> PathBuf {
        self.dir.join(format!("mamba_tiny_decode_b{batch}.hlo.txt"))
    }

    /// Elements in one sequence's conv state (layers × D × (J−1)).
    pub fn conv_state_elems(&self) -> usize {
        self.n_layer * self.d_inner * (self.d_conv - 1)
    }

    /// Elements in one sequence's SSM state (layers × D × N).
    pub fn ssm_state_elems(&self) -> usize {
        self.n_layer * self.d_inner * self.d_state
    }
}

/// Golden test vectors exported by aot.py (used by the runtime
/// integration test).
#[derive(Debug, Clone)]
pub struct Golden {
    /// The token batch the golden prefill ran on.
    pub prefill_tokens: Vec<i32>,
    /// A sample of the golden prefill logits (first row prefix).
    pub prefill_logits_sample: Vec<f32>,
    /// Per-row argmax of the golden prefill logits.
    pub prefill_logits_argmax: Vec<i64>,
    /// The token batch the golden decode step ran on.
    pub decode_token: Vec<i32>,
    /// A sample of the golden decode logits (first row prefix).
    pub decode_logits_sample: Vec<f32>,
    /// Per-row argmax of the golden decode logits.
    pub decode_logits_argmax: Vec<i64>,
    /// Checksum of the golden post-decode SSM state.
    pub ssm_state_sum: f64,
}

impl Golden {
    /// Load `golden.json` from an artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Golden> {
        let path = dir.as_ref().join("golden.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = JsonValue::parse(&text).map_err(|e| anyhow!("golden parse: {e}"))?;
        let ints = |k: &str| -> Vec<i64> {
            v.get(k)
                .and_then(|x| x.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_i64()).collect())
                .unwrap_or_default()
        };
        let floats = |k: &str| -> Vec<f32> {
            v.get(k)
                .and_then(|x| x.as_arr())
                .map(|a| a.iter().filter_map(|x| x.as_f64()).map(|f| f as f32).collect())
                .unwrap_or_default()
        };
        Ok(Golden {
            prefill_tokens: ints("prefill_tokens").iter().map(|&x| x as i32).collect(),
            prefill_logits_sample: floats("prefill_logits_sample"),
            prefill_logits_argmax: ints("prefill_logits_argmax"),
            decode_token: ints("decode_token").iter().map(|&x| x as i32).collect(),
            decode_logits_sample: floats("decode_logits_sample"),
            decode_logits_argmax: ints("decode_logits_argmax"),
            ssm_state_sum: v.get("ssm_state_sum").and_then(|x| x.as_f64()).unwrap_or(0.0),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn manifest_loads_when_built() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.d_conv, 4);
        assert!(m.prefill_batches.contains(&1));
        assert!(m.prefill_path(1).exists());
        assert!(m.decode_path(1).exists());
        assert_eq!(m.ssm_state_elems(), m.n_layer * m.d_inner * m.d_state);
    }

    #[test]
    fn golden_loads_when_built() {
        let dir = artifacts_dir();
        if !dir.join("golden.json").exists() {
            return;
        }
        let g = Golden::load(&dir).unwrap();
        assert_eq!(g.prefill_logits_argmax.len(), 2);
        assert_eq!(g.decode_token.len(), 2);
        assert!(!g.prefill_logits_sample.is_empty());
    }
}
