//! The typed engine-launch surface: one validated description of one
//! engine invocation.
//!
//! Mambalaya's fusion mappings only pay off when the runtime can hand
//! the engine a *whole varlen cascade* in one launch and let state live
//! on-device. The legacy `Executor` surface grew four overlapping step
//! entry points behind a seven-positional-slice calling convention
//! (`lens, tokens, rows, conv, ssm, stride, ws`) that could express
//! neither of the remaining ROADMAP items (PJRT buffer donation, a true
//! varlen fused chunk kernel). This module replaces that convention
//! with three typed objects and one bundle:
//!
//! * [`MixedBatch`] — a **validated view** over one tick's varlen
//!   batch: per-row [`Segment`]s (`len`, slab `row`, [`Phase`]) over a
//!   flat token buffer. Constructed once by the scheduler;
//!   [`MixedBatch::new`] centralizes the shape checks that used to be
//!   scattered `ensure!`s in the default engine decomposition — and
//!   *enforces* the row-aliasing contract (two batch rows sharing one
//!   slab row would silently corrupt state in an in-place engine, so
//!   aliased rows are a construction error, not a documented footgun).
//! * [`StateSlabs`] — the borrowed layer-major conv/ssm slab pair with
//!   its row `stride` and a [`Donation`] annotation, so a real PJRT
//!   backend can mark the state inputs as donated/aliased buffers
//!   while the [`Workspace`](super::engine::Workspace) traffic
//!   counters keep pricing whatever the engine actually copies.
//! * [`EngineCaps`] — the engine's capability report. The scheduler
//!   reads it once at construction: the planner masks out fusion plans
//!   the engine cannot execute ([`crate::planner::Planner::apply_caps`]),
//!   and the state path is chosen from `in_place_state` instead of
//!   being hardcoded. This replaces the old `register_variant`
//!   trial-and-error negotiation.
//!
//! A [`LaunchSpec`] bundles a `MixedBatch` + `StateSlabs` + an optional
//! [`PlanChoice`] + the caller's `Workspace`, and is the single
//! argument of [`Executor::launch`](super::engine::Executor::launch) —
//! the one entry point every engine implements. The legacy step
//! methods survive as thin deprecated wrappers that build a
//! `LaunchSpec`.
//!
//! ## The `Donation` contract
//!
//! With [`Donation::Retain`] the engine must treat the slabs as live
//! caller memory: it may stage copies out of them (counted in the
//! workspace [`TrafficCounters`](super::engine::TrafficCounters)) and
//! must write each row's final state back before returning. With
//! [`Donation::DonateInPlace`] the caller additionally promises not to
//! read any launched row until the call returns, so a device backend
//! may alias the state inputs to its outputs (PJRT input/output buffer
//! donation) and update them truly in place — no device-side
//! round-trip through fresh allocations. Host-side engines (the mock,
//! the default decomposition) already advance the slabs in place, so
//! for them the annotation is observability only: the traffic counters
//! price what is still copied either way. On error the slab contents
//! are unspecified under either annotation (rows may be partially
//! advanced) — the scheduler poisons itself accordingly.

use crate::planner::PlanChoice;

use super::artifact::Manifest;
use super::engine::Workspace;

/// What one batch row does this tick — declared by the scheduler so
/// engines never have to re-derive it by scanning state memory.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// A single-token decode step (`len == 1`).
    Decode,
    /// A prefill chunk starting from **zero state** (the first chunk of
    /// a prompt; the caller guarantees the row's slab state is zero).
    PrefillFirst,
    /// A mid-prompt prefill chunk continuing from carried state.
    PrefillCont,
}

/// One row of a [`MixedBatch`]: how many flat tokens it consumes, which
/// slab row holds its recurrent state, and its declared [`Phase`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Segment {
    /// Tokens this row consumes from the flat token buffer (≥ 1).
    pub len: usize,
    /// Slab row index holding this sequence's state (must be unique
    /// within the batch — enforced by [`MixedBatch::new`]).
    pub row: usize,
    /// Declared phase; [`Phase::Decode`] iff `len == 1`.
    pub phase: Phase,
}

/// A validated view over one tick's varlen batch: per-row [`Segment`]s
/// plus the flat token buffer they index into. Constructing one proves
/// the shape invariants the engines rely on, so engine implementations
/// validate the *slab* shapes (via [`LaunchSpec::validate`]) and
/// nothing else.
#[derive(Debug, Clone, Copy)]
pub struct MixedBatch<'a> {
    segs: &'a [Segment],
    tokens: &'a [i32],
}

impl<'a> MixedBatch<'a> {
    /// Validate and wrap a batch view. Errors (instead of corrupting
    /// state later) on: an empty batch, a zero-length row, a
    /// phase/length mismatch (`Decode` ⇔ `len == 1`), a token buffer
    /// that does not match `Σ len`, and — the contract the legacy
    /// surface only documented — two segments aliasing one slab row.
    pub fn new(segs: &'a [Segment], tokens: &'a [i32]) -> anyhow::Result<MixedBatch<'a>> {
        anyhow::ensure!(!segs.is_empty(), "empty mixed batch");
        let mut total = 0usize;
        for s in segs {
            anyhow::ensure!(s.len >= 1, "zero-length mixed row");
            anyhow::ensure!(
                (s.len == 1) == (s.phase == Phase::Decode),
                "phase {:?} inconsistent with len {}",
                s.phase,
                s.len
            );
            total += s.len;
        }
        anyhow::ensure!(
            tokens.len() == total,
            "mixed tokens: got {}, want {total}",
            tokens.len()
        );
        // Distinct-rows contract: aliasing two batch rows onto one slab
        // row silently corrupts state under any in-place engine.
        // Batches are scheduler-tick sized (tens of rows), so the
        // allocation-free pairwise check beats building a set.
        for (i, a) in segs.iter().enumerate() {
            for b in &segs[i + 1..] {
                anyhow::ensure!(
                    a.row != b.row,
                    "aliased slab row {} in mixed batch (rows must be distinct)",
                    a.row
                );
            }
        }
        Ok(MixedBatch { segs, tokens })
    }

    /// Number of batch rows.
    pub fn rows(&self) -> usize {
        self.segs.len()
    }

    /// The per-row segments.
    pub fn segments(&self) -> &'a [Segment] {
        self.segs
    }

    /// The flat token buffer (`Σ len` tokens, row-major).
    pub fn tokens(&self) -> &'a [i32] {
        self.tokens
    }

    /// Total tokens across all rows.
    pub fn total_tokens(&self) -> usize {
        self.tokens.len()
    }

    /// Iterate `(batch index, segment, this row's token slice)` — the
    /// walk both the default decomposition and fused engines use.
    pub fn iter(&self) -> impl Iterator<Item = (usize, Segment, &'a [i32])> {
        let (segs, tokens) = (self.segs, self.tokens);
        let mut off = 0usize;
        segs.iter().enumerate().map(move |(b, &seg)| {
            let slice = &tokens[off..off + seg.len];
            off += seg.len;
            (b, seg, slice)
        })
    }

    /// Fill `offs` with each row's starting offset into the flat token
    /// buffer (cleared first; reuses capacity).
    pub fn fill_offsets(&self, offs: &mut Vec<usize>) {
        offs.clear();
        let mut o = 0usize;
        for s in self.segs {
            offs.push(o);
            o += s.len;
        }
    }

    /// Rows advancing exactly one token (the engine-visible decode set).
    pub fn decode_rows(&self) -> usize {
        self.segs.iter().filter(|s| s.len == 1).count()
    }

    /// Longest multi-token chunk in the batch (0 when decode-only).
    pub fn max_chunk(&self) -> usize {
        self.segs.iter().map(|s| s.len).filter(|&l| l > 1).max().unwrap_or(0)
    }
}

/// How the engine may treat the caller's state slabs for one launch.
/// See the module docs for the full contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Donation {
    /// Live caller memory: stage copies if you must (priced by the
    /// workspace traffic counters), write final rows back on success.
    Retain,
    /// The caller will not read launched rows mid-call: a device
    /// backend may alias state inputs to outputs (PJRT buffer
    /// donation) and update them in place.
    DonateInPlace,
}

/// The borrowed layer-major state slab pair one launch advances:
/// `[layers, stride, per-layer]` conv and ssm slabs, the row `stride`,
/// and the caller's [`Donation`] annotation.
#[derive(Debug)]
pub struct StateSlabs<'a> {
    conv: &'a mut [f32],
    ssm: &'a mut [f32],
    stride: usize,
    donation: Donation,
}

impl<'a> StateSlabs<'a> {
    /// Wrap the slab pair. Shape validation against the model's
    /// dimensions happens in [`LaunchSpec::validate`] (it needs the
    /// manifest).
    pub fn new(
        conv: &'a mut [f32],
        ssm: &'a mut [f32],
        stride: usize,
        donation: Donation,
    ) -> StateSlabs<'a> {
        StateSlabs { conv, ssm, stride, donation }
    }

    /// Rows per layer stripe.
    pub fn stride(&self) -> usize {
        self.stride
    }

    /// The caller's donation annotation for this launch.
    pub fn donation(&self) -> Donation {
        self.donation
    }

    /// Shared views of both slabs: `(conv, ssm)`.
    pub fn slabs(&self) -> (&[f32], &[f32]) {
        (&*self.conv, &*self.ssm)
    }

    /// Mutable views of both slabs: `(conv, ssm)`.
    pub fn slabs_mut(&mut self) -> (&mut [f32], &mut [f32]) {
        (&mut *self.conv, &mut *self.ssm)
    }
}

/// An engine's capability report: which launch shapes it can fuse and
/// which fusion plans it can execute. The scheduler reads this once at
/// construction and negotiates from it — replacing the old
/// `register_variant` trial-and-error (announce every candidate, treat
/// an `Err` as "unavailable").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineCaps {
    /// The engine executes a whole varlen [`MixedBatch`] as **one**
    /// fused device launch (`device_calls == 1` per tick). When false,
    /// the default trait decomposition emulates the varlen call through
    /// the compiled prefill/decode entry points — `max(chunk)` lockstep
    /// device calls per tick plus staging traffic.
    pub varlen_kernel: bool,
    /// The engine advances caller-owned slab rows in place at arbitrary
    /// strides (the resident-arena contract). When false the scheduler
    /// falls back to the packed reference data path.
    pub in_place_state: bool,
    /// The engine honours [`Donation::DonateInPlace`] — it aliases
    /// state inputs to outputs device-side (PJRT buffer donation)
    /// instead of round-tripping through fresh device allocations.
    pub donation: bool,
    /// Per-[`PlanChoice`] executability, indexed by
    /// [`PlanChoice::index`]. The planner never selects an unavailable
    /// plan ([`crate::planner::Planner::apply_caps`]) — except for a
    /// degenerate report that masks out *every* candidate, where one
    /// stays selectable so serving can proceed and the inconsistency
    /// is loudly reported at construction.
    pub plans: [bool; PlanChoice::COUNT],
}

impl EngineCaps {
    /// The conservative baseline every engine satisfies by construction
    /// of the default trait methods: no fused varlen kernel, in-place
    /// slab advancement via the decomposition, no donation, and every
    /// plan nominally executable (a single-mapping engine executes its
    /// one compiled mapping whatever the plan says).
    pub fn baseline() -> EngineCaps {
        EngineCaps {
            varlen_kernel: false,
            in_place_state: true,
            donation: false,
            plans: [true; PlanChoice::COUNT],
        }
    }

    /// Everything on — what a fully fused in-process engine (the mock)
    /// or a finished PJRT varlen backend advertises.
    pub fn full() -> EngineCaps {
        EngineCaps { varlen_kernel: true, in_place_state: true, donation: true, ..EngineCaps::baseline() }
    }

    /// Number of executable plans.
    pub fn plans_available(&self) -> usize {
        self.plans.iter().filter(|&&p| p).count()
    }

    /// Is this capability report consistent with per-plan
    /// donation-safety verdicts (indexed by [`PlanChoice::index`], as
    /// computed by `verify::donation`)? An engine may only advertise
    /// `donation` if every plan it declares executable is proven safe
    /// to run over in-place-donated [`StateSlabs`] — otherwise a
    /// planner pick could read pre-update state after the overwrite.
    pub fn donation_sound(&self, donation_safe: &[bool; PlanChoice::COUNT]) -> bool {
        !self.donation
            || self.plans.iter().zip(donation_safe.iter()).all(|(&enabled, &safe)| !enabled || safe)
    }

    /// One-line operator summary (`serve_mamba` prints this at startup
    /// so operators can see which fused paths a backend advertises).
    pub fn summary(&self) -> String {
        let yn = |b: bool| if b { "yes" } else { "no" };
        let missing: Vec<String> = PlanChoice::candidates()
            .iter()
            .filter(|c| !self.plans[c.index()])
            .map(|c| c.name())
            .collect();
        let plans = if missing.is_empty() {
            format!("{}/{}", PlanChoice::COUNT, PlanChoice::COUNT)
        } else {
            format!(
                "{}/{} (unavailable: {})",
                self.plans_available(),
                PlanChoice::COUNT,
                missing.join(",")
            )
        };
        format!(
            "varlen_kernel={} in_place_state={} donation={} plans={}",
            yn(self.varlen_kernel),
            yn(self.in_place_state),
            yn(self.donation),
            plans
        )
    }
}

impl Default for EngineCaps {
    fn default() -> Self {
        EngineCaps::baseline()
    }
}

/// Everything one engine invocation needs, in one typed bundle: the
/// validated varlen batch, the state slabs it advances, the fusion
/// plan the planner chose (`None` for unplanned legacy calls — the
/// engine executes its default mapping and models no plan cost), and
/// the caller's persistent [`Workspace`] (logits surface, staging
/// buffers, traffic / device-call / modeled-cost counters).
#[derive(Debug)]
pub struct LaunchSpec<'a> {
    /// The tick's varlen batch view.
    pub batch: MixedBatch<'a>,
    /// The state slabs the launch advances.
    pub state: StateSlabs<'a>,
    /// The fusion plan to execute, if the caller planned one.
    pub plan: Option<PlanChoice>,
    /// The caller's persistent workspace.
    pub ws: &'a mut Workspace,
}

impl<'a> LaunchSpec<'a> {
    /// Validate the batch↔slab agreement an engine must rely on: every
    /// segment row within `stride`, and both slabs shaped
    /// `[layers, stride, per-layer]` for this manifest. Engines call
    /// this first (batch-internal invariants already hold by
    /// [`MixedBatch::new`] construction).
    pub fn validate(&self, m: &Manifest) -> anyhow::Result<()> {
        let stride = self.state.stride();
        for s in self.batch.segments() {
            anyhow::ensure!(s.row < stride, "row index {} past stride {stride}", s.row);
        }
        let (nl, cp, sp) =
            (m.n_layer, m.d_inner * (m.d_conv - 1), m.d_inner * m.d_state);
        let (conv, ssm) = self.state.slabs();
        anyhow::ensure!(
            conv.len() == nl * stride * cp,
            "mixed conv slab: got {}, want {}",
            conv.len(),
            nl * stride * cp
        );
        anyhow::ensure!(
            ssm.len() == nl * stride * sp,
            "mixed ssm slab: got {}, want {}",
            ssm.len(),
            nl * stride * sp
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seg(len: usize, row: usize) -> Segment {
        let phase = if len == 1 { Phase::Decode } else { Phase::PrefillCont };
        Segment { len, row, phase }
    }

    #[test]
    fn mixed_batch_validates_shapes() {
        let toks = [1i32, 2, 3, 4];
        let segs = [seg(3, 0), seg(1, 1)];
        let b = MixedBatch::new(&segs, &toks).unwrap();
        assert_eq!(b.rows(), 2);
        assert_eq!(b.total_tokens(), 4);
        assert_eq!(b.decode_rows(), 1);
        assert_eq!(b.max_chunk(), 3);

        assert!(MixedBatch::new(&[], &[]).is_err(), "empty batch");
        assert!(
            MixedBatch::new(&[Segment { len: 0, row: 0, phase: Phase::Decode }], &[]).is_err(),
            "zero-length row"
        );
        assert!(MixedBatch::new(&segs, &toks[..3]).is_err(), "token shortfall");
        assert!(
            MixedBatch::new(&[Segment { len: 2, row: 0, phase: Phase::Decode }], &toks[..2])
                .is_err(),
            "decode phase on a multi-token row"
        );
        assert!(
            MixedBatch::new(&[Segment { len: 1, row: 0, phase: Phase::PrefillCont }], &toks[..1])
                .is_err(),
            "prefill phase on a unit row"
        );
    }

    #[test]
    fn mixed_batch_rejects_aliased_rows() {
        // The regression the legacy surface could not catch: two batch
        // rows sharing slab row 3 would silently corrupt state in any
        // in-place engine. Construction must fail instead.
        let toks = [1i32, 2, 3];
        let segs = [seg(1, 3), seg(1, 0), seg(1, 3)];
        let err = MixedBatch::new(&segs, &toks).unwrap_err();
        assert!(err.to_string().contains("aliased slab row 3"), "{err}");
    }

    #[test]
    fn iter_walks_rows_with_token_slices() {
        let toks = [10i32, 11, 12, 13, 14, 15];
        let segs = [seg(2, 4), seg(1, 0), seg(3, 2)];
        let b = MixedBatch::new(&segs, &toks).unwrap();
        let walked: Vec<(usize, usize, Vec<i32>)> =
            b.iter().map(|(i, s, t)| (i, s.row, t.to_vec())).collect();
        assert_eq!(
            walked,
            vec![
                (0, 4, vec![10, 11]),
                (1, 0, vec![12]),
                (2, 2, vec![13, 14, 15]),
            ]
        );
        let mut offs = Vec::new();
        b.fill_offsets(&mut offs);
        assert_eq!(offs, vec![0, 2, 3]);
    }

    #[test]
    fn launch_spec_validates_slab_shapes() {
        // Hand-built tiny manifest: 2 layers, cp = 8*3 = 24, sp = 8*2 = 16.
        let m = Manifest {
            model: "test".into(),
            vocab: 17,
            d_model: 4,
            d_inner: 8,
            d_state: 2,
            d_conv: 4,
            n_layer: 2,
            prefill_len: 8,
            prefill_batches: vec![1],
            decode_batches: vec![1],
            dir: std::path::PathBuf::from("/nonexistent"),
        };
        let (cp, sp) = (24usize, 16usize);
        let stride = 3usize;
        let mut conv = vec![0f32; 2 * stride * cp];
        let mut ssm = vec![0f32; 2 * stride * sp];
        let toks = [5i32];
        let segs = [seg(1, 2)];
        let batch = MixedBatch::new(&segs, &toks).unwrap();
        let mut ws = Workspace::new();
        let spec = LaunchSpec {
            batch,
            state: StateSlabs::new(&mut conv, &mut ssm, stride, Donation::Retain),
            plan: None,
            ws: &mut ws,
        };
        spec.validate(&m).unwrap();

        // Row past stride.
        let bad_segs = [seg(1, 3)];
        let bad_batch = MixedBatch::new(&bad_segs, &toks).unwrap();
        let mut ws2 = Workspace::new();
        let mut conv2 = vec![0f32; 2 * stride * cp];
        let mut ssm2 = vec![0f32; 2 * stride * sp];
        let spec = LaunchSpec {
            batch: bad_batch,
            state: StateSlabs::new(&mut conv2, &mut ssm2, stride, Donation::Retain),
            plan: None,
            ws: &mut ws2,
        };
        assert!(spec.validate(&m).is_err());

        // Wrong slab size.
        let mut ws3 = Workspace::new();
        let mut conv3 = vec![0f32; 7];
        let mut ssm3 = vec![0f32; 2 * stride * sp];
        let spec = LaunchSpec {
            batch,
            state: StateSlabs::new(&mut conv3, &mut ssm3, stride, Donation::Retain),
            plan: None,
            ws: &mut ws3,
        };
        assert!(spec.validate(&m).is_err());
    }

    #[test]
    fn caps_summary_reports_negotiation_surface() {
        let full = EngineCaps::full();
        assert!(full.varlen_kernel && full.donation);
        assert_eq!(full.plans_available(), PlanChoice::COUNT);
        let s = full.summary();
        assert!(s.contains("varlen_kernel=yes"), "{s}");
        assert!(s.contains("donation=yes"), "{s}");
        assert!(s.contains(&format!("plans={}/{}", PlanChoice::COUNT, PlanChoice::COUNT)), "{s}");

        let mut partial = EngineCaps::baseline();
        let ff = PlanChoice::candidates()[0];
        partial.plans[ff.index()] = false;
        let s = partial.summary();
        assert!(s.contains("varlen_kernel=no"), "{s}");
        assert!(s.contains("unavailable:"), "{s}");
        assert!(s.contains(&ff.name()), "{s}");
        assert_eq!(partial.plans_available(), PlanChoice::COUNT - 1);
        assert_eq!(EngineCaps::default(), EngineCaps::baseline());
    }
}
