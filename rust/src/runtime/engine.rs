//! PJRT execution engine: loads the AOT HLO-text artifacts, compiles
//! them once per batch size, and serves prefill/decode calls from the
//! coordinator's hot path. Python is never involved at runtime.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::artifact::Manifest;

/// Raw per-call outputs: last-position logits plus the packed recurrent
/// states (the coordinator scatters them back into per-sequence slots).
#[derive(Debug, Clone)]
pub struct StepOutput {
    /// `[batch, vocab]`, row-major.
    pub logits: Vec<f32>,
    /// `[layers, batch, D, J-1]`, row-major.
    pub conv_state: Vec<f32>,
    /// `[layers, batch, D, N]`, row-major.
    pub ssm_state: Vec<f32>,
}

/// Abstracts the model executor so the coordinator can be tested
/// without PJRT (see [`super::mock::MockEngine`]). Not `Send`: PJRT
/// handles hold raw pointers, so each server worker *constructs its own
/// engine* on its thread (see [`crate::coordinator::server::Server`]).
pub trait Executor {
    fn manifest(&self) -> &Manifest;

    /// Prefill a batch of `batch × prefill_len` tokens from zero state.
    fn prefill(&self, batch: usize, tokens: &[i32]) -> Result<StepOutput>;

    /// One decode step for `batch` sequences with packed states.
    fn decode(
        &self,
        batch: usize,
        tokens: &[i32],
        conv_state: &[f32],
        ssm_state: &[f32],
    ) -> Result<StepOutput>;
}

/// The real PJRT-backed engine.
pub struct MambaEngine {
    manifest: Manifest,
    client: xla::PjRtClient,
    prefill_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    decode_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
}

impl MambaEngine {
    /// Load and compile every artifact listed in the manifest.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<MambaEngine> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let compile = |path: &Path| -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).with_context(|| format!("compiling {}", path.display()))
        };
        let mut prefill_exes = BTreeMap::new();
        for &b in &manifest.prefill_batches {
            prefill_exes.insert(b, compile(&manifest.prefill_path(b))?);
        }
        let mut decode_exes = BTreeMap::new();
        for &b in &manifest.decode_batches {
            decode_exes.insert(b, compile(&manifest.decode_path(b))?);
        }
        Ok(MambaEngine { manifest, client, prefill_exes, decode_exes })
    }

    /// The PJRT platform backing this engine (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Smallest compiled batch size ≥ `n` (requests are padded up).
    pub fn fit_batch(sizes: &[usize], n: usize) -> Option<usize> {
        sizes.iter().copied().filter(|&b| b >= n).min()
    }

    fn unpack(result: xla::Literal) -> Result<StepOutput> {
        let parts = result.to_tuple()?;
        if parts.len() != 3 {
            anyhow::bail!("expected 3 outputs, got {}", parts.len());
        }
        let mut it = parts.into_iter();
        let logits = it.next().unwrap().to_vec::<f32>()?;
        let conv_state = it.next().unwrap().to_vec::<f32>()?;
        let ssm_state = it.next().unwrap().to_vec::<f32>()?;
        Ok(StepOutput { logits, conv_state, ssm_state })
    }
}

impl Executor for MambaEngine {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn prefill(&self, batch: usize, tokens: &[i32]) -> Result<StepOutput> {
        let exe = self
            .prefill_exes
            .get(&batch)
            .ok_or_else(|| anyhow!("no prefill executable for batch {batch}"))?;
        let expect = batch * self.manifest.prefill_len;
        if tokens.len() != expect {
            anyhow::bail!("prefill tokens: got {}, want {}", tokens.len(), expect);
        }
        let toks = xla::Literal::vec1(tokens)
            .reshape(&[batch as i64, self.manifest.prefill_len as i64])?;
        let result = exe.execute::<xla::Literal>(&[toks])?[0][0].to_literal_sync()?;
        Self::unpack(result)
    }

    fn decode(
        &self,
        batch: usize,
        tokens: &[i32],
        conv_state: &[f32],
        ssm_state: &[f32],
    ) -> Result<StepOutput> {
        let exe = self
            .decode_exes
            .get(&batch)
            .ok_or_else(|| anyhow!("no decode executable for batch {batch}"))?;
        if tokens.len() != batch {
            anyhow::bail!("decode tokens: got {}, want {batch}", tokens.len());
        }
        let m = &self.manifest;
        let conv = xla::Literal::vec1(conv_state).reshape(&[
            m.n_layer as i64,
            batch as i64,
            m.d_inner as i64,
            (m.d_conv - 1) as i64,
        ])?;
        let ssm = xla::Literal::vec1(ssm_state).reshape(&[
            m.n_layer as i64,
            batch as i64,
            m.d_inner as i64,
            m.d_state as i64,
        ])?;
        let toks = xla::Literal::vec1(tokens);
        let result = exe.execute::<xla::Literal>(&[toks, conv, ssm])?[0][0].to_literal_sync()?;
        Self::unpack(result)
    }
}

/// Argmax over each row of a `[batch, vocab]` logits buffer.
pub fn argmax_rows(logits: &[f32], vocab: usize) -> Vec<i32> {
    logits
        .chunks_exact(vocab)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                .map(|(i, _)| i as i32)
                .unwrap_or(0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_batch_picks_smallest_fit() {
        let sizes = [1, 2, 4, 8];
        assert_eq!(MambaEngine::fit_batch(&sizes, 1), Some(1));
        assert_eq!(MambaEngine::fit_batch(&sizes, 3), Some(4));
        assert_eq!(MambaEngine::fit_batch(&sizes, 8), Some(8));
        assert_eq!(MambaEngine::fit_batch(&sizes, 9), None);
    }

    #[test]
    fn argmax_rows_basic() {
        let logits = [0.1, 0.9, 0.0, 7.0, -1.0, 2.0];
        assert_eq!(argmax_rows(&logits, 3), vec![1, 0]);
    }
}
