//! PJRT execution engine: loads the AOT HLO-text artifacts, compiles
//! them once per batch size, and serves prefill/decode calls from the
//! coordinator's hot path. Python is never involved at runtime.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::artifact::Manifest;

/// Raw per-call outputs: last-position logits plus the packed recurrent
/// states (the coordinator scatters them back into per-sequence slots).
#[derive(Debug, Clone)]
pub struct StepOutput {
    /// `[batch, vocab]`, row-major.
    pub logits: Vec<f32>,
    /// `[layers, batch, D, J-1]`, row-major.
    pub conv_state: Vec<f32>,
    /// `[layers, batch, D, N]`, row-major.
    pub ssm_state: Vec<f32>,
}

/// Abstracts the model executor so the coordinator can be tested
/// without PJRT (see [`super::mock::MockEngine`]). Not `Send`: PJRT
/// handles hold raw pointers, so each server worker *constructs its own
/// engine* on its thread (see [`crate::coordinator::server::Server`]).
pub trait Executor {
    fn manifest(&self) -> &Manifest;

    /// Prefill a batch of `batch × prefill_len` tokens from zero state.
    fn prefill(&self, batch: usize, tokens: &[i32]) -> Result<StepOutput>;

    /// One decode step for `batch` sequences with packed states.
    fn decode(
        &self,
        batch: usize,
        tokens: &[i32],
        conv_state: &[f32],
        ssm_state: &[f32],
    ) -> Result<StepOutput>;

    /// One **mixed** invocation: a varlen batch where row `b` consumes
    /// `lens[b]` tokens from the flat `tokens` buffer, starting from
    /// the packed per-row states (`[layers, batch, …]`, layer-major;
    /// zero rows mean "fresh sequence"). Returns the *last-position*
    /// logits per row plus the final packed states — so a row with
    /// `lens[b] == 1` is a decode step, a row with `lens[b] > 1` is a
    /// prefill chunk, and the coordinator can schedule both in the same
    /// engine call (continuous batching with chunked prefill).
    ///
    /// The default implementation decomposes the batch onto the
    /// compiled `prefill`/`decode` entry points — single-token rows run
    /// as padded compiled-decode batches, full-`prefill_len` rows with
    /// zero state run through the compiled prefill, and everything else
    /// (mid-prompt chunks) advances in lockstep through compiled decode
    /// batches, one call per token *position* shared across rows. That
    /// is correct for any engine; engines with a fused varlen kernel
    /// override it (see [`super::mock::MockEngine`], whose override is
    /// verified bit-identical to this default).
    fn step_mixed(
        &self,
        lens: &[usize],
        tokens: &[i32],
        conv_state: &[f32],
        ssm_state: &[f32],
    ) -> Result<StepOutput> {
        let m = self.manifest();
        let batch = lens.len();
        let (nl, vocab, plen) = (m.n_layer, m.vocab, m.prefill_len);
        let cp = m.d_inner * (m.d_conv - 1);
        let sp = m.d_inner * m.d_state;
        anyhow::ensure!(batch > 0, "empty mixed batch");
        anyhow::ensure!(lens.iter().all(|&l| l >= 1), "zero-length mixed row");
        let total: usize = lens.iter().sum();
        anyhow::ensure!(tokens.len() == total, "mixed tokens: got {}, want {total}", tokens.len());
        anyhow::ensure!(
            conv_state.len() == nl * batch * cp,
            "mixed conv state: got {}, want {}",
            conv_state.len(),
            nl * batch * cp
        );
        anyhow::ensure!(
            ssm_state.len() == nl * batch * sp,
            "mixed ssm state: got {}, want {}",
            ssm_state.len(),
            nl * batch * sp
        );

        // Flat-token offset of each row.
        let mut offs = Vec::with_capacity(batch);
        let mut o = 0usize;
        for &l in lens {
            offs.push(o);
            o += l;
        }

        let mut logits = vec![0f32; batch * vocab];
        let mut conv_out = vec![0f32; nl * batch * cp];
        let mut ssm_out = vec![0f32; nl * batch * sp];

        let zero_state = |b: usize| {
            (0..nl).all(|l| {
                conv_state[(l * batch + b) * cp..(l * batch + b + 1) * cp]
                    .iter()
                    .all(|&x| x == 0.0)
                    && ssm_state[(l * batch + b) * sp..(l * batch + b + 1) * sp]
                        .iter()
                        .all(|&x| x == 0.0)
            })
        };

        // Bucket rows by which compiled entry point serves them.
        let mut decode_rows: Vec<usize> = Vec::new();
        let mut prefill_rows: Vec<usize> = Vec::new();
        let mut scan_rows: Vec<usize> = Vec::new();
        for b in 0..batch {
            if lens[b] == 1 {
                decode_rows.push(b);
            } else if lens[b] == plen && zero_state(b) {
                prefill_rows.push(b);
            } else {
                scan_rows.push(b);
            }
        }

        // 1. Single-token rows → compiled decode batches, padded to a
        //    compiled size by repeating the last row (groups of at most
        //    the largest compiled size).
        if !decode_rows.is_empty() {
            let largest = m.decode_batches.iter().copied().max().unwrap_or(1);
            let mut i = 0usize;
            while i < decode_rows.len() {
                let n = (decode_rows.len() - i).min(largest);
                let group = &decode_rows[i..i + n];
                let size = MambaEngine::fit_batch(&m.decode_batches, n).unwrap_or(n);
                let mut toks = Vec::with_capacity(size);
                let mut c = vec![0f32; nl * size * cp];
                let mut s = vec![0f32; nl * size * sp];
                for j in 0..size {
                    let b = group[j.min(n - 1)];
                    toks.push(tokens[offs[b]]);
                    copy_state_row(nl, cp, conv_state, batch, b, &mut c, size, j);
                    copy_state_row(nl, sp, ssm_state, batch, b, &mut s, size, j);
                }
                let out = self.decode(size, &toks, &c, &s)?;
                for (j, &b) in group.iter().enumerate() {
                    logits[b * vocab..(b + 1) * vocab]
                        .copy_from_slice(&out.logits[j * vocab..(j + 1) * vocab]);
                    copy_state_row(nl, cp, &out.conv_state, size, j, &mut conv_out, batch, b);
                    copy_state_row(nl, sp, &out.ssm_state, size, j, &mut ssm_out, batch, b);
                }
                i += n;
            }
        }

        // 2. Full-length fresh rows → the compiled prefill path.
        if !prefill_rows.is_empty() {
            let largest = m.prefill_batches.iter().copied().max().unwrap_or(1);
            let mut i = 0usize;
            while i < prefill_rows.len() {
                let n = (prefill_rows.len() - i).min(largest);
                let group = &prefill_rows[i..i + n];
                let size = MambaEngine::fit_batch(&m.prefill_batches, n).unwrap_or(n);
                let mut toks = Vec::with_capacity(size * plen);
                for j in 0..size {
                    let b = group[j.min(n - 1)];
                    toks.extend_from_slice(&tokens[offs[b]..offs[b] + plen]);
                }
                let out = self.prefill(size, &toks)?;
                for (j, &b) in group.iter().enumerate() {
                    logits[b * vocab..(b + 1) * vocab]
                        .copy_from_slice(&out.logits[j * vocab..(j + 1) * vocab]);
                    copy_state_row(nl, cp, &out.conv_state, size, j, &mut conv_out, batch, b);
                    copy_state_row(nl, sp, &out.ssm_state, size, j, &mut ssm_out, batch, b);
                }
                i += n;
            }
        }

        // 3. Everything else (mid-prompt chunks, odd lengths) advances
        //    in *lockstep* through compiled decode batches: one decode
        //    call per token position shared across all scan rows, so a
        //    tick's chunk cost is max(chunk lens) device calls, not
        //    sum(chunk lens). (A compiled varlen chunk kernel — i.e. an
        //    overridden step_mixed — is still the real fix for
        //    production engines.)
        if !scan_rows.is_empty() {
            let k = scan_rows.len();
            let max_len = scan_rows.iter().map(|&b| lens[b]).max().unwrap();
            let largest = m.decode_batches.iter().copied().max().unwrap_or(1);
            // Working states, packed [layers, k, per] in scan-row order.
            let mut c = vec![0f32; nl * k * cp];
            let mut s = vec![0f32; nl * k * sp];
            for (j, &b) in scan_rows.iter().enumerate() {
                copy_state_row(nl, cp, conv_state, batch, b, &mut c, k, j);
                copy_state_row(nl, sp, ssm_state, batch, b, &mut s, k, j);
            }
            for t in 0..max_len {
                // Scan-row indices still holding a token at position t.
                let active: Vec<usize> =
                    (0..k).filter(|&j| t < lens[scan_rows[j]]).collect();
                let mut i = 0usize;
                while i < active.len() {
                    let n = (active.len() - i).min(largest);
                    let group = &active[i..i + n];
                    let size = MambaEngine::fit_batch(&m.decode_batches, n).unwrap_or(n);
                    let mut toks = Vec::with_capacity(size);
                    let mut gc = vec![0f32; nl * size * cp];
                    let mut gs = vec![0f32; nl * size * sp];
                    for jj in 0..size {
                        let j = group[jj.min(n - 1)];
                        toks.push(tokens[offs[scan_rows[j]] + t]);
                        copy_state_row(nl, cp, &c, k, j, &mut gc, size, jj);
                        copy_state_row(nl, sp, &s, k, j, &mut gs, size, jj);
                    }
                    let out = self.decode(size, &toks, &gc, &gs)?;
                    for (jj, &j) in group.iter().enumerate() {
                        copy_state_row(nl, cp, &out.conv_state, size, jj, &mut c, k, j);
                        copy_state_row(nl, sp, &out.ssm_state, size, jj, &mut s, k, j);
                        if t + 1 == lens[scan_rows[j]] {
                            let b = scan_rows[j];
                            logits[b * vocab..(b + 1) * vocab]
                                .copy_from_slice(&out.logits[jj * vocab..(jj + 1) * vocab]);
                        }
                    }
                    i += n;
                }
            }
            for (j, &b) in scan_rows.iter().enumerate() {
                copy_state_row(nl, cp, &c, k, j, &mut conv_out, batch, b);
                copy_state_row(nl, sp, &s, k, j, &mut ssm_out, batch, b);
            }
        }

        Ok(StepOutput { logits, conv_state: conv_out, ssm_state: ssm_out })
    }
}

/// Copy one sequence's per-layer state row between packed layer-major
/// buffers of (possibly) different batch widths.
pub(crate) fn copy_state_row(
    n_layer: usize,
    per: usize,
    src: &[f32],
    src_batch: usize,
    sb: usize,
    dst: &mut [f32],
    dst_batch: usize,
    db: usize,
) {
    for l in 0..n_layer {
        let s0 = (l * src_batch + sb) * per;
        let d0 = (l * dst_batch + db) * per;
        dst[d0..d0 + per].copy_from_slice(&src[s0..s0 + per]);
    }
}

/// The real PJRT-backed engine.
pub struct MambaEngine {
    manifest: Manifest,
    client: xla::PjRtClient,
    prefill_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    decode_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
}

impl MambaEngine {
    /// Load and compile every artifact listed in the manifest.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<MambaEngine> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let compile = |path: &Path| -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).with_context(|| format!("compiling {}", path.display()))
        };
        let mut prefill_exes = BTreeMap::new();
        for &b in &manifest.prefill_batches {
            prefill_exes.insert(b, compile(&manifest.prefill_path(b))?);
        }
        let mut decode_exes = BTreeMap::new();
        for &b in &manifest.decode_batches {
            decode_exes.insert(b, compile(&manifest.decode_path(b))?);
        }
        Ok(MambaEngine { manifest, client, prefill_exes, decode_exes })
    }

    /// The PJRT platform backing this engine (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Smallest compiled batch size ≥ `n` (requests are padded up).
    pub fn fit_batch(sizes: &[usize], n: usize) -> Option<usize> {
        sizes.iter().copied().filter(|&b| b >= n).min()
    }

    fn unpack(result: xla::Literal) -> Result<StepOutput> {
        let parts = result.to_tuple()?;
        if parts.len() != 3 {
            anyhow::bail!("expected 3 outputs, got {}", parts.len());
        }
        let mut it = parts.into_iter();
        let logits = it.next().unwrap().to_vec::<f32>()?;
        let conv_state = it.next().unwrap().to_vec::<f32>()?;
        let ssm_state = it.next().unwrap().to_vec::<f32>()?;
        Ok(StepOutput { logits, conv_state, ssm_state })
    }
}

impl Executor for MambaEngine {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn prefill(&self, batch: usize, tokens: &[i32]) -> Result<StepOutput> {
        let exe = self
            .prefill_exes
            .get(&batch)
            .ok_or_else(|| anyhow!("no prefill executable for batch {batch}"))?;
        let expect = batch * self.manifest.prefill_len;
        if tokens.len() != expect {
            anyhow::bail!("prefill tokens: got {}, want {}", tokens.len(), expect);
        }
        let toks = xla::Literal::vec1(tokens)
            .reshape(&[batch as i64, self.manifest.prefill_len as i64])?;
        let result = exe.execute::<xla::Literal>(&[toks])?[0][0].to_literal_sync()?;
        Self::unpack(result)
    }

    fn decode(
        &self,
        batch: usize,
        tokens: &[i32],
        conv_state: &[f32],
        ssm_state: &[f32],
    ) -> Result<StepOutput> {
        let exe = self
            .decode_exes
            .get(&batch)
            .ok_or_else(|| anyhow!("no decode executable for batch {batch}"))?;
        if tokens.len() != batch {
            anyhow::bail!("decode tokens: got {}, want {batch}", tokens.len());
        }
        let m = &self.manifest;
        let conv = xla::Literal::vec1(conv_state).reshape(&[
            m.n_layer as i64,
            batch as i64,
            m.d_inner as i64,
            (m.d_conv - 1) as i64,
        ])?;
        let ssm = xla::Literal::vec1(ssm_state).reshape(&[
            m.n_layer as i64,
            batch as i64,
            m.d_inner as i64,
            m.d_state as i64,
        ])?;
        let toks = xla::Literal::vec1(tokens);
        let result = exe.execute::<xla::Literal>(&[toks, conv, ssm])?[0][0].to_literal_sync()?;
        Self::unpack(result)
    }
}

/// Argmax over each row of a `[batch, vocab]` logits buffer.
pub fn argmax_rows(logits: &[f32], vocab: usize) -> Vec<i32> {
    logits
        .chunks_exact(vocab)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
                .map(|(i, _)| i as i32)
                .unwrap_or(0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_batch_picks_smallest_fit() {
        let sizes = [1, 2, 4, 8];
        assert_eq!(MambaEngine::fit_batch(&sizes, 1), Some(1));
        assert_eq!(MambaEngine::fit_batch(&sizes, 3), Some(4));
        assert_eq!(MambaEngine::fit_batch(&sizes, 8), Some(8));
        assert_eq!(MambaEngine::fit_batch(&sizes, 9), None);
    }

    #[test]
    fn argmax_rows_basic() {
        let logits = [0.1, 0.9, 0.0, 7.0, -1.0, 2.0];
        assert_eq!(argmax_rows(&logits, 3), vec![1, 0]);
    }
}
