//! PJRT execution engine: loads the AOT HLO-text artifacts, compiles
//! them once per batch size, and serves the coordinator's launches
//! from the hot path. Python is never involved at runtime.
//!
//! ## Capability negotiation
//!
//! An [`Executor`] is two things: a set of **compiled primitives**
//! ([`Executor::prefill`], [`Executor::decode`]) and one **launch
//! entry point** ([`Executor::launch`]) that executes a whole varlen
//! tick described by a typed [`LaunchSpec`]. What an engine can fuse is
//! *declared*, not probed: [`Executor::caps`] returns an
//! [`EngineCaps`] report the scheduler reads once at construction —
//! the planner masks out unexecutable fusion plans
//! ([`crate::planner::Planner::apply_caps`]), the state path follows
//! `in_place_state`, and the [`Donation`] annotation is honoured only
//! when `donation` is set. An engine with `varlen_kernel: false`
//! simply inherits the default `launch`, which decomposes the batch
//! onto the compiled primitives (and prices every staged byte and
//! device call in the [`Workspace`] counters, so the difference
//! between a fused and an emulated engine is observable in
//! deterministic numbers).
//!
//! The legacy step methods (`step_mixed`, `step_mixed_into`,
//! `step_planned_into`, `register_variant`) survive as thin deprecated
//! wrappers over `launch` / `caps` — see [`super::spec`] for the
//! migration story.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use super::artifact::Manifest;
use super::spec::{Donation, EngineCaps, LaunchSpec, MixedBatch, Phase, Segment, StateSlabs};

/// Raw per-call outputs: last-position logits plus the packed recurrent
/// states (the coordinator scatters them back into per-sequence slots).
#[derive(Debug, Clone)]
pub struct StepOutput {
    /// `[batch, vocab]`, row-major.
    pub logits: Vec<f32>,
    /// `[layers, batch, D, J-1]`, row-major.
    pub conv_state: Vec<f32>,
    /// `[layers, batch, D, N]`, row-major.
    pub ssm_state: Vec<f32>,
}

/// Deterministic state-traffic accounting, mirroring the paper's
/// inter-operator memory-traffic bookkeeping at the host level: every
/// byte of recurrent state that is *copied* (rather than staying
/// resident) is counted exactly once.
///
/// Convention: a copy whose **destination is a staging buffer**
/// (resident slab → staging, staging → staging, engine output →
/// staging) counts as `bytes_gathered`; a copy whose **destination is
/// resident storage** (staging or engine output → slab, arena
/// relocation on growth) counts as `bytes_scattered`. A steady-state
/// decode tick on a fused engine moves zero bytes on both counters.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct TrafficCounters {
    /// Bytes copied into staging buffers (see the type docs for the
    /// destination convention).
    pub bytes_gathered: u64,
    /// Bytes copied into resident storage.
    pub bytes_scattered: u64,
}

impl TrafficCounters {
    /// Gathered + scattered.
    pub fn total(&self) -> u64 {
        self.bytes_gathered + self.bytes_scattered
    }

    /// Accumulate another counter set into this one.
    pub fn merge(&mut self, other: TrafficCounters) {
        self.bytes_gathered += other.bytes_gathered;
        self.bytes_scattered += other.bytes_scattered;
    }
}

/// Caller-owned reusable buffers for [`Executor::launch`].
///
/// The scheduler holds one `Workspace` for its whole lifetime, so the
/// per-tick hot path performs no heap allocation once the buffers have
/// grown to the workload's steady-state sizes: `logits` is the output
/// surface, the private staging buffers serve the default
/// prefill/decode decomposition (reused across every lockstep-scan
/// position rather than reallocated per position), and the counters
/// record exactly what each launch cost — `traffic` / `padded_rows`
/// for host state copies, `device_calls` for compiled-entry-point
/// invocations (1 per tick on a fused varlen engine, `max(chunk)`-ish
/// for the decomposition), and the modeled-cost pair for engines that
/// model per-plan device behaviour.
#[derive(Debug, Default)]
pub struct Workspace {
    /// `[batch, vocab]` last-position logits of the most recent call.
    pub logits: Vec<f32>,
    traffic: TrafficCounters,
    padded_rows: u64,
    /// Device launches (compiled-executable invocations) since the last
    /// drain. A fused varlen engine records exactly one per
    /// [`Executor::launch`]; the default decomposition records one per
    /// compiled prefill/decode call it stages.
    device_calls: u64,
    /// Engine-modeled device cost of the calls since the last drain
    /// (cycles / DRAM bytes under the executed fusion plan). Charged by
    /// engines that model per-plan device behaviour (the mock; see
    /// [`Workspace::record_modeled`]) — distinct from the host-copy
    /// `traffic` counters, which stay zero on the resident fused path
    /// regardless of plan choice.
    modeled_cycles: u64,
    modeled_bytes: u64,
    // Staging for the default compiled-entry-point decomposition.
    toks: Vec<i32>,
    offs: Vec<usize>,
    decode_rows: Vec<usize>,
    prefill_rows: Vec<usize>,
    scan_rows: Vec<usize>,
    active: Vec<usize>,
    scan_conv: Vec<f32>,
    scan_ssm: Vec<f32>,
    group_conv: Vec<f32>,
    group_ssm: Vec<f32>,
}

impl Workspace {
    /// Fresh workspace with empty buffers (they grow on first use and
    /// are reused thereafter).
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// Size `logits` for a `batch × vocab` call, zero-filled. Reuses
    /// the existing capacity (no allocation once warm).
    pub fn reset_logits(&mut self, batch: usize, vocab: usize) {
        self.logits.clear();
        self.logits.resize(batch * vocab, 0.0);
    }

    /// State bytes copied by calls through this workspace since the
    /// last [`Workspace::take_traffic`].
    pub fn traffic(&self) -> TrafficCounters {
        self.traffic
    }

    /// Drain the traffic counters (returns the counts, resets to zero).
    pub fn take_traffic(&mut self) -> TrafficCounters {
        std::mem::take(&mut self.traffic)
    }

    /// Padded rows shipped to compiled decode batches since the last
    /// [`Workspace::take_padded_rows`].
    pub fn padded_rows(&self) -> u64 {
        self.padded_rows
    }

    /// Drain the padded-row counter.
    pub fn take_padded_rows(&mut self) -> u64 {
        std::mem::take(&mut self.padded_rows)
    }

    /// Record one device launch (engine implementors: call once per
    /// compiled-executable invocation, so the fused-vs-decomposed
    /// launch-count difference is observable in deterministic
    /// counters).
    pub fn record_device_call(&mut self) {
        self.device_calls += 1;
    }

    /// Device launches since the last [`Workspace::take_device_calls`].
    pub fn device_calls(&self) -> u64 {
        self.device_calls
    }

    /// Drain the device-launch counter.
    pub fn take_device_calls(&mut self) -> u64 {
        std::mem::take(&mut self.device_calls)
    }

    /// Charge modeled device cost for a call (engine implementors:
    /// called from [`Executor::launch`] overrides with the executed
    /// plan's analytical cycle/byte cost, so plan choice is observable
    /// in deterministic counters).
    pub fn record_modeled(&mut self, cycles: u64, bytes: u64) {
        self.modeled_cycles += cycles;
        self.modeled_bytes += bytes;
    }

    /// Modeled device cost since the last [`Workspace::take_modeled`].
    pub fn modeled(&self) -> (u64, u64) {
        (self.modeled_cycles, self.modeled_bytes)
    }

    /// Drain the modeled-cost counters: `(cycles, bytes)`.
    pub fn take_modeled(&mut self) -> (u64, u64) {
        (std::mem::take(&mut self.modeled_cycles), std::mem::take(&mut self.modeled_bytes))
    }
}

/// Abstracts the model executor so the coordinator can be tested
/// without PJRT (see [`super::mock::MockEngine`]). Not `Send`: PJRT
/// handles hold raw pointers, so each server worker *constructs its own
/// engine* on its thread (see [`crate::coordinator::server::Server`]).
///
/// Engines implement [`Executor::manifest`], the compiled primitives
/// ([`Executor::prefill`] / [`Executor::decode`]), and — when they can
/// do better than the default decomposition — [`Executor::launch`] and
/// [`Executor::caps`]. Everything else is provided.
pub trait Executor {
    /// The model/artifact description this engine executes.
    fn manifest(&self) -> &Manifest;

    /// The engine's capability report. The default is the conservative
    /// [`EngineCaps::baseline`] every engine satisfies by construction;
    /// engines with a fused varlen kernel, device-side in-place state,
    /// buffer donation, or a restricted executable plan set override
    /// this to *declare* it — the scheduler and planner negotiate from
    /// the report instead of probing.
    fn caps(&self) -> EngineCaps {
        EngineCaps::baseline()
    }

    /// Prefill a batch of `batch × prefill_len` tokens from zero state.
    fn prefill(&self, batch: usize, tokens: &[i32]) -> Result<StepOutput>;

    /// One decode step for `batch` sequences with packed states.
    fn decode(
        &self,
        batch: usize,
        tokens: &[i32],
        conv_state: &[f32],
        ssm_state: &[f32],
    ) -> Result<StepOutput>;

    /// Execute one varlen tick described by `spec` — **the** engine
    /// entry point.
    ///
    /// Each batch row `b` consumes its segment's tokens starting from
    /// the slab row `spec.batch.segments()[b].row`, advances that row's
    /// state **in place** in `spec.state`, and (on success) leaves its
    /// last-position logits in `spec.ws.logits[b*vocab..]`. Rows are
    /// guaranteed distinct by [`MixedBatch`] construction; slab shapes
    /// are checked via [`LaunchSpec::validate`]. `spec.plan` carries
    /// the planner's fusion-plan choice (`None` for unplanned calls):
    /// single-mapping engines ignore it, multi-variant engines dispatch
    /// on it, modeling engines charge its analytical cost via
    /// [`Workspace::record_modeled`]. Every state byte the launch
    /// copies is priced into the workspace [`TrafficCounters`], and
    /// every compiled-executable invocation is counted via
    /// [`Workspace::record_device_call`].
    ///
    /// The default implementation decomposes the batch onto the
    /// compiled `prefill`/`decode` primitives — decode rows as padded
    /// compiled-decode batches, full-`prefill_len` fresh rows
    /// ([`Phase::PrefillFirst`]) through the compiled prefill, and
    /// everything else (mid-prompt chunks) in lockstep through compiled
    /// decode, one call per shared token position — which is correct
    /// for any engine but costs `max(chunk)` device calls plus staging
    /// traffic. Engines whose [`EngineCaps::varlen_kernel`] is true
    /// override it with a real fused launch (see
    /// [`super::mock::MockEngine`], whose allocation-free override is
    /// verified bit-identical to this default).
    fn launch(&self, mut spec: LaunchSpec<'_>) -> Result<()> {
        decompose_launch(self, &mut spec)
    }

    /// One **mixed** invocation with value semantics: row `b` consumes
    /// `lens[b]` tokens from the flat `tokens` buffer starting from the
    /// packed per-row states; returns last-position logits plus final
    /// packed states.
    ///
    /// Deprecated wrapper: copies the inputs, builds a [`LaunchSpec`]
    /// over identity rows, runs [`Executor::launch`] against a
    /// throwaway [`Workspace`], and repacks a [`StepOutput`].
    #[deprecated(note = "build a LaunchSpec and call Executor::launch")]
    fn step_mixed(
        &self,
        lens: &[usize],
        tokens: &[i32],
        conv_state: &[f32],
        ssm_state: &[f32],
    ) -> Result<StepOutput> {
        let batch = lens.len();
        anyhow::ensure!(batch > 0, "empty mixed batch");
        let mut conv = conv_state.to_vec();
        let mut ssm = ssm_state.to_vec();
        let rows: Vec<usize> = (0..batch).collect();
        let segs = segments_from_slices(self.manifest(), lens, &rows, &conv, &ssm, batch);
        let mut ws = Workspace::new();
        {
            let spec = LaunchSpec {
                batch: MixedBatch::new(&segs, tokens)?,
                state: StateSlabs::new(&mut conv, &mut ssm, batch, Donation::Retain),
                plan: None,
                ws: &mut ws,
            };
            self.launch(spec)?;
        }
        Ok(StepOutput {
            logits: std::mem::take(&mut ws.logits),
            conv_state: conv,
            ssm_state: ssm,
        })
    }

    /// One mixed invocation writing into caller-owned storage through
    /// the legacy seven-slice convention (`lens, tokens, rows, conv,
    /// ssm, stride, ws`).
    ///
    /// Deprecated wrapper: classifies each row's [`Phase`] (zero-state
    /// scan, exactly the check the old default decomposition did),
    /// builds a [`LaunchSpec`], and calls [`Executor::launch`] — so it
    /// stays bit-identical to the old entry point while every engine
    /// only implements the new surface.
    #[deprecated(note = "build a LaunchSpec and call Executor::launch")]
    #[allow(clippy::too_many_arguments)]
    fn step_mixed_into(
        &self,
        lens: &[usize],
        tokens: &[i32],
        rows: &[usize],
        conv: &mut [f32],
        ssm: &mut [f32],
        stride: usize,
        ws: &mut Workspace,
    ) -> Result<()> {
        anyhow::ensure!(
            rows.len() == lens.len(),
            "row plan: got {}, want {}",
            rows.len(),
            lens.len()
        );
        let segs = segments_from_slices(self.manifest(), lens, rows, conv, ssm, stride);
        let spec = LaunchSpec {
            batch: MixedBatch::new(&segs, tokens)?,
            state: StateSlabs::new(conv, ssm, stride, Donation::Retain),
            plan: None,
            ws,
        };
        self.launch(spec)
    }

    /// Announce a candidate fusion plan (legacy negotiation: the
    /// scheduler used to announce every candidate and treat `Err` as
    /// "unavailable").
    ///
    /// Deprecated: engines now *declare* per-plan availability in
    /// [`EngineCaps::plans`] and the planner masks its candidate set
    /// from the report — no trial-and-error. The default accepts
    /// everything, matching [`EngineCaps::baseline`].
    #[deprecated(note = "declare per-plan availability in Executor::caps().plans")]
    fn register_variant(&mut self, _choice: crate::planner::PlanChoice) -> Result<()> {
        Ok(())
    }

    /// The legacy seven-slice mixed call with an explicit fusion-plan
    /// choice.
    ///
    /// Deprecated wrapper: identical to [`Executor::step_mixed_into`]
    /// except the built [`LaunchSpec`] carries `Some(choice)`, so
    /// modeling engines charge the plan's analytical cost exactly as
    /// the old entry point did.
    #[deprecated(note = "build a LaunchSpec (with plan: Some(choice)) and call Executor::launch")]
    #[allow(clippy::too_many_arguments)]
    fn step_planned_into(
        &self,
        choice: crate::planner::PlanChoice,
        lens: &[usize],
        tokens: &[i32],
        rows: &[usize],
        conv: &mut [f32],
        ssm: &mut [f32],
        stride: usize,
        ws: &mut Workspace,
    ) -> Result<()> {
        anyhow::ensure!(
            rows.len() == lens.len(),
            "row plan: got {}, want {}",
            rows.len(),
            lens.len()
        );
        let segs = segments_from_slices(self.manifest(), lens, rows, conv, ssm, stride);
        let spec = LaunchSpec {
            batch: MixedBatch::new(&segs, tokens)?,
            state: StateSlabs::new(conv, ssm, stride, Donation::Retain),
            plan: Some(choice),
            ws,
        };
        self.launch(spec)
    }
}

/// Build the per-row [`Segment`]s for a legacy raw-slice call:
/// `len == 1` rows are decode steps; `len == prefill_len` rows are
/// classified [`Phase::PrefillFirst`] iff their slab state is all-zero
/// (the same scan, on the same rows, the old default decomposition
/// performed — other lengths route to the lockstep scan whatever their
/// state, so they skip the scan and declare [`Phase::PrefillCont`],
/// which makes no zero-state claim). Out-of-range rows are classified
/// without a state scan and rejected later by [`LaunchSpec::validate`].
fn segments_from_slices(
    m: &Manifest,
    lens: &[usize],
    rows: &[usize],
    conv: &[f32],
    ssm: &[f32],
    stride: usize,
) -> Vec<Segment> {
    let (nl, cp, sp) = (m.n_layer, m.d_inner * (m.d_conv - 1), m.d_inner * m.d_state);
    let zero_state = |r: usize| {
        (0..nl).all(|l| {
            let c0 = (l * stride + r) * cp;
            let s0 = (l * stride + r) * sp;
            conv.get(c0..c0 + cp).map_or(false, |c| c.iter().all(|&x| x == 0.0))
                && ssm.get(s0..s0 + sp).map_or(false, |s| s.iter().all(|&x| x == 0.0))
        })
    };
    lens.iter()
        .zip(rows)
        .map(|(&len, &row)| {
            let phase = if len == 1 {
                Phase::Decode
            } else if len == m.prefill_len && row < stride && zero_state(row) {
                Phase::PrefillFirst
            } else {
                Phase::PrefillCont
            };
            Segment { len, row, phase }
        })
        .collect()
}

/// The default [`Executor::launch`] implementation: decompose a varlen
/// batch onto the compiled `prefill`/`decode` primitives.
///
/// Decode rows run as padded compiled-decode batches;
/// full-`prefill_len` [`Phase::PrefillFirst`] rows run through the
/// compiled prefill (fresh rows start from zero inside the compiled
/// kernel — declared, so no state scan is needed); everything else
/// (mid-prompt chunks, odd lengths) advances in **lockstep** through
/// compiled decode batches, one device call per token position shared
/// across rows — so a tick's chunk cost is `max(chunk lens)` device
/// calls, not `sum(chunk lens)`. All staging goes through the
/// workspace's reusable buffers, every copied byte lands in the
/// traffic counters, and every compiled call bumps `device_calls`.
/// (A compiled varlen chunk kernel — an engine whose caps declare
/// `varlen_kernel` and whose `launch` override uses it — is still the
/// real fix for production engines.)
pub(crate) fn decompose_launch<E: Executor + ?Sized>(
    engine: &E,
    spec: &mut LaunchSpec<'_>,
) -> Result<()> {
    let m = engine.manifest();
    spec.validate(m)?;
    let batch = spec.batch;
    let segs = batch.segments();
    let toks_flat = batch.tokens();
    let nb = batch.rows();
    let (nl, vocab, plen) = (m.n_layer, m.vocab, m.prefill_len);
    let cp = m.d_inner * (m.d_conv - 1);
    let sp = m.d_inner * m.d_state;
    let stride = spec.state.stride();
    let ws = &mut *spec.ws;
    let (conv, ssm) = spec.state.slabs_mut();

    ws.reset_logits(nb, vocab);
    batch.fill_offsets(&mut ws.offs);

    // Bucket rows by which compiled entry point serves them — from the
    // declared phases (the legacy surface re-derived PrefillFirst by
    // scanning state memory; the typed batch declares it).
    ws.decode_rows.clear();
    ws.prefill_rows.clear();
    ws.scan_rows.clear();
    for (b, seg) in segs.iter().enumerate() {
        match seg.phase {
            Phase::Decode => ws.decode_rows.push(b),
            Phase::PrefillFirst if seg.len == plen => ws.prefill_rows.push(b),
            _ => ws.scan_rows.push(b),
        }
    }

    let row_bytes = ((cp + sp) * nl * 4) as u64;

    // 1. Single-token rows → compiled decode batches, padded to a
    //    compiled size by repeating the last row (groups of at most
    //    the largest compiled size).
    if !ws.decode_rows.is_empty() {
        let largest = m.decode_batches.iter().copied().max().unwrap_or(1);
        let mut i = 0usize;
        while i < ws.decode_rows.len() {
            let n = (ws.decode_rows.len() - i).min(largest);
            let size = MambaEngine::fit_batch(&m.decode_batches, n).unwrap_or(n);
            ws.toks.clear();
            ws.group_conv.clear();
            ws.group_conv.resize(nl * size * cp, 0.0);
            ws.group_ssm.clear();
            ws.group_ssm.resize(nl * size * sp, 0.0);
            for j in 0..size {
                let b = ws.decode_rows[i + j.min(n - 1)];
                ws.toks.push(toks_flat[ws.offs[b]]);
                copy_state_row(nl, cp, conv, stride, segs[b].row, &mut ws.group_conv, size, j);
                copy_state_row(nl, sp, ssm, stride, segs[b].row, &mut ws.group_ssm, size, j);
            }
            ws.traffic.bytes_gathered += size as u64 * row_bytes;
            ws.padded_rows += (size - n) as u64;
            ws.device_calls += 1;
            let out = engine.decode(size, &ws.toks, &ws.group_conv, &ws.group_ssm)?;
            for j in 0..n {
                let b = ws.decode_rows[i + j];
                ws.logits[b * vocab..(b + 1) * vocab]
                    .copy_from_slice(&out.logits[j * vocab..(j + 1) * vocab]);
                copy_state_row(nl, cp, &out.conv_state, size, j, conv, stride, segs[b].row);
                copy_state_row(nl, sp, &out.ssm_state, size, j, ssm, stride, segs[b].row);
            }
            ws.traffic.bytes_scattered += n as u64 * row_bytes;
            i += n;
        }
    }

    // 2. Full-length fresh rows → the compiled prefill path (no state
    //    gather: fresh rows start from zero inside the compiled
    //    kernel).
    if !ws.prefill_rows.is_empty() {
        let largest = m.prefill_batches.iter().copied().max().unwrap_or(1);
        let mut i = 0usize;
        while i < ws.prefill_rows.len() {
            let n = (ws.prefill_rows.len() - i).min(largest);
            let size = MambaEngine::fit_batch(&m.prefill_batches, n).unwrap_or(n);
            ws.toks.clear();
            for j in 0..size {
                let b = ws.prefill_rows[i + j.min(n - 1)];
                ws.toks.extend_from_slice(&toks_flat[ws.offs[b]..ws.offs[b] + plen]);
            }
            ws.device_calls += 1;
            let out = engine.prefill(size, &ws.toks)?;
            for j in 0..n {
                let b = ws.prefill_rows[i + j];
                ws.logits[b * vocab..(b + 1) * vocab]
                    .copy_from_slice(&out.logits[j * vocab..(j + 1) * vocab]);
                copy_state_row(nl, cp, &out.conv_state, size, j, conv, stride, segs[b].row);
                copy_state_row(nl, sp, &out.ssm_state, size, j, ssm, stride, segs[b].row);
            }
            ws.traffic.bytes_scattered += n as u64 * row_bytes;
            i += n;
        }
    }

    // 3. Everything else (mid-prompt chunks, odd lengths) advances in
    //    *lockstep* through compiled decode batches: one decode call
    //    per token position shared across all scan rows, so a tick's
    //    chunk cost is max(chunk lens) device calls, not
    //    sum(chunk lens). The scan working set and the per-group
    //    staging buffers live in `ws` and are reused across every
    //    position.
    if !ws.scan_rows.is_empty() {
        let k = ws.scan_rows.len();
        let max_len =
            ws.scan_rows.iter().map(|&b| segs[b].len).max().expect("scan_rows checked non-empty");
        let largest = m.decode_batches.iter().copied().max().unwrap_or(1);
        // Working states, packed [layers, k, per] in scan-row order,
        // staged out of the slab once (not per position).
        ws.scan_conv.clear();
        ws.scan_conv.resize(nl * k * cp, 0.0);
        ws.scan_ssm.clear();
        ws.scan_ssm.resize(nl * k * sp, 0.0);
        for j in 0..k {
            let b = ws.scan_rows[j];
            copy_state_row(nl, cp, conv, stride, segs[b].row, &mut ws.scan_conv, k, j);
            copy_state_row(nl, sp, ssm, stride, segs[b].row, &mut ws.scan_ssm, k, j);
        }
        ws.traffic.bytes_gathered += k as u64 * row_bytes;
        for t in 0..max_len {
            // Scan-row indices still holding a token at position t.
            ws.active.clear();
            for j in 0..k {
                if t < segs[ws.scan_rows[j]].len {
                    ws.active.push(j);
                }
            }
            let mut i = 0usize;
            while i < ws.active.len() {
                let n = (ws.active.len() - i).min(largest);
                let size = MambaEngine::fit_batch(&m.decode_batches, n).unwrap_or(n);
                ws.toks.clear();
                ws.group_conv.clear();
                ws.group_conv.resize(nl * size * cp, 0.0);
                ws.group_ssm.clear();
                ws.group_ssm.resize(nl * size * sp, 0.0);
                for jj in 0..size {
                    let j = ws.active[i + jj.min(n - 1)];
                    ws.toks.push(toks_flat[ws.offs[ws.scan_rows[j]] + t]);
                    copy_state_row(nl, cp, &ws.scan_conv, k, j, &mut ws.group_conv, size, jj);
                    copy_state_row(nl, sp, &ws.scan_ssm, k, j, &mut ws.group_ssm, size, jj);
                }
                ws.traffic.bytes_gathered += size as u64 * row_bytes;
                ws.padded_rows += (size - n) as u64;
                ws.device_calls += 1;
                let out = engine.decode(size, &ws.toks, &ws.group_conv, &ws.group_ssm)?;
                for jj in 0..n {
                    let j = ws.active[i + jj];
                    copy_state_row(nl, cp, &out.conv_state, size, jj, &mut ws.scan_conv, k, j);
                    copy_state_row(nl, sp, &out.ssm_state, size, jj, &mut ws.scan_ssm, k, j);
                    if t + 1 == segs[ws.scan_rows[j]].len {
                        let b = ws.scan_rows[j];
                        ws.logits[b * vocab..(b + 1) * vocab]
                            .copy_from_slice(&out.logits[jj * vocab..(jj + 1) * vocab]);
                    }
                }
                // Engine output → scan working set (staging).
                ws.traffic.bytes_gathered += n as u64 * row_bytes;
                i += n;
            }
        }
        for j in 0..k {
            let b = ws.scan_rows[j];
            copy_state_row(nl, cp, &ws.scan_conv, k, j, conv, stride, segs[b].row);
            copy_state_row(nl, sp, &ws.scan_ssm, k, j, ssm, stride, segs[b].row);
        }
        ws.traffic.bytes_scattered += k as u64 * row_bytes;
    }

    Ok(())
}

/// Copy one sequence's per-layer state row between packed layer-major
/// buffers of (possibly) different batch widths.
pub(crate) fn copy_state_row(
    n_layer: usize,
    per: usize,
    src: &[f32],
    src_batch: usize,
    sb: usize,
    dst: &mut [f32],
    dst_batch: usize,
    db: usize,
) {
    for l in 0..n_layer {
        let s0 = (l * src_batch + sb) * per;
        let d0 = (l * dst_batch + db) * per;
        dst[d0..d0 + per].copy_from_slice(&src[s0..s0 + per]);
    }
}

/// The real PJRT-backed engine.
pub struct MambaEngine {
    manifest: Manifest,
    client: xla::PjRtClient,
    prefill_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    decode_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
}

impl MambaEngine {
    /// Load and compile every artifact listed in the manifest.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<MambaEngine> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let compile = |path: &Path| -> Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).with_context(|| format!("compiling {}", path.display()))
        };
        let mut prefill_exes = BTreeMap::new();
        for &b in &manifest.prefill_batches {
            prefill_exes.insert(b, compile(&manifest.prefill_path(b))?);
        }
        let mut decode_exes = BTreeMap::new();
        for &b in &manifest.decode_batches {
            decode_exes.insert(b, compile(&manifest.decode_path(b))?);
        }
        Ok(MambaEngine { manifest, client, prefill_exes, decode_exes })
    }

    /// The PJRT platform backing this engine (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Smallest compiled batch size ≥ `n` (requests are padded up).
    pub fn fit_batch(sizes: &[usize], n: usize) -> Option<usize> {
        sizes.iter().copied().filter(|&b| b >= n).min()
    }

    fn unpack(result: xla::Literal) -> Result<StepOutput> {
        let parts = result.to_tuple()?;
        if parts.len() != 3 {
            anyhow::bail!("expected 3 outputs, got {}", parts.len());
        }
        let mut it = parts.into_iter();
        let logits = it.next().expect("tuple length checked").to_vec::<f32>()?;
        let conv_state = it.next().expect("tuple length checked").to_vec::<f32>()?;
        let ssm_state = it.next().expect("tuple length checked").to_vec::<f32>()?;
        Ok(StepOutput { logits, conv_state, ssm_state })
    }
}

impl Executor for MambaEngine {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Honest report for today's artifact set: compiled per-shape
    /// prefill/decode executables only, so varlen ticks go through the
    /// default decomposition and no buffer donation is wired up yet.
    /// The two open ROADMAP items are exactly the two flags to flip: a
    /// varlen chunk executable (`varlen_kernel: true` + a `launch`
    /// override) and PJRT input/output aliasing (`donation: true`).
    fn caps(&self) -> EngineCaps {
        EngineCaps::baseline()
    }

    fn prefill(&self, batch: usize, tokens: &[i32]) -> Result<StepOutput> {
        let exe = self
            .prefill_exes
            .get(&batch)
            .ok_or_else(|| anyhow!("no prefill executable for batch {batch}"))?;
        let expect = batch * self.manifest.prefill_len;
        if tokens.len() != expect {
            anyhow::bail!("prefill tokens: got {}, want {}", tokens.len(), expect);
        }
        let toks = xla::Literal::vec1(tokens)
            .reshape(&[batch as i64, self.manifest.prefill_len as i64])?;
        let result = exe.execute::<xla::Literal>(&[toks])?[0][0].to_literal_sync()?;
        Self::unpack(result)
    }

    fn decode(
        &self,
        batch: usize,
        tokens: &[i32],
        conv_state: &[f32],
        ssm_state: &[f32],
    ) -> Result<StepOutput> {
        let exe = self
            .decode_exes
            .get(&batch)
            .ok_or_else(|| anyhow!("no decode executable for batch {batch}"))?;
        if tokens.len() != batch {
            anyhow::bail!("decode tokens: got {}, want {batch}", tokens.len());
        }
        let m = &self.manifest;
        let conv = xla::Literal::vec1(conv_state).reshape(&[
            m.n_layer as i64,
            batch as i64,
            m.d_inner as i64,
            (m.d_conv - 1) as i64,
        ])?;
        let ssm = xla::Literal::vec1(ssm_state).reshape(&[
            m.n_layer as i64,
            batch as i64,
            m.d_inner as i64,
            m.d_state as i64,
        ])?;
        let toks = xla::Literal::vec1(tokens);
        let result = exe.execute::<xla::Literal>(&[toks, conv, ssm])?[0][0].to_literal_sync()?;
        Self::unpack(result)
    }
}

/// Argmax over each row of a `[batch, vocab]` logits buffer, written
/// into a caller-owned vector (cleared first; reuses its capacity so
/// the scheduler's sampling step allocates nothing once warm).
pub fn argmax_rows_into(logits: &[f32], vocab: usize, out: &mut Vec<i32>) {
    out.clear();
    out.extend(logits.chunks_exact(vocab).map(|row| {
        row.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
            .map(|(i, _)| i as i32)
            .unwrap_or(0)
    }));
}

/// Argmax over each row of a `[batch, vocab]` logits buffer.
pub fn argmax_rows(logits: &[f32], vocab: usize) -> Vec<i32> {
    let mut out = Vec::new();
    argmax_rows_into(logits, vocab, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_batch_picks_smallest_fit() {
        let sizes = [1, 2, 4, 8];
        assert_eq!(MambaEngine::fit_batch(&sizes, 1), Some(1));
        assert_eq!(MambaEngine::fit_batch(&sizes, 3), Some(4));
        assert_eq!(MambaEngine::fit_batch(&sizes, 8), Some(8));
        assert_eq!(MambaEngine::fit_batch(&sizes, 9), None);
    }

    #[test]
    fn argmax_rows_basic() {
        let logits = [0.1, 0.9, 0.0, 7.0, -1.0, 2.0];
        assert_eq!(argmax_rows(&logits, 3), vec![1, 0]);
    }

    #[test]
    fn argmax_rows_into_reuses_buffer() {
        let logits = [0.1, 0.9, 0.0, 7.0, -1.0, 2.0];
        let mut out = Vec::with_capacity(8);
        argmax_rows_into(&logits, 3, &mut out);
        assert_eq!(out, vec![1, 0]);
        let cap = out.capacity();
        argmax_rows_into(&logits[..3], 3, &mut out);
        assert_eq!(out, vec![1]);
        assert_eq!(out.capacity(), cap, "buffer must be reused, not reallocated");
    }

    #[test]
    fn traffic_counters_merge_and_total() {
        let mut a = TrafficCounters { bytes_gathered: 3, bytes_scattered: 5 };
        a.merge(TrafficCounters { bytes_gathered: 10, bytes_scattered: 20 });
        assert_eq!(a.bytes_gathered, 13);
        assert_eq!(a.bytes_scattered, 25);
        assert_eq!(a.total(), 38);
    }

    #[test]
    fn workspace_reset_logits_reuses_capacity() {
        let mut ws = Workspace::new();
        ws.reset_logits(4, 10);
        assert_eq!(ws.logits.len(), 40);
        ws.logits[7] = 3.5;
        let cap = ws.logits.capacity();
        ws.reset_logits(2, 10);
        assert_eq!(ws.logits.len(), 20);
        assert!(ws.logits.iter().all(|&x| x == 0.0), "stale logits must be cleared");
        assert_eq!(ws.logits.capacity(), cap);
    }

    #[test]
    fn workspace_modeled_counters_accumulate_and_drain() {
        let mut ws = Workspace::new();
        ws.record_modeled(100, 4096);
        ws.record_modeled(50, 1024);
        assert_eq!(ws.modeled(), (150, 5120));
        assert_eq!(ws.take_modeled(), (150, 5120));
        assert_eq!(ws.modeled(), (0, 0));
    }

    #[test]
    fn workspace_take_drains_counters() {
        let mut ws = Workspace::new();
        ws.traffic.bytes_gathered = 8;
        ws.traffic.bytes_scattered = 4;
        ws.padded_rows = 2;
        ws.record_device_call();
        ws.record_device_call();
        ws.record_device_call();
        let t = ws.take_traffic();
        assert_eq!(t.total(), 12);
        assert_eq!(ws.traffic(), TrafficCounters::default());
        assert_eq!(ws.take_padded_rows(), 2);
        assert_eq!(ws.padded_rows(), 0);
        assert_eq!(ws.device_calls(), 3);
        assert_eq!(ws.take_device_calls(), 3);
        assert_eq!(ws.device_calls(), 0);
    }
}
