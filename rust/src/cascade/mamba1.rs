//! The Mamba-1 layer as a 24-Einsum extended cascade (paper Figure 1).
//!
//! Rank key: `I` = token position (generational), `E` = d_model,
//! `D` = d_inner, `N` = d_state, `R` = dt_rank, `J` = conv kernel.
//! Batch is folded into the `I` extent (tokens are what flow through a
//! layer; weights are shared across them), matching the per-layer
//! analysis of the paper.
//!
//! Einsum numbering preserves every anchor the paper's prose uses:
//! NUM at #3 (reduces over E), NEX at #5, TX/RX at #7–8 (shared-input),
//! the conv `TX→TTX` non-unit-step access at #9, LEX at #10 (two-pass),
//! skinny x-proj GEMMs at #11–13, dt GEMM+softplus at #14–15,
//! discretization at #16–17 (shared-input from Δ), the SSM region at
//! #16–21, post-processing → Y at #22–23, out-proj at #24. See
//! DESIGN.md §2 for the full table and the paper's (internally
//! inconsistent) alternate numberings.

use crate::einsum::{
    Cascade, DType, EinsumSpec, Operand, OpKind, Rank, RankAccess, TensorClass, TensorSpec,
    UnaryFn,
};

/// Names of the Einsums in the SSM region (paper: Einsums 16–21).
pub const SSM_REGION: [usize; 6] = [16, 17, 18, 19, 20, 21];

/// Build the Mamba-1 single-layer cascade.
///
/// * `cfg` — model dimensions;
/// * `seqlen` — tokens along the generational `I` rank (1 = decode step);
/// * `batch` — batch size, folded into the `I` extent.
pub fn build(cfg: &super::config::ModelConfig, seqlen: u64, batch: u64) -> Cascade {
    let tokens = seqlen.max(1) * batch.max(1);
    let i = Rank::generational("I", tokens);
    let e = Rank::new("E", cfg.d_model);
    let d = Rank::new("D", cfg.d_inner);
    let n = Rank::new("N", cfg.d_state);
    let r = Rank::new("R", cfg.dt_rank);
    let j = Rank::new("J", cfg.d_conv);

    let dt = DType::F16;
    use TensorClass::*;

    // --- tensor shorthands -------------------------------------------------
    let t = |name: &str, ranks: &[&Rank], class: TensorClass| {
        TensorSpec::new(name, ranks.iter().map(|r| (*r).clone()).collect(), dt, class)
    };

    // External inputs.
    let t_in = t("In", &[&i, &e], Input);
    let t_res = t("Res", &[&i, &e], Input);

    // Weights.
    let w_gamma = t("Gamma", &[&e], Weight);
    let w_beta = t("Beta", &[&e], Weight);
    let w_tx = t("Wtx", &[&e, &d], Weight);
    let w_rx = t("Wrx", &[&e, &d], Weight);
    let w_conv = t("Wconv", &[&d, &j], Weight);
    let w_cbias = t("Bconv", &[&d], Weight);
    let w_b = t("Wb", &[&d, &n], Weight);
    let w_c = t("Wc", &[&d, &n], Weight);
    let w_dlt = t("Wdlt", &[&d, &r], Weight);
    let w_dt = t("Wdt", &[&r, &d], Weight);
    let w_dtb = t("Bdt", &[&d], Weight);
    let w_a = t("A", &[&d, &n], Weight);
    let w_skip = t("Dw", &[&d], Weight);
    let w_o = t("Wo", &[&d, &e], Weight);

    // Intermediates (declared as we produce them).
    let t_x = t("X", &[&i, &e], Intermediate);
    let t_sq = t("SQ", &[&i, &e], Intermediate);
    let t_num = t("NUM", &[&i], Intermediate);
    let t_isr = t("ISR", &[&i], Intermediate);
    let t_nex = t("NEX", &[&i, &e], Intermediate);
    let t_gx = t("GX", &[&i, &e], Intermediate);
    let t_tx = t("TX", &[&i, &d], Intermediate);
    let t_rx = t("RX", &[&i, &d], Intermediate);
    let t_ttx = t("TTX", &[&i, &d], Intermediate);
    let t_lex = t("LEX", &[&i, &d], Intermediate);
    let t_xb = t("XB", &[&i, &n], Intermediate);
    let t_xc = t("XC", &[&i, &n], Intermediate);
    let t_ttd = t("TTD", &[&i, &r], Intermediate);
    let t_dt = t("DT", &[&i, &d], Intermediate);
    let t_dl = t("DL", &[&i, &d], Intermediate);
    let t_ab = t("AB", &[&i, &d, &n], Intermediate);
    let t_bb = t("BB", &[&i, &d, &n], Intermediate);
    let t_bx = t("BX", &[&i, &d, &n], Intermediate);
    let t_hh = t("HH", &[&i, &d, &n], Intermediate);
    let t_h = t("H", &[&i, &d, &n], Recurrent);
    let t_s = t("S", &[&i, &d], Intermediate);
    let t_sd = t("SD", &[&i, &d], Intermediate);
    let t_y = t("Y", &[&i, &d], Intermediate);
    let t_out = t("Out", &[&i, &e], Output);

    let p = Operand::plain;

    let einsums = vec![
        // 1: residual stream entry — X used at #2, #5 and conceptually by
        // the next layer; the paper flags X as a two-pass tensor.
        EinsumSpec::new(1, "X", t_x.clone(), vec![p(t_in), p(t_res)], vec![], OpKind::Add),
        // 2–6: RMSNorm.
        EinsumSpec::new(
            2,
            "SQ",
            t_sq.clone(),
            vec![p(t_x.clone()), p(t_x.clone())],
            vec![],
            OpKind::Mul,
        ),
        EinsumSpec::new(
            3,
            "NUM",
            t_num.clone(),
            vec![p(t_sq)],
            vec![e.clone()],
            OpKind::MulAcc, // Σ_e SQ·1 — reduction, not GEMM-scale
        ),
        EinsumSpec::new(
            4,
            "ISR",
            t_isr.clone(),
            vec![p(t_num)],
            vec![],
            OpKind::Unary(UnaryFn::Rsqrt),
        ),
        EinsumSpec::new(
            5,
            "NEX",
            t_nex.clone(),
            vec![p(t_x.clone()), p(t_isr)],
            vec![],
            OpKind::Mul,
        ),
        EinsumSpec::new(
            6,
            "GX",
            t_gx.clone(),
            vec![p(t_nex), p(w_gamma), p(w_beta)],
            vec![],
            OpKind::MulAdd,
        ),
        // 7–8: in-proj, shared-input GEMM pair.
        EinsumSpec::new(
            7,
            "TX",
            t_tx.clone(),
            vec![p(t_gx.clone()), p(w_tx)],
            vec![e.clone()],
            OpKind::MulAcc,
        ),
        EinsumSpec::new(
            8,
            "RX",
            t_rx.clone(),
            vec![p(t_gx), p(w_rx)],
            vec![e.clone()],
            OpKind::MulAcc,
        ),
        // 9: causal depthwise conv — windowed access along I.
        EinsumSpec::new(
            9,
            "TTX",
            t_ttx.clone(),
            vec![
                Operand::with_access(t_tx, "I", RankAccess::Windowed { window: cfg.d_conv }),
                p(w_conv),
            ],
            vec![j],
            OpKind::MulAcc,
        ),
        // 10: SiLU — LEX, the cascade's most-consumed (two-pass) tensor.
        EinsumSpec::new(
            10,
            "LEX",
            t_lex.clone(),
            vec![p(t_ttx), p(w_cbias)],
            vec![],
            OpKind::Unary(UnaryFn::SiLU),
        ),
        // 11–13: x-proj, shared-input skinny GEMMs (non-ideal aspect).
        EinsumSpec::new(
            11,
            "XB",
            t_xb.clone(),
            vec![p(t_lex.clone()), p(w_b)],
            vec![d.clone()],
            OpKind::MulAcc,
        ),
        EinsumSpec::new(
            12,
            "XC",
            t_xc.clone(),
            vec![p(t_lex.clone()), p(w_c)],
            vec![d.clone()],
            OpKind::MulAcc,
        ),
        EinsumSpec::new(
            13,
            "TTD",
            t_ttd.clone(),
            vec![p(t_lex.clone()), p(w_dlt)],
            vec![d.clone()],
            OpKind::MulAcc,
        ),
        // 14–15: dt-proj GEMM + softplus.
        EinsumSpec::new(
            14,
            "DT",
            t_dt.clone(),
            vec![p(t_ttd), p(w_dt)],
            vec![r],
            OpKind::MulAcc,
        ),
        EinsumSpec::new(
            15,
            "DL",
            t_dl.clone(),
            vec![p(t_dt), p(w_dtb)],
            vec![],
            OpKind::Unary(UnaryFn::Softplus),
        ),
        // 16–17: discretization (shared-input pair from Δ).
        EinsumSpec::new(
            16,
            "AB",
            t_ab.clone(),
            vec![p(t_dl.clone()), p(w_a)],
            vec![],
            OpKind::MulUnary(UnaryFn::Exp), // exp(Δ ⊗ A)
        ),
        EinsumSpec::new(
            17,
            "BB",
            t_bb.clone(),
            vec![p(t_dl), p(t_xb)],
            vec![],
            OpKind::Mul, // Δ ⊗ B (broadcast outer product)
        ),
        // 18: input scaling B̄ · x.
        EinsumSpec::new(
            18,
            "BX",
            t_bx.clone(),
            vec![p(t_bb), p(t_lex.clone())],
            vec![],
            OpKind::Mul,
        ),
        // 19–20: the recurrence.
        EinsumSpec::new(
            19,
            "HH",
            t_hh.clone(),
            vec![
                p(t_ab),
                Operand::with_access(t_h.clone(), "I", RankAccess::Lagged { offset: 1 }),
            ],
            vec![],
            OpKind::Mul,
        ),
        EinsumSpec::new(20, "H", t_h.clone(), vec![p(t_hh), p(t_bx)], vec![], OpKind::Add),
        // 21: readout S = Σ_n C · H.
        EinsumSpec::new(
            21,
            "S",
            t_s.clone(),
            vec![p(t_xc), p(t_h)],
            vec![n],
            OpKind::MulAcc,
        ),
        // 22–23: skip + gate.
        EinsumSpec::new(
            22,
            "SD",
            t_sd.clone(),
            vec![p(t_s), p(w_skip), p(t_lex)],
            vec![],
            OpKind::MulAdd,
        ),
        EinsumSpec::new(
            23,
            "Y",
            t_y.clone(),
            vec![p(t_sd), p(t_rx)],
            vec![],
            OpKind::MulUnary(UnaryFn::SiLU), // SD · SiLU(RX)
        ),
        // 24: out-proj.
        EinsumSpec::new(24, "Out", t_out, vec![p(t_y), p(w_o)], vec![d], OpKind::MulAcc),
    ];

    Cascade::new(format!("mamba1/{}/I={}", cfg.name, tokens), einsums)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cascade::config::ModelConfig;
    use crate::einsum::SpaceRelation;

    fn c370(seq: u64) -> Cascade {
        build(&ModelConfig::mamba_370m(), seq, 1)
    }

    #[test]
    fn has_24_einsums_and_validates() {
        let c = c370(512);
        assert_eq!(c.len(), 24);
        c.validate().expect("cascade must validate");
    }

    #[test]
    fn seven_gemm_like() {
        // Paper §II: "7 of those 24 are GEMM-like".
        let c = c370(512);
        let gemms: Vec<usize> =
            c.einsums().iter().filter(|e| e.is_gemm_like()).map(|e| e.id).collect();
        assert_eq!(gemms, vec![7, 8, 11, 12, 13, 14, 24]);
        assert_eq!(c.gemm_count(), 7);
    }

    #[test]
    fn paper_anchor_einsums() {
        let c = c370(64);
        assert_eq!(c.by_id(3).unwrap().name, "NUM");
        assert_eq!(c.by_id(5).unwrap().name, "NEX");
        assert_eq!(c.by_id(7).unwrap().name, "TX");
        assert_eq!(c.by_id(8).unwrap().name, "RX");
        assert_eq!(c.by_id(10).unwrap().name, "LEX");
        assert_eq!(c.by_id(21).unwrap().name, "S");
        assert_eq!(c.by_id(24).unwrap().name, "Out");
    }

    #[test]
    fn recurrent_edges_exist() {
        let c = c370(64);
        let edges = c.edges();
        // H[i-1] read by HH (#19): a dashed recurrent edge from 20 → 19.
        assert!(edges.iter().any(|e| e.tensor == "H" && e.to == 19 && e.recurrent));
        // TX windowed by conv (#9).
        assert!(c.by_id(9).unwrap().is_recurrent());
    }

    #[test]
    fn rx_has_long_liveness() {
        // Paper: RX "has a long dependency chain: it is not needed again
        // until Einsum 22/23".
        let c = c370(64);
        let live = c.liveness();
        let rx = live.iter().find(|(n, _, _)| n == "RX").unwrap();
        assert_eq!(rx.1, 8);
        assert_eq!(rx.2, 23);
        assert!(rx.2 - rx.1 >= 15);
    }

    #[test]
    fn lex_is_multiconsumer() {
        let c = c370(64);
        let consumers = c.consumers();
        let lex = consumers.get("LEX").unwrap();
        // LEX feeds x-proj (11,12,13), BX (18), and skip (22).
        assert_eq!(lex, &vec![11, 12, 13, 18, 22]);
    }

    #[test]
    fn ssm_region_relations() {
        // Inside the SSM region (16–21): 16→19 equal spaces, 20→21 is a
        // reduction boundary (superset).
        let c = c370(64);
        let ab = c.by_id(16).unwrap().iteration_space();
        let hh = c.by_id(19).unwrap().iteration_space();
        assert_eq!(ab.relation(&hh), SpaceRelation::Equal);
        let s = c.by_id(21).unwrap().iteration_space();
        let h = c.by_id(20).unwrap().iteration_space();
        // S iterates {I,D,N} too (N is reduced) → equal rank sets.
        assert_eq!(h.relation(&s), SpaceRelation::Equal);
        // But S's *output* drops N: downstream of S sees {I,D}.
        let sd = c.by_id(22).unwrap().iteration_space();
        assert_eq!(s.relation(&sd), SpaceRelation::Superset);
    }

    #[test]
    fn decode_cascade_has_unit_i() {
        let c = build(&ModelConfig::mamba_370m(), 1, 1);
        let e = c.by_id(19).unwrap();
        let is = e.iteration_space();
        assert_eq!(is.rank("I").unwrap().extent, 1);
    }

    #[test]
    fn batch_folds_into_i() {
        let c = build(&ModelConfig::mamba_370m(), 1, 64);
        assert_eq!(c.by_id(1).unwrap().output.ranks[0].extent, 64);
    }
}
