//! Pedagogical cascades from the paper's Figures 4–8: the canonical
//! two-Einsum pattern for each fusion class, and the five-Einsum
//! greedy-stitching example of Figure 8. Used by tests, the quickstart
//! example, and the fusion-classifier unit tests.

use crate::einsum::{
    Cascade, DType, EinsumSpec, Operand, OpKind, Rank, TensorClass, TensorSpec, UnaryFn,
};

fn t(name: &str, ranks: &[&Rank], class: TensorClass) -> TensorSpec {
    TensorSpec::new(name, ranks.iter().map(|r| (*r).clone()).collect(), DType::F16, class)
}

/// Figure 4 — RI: elementwise (`Z = A·B`) → reduce... the paper's RI
/// figure fuses two Einsums with the *same* iteration space {M,K}:
/// `Z[m,k] = A[m,k]·B[m,k]`, then `Y[m] = Σ_k Z[m,k]` shares {M,K}.
pub fn fig4_ri(m: u64, k: u64) -> Cascade {
    let rm = Rank::new("M", m);
    let rk = Rank::new("K", k);
    let a = t("A", &[&rm, &rk], TensorClass::Input);
    let b = t("B", &[&rm, &rk], TensorClass::Input);
    let z = t("Z", &[&rm, &rk], TensorClass::Intermediate);
    let y = t("Y", &[&rm], TensorClass::Output);
    let p = Operand::plain;
    Cascade::new(
        "fig4-ri",
        vec![
            EinsumSpec::new(1, "Z", z.clone(), vec![p(a), p(b)], vec![], OpKind::Mul),
            EinsumSpec::new(2, "Y", y, vec![p(z)], vec![rk], OpKind::MulAcc),
        ],
    )
}

/// Figure 5 — RSb: matrix-vector (`Z[m] = Σ_k A[m,k]·B[k]`) followed by
/// an elementwise op (`Y[m] = f(Z[m])`): upstream {M,K} ⊃ downstream {M}.
pub fn fig5_rsb(m: u64, k: u64) -> Cascade {
    let rm = Rank::new("M", m);
    let rk = Rank::new("K", k);
    let a = t("A", &[&rm, &rk], TensorClass::Input);
    let b = t("B", &[&rk], TensorClass::Input);
    let z = t("Z", &[&rm], TensorClass::Intermediate);
    let y = t("Y", &[&rm], TensorClass::Output);
    let p = Operand::plain;
    Cascade::new(
        "fig5-rsb",
        vec![
            EinsumSpec::new(1, "Z", z.clone(), vec![p(a), p(b)], vec![rk], OpKind::MulAcc),
            EinsumSpec::new(2, "Y", y, vec![p(z)], vec![], OpKind::Unary(UnaryFn::Exp)),
        ],
    )
}

/// Figure 6 — RSp: broadcast (`Z[m] = f(A[m])`) followed by matrix
/// multiply that broadcasts Z over a new rank:
/// `Y[m,p] = Σ_n Z[m]·C[n,p]·B[m,n]` — modeled minimally as upstream {M}
/// ⊂ downstream {M,N,P}.
pub fn fig6_rsp(m: u64, n: u64, p_: u64) -> Cascade {
    let rm = Rank::new("M", m);
    let rn = Rank::new("N", n);
    let rp = Rank::new("P", p_);
    let a = t("A", &[&rm], TensorClass::Input);
    let c = t("C", &[&rn, &rp], TensorClass::Input);
    let z = t("Z", &[&rm], TensorClass::Intermediate);
    let y = t("Y", &[&rm, &rp], TensorClass::Output);
    let pl = Operand::plain;
    Cascade::new(
        "fig6-rsp",
        vec![
            EinsumSpec::new(1, "Z", z.clone(), vec![pl(a)], vec![], OpKind::Unary(UnaryFn::Exp)),
            EinsumSpec::new(2, "Y", y, vec![pl(z), pl(c)], vec![rn], OpKind::MulAcc),
        ],
    )
}

/// Figure 7 — RD: back-to-back matmuls `Z[m,n] = Σ_k A·B` then
/// `Y[m,p] = Σ_n Z·C`: upstream {M,N,K} ⊥ downstream {M,N,P}.
pub fn fig7_rd(m: u64, n: u64, k: u64, p_: u64) -> Cascade {
    let rm = Rank::new("M", m);
    let rn = Rank::new("N", n);
    let rk = Rank::new("K", k);
    let rp = Rank::new("P", p_);
    let a = t("A", &[&rm, &rk], TensorClass::Input);
    let b = t("B", &[&rk, &rn], TensorClass::Input);
    let c = t("C", &[&rn, &rp], TensorClass::Input);
    let z = t("Z", &[&rm, &rn], TensorClass::Intermediate);
    let y = t("Y", &[&rm, &rp], TensorClass::Output);
    let pl = Operand::plain;
    Cascade::new(
        "fig7-rd",
        vec![
            EinsumSpec::new(1, "Z", z.clone(), vec![pl(a), pl(b)], vec![rk], OpKind::MulAcc),
            EinsumSpec::new(2, "Y", y, vec![pl(z), pl(c)], vec![rn], OpKind::MulAcc),
        ],
    )
}

/// Figure 8 — the five-Einsum greedy-stitching example:
/// E1 `Z[m,n] = Σ_k A[m,k]·B[k,n]`       IS₁ = {M,N,K}
/// E2 `Y[m,n,p] = Z[m,n]·C[p]`           IS₂ = {M,N,P}
/// E3 `X[m,n,q] = Σ_p Y[m,n,p]·W[q]`     IS₃ = {M,N,P,Q}
/// E4 `V[n] = Σ_{m,q} X[m,n,q]·D[q]`     IS₄ = {M,N,Q}
/// E5 `U[n] = f(V[n])`                   IS₅ = {N}
/// Greedy stitching yields groups {E1,E2,E3} and {E4,E5}.
pub fn fig8_five(m: u64, n: u64, k: u64, p_: u64, q: u64) -> Cascade {
    let rm = Rank::new("M", m);
    let rn = Rank::new("N", n);
    let rk = Rank::new("K", k);
    let rp = Rank::new("P", p_);
    let rq = Rank::new("Q", q);
    let a = t("A", &[&rm, &rk], TensorClass::Input);
    let b = t("B", &[&rk, &rn], TensorClass::Input);
    let c = t("C", &[&rp], TensorClass::Input);
    let w = t("W", &[&rq], TensorClass::Input);
    let d = t("D", &[&rq], TensorClass::Input);
    let z = t("Z", &[&rm, &rn], TensorClass::Intermediate);
    let y = t("Y", &[&rm, &rn, &rp], TensorClass::Intermediate);
    let x = t("X", &[&rm, &rn, &rq], TensorClass::Intermediate);
    let v = t("V", &[&rn], TensorClass::Intermediate);
    let u = t("U", &[&rn], TensorClass::Output);
    let pl = Operand::plain;
    Cascade::new(
        "fig8-five",
        vec![
            EinsumSpec::new(1, "Z", z.clone(), vec![pl(a), pl(b)], vec![rk], OpKind::MulAcc),
            EinsumSpec::new(2, "Y", y.clone(), vec![pl(z), pl(c)], vec![], OpKind::Mul),
            EinsumSpec::new(3, "X", x.clone(), vec![pl(y), pl(w)], vec![rp], OpKind::MulAcc),
            EinsumSpec::new(
                4,
                "V",
                v.clone(),
                vec![pl(x), pl(d)],
                vec![rm.clone(), rq],
                OpKind::MulAcc,
            ),
            EinsumSpec::new(5, "U", u, vec![pl(v)], vec![], OpKind::Unary(UnaryFn::Exp)),
        ],
    )
}

/// The generational-rank example of paper Eq. (1):
/// `Z[i+1] = A[i] · Z[i]` over `i ≤ K`.
pub fn eq1_generational(k: u64) -> Cascade {
    let ri = Rank::generational("I", k);
    let a = t("A", &[&ri], TensorClass::Input);
    let z = t("Z", &[&ri], TensorClass::Recurrent);
    Cascade::new(
        "eq1-generational",
        vec![EinsumSpec::new(
            1,
            "Z",
            z.clone(),
            vec![
                Operand::plain(a),
                Operand::with_access(z.clone(), "I", crate::einsum::RankAccess::Lagged { offset: 1 }),
            ],
            vec![],
            OpKind::Mul,
        )],
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::einsum::SpaceRelation;

    #[test]
    fn all_examples_validate() {
        fig4_ri(8, 4).validate().unwrap();
        fig5_rsb(8, 4).validate().unwrap();
        fig6_rsp(8, 4, 2).validate().unwrap();
        fig7_rd(8, 4, 6, 2).validate().unwrap();
        fig8_five(4, 5, 6, 3, 2).validate().unwrap();
        eq1_generational(10).validate().unwrap();
    }

    #[test]
    fn example_relations_match_figures() {
        let rel = |c: &Cascade| {
            let up = c.einsums()[0].iteration_space();
            let dn = c.einsums()[1].iteration_space();
            up.relation(&dn)
        };
        assert_eq!(rel(&fig4_ri(8, 4)), SpaceRelation::Equal);
        assert_eq!(rel(&fig5_rsb(8, 4)), SpaceRelation::Superset);
        assert_eq!(rel(&fig6_rsp(8, 4, 2)), SpaceRelation::Subset);
        assert_eq!(rel(&fig7_rd(8, 4, 6, 2)), SpaceRelation::Disjoint);
    }

    #[test]
    fn fig8_iteration_spaces() {
        let c = fig8_five(4, 5, 6, 3, 2);
        let spaces: Vec<Vec<String>> = c
            .einsums()
            .iter()
            .map(|e| {
                e.iteration_space().rank_names().iter().map(|s| s.to_string()).collect()
            })
            .collect();
        assert_eq!(spaces[0], vec!["K", "M", "N"]);
        assert_eq!(spaces[1], vec!["M", "N", "P"]);
        assert_eq!(spaces[2], vec!["M", "N", "P", "Q"]);
        assert_eq!(spaces[3], vec!["M", "N", "Q"]);
        assert_eq!(spaces[4], vec!["N"]);
    }
}
