//! Workload configurations: model dimensions for the Mamba family.
//!
//! Dims follow the released state-spaces checkpoints: `d_inner = 2·d_model`,
//! `d_state = 16` (Mamba-1), `dt_rank = ceil(d_model/16)`, conv kernel 4.
//! The paper evaluates mamba-370m and mamba-2.8b (§VI-A); the tiny config
//! is the functional serving model (examples/serve_mamba).

/// Model dimensions for one Mamba model.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelConfig {
    pub name: String,
    /// `E`: embedding / d_model.
    pub d_model: u64,
    /// `D`: inner dimension (2·E for Mamba).
    pub d_inner: u64,
    /// `N`: SSM state size (16 for Mamba-1).
    pub d_state: u64,
    /// `R`: low-rank Δ projection dimension.
    pub dt_rank: u64,
    /// `J`: causal-conv kernel width.
    pub d_conv: u64,
    /// Number of layers.
    pub layers: u64,
    /// Vocabulary size (used by the functional serving model).
    pub vocab: u64,
}

impl ModelConfig {
    fn new(name: &str, d_model: u64, layers: u64) -> Self {
        ModelConfig {
            name: name.to_string(),
            d_model,
            d_inner: 2 * d_model,
            d_state: 16,
            dt_rank: d_model.div_ceil(16),
            d_conv: 4,
            layers,
            vocab: 50280,
        }
    }

    /// mamba-130m: E=768, 24 layers.
    pub fn mamba_130m() -> Self {
        Self::new("mamba-130m", 768, 24)
    }

    /// mamba-370m: E=1024, 48 layers (paper's primary model).
    pub fn mamba_370m() -> Self {
        Self::new("mamba-370m", 1024, 48)
    }

    /// mamba-1.4b: E=2048, 48 layers.
    pub fn mamba_1_4b() -> Self {
        Self::new("mamba-1.4b", 2048, 48)
    }

    /// mamba-2.8b: E=2560, 64 layers ("more than doubles the E and D
    /// ranks and uses 64 layers", paper §VI-A).
    pub fn mamba_2_8b() -> Self {
        Self::new("mamba-2.8b", 2560, 64)
    }

    /// Tiny functional model for end-to-end serving on CPU PJRT:
    /// E=64, 2 layers, small vocab. Exercises the same cascade shape.
    pub fn tiny() -> Self {
        let mut c = Self::new("mamba-tiny", 64, 2);
        c.vocab = 256;
        c
    }

    /// Look up by name (CLI).
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "mamba-130m" | "130m" => Some(Self::mamba_130m()),
            "mamba-370m" | "370m" => Some(Self::mamba_370m()),
            "mamba-1.4b" | "1.4b" => Some(Self::mamba_1_4b()),
            "mamba-2.8b" | "2.8b" => Some(Self::mamba_2_8b()),
            "tiny" | "mamba-tiny" => Some(Self::tiny()),
            _ => None,
        }
    }

    /// Per-layer weight parameter count (Mamba-1 block):
    /// in-proj (E·2D) + conv (D·J + D) + x-proj (D·(2N+R)) +
    /// dt-proj (R·D + D) + A (D·N) + D-skip (D) + out-proj (D·E) + norm (E).
    pub fn layer_params(&self) -> u64 {
        let (e, d, n, r, j) =
            (self.d_model, self.d_inner, self.d_state, self.dt_rank, self.d_conv);
        e * 2 * d + d * j + d + d * (2 * n + r) + r * d + d + d * n + d + d * e + e
    }

    /// Total parameters (layers + embedding + lm head tied).
    pub fn total_params(&self) -> u64 {
        self.layers * self.layer_params() + self.vocab * self.d_model
    }
}

/// A serving/analysis scenario: batch and phase lengths (paper §VI-C:
/// "each bar grouping is a specific ratio of context length to token
/// generation length").
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Scenario {
    pub name: String,
    pub batch: u64,
    /// Prefill (context) length.
    pub prefill: u64,
    /// Decode (generation) length.
    pub decode: u64,
}

impl Scenario {
    pub fn new(name: &str, batch: u64, prefill: u64, decode: u64) -> Self {
        Scenario { name: name.to_string(), batch, prefill, decode }
    }

    /// The paper's three scenario families (Fig 12): small context /
    /// long generation, balanced, large context / short generation.
    pub fn paper_suite() -> Vec<Scenario> {
        vec![
            Scenario::new("ctx:gen=1:64 (explain)", 64, 64, 4096),
            Scenario::new("ctx:gen=1:1 (edit)", 64, 1024, 1024),
            Scenario::new("ctx:gen=64:1 (summarize)", 64, 16384, 256),
        ]
    }

    /// Ratio of prefill to decode length.
    pub fn ratio(&self) -> f64 {
        self.prefill as f64 / self.decode.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_dims() {
        let m = ModelConfig::mamba_370m();
        assert_eq!(m.d_model, 1024);
        assert_eq!(m.d_inner, 2048);
        assert_eq!(m.d_state, 16);
        assert_eq!(m.dt_rank, 64);
        assert_eq!(m.layers, 48);

        let big = ModelConfig::mamba_2_8b();
        // "more than doubles the E and D ranks and uses 64 layers"
        assert!(big.d_model >= 2 * m.d_model);
        assert_eq!(big.layers, 64);
    }

    #[test]
    fn param_counts_are_plausible() {
        // mamba-370m should land near 370M params (±25%).
        let p = ModelConfig::mamba_370m().total_params() as f64;
        assert!(p > 0.75 * 370e6 && p < 1.25 * 370e6, "params = {p}");
        let p = ModelConfig::mamba_2_8b().total_params() as f64;
        assert!(p > 0.75 * 2.8e9 && p < 1.25 * 2.8e9, "params = {p}");
    }

    #[test]
    fn lookup() {
        assert!(ModelConfig::by_name("370m").is_some());
        assert!(ModelConfig::by_name("nope").is_none());
    }

    #[test]
    fn scenarios() {
        let suite = Scenario::paper_suite();
        assert_eq!(suite.len(), 3);
        assert!(suite[0].ratio() < 1.0);
        assert!(suite[2].ratio() > 1.0);
    }
}
