//! Mamba-2 (SSD) layer as an extended-Einsum cascade.
//!
//! Table II claims the taxonomy covers "Mamba-1/2, TA+". Mamba-2's
//! structured state-space duality simplifies the recurrence: `A` becomes
//! a per-head *scalar* `a_{i,h}` (shared across the head's channels),
//! `B`/`C` are shared across heads like grouped attention, and the norm
//! moves after gating. The cascade is shorter (16 Einsums here) but has
//! the same fusion-relevant structure: elementwise preamble, shared-input
//! GEMMs, a generational-rank recurrence, a reduction readout, gating,
//! and an out-projection.

use crate::einsum::{
    Cascade, DType, EinsumSpec, Operand, OpKind, Rank, RankAccess, TensorClass, TensorSpec,
    UnaryFn,
};

use super::config::ModelConfig;

/// Build the Mamba-2 single-layer cascade. `P` = head dim, `Hh` = heads
/// (d_inner = Hh·P), `N` = state dim (larger in Mamba-2, 128 typical).
pub fn build(cfg: &ModelConfig, seqlen: u64, batch: u64) -> Cascade {
    let tokens = seqlen.max(1) * batch.max(1);
    let head_dim = 64u64.min(cfg.d_inner);
    let heads = cfg.d_inner / head_dim;
    let n_state = 128u64;

    let i = Rank::generational("I", tokens);
    let e = Rank::new("E", cfg.d_model);
    let h = Rank::new("Hh", heads);
    let p_ = Rank::new("P", head_dim);
    let n = Rank::new("N", n_state);
    let dt = DType::F16;
    use TensorClass::*;

    let t = |name: &str, ranks: &[&Rank], class: TensorClass| {
        TensorSpec::new(name, ranks.iter().map(|r| (*r).clone()).collect(), dt, class)
    };

    let t_in = t("In", &[&i, &e], Input);
    let w_gamma = t("Gamma", &[&e], Weight);
    let w_zx = t("Wzx", &[&e, &h, &p_], Weight);
    let w_x = t("Wx", &[&e, &h, &p_], Weight);
    let w_b = t("Wb", &[&e, &n], Weight);
    let w_c = t("Wc", &[&e, &n], Weight);
    let w_dt = t("Wdt", &[&e, &h], Weight);
    let w_a = t("Alog", &[&h], Weight);
    let w_skip = t("Dw", &[&h], Weight);
    let w_o = t("Wo", &[&h, &p_, &e], Weight);

    let t_sq = t("SQ", &[&i, &e], Intermediate);
    let t_num = t("NUM", &[&i], Intermediate);
    let t_isr = t("ISR", &[&i], Intermediate);
    let t_nx = t("NX", &[&i, &e], Intermediate);
    let t_z = t("Z", &[&i, &h, &p_], Intermediate);
    let t_xp = t("XP", &[&i, &h, &p_], Intermediate);
    let t_b = t("Bt", &[&i, &n], Intermediate);
    let t_c = t("Ct", &[&i, &n], Intermediate);
    let t_dtr = t("DTr", &[&i, &h], Intermediate);
    let t_dl = t("DL", &[&i, &h], Intermediate);
    let t_ab = t("ABar", &[&i, &h], Intermediate);
    let t_bx = t("BX", &[&i, &h, &p_, &n], Intermediate);
    let t_hst = t("Hs", &[&i, &h, &p_, &n], Recurrent);
    let t_s = t("S", &[&i, &h, &p_], Intermediate);
    let t_y = t("Y", &[&i, &h, &p_], Intermediate);
    let t_out = t("Out", &[&i, &e], Output);

    let pl = Operand::plain;
    let einsums = vec![
        EinsumSpec::new(1, "SQ", t_sq.clone(), vec![pl(t_in.clone()), pl(t_in.clone())], vec![], OpKind::Mul),
        EinsumSpec::new(2, "NUM", t_num.clone(), vec![pl(t_sq)], vec![e.clone()], OpKind::MulAcc),
        EinsumSpec::new(3, "ISR", t_isr.clone(), vec![pl(t_num)], vec![], OpKind::Unary(UnaryFn::Rsqrt)),
        EinsumSpec::new(4, "NX", t_nx.clone(), vec![pl(t_in), pl(t_isr), pl(w_gamma)], vec![], OpKind::MulAdd),
        // Shared-input projection block (z, x, B, C, Δ all from NX).
        EinsumSpec::new(5, "Z", t_z.clone(), vec![pl(t_nx.clone()), pl(w_zx)], vec![e.clone()], OpKind::MulAcc),
        EinsumSpec::new(6, "XP", t_xp.clone(), vec![pl(t_nx.clone()), pl(w_x)], vec![e.clone()], OpKind::MulAcc),
        EinsumSpec::new(7, "Bt", t_b.clone(), vec![pl(t_nx.clone()), pl(w_b)], vec![e.clone()], OpKind::MulAcc),
        EinsumSpec::new(8, "Ct", t_c.clone(), vec![pl(t_nx.clone()), pl(w_c)], vec![e.clone()], OpKind::MulAcc),
        EinsumSpec::new(9, "DTr", t_dtr.clone(), vec![pl(t_nx), pl(w_dt)], vec![e.clone()], OpKind::MulAcc),
        EinsumSpec::new(10, "DL", t_dl.clone(), vec![pl(t_dtr)], vec![], OpKind::Unary(UnaryFn::Softplus)),
        // Scalar discretization per head: ABar = exp(-Δ·exp(Alog)).
        EinsumSpec::new(11, "ABar", t_ab.clone(), vec![pl(t_dl.clone()), pl(w_a)], vec![], OpKind::MulUnary(UnaryFn::Exp)),
        // BX = Δ · x ⊗ B (broadcast outer over P×N).
        EinsumSpec::new(12, "BX", t_bx.clone(), vec![pl(t_dl), pl(t_xp.clone()), pl(t_b)], vec![], OpKind::MulAdd),
        // Recurrence: Hs[i] = ABar[i]·Hs[i-1] + BX[i].
        EinsumSpec::new(
            13,
            "Hs",
            t_hst.clone(),
            vec![
                pl(t_ab),
                Operand::with_access(t_hst.clone(), "I", RankAccess::Lagged { offset: 1 }),
                pl(t_bx),
            ],
            vec![],
            OpKind::MulAdd,
        ),
        // Readout S = Σ_n C·Hs, then skip + gate.
        EinsumSpec::new(14, "S", t_s.clone(), vec![pl(t_c), pl(t_hst)], vec![n], OpKind::MulAcc),
        EinsumSpec::new(15, "Y", t_y.clone(), vec![pl(t_s), pl(w_skip), pl(t_xp), pl(t_z)], vec![], OpKind::MulUnary(UnaryFn::SiLU)),
        EinsumSpec::new(16, "Out", t_out, vec![pl(t_y), pl(w_o)], vec![h, p_], OpKind::MulAcc),
    ];

    Cascade::new(format!("mamba2/{}/I={}", cfg.name, tokens), einsums)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_validates() {
        let c = build(&ModelConfig::mamba_370m(), 128, 1);
        assert_eq!(c.len(), 16);
        c.validate().unwrap();
    }

    #[test]
    fn has_recurrence_and_gemms() {
        let c = build(&ModelConfig::mamba_370m(), 128, 1);
        assert!(c.by_id(13).unwrap().is_recurrent());
        // z/x/B/C/Δ projections + readout + out-proj are contractions.
        assert!(c.gemm_count() >= 7);
    }

    #[test]
    fn state_is_larger_than_mamba1() {
        let c = build(&ModelConfig::mamba_370m(), 1, 1);
        let hs = &c.by_id(13).unwrap().output;
        // Mamba-2 state: heads × head_dim × 128 = d_inner × 128 per token.
        assert_eq!(hs.elements(), 2048 * 128);
    }
}
