//! Concrete workload cascades.
//!
//! * [`mamba1`] — the paper's 24-Einsum Mamba-1 layer (Figure 1);
//! * [`mamba2`] — the Mamba-2 / SSD variant (Table II "Mamba-1/2");
//! * [`transformer`] — the 8-Einsum Transformer foil (FuseMax);
//! * [`examples`] — the pedagogical cascades of Figures 4–8 and Eq. (1);
//! * [`config`] — model dimension configs and serving scenarios.

pub mod config;
pub mod examples;
pub mod mamba1;
pub mod mamba2;
pub mod transformer;

pub use config::{ModelConfig, Scenario};
