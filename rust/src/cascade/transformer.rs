//! A Transformer layer as an 8-Einsum cascade — the complexity foil the
//! paper cites from Nayak et al. (FuseMax): "(A) a small number of
//! overall operators (8 per layer), (B) a relative prevalence of
//! GEMM-like operators (6 out of 8), (C) relative simplicity of
//! producer-consumer dependencies".
//!
//! Einsums: Q/K/V projections, QK^T, softmax (one fused non-GEMM op as
//! FuseMax counts it), AV, output projection, FFN (folded to one GEMM
//! in the 8-op accounting — the attention block is the unit FuseMax
//! analyzes; we follow the same accounting so comparisons line up).

use crate::einsum::{
    Cascade, DType, EinsumSpec, Operand, OpKind, Rank, TensorClass, TensorSpec, UnaryFn,
};

/// Transformer attention-layer dims.
#[derive(Debug, Clone)]
pub struct TransformerConfig {
    pub name: String,
    /// Sequence length (query = key length for self-attention).
    pub seq: u64,
    /// Model width.
    pub d_model: u64,
    /// Per-head width.
    pub d_head: u64,
    /// Head count.
    pub heads: u64,
}

impl TransformerConfig {
    /// GPT-2-medium-like layer, comparable to mamba-370m width.
    pub fn medium(seq: u64) -> Self {
        TransformerConfig { name: "tfm-medium".into(), seq, d_model: 1024, d_head: 64, heads: 16 }
    }
}

/// Build the 8-Einsum attention cascade.
pub fn build(cfg: &TransformerConfig) -> Cascade {
    let i = Rank::new("I", cfg.seq); // query positions
    let k = Rank::new("K", cfg.seq); // key positions
    let e = Rank::new("E", cfg.d_model);
    let f = Rank::new("F", cfg.d_head * cfg.heads); // projected width
    let dt = DType::F16;
    use TensorClass::*;

    let t = |name: &str, ranks: &[&Rank], class: TensorClass| {
        TensorSpec::new(name, ranks.iter().map(|r| (*r).clone()).collect(), dt, class)
    };

    let x = t("X", &[&i, &e], Input);
    let xk = t("Xk", &[&k, &e], Input); // same activations viewed over K
    let wq = t("Wq", &[&e, &f], Weight);
    let wk = t("Wk", &[&e, &f], Weight);
    let wv = t("Wv", &[&e, &f], Weight);
    let wo = t("Wo", &[&f, &e], Weight);

    let q = t("Q", &[&i, &f], Intermediate);
    let kk = t("Kt", &[&k, &f], Intermediate);
    let v = t("V", &[&k, &f], Intermediate);
    let qk = t("QK", &[&i, &k], Intermediate);
    let pr = t("P", &[&i, &k], Intermediate);
    let av = t("AV", &[&i, &f], Intermediate);
    let o = t("O", &[&i, &e], Intermediate);
    let out = t("Out", &[&i, &e], Output);

    let p = Operand::plain;
    let einsums = vec![
        EinsumSpec::new(1, "Q", q.clone(), vec![p(x.clone()), p(wq)], vec![e.clone()], OpKind::MulAcc),
        EinsumSpec::new(2, "Kt", kk.clone(), vec![p(xk.clone()), p(wk)], vec![e.clone()], OpKind::MulAcc),
        EinsumSpec::new(3, "V", v.clone(), vec![p(xk), p(wv)], vec![e.clone()], OpKind::MulAcc),
        EinsumSpec::new(4, "QK", qk.clone(), vec![p(q), p(kk)], vec![f.clone()], OpKind::MulAcc),
        // Softmax folded to one non-GEMM op over {I,K} (FuseMax
        // accounting: max/exp/sum/div are a single bulk nonlinearity).
        EinsumSpec::new(5, "P", pr.clone(), vec![p(qk)], vec![], OpKind::Unary(UnaryFn::Exp)),
        EinsumSpec::new(6, "AV", av.clone(), vec![p(pr), p(v)], vec![k], OpKind::MulAcc),
        EinsumSpec::new(7, "O", o.clone(), vec![p(av), p(wo)], vec![f], OpKind::MulAcc),
        // 8: residual add back into the stream (elementwise).
        EinsumSpec::new(8, "Out", out, vec![p(o), p(x)], vec![], OpKind::Add),
    ];

    Cascade::new(format!("transformer/{}/I={}", cfg.name, cfg.seq), einsums)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eight_ops_six_gemms() {
        // The paper's cited Transformer features: 8 ops, 6 GEMM-like
        // (Q, K, V, QK^T, AV, O-proj; softmax and residual are not).
        let c = build(&TransformerConfig::medium(1024));
        assert_eq!(c.len(), 8);
        assert_eq!(c.gemm_count(), 6);
    }

    #[test]
    fn validates() {
        let c = build(&TransformerConfig::medium(256));
        c.validate().unwrap();
    }

    #[test]
    fn liveness_is_short() {
        // "relative simplicity of producer-consumer dependencies and
        // short lifetimes of intermediates": max liveness distance ≤ 3.
        let c = build(&TransformerConfig::medium(256));
        for (name, from, to) in c.liveness() {
            assert!(to - from <= 3, "{name} lives {from}→{to}");
        }
    }
}
