//! Traffic analysis: per-tensor DRAM traffic attribution under a fusion
//! plan — the drill-down behind Table I and Figure 14 (which tensors
//! actually carry the inter-Einsum bytes, and what each fusion variant
//! eliminates).

use std::collections::BTreeMap;

use crate::einsum::cascade::CascadeIndex;
use crate::einsum::{Cascade, TensorClass};
use crate::fusion::FusionPlan;
use crate::model::passes::analyze_scope_with;

/// Traffic attributed to one tensor under a plan.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TensorTraffic {
    pub reads: u64,
    pub writes: u64,
    /// Inter-Einsum (shared) vs intra (unique) classification.
    pub shared: bool,
    /// Class of the tensor (weight/input/intermediate/...).
    pub class: Option<TensorClass>,
}

impl TensorTraffic {
    pub fn total(&self) -> u64 {
        self.reads + self.writes
    }
}

/// Per-tensor breakdown for a whole plan.
#[derive(Debug, Clone)]
pub struct TrafficBreakdown {
    pub by_tensor: BTreeMap<String, TensorTraffic>,
}

impl TrafficBreakdown {
    /// Total bytes.
    pub fn total(&self) -> u64 {
        self.by_tensor.values().map(|t| t.total()).sum()
    }

    /// Tensors sorted by descending traffic.
    pub fn hottest(&self) -> Vec<(&str, &TensorTraffic)> {
        let mut v: Vec<(&str, &TensorTraffic)> =
            self.by_tensor.iter().map(|(k, t)| (k.as_str(), t)).collect();
        v.sort_by_key(|(_, t)| std::cmp::Reverse(t.total()));
        v
    }

    /// Render the top-k tensors as a table.
    pub fn report(&self, k: usize) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let total = self.total().max(1);
        let _ = writeln!(s, "{:<8} {:>14} {:>14} {:>7} {:<6}", "tensor", "reads", "writes", "share", "kind");
        for (name, t) in self.hottest().into_iter().take(k) {
            let kind = match t.class {
                Some(TensorClass::Weight) => "weight",
                Some(TensorClass::Input) => "input",
                Some(TensorClass::Recurrent) => "state",
                Some(TensorClass::Output) => "output",
                _ => {
                    if t.shared {
                        "inter"
                    } else {
                        "intra"
                    }
                }
            };
            let _ = writeln!(
                s,
                "{:<8} {:>14} {:>14} {:>6.1}% {:<6}",
                name,
                t.reads,
                t.writes,
                100.0 * t.total() as f64 / total as f64,
                kind
            );
        }
        s
    }
}

/// Attribute DRAM traffic per tensor under a fusion plan, using the
/// same accounting as the execution model (pass reloads included,
/// staging/bridge surcharges excluded — those are mapping artifacts
/// attributed to the group, not a tensor).
pub fn breakdown(c: &Cascade, plan: &FusionPlan) -> TrafficBreakdown {
    let idx = CascadeIndex::new(c);
    let mut by_tensor: BTreeMap<String, TensorTraffic> = BTreeMap::new();
    let mut class_of: BTreeMap<&str, TensorClass> = BTreeMap::new();
    for e in c.einsums() {
        class_of.insert(&e.output.name, e.output.class);
        for op in &e.inputs {
            class_of.entry(&op.tensor.name).or_insert(op.tensor.class);
        }
    }

    for g in &plan.groups {
        let singleton = g.einsums.len() == 1;
        let passes = analyze_scope_with(c, &idx, &g.einsums);
        let internal: Vec<&str> = g.internal_tensors.iter().map(|s| s.as_str()).collect();
        let mut charged: Vec<&str> = Vec::new();
        for &id in &g.einsums {
            let e = c.by_id(id).expect("member");
            let mut seen: Vec<&str> = Vec::new();
            for op in &e.inputs {
                let name = op.tensor.name.as_str();
                if seen.contains(&name) {
                    continue;
                }
                seen.push(name);
                if !singleton {
                    if internal.contains(&name) || charged.contains(&name) {
                        continue;
                    }
                    charged.push(name);
                }
                let n = if singleton { 1 } else { passes.passes_of(name) as u64 };
                let entry = by_tensor.entry(name.to_string()).or_default();
                entry.reads += op.tensor.bytes() * n;
                entry.shared = idx.is_shared(name);
                entry.class = class_of.get(name).copied();
            }
            let out = &e.output;
            if singleton || !internal.contains(&out.name.as_str()) {
                let entry = by_tensor.entry(out.name.clone()).or_default();
                entry.writes += out.bytes();
                entry.shared = idx.is_shared(&out.name);
                entry.class = class_of.get(out.name.as_str()).copied();
            } else {
                // Multi-pass internal tensor: spilled once, reloaded per
                // extra pass (X / LEX in the fully-fused group).
                let n = passes.passes_of(&out.name) as u64;
                if n > 1 {
                    let entry = by_tensor.entry(out.name.clone()).or_default();
                    entry.writes += out.bytes();
                    entry.reads += out.bytes() * (n - 1);
                    entry.shared = idx.is_shared(&out.name);
                    entry.class = class_of.get(out.name.as_str()).copied();
                }
            }
        }
    }
    TrafficBreakdown { by_tensor }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cascade::{mamba1, ModelConfig};
    use crate::fusion::{stitch, FusionVariant};

    fn c370() -> Cascade {
        mamba1::build(&ModelConfig::mamba_370m(), 1024, 1)
    }

    #[test]
    fn unfused_breakdown_matches_exec_totals() {
        let c = c370();
        let plan = stitch(&c, FusionVariant::Unfused);
        let bd = breakdown(&c, &plan);
        let arch = crate::arch::ArchSpec::mambalaya();
        let cost = crate::model::evaluate(&c, &plan, &arch, &Default::default());
        assert_eq!(bd.total(), cost.traffic.total());
    }

    #[test]
    fn ssm_tensors_dominate_unfused_traffic() {
        // The I×D×N intermediates (AB/BB/BX/HH/H) are the traffic
        // hogs — the quantitative reason the SSM region is everyone's
        // first fusion target.
        let c = c370();
        let bd = breakdown(&c, &stitch(&c, FusionVariant::Unfused));
        let hot: Vec<&str> = bd.hottest().into_iter().take(6).map(|(n, _)| n).collect();
        for t in ["AB", "BB", "BX", "HH", "H"] {
            assert!(hot.contains(&t), "{t} not in top-6 {hot:?}");
        }
    }

    #[test]
    fn fusion_silences_internal_tensors() {
        let c = c370();
        let bd = breakdown(&c, &stitch(&c, FusionVariant::RIOnly));
        // HH is internal to the RI SSM group → zero traffic.
        assert!(!bd.by_tensor.contains_key("HH"));
        // LEX still flows between groups.
        assert!(bd.by_tensor.contains_key("LEX"));
    }

    #[test]
    fn fully_fused_leaves_two_pass_tensors_and_weights() {
        let c = c370();
        let bd = breakdown(&c, &stitch(&c, FusionVariant::FullyFused));
        // X and LEX spill once and reload once (2 passes each).
        assert_eq!(bd.by_tensor["X"].writes, 1024 * 1024 * 2);
        assert_eq!(bd.by_tensor["X"].reads, 1024 * 1024 * 2);
        assert_eq!(bd.by_tensor["LEX"].reads, 1024 * 2048 * 2);
        // Weights always stream once.
        assert_eq!(bd.by_tensor["Wtx"].reads, 1024 * 2048 * 2);
        // All SSM intermediates silent.
        for t in ["AB", "BB", "BX", "HH"] {
            assert!(!bd.by_tensor.contains_key(t), "{t}");
        }
    }

    #[test]
    fn report_renders() {
        let c = c370();
        let bd = breakdown(&c, &stitch(&c, FusionVariant::Unfused));
        let r = bd.report(5);
        assert!(r.lines().count() == 6);
        assert!(r.contains('%'));
    }
}
