//! Roofline analysis and utilization-over-time series (paper Figures 2,
//! 10 and 15).
//!
//! A roofline point is (operational intensity, achieved throughput);
//! the utilization-over-time view plots each phase of a [`LayerCost`]
//! as a span whose height is the phase's achieved fraction of peak and
//! whose shading splits compute-bound from memory-bound phases.

use std::fmt::Write as _;

use crate::arch::{ArchSpec, Binding};
use crate::model::LayerCost;

/// One span of the utilization-over-time series.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    /// Einsums active in this phase.
    pub einsums: Vec<usize>,
    /// Start/end time in cycles.
    pub start: u64,
    pub end: u64,
    /// Achieved compute throughput / 2D-mode peak ∈ [0,1].
    pub utilization: f64,
    /// Operational intensity (FLOP/byte) of the phase.
    pub intensity: f64,
    /// Memory-bound (true) vs compute-bound (false).
    pub memory_bound: bool,
}

/// The full utilization timeline of a layer.
#[derive(Debug, Clone)]
pub struct Timeline {
    pub name: String,
    pub spans: Vec<Span>,
    pub total_cycles: u64,
}

/// Build the timeline from a layer cost.
pub fn timeline(cost: &LayerCost, arch: &ArchSpec) -> Timeline {
    let mut spans = Vec::new();
    let mut t = 0u64;
    for p in &cost.phases {
        let end = t + p.latency;
        spans.push(Span {
            einsums: p.einsums.clone(),
            start: t,
            end,
            utilization: p.utilization(arch),
            intensity: p.intensity(),
            memory_bound: p.mem_cycles >= p.cycles_2d.max(p.cycles_small),
        });
        t = end;
    }
    Timeline {
        name: format!("{}/{}", cost.cascade_name, cost.variant_name),
        spans,
        total_cycles: t,
    }
}

/// Roofline-attainable throughput fraction at a given intensity.
pub fn attainable_fraction(arch: &ArchSpec, intensity: f64) -> f64 {
    let peak = arch.peak_flops(Binding::Mode2D);
    let bw = arch.dram_gbps * 1e9;
    ((intensity * bw) / peak).min(1.0)
}

/// Render the timeline as an ASCII utilization-over-time chart, the
/// textual analogue of Figures 2(b,c)/10/15. `width` = chart columns.
pub fn ascii_chart(tl: &Timeline, width: usize) -> String {
    const ROWS: usize = 8;
    let mut out = String::new();
    let _ = writeln!(out, "{} — {} cycles", tl.name, tl.total_cycles);
    if tl.total_cycles == 0 || tl.spans.is_empty() {
        return out;
    }
    // Column → utilization (sample by time).
    let mut cols = vec![(0.0f64, false); width];
    for (ci, col) in cols.iter_mut().enumerate() {
        let t = (ci as u64 * tl.total_cycles) / width as u64;
        if let Some(s) = tl.spans.iter().find(|s| s.start <= t && t < s.end) {
            *col = (s.utilization, s.memory_bound);
        }
    }
    for row in (0..ROWS).rev() {
        let thresh = (row as f64 + 0.5) / ROWS as f64;
        let mut line = String::new();
        for &(u, mb) in &cols {
            if u >= thresh {
                line.push(if mb { '░' } else { '█' });
            } else {
                line.push(' ');
            }
        }
        let _ = writeln!(out, "{:>4.0}% |{}|", (row as f64 + 1.0) / ROWS as f64 * 100.0, line);
    }
    let _ = writeln!(out, "      +{}+  █ compute-bound  ░ memory-bound", "-".repeat(width));
    // Phase labels.
    let mut labels = String::from("       ");
    for s in &tl.spans {
        let c0 = (s.start as usize * width) / tl.total_cycles as usize;
        let label = format!("{}", s.einsums.first().unwrap_or(&0));
        while labels.len() < 7 + c0 {
            labels.push(' ');
        }
        labels.push_str(&label);
    }
    let _ = writeln!(out, "{labels}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cascade::{mamba1, ModelConfig};
    use crate::fusion::{stitch, FusionVariant};
    use crate::model::{evaluate, ExecOptions};

    fn tl(v: FusionVariant) -> (Timeline, ArchSpec) {
        let c = mamba1::build(&ModelConfig::mamba_370m(), 4096, 1);
        let arch = ArchSpec::mambalaya();
        let cost = evaluate(&c, &stitch(&c, v), &arch, &ExecOptions::default());
        (timeline(&cost, &arch), arch)
    }

    #[test]
    fn spans_are_contiguous_and_cover_total() {
        let (t, _) = tl(FusionVariant::Unfused);
        assert_eq!(t.spans.len(), 24);
        let mut prev = 0;
        for s in &t.spans {
            assert_eq!(s.start, prev);
            assert!(s.end >= s.start);
            prev = s.end;
        }
        assert_eq!(prev, t.total_cycles);
    }

    #[test]
    fn unfused_prefill_alternates_boundness() {
        // Paper Fig 2b: unfused prefill alternates between compute-bound
        // (GEMMs) and memory-bound Einsums.
        let (t, _) = tl(FusionVariant::Unfused);
        let bound: Vec<bool> = t.spans.iter().map(|s| s.memory_bound).collect();
        assert!(bound.iter().any(|&b| b));
        assert!(bound.iter().any(|&b| !b));
    }

    #[test]
    fn fused_prefill_raises_utilization() {
        let (unf, arch) = tl(FusionVariant::Unfused);
        let (ff, _) = tl(FusionVariant::FullyFused);
        let avg = |t: &Timeline| {
            t.spans
                .iter()
                .map(|s| s.utilization * (s.end - s.start) as f64)
                .sum::<f64>()
                / t.total_cycles.max(1) as f64
        };
        assert!(avg(&ff) > avg(&unf));
        let _ = arch;
    }

    #[test]
    fn roofline_attainable() {
        let arch = ArchSpec::mambalaya();
        assert!(attainable_fraction(&arch, 1.0) < 0.01);
        assert_eq!(attainable_fraction(&arch, 1e6), 1.0);
        let knee = arch.machine_balance();
        assert!((attainable_fraction(&arch, knee) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ascii_chart_renders() {
        let (t, _) = tl(FusionVariant::RIOnly);
        let chart = ascii_chart(&t, 72);
        assert!(chart.contains('%'));
        assert!(chart.lines().count() >= 10);
    }
}
