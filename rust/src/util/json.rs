//! Minimal JSON emitter (no serde in the vendored crate set). Supports
//! exactly what the report/metrics paths need: objects, arrays, strings,
//! numbers, bools.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<JsonValue>),
    Obj(BTreeMap<String, JsonValue>),
}

impl JsonValue {
    pub fn obj() -> Self {
        JsonValue::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics on non-objects).
    pub fn set(&mut self, key: &str, value: impl Into<JsonValue>) -> &mut Self {
        match self {
            JsonValue::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    /// Push into an array (panics on non-arrays).
    pub fn push(&mut self, value: impl Into<JsonValue>) -> &mut Self {
        match self {
            JsonValue::Arr(v) => v.push(value.into()),
            _ => panic!("push() on non-array"),
        };
        self
    }

    fn escape(s: &str, out: &mut String) {
        out.push('"');
        for ch in s.chars() {
            match ch {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\r' => out.push_str("\\r"),
                '\t' => out.push_str("\\t"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out.push('"');
    }

    fn write(&self, out: &mut String) {
        match self {
            JsonValue::Null => out.push_str("null"),
            JsonValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            JsonValue::Num(n) => {
                if n.is_finite() {
                    out.push_str(&format!("{n}"));
                } else {
                    out.push_str("null");
                }
            }
            JsonValue::Int(i) => out.push_str(&format!("{i}")),
            JsonValue::Str(s) => Self::escape(s, out),
            JsonValue::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            JsonValue::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Self::escape(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl JsonValue {
    /// Parse JSON text (strict enough for our own artifacts).
    pub fn parse(s: &str) -> Result<JsonValue, String> {
        let bytes = s.as_bytes();
        let mut pos = 0usize;
        let v = Self::parse_value(bytes, &mut pos)?;
        Self::skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing data at byte {pos}"));
        }
        Ok(v)
    }

    fn skip_ws(b: &[u8], pos: &mut usize) {
        while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
            *pos += 1;
        }
    }

    fn parse_value(b: &[u8], pos: &mut usize) -> Result<JsonValue, String> {
        Self::skip_ws(b, pos);
        match b.get(*pos) {
            None => Err("unexpected end".into()),
            Some(b'{') => {
                *pos += 1;
                let mut map = BTreeMap::new();
                Self::skip_ws(b, pos);
                if b.get(*pos) == Some(&b'}') {
                    *pos += 1;
                    return Ok(JsonValue::Obj(map));
                }
                loop {
                    Self::skip_ws(b, pos);
                    let key = match Self::parse_value(b, pos)? {
                        JsonValue::Str(s) => s,
                        _ => return Err("object key must be a string".into()),
                    };
                    Self::skip_ws(b, pos);
                    if b.get(*pos) != Some(&b':') {
                        return Err(format!("expected ':' at byte {pos}"));
                    }
                    *pos += 1;
                    let val = Self::parse_value(b, pos)?;
                    map.insert(key, val);
                    Self::skip_ws(b, pos);
                    match b.get(*pos) {
                        Some(b',') => *pos += 1,
                        Some(b'}') => {
                            *pos += 1;
                            return Ok(JsonValue::Obj(map));
                        }
                        _ => return Err(format!("expected ',' or '}}' at byte {pos}")),
                    }
                }
            }
            Some(b'[') => {
                *pos += 1;
                let mut items = Vec::new();
                Self::skip_ws(b, pos);
                if b.get(*pos) == Some(&b']') {
                    *pos += 1;
                    return Ok(JsonValue::Arr(items));
                }
                loop {
                    items.push(Self::parse_value(b, pos)?);
                    Self::skip_ws(b, pos);
                    match b.get(*pos) {
                        Some(b',') => *pos += 1,
                        Some(b']') => {
                            *pos += 1;
                            return Ok(JsonValue::Arr(items));
                        }
                        _ => return Err(format!("expected ',' or ']' at byte {pos}")),
                    }
                }
            }
            Some(b'"') => {
                *pos += 1;
                let mut out = String::new();
                while let Some(&c) = b.get(*pos) {
                    match c {
                        b'"' => {
                            *pos += 1;
                            return Ok(JsonValue::Str(out));
                        }
                        b'\\' => {
                            *pos += 1;
                            match b.get(*pos) {
                                Some(b'n') => out.push('\n'),
                                Some(b't') => out.push('\t'),
                                Some(b'r') => out.push('\r'),
                                Some(b'"') => out.push('"'),
                                Some(b'\\') => out.push('\\'),
                                Some(b'/') => out.push('/'),
                                Some(b'u') => {
                                    let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                                        .map_err(|e| e.to_string())?;
                                    let cp = u32::from_str_radix(hex, 16)
                                        .map_err(|e| e.to_string())?;
                                    out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                                    *pos += 4;
                                }
                                other => return Err(format!("bad escape {other:?}")),
                            }
                            *pos += 1;
                        }
                        _ => {
                            // Copy one UTF-8 scalar.
                            let start = *pos;
                            let len = match c {
                                0x00..=0x7F => 1,
                                0xC0..=0xDF => 2,
                                0xE0..=0xEF => 3,
                                _ => 4,
                            };
                            out.push_str(
                                std::str::from_utf8(&b[start..start + len])
                                    .map_err(|e| e.to_string())?,
                            );
                            *pos += len;
                        }
                    }
                }
                Err("unterminated string".into())
            }
            Some(b't') if b[*pos..].starts_with(b"true") => {
                *pos += 4;
                Ok(JsonValue::Bool(true))
            }
            Some(b'f') if b[*pos..].starts_with(b"false") => {
                *pos += 5;
                Ok(JsonValue::Bool(false))
            }
            Some(b'n') if b[*pos..].starts_with(b"null") => {
                *pos += 4;
                Ok(JsonValue::Null)
            }
            Some(_) => {
                let start = *pos;
                while *pos < b.len()
                    && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
                {
                    *pos += 1;
                }
                let text = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
                if text.contains(['.', 'e', 'E']) {
                    text.parse::<f64>().map(JsonValue::Num).map_err(|e| e.to_string())
                } else {
                    text.parse::<i64>().map(JsonValue::Int).map_err(|e| e.to_string())
                }
            }
        }
    }

    /// Accessors for parsed documents.
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            JsonValue::Int(i) => Some(*i),
            JsonValue::Num(n) => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(n) => Some(*n),
            JsonValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(v) => Some(v),
            _ => None,
        }
    }
}

impl fmt::Display for JsonValue {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s);
        write!(f, "{s}")
    }
}

impl From<&str> for JsonValue {
    fn from(s: &str) -> Self {
        JsonValue::Str(s.to_string())
    }
}
impl From<String> for JsonValue {
    fn from(s: String) -> Self {
        JsonValue::Str(s)
    }
}
impl From<f64> for JsonValue {
    fn from(n: f64) -> Self {
        JsonValue::Num(n)
    }
}
impl From<u64> for JsonValue {
    fn from(n: u64) -> Self {
        JsonValue::Int(n as i64)
    }
}
impl From<usize> for JsonValue {
    fn from(n: usize) -> Self {
        JsonValue::Int(n as i64)
    }
}
impl From<i64> for JsonValue {
    fn from(n: i64) -> Self {
        JsonValue::Int(n)
    }
}
impl From<bool> for JsonValue {
    fn from(b: bool) -> Self {
        JsonValue::Bool(b)
    }
}
impl From<Vec<JsonValue>> for JsonValue {
    fn from(v: Vec<JsonValue>) -> Self {
        JsonValue::Arr(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let mut o = JsonValue::obj();
        o.set("name", "mambalaya").set("n", 3u64).set("ok", true);
        let mut arr = JsonValue::Arr(vec![]);
        arr.push(1.5f64).push("x");
        o.set("xs", arr);
        assert_eq!(o.to_string(), r#"{"n":3,"name":"mambalaya","ok":true,"xs":[1.5,"x"]}"#);
    }

    #[test]
    fn escapes_strings() {
        let v = JsonValue::from("a\"b\\c\nd");
        assert_eq!(v.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn parse_roundtrip() {
        let text = r#"{"a": [1, 2.5, "x"], "b": {"c": true, "d": null}, "e": -3}"#;
        let v = JsonValue::parse(text).unwrap();
        assert_eq!(v.get("e").unwrap().as_i64(), Some(-3));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(v.get("b").unwrap().get("c"), Some(&JsonValue::Bool(true)));
        // Emit → parse → emit is stable.
        let emitted = v.to_string();
        assert_eq!(JsonValue::parse(&emitted).unwrap().to_string(), emitted);
    }

    #[test]
    fn parse_escapes_and_unicode() {
        let v = JsonValue::parse(r#""a\nAé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nAé"));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(JsonValue::parse("{").is_err());
        assert!(JsonValue::parse("[1,]").is_err());
        assert!(JsonValue::parse("12 34").is_err());
    }
}
