//! Minimal CSV writer (RFC-4180-ish quoting) for figure/table exports.

use std::fmt::Write as _;

/// Builds CSV text in memory; callers persist it with `std::fs::write`.
#[derive(Debug, Default, Clone)]
pub struct CsvWriter {
    buf: String,
    cols: usize,
}

impl CsvWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Write the header row; fixes the column count.
    pub fn header(&mut self, cols: &[&str]) -> &mut Self {
        assert!(self.buf.is_empty(), "header must come first");
        self.cols = cols.len();
        self.raw_row(cols.iter().map(|s| s.to_string()));
        self
    }

    /// Write a data row (must match the header width if one was set).
    pub fn row<I, S>(&mut self, cells: I) -> &mut Self
    where
        I: IntoIterator<Item = S>,
        S: ToString,
    {
        let cells: Vec<String> = cells.into_iter().map(|c| c.to_string()).collect();
        if self.cols > 0 {
            assert_eq!(cells.len(), self.cols, "row width mismatch");
        }
        self.raw_row(cells.into_iter());
        self
    }

    fn raw_row<I: Iterator<Item = String>>(&mut self, cells: I) {
        let quoted: Vec<String> = cells.map(|c| Self::quote(&c)).collect();
        let _ = writeln!(self.buf, "{}", quoted.join(","));
    }

    fn quote(s: &str) -> String {
        if s.contains([',', '"', '\n']) {
            format!("\"{}\"", s.replace('"', "\"\""))
        } else {
            s.to_string()
        }
    }

    pub fn finish(&self) -> String {
        self.buf.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_rows() {
        let mut w = CsvWriter::new();
        w.header(&["a", "b"]).row(["1", "2"]).row(["x,y", "q\"z"]);
        let out = w.finish();
        assert_eq!(out, "a,b\n1,2\n\"x,y\",\"q\"\"z\"\n");
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn width_checked() {
        let mut w = CsvWriter::new();
        w.header(&["a", "b"]).row(["only-one"]);
    }

    #[test]
    fn numeric_cells() {
        let mut w = CsvWriter::new();
        w.header(&["n", "f"]).row([format!("{}", 3), format!("{:.2}", 1.5)]);
        assert!(w.finish().contains("3,1.50"));
    }
}
