//! Small utilities hand-rolled for the offline build environment (no
//! clap / serde / rand in the vendored crate set — see DESIGN.md §4).

pub mod cli;
pub mod csv;
pub mod json;
pub mod rng;

pub use cli::Args;
pub use csv::CsvWriter;
pub use json::JsonValue;
pub use rng::XorShift;
