//! A tiny deterministic PRNG (xorshift64*) for synthetic workloads and
//! the property-test harness. Not cryptographic.

/// xorshift64* generator.
#[derive(Debug, Clone)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    pub fn new(seed: u64) -> Self {
        XorShift { state: seed.max(1) }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, bound)` (bound > 0).
    pub fn below(&mut self, bound: u64) -> u64 {
        self.next_u64() % bound
    }

    /// Uniform in `[lo, hi]`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [-1, 1).
    pub fn f32_sym(&mut self) -> f32 {
        (self.f64() * 2.0 - 1.0) as f32
    }

    /// Pick one element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = XorShift::new(42);
        let mut b = XorShift::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn bounds_respected() {
        let mut r = XorShift::new(7);
        for _ in 0..1000 {
            let v = r.range(3, 9);
            assert!((3..=9).contains(&v));
            let f = r.f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn roughly_uniform() {
        let mut r = XorShift::new(1);
        let mut buckets = [0u32; 8];
        for _ in 0..8000 {
            buckets[r.below(8) as usize] += 1;
        }
        for b in buckets {
            assert!((700..1300).contains(&b), "bucket {b}");
        }
    }
}
