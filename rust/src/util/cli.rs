//! Minimal CLI argument parser (no clap in the vendored crate set):
//! positional subcommands plus `--flag`, `--key value` / `--key=value`.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Positional arguments (subcommand first).
    pub positional: Vec<String>,
    /// `--key value` or `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag`s.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    args.options.insert(rest.to_string(), v);
                } else {
                    args.flags.push(rest.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    /// Parse the process's own arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("reproduce --exp fig12 --seq=4096 --verbose");
        assert_eq!(a.subcommand(), Some("reproduce"));
        assert_eq!(a.get("exp"), Some("fig12"));
        assert_eq!(a.get_u64("seq", 0), 4096);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn flag_before_value_option() {
        let a = parse("serve --metrics --port 8080");
        assert!(a.flag("metrics"));
        assert_eq!(a.get_u64("port", 0), 8080);
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_or("model", "370m"), "370m");
        assert_eq!(a.get_u64("seq", 7), 7);
    }
}
