//! End-to-end workload evaluation: combine per-layer prefill and decode
//! costs into full-scenario latencies (paper Figure 12/13: context
//! length : generation length ratios).

use crate::arch::{baseline_plan, ArchSpec, Baseline, Staging};
use crate::cascade::{mamba1, ModelConfig, Scenario};
use crate::fusion::{stitch, FusionVariant};
use crate::model::{evaluate, ideal_cost, ExecOptions, LayerCost, Traffic};

/// A design point: a fusion variant on Mambalaya, or a baseline.
///
/// Also serves as the planner's *plan choice* (re-exported as
/// [`crate::planner::PlanChoice`]): the unit the serving loop selects
/// between per tick, and the index space of the per-plan metrics.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DesignPoint {
    Variant(FusionVariant),
    Baseline(Baseline),
}

impl DesignPoint {
    /// Number of design points ([`DesignPoint::all`] length) — the
    /// fixed index space for per-plan counters.
    pub const COUNT: usize = 7;

    pub fn name(&self) -> String {
        match self {
            DesignPoint::Variant(v) => v.name().to_string(),
            DesignPoint::Baseline(b) => b.name().to_string(),
        }
    }

    /// All points compared in Figures 12–15.
    pub fn all() -> Vec<DesignPoint> {
        let mut v: Vec<DesignPoint> =
            FusionVariant::all().into_iter().map(DesignPoint::Variant).collect();
        v.push(DesignPoint::Baseline(Baseline::MarcaLike));
        v.push(DesignPoint::Baseline(Baseline::GeensLike));
        v
    }

    /// Stable position in [`DesignPoint::all`] (metrics index).
    pub fn index(&self) -> usize {
        match self {
            DesignPoint::Variant(FusionVariant::Unfused) => 0,
            DesignPoint::Variant(FusionVariant::RIOnly) => 1,
            DesignPoint::Variant(FusionVariant::RIRSb) => 2,
            DesignPoint::Variant(FusionVariant::RIRSbRSp) => 3,
            DesignPoint::Variant(FusionVariant::FullyFused) => 4,
            DesignPoint::Baseline(Baseline::BestUnfused) => 0,
            DesignPoint::Baseline(Baseline::MarcaLike) => 5,
            DesignPoint::Baseline(Baseline::GeensLike) => 6,
        }
    }

    /// Parse a CLI/JSON name (variant names, `marca-like`, `geens-like`).
    pub fn parse(s: &str) -> Option<DesignPoint> {
        if let Some(v) = FusionVariant::parse(s) {
            return Some(DesignPoint::Variant(v));
        }
        match s.to_ascii_lowercase().as_str() {
            "marca-like" | "marca" => Some(DesignPoint::Baseline(Baseline::MarcaLike)),
            "geens-like" | "geens" => Some(DesignPoint::Baseline(Baseline::GeensLike)),
            _ => None,
        }
    }

    /// Build the fusion plan this point executes on a cascade.
    pub fn plan(&self, c: &crate::einsum::Cascade) -> crate::fusion::FusionPlan {
        match self {
            DesignPoint::Variant(v) => stitch(c, *v),
            DesignPoint::Baseline(b) => baseline_plan(c, *b),
        }
    }

    /// Intermediate staging discipline of this point.
    pub fn staging(&self) -> Staging {
        match self {
            DesignPoint::Baseline(b) => b.staging(),
            _ => Staging::UnitTile,
        }
    }
}

/// End-to-end cost of a scenario at a design point.
#[derive(Debug, Clone)]
pub struct ScenarioCost {
    pub scenario: String,
    pub design: String,
    /// Prefill cycles (all layers, whole context).
    pub prefill_cycles: u64,
    /// Decode cycles (all layers × generated tokens).
    pub decode_cycles: u64,
    pub prefill_traffic: Traffic,
    pub decode_traffic: Traffic,
}

impl ScenarioCost {
    pub fn total_cycles(&self) -> u64 {
        self.prefill_cycles + self.decode_cycles
    }

    pub fn total_secs(&self, arch: &ArchSpec) -> f64 {
        self.total_cycles() as f64 / arch.cycles_per_sec()
    }
}

/// Evaluate one layer in prefill mode at a design point.
pub fn prefill_layer(
    cfg: &ModelConfig,
    seq: u64,
    batch: u64,
    point: DesignPoint,
    arch: &ArchSpec,
    pipelined: bool,
) -> LayerCost {
    let c = mamba1::build(cfg, seq, batch);
    let plan = match point {
        DesignPoint::Variant(v) => stitch(&c, v),
        DesignPoint::Baseline(b) => baseline_plan(&c, b),
    };
    let opts =
        ExecOptions { staging: point.staging(), pipelined, decode_state_io: false };
    evaluate(&c, &plan, arch, &opts)
}

/// Evaluate one layer in decode mode (single step, batch tokens).
pub fn decode_layer(
    cfg: &ModelConfig,
    batch: u64,
    point: DesignPoint,
    arch: &ArchSpec,
) -> LayerCost {
    let c = mamba1::build(cfg, 1, batch);
    let plan = match point {
        DesignPoint::Variant(v) => stitch(&c, v),
        DesignPoint::Baseline(b) => baseline_plan(&c, b),
    };
    let opts =
        ExecOptions { staging: point.staging(), pipelined: false, decode_state_io: true };
    evaluate(&c, &plan, arch, &opts)
}

/// The ideal (algorithmic-minimum, zero inter-Einsum traffic) layer
/// costs — the red line of Figure 12.
pub fn ideal_layer(
    cfg: &ModelConfig,
    seq: u64,
    batch: u64,
    arch: &ArchSpec,
    decode: bool,
) -> LayerCost {
    let c = mamba1::build(cfg, seq, batch);
    let plan = stitch(&c, FusionVariant::FullyFused);
    let opts = ExecOptions {
        staging: Staging::UnitTile,
        pipelined: true,
        decode_state_io: decode,
    };
    ideal_cost(&c, &plan, arch, &opts)
}

/// Evaluate a full scenario: prefill once over the context, then
/// `decode` steps of generation, across all layers.
pub fn scenario_cost(
    cfg: &ModelConfig,
    s: &Scenario,
    point: DesignPoint,
    arch: &ArchSpec,
    pipelined: bool,
) -> ScenarioCost {
    let pf = prefill_layer(cfg, s.prefill, s.batch, point, arch, pipelined);
    let dc = decode_layer(cfg, s.batch, point, arch);
    ScenarioCost {
        scenario: s.name.clone(),
        design: point.name(),
        prefill_cycles: pf.latency * cfg.layers,
        decode_cycles: dc.latency * cfg.layers * s.decode,
        prefill_traffic: pf.traffic,
        decode_traffic: dc.traffic,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decode_dominated_scenario_prefers_ri_over_fully_fused() {
        // Paper Fig 12: "for relatively large decode length, RI fusion
        // performs the best" among... (we require at least: RI beats the
        // unfused baseline and fully-fused doesn't win decode-heavy).
        let cfg = ModelConfig::mamba_370m();
        let arch = ArchSpec::mambalaya();
        let s = Scenario::new("decode-heavy", 64, 64, 4096);
        let unf = scenario_cost(&cfg, &s, DesignPoint::Variant(FusionVariant::Unfused), &arch, false);
        let ri = scenario_cost(&cfg, &s, DesignPoint::Variant(FusionVariant::RIOnly), &arch, false);
        assert!(unf.total_cycles() as f64 / ri.total_cycles() as f64 > 1.5);
    }

    #[test]
    fn prefill_dominated_scenario_prefers_fully_fused() {
        let cfg = ModelConfig::mamba_370m();
        let arch = ArchSpec::mambalaya();
        let s = Scenario::new("prefill-heavy", 64, 16384, 256);
        let ff =
            scenario_cost(&cfg, &s, DesignPoint::Variant(FusionVariant::FullyFused), &arch, false);
        for v in [FusionVariant::Unfused, FusionVariant::RIOnly, FusionVariant::RIRSb] {
            let other = scenario_cost(&cfg, &s, DesignPoint::Variant(v), &arch, false);
            assert!(
                ff.total_cycles() <= other.total_cycles(),
                "fully-fused loses to {v} in prefill-heavy"
            );
        }
    }

    #[test]
    fn scenario_suite_evaluates_everywhere() {
        let cfg = ModelConfig::mamba_130m();
        let arch = ArchSpec::mambalaya();
        for s in Scenario::paper_suite() {
            for p in DesignPoint::all() {
                let c = scenario_cost(&cfg, &s, p, &arch, false);
                assert!(c.total_cycles() > 0);
            }
        }
    }

    #[test]
    fn ideal_bounds_everything() {
        let cfg = ModelConfig::mamba_370m();
        let arch = ArchSpec::mambalaya();
        let ideal = ideal_layer(&cfg, 4096, 1, &arch, false);
        for p in DesignPoint::all() {
            let real = prefill_layer(&cfg, 4096, 1, p, &arch, false);
            assert!(
                real.latency >= ideal.latency,
                "{} beats ideal: {} < {}",
                p.name(),
                real.latency,
                ideal.latency
            );
        }
    }
}
