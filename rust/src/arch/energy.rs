//! Energy model: per-access energy costs in the Accelergy/Timeloop
//! tradition (the paper claims "energy efficiency gains from the
//! traffic reductions" qualitatively; this module quantifies them under
//! standard 45/32 nm-scaled per-access constants).
//!
//! Energy = Σ DRAM bytes × e_dram + buffer bytes × e_buf + FLOPs/2 ×
//! e_mac + low-intensity ops × e_alu. Buffer traffic is approximated as
//! one buffer round-trip per operand element consumed by compute (every
//! PE operand stages through the global buffer), which is the same
//! simplification Timeloop's two-level runs use.

use crate::model::LayerCost;

/// Per-access energy constants (picojoules).
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyModel {
    /// DRAM access energy per byte.
    pub dram_pj_per_byte: f64,
    /// Global-buffer access energy per byte.
    pub buffer_pj_per_byte: f64,
    /// One fp16 MAC.
    pub mac_pj: f64,
    /// One low-intensity (nonlinear/elementwise) op.
    pub alu_pj: f64,
}

impl Default for EnergyModel {
    /// Constants in the range used by Timeloop/Accelergy exemplars:
    /// DRAM ≈ 62.5 pJ/B (500 pJ / 8 B line), SRAM buffer ≈ 1 pJ/B,
    /// fp16 MAC ≈ 1 pJ, ALU op ≈ 0.5 pJ.
    fn default() -> Self {
        EnergyModel {
            dram_pj_per_byte: 62.5,
            buffer_pj_per_byte: 1.0,
            mac_pj: 1.0,
            alu_pj: 0.5,
        }
    }
}

/// Energy breakdown for one evaluated layer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyCost {
    pub dram_pj: f64,
    pub buffer_pj: f64,
    pub compute_pj: f64,
}

impl EnergyCost {
    pub fn total_pj(&self) -> f64 {
        self.dram_pj + self.buffer_pj + self.compute_pj
    }

    pub fn total_mj(&self) -> f64 {
        self.total_pj() * 1e-9
    }
}

impl EnergyModel {
    /// Energy of a layer cost produced by [`crate::model::evaluate`].
    pub fn cost(&self, layer: &LayerCost) -> EnergyCost {
        let dram_bytes = layer.traffic.total() as f64;
        // Buffer staging: every DRAM byte passes through the buffer
        // once, plus on-chip reuse traffic ≈ 2 bytes per FLOP operand
        // pair is dominated by the datapath registers; we charge the
        // DRAM-coupled staging only (conservative lower bound).
        let buffer_bytes = dram_bytes;
        // FLOPs: MACs on the arrays (2 FLOP each) — split is immaterial
        // at the energy level since e_mac ≈ 2·e_alu here.
        let macs = layer.flops as f64 / 2.0;
        EnergyCost {
            dram_pj: dram_bytes * self.dram_pj_per_byte,
            buffer_pj: buffer_bytes * self.buffer_pj_per_byte,
            compute_pj: macs * self.mac_pj,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ArchSpec;
    use crate::cascade::{mamba1, ModelConfig};
    use crate::fusion::{stitch, FusionVariant};
    use crate::model::{evaluate, ExecOptions};

    fn layer(v: FusionVariant) -> LayerCost {
        let c = mamba1::build(&ModelConfig::mamba_370m(), 4096, 1);
        evaluate(&c, &stitch(&c, v), &ArchSpec::mambalaya(), &ExecOptions::default())
    }

    #[test]
    fn fusion_saves_energy() {
        // The paper's qualitative claim: traffic reductions are energy
        // reductions (DRAM dominates at 62.5 pJ/B vs 1 pJ/MAC).
        let em = EnergyModel::default();
        let unfused = em.cost(&layer(FusionVariant::Unfused));
        let fused = em.cost(&layer(FusionVariant::RIRSbRSp));
        assert!(fused.total_pj() < 0.5 * unfused.total_pj());
        // DRAM dominates the unfused energy.
        assert!(unfused.dram_pj > unfused.compute_pj);
    }

    #[test]
    fn compute_energy_invariant_under_fusion() {
        let em = EnergyModel::default();
        let a = em.cost(&layer(FusionVariant::Unfused));
        let b = em.cost(&layer(FusionVariant::FullyFused));
        assert!((a.compute_pj - b.compute_pj).abs() < 1e-6);
    }

    #[test]
    fn units_are_sane() {
        let em = EnergyModel::default();
        let e = em.cost(&layer(FusionVariant::RIOnly));
        // One mamba-370m layer at I=4096 should land in the mJ range.
        assert!(e.total_mj() > 0.01 && e.total_mj() < 1e3, "{}", e.total_mj());
    }
}
