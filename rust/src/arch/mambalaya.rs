//! Mambalaya binding rules (paper §V-B): which compute structure each
//! Einsum of a fusion group runs on, per fusion variant.
//!
//! * **RI-only**: elementwise-only groups → the 2D array in 1D mode
//!   (8192 PEs); GEMMs (and their groups) → 2D mode.
//! * **RI+RSb**: groups are "elementwise" or "GEMM → elementwise"; the
//!   elementwise tail stays on the 2D array (its data is already
//!   there).
//! * **RI+RSb+RSp / Fully-Fused**: elementwise ops *preceding* a GEMM in
//!   their group are bound to the small 1D array (256 PEs) and broadcast
//!   into the 2D array; elementwise ops *after* a GEMM run in 2D mode.

use crate::einsum::{Cascade, Intensity};
use crate::fusion::{FusionGroup, FusionPlan};

use super::spec::Binding;

/// Binding decision for one Einsum.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BindingChoice {
    pub einsum: usize,
    pub binding: Binding,
}

/// Bind every Einsum of a fusion group per §V-B.
pub fn bind_group(c: &Cascade, g: &FusionGroup) -> Vec<BindingChoice> {
    let members: Vec<_> = g.einsums.iter().map(|&id| c.by_id(id).expect("member")).collect();
    let gemm_positions: Vec<usize> = members
        .iter()
        .enumerate()
        .filter(|(_, e)| e.intensity() == Intensity::High)
        .map(|(i, _)| i)
        .collect();

    members
        .iter()
        .enumerate()
        .map(|(i, e)| {
            let binding = if e.intensity() == Intensity::High {
                Binding::Mode2D
            } else if gemm_positions.is_empty() {
                // Low-intensity-only group: full 1D mode of the 2D array.
                Binding::Wide1D
            } else if gemm_positions.iter().any(|&gp| gp < i) {
                // Follows a GEMM in this group: its data is already
                // resident on the 2D array — stay in 2D mode ("any
                // elementwise Einsum that follows a GEMM will execute in
                // 2D mode", §V-B).
                Binding::Mode2D
            } else {
                // Precedes every GEMM of the group: the small 1D array,
                // broadcasting its result into the 2D array.
                Binding::Small1D
            };
            BindingChoice { einsum: e.id, binding }
        })
        .collect()
}

/// Bind a whole plan. Returns choices in cascade order.
pub fn bind_plan(c: &Cascade, plan: &FusionPlan) -> Vec<BindingChoice> {
    let mut out: Vec<BindingChoice> =
        plan.groups.iter().flat_map(|g| bind_group(c, g)).collect();
    out.sort_by_key(|b| b.einsum);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cascade::{mamba1, ModelConfig};
    use crate::fusion::{stitch, FusionVariant};

    fn bindings(variant: FusionVariant) -> Vec<BindingChoice> {
        let c = mamba1::build(&ModelConfig::mamba_370m(), 64, 1);
        let plan = stitch(&c, variant);
        bind_plan(&c, &plan)
    }

    fn binding_of(bs: &[BindingChoice], id: usize) -> Binding {
        bs.iter().find(|b| b.einsum == id).unwrap().binding
    }

    #[test]
    fn ri_only_norm_runs_wide() {
        // §VI-C: under RI-only, the normalization steps bind to the 8192
        // PE 1D mode (no GEMM shares their groups).
        let bs = bindings(FusionVariant::RIOnly);
        for id in [1, 2, 3] {
            assert_eq!(binding_of(&bs, id), Binding::Wide1D, "einsum {id}");
        }
        // GEMMs are 2D.
        for id in [7, 8, 24] {
            assert_eq!(binding_of(&bs, id), Binding::Mode2D, "einsum {id}");
        }
        // The SSM group (16–21) is elementwise-only → wide 1D.
        for id in 16..=21 {
            assert_eq!(binding_of(&bs, id), Binding::Wide1D, "einsum {id}");
        }
    }

    #[test]
    fn rsp_norm_runs_small() {
        // §V-B: with RSp stitching, Einsums 1–6 precede the in-proj GEMM
        // in their group → bound to the 256-PE 1D array.
        let bs = bindings(FusionVariant::RIRSbRSp);
        for id in 1..=6 {
            assert_eq!(binding_of(&bs, id), Binding::Small1D, "einsum {id}");
        }
        // Post-GEMM elementwise (the SSM region follows dt-proj GEMM in
        // group 3) runs in 2D mode.
        for id in [15, 16, 19, 20] {
            assert_eq!(binding_of(&bs, id), Binding::Mode2D, "einsum {id}");
        }
    }

    #[test]
    fn ri_rsb_gemm_tail_stays_2d() {
        // §V-B RI+RSb: GEMM (14) followed by elementwise (15) — the
        // elementwise op remains on the 2D array.
        let bs = bindings(FusionVariant::RIRSb);
        assert_eq!(binding_of(&bs, 14), Binding::Mode2D);
        assert_eq!(binding_of(&bs, 15), Binding::Mode2D);
    }

    #[test]
    fn every_einsum_bound_exactly_once() {
        for v in FusionVariant::all() {
            let bs = bindings(v);
            let mut ids: Vec<usize> = bs.iter().map(|b| b.einsum).collect();
            ids.dedup();
            assert_eq!(ids, (1..=24).collect::<Vec<_>>(), "variant {v}");
        }
    }
}
