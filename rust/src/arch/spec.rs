//! Architecture specifications (paper §V, Table III).

use std::fmt;

/// Which compute structure an Einsum is bound to (paper §V-A/B).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Binding {
    /// The 256×256 2D PE array in systolic (2D) mode — GEMMs and
    /// elementwise ops that follow a GEMM inside a fusion group.
    Mode2D,
    /// The 2D array reconfigured to 1D mode: 8192 PEs directly connected
    /// to the global buffer — elementwise-only fusion groups.
    Wide1D,
    /// The separate low-intensity 1D array (256 PEs) feeding the 2D
    /// array — elementwise ops that precede a GEMM in their group.
    Small1D,
}

impl fmt::Display for Binding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Binding::Mode2D => "2D(256x256)",
            Binding::Wide1D => "1D-wide(8192)",
            Binding::Small1D => "1D-small(256)",
        };
        write!(f, "{s}")
    }
}

/// An accelerator configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ArchSpec {
    pub name: String,
    /// 2D array rows/cols (256×256 = 65 536 PEs).
    pub pe_2d_rows: u64,
    pub pe_2d_cols: u64,
    /// PEs exposed in the 2D array's 1D mode.
    pub pe_1d_wide: u64,
    /// PEs in the standalone low-intensity 1D array.
    pub pe_1d_small: u64,
    /// Clock (GHz).
    pub freq_ghz: f64,
    /// DRAM bandwidth (GB/s).
    pub dram_gbps: f64,
    /// Global on-chip buffer (bytes).
    pub buffer_bytes: u64,
    /// Total register capacity (bytes) — per-PE accumulators.
    pub reg_bytes: u64,
}

impl ArchSpec {
    /// Mambalaya, configured per Table III (iso-parameter with an H100:
    /// 1.75 GHz, 2039 GB/s, 32 MB global buffer, 4.25 MB registers;
    /// 65 536 + 256 PEs).
    pub fn mambalaya() -> Self {
        ArchSpec {
            name: "mambalaya".into(),
            pe_2d_rows: 256,
            pe_2d_cols: 256,
            pe_1d_wide: 8192,
            pe_1d_small: 256,
            freq_ghz: 1.75,
            dram_gbps: 2039.0,
            buffer_bytes: 32 << 20,
            reg_bytes: (4 << 20) + (256 << 10), // 4.25 MB
        }
    }

    /// PE count for a binding.
    pub fn pes(&self, b: Binding) -> u64 {
        match b {
            Binding::Mode2D => self.pe_2d_rows * self.pe_2d_cols,
            Binding::Wide1D => self.pe_1d_wide,
            Binding::Small1D => self.pe_1d_small,
        }
    }

    /// Cycles per second.
    pub fn cycles_per_sec(&self) -> f64 {
        self.freq_ghz * 1e9
    }

    /// DRAM bytes transferable per cycle.
    pub fn bytes_per_cycle(&self) -> f64 {
        self.dram_gbps * 1e9 / self.cycles_per_sec()
    }

    /// Peak FLOP/s of a binding (each PE: 1 MAC = 2 FLOP per cycle).
    pub fn peak_flops(&self, b: Binding) -> f64 {
        self.pes(b) as f64 * 2.0 * self.cycles_per_sec()
    }

    /// Machine balance (FLOP/byte) at the 2D-mode peak — the roofline
    /// knee used in Figures 2/10/15.
    pub fn machine_balance(&self) -> f64 {
        self.peak_flops(Binding::Mode2D) / (self.dram_gbps * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_values() {
        let a = ArchSpec::mambalaya();
        assert_eq!(a.pes(Binding::Mode2D), 65_536);
        assert_eq!(a.pes(Binding::Wide1D), 8_192);
        assert_eq!(a.pes(Binding::Small1D), 256);
        assert_eq!(a.buffer_bytes, 32 << 20);
        assert!((a.freq_ghz - 1.75).abs() < 1e-9);
        assert!((a.dram_gbps - 2039.0).abs() < 1e-9);
        // Register file 4.25 MB.
        assert_eq!(a.reg_bytes, 4_456_448);
    }

    #[test]
    fn derived_rates() {
        let a = ArchSpec::mambalaya();
        // 65536 PEs × 2 flop × 1.75 GHz ≈ 229 Tflop/s.
        let peak = a.peak_flops(Binding::Mode2D);
        assert!((peak / 1e12 - 229.376).abs() < 0.01, "peak = {peak}");
        // ~1165 B/cycle at 2039 GB/s / 1.75 GHz.
        assert!((a.bytes_per_cycle() - 2039.0 / 1.75).abs() < 1.0);
        // Roofline knee ≈ 112 flop/byte.
        assert!((a.machine_balance() - 112.5).abs() < 0.5);
    }
}
