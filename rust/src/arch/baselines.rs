//! Prior state-of-the-art baselines (paper §VI-B).
//!
//! For an apples-to-apples comparison the paper gives both baselines the
//! benefit of the doubt: best-case unfused Einsums with algorithmic
//! minimum traffic, plus rank-isomorphic fusion applied to the SSM
//! region only (Einsums 16–21 for Mamba-1), bound onto the Mambalaya
//! architecture. The two differ in how they stage the SSM intermediates:
//!
//! * **MARCA-like** — operation-level fusion with *non-unit* intermediate
//!   tiles: the fused SSM intermediates are staged at full sequence
//!   extent ("brittle to changes in on-chip buffer sizes", Table II), so
//!   once `I·D·N` tiles exceed the buffer, they spill to DRAM.
//! * **Geens-like** — fine-grained, memory-aware fusion: intermediates
//!   partitioned to unit size along `I` (further tiled along D/N when
//!   needed), so the SSM intermediates never spill.

use crate::cascade::mamba1::SSM_REGION;
use crate::einsum::Cascade;
use crate::fusion::{classify_pair, FusionGroup, FusionPlan, JoinRecord};

/// How a baseline stages intermediates inside its fused group(s).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Staging {
    /// Unit-size tiles along the generational rank — never spills
    /// (Geens-like, and Mambalaya's own strategy).
    UnitTile,
    /// Full-extent staging of intermediates — spills once the tensor
    /// exceeds its share of the buffer (MARCA-like).
    FullExtent,
}

/// A named baseline design point.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Baseline {
    /// Best-case unfused (Table I / Figure 2 reference).
    BestUnfused,
    /// MARCA-like: RI fusion of the SSM region, full-extent staging.
    MarcaLike,
    /// Geens-like: RI fusion of the SSM region, unit-tile staging.
    GeensLike,
}

impl Baseline {
    pub fn name(&self) -> &'static str {
        match self {
            Baseline::BestUnfused => "best-unfused",
            Baseline::MarcaLike => "marca-like",
            Baseline::GeensLike => "geens-like",
        }
    }

    pub fn staging(&self) -> Staging {
        match self {
            Baseline::MarcaLike => Staging::FullExtent,
            _ => Staging::UnitTile,
        }
    }
}

/// Build the fusion plan a baseline uses on the Mamba-1 cascade:
/// every Einsum its own group except the SSM region (16–21), which is
/// one RI-fused group (for MARCA-like / Geens-like).
pub fn baseline_plan(c: &Cascade, b: Baseline) -> FusionPlan {
    if b == Baseline::BestUnfused {
        return crate::fusion::unfused_plan(c);
    }
    let mut groups: Vec<FusionGroup> = Vec::new();
    let mut ssm_group: Option<FusionGroup> = None;
    for e in c.einsums() {
        if SSM_REGION.contains(&e.id) {
            let g = ssm_group.get_or_insert_with(|| FusionGroup {
                einsums: vec![],
                joins: vec![],
                stationary: e.iteration_space(),
                internal_tensors: vec![],
                rd_bridged: false,
            });
            // Link provenance: classify against the in-group producer.
            let via = g
                .einsums
                .iter()
                .rev()
                .find_map(|&pid| {
                    let p = c.by_id(pid)?;
                    e.operand(&p.output.name).map(|_| p)
                })
                .map(|p| (p.id, classify_pair(p, e)));
            g.einsums.push(e.id);
            g.joins.push(match via {
                Some((pid, Some(pf))) => JoinRecord {
                    einsum: e.id,
                    via: Some(pid),
                    class: Some(pf.class),
                    tensor: Some(pf.intermediate),
                },
                _ => JoinRecord { einsum: e.id, via: None, class: None, tensor: None },
            });
            g.stationary = g.stationary.intersect(&e.iteration_space());
            // Flush once the region is complete.
            if e.id == *SSM_REGION.last().unwrap() {
                groups.push(ssm_group.take().unwrap());
            }
        } else {
            groups.push(FusionGroup {
                einsums: vec![e.id],
                joins: vec![JoinRecord { einsum: e.id, via: None, class: None, tensor: None }],
                stationary: e.iteration_space(),
                internal_tensors: vec![],
                rd_bridged: false,
            });
        }
    }
    // A cascade holding only a prefix of the SSM-region ids (Mamba-2
    // reuses id 16 but has no 21) never hits the flush above; push the
    // pending group so no Einsum is dropped from the plan — the
    // verifier's coverage check caught this.
    if let Some(g) = ssm_group.take() {
        groups.push(g);
    }
    let mut plan = FusionPlan {
        cascade_name: c.name.clone(),
        variant_name: b.name().to_string(),
        groups,
    };
    // Mark internal tensors of the SSM group.
    let consumers = c.consumers();
    for g in &mut plan.groups {
        let mut internal = Vec::new();
        for &id in &g.einsums {
            let e = c.by_id(id).unwrap();
            if let Some(cs) = consumers.get(e.output.name.as_str()) {
                if !cs.is_empty() && cs.iter().all(|cid| g.einsums.contains(cid)) {
                    internal.push(e.output.name.clone());
                }
            }
        }
        g.internal_tensors = internal;
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cascade::{mamba1, ModelConfig};

    #[test]
    fn marca_like_has_19_groups() {
        // 24 Einsums − 6 (SSM fused to 1) = 19 groups.
        let c = mamba1::build(&ModelConfig::mamba_370m(), 64, 1);
        let plan = baseline_plan(&c, Baseline::MarcaLike);
        plan.validate(&c).unwrap();
        assert_eq!(plan.groups.len(), 19);
        let ssm = plan.groups.iter().find(|g| g.einsums.len() > 1).unwrap();
        assert_eq!(ssm.einsums, vec![16, 17, 18, 19, 20, 21]);
    }

    #[test]
    fn ssm_internals_stay_on_chip_structurally() {
        let c = mamba1::build(&ModelConfig::mamba_370m(), 64, 1);
        let plan = baseline_plan(&c, Baseline::GeensLike);
        let ssm = plan.groups.iter().find(|g| g.einsums.len() > 1).unwrap();
        // AB, BB, BX, HH, H die inside the region; S leaves it.
        for t in ["AB", "BB", "BX", "HH"] {
            assert!(ssm.internal_tensors.iter().any(|x| x == t), "{t}");
        }
        assert!(!ssm.internal_tensors.iter().any(|x| x == "S"));
    }

    #[test]
    fn best_unfused_is_unfused() {
        let c = mamba1::build(&ModelConfig::mamba_370m(), 64, 1);
        let plan = baseline_plan(&c, Baseline::BestUnfused);
        assert_eq!(plan.groups.len(), 24);
    }

    #[test]
    fn staging_assignments() {
        assert_eq!(Baseline::MarcaLike.staging(), Staging::FullExtent);
        assert_eq!(Baseline::GeensLike.staging(), Staging::UnitTile);
    }
}
