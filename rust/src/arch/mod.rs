//! Architecture specifications and binding (paper §V, Table III).
//!
//! * [`spec`] — the Mambalaya configuration and derived rates;
//! * [`mambalaya`] — §V-B binding rules (which structure runs what);
//! * [`baselines`] — MARCA-like / Geens-like / Best-Unfused (§VI-B).

pub mod baselines;
pub mod energy;
pub mod mambalaya;
pub mod spec;

pub use baselines::{baseline_plan, Baseline, Staging};
pub use energy::{EnergyCost, EnergyModel};
pub use mambalaya::{bind_group, bind_plan, BindingChoice};
pub use spec::{ArchSpec, Binding};
