//! SLO-aware admission control for the network front-end.
//!
//! Sits *above* the coordinator: before a request reaches
//! [`crate::coordinator::Server::submit`] the controller decides
//! admit-or-shed from three signals, mirroring the same
//! `WorkloadFeatures` inputs the planner consumes:
//!
//! 1. **Per-class token-budget shares** over a fixed admission window:
//!    each [`Priority`] class may spend at most `share × (token_budget
//!    × window_ticks)` prompt tokens per window, so a flood of Batch
//!    traffic cannot crowd Interactive requests out of the batcher's
//!    chunk budget.
//! 2. **Deadline tracking** on the scheduler's deterministic
//!    tick histograms ([`crate::coordinator::LatencyReport`]): a
//!    first-token estimate past the class deadline sheds up front
//!    rather than admitting work that will miss its SLO anyway, and a
//!    measured p99 past the Interactive deadline puts the controller
//!    into SLO-pressure mode where non-Interactive traffic sheds.
//! 3. **Queue-depth / load backstops** on queued prompt tokens and
//!    resident state bytes, bounding memory under overload no matter
//!    how shares are configured.
//!
//! Every shed is a *terminal error* to the caller — the front-end
//! turns it into exactly one [`super::wire::Frame::Error`] on the
//! socket, and [`crate::coordinator::Server::shed_request`] records a
//! `[Submit, Failed]` span so traces still reconcile.
//!
//! The controller is clock-agnostic: `now_tick` is whatever monotone
//! counter the caller has (scheduler work ticks in the bench gate,
//! router-loop iterations in the TCP server). Determinism in the
//! gates comes from feeding it the deterministic tick clock.

use crate::coordinator::{LatencyReport, PRIORITY_CLASSES};
use crate::obs::Histogram;

/// Request priority class. `Interactive` is the protected class the
/// SLO gate measures; `Batch` is the first to shed under pressure.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    Interactive = 0,
    Standard = 1,
    Batch = 2,
}

impl Priority {
    /// Number of classes; must equal
    /// [`crate::coordinator::PRIORITY_CLASSES`] (the coordinator-side
    /// constant the per-class counters are sized by).
    pub const COUNT: usize = PRIORITY_CLASSES;

    /// All classes, highest priority first.
    pub const ALL: [Priority; Priority::COUNT] =
        [Priority::Interactive, Priority::Standard, Priority::Batch];

    /// Index into per-class arrays.
    pub fn index(self) -> usize {
        self as usize
    }

    /// Class for a wire-level index, if in range.
    pub fn from_index(i: usize) -> Option<Priority> {
        Priority::ALL.get(i).copied()
    }

    /// Lower-case class name (CLI and report labels).
    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Standard => "standard",
            Priority::Batch => "batch",
        }
    }

    /// Parse a CLI spelling (`interactive` / `standard` / `batch`).
    pub fn parse(s: &str) -> Option<Priority> {
        Priority::ALL.into_iter().find(|p| p.name() == s)
    }
}

impl std::fmt::Display for Priority {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Why a request was refused admission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// Queued prompt tokens would exceed `max_queued_tokens`.
    QueueFull,
    /// The class spent its token share for this admission window.
    ClassBudgetExhausted,
    /// First-token estimate (or observed p99 under SLO pressure)
    /// exceeds the class deadline.
    DeadlineUnmeetable,
    /// Resident state bytes or budget utilization past the load
    /// backstop.
    Overloaded,
}

impl ShedReason {
    /// Stable label (wire error messages, shed counters, reports).
    pub fn name(self) -> &'static str {
        match self {
            ShedReason::QueueFull => "queue_full",
            ShedReason::ClassBudgetExhausted => "class_budget_exhausted",
            ShedReason::DeadlineUnmeetable => "deadline_unmeetable",
            ShedReason::Overloaded => "overloaded",
        }
    }
}

impl std::fmt::Display for ShedReason {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Admission policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionConfig {
    /// Fixed admission-window length in caller ticks. Shares reset at
    /// each window boundary (`now_tick / window_ticks`).
    pub window_ticks: u64,
    /// Scheduler token budget per tick (the batcher's
    /// `BatchPolicy::token_budget`); window capacity is
    /// `token_budget × window_ticks` prompt tokens.
    pub token_budget: u64,
    /// Per-class fraction of the window capacity, indexed by
    /// [`Priority::index`]. `1.0` = may use the whole window,
    /// `0.0` = always shed.
    pub shares: [f64; PRIORITY_CLASSES],
    /// Per-class TTFT deadline in caller ticks; `u64::MAX` disables
    /// deadline shedding for that class.
    pub ttft_deadline_ticks: [u64; PRIORITY_CLASSES],
    /// Backstop: maximum queued (admitted, not yet first-token)
    /// prompt tokens, any class.
    pub max_queued_tokens: u64,
    /// Backstop: maximum resident state bytes reported by the load
    /// signal before everything sheds as `Overloaded`.
    pub max_resident_bytes: u64,
}

impl Default for AdmissionConfig {
    /// Permissive: admits everything (conformance tests exercise the
    /// wire path without shedding).
    fn default() -> AdmissionConfig {
        AdmissionConfig {
            window_ticks: 64,
            token_budget: u64::MAX / (64 * 2), // capacity never overflows
            shares: [1.0; PRIORITY_CLASSES],
            ttft_deadline_ticks: [u64::MAX; PRIORITY_CLASSES],
            max_queued_tokens: u64::MAX,
            max_resident_bytes: u64::MAX,
        }
    }
}

/// Instantaneous load observed by the caller, mirroring the planner's
/// `WorkloadFeatures` signals (resident bytes, budget use) so the
/// shed policy and the plan policy read the same gauges.
#[derive(Debug, Clone, Copy, Default)]
pub struct LoadSignal {
    /// Requests queued or mid-prefill (not yet at first token).
    pub queue_depth: u64,
    /// Prompt tokens admitted but not yet at first token.
    pub queued_prompt_tokens: u64,
    /// Requests in steady-state decode.
    pub running: u64,
    /// Bytes of recurrent state resident across shards.
    pub resident_state_bytes: u64,
    /// Fraction of the per-tick token budget recently used (0..=1).
    pub budget_utilization: f64,
}

/// Per-class admission state over fixed windows.
#[derive(Debug)]
pub struct AdmissionController {
    cfg: AdmissionConfig,
    window_id: u64,
    /// Prompt tokens admitted per class in the current window.
    spent: [u64; PRIORITY_CLASSES],
    admitted: [u64; PRIORITY_CLASSES],
    shed: [u64; PRIORITY_CLASSES],
    /// Wall-clock TTFT per class, for reports (`note_ttft`).
    ttft_wall: [Histogram; PRIORITY_CLASSES],
    /// Last observed p99 TTFT in ticks (from `note_latency`);
    /// `0` until a report arrives.
    last_p99_ttft_ticks: u64,
}

impl AdmissionController {
    pub fn new(cfg: AdmissionConfig) -> AdmissionController {
        AdmissionController {
            cfg,
            window_id: 0,
            spent: [0; PRIORITY_CLASSES],
            admitted: [0; PRIORITY_CLASSES],
            shed: [0; PRIORITY_CLASSES],
            ttft_wall: [Histogram::new(); PRIORITY_CLASSES],
            last_p99_ttft_ticks: 0,
        }
    }

    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    /// Decide admit-or-shed for one request. On `Err` the class shed
    /// counter has been bumped; on `Ok` the class spent/admitted
    /// counters have.
    pub fn admit(
        &mut self,
        class: Priority,
        prompt_tokens: u64,
        now_tick: u64,
        load: &LoadSignal,
    ) -> Result<(), ShedReason> {
        self.roll_window(now_tick);
        let i = class.index();
        let verdict = self.check(class, prompt_tokens, load);
        match verdict {
            Ok(()) => {
                self.spent[i] = self.spent[i].saturating_add(prompt_tokens);
                self.admitted[i] += 1;
            }
            Err(_) => self.shed[i] += 1,
        }
        verdict
    }

    fn check(
        &self,
        class: Priority,
        prompt_tokens: u64,
        load: &LoadSignal,
    ) -> Result<(), ShedReason> {
        let cfg = &self.cfg;
        let i = class.index();
        // Backstops first: they bound memory regardless of shares.
        if load.queued_prompt_tokens.saturating_add(prompt_tokens) > cfg.max_queued_tokens {
            return Err(ShedReason::QueueFull);
        }
        if load.resident_state_bytes > cfg.max_resident_bytes {
            return Err(ShedReason::Overloaded);
        }
        // Deadline estimate: the batcher drains at most `token_budget`
        // tokens per tick, so everything already queued plus this
        // prompt needs at least this many ticks to reach first token.
        let deadline = cfg.ttft_deadline_ticks[i];
        if deadline != u64::MAX {
            let backlog = load.queued_prompt_tokens.saturating_add(prompt_tokens);
            let est_ticks = backlog.div_ceil(cfg.token_budget.max(1));
            if est_ticks > deadline {
                return Err(ShedReason::DeadlineUnmeetable);
            }
        }
        // SLO pressure: observed p99 past the Interactive deadline
        // means the system is behind — shed non-Interactive traffic
        // until the protected class recovers.
        let interactive_deadline = cfg.ttft_deadline_ticks[Priority::Interactive.index()];
        if class != Priority::Interactive
            && interactive_deadline != u64::MAX
            && self.last_p99_ttft_ticks > interactive_deadline
        {
            return Err(ShedReason::DeadlineUnmeetable);
        }
        // Per-class share of the window's token capacity.
        let capacity = (cfg.token_budget as f64) * (cfg.window_ticks as f64);
        let allowance = cfg.shares[i].clamp(0.0, 1.0) * capacity;
        if (self.spent[i].saturating_add(prompt_tokens)) as f64 > allowance {
            return Err(ShedReason::ClassBudgetExhausted);
        }
        Ok(())
    }

    fn roll_window(&mut self, now_tick: u64) {
        let wid = now_tick / self.cfg.window_ticks.max(1);
        if wid != self.window_id {
            self.window_id = wid;
            self.spent = [0; PRIORITY_CLASSES];
        }
    }

    /// Feed the scheduler's deterministic latency distributions; the
    /// observed p99 TTFT (ticks) drives SLO-pressure shedding.
    pub fn note_latency(&mut self, report: &LatencyReport) {
        if report.ttft_ticks.count() > 0 {
            self.last_p99_ttft_ticks = report.ttft_ticks.percentile(0.99);
        }
    }

    /// Record one wall-clock TTFT observation for a class (seconds).
    pub fn note_ttft(&mut self, class: Priority, secs: f64) {
        self.ttft_wall[class.index()].record_secs(secs);
    }

    /// Requests admitted per class (all windows).
    pub fn admitted(&self) -> [u64; PRIORITY_CLASSES] {
        self.admitted
    }

    /// Requests shed per class (all windows).
    pub fn shed(&self) -> [u64; PRIORITY_CLASSES] {
        self.shed
    }

    /// Wall-clock TTFT histogram for one class.
    pub fn ttft_wall(&self, class: Priority) -> &Histogram {
        &self.ttft_wall[class.index()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> AdmissionConfig {
        AdmissionConfig {
            window_ticks: 10,
            token_budget: 16,
            shares: [1.0, 0.5, 0.25],
            ttft_deadline_ticks: [u64::MAX; PRIORITY_CLASSES],
            max_queued_tokens: u64::MAX,
            max_resident_bytes: u64::MAX,
        }
    }

    #[test]
    fn priority_round_trips_and_matches_coordinator_width() {
        assert_eq!(Priority::COUNT, PRIORITY_CLASSES);
        for (i, p) in Priority::ALL.into_iter().enumerate() {
            assert_eq!(p.index(), i);
            assert_eq!(Priority::from_index(i), Some(p));
            assert_eq!(Priority::parse(p.name()), Some(p));
        }
        assert_eq!(Priority::from_index(PRIORITY_CLASSES), None);
        assert_eq!(Priority::parse("extreme"), None);
    }

    #[test]
    fn class_share_caps_spend_and_resets_at_window() {
        let mut ac = AdmissionController::new(cfg());
        let load = LoadSignal::default();
        // Batch share: 0.25 * 160 = 40 tokens per window.
        assert!(ac.admit(Priority::Batch, 32, 0, &load).is_ok());
        assert_eq!(
            ac.admit(Priority::Batch, 32, 1, &load),
            Err(ShedReason::ClassBudgetExhausted)
        );
        // Interactive is unaffected by Batch's exhaustion.
        assert!(ac.admit(Priority::Interactive, 32, 1, &load).is_ok());
        // Next window: Batch spend resets.
        assert!(ac.admit(Priority::Batch, 32, 10, &load).is_ok());
        assert_eq!(ac.admitted(), [1, 0, 2]);
        assert_eq!(ac.shed(), [0, 0, 1]);
    }

    #[test]
    fn zero_share_always_sheds() {
        let mut c = cfg();
        c.shares[Priority::Batch.index()] = 0.0;
        let mut ac = AdmissionController::new(c);
        let load = LoadSignal::default();
        for tick in 0..25 {
            assert_eq!(
                ac.admit(Priority::Batch, 1, tick, &load),
                Err(ShedReason::ClassBudgetExhausted)
            );
            assert!(ac.admit(Priority::Interactive, 1, tick, &load).is_ok());
        }
        assert_eq!(ac.shed()[Priority::Batch.index()], 25);
    }

    #[test]
    fn queued_token_backstop_sheds_any_class() {
        let mut c = cfg();
        c.max_queued_tokens = 100;
        let mut ac = AdmissionController::new(c);
        let load = LoadSignal { queued_prompt_tokens: 90, ..LoadSignal::default() };
        assert_eq!(
            ac.admit(Priority::Interactive, 32, 0, &load),
            Err(ShedReason::QueueFull)
        );
        assert!(ac.admit(Priority::Interactive, 10, 0, &load).is_ok());
    }

    #[test]
    fn resident_bytes_backstop_sheds_as_overloaded() {
        let mut c = cfg();
        c.max_resident_bytes = 1 << 20;
        let mut ac = AdmissionController::new(c);
        let load = LoadSignal { resident_state_bytes: (1 << 20) + 1, ..LoadSignal::default() };
        assert_eq!(ac.admit(Priority::Batch, 1, 0, &load), Err(ShedReason::Overloaded));
    }

    #[test]
    fn deadline_estimate_sheds_when_backlog_is_too_deep() {
        let mut c = cfg();
        // 16 tokens/tick, deadline 4 ticks => at most 64 backlog tokens.
        c.ttft_deadline_ticks[Priority::Interactive.index()] = 4;
        let mut ac = AdmissionController::new(c);
        let deep = LoadSignal { queued_prompt_tokens: 80, ..LoadSignal::default() };
        assert_eq!(
            ac.admit(Priority::Interactive, 16, 0, &deep),
            Err(ShedReason::DeadlineUnmeetable)
        );
        let shallow = LoadSignal { queued_prompt_tokens: 16, ..LoadSignal::default() };
        assert!(ac.admit(Priority::Interactive, 16, 0, &shallow).is_ok());
    }

    #[test]
    fn slo_pressure_sheds_non_interactive_only() {
        let mut c = cfg();
        c.ttft_deadline_ticks[Priority::Interactive.index()] = 8;
        let mut ac = AdmissionController::new(c);
        let load = LoadSignal::default();
        // Observed p99 TTFT of 20 ticks blows the 8-tick deadline.
        let mut report = LatencyReport::default();
        for _ in 0..10 {
            report.ttft_ticks.record(20);
        }
        ac.note_latency(&report);
        assert_eq!(
            ac.admit(Priority::Batch, 1, 0, &load),
            Err(ShedReason::DeadlineUnmeetable)
        );
        assert_eq!(
            ac.admit(Priority::Standard, 1, 0, &load),
            Err(ShedReason::DeadlineUnmeetable)
        );
        assert!(ac.admit(Priority::Interactive, 1, 0, &load).is_ok());
        // Recovery: a healthy report lifts the pressure.
        let mut healthy = LatencyReport::default();
        for _ in 0..10 {
            healthy.ttft_ticks.record(2);
        }
        ac.note_latency(&healthy);
        assert!(ac.admit(Priority::Batch, 1, 1, &load).is_ok());
    }

    #[test]
    fn default_config_admits_everything() {
        let mut ac = AdmissionController::new(AdmissionConfig::default());
        let load = LoadSignal {
            queue_depth: 1_000,
            queued_prompt_tokens: 1 << 30,
            running: 1_000,
            resident_state_bytes: 1 << 40,
            budget_utilization: 1.0,
        };
        for (p, tick) in Priority::ALL.into_iter().zip(0u64..) {
            assert!(ac.admit(p, 1 << 20, tick, &load).is_ok());
        }
    }
}
