//! The network serving front-end: framed wire protocol, TCP accept
//! loop with per-connection streaming, and SLO-aware admission
//! control.
//!
//! This layer sits strictly *above* the coordinator — it speaks
//! [`crate::coordinator::Server`]'s `submit`/sink API and never
//! reaches into worker internals. Three pieces:
//!
//! * [`wire`] — a std-only length-prefixed frame protocol with a
//!   version-carrying Hello header; decoding is total (typed
//!   [`WireError`]s, never panics).
//! * [`admission`] — priority classes with per-class token-budget
//!   shares over fixed windows, deadline tracking on the scheduler's
//!   deterministic tick histograms, and queue-depth/resident-bytes
//!   load backstops mirroring the planner's `WorkloadFeatures`
//!   signals.
//! * [`connection`] — the accept loop, per-connection reader/writer
//!   threads, and the router loop bridging sockets to the server
//!   while preserving the exactly-one-terminal-message contract end
//!   to end over the wire (shed requests included).

pub mod admission;
pub mod connection;
pub mod wire;

pub use admission::{
    AdmissionConfig, AdmissionController, LoadSignal, Priority, ShedReason,
};
pub use connection::{run_client, serve, ClientReply, FrontendConfig, FrontendStats};
pub use wire::{
    decode_frame, encode_frame, read_frame, write_frame, Frame, WireError, HELLO_MAGIC,
    MAX_FRAME_LEN, PROTOCOL_VERSION,
};
