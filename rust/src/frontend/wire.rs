//! Length-prefixed framed wire protocol for the network front-end.
//!
//! Every frame is `[len: u32 LE][kind: u32 LE][payload...]` where
//! `len` counts the kind word plus the payload (so `len >= 4`), is a
//! multiple of 4 (frames are 4-byte aligned end to end — variable
//! fields carry explicit byte lengths and pad with zeros), and is
//! bounded by [`MAX_FRAME_LEN`]. The first frame in each direction is
//! a version-carrying [`Frame::Hello`] header: magic + protocol
//! version, rejected with [`WireError::VersionMismatch`] on skew so a
//! stale client fails loudly at the handshake instead of mis-parsing
//! mid-stream.
//!
//! Decoding is **total**: truncated, oversized, misaligned,
//! unknown-kind, bad-magic and version-mismatch inputs all return a
//! typed [`WireError`] — never a panic — which the property suite
//! (`rust/tests/frontend_wire.rs`) drives with adversarial bytes.

use std::io::{Read, Write};

/// Protocol version carried by the [`Frame::Hello`] header frame.
pub const PROTOCOL_VERSION: u32 = 1;

/// Magic word in the Hello frame (`"MBLY"` little-endian) — catches a
/// client speaking a different protocol entirely before any state is
/// allocated for it.
pub const HELLO_MAGIC: u32 = 0x594c_424d;

/// Upper bound on `len` (kind + payload bytes). Generous for prompts
/// (a quarter-million tokens) while bounding what a hostile
/// length-prefix can make the server allocate.
pub const MAX_FRAME_LEN: u32 = 1 << 20;

/// Frame kind discriminants on the wire.
const KIND_HELLO: u32 = 1;
const KIND_SUBMIT: u32 = 2;
const KIND_TOKEN: u32 = 3;
const KIND_DONE: u32 = 4;
const KIND_ERROR: u32 = 5;

/// One protocol frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Frame {
    /// Version-carrying header; first frame in each direction.
    Hello { version: u32 },
    /// Client → server: one generation request.
    Submit {
        id: u64,
        /// Priority-class index (see [`super::Priority`]); validated
        /// against [`crate::coordinator::PRIORITY_CLASSES`] at decode.
        priority: u32,
        max_new_tokens: u32,
        prompt: Vec<i32>,
    },
    /// Server → client: one generated token of request `id`.
    Token { id: u64, token: i32 },
    /// Server → client: terminal success. `n_tokens` must equal the
    /// Token frames streamed before it (the client checks).
    Done { id: u64, n_tokens: u32, ttft_us: u32, total_us: u32 },
    /// Server → client: terminal failure (admission shed, fault-path
    /// exhaustion, duplicate id, ...). Exactly one of Done/Error per
    /// submitted id — the wire form of the exactly-one-terminal-message
    /// contract.
    Error { id: u64, reason: String },
}

/// Typed decode/IO failure. Every malformed input maps here; decoding
/// never panics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireError {
    /// Input ended before the declared frame length.
    Truncated,
    /// Declared length exceeds [`MAX_FRAME_LEN`].
    Oversized { len: u32 },
    /// Declared length below the 4-byte kind word or not 4-byte
    /// aligned.
    Misaligned { len: u32 },
    /// Unknown frame-kind discriminant.
    UnknownKind(u32),
    /// Hello carried a different protocol version.
    VersionMismatch { got: u32, want: u32 },
    /// Hello magic word mismatch (not this protocol at all).
    BadMagic(u32),
    /// Structurally invalid payload for the declared kind.
    BadPayload(&'static str),
    /// Underlying socket error (kind only — keeps the error `Eq` and
    /// cheap to match in tests).
    Io(std::io::ErrorKind),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated => write!(f, "frame truncated"),
            WireError::Oversized { len } => {
                write!(f, "frame length {len} exceeds max {MAX_FRAME_LEN}")
            }
            WireError::Misaligned { len } => {
                write!(f, "frame length {len} not 4-byte aligned (or below the kind word)")
            }
            WireError::UnknownKind(k) => write!(f, "unknown frame kind {k}"),
            WireError::VersionMismatch { got, want } => {
                write!(f, "protocol version mismatch: got {got}, want {want}")
            }
            WireError::BadMagic(m) => write!(f, "bad hello magic {m:#010x}"),
            WireError::BadPayload(what) => write!(f, "bad frame payload: {what}"),
            WireError::Io(kind) => write!(f, "io error: {kind:?}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> WireError {
        match e.kind() {
            std::io::ErrorKind::UnexpectedEof => WireError::Truncated,
            kind => WireError::Io(kind),
        }
    }
}

/// Little-endian scratch writer over a byte vec.
struct Enc(Vec<u8>);

impl Enc {
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn i32(&mut self, v: i32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
}

/// Little-endian cursor over a payload slice; every read is
/// bounds-checked and fails as [`WireError::Truncated`].
struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let end = self.pos.checked_add(n).ok_or(WireError::Truncated)?;
        if end > self.buf.len() {
            return Err(WireError::Truncated);
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }
    fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }
    fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }
    fn i32(&mut self) -> Result<i32, WireError> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }
}

/// Round up to the next multiple of 4 (frame alignment).
fn pad4(n: usize) -> usize {
    (n + 3) & !3
}

impl Frame {
    fn kind(&self) -> u32 {
        match self {
            Frame::Hello { .. } => KIND_HELLO,
            Frame::Submit { .. } => KIND_SUBMIT,
            Frame::Token { .. } => KIND_TOKEN,
            Frame::Done { .. } => KIND_DONE,
            Frame::Error { .. } => KIND_ERROR,
        }
    }
}

/// Encode one frame, length prefix included.
pub fn encode_frame(frame: &Frame) -> Vec<u8> {
    let mut body = Enc(Vec::new());
    body.u32(frame.kind());
    match frame {
        Frame::Hello { version } => {
            body.u32(HELLO_MAGIC);
            body.u32(*version);
        }
        Frame::Submit { id, priority, max_new_tokens, prompt } => {
            body.u64(*id);
            body.u32(*priority);
            body.u32(*max_new_tokens);
            body.u32(prompt.len() as u32);
            for &t in prompt {
                body.i32(t);
            }
        }
        Frame::Token { id, token } => {
            body.u64(*id);
            body.i32(*token);
        }
        Frame::Done { id, n_tokens, ttft_us, total_us } => {
            body.u64(*id);
            body.u32(*n_tokens);
            body.u32(*ttft_us);
            body.u32(*total_us);
        }
        Frame::Error { id, reason } => {
            body.u64(*id);
            let bytes = reason.as_bytes();
            body.u32(bytes.len() as u32);
            body.0.extend_from_slice(bytes);
            // Zero-pad the variable tail to keep the frame 4-aligned.
            body.0.resize(pad4(body.0.len()), 0);
        }
    }
    let mut out = Enc(Vec::with_capacity(4 + body.0.len()));
    out.u32(body.0.len() as u32);
    out.0.extend_from_slice(&body.0);
    out.0
}

/// Decode one frame from the front of `buf`. Returns the frame and the
/// total bytes consumed (prefix included) so a caller over a byte
/// stream can advance. All malformed input returns a [`WireError`].
pub fn decode_frame(buf: &[u8]) -> Result<(Frame, usize), WireError> {
    let mut d = Dec { buf, pos: 0 };
    let len = d.u32()?;
    if len > MAX_FRAME_LEN {
        return Err(WireError::Oversized { len });
    }
    if len < 4 || len % 4 != 0 {
        return Err(WireError::Misaligned { len });
    }
    let body = d.take(len as usize)?;
    let frame = decode_body(body)?;
    Ok((frame, 4 + len as usize))
}

/// Decode a frame body (kind word + payload, no length prefix).
fn decode_body(body: &[u8]) -> Result<Frame, WireError> {
    let mut d = Dec { buf: body, pos: 0 };
    let kind = d.u32()?;
    match kind {
        KIND_HELLO => {
            let magic = d.u32()?;
            if magic != HELLO_MAGIC {
                return Err(WireError::BadMagic(magic));
            }
            let version = d.u32()?;
            if version != PROTOCOL_VERSION {
                return Err(WireError::VersionMismatch { got: version, want: PROTOCOL_VERSION });
            }
            Ok(Frame::Hello { version })
        }
        KIND_SUBMIT => {
            let id = d.u64()?;
            let priority = d.u32()?;
            if priority >= crate::coordinator::PRIORITY_CLASSES as u32 {
                return Err(WireError::BadPayload("priority class out of range"));
            }
            let max_new_tokens = d.u32()?;
            if max_new_tokens > MAX_FRAME_LEN {
                return Err(WireError::BadPayload("max_new_tokens implausibly large"));
            }
            let n = d.u32()? as usize;
            let raw = d.take(n.checked_mul(4).ok_or(WireError::Truncated)?)?;
            let prompt = raw
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes(c.try_into().expect("4 bytes")))
                .collect();
            Ok(Frame::Submit { id, priority, max_new_tokens, prompt })
        }
        KIND_TOKEN => {
            let id = d.u64()?;
            let token = d.i32()?;
            Ok(Frame::Token { id, token })
        }
        KIND_DONE => {
            let id = d.u64()?;
            let n_tokens = d.u32()?;
            let ttft_us = d.u32()?;
            let total_us = d.u32()?;
            Ok(Frame::Done { id, n_tokens, ttft_us, total_us })
        }
        KIND_ERROR => {
            let id = d.u64()?;
            let n = d.u32()? as usize;
            if n > body.len() {
                return Err(WireError::Truncated);
            }
            let raw = d.take(n)?;
            let reason = std::str::from_utf8(raw)
                .map_err(|_| WireError::BadPayload("error reason not utf-8"))?
                .to_string();
            Ok(Frame::Error { id, reason })
        }
        other => Err(WireError::UnknownKind(other)),
    }
}

/// Write one frame to a stream.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> Result<(), WireError> {
    w.write_all(&encode_frame(frame))?;
    Ok(())
}

/// Read one frame from a stream. Length-prefix validation happens
/// *before* the body allocation, so a hostile prefix cannot make the
/// reader allocate more than [`MAX_FRAME_LEN`].
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame, WireError> {
    let mut prefix = [0u8; 4];
    r.read_exact(&mut prefix)?;
    let len = u32::from_le_bytes(prefix);
    if len > MAX_FRAME_LEN {
        return Err(WireError::Oversized { len });
    }
    if len < 4 || len % 4 != 0 {
        return Err(WireError::Misaligned { len });
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)?;
    decode_body(&body)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(f: Frame) {
        let bytes = encode_frame(&f);
        assert_eq!(bytes.len() % 4, 0, "frames are 4-byte aligned: {f:?}");
        let (got, used) = decode_frame(&bytes).expect("round trip");
        assert_eq!(got, f);
        assert_eq!(used, bytes.len(), "decode consumes the whole frame");
        // Stream form agrees with the buffer form.
        let mut cursor = std::io::Cursor::new(bytes);
        assert_eq!(read_frame(&mut cursor).expect("stream round trip"), f);
    }

    #[test]
    fn every_kind_round_trips() {
        round_trip(Frame::Hello { version: PROTOCOL_VERSION });
        round_trip(Frame::Submit {
            id: 42,
            priority: 2,
            max_new_tokens: 17,
            prompt: vec![-1, 0, 1, i32::MAX, i32::MIN],
        });
        round_trip(Frame::Submit { id: 0, priority: 0, max_new_tokens: 0, prompt: vec![] });
        round_trip(Frame::Token { id: u64::MAX, token: -7 });
        round_trip(Frame::Done { id: 9, n_tokens: 3, ttft_us: 120, total_us: 950 });
        round_trip(Frame::Error { id: 5, reason: "shed: batch share exhausted".into() });
        round_trip(Frame::Error { id: 5, reason: String::new() });
        // Reason lengths around the padding boundary.
        for n in 0..9 {
            round_trip(Frame::Error { id: 1, reason: "x".repeat(n) });
        }
    }

    #[test]
    fn version_mismatch_is_rejected() {
        let mut bytes = encode_frame(&Frame::Hello { version: PROTOCOL_VERSION });
        // Patch the version word (last 4 bytes of the hello payload).
        let n = bytes.len();
        bytes[n - 4..].copy_from_slice(&(PROTOCOL_VERSION + 1).to_le_bytes());
        assert_eq!(
            decode_frame(&bytes).unwrap_err(),
            WireError::VersionMismatch { got: PROTOCOL_VERSION + 1, want: PROTOCOL_VERSION }
        );
    }

    #[test]
    fn corrupt_length_prefixes_are_rejected() {
        let good = encode_frame(&Frame::Token { id: 1, token: 2 });
        // Oversized declared length.
        let mut b = good.clone();
        b[..4].copy_from_slice(&(MAX_FRAME_LEN + 4).to_le_bytes());
        assert!(matches!(decode_frame(&b), Err(WireError::Oversized { .. })));
        // Misaligned declared length.
        let mut b = good.clone();
        b[..4].copy_from_slice(&10u32.to_le_bytes());
        assert!(matches!(decode_frame(&b), Err(WireError::Misaligned { len: 10 })));
        // Below the kind word.
        let mut b = good.clone();
        b[..4].copy_from_slice(&0u32.to_le_bytes());
        assert!(matches!(decode_frame(&b), Err(WireError::Misaligned { len: 0 })));
        // Truncated mid-body.
        let b = &good[..good.len() - 2];
        assert_eq!(decode_frame(b).unwrap_err(), WireError::Truncated);
    }

    #[test]
    fn oversized_submit_is_refused_before_allocation() {
        // A hostile prefix claiming a giant body must fail on the
        // prefix check, not allocate.
        let mut b = Vec::new();
        b.extend_from_slice(&u32::MAX.to_le_bytes());
        b.extend_from_slice(&KIND_SUBMIT.to_le_bytes());
        assert!(matches!(decode_frame(&b), Err(WireError::Oversized { .. })));
    }
}
