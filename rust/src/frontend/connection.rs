//! TCP accept loop, per-connection streaming, and the router loop
//! that bridges sockets to the coordinator.
//!
//! Thread layout per [`serve`] call:
//!
//! ```text
//!             accept thread ── one per listener
//!            /      |
//!      reader    writer      ── one pair per connection
//!          \        ^
//!   ConnEvent       | encoded frames (mpsc)
//!            \      |
//!          router loop        ── the calling thread; owns the Server
//!                               and the AdmissionController
//! ```
//!
//! * The **reader** validates the client's [`Frame::Hello`] (magic +
//!   version), answers with the server's Hello, then forwards each
//!   [`Frame::Submit`] to the router. Any wire error is answered with
//!   a terminal [`Frame::Error`] and the connection closes — malformed
//!   bytes never reach the coordinator.
//! * The **writer** owns the socket's write half and drains an mpsc of
//!   pre-encoded frames, so the router and the reader can both reply
//!   without sharing a stream lock.
//! * The **router loop** admits or sheds each submit, forwards
//!   admitted requests to [`Server::submit`], and polls the per-request
//!   response sinks — streaming each generated token as a
//!   [`Frame::Token`] followed by exactly one terminal
//!   ([`Frame::Done`] or [`Frame::Error`]) per submitted id. Shed
//!   requests take the [`Server::shed_request`] path so their spans
//!   still reconcile against the traffic counters.
//!
//! The exactly-one-terminal-message contract the coordinator upholds
//! in-process therefore extends end to end over the socket: every
//! submitted id receives exactly one Done or Error frame, including
//! sheds, duplicates and supervision failures.

use std::collections::HashMap;
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::time::Duration;

use crate::coordinator::{Request, Response, Server, PRIORITY_CLASSES};

use super::admission::{AdmissionConfig, AdmissionController, LoadSignal, Priority};
use super::wire::{encode_frame, read_frame, write_frame, Frame, WireError, PROTOCOL_VERSION};

/// Front-end serving configuration.
#[derive(Debug, Clone, Copy)]
pub struct FrontendConfig {
    /// Admission policy (shares, deadlines, backstops).
    pub admission: AdmissionConfig,
    /// Stop accepting after this many connections and return once all
    /// of them have drained; `None` serves forever (daemon mode).
    pub max_connections: Option<usize>,
}

impl Default for FrontendConfig {
    fn default() -> FrontendConfig {
        FrontendConfig { admission: AdmissionConfig::default(), max_connections: None }
    }
}

/// What the front-end did over one [`serve`] call.
#[derive(Debug, Clone, Copy, Default)]
pub struct FrontendStats {
    /// Connections accepted.
    pub connections: u64,
    /// Submit frames received (admitted or shed).
    pub requests: u64,
    /// Requests admitted per priority class.
    pub admitted: [u64; PRIORITY_CLASSES],
    /// Requests shed per priority class.
    pub shed: [u64; PRIORITY_CLASSES],
    /// Terminal Error frames written (sheds, duplicates, failures).
    pub errors: u64,
}

/// Reader/accept → router messages.
enum ConnEvent {
    /// New connection; `out` feeds its writer thread.
    Opened { conn: u64, out: Sender<Vec<u8>> },
    /// A validated Submit frame from connection `conn`.
    Submit { conn: u64, id: u64, priority: u32, max_new_tokens: u32, prompt: Vec<i32> },
    /// Reader finished (EOF or wire error already answered).
    Closed { conn: u64 },
    /// Listener stopped accepting (socket error or max reached).
    AcceptDone,
}

/// An admitted request awaiting its terminal response.
struct Pending {
    rx: Receiver<Response>,
    class: Priority,
    prompt_tokens: u64,
    out: Sender<Vec<u8>>,
}

/// Serve connections from `listener`, bridging to `server`, until
/// `cfg.max_connections` connections have fully drained (or forever if
/// `None`). Returns the server (for trace/traffic inspection and
/// shutdown) and the front-end's accounting.
pub fn serve(
    listener: TcpListener,
    mut server: Server,
    cfg: FrontendConfig,
) -> std::io::Result<(Server, FrontendStats)> {
    let (ev_tx, ev_rx) = channel::<ConnEvent>();
    let max_conns = cfg.max_connections;
    let accept_tx = ev_tx.clone();
    let accept = std::thread::spawn(move || {
        accept_loop(listener, max_conns, accept_tx);
    });
    // The router keeps no clone of ev_tx: once the accept loop and all
    // readers finish, the channel disconnects and the drain loop can
    // tell "no events now" from "no events ever again".
    drop(ev_tx);

    let mut admission = AdmissionController::new(cfg.admission);
    let mut stats = FrontendStats::default();
    let mut pending: HashMap<u64, Pending> = HashMap::new();
    let mut conn_out: HashMap<u64, Sender<Vec<u8>>> = HashMap::new();
    let mut queued_tokens: u64 = 0;
    let mut opened: u64 = 0;
    let mut closed: u64 = 0;
    let mut accept_done = false;
    let mut events_live = true;
    let mut now_tick: u64 = 0;

    loop {
        // Drain control/submit events without blocking the poll loop.
        while events_live {
            match ev_rx.try_recv() {
                Ok(ConnEvent::Opened { conn, out }) => {
                    opened += 1;
                    stats.connections += 1;
                    conn_out.insert(conn, out);
                }
                Ok(ConnEvent::Submit { conn, id, priority, max_new_tokens, prompt }) => {
                    let Some(out) = conn_out.get(&conn) else { continue };
                    stats.requests += 1;
                    // Decode validated `priority < PRIORITY_CLASSES`.
                    let class = Priority::from_index(priority as usize)
                        .unwrap_or(Priority::Batch);
                    let load = load_signal(&server, &pending, queued_tokens, &cfg.admission);
                    match admission.admit(class, prompt.len() as u64, now_tick, &load) {
                        Ok(()) => {
                            stats.admitted[class.index()] += 1;
                            server.record_admitted(class.index());
                            let prompt_tokens = prompt.len() as u64;
                            queued_tokens += prompt_tokens;
                            let rx = server.submit(Request {
                                id,
                                prompt,
                                max_new_tokens: max_new_tokens as usize,
                            });
                            pending.insert(
                                id,
                                Pending { rx, class, prompt_tokens, out: out.clone() },
                            );
                        }
                        Err(reason) => {
                            stats.shed[class.index()] += 1;
                            stats.errors += 1;
                            let resp = server.shed_request(
                                id,
                                class.index(),
                                format!("shed: {reason}"),
                            );
                            let frame = Frame::Error {
                                id,
                                reason: resp.error.unwrap_or_else(|| format!("shed: {reason}")),
                            };
                            let _ = out.send(encode_frame(&frame));
                        }
                    }
                }
                Ok(ConnEvent::Closed { conn }) => {
                    closed += 1;
                    // Pending entries hold their own sender clones, so
                    // the writer stays alive until its responses drain.
                    conn_out.remove(&conn);
                }
                Ok(ConnEvent::AcceptDone) => accept_done = true,
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    events_live = false;
                    accept_done = true;
                }
            }
        }

        // Pump fault supervision while requests are in flight.
        server.supervise();

        // Poll response sinks: stream tokens, then exactly one terminal.
        let ready: Vec<(u64, Option<Response>)> = pending
            .iter()
            .filter_map(|(&id, p)| match p.rx.try_recv() {
                Ok(resp) => Some((id, Some(resp))),
                // Sink dropped without a response: duplicate submit
                // (the server keeps the original's sink) or a hole in
                // supervision; either way the client still gets its
                // one terminal frame.
                Err(TryRecvError::Disconnected) => Some((id, None)),
                Err(TryRecvError::Empty) => None,
            })
            .collect();
        for (id, resp) in ready {
            let p = pending.remove(&id).expect("ready id is pending");
            queued_tokens = queued_tokens.saturating_sub(p.prompt_tokens);
            match resp {
                Some(resp) if resp.error.is_none() => {
                    admission.note_ttft(p.class, resp.ttft);
                    for &t in &resp.tokens {
                        let _ = p.out.send(encode_frame(&Frame::Token { id, token: t }));
                    }
                    let _ = p.out.send(encode_frame(&Frame::Done {
                        id,
                        n_tokens: resp.tokens.len() as u32,
                        ttft_us: (resp.ttft * 1e6).round().max(0.0) as u32,
                        total_us: (resp.total * 1e6).round().max(0.0) as u32,
                    }));
                }
                Some(resp) => {
                    stats.errors += 1;
                    let reason =
                        resp.error.unwrap_or_else(|| "request failed".to_string());
                    let _ = p.out.send(encode_frame(&Frame::Error { id, reason }));
                }
                None => {
                    stats.errors += 1;
                    let _ = p.out.send(encode_frame(&Frame::Error {
                        id,
                        reason: "request dropped (duplicate id?)".into(),
                    }));
                }
            }
        }

        now_tick += 1;
        // Refresh the SLO-pressure signal from the scheduler's
        // deterministic tick histograms once per admission window.
        if now_tick % cfg.admission.window_ticks.max(1) == 0 {
            admission.note_latency(&server.latency());
        }

        let drained = pending.is_empty();
        if accept_done && opened == closed && drained && !events_live {
            break;
        }
        if let Some(n) = cfg.max_connections {
            if accept_done && opened == n as u64 && opened == closed && drained {
                break;
            }
        }
        if drained && !accept_done {
            // Idle: nothing in flight, wait for the next event rather
            // than spinning. Wake periodically to re-check liveness.
            std::thread::sleep(Duration::from_micros(500));
        } else if !drained {
            std::thread::sleep(Duration::from_micros(100));
        }
    }

    drop(conn_out);
    let _ = accept.join();
    Ok((server, stats))
}

fn accept_loop(listener: TcpListener, max: Option<usize>, ev_tx: Sender<ConnEvent>) {
    let mut accepted = 0usize;
    loop {
        if let Some(n) = max {
            if accepted >= n {
                break;
            }
        }
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(_) => break,
        };
        accepted += 1;
        let conn = accepted as u64;
        let (out_tx, out_rx) = channel::<Vec<u8>>();
        let write_half = match stream.try_clone() {
            Ok(s) => s,
            Err(_) => continue,
        };
        std::thread::spawn(move || writer_loop(write_half, out_rx));
        if ev_tx.send(ConnEvent::Opened { conn, out: out_tx.clone() }).is_err() {
            break;
        }
        let reader_tx = ev_tx.clone();
        std::thread::spawn(move || {
            reader_loop(stream, conn, out_tx, reader_tx);
        });
    }
    let _ = ev_tx.send(ConnEvent::AcceptDone);
}

/// Drain pre-encoded frames onto the socket. Exits when every sender
/// (reader, router, pending entries) has dropped, or on write error.
fn writer_loop(mut stream: TcpStream, out_rx: Receiver<Vec<u8>>) {
    while let Ok(bytes) = out_rx.recv() {
        if stream.write_all(&bytes).is_err() {
            break;
        }
    }
    let _ = stream.flush();
}

/// Per-connection read half: handshake, then forward Submits.
fn reader_loop(
    mut stream: TcpStream,
    conn: u64,
    out: Sender<Vec<u8>>,
    ev_tx: Sender<ConnEvent>,
) {
    match read_frame(&mut stream) {
        Ok(Frame::Hello { .. }) => {
            // decode already enforced magic + version; answer in kind.
            let _ = out.send(encode_frame(&Frame::Hello { version: PROTOCOL_VERSION }));
            loop {
                match read_frame(&mut stream) {
                    Ok(Frame::Submit { id, priority, max_new_tokens, prompt }) => {
                        if ev_tx
                            .send(ConnEvent::Submit { conn, id, priority, max_new_tokens, prompt })
                            .is_err()
                        {
                            break;
                        }
                    }
                    Ok(_) => {
                        let _ = out.send(encode_frame(&Frame::Error {
                            id: 0,
                            reason: "protocol error: expected Submit".into(),
                        }));
                        break;
                    }
                    Err(WireError::Truncated) => break, // clean EOF
                    Err(e) => {
                        let _ = out.send(encode_frame(&Frame::Error {
                            id: 0,
                            reason: format!("protocol error: {e}"),
                        }));
                        break;
                    }
                }
            }
        }
        Ok(_) => {
            let _ = out.send(encode_frame(&Frame::Error {
                id: 0,
                reason: "protocol error: expected Hello".into(),
            }));
        }
        Err(WireError::Truncated) => {} // connected then closed
        Err(e) => {
            let _ = out.send(encode_frame(&Frame::Error {
                id: 0,
                reason: format!("protocol error: {e}"),
            }));
        }
    }
    let _ = ev_tx.send(ConnEvent::Closed { conn });
}

fn load_signal(
    server: &Server,
    pending: &HashMap<u64, Pending>,
    queued_tokens: u64,
    cfg: &AdmissionConfig,
) -> LoadSignal {
    let loads = server.loads();
    let running: u64 = loads.iter().map(|l| l.running as u64).sum();
    let waiting: u64 = loads.iter().map(|l| l.waiting as u64).sum();
    let resident: u64 = loads.iter().map(|l| l.resident_bytes).sum();
    LoadSignal {
        queue_depth: waiting.max(pending.len() as u64),
        queued_prompt_tokens: queued_tokens,
        running,
        resident_state_bytes: resident,
        budget_utilization: (running as f64 / cfg.token_budget.max(1) as f64).min(1.0),
    }
}

/// One client-side request outcome.
#[derive(Debug, Clone)]
pub struct ClientReply {
    pub id: u64,
    /// Tokens streamed before the terminal frame.
    pub tokens: Vec<i32>,
    /// `None` on [`Frame::Done`]; the error reason on [`Frame::Error`].
    pub error: Option<String>,
    /// Server-reported microseconds to first token (0 on error).
    pub ttft_us: u32,
}

/// Connect to a front-end, handshake, pipeline every request, and
/// collect one terminal reply per id. Verifies the streamed token
/// count matches each Done frame's `n_tokens`. Replies come back in
/// submission order.
pub fn run_client(
    addr: &str,
    reqs: &[(Request, Priority)],
    timeout: Option<Duration>,
) -> Result<Vec<ClientReply>, WireError> {
    let mut stream = TcpStream::connect(addr).map_err(WireError::from)?;
    stream.set_read_timeout(timeout).map_err(WireError::from)?;
    write_frame(&mut stream, &Frame::Hello { version: PROTOCOL_VERSION })?;
    match read_frame(&mut stream)? {
        Frame::Hello { .. } => {}
        _ => return Err(WireError::BadPayload("server did not answer Hello")),
    }
    for (req, prio) in reqs {
        write_frame(
            &mut stream,
            &Frame::Submit {
                id: req.id,
                priority: prio.index() as u32,
                max_new_tokens: req.max_new_tokens as u32,
                prompt: req.prompt.clone(),
            },
        )?;
    }
    let mut tokens: HashMap<u64, Vec<i32>> = HashMap::new();
    let mut done: HashMap<u64, ClientReply> = HashMap::new();
    while done.len() < reqs.len() {
        match read_frame(&mut stream)? {
            Frame::Token { id, token } => tokens.entry(id).or_default().push(token),
            Frame::Done { id, n_tokens, ttft_us, .. } => {
                let toks = tokens.remove(&id).unwrap_or_default();
                if toks.len() as u32 != n_tokens {
                    return Err(WireError::BadPayload("Done n_tokens != streamed tokens"));
                }
                done.insert(id, ClientReply { id, tokens: toks, error: None, ttft_us });
            }
            Frame::Error { id, reason } => {
                let toks = tokens.remove(&id).unwrap_or_default();
                done.insert(
                    id,
                    ClientReply { id, tokens: toks, error: Some(reason), ttft_us: 0 },
                );
            }
            Frame::Hello { .. } | Frame::Submit { .. } => {
                return Err(WireError::BadPayload("unexpected frame from server"));
            }
        }
    }
    Ok(reqs
        .iter()
        .map(|(r, _)| done.remove(&r.id).expect("one terminal per submitted id"))
        .collect())
}
