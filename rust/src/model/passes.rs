//! Pass analysis (after FuseMax): how many times a fused mapping must
//! stream a tensor through the datapath.
//!
//! Inside a fusion group, a consumer of tensor `T` needs a *fresh pass*
//! over `T` when it transitively depends on the output of an earlier
//! consumer of `T` through an Einsum that **reduces over one of `T`'s
//! ranks**: the reduction is a synchronization barrier — its result only
//! exists after the full extent of that rank of `T` has streamed by, so
//! the later consumer cannot share the earlier consumer's pass.
//!
//! In Mamba this is exactly why `X` (Einsum 1) and `LEX` (Einsum 10)
//! need two passes (paper §VI-C.1): `NEX = X·rsqrt(Σ_e X²)` makes the
//! second consumer of `X` depend on the `E`-reduction of `X`, and the
//! SSM's consumption of `LEX` depends on `Δ`, which is computed by
//! `D`-reductions of `LEX` itself.

use std::collections::{BTreeMap, BTreeSet};

use crate::einsum::cascade::CascadeIndex;
use crate::einsum::Cascade;

/// Per-tensor pass counts within a fused scope.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PassAnalysis {
    /// tensor name → number of passes (≥ 1). Tensors not present need
    /// a single pass.
    pub passes: BTreeMap<String, u32>,
}

impl PassAnalysis {
    pub fn passes_of(&self, tensor: &str) -> u32 {
        self.passes.get(tensor).copied().unwrap_or(1)
    }
}

/// Does Einsum `to` transitively depend on the output of Einsum `from`
/// via a path that contains an Einsum reducing over any rank in
/// `barrier_ranks`? Paths are forward dataflow edges restricted to
/// `scope` (the fusion group's members).
fn depends_via_reduction(
    c: &Cascade,
    idx: &CascadeIndex,
    scope: &BTreeSet<usize>,
    from: usize,
    to: usize,
    barrier_ranks: &BTreeSet<&str>,
) -> bool {
    // DFS over (einsum, crossed_barrier) states.
    let mut stack = vec![(from, reduces_barrier(c, from, barrier_ranks))];
    let mut seen = BTreeSet::new();
    while let Some((id, crossed)) = stack.pop() {
        if !seen.insert((id, crossed)) {
            continue;
        }
        let e = match c.by_id(id) {
            Some(e) => e,
            None => continue,
        };
        {
            for &nid in idx.consumers_of(&e.output.name) {
                if nid <= id || !scope.contains(&nid) {
                    continue; // forward edges inside the scope only
                }
                if nid == to {
                    // The destination's own reduction is not a barrier:
                    // it consumes T elementwise *while* reducing. Only
                    // reductions strictly between the consumers (or at
                    // the source) serialize passes.
                    if crossed {
                        return true;
                    }
                    continue;
                }
                let crossed_here = crossed || reduces_barrier(c, nid, barrier_ranks);
                stack.push((nid, crossed_here));
            }
        }
    }
    false
}

/// Does Einsum `id` reduce over any of the barrier ranks?
fn reduces_barrier(c: &Cascade, id: usize, barrier_ranks: &BTreeSet<&str>) -> bool {
    c.by_id(id)
        .map(|e| e.reduction_ranks.iter().any(|r| barrier_ranks.contains(r.name.as_str())))
        .unwrap_or(false)
}

/// Analyze pass counts for every multi-consumer tensor within a fused
/// scope (a fusion group's Einsum ids).
pub fn analyze_scope(c: &Cascade, scope_ids: &[usize]) -> PassAnalysis {
    analyze_scope_with(c, &CascadeIndex::new(c), scope_ids)
}

/// [`analyze_scope`] with a prebuilt index (the DSE hot path — avoids
/// rebuilding the consumer maps per fusion group; §Perf).
pub fn analyze_scope_with(
    c: &Cascade,
    idx: &CascadeIndex,
    scope_ids: &[usize],
) -> PassAnalysis {
    let scope: BTreeSet<usize> = scope_ids.iter().copied().collect();
    let mut passes = BTreeMap::new();

    for e in c.einsums() {
        let t = &e.output;
        let cs: Vec<usize> = {
            let all = idx.consumers_of(&t.name);
            if all.is_empty() { continue; }
            all.iter().copied().filter(|id| scope.contains(id)).collect()
        };
        if cs.len() < 2 {
            continue;
        }
        let barrier: BTreeSet<&str> = t.ranks.iter().map(|r| r.name.as_str()).collect();
        // Wave (level) assignment: consumer `cid` belongs to wave
        // `1 + max(wave(prev))` over all earlier consumers `prev` it
        // depends on through a barrier reduction, else wave 0.
        let mut wave_of: BTreeMap<usize, u32> = BTreeMap::new();
        for &cid in &cs {
            let mut w = 0;
            for (&prev, &pw) in wave_of.iter() {
                if depends_via_reduction(c, idx, &scope, prev, cid, &barrier) {
                    w = w.max(pw + 1);
                }
            }
            wave_of.insert(cid, w);
        }
        let nwaves = wave_of.values().copied().max().unwrap_or(0) + 1;
        if nwaves > 1 {
            passes.insert(t.name.clone(), nwaves);
        }
    }
    PassAnalysis { passes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cascade::{mamba1, ModelConfig};

    fn full_scope() -> (Cascade, Vec<usize>) {
        let c = mamba1::build(&ModelConfig::mamba_370m(), 64, 1);
        let ids: Vec<usize> = (1..=24).collect();
        (c, ids)
    }

    #[test]
    fn x_and_lex_need_two_passes() {
        // Paper §VI-C.1: "tensors X and LEX (Einsums 1 and 10) need two
        // passes and thus must be loaded multiple times."
        let (c, ids) = full_scope();
        let pa = analyze_scope(&c, &ids);
        assert_eq!(pa.passes_of("X"), 2, "passes = {:?}", pa.passes);
        assert_eq!(pa.passes_of("LEX"), 2, "passes = {:?}", pa.passes);
    }

    #[test]
    fn other_tensors_are_single_pass() {
        let (c, ids) = full_scope();
        let pa = analyze_scope(&c, &ids);
        for t in ["TX", "DL", "H", "SD", "GX"] {
            assert_eq!(pa.passes_of(t), 1, "{t}: {:?}", pa.passes);
        }
    }

    #[test]
    fn scope_restriction_limits_passes() {
        // If the scope covers only the norm front-end (1–6), X still
        // needs 2 passes (the NUM reduction sits between its consumers).
        let c = mamba1::build(&ModelConfig::mamba_370m(), 64, 1).clone();
        let pa = analyze_scope(&c, &(1..=6).collect::<Vec<_>>());
        assert_eq!(pa.passes_of("X"), 2);
        // A scope without both consumers ⇒ single pass.
        let pa = analyze_scope(&c, &(1..=3).collect::<Vec<_>>());
        assert_eq!(pa.passes_of("X"), 1);
    }
}
