//! The mapper: search the (loop order × tiling) space for the
//! DRAM-traffic-minimal mapping of one Einsum under a buffer budget and
//! the stationarity constraints fusion imposes — the role Timeloop's
//! mapper plays in the paper's methodology (§VI-A: "we specify the
//! mapping constraints imposed by Algorithm 1 and feed said constraints
//! into the Timeloop mapper for each individual Einsum").
//!
//! Search space: permutations of the Einsum's ranks as the outer loop
//! order (≤ 5 ranks ⇒ ≤ 120 orders) × power-of-two tile sizes per rank.
//! Constraints:
//! * buffer: the resident tile set must fit the budget;
//! * stationarity: ranks in `stationary` (the fusion group's surviving
//!   intersection, paper §III-D) must occupy the *outermost* loop
//!   positions — they are the ranks the fused traversal shares, so a
//!   tile of them is processed to completion before moving on.

use crate::einsum::{EinsumSpec, IterSpace};

use super::mapping::{LoopLevel, Mapping};

/// Mapper result: the chosen mapping and its cost.
#[derive(Debug, Clone)]
pub struct Mapped {
    pub mapping: Mapping,
    pub dram_bytes: u64,
    pub buffer_bytes: u64,
}

/// Search options.
#[derive(Debug, Clone)]
pub struct MapperOptions {
    /// On-chip buffer budget (bytes) for this Einsum's tiles.
    pub buffer_budget: u64,
    /// Ranks that must sit outermost (fusion stationarity); empty for
    /// an unfused Einsum.
    pub stationary: IterSpace,
    /// Cap on tile-size choices per rank (powers of two enumerated up
    /// to the extent; the cap bounds the search).
    pub max_tile_choices: usize,
}

impl Default for MapperOptions {
    fn default() -> Self {
        MapperOptions {
            buffer_budget: u64::MAX,
            stationary: IterSpace::empty(),
            max_tile_choices: 12,
        }
    }
}

/// Tile-size candidates for a rank: powers of two up to the extent
/// (including the extent itself), newest-first capped.
fn tile_choices(extent: u64, cap: usize) -> Vec<u64> {
    let mut out = vec![extent];
    let mut t = 1;
    while t < extent && out.len() < cap {
        out.push(t);
        t *= 2;
    }
    out.sort_unstable();
    out.dedup();
    out
}

/// Exhaustively search loop orders × tilings for the minimum-traffic
/// mapping. Returns `None` when even the smallest tiling overflows the
/// budget (the Einsum cannot execute without spilling below algorithmic
/// assumptions — callers fall back to unit tiles).
pub fn search(e: &EinsumSpec, opts: &MapperOptions) -> Option<Mapped> {
    let space = e.iteration_space();
    let ranks: Vec<(String, u64)> =
        space.ranks().iter().map(|r| (r.name.clone(), r.extent)).collect();
    let n = ranks.len();

    // Enumerate tilings: cartesian product of per-rank tile choices.
    let choices: Vec<Vec<u64>> =
        ranks.iter().map(|(_, ext)| tile_choices(*ext, opts.max_tile_choices)).collect();

    let mut best: Option<Mapped> = None;
    let mut tile_idx = vec![0usize; n];
    'tiles: loop {
        // Build the tile map for this combination.
        let tiles: std::collections::BTreeMap<String, u64> = ranks
            .iter()
            .zip(&tile_idx)
            .map(|((name, _), &ci)| (name.clone(), choices[ranks.iter().position(|(r, _)| r == name).unwrap()][ci]))
            .collect();

        // Outer loops = ranks with >1 trip.
        let tiled: Vec<(String, u64)> = ranks
            .iter()
            .filter_map(|(name, ext)| {
                let t = tiles[name];
                let trips = ext.div_ceil(t);
                (trips > 1).then(|| (name.clone(), trips))
            })
            .collect();

        // Permute the outer loops; stationary ranks must be outermost,
        // so permute stationary and free ranks separately and
        // concatenate.
        let (stat, free): (Vec<_>, Vec<_>) =
            tiled.iter().cloned().partition(|(r, _)| opts.stationary.contains(r));
        for stat_perm in permutations(&stat) {
            for free_perm in permutations(&free) {
                let outer: Vec<LoopLevel> = stat_perm
                    .iter()
                    .chain(free_perm.iter())
                    .map(|(rank, trips)| LoopLevel { rank: rank.clone(), trips: *trips })
                    .collect();
                let m = Mapping { outer, tiles: tiles.clone() };
                let buf = m.buffer_bytes(e);
                if buf > opts.buffer_budget {
                    continue;
                }
                let traffic = m.dram_traffic(e);
                let better = match &best {
                    None => true,
                    Some(b) => {
                        traffic < b.dram_bytes
                            || (traffic == b.dram_bytes && buf < b.buffer_bytes)
                    }
                };
                if better {
                    best = Some(Mapped { mapping: m, dram_bytes: traffic, buffer_bytes: buf });
                }
            }
        }

        // Advance the tiling odometer.
        for i in 0..=n {
            if i == n {
                break 'tiles;
            }
            tile_idx[i] += 1;
            if tile_idx[i] < choices[i].len() {
                break;
            }
            tile_idx[i] = 0;
        }
        if n == 0 {
            break;
        }
    }
    best
}

/// All permutations of a small slice (≤ 5 elements in practice).
fn permutations<T: Clone>(xs: &[T]) -> Vec<Vec<T>> {
    if xs.is_empty() {
        return vec![Vec::new()];
    }
    let mut out = Vec::new();
    for i in 0..xs.len() {
        let mut rest = xs.to_vec();
        let x = rest.remove(i);
        for mut p in permutations(&rest) {
            p.insert(0, x.clone());
            out.push(p);
        }
    }
    out
}

/// Map every Einsum of a cascade independently (the paper's per-Einsum
/// Timeloop runs), under a shared buffer budget. Returns (einsum id,
/// Mapped) pairs.
pub fn map_cascade(
    c: &crate::einsum::Cascade,
    buffer_budget: u64,
) -> Vec<(usize, Option<Mapped>)> {
    c.einsums()
        .iter()
        .map(|e| {
            let opts = MapperOptions { buffer_budget, ..Default::default() };
            (e.id, search(e, &opts))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cascade::{mamba1, ModelConfig};
    use crate::model::cost::unfused_traffic;

    fn cascade() -> crate::einsum::Cascade {
        mamba1::build(&ModelConfig::mamba_370m(), 256, 1)
    }

    #[test]
    fn infinite_buffer_reaches_algorithmic_minimum() {
        // With an unconstrained buffer the mapper must find the
        // untiled mapping: each tensor touched exactly once — the
        // "Best Unfused" assumption of Table I.
        let c = cascade();
        for e in c.einsums() {
            let mapped = search(e, &MapperOptions::default()).expect("mappable");
            let min = unfused_traffic(&c, e).total();
            assert_eq!(mapped.dram_bytes, min, "einsum #{}", e.id);
        }
    }

    #[test]
    fn tight_buffer_increases_traffic_monotonically() {
        let c = cascade();
        let e = c.by_id(7).unwrap(); // the big in-proj GEMM
        let budgets = [u64::MAX, 8 << 20, 2 << 20, 256 << 10];
        let mut last = 0u64;
        for b in budgets {
            let mapped = search(e, &MapperOptions { buffer_budget: b, ..Default::default() })
                .expect("mappable");
            assert!(mapped.buffer_bytes <= b);
            assert!(
                mapped.dram_bytes >= last,
                "traffic must grow as the buffer shrinks: {} < {last} at {b}",
                mapped.dram_bytes
            );
            last = mapped.dram_bytes;
        }
        // The smallest budget really forces extra traffic.
        let tight = search(
            e,
            &MapperOptions { buffer_budget: 256 << 10, ..Default::default() },
        )
        .unwrap();
        assert!(tight.dram_bytes > unfused_traffic(&c, e).total());
    }

    #[test]
    fn stationarity_constraint_is_respected() {
        let c = cascade();
        let e = c.by_id(7).unwrap();
        let mut stat_ranks = crate::einsum::IterSpace::empty();
        stat_ranks = stat_ranks.union(&crate::einsum::IterSpace::new(vec![
            crate::einsum::Rank::generational("I", 256),
        ]));
        let opts = MapperOptions {
            buffer_budget: 1 << 20, // force tiling
            stationary: stat_ranks,
            ..Default::default()
        };
        let mapped = search(e, &opts).expect("mappable");
        // If I appears among the outer loops, it must be outermost.
        if let Some(pos) = mapped.mapping.outer.iter().position(|l| l.rank == "I") {
            for (i, l) in mapped.mapping.outer.iter().enumerate() {
                if i < pos {
                    assert_eq!(l.rank, "I", "non-stationary rank {} outside I", l.rank);
                }
            }
        }
    }

    #[test]
    fn mapper_prefers_output_stationary_gemm() {
        // For a GEMM under moderate pressure the best mapping keeps the
        // reduction innermost (no partial-sum spills) — the upstream-
        // output-stationary dataflow the fusion classes require.
        let c = cascade();
        let e = c.by_id(24).unwrap(); // out-proj
        let mapped = search(
            e,
            &MapperOptions { buffer_budget: 4 << 20, ..Default::default() },
        )
        .unwrap();
        assert!(mapped.mapping.output_stationary(e), "{}", mapped.mapping);
    }

    #[test]
    fn whole_cascade_maps_under_table3_buffer() {
        let c = cascade();
        let arch = crate::arch::ArchSpec::mambalaya();
        for (id, mapped) in map_cascade(&c, arch.buffer_bytes) {
            let m = mapped.unwrap_or_else(|| panic!("einsum #{id} unmappable"));
            assert!(m.buffer_bytes <= arch.buffer_bytes);
        }
    }
}
