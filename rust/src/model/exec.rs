//! Cascade execution model: evaluates a fusion plan on an architecture
//! into a per-phase timeline (the paper's Figures 2/10/15) and totals
//! (Figures 12/13/14, Table I).
//!
//! Modeling assumptions (DESIGN.md §7):
//! * per-Einsum compute = work / bound-PE count + fill (pseudo-optimal
//!   intra-Einsum mapping, as the paper grants Timeloop);
//! * per-group memory = algorithmic-minimum DRAM traffic with fusion
//!   exceptions (pass reloads, staging spills, RD-bridge partials);
//! * the 2D array and its 1D-wide mode are the *same silicon* —
//!   members bound to either serialize; the small 1D array overlaps
//!   (it pipelines into the 2D array, §V-A);
//! * group latency = max(compute, memory) — fused traversals overlap
//!   compute with DRAM streaming; groups execute back-to-back unless
//!   `pipelined` (then compute and memory overlap across groups too).
//!
//! The inter-group byte accounting in [`eval_group`] is cross-checked
//! in CI by [`crate::verify::traffic`], which recomputes it from
//! liveness first principles — a term added or dropped here without a
//! matching update there fails `mambalaya verify` as traffic drift.

use crate::arch::{bind_group, ArchSpec, Binding, Staging};
use crate::einsum::cascade::CascadeIndex;
use crate::einsum::Cascade;
use crate::fusion::{FusionGroup, FusionPlan};

use super::cost::{compute_cycles, unfused_traffic_with, weight_bytes, Traffic};
use super::passes::analyze_scope_with;

/// Evaluation options.
#[derive(Debug, Clone, Copy)]
pub struct ExecOptions {
    /// Intermediate staging discipline (MARCA-like = FullExtent).
    pub staging: Staging,
    /// Overlap compute and memory *across* fusion groups (the paper's
    /// "with parallel pipelining" results, §VI-C.1).
    pub pipelined: bool,
    /// Charge per-invocation recurrent-state load/store (token
    /// generation: H and the conv window enter/leave the chip each
    /// step).
    pub decode_state_io: bool,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions { staging: Staging::UnitTile, pipelined: false, decode_state_io: false }
    }
}

/// Cost of one phase (= one fusion group).
#[derive(Debug, Clone)]
pub struct PhaseCost {
    pub einsums: Vec<usize>,
    /// Compute cycles on the 2D array (2D + wide-1D modes serialize).
    pub cycles_2d: u64,
    /// Compute cycles on the small 1D array (overlaps the 2D array).
    pub cycles_small: u64,
    /// DRAM traffic of the phase.
    pub traffic: Traffic,
    /// Memory cycles implied by the traffic.
    pub mem_cycles: u64,
    /// Phase latency (cycles).
    pub latency: u64,
    /// Total FLOPs executed in the phase.
    pub flops: u64,
}

impl PhaseCost {
    /// Achieved compute throughput as a fraction of the 2D-mode peak.
    /// Clamped to 1.0: work retired on the overlapping small 1D array
    /// can push raw throughput marginally past the 2D-mode peak.
    pub fn utilization(&self, arch: &ArchSpec) -> f64 {
        if self.latency == 0 {
            return 0.0;
        }
        let peak_per_cycle = arch.pes(Binding::Mode2D) as f64 * 2.0;
        (self.flops as f64 / (self.latency as f64 * peak_per_cycle)).min(1.0)
    }

    /// Operational intensity (FLOP / DRAM byte).
    pub fn intensity(&self) -> f64 {
        let b = self.traffic.total();
        if b == 0 {
            f64::INFINITY
        } else {
            self.flops as f64 / b as f64
        }
    }
}

/// Cost of a full single-layer cascade under a plan.
#[derive(Debug, Clone)]
pub struct LayerCost {
    pub cascade_name: String,
    pub variant_name: String,
    pub phases: Vec<PhaseCost>,
    /// End-to-end latency in cycles (respecting `pipelined`).
    pub latency: u64,
    pub flops: u64,
    pub traffic: Traffic,
}

impl LayerCost {
    pub fn latency_secs(&self, arch: &ArchSpec) -> f64 {
        self.latency as f64 / arch.cycles_per_sec()
    }

    pub fn intensity(&self) -> f64 {
        let b = self.traffic.total();
        if b == 0 {
            f64::INFINITY
        } else {
            self.flops as f64 / b as f64
        }
    }
}

/// Evaluate one fusion group.
fn eval_group(
    c: &Cascade,
    idx: &CascadeIndex,
    g: &FusionGroup,
    arch: &ArchSpec,
    opts: &ExecOptions,
) -> PhaseCost {
    let bindings = bind_group(c, g);
    let binding_of = |id: usize| {
        bindings.iter().find(|b| b.einsum == id).map(|b| b.binding).unwrap_or(Binding::Wide1D)
    };
    let passes = analyze_scope_with(c, idx, &g.einsums);
    let internal: Vec<&str> = g.internal_tensors.iter().map(|s| s.as_str()).collect();

    let mut cycles_2d = 0u64;
    let mut cycles_small = 0u64;
    let mut flops = 0u64;
    let mut traffic = Traffic::default();
    // Tensors already charged in this group (first consumer pays; later
    // consumers ride the same pass unless pass analysis says otherwise).
    let mut charged: Vec<&str> = Vec::new();

    let singleton = g.einsums.len() == 1;

    for &id in &g.einsums {
        let e = c.by_id(id).expect("group member");
        flops += e.flops();
        match binding_of(id) {
            Binding::Small1D => cycles_small += compute_cycles(e, arch, Binding::Small1D),
            b => cycles_2d += compute_cycles(e, arch, b),
        }

        if singleton {
            // Best-unfused accounting: all inputs in, output out.
            traffic.add(&unfused_traffic_with(idx, e));
            continue;
        }

        // Fused accounting: inputs.
        for op in &e.inputs {
            let name = op.tensor.name.as_str();
            if internal.contains(&name) {
                continue; // stays on-chip
            }
            let n_passes = passes.passes_of(name) as u64;
            if let Some(pos) = charged.iter().position(|&t| t == name) {
                let _ = pos; // already charged (with its pass count)
                continue;
            }
            charged.push(name);
            let bytes = op.tensor.bytes() * n_passes;
            if idx.is_shared(name) {
                traffic.inter_read += bytes;
            } else {
                traffic.intra_read += bytes;
            }
        }
        // Output: written iff it leaves the group — or if it needs
        // multiple passes even *inside* the group (X and LEX, paper
        // §VI-C.1: a pass boundary forces a spill and per-pass reloads;
        // "loaded multiple times").
        let out_name = e.output.name.as_str();
        let bytes = e.output.bytes();
        if !internal.contains(&out_name) {
            if idx.is_shared(out_name) {
                traffic.inter_write += bytes;
            } else {
                traffic.intra_write += bytes;
            }
        } else {
            let n_passes = passes.passes_of(out_name) as u64;
            if n_passes > 1 {
                traffic.inter_write += bytes;
                traffic.inter_read += bytes * (n_passes - 1);
            }
        }
    }

    if !singleton {
        apply_staging_spills(c, idx, g, arch, opts, &mut traffic);
        if g.rd_bridged {
            apply_rd_bridge_costs(c, g, &mut traffic);
        }
    }
    if opts.decode_state_io {
        apply_state_io(c, g, &mut traffic);
    }

    let mem_cycles = (traffic.total() as f64 / arch.bytes_per_cycle()).ceil() as u64;
    let latency = cycles_2d.max(cycles_small).max(mem_cycles);
    PhaseCost {
        einsums: g.einsums.clone(),
        cycles_2d,
        cycles_small,
        traffic,
        mem_cycles,
        latency,
        flops,
    }
}

/// MARCA-like full-extent staging: internal tensors staged at full
/// sequence extent spill to DRAM once the live set exceeds the buffer
/// (minus the resident weight working set). Spilled tensors pay a write
/// and a read of their full size (inter-Einsum traffic — they are
/// shared tensors).
fn apply_staging_spills(
    c: &Cascade,
    idx: &CascadeIndex,
    g: &FusionGroup,
    arch: &ArchSpec,
    opts: &ExecOptions,
    traffic: &mut Traffic,
) {
    if opts.staging != Staging::FullExtent {
        return;
    }
    let weights: u64 = g.einsums.iter().map(|&id| weight_bytes(c.by_id(id).unwrap())).sum();
    let budget = arch.buffer_bytes.saturating_sub(weights);
    // Walk members in order, tracking the live full-extent intermediates.
    let mut live: Vec<(&str, u64, usize)> = Vec::new(); // (name, bytes, last consumer)
    for &id in &g.einsums {
        let e = c.by_id(id).unwrap();
        live.retain(|(_, _, last)| *last >= id);
        if g.internal_tensors.iter().any(|t| t == &e.output.name) {
            let last = idx.consumers_of(&e.output.name).iter().max().copied().unwrap_or(id);
            live.push((e.output.name.as_str(), e.output.bytes(), last));
        }
        let occupancy: u64 = live.iter().map(|(_, b, _)| *b).sum();
        if occupancy > budget {
            // Spill the largest live tensor (write now, read back at its
            // consumer) until we fit.
            live.sort_by_key(|(_, b, _)| std::cmp::Reverse(*b));
            while live.iter().map(|(_, b, _)| *b).sum::<u64>() > budget && !live.is_empty() {
                let (_, bytes, _) = live.remove(0);
                traffic.inter_write += bytes;
                traffic.inter_read += bytes;
            }
        }
    }
}

/// Fully-fused RD bridges (§IV-D): partial products of the upstream
/// intermediate write to main memory and the downstream Einsum triggers
/// on final writes — the intermediate round-trips DRAM once. The
/// I-stationary streaming the bridge forces also constrains every
/// in-group GEMM's dataflow, spilling K-partial output tiles (the
/// "comparatively worse intra-Einsum traffic" of Figure 14).
fn apply_rd_bridge_costs(c: &Cascade, g: &FusionGroup, traffic: &mut Traffic) {
    use crate::fusion::FusionClass;
    for j in &g.joins {
        if j.class == Some(FusionClass::RD) {
            if let Some(up) = j.via.and_then(|via| c.by_id(via)) {
                let bytes = up.output.bytes();
                traffic.inter_write += bytes;
                traffic.inter_read += bytes;
            }
        }
    }
    for &id in &g.einsums {
        let e = c.by_id(id).unwrap();
        if e.is_gemm_like() {
            let bytes = e.output.bytes();
            traffic.intra_write += bytes;
            traffic.intra_read += bytes;
        }
    }
}

/// Decode-step state I/O: every recurrent/windowed tensor's live window
/// is loaded at step start and stored at step end (`H` and the conv tail
/// of `TX` are Mamba's "KV cache").
fn apply_state_io(c: &Cascade, g: &FusionGroup, traffic: &mut Traffic) {
    let mut seen: Vec<&str> = Vec::new();
    for &id in &g.einsums {
        let e = c.by_id(id).unwrap();
        for op in &e.inputs {
            if !op.is_recurrent() || seen.contains(&op.tensor.name.as_str()) {
                continue;
            }
            seen.push(&op.tensor.name);
            for (rank, acc) in op.tensor.ranks.iter().zip(&op.accesses) {
                if acc.is_recurrent() && rank.is_generational() {
                    // One generation of state per token in flight: the I
                    // extent of a decode cascade *is* the batch size.
                    let window = acc.lookback();
                    let per_gen = op.tensor.generation_bytes(&rank.name);
                    let bytes = per_gen * window * rank.extent;
                    traffic.inter_read += bytes;
                    traffic.inter_write += bytes;
                }
            }
        }
    }
}

/// Evaluate a full plan.
pub fn evaluate(
    c: &Cascade,
    plan: &FusionPlan,
    arch: &ArchSpec,
    opts: &ExecOptions,
) -> LayerCost {
    // Build the lookup index once; eval_group is the DSE inner loop.
    let idx = CascadeIndex::new(c);
    let phases: Vec<PhaseCost> =
        plan.groups.iter().map(|g| eval_group(c, &idx, g, arch, opts)).collect();
    let mut traffic = Traffic::default();
    let mut flops = 0u64;
    for p in &phases {
        traffic.add(&p.traffic);
        flops += p.flops;
    }
    let latency = if opts.pipelined {
        // Compute and memory streams overlap across group boundaries;
        // the small 1D array overlaps the 2D array throughout.
        let c2d: u64 = phases.iter().map(|p| p.cycles_2d).sum();
        let csm: u64 = phases.iter().map(|p| p.cycles_small).sum();
        let mem: u64 = phases.iter().map(|p| p.mem_cycles).sum();
        c2d.max(csm).max(mem)
    } else {
        phases.iter().map(|p| p.latency).sum()
    };
    LayerCost {
        cascade_name: c.name.clone(),
        variant_name: plan.variant_name.clone(),
        phases,
        latency,
        flops,
        traffic,
    }
}

/// The *ideal* cost for a plan: all inter-Einsum traffic removed, intra
/// kept (paper Figure 2 bottom / Figure 12 red line).
pub fn ideal_cost(c: &Cascade, plan: &FusionPlan, arch: &ArchSpec, opts: &ExecOptions) -> LayerCost {
    let mut cost = evaluate(c, plan, arch, opts);
    let mut traffic = Traffic::default();
    let mut flops = 0u64;
    for p in &mut cost.phases {
        p.traffic.inter_read = 0;
        p.traffic.inter_write = 0;
        p.mem_cycles = (p.traffic.total() as f64 / arch.bytes_per_cycle()).ceil() as u64;
        p.latency = p.cycles_2d.max(p.cycles_small).max(p.mem_cycles);
        traffic.add(&p.traffic);
        flops += p.flops;
    }
    cost.latency = if opts.pipelined {
        let c2d: u64 = cost.phases.iter().map(|p| p.cycles_2d).sum();
        let csm: u64 = cost.phases.iter().map(|p| p.cycles_small).sum();
        let mem: u64 = cost.phases.iter().map(|p| p.mem_cycles).sum();
        c2d.max(csm).max(mem)
    } else {
        cost.phases.iter().map(|p| p.latency).sum()
    };
    cost.traffic = traffic;
    cost.flops = flops;
    cost
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{baseline_plan, Baseline};
    use crate::cascade::{mamba1, ModelConfig};
    use crate::fusion::{stitch, FusionVariant};

    fn prefill(seq: u64, v: FusionVariant) -> LayerCost {
        let c = mamba1::build(&ModelConfig::mamba_370m(), seq, 1);
        let plan = stitch(&c, v);
        evaluate(&c, &plan, &ArchSpec::mambalaya(), &ExecOptions::default())
    }

    #[test]
    fn unfused_prefill_is_memory_bound_overall() {
        // Paper Fig 2a: unfused Mamba is fundamentally memory-bound.
        let cost = prefill(4096, FusionVariant::Unfused);
        let arch = ArchSpec::mambalaya();
        assert!(cost.intensity() < arch.machine_balance(), "oi = {}", cost.intensity());
    }

    #[test]
    fn fusion_strictly_reduces_inter_traffic() {
        let mut prev = u64::MAX;
        for v in FusionVariant::all() {
            let t = prefill(4096, v).traffic.inter();
            if v != FusionVariant::FullyFused {
                // Monotone through RI → RSb → RSp (fully-fused trades
                // some traffic back for single-group smoothness).
                assert!(t <= prev, "{v}: {t} > {prev}");
            }
            prev = t;
        }
    }

    #[test]
    fn fused_variants_speed_up_prefill() {
        let base = prefill(4096, FusionVariant::Unfused).latency as f64;
        let ri = prefill(4096, FusionVariant::RIOnly).latency as f64;
        let rsb = prefill(4096, FusionVariant::RIRSb).latency as f64;
        let rsp = prefill(4096, FusionVariant::RIRSbRSp).latency as f64;
        let ff = prefill(4096, FusionVariant::FullyFused).latency as f64;
        assert!(base / ri > 1.5, "RI speedup {}", base / ri);
        assert!(rsb <= ri);
        assert!(rsp <= rsb);
        // Fully fused is the best prefill strategy (paper Fig 12).
        assert!(ff <= rsp, "ff {ff} vs rsp {rsp}");
    }

    #[test]
    fn marca_like_spills_ssm_intermediates_on_long_prefill() {
        let c = mamba1::build(&ModelConfig::mamba_370m(), 16384, 1);
        let arch = ArchSpec::mambalaya();
        let marca = evaluate(
            &c,
            &baseline_plan(&c, Baseline::MarcaLike),
            &arch,
            &ExecOptions { staging: Staging::FullExtent, ..Default::default() },
        );
        let geens = evaluate(
            &c,
            &baseline_plan(&c, Baseline::GeensLike),
            &arch,
            &ExecOptions::default(),
        );
        // Fine-grained staging strictly beats full-extent staging.
        assert!(geens.latency < marca.latency);
        assert!(geens.traffic.inter() < marca.traffic.inter());
    }

    #[test]
    fn pipelining_improves_or_matches() {
        let c = mamba1::build(&ModelConfig::mamba_370m(), 4096, 1);
        let arch = ArchSpec::mambalaya();
        for v in FusionVariant::fused() {
            let plan = stitch(&c, v);
            let seq = evaluate(&c, &plan, &arch, &ExecOptions::default());
            let pipe = evaluate(
                &c,
                &plan,
                &arch,
                &ExecOptions { pipelined: true, ..Default::default() },
            );
            assert!(pipe.latency <= seq.latency, "{v}");
        }
    }

    #[test]
    fn ideal_cost_drops_inter_traffic() {
        let c = mamba1::build(&ModelConfig::mamba_370m(), 4096, 1);
        let plan = stitch(&c, FusionVariant::Unfused);
        let arch = ArchSpec::mambalaya();
        let ideal = ideal_cost(&c, &plan, &arch, &ExecOptions::default());
        assert_eq!(ideal.traffic.inter(), 0);
        let real = evaluate(&c, &plan, &arch, &ExecOptions::default());
        assert!(ideal.latency < real.latency);
    }

    #[test]
    fn decode_state_io_is_charged() {
        let c = mamba1::build(&ModelConfig::mamba_370m(), 1, 64);
        let plan = stitch(&c, FusionVariant::RIOnly);
        let arch = ArchSpec::mambalaya();
        let without = evaluate(&c, &plan, &arch, &ExecOptions::default());
        let with = evaluate(
            &c,
            &plan,
            &arch,
            &ExecOptions { decode_state_io: true, ..Default::default() },
        );
        assert!(with.traffic.total() > without.traffic.total());
    }
}
