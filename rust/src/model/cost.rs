//! Per-Einsum analytical costs: compute cycles under a binding, and
//! DRAM traffic under the algorithmic-minimum assumption the paper
//! states for its Timeloop runs ("sufficient buffering to achieve
//! perfect data reuse within each Einsum").

use crate::arch::{ArchSpec, Binding};
use crate::einsum::cascade::CascadeIndex;
use crate::einsum::{Cascade, EinsumSpec, TensorClass};

/// Traffic for one Einsum, split the way Table I / Figure 14 report it.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Traffic {
    /// Bytes read for tensors shared with other Einsums (intermediates).
    pub inter_read: u64,
    /// Bytes written for tensors shared with other Einsums.
    pub inter_write: u64,
    /// Bytes read for tensors unique to this Einsum (weights, true
    /// inputs).
    pub intra_read: u64,
    /// Bytes written for tensors unique to this Einsum (final outputs,
    /// spilled partial products).
    pub intra_write: u64,
}

impl Traffic {
    pub fn total(&self) -> u64 {
        self.inter_read + self.inter_write + self.intra_read + self.intra_write
    }

    pub fn reads(&self) -> u64 {
        self.inter_read + self.intra_read
    }

    pub fn writes(&self) -> u64 {
        self.inter_write + self.intra_write
    }

    pub fn inter(&self) -> u64 {
        self.inter_read + self.inter_write
    }

    pub fn intra(&self) -> u64 {
        self.intra_read + self.intra_write
    }

    pub fn add(&mut self, other: &Traffic) {
        self.inter_read += other.inter_read;
        self.inter_write += other.inter_write;
        self.intra_read += other.intra_read;
        self.intra_write += other.intra_write;
    }
}

/// Compute cycles for an Einsum bound to `binding` on `arch`.
///
/// Model: each PE retires one MAC (or one low-intensity op) per cycle
/// through its 6-stage pipelined functional unit (paper §V-A). The
/// mapper is assumed to find a near-optimal spatial mapping (K-splitting
/// and output tiling are both available on the store-and-forward array),
/// so utilization is limited only by the total work vs the PE count and
/// by the array fill latency.
pub fn compute_cycles(e: &EinsumSpec, arch: &ArchSpec, binding: Binding) -> u64 {
    let pes = arch.pes(binding);
    let work = if e.op.is_mulacc() {
        // MACs = points of the full iteration space.
        e.iteration_space().points()
    } else {
        e.op.elementwise_ops() * e.output.elements()
    };
    // Array fill/drain: one pass through the systolic dimension for 2D
    // mode, pipeline depth for the 1D arrays.
    let fill = match binding {
        Binding::Mode2D => arch.pe_2d_rows + arch.pe_2d_cols,
        Binding::Wide1D | Binding::Small1D => 6,
    };
    work.div_ceil(pes) + fill
}

/// Is a tensor "shared" (inter-Einsum) in the Table-I sense: produced by
/// some Einsum in the cascade, or consumed by more than one?
pub fn is_shared(c: &Cascade, name: &str) -> bool {
    if c.producers().contains_key(name) {
        return true;
    }
    c.consumers().get(name).map(|v| v.len() > 1).unwrap_or(false)
}

/// Algorithmic-minimum traffic for one Einsum executed *unfused*: every
/// input read once from DRAM, the output written once.
pub fn unfused_traffic(c: &Cascade, e: &EinsumSpec) -> Traffic {
    unfused_traffic_with(&CascadeIndex::new(c), e)
}

/// [`unfused_traffic`] with a prebuilt index (DSE hot path, §Perf).
pub fn unfused_traffic_with(idx: &CascadeIndex, e: &EinsumSpec) -> Traffic {
    let mut t = Traffic::default();
    // Inputs, deduplicated by tensor name (X·X reads X once).
    let mut seen: Vec<&str> = Vec::new();
    for op in &e.inputs {
        if seen.contains(&op.tensor.name.as_str()) {
            continue;
        }
        seen.push(&op.tensor.name);
        let bytes = op.tensor.bytes();
        if idx.is_shared(&op.tensor.name) {
            t.inter_read += bytes;
        } else {
            t.intra_read += bytes;
        }
    }
    let out_bytes = e.output.bytes();
    if idx.is_shared(&e.output.name) {
        t.inter_write += out_bytes;
    } else {
        t.intra_write += out_bytes;
    }
    t
}

/// Bytes of weights an Einsum reads (resident working set for buffer
/// booking).
pub fn weight_bytes(e: &EinsumSpec) -> u64 {
    let mut seen: Vec<&str> = Vec::new();
    let mut total = 0;
    for op in &e.inputs {
        if op.tensor.class == TensorClass::Weight && !seen.contains(&op.tensor.name.as_str()) {
            seen.push(&op.tensor.name);
            total += op.tensor.bytes();
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cascade::{mamba1, ModelConfig};

    #[test]
    fn gemm_cycles_scale_with_pes() {
        let cfg = ModelConfig::mamba_370m();
        let c = mamba1::build(&cfg, 256, 1);
        let arch = ArchSpec::mambalaya();
        let tx = c.by_id(7).unwrap(); // I×E×D GEMM
        let macs = 256 * 1024 * 2048;
        let cy2d = compute_cycles(tx, &arch, Binding::Mode2D);
        assert_eq!(cy2d, macs / 65_536 + 512);
        let cy1d = compute_cycles(tx, &arch, Binding::Small1D);
        assert!(cy1d > cy2d * 100);
    }

    #[test]
    fn elementwise_cycles() {
        let cfg = ModelConfig::mamba_370m();
        let c = mamba1::build(&cfg, 64, 1);
        let arch = ArchSpec::mambalaya();
        let sq = c.by_id(2).unwrap(); // I×E elementwise
        let cy = compute_cycles(sq, &arch, Binding::Wide1D);
        assert_eq!(cy, (64u64 * 1024).div_ceil(8192) + 6);
    }

    #[test]
    fn unfused_traffic_classifies_inter_vs_intra() {
        let cfg = ModelConfig::mamba_370m();
        let c = mamba1::build(&cfg, 64, 1);
        let tx = c.by_id(7).unwrap();
        let t = unfused_traffic(&c, tx);
        // GX (intermediate) is inter; Wtx (weight) is intra.
        assert_eq!(t.inter_read, 64 * 1024 * 2);
        assert_eq!(t.intra_read, 1024 * 2048 * 2);
        // TX output is consumed later → inter write.
        assert_eq!(t.inter_write, 64 * 2048 * 2);
        assert_eq!(t.intra_write, 0);
    }

    #[test]
    fn duplicate_operand_reads_once() {
        let cfg = ModelConfig::mamba_370m();
        let c = mamba1::build(&cfg, 64, 1);
        let sq = c.by_id(2).unwrap(); // SQ = X·X
        let t = unfused_traffic(&c, sq);
        assert_eq!(t.inter_read, 64 * 1024 * 2); // X once, not twice
    }

    #[test]
    fn weight_bytes_of_inproj() {
        let cfg = ModelConfig::mamba_370m();
        let c = mamba1::build(&cfg, 64, 1);
        assert_eq!(weight_bytes(c.by_id(7).unwrap()), 1024 * 2048 * 2);
        assert_eq!(weight_bytes(c.by_id(2).unwrap()), 0);
    }
}
