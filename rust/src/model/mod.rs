//! Analytical accelerator model — the Timeloop-replacement substrate
//! (see DESIGN.md §4 for the substitution rationale).
//!
//! * [`cost`] — per-Einsum compute cycles and algorithmic-minimum traffic;
//! * [`passes`] — FuseMax-style pass analysis (why X/LEX reload);
//! * [`exec`] — group/layer evaluation into phase timelines.

pub mod cost;
pub mod exec;
pub mod mapper;
pub mod mapping;
pub mod passes;

pub use cost::{compute_cycles, unfused_traffic, Traffic};
pub use exec::{evaluate, ideal_cost, ExecOptions, LayerCost, PhaseCost};
pub use mapper::{map_cascade, search as map_search, Mapped, MapperOptions};
pub use mapping::{LoopLevel, Mapping};
pub use passes::{analyze_scope, analyze_scope_with, PassAnalysis};
