//! Mappings: loop nests with per-rank tiling for a single Einsum on a
//! two-level memory hierarchy (DRAM → on-chip buffer → PEs).
//!
//! This is the representation the [`super::mapper`] searches — the
//! Timeloop-substitute substrate (DESIGN.md §4). A mapping fixes, for
//! each rank of the Einsum's iteration space, a *tile size* (the extent
//! kept resident per buffer refill) and a *loop order* over the outer
//! (DRAM-level) tile loops. Traffic follows the classical reuse rule:
//! an operand is refetched once per iteration of every outer loop over
//! a rank it does **not** index; outputs with reduction ranks outside
//! the innermost position pay partial-sum write/read round-trips.

use std::collections::BTreeMap;

use crate::einsum::{EinsumSpec, TensorSpec};

/// One outer-loop level: rank name + number of tiles (trip count).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LoopLevel {
    pub rank: String,
    pub trips: u64,
}

/// A complete mapping for one Einsum.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Mapping {
    /// Outer (DRAM-level) loops, outermost first. Ranks with one trip
    /// are omitted — their full extent stays buffer-resident.
    pub outer: Vec<LoopLevel>,
    /// Tile size per rank (full extent for ranks absent from `outer`).
    pub tiles: BTreeMap<String, u64>,
}

impl Mapping {
    /// The trivial mapping: everything in one tile (valid only if the
    /// buffer can hold all operands — the paper's "algorithmic
    /// minimum" assumption).
    pub fn untiled(e: &EinsumSpec) -> Mapping {
        let tiles = e
            .iteration_space()
            .ranks()
            .iter()
            .map(|r| (r.name.clone(), r.extent))
            .collect();
        Mapping { outer: Vec::new(), tiles }
    }

    /// Tile size of a rank (1 when the rank is unknown).
    pub fn tile(&self, rank: &str) -> u64 {
        self.tiles.get(rank).copied().unwrap_or(1)
    }

    /// Buffer-resident bytes of one operand tile.
    pub fn operand_tile_bytes(&self, t: &TensorSpec) -> u64 {
        let elems: u64 = t.ranks.iter().map(|r| self.tile(&r.name).min(r.extent)).product();
        elems * t.dtype.bytes()
    }

    /// Total buffer occupancy: sum of operand + output tiles.
    pub fn buffer_bytes(&self, e: &EinsumSpec) -> u64 {
        let mut seen: Vec<&str> = Vec::new();
        let mut total = self.operand_tile_bytes(&e.output);
        for op in &e.inputs {
            if seen.contains(&op.tensor.name.as_str()) {
                continue;
            }
            seen.push(&op.tensor.name);
            total += self.operand_tile_bytes(&op.tensor);
        }
        total
    }

    /// DRAM traffic (bytes) this mapping incurs for the Einsum.
    ///
    /// For each input operand: `tensor_bytes × Π trips(outer ranks the
    /// operand does not index)` — outer loops over foreign ranks force
    /// refetch. For the output: one write of the full tensor, plus a
    /// write+read round-trip per extra visit when a *reduction* rank's
    /// outer loop sits outside an output rank's loop (partial sums
    /// leave the chip).
    pub fn dram_traffic(&self, e: &EinsumSpec) -> u64 {
        let mut total = 0u64;
        let mut seen: Vec<&str> = Vec::new();
        for op in &e.inputs {
            if seen.contains(&op.tensor.name.as_str()) {
                continue;
            }
            seen.push(&op.tensor.name);
            let mut fetches = 1u64;
            for lvl in &self.outer {
                if !op.tensor.has_rank(&lvl.rank) {
                    fetches = fetches.saturating_mul(lvl.trips);
                }
            }
            total += op.tensor.bytes().saturating_mul(fetches);
        }
        // Output: visits = product of trips of reduction-rank loops that
        // are *outside* the innermost output-rank loop position. With
        // output-stationary orders (reduction innermost) this is 1.
        let red: Vec<&str> = e.reduction_ranks.iter().map(|r| r.name.as_str()).collect();
        let innermost_out = self
            .outer
            .iter()
            .rposition(|l| e.output.has_rank(&l.rank))
            .map(|i| i as i64)
            .unwrap_or(-1);
        let mut visits = 1u64;
        for (pos, lvl) in self.outer.iter().enumerate() {
            if red.contains(&lvl.rank.as_str()) && (pos as i64) < innermost_out {
                visits = visits.saturating_mul(lvl.trips);
            }
        }
        // First visit: one write. Each extra visit: read + write of the
        // partial output.
        total += e.output.bytes() * (2 * visits - 1);
        total
    }

    /// Is this mapping output-stationary (no partial-sum spills)?
    pub fn output_stationary(&self, e: &EinsumSpec) -> bool {
        let red: Vec<&str> = e.reduction_ranks.iter().map(|r| r.name.as_str()).collect();
        let innermost_out = self
            .outer
            .iter()
            .rposition(|l| e.output.has_rank(&l.rank))
            .map(|i| i as i64)
            .unwrap_or(-1);
        !self
            .outer
            .iter()
            .enumerate()
            .any(|(pos, l)| red.contains(&l.rank.as_str()) && (pos as i64) < innermost_out)
    }
}

impl std::fmt::Display for Mapping {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.outer.is_empty() {
            write!(f, "untiled")
        } else {
            let loops: Vec<String> =
                self.outer.iter().map(|l| format!("{}/{}", l.rank, l.trips)).collect();
            write!(f, "for {}", loops.join(" ⋅ "))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cascade::{mamba1, ModelConfig};

    fn tx_einsum() -> EinsumSpec {
        mamba1::build(&ModelConfig::mamba_370m(), 256, 1).by_id(7).unwrap().clone()
    }

    #[test]
    fn untiled_holds_everything_and_hits_minimum() {
        let e = tx_einsum();
        let m = Mapping::untiled(&e);
        // Algorithmic minimum: each tensor once.
        let min: u64 = (256 * 1024 + 1024 * 2048 + 256 * 2048) * 2;
        assert_eq!(m.dram_traffic(&e), min);
        assert!(m.output_stationary(&e));
        assert_eq!(m.buffer_bytes(&e), min); // everything resident
    }

    #[test]
    fn foreign_rank_loops_force_refetch() {
        // Tiling I into 4 tiles forces the weight (no I rank) to be
        // refetched 4× unless it stays resident — our model charges the
        // refetch; keeping it resident is expressed by trips=1.
        let e = tx_einsum();
        let mut tiles = Mapping::untiled(&e).tiles;
        tiles.insert("I".into(), 64); // 256/64 = 4 trips
        let m = Mapping {
            outer: vec![LoopLevel { rank: "I".into(), trips: 4 }],
            tiles,
        };
        let w_bytes = 1024 * 2048 * 2u64;
        let base = Mapping::untiled(&e).dram_traffic(&e);
        assert_eq!(m.dram_traffic(&e), base + 3 * w_bytes);
        // Buffer shrinks accordingly (GX and TX tiles are 4× smaller).
        assert!(m.buffer_bytes(&e) < Mapping::untiled(&e).buffer_bytes(&e));
    }

    #[test]
    fn reduction_outside_output_spills_partials() {
        // Loop order (E outer, I inner): E is a reduction rank placed
        // outside the output loop → partial sums round-trip.
        let e = tx_einsum();
        let mut tiles = Mapping::untiled(&e).tiles;
        tiles.insert("E".into(), 256); // 4 trips
        tiles.insert("I".into(), 64); // 4 trips
        let m = Mapping {
            outer: vec![
                LoopLevel { rank: "E".into(), trips: 4 },
                LoopLevel { rank: "I".into(), trips: 4 },
            ],
            tiles,
        };
        assert!(!m.output_stationary(&e));
        let out_bytes = 256 * 2048 * 2u64;
        // visits = 4 → output traffic = (2·4 − 1)·out vs 1·out.
        let os = Mapping {
            outer: vec![
                LoopLevel { rank: "I".into(), trips: 4 },
                LoopLevel { rank: "E".into(), trips: 4 },
            ],
            tiles: m.tiles.clone(),
        };
        assert!(os.output_stationary(&e));
        assert_eq!(m.dram_traffic(&e) - os.dram_traffic(&e), 6 * out_bytes);
    }
}
