//! # Mambalaya
//!
//! A from-scratch reproduction of *"Mambalaya: Einsum-Based Fusion
//! Optimizations on State-Space Models"* (CS.AR 2026): the
//! extended-Einsum formulation of Mamba, the RI/RSb/RSp/RD fusion
//! taxonomy with greedy stitching, an analytical accelerator model of
//! the Mambalaya architecture and its baselines, and a functional
//! three-layer Rust + JAX + Pallas serving stack (AOT via xla/PJRT).
//!
//! Layer map (see `DESIGN.md` for the full inventory):
//! * [`einsum`] / [`cascade`] — the extended-Einsum IR and the concrete
//!   Mamba-1/Mamba-2/Transformer cascades;
//! * [`fusion`] — classification + greedy stitching (the paper's core);
//! * [`arch`] / [`model`] / [`traffic`] / [`roofline`] / [`workload`] —
//!   the analytical accelerator substrate (Timeloop substitute);
//! * [`planner`] — workload-adaptive fusion-plan selection bridging the
//!   analytical model into the serving loop: per-tick
//!   [`planner::WorkloadFeatures`] → shape-bucketed
//!   [`planner::CostModel`] evaluation of every candidate
//!   [`planner::PlanChoice`] → [`planner::Planner`] policy (static /
//!   adaptive / autotuned [`planner::PlanTable`], with dwell
//!   hysteresis); the choice dispatches through
//!   [`runtime::Executor::step_planned_into`] and its quality is
//!   observable in the deterministic modeled-cost counters;
//! * [`report`] — regenerates every paper table and figure;
//! * [`runtime`] / [`coordinator`] — the serving stack (python never
//!   runs on the request path). The runtime's [`runtime::Executor`]
//!   exposes prefill, decode, and the varlen mixed call in two forms:
//!   allocating `step_mixed`, and the zero-copy `step_mixed_into`
//!   which advances caller-owned state slabs **in place** through a
//!   per-tick row plan and reusable [`runtime::Workspace`] buffers.
//!   The coordinator drives **continuous batching with chunked
//!   prefill**: each [`coordinator::Scheduler`] tick is one mixed
//!   engine invocation combining one decode token per running sequence
//!   with prefill chunks from waiting prompts, bounded by the
//!   [`coordinator::BatchPolicy`] knobs `chunk_tokens` (chunk size; 0 =
//!   monolithic) and `token_budget` (per-tick token cost cap). All
//!   recurrent state lives resident in the **sharded**
//!   [`coordinator::StateArena`] (stable free-list rows addressed by
//!   globally stable [`coordinator::SlotHandle`]s, engine layout), so
//!   a prompt may span many ticks before its first sampled token while
//!   decode never stalls, and a steady-state decode tick moves zero
//!   state bytes — the deterministic `bytes_gathered`/`bytes_scattered`
//!   counters in [`coordinator::Metrics`] prove it per run. The
//!   slot-aware router ([`coordinator::ShardMap`] +
//!   [`coordinator::RouterPolicy`]) places requests by least-load and
//!   live-migrates in-flight requests between worker shards by moving
//!   their resident rows (one counted `bytes_migrated` transfer, never
//!   a re-prefill);
//! * [`util`] / [`prop`] / [`bench_util`] — offline-build stand-ins for
//!   clap/serde/proptest/criterion (plus vendored `anyhow`/`xla` shims
//!   under `rust/vendor/`).
//!
//! `EXPERIMENTS.md` records paper-vs-measured for every experiment.

pub mod arch;
pub mod bench_util;
pub mod cascade;
pub mod coordinator;
pub mod einsum;
pub mod fusion;
pub mod model;
pub mod planner;
pub mod prop;
pub mod report;
pub mod roofline;
pub mod runtime;
pub mod traffic;
pub mod util;
pub mod workload;
