//! # Mambalaya
//!
//! A from-scratch reproduction of *"Mambalaya: Einsum-Based Fusion
//! Optimizations on State-Space Models"* (CS.AR 2026): the
//! extended-Einsum formulation of Mamba, the RI/RSb/RSp/RD fusion
//! taxonomy with greedy stitching, an analytical accelerator model of
//! the Mambalaya architecture and its baselines, and a functional
//! three-layer Rust + JAX + Pallas serving stack (AOT via xla/PJRT).
//!
//! Layer map (see `DESIGN.md` for the full inventory):
//! * [`einsum`] / [`cascade`] — the extended-Einsum IR and the concrete
//!   Mamba-1/Mamba-2/Transformer cascades;
//! * [`fusion`] — classification + greedy stitching (the paper's core);
//! * [`arch`] / [`model`] / [`traffic`] / [`roofline`] / [`workload`] —
//!   the analytical accelerator substrate (Timeloop substitute);
//! * [`planner`] — workload-adaptive fusion-plan selection bridging the
//!   analytical model into the serving loop: per-tick
//!   [`planner::WorkloadFeatures`] → shape-bucketed
//!   [`planner::CostModel`] evaluation of every candidate
//!   [`planner::PlanChoice`] → [`planner::Planner`] policy (static /
//!   adaptive / autotuned [`planner::PlanTable`], with dwell
//!   hysteresis); the candidate set is masked from the engine's
//!   capability report ([`planner::Planner::apply_caps`]), the choice
//!   rides in each tick's [`runtime::LaunchSpec`], and its quality is
//!   observable in the deterministic modeled-cost counters;
//! * [`report`] — regenerates every paper table and figure;
//! * [`runtime`] / [`coordinator`] — the serving stack (python never
//!   runs on the request path). The runtime's [`runtime::Executor`] is
//!   a typed launch surface: compiled primitives (prefill / decode)
//!   plus **one entry point** [`runtime::Executor::launch`] over a
//!   validated [`runtime::LaunchSpec`] — a [`runtime::MixedBatch`] of
//!   per-row [`runtime::Segment`]s (distinct-rows contract enforced at
//!   construction), [`runtime::StateSlabs`] carrying stride and a
//!   [`runtime::Donation`] annotation (PJRT buffer-donation ready),
//!   the plan choice, and reusable [`runtime::Workspace`] buffers
//!   whose counters price staged bytes, padded rows and device calls.
//!   What an engine can fuse is *declared* in
//!   [`runtime::EngineCaps`] and negotiated at scheduler
//!   construction; engines without a varlen kernel inherit the default
//!   compiled-primitive decomposition, and the legacy step methods are
//!   deprecated wrappers over `launch`.
//!   The coordinator drives **continuous batching with chunked
//!   prefill**: each [`coordinator::Scheduler`] tick is one engine
//!   launch combining one decode token per running sequence
//!   with prefill chunks from waiting prompts, bounded by the
//!   [`coordinator::BatchPolicy`] knobs `chunk_tokens` (chunk size; 0 =
//!   monolithic) and `token_budget` (per-tick token cost cap). All
//!   recurrent state lives resident in the **sharded**
//!   [`coordinator::StateArena`] (stable free-list rows addressed by
//!   globally stable [`coordinator::SlotHandle`]s, engine layout), so
//!   a prompt may span many ticks before its first sampled token while
//!   decode never stalls, and a steady-state decode tick moves zero
//!   state bytes — the deterministic `bytes_gathered`/`bytes_scattered`
//!   counters in [`coordinator::Metrics`] prove it per run. The
//!   slot-aware router ([`coordinator::ShardMap`] +
//!   [`coordinator::RouterPolicy`]) places requests by least-load and
//!   live-migrates in-flight requests between worker shards by moving
//!   their resident rows (one counted `bytes_migrated` transfer, never
//!   a re-prefill);
//! * [`frontend`] — the network serving front-end above the
//!   coordinator: a std-only length-prefixed wire protocol with a
//!   version-carrying Hello handshake ([`frontend::wire`]), a TCP
//!   accept loop with per-connection streaming token responses
//!   ([`frontend::serve`] / [`frontend::run_client`]), and SLO-aware
//!   admission control ([`frontend::AdmissionController`]): priority
//!   classes with per-class token-budget shares, deadline tracking on
//!   the deterministic tick histograms, and queue-depth/load shedding
//!   from the same signals the planner's `WorkloadFeatures` read. A
//!   shed is a terminal [`frontend::Frame::Error`] on the socket and a
//!   reconciled `[Submit, Failed]` span in the trace — the
//!   exactly-one-terminal-message contract holds end to end over the
//!   wire;
//! * [`obs`] — deterministic observability over the serving stack:
//!   typed [`obs::TraceEvent`] request-lifecycle records stamped with
//!   the scheduler's tick clock in bounded pre-allocated
//!   [`obs::TraceRing`]s (zero-alloc steady state, counted drops),
//!   per-request [`obs::Span`] stitching across migration/salvage
//!   hops with Chrome-trace/Perfetto export ([`obs::chrome_trace`]),
//!   mergeable log2 [`obs::Histogram`] latency percentiles (tick
//!   units gateable, wall units reporting), and the
//!   [`obs::reconcile`] property that forces trace sums to equal the
//!   traffic counters bit-for-bit in every CI gate;
//! * [`verify`] — the static verifier over the analytical layer
//!   (`mambalaya verify`, CI-gated): rebuilds each cascade's dataflow
//!   DAG and proves every [`planner::PlanChoice`] legal (convex groups,
//!   acyclic condensed graph, honest join provenance), recomputes
//!   per-group live-set traffic against [`model::evaluate`]'s byte
//!   accounting (the cost-model drift detector), derives per-plan
//!   `donation_safe` verdicts for [`runtime::EngineCaps`], and lints
//!   the source tree for repo invariants (wall-clock allowlist, bare
//!   hot-path unwraps, deprecated executor calls, unregistered tests);
//! * [`util`] / [`prop`] / [`bench_util`] — offline-build stand-ins for
//!   clap/serde/proptest/criterion (plus vendored `anyhow`/`xla` shims
//!   under `rust/vendor/`).
//!
//! `EXPERIMENTS.md` records paper-vs-measured for every experiment.

pub mod arch;
pub mod bench_util;
pub mod cascade;
pub mod coordinator;
pub mod einsum;
pub mod frontend;
pub mod fusion;
pub mod model;
pub mod obs;
pub mod planner;
pub mod prop;
pub mod report;
pub mod roofline;
pub mod runtime;
pub mod traffic;
pub mod util;
pub mod verify;
pub mod workload;
