//! Session-keyed recurrent-state snapshot cache.
//!
//! Mamba's per-sequence state is a *fixed-size* compressed summary of
//! everything the sequence has seen — not an ever-growing KV cache —
//! which makes prefix caching trivial for SSMs: a whole conversation
//! compresses to one `state_bytes_per_seq` arena row. On request
//! completion the scheduler may copy that row out here, keyed by
//! session id, together with the *history* (prompt ++ generated
//! tokens) the state summarizes. A follow-up turn whose prompt starts
//! with that history attaches the snapshot via the arena's
//! `attach_row` splice and prefills **only the new tokens**.
//!
//! `fork()` is copy-on-write: N best-of-N / parallel-sampling decodes
//! register N session keys against one refcounted payload
//! (`Rc<SnapshotPayload>`), so a fan-out adds zero cached bytes — the
//! counted copy happens on each attach, exactly once per decode, same
//! as a migration attach.
//!
//! Eviction is LRU over a configurable **byte budget** measured on
//! the unique-payload gauge (shared fork payloads count once). All
//! cache activity is mirrored into `Metrics`/`TrafficSnapshot` by the
//! scheduler (`snapshots_stored`, `snapshot_hits`, `snapshot_forks`,
//! `snapshot_bytes_restored`, `prefill_tokens_skipped`,
//! `snapshot_evictions`, and the `snapshot_bytes_cached` gauge) so
//! the bench gate can assert the skip arithmetic deterministically.
//!
//! The cache is single-threaded state owned by one scheduler (the
//! server pins every session to one shard), so plain `Rc` is correct;
//! nothing here crosses a thread boundary.

use std::collections::BTreeMap;
use std::rc::Rc;

/// One cached state payload, sequence-major, same layout as
/// `MigrationPacket`: `conv` is `n_layer * conv_per_layer` floats,
/// `ssm` is `n_layer * ssm_per_layer` floats.
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotPayload {
    pub conv: Vec<f32>,
    pub ssm: Vec<f32>,
}

impl SnapshotPayload {
    /// Bytes this payload occupies (f32 elements × 4) — matches
    /// `StateArena::bytes_per_seq()` for same-manifest payloads.
    pub fn state_bytes(&self) -> u64 {
        ((self.conv.len() + self.ssm.len()) * 4) as u64
    }
}

/// A successful cache lookup: the payload to attach and how much of
/// the submitted prompt it already covers.
#[derive(Debug, Clone)]
pub struct SnapshotHit {
    /// Tokens of the new prompt already summarized by the payload —
    /// the prefill cursor starts here.
    pub history_len: usize,
    pub payload: Rc<SnapshotPayload>,
}

/// Snapshot-cache tuning knobs.
#[derive(Debug, Clone, Copy)]
pub struct SnapshotConfig {
    /// LRU byte budget over unique payload bytes. `0` disables
    /// caching entirely (every `store` is immediately evicted).
    pub byte_budget: u64,
}

impl Default for SnapshotConfig {
    fn default() -> Self {
        // 64 MiB — thousands of rows for the bench-scale manifests,
        // small enough that real deployments will want to raise it.
        SnapshotConfig { byte_budget: 64 << 20 }
    }
}

#[derive(Debug)]
struct Entry {
    payload: Rc<SnapshotPayload>,
    /// The token history the payload summarizes (prompt ++ fed-back
    /// generated tokens). A follow-up hits iff its prompt strictly
    /// extends this.
    history: Vec<i32>,
    /// LRU clock stamp of the last store/lookup/fork touch.
    touched: u64,
}

/// Session-keyed LRU cache of recurrent-state snapshots. See the
/// module docs for semantics.
#[derive(Debug)]
pub struct SnapshotCache {
    entries: BTreeMap<u64, Entry>,
    config: SnapshotConfig,
    /// Monotone logical clock driving LRU ordering.
    clock: u64,
    /// Gauge: unique payload bytes resident (fork-shared payloads
    /// counted once).
    resident: u64,
    /// Monotone total of entries evicted by the byte budget.
    evictions: u64,
}

impl SnapshotCache {
    pub fn new(config: SnapshotConfig) -> SnapshotCache {
        SnapshotCache {
            entries: BTreeMap::new(),
            config,
            clock: 0,
            resident: 0,
            evictions: 0,
        }
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Bytes `payload` contributes to the unique-bytes gauge given the
    /// rest of the cache: zero if any *other* entry shares the same
    /// allocation (fork), its size otherwise.
    fn unique_bytes(&self, session: u64, payload: &Rc<SnapshotPayload>) -> u64 {
        let shared = self
            .entries
            .iter()
            .any(|(&s, e)| s != session && Rc::ptr_eq(&e.payload, payload));
        if shared {
            0
        } else {
            payload.state_bytes()
        }
    }

    fn remove_entry(&mut self, session: u64) -> Option<Entry> {
        let e = self.entries.remove(&session)?;
        self.resident -= self.unique_bytes(session, &e.payload);
        Some(e)
    }

    /// Evict least-recently-touched entries until the unique-bytes
    /// gauge fits the budget. With `byte_budget == 0` this empties the
    /// cache (caching disabled). Evicting one member of a fork family
    /// frees nothing until the last member goes — the loop keeps
    /// evicting, so the budget always ends respected.
    fn evict_to_budget(&mut self) {
        while self.resident > self.config.byte_budget {
            let Some((&victim, _)) = self.entries.iter().min_by_key(|(_, e)| e.touched) else {
                break;
            };
            self.remove_entry(victim);
            self.evictions += 1;
        }
        if self.config.byte_budget == 0 && !self.entries.is_empty() {
            // resident can be 0 while fork-only entries remain; a zero
            // budget still means "cache nothing".
            let victims: Vec<u64> = self.entries.keys().copied().collect();
            for v in victims {
                self.remove_entry(v);
                self.evictions += 1;
            }
        }
    }

    /// Store a completed request's state for `session`, replacing any
    /// prior snapshot for that session, then enforce the byte budget.
    /// Under a budget smaller than one payload the fresh entry itself
    /// is evicted — `store` never over-commits the budget.
    pub fn store(&mut self, session: u64, history: Vec<i32>, conv: Vec<f32>, ssm: Vec<f32>) {
        self.remove_entry(session);
        let payload = Rc::new(SnapshotPayload { conv, ssm });
        self.resident += payload.state_bytes();
        let touched = self.tick();
        self.entries.insert(session, Entry { payload, history, touched });
        self.evict_to_budget();
    }

    /// Copy-on-write fork: register `child` against `parent`'s payload
    /// and history. O(history) for the token clone, O(1) for the state
    /// (an `Rc` clone — zero new cached bytes). Returns `false` if the
    /// parent has no snapshot or the child key is taken.
    pub fn fork(&mut self, parent: u64, child: u64) -> bool {
        if parent == child || self.entries.contains_key(&child) {
            return false;
        }
        let Some(p) = self.entries.get(&parent) else {
            return false;
        };
        let payload = Rc::clone(&p.payload);
        let history = p.history.clone();
        let touched = self.tick();
        self.entries.insert(child, Entry { payload, history, touched });
        // Shared payload: the unique-bytes gauge is unchanged, so the
        // budget cannot newly overflow; no eviction pass needed.
        true
    }

    /// Look up `session` for a follow-up `prompt`. Hits iff the prompt
    /// *strictly* extends the stored history (equal-length prompts
    /// would leave zero tokens to prefill — the engine needs at least
    /// one new token to produce a next-token distribution, so that is
    /// a miss). A hit refreshes the LRU stamp and returns an owned
    /// handle to the refcounted payload.
    pub fn lookup(&mut self, session: u64, prompt: &[i32]) -> Option<SnapshotHit> {
        let stamp = self.clock + 1;
        let e = self.entries.get_mut(&session)?;
        let h = e.history.len();
        if prompt.len() <= h || prompt[..h] != e.history[..] {
            return None;
        }
        e.touched = stamp;
        self.clock = stamp;
        Some(SnapshotHit { history_len: h, payload: Rc::clone(&e.payload) })
    }

    /// Drop `session`'s snapshot (not counted as an eviction).
    pub fn remove(&mut self, session: u64) -> bool {
        self.remove_entry(session).is_some()
    }

    /// Replace the byte budget and immediately re-enforce it.
    pub fn set_budget(&mut self, byte_budget: u64) {
        self.config.byte_budget = byte_budget;
        self.evict_to_budget();
    }

    pub fn budget(&self) -> u64 {
        self.config.byte_budget
    }

    /// Gauge: unique payload bytes resident.
    pub fn resident_bytes(&self) -> u64 {
        self.resident
    }

    /// Monotone total of budget evictions.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn contains(&self, session: u64) -> bool {
        self.entries.contains_key(&session)
    }

    /// The stored history for `session` (tests / diagnostics).
    pub fn history(&self, session: u64) -> Option<&[i32]> {
        self.entries.get(&session).map(|e| e.history.as_slice())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payload(tag: f32, n: usize) -> (Vec<f32>, Vec<f32>) {
        (vec![tag; n], vec![tag + 0.5; n])
    }

    #[test]
    fn store_lookup_strict_prefix() {
        let mut c = SnapshotCache::new(SnapshotConfig::default());
        let (conv, ssm) = payload(1.0, 4);
        c.store(7, vec![1, 2, 3], conv.clone(), ssm.clone());
        assert_eq!(c.len(), 1);
        assert_eq!(c.resident_bytes(), 8 * 4);
        assert_eq!(c.history(7), Some(&[1, 2, 3][..]));

        // Strict extension hits and carries the payload bit-identically.
        let hit = c.lookup(7, &[1, 2, 3, 4]).expect("strict extension hits");
        assert_eq!(hit.history_len, 3);
        assert_eq!(hit.payload.conv, conv);
        assert_eq!(hit.payload.ssm, ssm);

        // Equal prompt, divergent prompt, short prompt, unknown session:
        // all misses.
        assert!(c.lookup(7, &[1, 2, 3]).is_none(), "equal prompt leaves nothing to prefill");
        assert!(c.lookup(7, &[1, 9, 3, 4]).is_none());
        assert!(c.lookup(7, &[1, 2]).is_none());
        assert!(c.lookup(8, &[1, 2, 3, 4]).is_none());
    }

    #[test]
    fn store_replaces_prior_snapshot() {
        let mut c = SnapshotCache::new(SnapshotConfig::default());
        let (conv, ssm) = payload(1.0, 4);
        c.store(7, vec![1], conv, ssm);
        let (conv2, ssm2) = payload(2.0, 4);
        c.store(7, vec![1, 2], conv2.clone(), ssm2);
        assert_eq!(c.len(), 1);
        assert_eq!(c.resident_bytes(), 8 * 4, "old payload bytes released");
        assert_eq!(c.lookup(7, &[1, 2, 9]).unwrap().payload.conv, conv2);
    }

    #[test]
    fn fork_shares_payload_bytes() {
        let mut c = SnapshotCache::new(SnapshotConfig::default());
        let (conv, ssm) = payload(3.0, 8);
        c.store(1, vec![5, 6], conv, ssm);
        let before = c.resident_bytes();
        assert!(c.fork(1, 2));
        assert!(c.fork(1, 3));
        assert_eq!(c.len(), 3);
        assert_eq!(c.resident_bytes(), before, "forks add zero cached bytes");
        // Children hit independently with the shared payload.
        let h2 = c.lookup(2, &[5, 6, 7]).unwrap();
        let h3 = c.lookup(3, &[5, 6, 8]).unwrap();
        assert!(Rc::ptr_eq(&h2.payload, &h3.payload));
        // Bad forks: unknown parent, taken child, self-fork.
        assert!(!c.fork(99, 4));
        assert!(!c.fork(1, 2));
        assert!(!c.fork(1, 1));
    }

    #[test]
    fn fork_bytes_survive_until_last_ref() {
        let mut c = SnapshotCache::new(SnapshotConfig::default());
        let (conv, ssm) = payload(3.0, 8);
        c.store(1, vec![5], conv, ssm);
        let bytes = c.resident_bytes();
        assert!(c.fork(1, 2));
        assert!(c.remove(1), "dropping the parent keeps the shared payload");
        assert_eq!(c.resident_bytes(), bytes);
        assert!(c.remove(2));
        assert_eq!(c.resident_bytes(), 0, "last ref releases the bytes");
        assert_eq!(c.evictions(), 0, "explicit removes are not evictions");
    }

    #[test]
    fn lru_eviction_respects_byte_budget() {
        // Each payload is 8 f32 = 32 bytes; budget fits exactly two.
        let mut c = SnapshotCache::new(SnapshotConfig { byte_budget: 64 });
        for s in 0..2u64 {
            let (conv, ssm) = payload(s as f32, 4);
            c.store(s, vec![s as i32], conv, ssm);
        }
        assert_eq!(c.resident_bytes(), 64);
        // Touch session 0 so session 1 becomes the LRU victim.
        assert!(c.lookup(0, &[0, 1]).is_some());
        let (conv, ssm) = payload(9.0, 4);
        c.store(2, vec![9], conv, ssm);
        assert_eq!(c.len(), 2);
        assert!(c.contains(0) && c.contains(2) && !c.contains(1));
        assert_eq!(c.resident_bytes(), 64);
        assert_eq!(c.evictions(), 1);
    }

    #[test]
    fn tiny_budget_evicts_fresh_store() {
        let mut c = SnapshotCache::new(SnapshotConfig { byte_budget: 8 });
        let (conv, ssm) = payload(1.0, 4);
        c.store(7, vec![1], conv, ssm);
        assert!(c.is_empty(), "store never over-commits the budget");
        assert_eq!(c.resident_bytes(), 0);
        assert_eq!(c.evictions(), 1);
    }

    #[test]
    fn zero_budget_disables_caching_even_for_forks() {
        let mut c = SnapshotCache::new(SnapshotConfig::default());
        let (conv, ssm) = payload(1.0, 4);
        c.store(1, vec![1], conv, ssm);
        assert!(c.fork(1, 2));
        c.set_budget(0);
        assert!(c.is_empty(), "zero budget evicts fork-only entries too");
        assert_eq!(c.resident_bytes(), 0);
        assert_eq!(c.evictions(), 2);
    }

    #[test]
    fn set_budget_shrink_evicts_lru_first() {
        let mut c = SnapshotCache::new(SnapshotConfig::default());
        for s in 0..3u64 {
            let (conv, ssm) = payload(s as f32, 4);
            c.store(s, vec![s as i32], conv, ssm);
        }
        assert!(c.lookup(0, &[0, 5]).is_some()); // refresh session 0
        c.set_budget(64);
        assert_eq!(c.len(), 2);
        assert!(!c.contains(1), "oldest-touched evicted first");
        assert!(c.contains(0) && c.contains(2));
    }
}
