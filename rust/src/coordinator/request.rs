//! Request types and the synthetic workload generator.

use std::time::Instant;

use crate::util::XorShift;

/// A generation request entering the coordinator.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub id: u64,
    /// Prompt token ids (length must equal the compiled prefill length).
    pub prompt: Vec<i32>,
    /// Number of tokens to generate.
    pub max_new_tokens: usize,
}

/// Completed response — or, when `error` is set, the request's
/// **terminal failure**. Under supervision every sink receives exactly
/// one `Response`; a request that exhausts its retry budget (or has no
/// healthy worker left) gets an explicit error here instead of a
/// silently dropped sink and a client hung on `recv()`.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Time from submission to first generated token (seconds).
    pub ttft: f64,
    /// Total time from submission to completion (seconds).
    pub total: f64,
    /// `Some(reason)` when the request failed terminally; `tokens`
    /// then holds whatever was generated before the failure (possibly
    /// empty) and must not be treated as a completed stream.
    pub error: Option<String>,
}

impl Response {
    /// A terminal failure response for request `id`.
    pub fn failure(id: u64, reason: impl Into<String>) -> Response {
        Response {
            id,
            tokens: Vec::new(),
            ttft: 0.0,
            total: 0.0,
            error: Some(reason.into()),
        }
    }

    /// True if this is a terminal failure rather than a completion.
    pub fn is_error(&self) -> bool {
        self.error.is_some()
    }
}

/// Coordinator-internal tracking for an in-flight request.
#[derive(Debug)]
pub struct InFlight {
    pub req: Request,
    pub submitted: Instant,
    pub first_token: Option<Instant>,
    pub generated: Vec<i32>,
    /// Prompt tokens already prefilled (the chunked-prefill cursor);
    /// the request starts generating once this reaches the prompt
    /// length. Mirrors the batcher's per-job cursor.
    pub prefill_pos: usize,
    /// How many leading `generated` tokens a `Reprefill`-mode migration
    /// has folded into `prompt` as replay history. A second re-prefill
    /// must append only `generated[prompt_replayed..]`, or the replayed
    /// history would duplicate those tokens and corrupt the stream. 0
    /// for every flight that was never reprefill-migrated.
    pub prompt_replayed: usize,
    /// How many times fault recovery has re-routed this flight
    /// (salvage attach or re-prefill after a worker death). Checked
    /// against the server's `max_replays` budget so a request that
    /// keeps landing on faults degrades to a terminal error instead of
    /// looping forever. 0 for every flight that never saw a fault;
    /// planned live migration does not count.
    pub replays: u32,
    /// Scheduler tick count when this flight was admitted — the
    /// deterministic companion of `submitted`. Re-stamped to the local
    /// clock on migration/salvage attach (tick clocks are per worker),
    /// so tick latencies measure on-shard scheduling delay.
    pub submitted_tick: u64,
    /// Tick count at the first generated token (deterministic TTFT =
    /// `first_token_tick - submitted_tick`).
    pub first_token_tick: Option<u64>,
    /// Tick count at the most recent generated token, for the
    /// deterministic inter-token gap histogram.
    pub last_token_tick: u64,
}

impl InFlight {
    pub fn new(req: Request) -> InFlight {
        // Preallocate the generation buffer at admission, so the
        // per-token push on the scheduler's hot path never reallocates
        // for reasonably sized requests. Clamped: max_new_tokens is
        // caller-supplied, and a hostile value must not become a huge
        // allocation before a single token is generated.
        let generated = Vec::with_capacity(req.max_new_tokens.min(4096));
        InFlight {
            req,
            submitted: Instant::now(),
            first_token: None,
            generated,
            prefill_pos: 0,
            prompt_replayed: 0,
            replays: 0,
            submitted_tick: 0,
            first_token_tick: None,
            last_token_tick: 0,
        }
    }

    pub fn done(&self) -> bool {
        self.generated.len() >= self.req.max_new_tokens
    }

    pub fn finish(&self) -> Response {
        let now = Instant::now();
        Response {
            id: self.req.id,
            tokens: self.generated.clone(),
            ttft: self
                .first_token
                .map(|t| (t - self.submitted).as_secs_f64())
                .unwrap_or_default(),
            total: (now - self.submitted).as_secs_f64(),
            error: None,
        }
    }
}

/// Synthetic workload generator: prompts with scenario-shaped prompt
/// and generation lengths (mirrors paper Figure 12's context:generation
/// ratios at serving scale). Defaults to fixed-length prompts of
/// `prompt_len`; [`WorkloadGen::with_prompt_range`] draws varied prompt
/// lengths for chunked-prefill workloads.
#[derive(Debug)]
pub struct WorkloadGen {
    rng: XorShift,
    vocab: u64,
    prompt_lo: usize,
    prompt_hi: usize,
    gen_lo: usize,
    gen_hi: usize,
    next_id: u64,
}

impl WorkloadGen {
    pub fn new(seed: u64, vocab: usize, prompt_len: usize, gen_lo: usize, gen_hi: usize) -> Self {
        WorkloadGen {
            rng: XorShift::new(seed),
            vocab: vocab as u64,
            prompt_lo: prompt_len,
            prompt_hi: prompt_len,
            gen_lo,
            gen_hi: gen_hi.max(gen_lo),
            next_id: 0,
        }
    }

    /// Draw prompt lengths uniformly in `[lo, hi]` (lo ≥ 1).
    pub fn with_prompt_range(mut self, lo: usize, hi: usize) -> Self {
        self.prompt_lo = lo.max(1);
        self.prompt_hi = hi.max(self.prompt_lo);
        self
    }

    pub fn next_request(&mut self) -> Request {
        let id = self.next_id;
        self.next_id += 1;
        let plen = self.rng.range(self.prompt_lo as u64, self.prompt_hi as u64) as usize;
        let prompt = (0..plen).map(|_| self.rng.below(self.vocab) as i32).collect();
        let max_new_tokens = self.rng.range(self.gen_lo as u64, self.gen_hi as u64) as usize;
        Request { id, prompt, max_new_tokens }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic_and_in_range() {
        let mut g1 = WorkloadGen::new(5, 17, 8, 2, 6);
        let mut g2 = WorkloadGen::new(5, 17, 8, 2, 6);
        for _ in 0..50 {
            let a = g1.next_request();
            let b = g2.next_request();
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.max_new_tokens, b.max_new_tokens);
            assert_eq!(a.prompt.len(), 8);
            assert!(a.prompt.iter().all(|&t| (0..17).contains(&t)));
            assert!((2..=6).contains(&a.max_new_tokens));
        }
    }

    #[test]
    fn prompt_range_draws_varied_lengths() {
        let mut g = WorkloadGen::new(6, 17, 8, 1, 1).with_prompt_range(2, 31);
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..64 {
            let r = g.next_request();
            assert!((2..=31).contains(&r.prompt.len()));
            seen.insert(r.prompt.len());
        }
        assert!(seen.len() > 5, "lengths barely vary: {seen:?}");
    }

    #[test]
    fn inflight_lifecycle() {
        let mut f = InFlight::new(Request { id: 1, prompt: vec![0], max_new_tokens: 2 });
        assert!(!f.done());
        f.generated.push(3);
        f.first_token = Some(std::time::Instant::now());
        f.generated.push(4);
        assert!(f.done());
        let r = f.finish();
        assert_eq!(r.tokens, vec![3, 4]);
        assert!(r.total >= r.ttft);
    }
}
