//! The L3 serving coordinator: slot-aware request router, continuous
//! batcher with chunked prefill, mixed prefill/decode scheduler, and
//! the **sharded** recurrent-state arena (Mamba's fixed-size analogue
//! of a KV-cache manager, kept resident in engine layout so the
//! steady-state decode tick moves zero state bytes; each worker owns
//! one shard, and in-flight requests migrate between shards by moving
//! their resident rows — never by re-prefilling). Python never runs here — the engine
//! executes AOT-compiled HLO artifacts via PJRT.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod server;
pub mod shard;
pub mod snapshot;
pub mod state;

pub use batcher::{Action, Batcher, BatchPolicy, ChunkPlan};
pub use metrics::{LatencyReport, Metrics, TrafficSnapshot, DWELL_BUCKETS, PRIORITY_CLASSES};
pub use request::{InFlight, Request, Response, WorkloadGen};
pub use scheduler::{Scheduler, StatePath};
pub use server::{serve_all, ResilienceStats, Server};
pub use shard::{
    Migration, MigrationMode, MigrationOutcome, MigrationPacket, RouterPolicy, ShardMap,
    WorkerLoad,
};
pub use snapshot::{SnapshotCache, SnapshotConfig, SnapshotHit, SnapshotPayload};
pub use state::{SlotHandle, StateArena};
