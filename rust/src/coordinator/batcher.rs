//! Continuous-batching policy with **chunked prefill**: every scheduler
//! tick is one *mixed* engine invocation that advances all running
//! (decoding) sequences by one token *and* admits prefill chunks from
//! waiting prompts, under a per-tick token budget. Splitting prompts
//! into fixed-size chunks bounds the work co-scheduled with decode, so
//! a long prompt can no longer stall generation for entire ticks — the
//! prefill/decode interference that all-or-nothing prefill batching
//! suffers from (and that MARCA-style accelerators attack in hardware).
//!
//! Specialized to Mamba's fixed-size state: admission is never blocked
//! by state growth, only by the slot count (`max_running`), and a
//! sequence mid-prefill holds exactly one slot for its partial state.

use std::collections::VecDeque;

/// Tunable policy knobs.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Max prompt tokens admitted per chunk row. `0` means monolithic:
    /// a prompt is admitted whole (still clipped by `token_budget`).
    pub chunk_tokens: usize,
    /// Per-tick token budget: each decode row costs 1, each prefill
    /// chunk costs its length. Bounds the latency of one engine call.
    pub token_budget: usize,
    /// Max prefill-chunk rows per tick (caps the varlen batch width).
    pub max_chunk_rows: usize,
    /// Max sequences holding a state slot (running + mid-prefill).
    pub max_running: usize,
    /// Once at least this many sequences are running, ticks are pure
    /// decode (anti-starvation for in-flight requests).
    pub decode_priority_threshold: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            chunk_tokens: 4,
            token_budget: 16,
            max_chunk_rows: 4,
            max_running: 8,
            decode_priority_threshold: 8,
        }
    }
}

impl BatchPolicy {
    /// Clamp degenerate knob values that could stall the scheduler
    /// (zero budget / zero slots / a zero decode-priority threshold
    /// would admit nothing forever).
    pub fn normalized(mut self) -> BatchPolicy {
        self.token_budget = self.token_budget.max(1);
        self.max_chunk_rows = self.max_chunk_rows.max(1);
        self.max_running = self.max_running.max(1);
        self.decode_priority_threshold = self.decode_priority_threshold.max(1);
        self
    }

    /// Build a policy from CLI args (shared by `mambalaya serve` and
    /// the `serve_mamba` example, so the knob names and defaults can't
    /// drift): `--chunk-tokens --token-budget --max-chunk-rows
    /// --max-running --decode-priority`.
    pub fn from_args(args: &crate::util::Args) -> BatchPolicy {
        let d = BatchPolicy::default();
        BatchPolicy {
            chunk_tokens: args.get_u64("chunk-tokens", d.chunk_tokens as u64) as usize,
            token_budget: args.get_u64("token-budget", d.token_budget as u64) as usize,
            max_chunk_rows: args.get_u64("max-chunk-rows", d.max_chunk_rows as u64) as usize,
            max_running: args.get_u64("max-running", d.max_running as u64) as usize,
            decode_priority_threshold: args
                .get_u64("decode-priority", d.decode_priority_threshold as u64)
                as usize,
        }
    }

    /// Effective chunk cap for a prompt of `total` tokens.
    fn chunk_cap(&self, total: usize) -> usize {
        if self.chunk_tokens == 0 {
            total
        } else {
            self.chunk_tokens
        }
    }
}

/// One prefill chunk scheduled for this tick.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChunkPlan {
    /// Sequence id.
    pub id: u64,
    /// Prompt offset this chunk starts at (== the sequence's cursor).
    pub start: usize,
    /// Tokens in this chunk (≥ 1).
    pub len: usize,
    /// True when this chunk completes the prompt (the scheduler samples
    /// the first token from its logits).
    pub last: bool,
}

/// What the scheduler should do next.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Action {
    /// One mixed engine invocation: advance the first `decode` running
    /// sequences by one token and run these prefill chunks.
    Mixed { chunks: Vec<ChunkPlan>, decode: usize },
    /// Nothing to do.
    Idle,
}

/// A waiting prompt and its prefill cursor.
#[derive(Debug, Clone)]
struct PrefillJob {
    id: u64,
    /// Total prompt tokens.
    total: usize,
    /// Tokens already prefilled (advanced by [`Batcher::commit`]).
    pos: usize,
}

/// The batcher: tracks waiting prompts (FIFO) with per-sequence prefill
/// cursors and decides the per-tick mixed batch. (Queues of actual
/// requests live in the scheduler; the batcher is a pure policy object,
/// which keeps it unit-testable.)
#[derive(Debug)]
pub struct Batcher {
    policy: BatchPolicy,
    jobs: VecDeque<PrefillJob>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher { policy: policy.normalized(), jobs: VecDeque::new() }
    }

    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    /// Enqueue a prompt of `prompt_tokens` tokens for prefill.
    pub fn enqueue(&mut self, id: u64, prompt_tokens: usize) {
        self.jobs.push_back(PrefillJob { id, total: prompt_tokens, pos: 0 });
    }

    /// Enqueue a prompt with its cursor already at `pos` — a migrated
    /// mid-prefill sequence, or a session snapshot hit whose history
    /// prefix is already summarized by the attached state (in both
    /// cases the partial state for `tokens[..pos]` was attached to the
    /// arena by the scheduler). Joins the FIFO tail like any other
    /// arrival.
    ///
    /// The assert is a programmer-error guard, not input validation:
    /// `Scheduler::attach` rejects malformed migration packets (cursor
    /// past prompt end, wrong payload shape, …) with an `Err` *before*
    /// reaching here, and the snapshot-hit path derives `pos` from a
    /// strict-prefix match, so a trip here means a scheduler bug.
    pub fn enqueue_at(&mut self, id: u64, prompt_tokens: usize, pos: usize) {
        assert!(pos < prompt_tokens, "cursor past prompt end for seq {id}");
        self.jobs.push_back(PrefillJob { id, total: prompt_tokens, pos });
    }

    /// Splice a waiting prompt out of the queue (migration detach).
    /// Returns its `(total, cursor)` so the target worker can resume at
    /// the same position.
    pub fn remove(&mut self, id: u64) -> Option<(usize, usize)> {
        let idx = self.jobs.iter().position(|j| j.id == id)?;
        let job = self.jobs.remove(idx).expect("position is in range");
        Some((job.total, job.pos))
    }

    /// Prompts not yet fully prefilled.
    pub fn waiting(&self) -> usize {
        self.jobs.len()
    }

    /// A sequence's prefill cursor (tests/metrics).
    pub fn cursor(&self, id: u64) -> Option<usize> {
        self.jobs.iter().find(|j| j.id == id).map(|j| j.pos)
    }

    /// Sequences that have started but not finished prefill (they hold
    /// a state slot for their partial state).
    pub fn mid_prefill(&self) -> usize {
        self.jobs.iter().filter(|j| j.pos > 0).count()
    }

    /// Decide the next action given the number of running sequences.
    ///
    /// Invariants (property-tested): the total token cost (decode rows
    /// + chunk lengths) never exceeds `token_budget`; chunks admit in
    /// strict FIFO order (always a prefix of the waiting queue); at
    /// most one chunk per sequence per tick; a fresh sequence is only
    /// admitted when a state slot is free.
    pub fn next_action(&self, running: usize) -> Action {
        let p = &self.policy;
        let budget_total = p.token_budget;
        let decode = running.min(budget_total);

        // Steady-state fast path: no waiting prompts (or pure-decode
        // priority) means the action is decode-only and this call
        // performs no heap allocation (`Vec::new` is allocation-free
        // until pushed) — part of the zero-alloc tick contract.
        if self.jobs.is_empty() || running >= p.decode_priority_threshold {
            return if decode == 0 {
                Action::Idle
            } else {
                Action::Mixed { chunks: Vec::new(), decode }
            };
        }

        let mut budget = budget_total - decode;
        let mut slots_free =
            p.max_running.saturating_sub(running + self.mid_prefill());

        let mut chunks = Vec::new();
        for job in self.jobs.iter() {
            if chunks.len() >= p.max_chunk_rows || budget == 0 {
                break;
            }
            // Strict FIFO: if the head job can't start, nothing
            // behind it may overtake.
            if job.pos == 0 && slots_free == 0 {
                break;
            }
            let len = (job.total - job.pos).min(p.chunk_cap(job.total)).min(budget);
            if len == 0 {
                break;
            }
            chunks.push(ChunkPlan {
                id: job.id,
                start: job.pos,
                len,
                last: job.pos + len == job.total,
            });
            budget -= len;
            if job.pos == 0 {
                slots_free -= 1;
            }
        }

        if chunks.is_empty() && decode == 0 {
            Action::Idle
        } else {
            Action::Mixed { chunks, decode }
        }
    }

    /// Record that the chunks of an executed action ran: advance each
    /// sequence's prefill cursor and retire completed jobs. Call after
    /// the engine invocation succeeds (fail-stop keeps cursors honest).
    pub fn commit(&mut self, chunks: &[ChunkPlan]) {
        for ch in chunks {
            let job = self
                .jobs
                .iter_mut()
                .find(|j| j.id == ch.id)
                .expect("committed chunk for unknown job");
            assert_eq!(job.pos, ch.start, "chunk start != cursor for seq {}", ch.id);
            job.pos += ch.len;
            assert!(job.pos <= job.total, "cursor past prompt end for seq {}", ch.id);
        }
        self.jobs.retain(|j| j.pos < j.total);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batcher() -> Batcher {
        Batcher::new(BatchPolicy {
            chunk_tokens: 4,
            token_budget: 16,
            max_chunk_rows: 4,
            max_running: 8,
            decode_priority_threshold: 6,
        })
    }

    fn chunks_of(a: &Action) -> Vec<ChunkPlan> {
        match a {
            Action::Mixed { chunks, .. } => chunks.clone(),
            Action::Idle => Vec::new(),
        }
    }

    #[test]
    fn idle_when_empty() {
        let b = batcher();
        assert_eq!(b.next_action(0), Action::Idle);
    }

    #[test]
    fn short_prompt_admits_whole_as_one_chunk() {
        let mut b = batcher();
        b.enqueue(1, 3);
        assert_eq!(
            b.next_action(0),
            Action::Mixed {
                chunks: vec![ChunkPlan { id: 1, start: 0, len: 3, last: true }],
                decode: 0
            }
        );
    }

    #[test]
    fn long_prompt_is_chunked_across_ticks() {
        let mut b = batcher();
        b.enqueue(1, 10);
        let a1 = chunks_of(&b.next_action(0));
        assert_eq!(a1, vec![ChunkPlan { id: 1, start: 0, len: 4, last: false }]);
        b.commit(&a1);
        assert_eq!(b.cursor(1), Some(4));
        assert_eq!(b.mid_prefill(), 1);
        let a2 = chunks_of(&b.next_action(0));
        assert_eq!(a2, vec![ChunkPlan { id: 1, start: 4, len: 4, last: false }]);
        b.commit(&a2);
        let a3 = chunks_of(&b.next_action(0));
        assert_eq!(a3, vec![ChunkPlan { id: 1, start: 8, len: 2, last: true }]);
        b.commit(&a3);
        assert_eq!(b.waiting(), 0);
    }

    #[test]
    fn decode_rides_along_and_budget_caps_chunks() {
        let mut b = batcher();
        b.enqueue(1, 100);
        b.enqueue(2, 100);
        // 5 running → decode 5 costs 5, leaving 11 tokens: two chunks of
        // 4 fit (FIFO: seq 1 then seq 2), then max_chunk_rows/budget
        // stop further admission at 3 remaining... chunk cap is 4, so
        // the third chunk would need another job — there is none.
        match b.next_action(5) {
            Action::Mixed { chunks, decode } => {
                assert_eq!(decode, 5);
                assert_eq!(chunks.len(), 2);
                assert_eq!(chunks[0], ChunkPlan { id: 1, start: 0, len: 4, last: false });
                assert_eq!(chunks[1], ChunkPlan { id: 2, start: 0, len: 4, last: false });
                let cost: usize = decode + chunks.iter().map(|c| c.len).sum::<usize>();
                assert!(cost <= b.policy().token_budget);
            }
            a => panic!("unexpected action {a:?}"),
        }
    }

    #[test]
    fn budget_clips_final_chunk() {
        let mut b = Batcher::new(BatchPolicy {
            chunk_tokens: 8,
            token_budget: 10,
            ..BatchPolicy::default()
        });
        b.enqueue(1, 20);
        // 4 running → budget left 6 < chunk 8: the chunk is clipped.
        let chunks = chunks_of(&b.next_action(4));
        assert_eq!(chunks, vec![ChunkPlan { id: 1, start: 0, len: 6, last: false }]);
    }

    #[test]
    fn decode_priority_threshold_blocks_admission() {
        let mut b = batcher();
        b.enqueue(1, 4);
        assert_eq!(b.next_action(6), Action::Mixed { chunks: vec![], decode: 6 });
    }

    #[test]
    fn slot_limit_blocks_fresh_sequences_fifo() {
        let mut b = Batcher::new(BatchPolicy {
            max_running: 2,
            decode_priority_threshold: 8,
            ..BatchPolicy::default()
        });
        b.enqueue(1, 4);
        b.enqueue(2, 4);
        // 2 running fill both slots: no admission, decode only.
        assert_eq!(b.next_action(2), Action::Mixed { chunks: vec![], decode: 2 });
        // One slot free: only the head job starts (strict FIFO).
        let chunks = chunks_of(&b.next_action(1));
        assert_eq!(chunks.len(), 1);
        assert_eq!(chunks[0].id, 1);
    }

    #[test]
    fn mid_prefill_sequences_keep_their_slot() {
        let mut b = Batcher::new(BatchPolicy {
            chunk_tokens: 2,
            token_budget: 2,
            max_running: 1,
            ..BatchPolicy::default()
        });
        b.enqueue(1, 6);
        b.enqueue(2, 2);
        let a = chunks_of(&b.next_action(0));
        assert_eq!(a, vec![ChunkPlan { id: 1, start: 0, len: 2, last: false }]);
        b.commit(&a);
        // Seq 1 mid-prefill holds the only slot; seq 2 cannot start,
        // and seq 1 keeps progressing.
        let a2 = chunks_of(&b.next_action(0));
        assert_eq!(a2, vec![ChunkPlan { id: 1, start: 2, len: 2, last: false }]);
    }

    #[test]
    fn monolithic_mode_admits_whole_prompt() {
        let mut b = Batcher::new(BatchPolicy {
            chunk_tokens: 0,
            token_budget: 1 << 20,
            ..BatchPolicy::default()
        });
        b.enqueue(1, 999);
        let chunks = chunks_of(&b.next_action(0));
        assert_eq!(chunks, vec![ChunkPlan { id: 1, start: 0, len: 999, last: true }]);
    }

    #[test]
    fn degenerate_policy_is_normalized_and_makes_progress() {
        // decode_priority_threshold = 0 (or zero budget/slots) must not
        // livelock the scheduler: normalized() clamps all of them.
        let mut b = Batcher::new(BatchPolicy {
            chunk_tokens: 2,
            token_budget: 0,
            max_chunk_rows: 0,
            max_running: 0,
            decode_priority_threshold: 0,
        });
        assert_eq!(b.policy().token_budget, 1);
        assert_eq!(b.policy().max_chunk_rows, 1);
        assert_eq!(b.policy().max_running, 1);
        assert_eq!(b.policy().decode_priority_threshold, 1);
        b.enqueue(1, 4);
        // Nothing running → the head job still gets a (budget-clipped)
        // chunk, so the queue drains.
        let chunks = chunks_of(&b.next_action(0));
        assert_eq!(chunks, vec![ChunkPlan { id: 1, start: 0, len: 1, last: false }]);
    }

    #[test]
    fn remove_and_enqueue_at_splice_mid_prefill_jobs() {
        let mut b = batcher();
        b.enqueue(1, 10);
        b.enqueue(2, 6);
        let a = chunks_of(&b.next_action(0));
        b.commit(&a);
        assert_eq!(b.cursor(1), Some(4));
        // Splice seq 1 out mid-prefill (migration detach)...
        assert_eq!(b.remove(1), Some((10, 4)));
        assert_eq!(b.remove(1), None);
        assert_eq!(b.waiting(), 1);
        // ...and back in at its cursor (migration attach): the next
        // chunk resumes exactly where the source worker stopped.
        b.enqueue_at(1, 10, 4);
        assert_eq!(b.cursor(1), Some(4));
        assert_eq!(b.mid_prefill(), 1);
        let chunks = chunks_of(&b.next_action(0));
        assert!(chunks
            .iter()
            .any(|c| *c == ChunkPlan { id: 1, start: 4, len: 4, last: false }));
    }

    #[test]
    #[should_panic(expected = "chunk start != cursor")]
    fn commit_rejects_stale_chunks() {
        let mut b = batcher();
        b.enqueue(1, 10);
        let a = chunks_of(&b.next_action(0));
        b.commit(&a);
        b.commit(&a); // same chunks again: cursor already advanced
    }
}
