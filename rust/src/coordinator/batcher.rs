//! Dynamic batching policy: decides, each scheduler tick, whether to
//! run a prefill batch (admitting waiting requests) or a decode step
//! (advancing running sequences) — the classic continuous-batching
//! trade-off, specialized to Mamba's fixed-size state (admission is
//! never blocked by state growth, only by slot count).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Tunable policy knobs.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Compiled prefill batch sizes (ascending).
    pub prefill_sizes: Vec<usize>,
    /// Compiled decode batch sizes (ascending).
    pub decode_sizes: Vec<usize>,
    /// Admit a partial prefill batch after this long.
    pub max_prefill_wait: Duration,
    /// Max concurrently running sequences (state slots).
    pub max_running: usize,
    /// Prefer decode once at least this many sequences are running
    /// (anti-starvation for in-flight requests).
    pub decode_priority_threshold: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            prefill_sizes: vec![1, 2, 4],
            decode_sizes: vec![1, 2, 4, 8],
            max_prefill_wait: Duration::from_millis(4),
            max_running: 8,
            decode_priority_threshold: 8,
        }
    }
}

/// What the scheduler should do next.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Action {
    /// Admit these many waiting requests as one prefill batch of the
    /// given compiled size (`admit ≤ size`).
    Prefill { admit: usize, size: usize },
    /// Run one decode step over all running sequences, padded to the
    /// given compiled size.
    Decode { size: usize },
    /// Nothing to do.
    Idle,
}

/// The batcher: tracks waiting counts and decides scheduling actions.
/// (Queues of actual requests live in the scheduler; the batcher is a
/// pure policy object, which keeps it unit-testable.)
#[derive(Debug)]
pub struct Batcher {
    policy: BatchPolicy,
    oldest_waiting: Option<Instant>,
    waiting: VecDeque<u64>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher { policy, oldest_waiting: None, waiting: VecDeque::new() }
    }

    pub fn policy(&self) -> &BatchPolicy {
        &self.policy
    }

    pub fn enqueue(&mut self, id: u64) {
        if self.waiting.is_empty() {
            self.oldest_waiting = Some(Instant::now());
        }
        self.waiting.push_back(id);
    }

    pub fn waiting(&self) -> usize {
        self.waiting.len()
    }

    /// Pop the ids admitted by a `Prefill` action.
    pub fn admit(&mut self, n: usize) -> Vec<u64> {
        let out: Vec<u64> = (0..n).filter_map(|_| self.waiting.pop_front()).collect();
        if self.waiting.is_empty() {
            self.oldest_waiting = None;
        } else {
            self.oldest_waiting = Some(Instant::now());
        }
        out
    }

    fn fit(sizes: &[usize], n: usize) -> Option<usize> {
        sizes.iter().copied().filter(|&s| s >= n).min()
    }

    fn largest(sizes: &[usize]) -> usize {
        sizes.iter().copied().max().unwrap_or(1)
    }

    /// Decide the next action given the number of running sequences.
    pub fn next_action(&self, running: usize, now: Instant) -> Action {
        let p = &self.policy;
        let slots_free = p.max_running.saturating_sub(running);
        let max_prefill = Self::largest(&p.prefill_sizes).min(slots_free);
        let can_prefill = !self.waiting.is_empty() && max_prefill > 0;

        // Anti-starvation: with a full complement of running sequences,
        // keep decoding.
        if running >= p.decode_priority_threshold && running > 0 {
            return Action::Decode { size: Self::fit(&p.decode_sizes, running).unwrap_or(running) };
        }

        if can_prefill {
            let waited = self
                .oldest_waiting
                .map(|t| now.duration_since(t))
                .unwrap_or(Duration::ZERO);
            let enough_for_full_batch = self.waiting.len() >= max_prefill;
            // Admit when a full batch is ready, when requests have aged,
            // or when nothing is running anyway.
            if enough_for_full_batch || waited >= p.max_prefill_wait || running == 0 {
                let admit = self.waiting.len().min(max_prefill);
                if let Some(size) = Self::fit(&p.prefill_sizes, admit) {
                    return Action::Prefill { admit, size };
                }
            }
        }

        if running > 0 {
            if let Some(size) = Self::fit(&p.decode_sizes, running) {
                return Action::Decode { size };
            }
            // More running sequences than the largest compiled batch:
            // decode in chunks of the largest size.
            return Action::Decode { size: Self::largest(&p.decode_sizes) };
        }

        Action::Idle
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn batcher() -> Batcher {
        Batcher::new(BatchPolicy {
            prefill_sizes: vec![1, 2, 4],
            decode_sizes: vec![1, 2, 4, 8],
            max_prefill_wait: Duration::from_millis(2),
            max_running: 8,
            decode_priority_threshold: 6,
        })
    }

    #[test]
    fn idle_when_empty() {
        let b = batcher();
        assert_eq!(b.next_action(0, Instant::now()), Action::Idle);
    }

    #[test]
    fn immediate_prefill_when_nothing_running() {
        let mut b = batcher();
        b.enqueue(1);
        assert_eq!(b.next_action(0, Instant::now()), Action::Prefill { admit: 1, size: 1 });
    }

    #[test]
    fn full_batch_admits_at_compiled_size() {
        let mut b = batcher();
        for i in 0..5 {
            b.enqueue(i);
        }
        // 5 waiting, cap 4 → admit 4 as a b=4 prefill.
        assert_eq!(b.next_action(1, Instant::now()), Action::Prefill { admit: 4, size: 4 });
        assert_eq!(b.admit(4), vec![0, 1, 2, 3]);
        assert_eq!(b.waiting(), 1);
    }

    #[test]
    fn partial_batch_waits_then_ages_out() {
        let mut b = batcher();
        b.enqueue(1);
        // One waiting, one running, not aged → decode wins.
        let now = Instant::now();
        assert_eq!(b.next_action(1, now), Action::Decode { size: 1 });
        // After the wait expires, the partial prefill is admitted.
        let later = now + Duration::from_millis(50);
        assert_eq!(b.next_action(1, later), Action::Prefill { admit: 1, size: 1 });
    }

    #[test]
    fn decode_priority_when_saturated() {
        let mut b = batcher();
        for i in 0..4 {
            b.enqueue(i);
        }
        assert_eq!(b.next_action(6, Instant::now()), Action::Decode { size: 8 });
    }

    #[test]
    fn padding_picks_next_compiled_size() {
        let b = batcher();
        assert_eq!(b.next_action(3, Instant::now()), Action::Decode { size: 4 });
        assert_eq!(b.next_action(5, Instant::now()), Action::Decode { size: 8 });
    }

    #[test]
    fn slot_limit_blocks_prefill() {
        let mut b = batcher();
        b.enqueue(1);
        // max_running = 8, all slots taken → decode only.
        assert_eq!(b.next_action(8, Instant::now()), Action::Decode { size: 8 });
    }
}
