//! Recurrent-state manager: Mamba's analogue of a KV-cache manager.
//!
//! Unlike attention's ever-growing KV cache, Mamba's per-sequence state
//! is *fixed-size* (the paper's "compressed summary": `H` is D×N per
//! layer plus the J−1 conv tail) — so the manager is a slab of
//! constant-size slots with gather/scatter into the PJRT batch layout
//! (`[layers, batch, …]`, layer-major).

use std::collections::BTreeMap;

use crate::runtime::engine::copy_state_row;

/// Per-sequence recurrent state, stored per-sequence-major
/// (`[layers, per_layer]` contiguous).
#[derive(Debug, Clone)]
pub struct SeqState {
    pub conv: Vec<f32>,
    pub ssm: Vec<f32>,
}

/// Slab of sequence states keyed by sequence id.
#[derive(Debug)]
pub struct StateManager {
    n_layer: usize,
    conv_per_layer: usize,
    ssm_per_layer: usize,
    slots: BTreeMap<u64, SeqState>,
    /// High-water mark (for metrics / capacity planning).
    peak: usize,
}

impl StateManager {
    pub fn new(n_layer: usize, conv_per_layer: usize, ssm_per_layer: usize) -> StateManager {
        StateManager { n_layer, conv_per_layer, ssm_per_layer, slots: BTreeMap::new(), peak: 0 }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn peak(&self) -> usize {
        self.peak
    }

    /// Bytes held per sequence (fixed — the Mamba property).
    pub fn bytes_per_seq(&self) -> usize {
        self.n_layer * (self.conv_per_layer + self.ssm_per_layer) * 4
    }

    pub fn contains(&self, seq: u64) -> bool {
        self.slots.contains_key(&seq)
    }

    /// Install a sequence's state from a *packed batch* output at row
    /// `b` of `batch` (layer-major unpack).
    pub fn install_from_batch(
        &mut self,
        seq: u64,
        batch: usize,
        b: usize,
        conv_batch: &[f32],
        ssm_batch: &[f32],
    ) {
        let (cp, sp) = (self.conv_per_layer, self.ssm_per_layer);
        let mut conv = Vec::with_capacity(self.n_layer * cp);
        let mut ssm = Vec::with_capacity(self.n_layer * sp);
        for l in 0..self.n_layer {
            conv.extend_from_slice(&conv_batch[(l * batch + b) * cp..(l * batch + b + 1) * cp]);
            ssm.extend_from_slice(&ssm_batch[(l * batch + b) * sp..(l * batch + b + 1) * sp]);
        }
        self.slots.insert(seq, SeqState { conv, ssm });
        self.peak = self.peak.max(self.slots.len());
    }

    /// Gather `seqs` (padding the tail by repeating the last sequence up
    /// to `batch`) into packed layer-major buffers for the engine.
    ///
    /// Returns `(conv, ssm)`. Panics if any sequence is missing.
    pub fn gather(&self, seqs: &[u64], batch: usize) -> (Vec<f32>, Vec<f32>) {
        assert!(!seqs.is_empty() && seqs.len() <= batch);
        let (cp, sp) = (self.conv_per_layer, self.ssm_per_layer);
        let mut conv = vec![0f32; self.n_layer * batch * cp];
        let mut ssm = vec![0f32; self.n_layer * batch * sp];
        for b in 0..batch {
            let seq = seqs[b.min(seqs.len() - 1)];
            let st = self.slots.get(&seq).unwrap_or_else(|| panic!("missing state {seq}"));
            for l in 0..self.n_layer {
                conv[(l * batch + b) * cp..(l * batch + b + 1) * cp]
                    .copy_from_slice(&st.conv[l * cp..(l + 1) * cp]);
                ssm[(l * batch + b) * sp..(l * batch + b + 1) * sp]
                    .copy_from_slice(&st.ssm[l * sp..(l + 1) * sp]);
            }
        }
        (conv, ssm)
    }

    /// Gather the rows of a *mixed* batch: `Some(seq)` rows copy the
    /// stored state (partial-prefill or decoding), `None` rows are
    /// fresh sequences and stay zero. No padding — the varlen mixed
    /// call takes exactly `rows.len()` rows.
    ///
    /// Panics if a `Some` sequence has no stored state.
    pub fn gather_rows(&self, rows: &[Option<u64>]) -> (Vec<f32>, Vec<f32>) {
        let batch = rows.len();
        let (cp, sp) = (self.conv_per_layer, self.ssm_per_layer);
        let mut conv = vec![0f32; self.n_layer * batch * cp];
        let mut ssm = vec![0f32; self.n_layer * batch * sp];
        for (b, row) in rows.iter().enumerate() {
            if let Some(seq) = row {
                let st =
                    self.slots.get(seq).unwrap_or_else(|| panic!("missing state {seq}"));
                // A slot is a [layers, per] buffer, i.e. batch-1 packed.
                copy_state_row(self.n_layer, cp, &st.conv, 1, 0, &mut conv, batch, b);
                copy_state_row(self.n_layer, sp, &st.ssm, 1, 0, &mut ssm, batch, b);
            }
        }
        (conv, ssm)
    }

    /// Scatter a decode step's packed outputs back into the slots of
    /// `seqs` (ignoring padded rows).
    pub fn scatter(&mut self, seqs: &[u64], batch: usize, conv_batch: &[f32], ssm_batch: &[f32]) {
        for (b, &seq) in seqs.iter().enumerate() {
            assert!(b < batch);
            self.install_from_batch(seq, batch, b, conv_batch, ssm_batch);
        }
    }

    /// Drop a finished sequence, freeing its slot.
    pub fn release(&mut self, seq: u64) -> bool {
        self.slots.remove(&seq).is_some()
    }

    /// Direct access (tests / debugging).
    pub fn get(&self, seq: u64) -> Option<&SeqState> {
        self.slots.get(&seq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr() -> StateManager {
        StateManager::new(2, 3, 4)
    }

    #[test]
    fn install_gather_roundtrip() {
        let mut m = mgr();
        // Batch of 2 in layer-major layout: layer0[s0,s1], layer1[s0,s1].
        let conv: Vec<f32> = (0..2 * 2 * 3).map(|x| x as f32).collect();
        let ssm: Vec<f32> = (100..100 + 2 * 2 * 4).map(|x| x as f32).collect();
        m.install_from_batch(7, 2, 0, &conv, &ssm);
        m.install_from_batch(9, 2, 1, &conv, &ssm);
        assert_eq!(m.len(), 2);
        let (c2, s2) = m.gather(&[7, 9], 2);
        assert_eq!(c2, conv);
        assert_eq!(s2, ssm);
    }

    #[test]
    fn gather_pads_with_last_sequence() {
        let mut m = mgr();
        let conv: Vec<f32> = (0..6).map(|x| x as f32).collect(); // batch 1
        let ssm: Vec<f32> = (0..8).map(|x| x as f32).collect();
        m.install_from_batch(1, 1, 0, &conv, &ssm);
        let (c, s) = m.gather(&[1], 4);
        assert_eq!(c.len(), 2 * 4 * 3);
        // Every row equals sequence 1's state.
        for b in 0..4 {
            for l in 0..2 {
                assert_eq!(&c[(l * 4 + b) * 3..(l * 4 + b + 1) * 3], &conv[(l + b * 0) * 3..][..3]);
            }
        }
        let _ = s;
    }

    #[test]
    fn gather_rows_mixes_stored_and_fresh() {
        let mut m = mgr();
        let conv: Vec<f32> = (0..2 * 3).map(|x| x as f32 + 1.0).collect();
        let ssm: Vec<f32> = (0..2 * 4).map(|x| x as f32 + 50.0).collect();
        m.install_from_batch(7, 1, 0, &conv, &ssm);
        let (c, s) = m.gather_rows(&[None, Some(7), None]);
        assert_eq!(c.len(), 2 * 3 * 3);
        assert_eq!(s.len(), 2 * 3 * 4);
        for l in 0..2 {
            // Fresh rows 0 and 2 are zero; row 1 carries seq 7's state.
            assert!(c[(l * 3) * 3..(l * 3 + 1) * 3].iter().all(|&x| x == 0.0));
            assert!(c[(l * 3 + 2) * 3..(l * 3 + 3) * 3].iter().all(|&x| x == 0.0));
            assert_eq!(&c[(l * 3 + 1) * 3..(l * 3 + 2) * 3], &conv[l * 3..(l + 1) * 3]);
            assert_eq!(&s[(l * 3 + 1) * 4..(l * 3 + 2) * 4], &ssm[l * 4..(l + 1) * 4]);
        }
    }

    #[test]
    fn release_frees_slot() {
        let mut m = mgr();
        let conv = vec![0f32; 6];
        let ssm = vec![0f32; 8];
        m.install_from_batch(5, 1, 0, &conv, &ssm);
        assert!(m.contains(5));
        assert!(m.release(5));
        assert!(!m.release(5));
        assert!(m.is_empty());
        assert_eq!(m.peak(), 1);
    }

    #[test]
    fn bytes_per_seq_fixed() {
        let m = mgr();
        assert_eq!(m.bytes_per_seq(), 2 * (3 + 4) * 4);
    }
}
