//! Recurrent-state arena: Mamba's analogue of a KV-cache manager,
//! rebuilt for **zero-copy state residency**.
//!
//! Unlike attention's ever-growing KV cache, Mamba's per-sequence state
//! is *fixed-size* (the paper's "compressed summary": `H` is D×N per
//! layer plus the J−1 conv tail) — so the arena is one contiguous
//! **layer-major slab** (`[layers, capacity, …]`) with free-list slot
//! allocation and stable row indices. A sequence is admitted to a row
//! once and its state never moves again: the scheduler wraps the slab
//! as a typed [`StateSlabs`] view inside each tick's
//! [`LaunchSpec`](crate::runtime::LaunchSpec) and the engine
//! ([`Executor::launch`](crate::runtime::engine::Executor::launch))
//! advances each row **in place**. Gather and scatter — the ~6
//! full state copies per tick of the old `BTreeMap<u64, Vec<f32>>`
//! manager — exist only on the explicit reference path
//! ([`StateArena::gather_rows`] / [`StateArena::install_from_batch`]),
//! and every byte they move is counted into [`TrafficCounters`],
//! mirroring the paper's inter-operator traffic accounting.
//!
//! Under the sharded server each worker owns one shard of the logically
//! global arena: slots are addressed by a globally stable
//! [`SlotHandle`] `(shard, row)`, and a sequence moves between shards
//! only through the explicit migration splice
//! ([`StateArena::detach_row`] → [`StateArena::attach_row`]) — a
//! single counted `bytes_migrated` transfer, never a re-prefill.

use std::collections::BTreeMap;

use crate::runtime::engine::{copy_state_row, TrafficCounters};
use crate::runtime::{Donation, StateSlabs};

/// A globally stable address for one resident state row: which shard's
/// arena holds it, and which row within that shard's slab. The row part
/// is stable for the sequence's residency on that shard (rows never
/// move while resident); a **migration** is the only operation that
/// changes a sequence's handle, and it changes the `shard` coordinate.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SlotHandle {
    /// The shard (server worker) whose arena owns the row.
    pub shard: usize,
    /// Row index within that shard's layer-major slab.
    pub row: usize,
}

/// Contiguous arena of per-sequence recurrent state with stable rows.
#[derive(Debug)]
pub struct StateArena {
    /// Which shard of the (logically global) sharded arena this slab
    /// is — the `shard` coordinate of every [`SlotHandle`] it issues.
    shard: usize,
    n_layer: usize,
    conv_per_layer: usize,
    ssm_per_layer: usize,
    /// Rows per layer stripe (the slab's batch stride).
    capacity: usize,
    /// `[layers, capacity, conv_per_layer]`, layer-major.
    conv: Vec<f32>,
    /// `[layers, capacity, ssm_per_layer]`, layer-major.
    ssm: Vec<f32>,
    /// LIFO free-list of rows — a released row is the next one reused,
    /// keeping the hot working set contiguous and cache-resident.
    free: Vec<usize>,
    /// Sequence id → arena row.
    rows: BTreeMap<u64, usize>,
    /// High-water mark (for metrics / capacity planning).
    peak: usize,
    traffic: TrafficCounters,
}

impl StateArena {
    pub fn new(
        n_layer: usize,
        conv_per_layer: usize,
        ssm_per_layer: usize,
        capacity: usize,
    ) -> StateArena {
        let capacity = capacity.max(1);
        StateArena {
            shard: 0,
            n_layer,
            conv_per_layer,
            ssm_per_layer,
            capacity,
            conv: vec![0f32; n_layer * capacity * conv_per_layer],
            ssm: vec![0f32; n_layer * capacity * ssm_per_layer],
            // Reversed so the first admit takes row 0.
            free: (0..capacity).rev().collect(),
            rows: BTreeMap::new(),
            peak: 0,
            traffic: TrafficCounters::default(),
        }
    }

    /// Set which shard of the sharded arena this slab is (the server
    /// assigns one per worker; defaults to 0 for single-shard use).
    pub fn set_shard(&mut self, shard: usize) {
        self.shard = shard;
    }

    /// This slab's shard index.
    pub fn shard(&self) -> usize {
        self.shard
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Rows per layer stripe (grows by doubling when exhausted).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn peak(&self) -> usize {
        self.peak
    }

    /// Bytes held per sequence (fixed — the Mamba property).
    pub fn bytes_per_seq(&self) -> usize {
        self.n_layer * (self.conv_per_layer + self.ssm_per_layer) * 4
    }

    /// Element counts of a sequence-major payload for this arena:
    /// `(conv_len, ssm_len)` — what [`StateArena::attach_row`] asserts
    /// and [`StateArena::snapshot`] produces. Callers validating a
    /// [`MigrationPacket`](super::shard::MigrationPacket) or building a
    /// snapshot payload check against this instead of hardcoding
    /// manifest arithmetic.
    pub fn payload_shape(&self) -> (usize, usize) {
        (self.n_layer * self.conv_per_layer, self.n_layer * self.ssm_per_layer)
    }

    /// Bytes of state currently resident (a gauge, not a counter).
    pub fn resident_bytes(&self) -> u64 {
        (self.rows.len() * self.bytes_per_seq()) as u64
    }

    pub fn contains(&self, seq: u64) -> bool {
        self.rows.contains_key(&seq)
    }

    /// The arena row a sequence resides at (stable for its lifetime).
    pub fn row_of(&self, seq: u64) -> Option<usize> {
        self.rows.get(&seq).copied()
    }

    /// The globally stable `(shard, row)` handle for a resident
    /// sequence.
    pub fn handle_of(&self, seq: u64) -> Option<SlotHandle> {
        self.row_of(seq).map(|row| SlotHandle { shard: self.shard, row })
    }

    /// State bytes copied by gather/install/relocation since the last
    /// [`StateArena::take_traffic`].
    pub fn traffic(&self) -> TrafficCounters {
        self.traffic
    }

    /// Drain the traffic counters (returns the counts, resets to zero).
    pub fn take_traffic(&mut self) -> TrafficCounters {
        std::mem::take(&mut self.traffic)
    }

    /// Admit a sequence: allocate a row from the free-list (LIFO) and
    /// zero it, so the engine sees a fresh zero state in place. Zeroing
    /// is initialization, not state movement — it is not counted as
    /// traffic. Re-admitting a resident sequence re-zeroes its row —
    /// which is why the scheduler rejects duplicate in-flight request
    /// ids at submit: a second admit under the same id would silently
    /// wipe the original's mid-flight state.
    pub fn admit(&mut self, seq: u64) -> usize {
        let row = match self.rows.get(&seq) {
            Some(&row) => row,
            None => self.alloc_row(seq),
        };
        self.zero_row(row);
        row
    }

    /// Drop a finished sequence, pushing its row back on the free-list
    /// (the next admit reuses it).
    pub fn release(&mut self, seq: u64) -> bool {
        match self.rows.remove(&seq) {
            Some(row) => {
                self.free.push(row);
                true
            }
            None => false,
        }
    }

    /// The resident slabs plus their row stride as raw parts:
    /// `(conv, ssm, stride)` (tests / legacy callers; the launch path
    /// uses the typed [`StateArena::slabs`] view). Zero-copy — the
    /// engine reads and writes arena rows in place.
    pub fn slab_mut(&mut self) -> (&mut [f32], &mut [f32], usize) {
        (&mut self.conv, &mut self.ssm, self.capacity)
    }

    /// Read-only view of the slabs (tests / diagnostics).
    pub fn slab(&self) -> (&[f32], &[f32], usize) {
        (&self.conv, &self.ssm, self.capacity)
    }

    /// The resident slabs wrapped as the typed [`StateSlabs`] view a
    /// [`LaunchSpec`](crate::runtime::LaunchSpec) carries — zero-copy;
    /// the engine reads and writes arena rows in place under the
    /// caller's [`Donation`] annotation.
    pub fn slabs(&mut self, donation: Donation) -> StateSlabs<'_> {
        StateSlabs::new(&mut self.conv, &mut self.ssm, self.capacity, donation)
    }

    /// Copy one sequence's state out as sequence-major `[layers, per]`
    /// buffers — the migration-detach payload and the snapshot-cache
    /// export path (one counted copy per completed session-tagged
    /// request; never on the per-tick hot path).
    pub fn snapshot(&self, seq: u64) -> Option<(Vec<f32>, Vec<f32>)> {
        let row = self.row_of(seq)?;
        let (cp, sp) = (self.conv_per_layer, self.ssm_per_layer);
        let mut conv = vec![0f32; self.n_layer * cp];
        let mut ssm = vec![0f32; self.n_layer * sp];
        copy_state_row(self.n_layer, cp, &self.conv, self.capacity, row, &mut conv, 1, 0);
        copy_state_row(self.n_layer, sp, &self.ssm, self.capacity, row, &mut ssm, 1, 0);
        Some((conv, ssm))
    }

    /// **Reference path**: gather the rows of a mixed batch into fresh
    /// packed layer-major buffers — `Some(seq)` rows copy the resident
    /// state, `None` rows are fresh sequences and stay zero. This is
    /// the pre-residency data path, kept for the equivalence tests and
    /// the traffic-counter baseline; every copied byte is counted.
    ///
    /// Panics if a `Some` sequence has no resident state.
    pub fn gather_rows(&mut self, rows: &[Option<u64>]) -> (Vec<f32>, Vec<f32>) {
        let batch = rows.len();
        let (cp, sp) = (self.conv_per_layer, self.ssm_per_layer);
        let per_seq = self.bytes_per_seq() as u64;
        let mut conv = vec![0f32; self.n_layer * batch * cp];
        let mut ssm = vec![0f32; self.n_layer * batch * sp];
        for (b, entry) in rows.iter().enumerate() {
            if let Some(seq) = entry {
                let row = self
                    .row_of(*seq)
                    .unwrap_or_else(|| panic!("missing state {seq}"));
                copy_state_row(self.n_layer, cp, &self.conv, self.capacity, row, &mut conv, batch, b);
                copy_state_row(self.n_layer, sp, &self.ssm, self.capacity, row, &mut ssm, batch, b);
                self.traffic.bytes_gathered += per_seq;
            }
        }
        (conv, ssm)
    }

    /// **Reference path**: install a sequence's state from a *packed
    /// batch* output at row `b` of `batch` (layer-major unpack),
    /// admitting the sequence if it has no row yet. Counted as
    /// scattered traffic.
    pub fn install_from_batch(
        &mut self,
        seq: u64,
        batch: usize,
        b: usize,
        conv_batch: &[f32],
        ssm_batch: &[f32],
    ) {
        let row = match self.rows.get(&seq) {
            Some(&row) => row,
            None => self.alloc_row(seq),
        };
        let (cp, sp) = (self.conv_per_layer, self.ssm_per_layer);
        let per_seq = self.bytes_per_seq() as u64;
        copy_state_row(self.n_layer, cp, conv_batch, batch, b, &mut self.conv, self.capacity, row);
        copy_state_row(self.n_layer, sp, ssm_batch, batch, b, &mut self.ssm, self.capacity, row);
        self.traffic.bytes_scattered += per_seq;
    }

    /// **Migration path**: splice a sequence's state *out* of this
    /// shard — copy it to sequence-major `[layers, per]` buffers and
    /// free the row in one step. The bytes are the inter-shard transfer
    /// payload, so they are **not** counted as gather/scatter traffic
    /// here; the scheduler counts them as `bytes_migrated` on the
    /// attaching side, exactly once per migration.
    pub fn detach_row(&mut self, seq: u64) -> Option<(Vec<f32>, Vec<f32>)> {
        let snap = self.snapshot(seq)?;
        self.release(seq);
        Some(snap)
    }

    /// **Migration path**: splice a migrated sequence's state *into*
    /// this shard from sequence-major `[layers, per]` buffers (the
    /// [`StateArena::detach_row`] payload of another shard). Allocates
    /// a row (free-list, growing if needed) and returns it. Not counted
    /// as gather/scatter traffic — see [`StateArena::detach_row`].
    pub fn attach_row(&mut self, seq: u64, conv: &[f32], ssm: &[f32]) -> usize {
        let (cp, sp) = (self.conv_per_layer, self.ssm_per_layer);
        assert_eq!(conv.len(), self.n_layer * cp, "attach conv payload shape");
        assert_eq!(ssm.len(), self.n_layer * sp, "attach ssm payload shape");
        let row = match self.rows.get(&seq) {
            Some(&row) => row,
            None => self.alloc_row(seq),
        };
        copy_state_row(self.n_layer, cp, conv, 1, 0, &mut self.conv, self.capacity, row);
        copy_state_row(self.n_layer, sp, ssm, 1, 0, &mut self.ssm, self.capacity, row);
        row
    }

    /// Allocate a row without zeroing (the caller overwrites it).
    fn alloc_row(&mut self, seq: u64) -> usize {
        let row = match self.free.pop() {
            Some(row) => row,
            None => {
                self.grow();
                self.free.pop().expect("grow refills the free-list")
            }
        };
        self.rows.insert(seq, row);
        self.peak = self.peak.max(self.rows.len());
        row
    }

    fn zero_row(&mut self, row: usize) {
        let (cp, sp) = (self.conv_per_layer, self.ssm_per_layer);
        for l in 0..self.n_layer {
            self.conv[(l * self.capacity + row) * cp..(l * self.capacity + row + 1) * cp]
                .fill(0.0);
            self.ssm[(l * self.capacity + row) * sp..(l * self.capacity + row + 1) * sp]
                .fill(0.0);
        }
    }

    /// Double the capacity, re-striding the layer-major slabs. Stable
    /// row indices are preserved; the relocation copies are counted as
    /// scattered traffic (bytes written into resident storage). The
    /// scheduler sizes the arena to the policy's slot cap, so growth
    /// never happens on its hot path.
    fn grow(&mut self) {
        let (cp, sp) = (self.conv_per_layer, self.ssm_per_layer);
        let old_cap = self.capacity;
        let new_cap = old_cap * 2;
        let mut conv = vec![0f32; self.n_layer * new_cap * cp];
        let mut ssm = vec![0f32; self.n_layer * new_cap * sp];
        for l in 0..self.n_layer {
            conv[l * new_cap * cp..l * new_cap * cp + old_cap * cp]
                .copy_from_slice(&self.conv[l * old_cap * cp..(l + 1) * old_cap * cp]);
            ssm[l * new_cap * sp..l * new_cap * sp + old_cap * sp]
                .copy_from_slice(&self.ssm[l * old_cap * sp..(l + 1) * old_cap * sp]);
        }
        self.traffic.bytes_scattered +=
            (self.n_layer * old_cap * (cp + sp) * 4) as u64;
        self.conv = conv;
        self.ssm = ssm;
        self.free.extend((old_cap..new_cap).rev());
        self.capacity = new_cap;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arena() -> StateArena {
        StateArena::new(2, 3, 4, 4)
    }

    #[test]
    fn install_gather_roundtrip() {
        let mut m = arena();
        // Batch of 2 in layer-major layout: layer0[s0,s1], layer1[s0,s1].
        let conv: Vec<f32> = (0..2 * 2 * 3).map(|x| x as f32).collect();
        let ssm: Vec<f32> = (100..100 + 2 * 2 * 4).map(|x| x as f32).collect();
        m.install_from_batch(7, 2, 0, &conv, &ssm);
        m.install_from_batch(9, 2, 1, &conv, &ssm);
        assert_eq!(m.len(), 2);
        let (c2, s2) = m.gather_rows(&[Some(7), Some(9)]);
        assert_eq!(c2, conv);
        assert_eq!(s2, ssm);
        // Two installs scattered, two gathers gathered.
        assert_eq!(m.traffic().bytes_scattered, 2 * m.bytes_per_seq() as u64);
        assert_eq!(m.traffic().bytes_gathered, 2 * m.bytes_per_seq() as u64);
    }

    #[test]
    fn gather_rows_mixes_stored_and_fresh() {
        let mut m = arena();
        let conv: Vec<f32> = (0..2 * 3).map(|x| x as f32 + 1.0).collect();
        let ssm: Vec<f32> = (0..2 * 4).map(|x| x as f32 + 50.0).collect();
        m.install_from_batch(7, 1, 0, &conv, &ssm);
        let (c, s) = m.gather_rows(&[None, Some(7), None]);
        assert_eq!(c.len(), 2 * 3 * 3);
        assert_eq!(s.len(), 2 * 3 * 4);
        for l in 0..2 {
            // Fresh rows 0 and 2 are zero; row 1 carries seq 7's state.
            assert!(c[(l * 3) * 3..(l * 3 + 1) * 3].iter().all(|&x| x == 0.0));
            assert!(c[(l * 3 + 2) * 3..(l * 3 + 3) * 3].iter().all(|&x| x == 0.0));
            assert_eq!(&c[(l * 3 + 1) * 3..(l * 3 + 2) * 3], &conv[l * 3..(l + 1) * 3]);
            assert_eq!(&s[(l * 3 + 1) * 4..(l * 3 + 2) * 4], &ssm[l * 4..(l + 1) * 4]);
        }
    }

    #[test]
    fn admit_zeroes_and_rows_are_stable() {
        let mut m = arena();
        let row = m.admit(5);
        assert_eq!(m.row_of(5), Some(row));
        // Dirty the row via the slab, then re-admit: zeroed again.
        {
            let (conv, _ssm, stride) = m.slab_mut();
            conv[row * 3] = 42.0;
            assert_eq!(stride, 4);
        }
        assert_eq!(m.admit(5), row, "re-admit keeps the same row");
        let (conv, ssm) = m.snapshot(5).unwrap();
        assert!(conv.iter().all(|&x| x == 0.0));
        assert!(ssm.iter().all(|&x| x == 0.0));
        // Admits and zeroing are not traffic.
        assert_eq!(m.traffic(), TrafficCounters::default());
    }

    #[test]
    fn release_frees_slot_and_lifo_reuses_it() {
        let mut m = arena();
        let r1 = m.admit(1);
        let r2 = m.admit(2);
        assert_ne!(r1, r2);
        assert!(m.release(1));
        assert!(!m.release(1));
        // LIFO: the freed row is the next one handed out.
        assert_eq!(m.admit(3), r1);
        assert_eq!(m.len(), 2);
        assert_eq!(m.peak(), 2);
        assert!(m.contains(3) && m.contains(2) && !m.contains(1));
    }

    #[test]
    fn grow_preserves_contents_and_row_indices() {
        let mut m = StateArena::new(2, 3, 4, 1);
        let conv: Vec<f32> = (0..2 * 3).map(|x| x as f32 + 1.0).collect();
        let ssm: Vec<f32> = (0..2 * 4).map(|x| x as f32 + 9.0).collect();
        m.install_from_batch(1, 1, 0, &conv, &ssm);
        let row1 = m.row_of(1).unwrap();
        let before = m.snapshot(1).unwrap();
        let scattered_before_grow = m.traffic().bytes_scattered;
        // Second admit exhausts capacity 1 → grow to 2.
        let row2 = m.admit(2);
        assert_eq!(m.capacity(), 2);
        assert_ne!(row1, row2);
        assert_eq!(m.row_of(1), Some(row1), "rows stay stable across growth");
        assert_eq!(m.snapshot(1).unwrap(), before, "contents survive re-striding");
        assert!(
            m.traffic().bytes_scattered > scattered_before_grow,
            "relocation is counted"
        );
    }

    #[test]
    fn take_traffic_drains() {
        let mut m = arena();
        let conv = vec![0f32; 6];
        let ssm = vec![0f32; 8];
        m.install_from_batch(5, 1, 0, &conv, &ssm);
        assert!(m.take_traffic().bytes_scattered > 0);
        assert_eq!(m.traffic(), TrafficCounters::default());
    }

    #[test]
    fn handles_are_shard_qualified_and_stable() {
        let mut m = arena();
        assert_eq!(m.shard(), 0);
        m.set_shard(3);
        let row = m.admit(7);
        assert_eq!(m.handle_of(7), Some(SlotHandle { shard: 3, row }));
        m.admit(8);
        assert_eq!(m.handle_of(7), Some(SlotHandle { shard: 3, row }), "handle stable");
        assert_eq!(m.handle_of(99), None);
    }

    #[test]
    fn detach_attach_round_trips_state_without_traffic() {
        let mut src = arena();
        let mut dst = arena();
        dst.set_shard(1);
        let conv: Vec<f32> = (0..2 * 3).map(|x| x as f32 + 1.0).collect();
        let ssm: Vec<f32> = (0..2 * 4).map(|x| x as f32 + 50.0).collect();
        src.install_from_batch(7, 1, 0, &conv, &ssm);
        src.take_traffic();

        let (pc, ps) = src.detach_row(7).expect("resident");
        // The payload is exactly one sequence's state.
        assert_eq!((pc.len() + ps.len()) * 4, src.bytes_per_seq());
        assert!(!src.contains(7), "detach frees the row");
        assert_eq!(src.resident_bytes(), 0);

        let row = dst.attach_row(7, &pc, &ps);
        assert_eq!(dst.handle_of(7), Some(SlotHandle { shard: 1, row }));
        assert_eq!(dst.snapshot(7).unwrap(), (conv, ssm), "state survives the move");
        // The transfer itself is not gather/scatter traffic (it is
        // counted as bytes_migrated by the scheduler, once).
        assert_eq!(src.traffic(), TrafficCounters::default());
        assert_eq!(dst.traffic(), TrafficCounters::default());
        assert_eq!(src.detach_row(7), None, "double detach is a no-op");
    }

    #[test]
    fn typed_slabs_view_matches_raw_slab() {
        let mut m = arena();
        m.admit(5);
        let (raw_conv_len, raw_ssm_len, stride) = {
            let (c, s, st) = m.slab();
            (c.len(), s.len(), st)
        };
        let mut view = m.slabs(Donation::DonateInPlace);
        assert_eq!(view.stride(), stride);
        assert_eq!(view.donation(), Donation::DonateInPlace);
        let (c, s) = view.slabs_mut();
        assert_eq!(c.len(), raw_conv_len);
        assert_eq!(s.len(), raw_ssm_len);
        // Writes through the view land in the arena (zero-copy).
        c[0] = 7.5;
        assert_eq!(m.slab().0[0], 7.5);
    }

    #[test]
    fn bytes_per_seq_and_resident_gauge() {
        let mut m = arena();
        assert_eq!(m.bytes_per_seq(), 2 * (3 + 4) * 4);
        assert_eq!(m.resident_bytes(), 0);
        m.admit(1);
        m.admit(2);
        assert_eq!(m.resident_bytes(), 2 * m.bytes_per_seq() as u64);
        m.release(1);
        assert_eq!(m.resident_bytes(), m.bytes_per_seq() as u64);
    }
}
