//! Slot-aware routing for the sharded state arena: the router-side
//! [`ShardMap`] (request → shard placement, tracked load), the
//! [`RouterPolicy`] migration heuristics (imbalance threshold,
//! per-request cooldown — the hysteresis that keeps alternating load
//! from thrashing state between workers), and the [`MigrationPacket`]
//! inter-shard transfer format.
//!
//! The paper's leader/worker split makes the router the leader and each
//! engine a worker. Pre-sharding, a request pinned to a hot worker
//! could only move by discarding its recurrent state and re-prefilling
//! — exactly the off-chip state round-trip Mambalaya's fusion mappings
//! exist to avoid. The migration protocol instead splices the resident
//! rows out of one shard's arena and into another's
//! ([`super::scheduler::Scheduler::detach`] /
//! [`super::scheduler::Scheduler::attach`]): a single
//! `state_bytes_per_seq` transfer, counted as `bytes_migrated`, with
//! the re-prefill it replaced counted as `reprefills_avoided`.
//!
//! Everything here is pure policy (no threads, no channels), so the
//! affinity / no-starvation / hysteresis properties are testable the
//! same way the batcher's invariants are (`rust/tests/router_properties.rs`).

use std::collections::{BTreeMap, BTreeSet};

use super::request::InFlight;

/// Tunable migration heuristics for the slot-aware router.
#[derive(Debug, Clone)]
pub struct RouterPolicy {
    /// Minimum load gap (hot − cold, in tracked in-flight requests)
    /// before a rebalance plans any migration. Moving one request
    /// changes the gap by 2, so a threshold of ≥ 2 makes ±1 load
    /// wiggles (one arrival / one completion) provably migration-free.
    pub migrate_threshold: usize,
    /// Max migrations planned per [`ShardMap::plan_rebalance`] call.
    pub max_moves_per_rebalance: usize,
    /// Rebalance rounds a freshly migrated request is pinned to its new
    /// shard (per-request hysteresis: alternating skew cannot ping-pong
    /// the same resident state back and forth every round).
    pub cooldown_rounds: u64,
}

impl Default for RouterPolicy {
    fn default() -> Self {
        RouterPolicy {
            migrate_threshold: 2,
            max_moves_per_rebalance: 4,
            cooldown_rounds: 2,
        }
    }
}

impl RouterPolicy {
    /// Clamp degenerate knob values (a zero threshold would migrate on
    /// every ±1 wiggle; zero moves would make rebalance a no-op
    /// forever, which is better expressed by not calling it).
    pub fn normalized(mut self) -> RouterPolicy {
        self.migrate_threshold = self.migrate_threshold.max(1);
        self.max_moves_per_rebalance = self.max_moves_per_rebalance.max(1);
        self
    }
}

/// One planned request move between shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Migration {
    pub seq: u64,
    pub from: usize,
    pub to: usize,
}

/// How the server realizes a planned migration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MigrationMode {
    /// Move the resident state rows between arenas (the point of the
    /// sharded design): one `state_bytes_per_seq` transfer.
    Move,
    /// Baseline for the counter gates: discard the state and rebuild it
    /// on the target worker by re-prefilling the already-processed
    /// tokens. Token outputs are identical; the cost shows up in the
    /// deterministic `reprefill_tokens` counter instead of
    /// `bytes_migrated`.
    Reprefill,
}

/// Outcome of one [`super::server::Server::rebalance`] pass.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MigrationOutcome {
    /// Moves the policy planned this round.
    pub planned: usize,
    /// Moves that actually landed (a plan can miss: the request may
    /// have completed, or not hold state yet).
    pub migrated: usize,
}

/// A live worker's load snapshot (queried over the worker channel).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkerLoad {
    pub shard: usize,
    /// Sequences currently generating.
    pub running: usize,
    /// Sequences waiting on (or mid-) prefill.
    pub waiting: usize,
    /// Bytes of recurrent state resident in this shard's arena.
    pub resident_bytes: u64,
}

/// The inter-shard transfer format: everything a worker needs to resume
/// an in-flight request exactly where the source worker left it — the
/// request bookkeeping (prompt, generated tokens, prefill cursor,
/// latency clocks) plus the sequence-major recurrent-state payload from
/// [`super::state::StateArena::detach_row`].
#[derive(Debug)]
pub struct MigrationPacket {
    /// The in-flight bookkeeping, moved verbatim (timing clocks keep
    /// running across the migration, so TTFT/latency stay honest).
    pub flight: InFlight,
    /// The source slot the state was detached from (handle provenance:
    /// its `shard` differs from the attaching arena's).
    pub from: super::state::SlotHandle,
    /// Sequence-major `[layers, conv_per_layer]` state payload.
    pub conv: Vec<f32>,
    /// Sequence-major `[layers, ssm_per_layer]` state payload.
    pub ssm: Vec<f32>,
}

impl MigrationPacket {
    pub fn seq(&self) -> u64 {
        self.flight.req.id
    }

    /// True when the request finished prefill (it is generating), so
    /// moving its state avoids re-prefilling the *whole* prompt plus
    /// the generated suffix.
    pub fn decode_phase(&self) -> bool {
        self.flight.prefill_pos >= self.flight.req.prompt.len()
    }

    /// Bytes of state this packet carries — exactly
    /// `state_bytes_per_seq` (the conservation law the conformance
    /// suite checks).
    pub fn state_bytes(&self) -> u64 {
        ((self.conv.len() + self.ssm.len()) * 4) as u64
    }

    /// Tokens the target worker would have to re-process to rebuild
    /// this state by re-prefilling (the cost migration avoids): for
    /// decode-phase requests the full prompt plus the generated suffix
    /// not already folded into it by a previous re-prefill (all but the
    /// pending last token); for mid-prefill ones, the prefill cursor.
    pub fn reprefill_cost_tokens(&self) -> usize {
        if self.decode_phase() {
            self.flight.req.prompt.len()
                + self
                    .flight
                    .generated
                    .len()
                    .saturating_sub(1)
                    .saturating_sub(self.flight.prompt_replayed)
        } else {
            self.flight.prefill_pos
        }
    }
}

/// The router's request → shard placement map with tracked per-shard
/// load and migration hysteresis state. Pure bookkeeping: the server
/// feeds it submissions, completion notifications and rebalance rounds;
/// it answers "where does this request go" and "what should move".
#[derive(Debug)]
pub struct ShardMap {
    placement: BTreeMap<u64, usize>,
    /// Tracked in-flight requests per shard (the routing load signal).
    counts: Vec<usize>,
    /// Rebalance round a migrated request is pinned until.
    cooldown_until: BTreeMap<u64, u64>,
    /// Monotone rebalance-round clock.
    round: u64,
    /// Shards retired by supervision (worker dead, not respawned):
    /// excluded from placement and from rebalance targets until
    /// [`ShardMap::revive`].
    dead: Vec<bool>,
}

impl ShardMap {
    pub fn new(n_shards: usize) -> ShardMap {
        let n = n_shards.max(1);
        ShardMap {
            placement: BTreeMap::new(),
            counts: vec![0; n],
            cooldown_until: BTreeMap::new(),
            round: 0,
            dead: vec![false; n],
        }
    }

    pub fn n_shards(&self) -> usize {
        self.counts.len()
    }

    /// Tracked in-flight requests per shard.
    pub fn loads(&self) -> &[usize] {
        &self.counts
    }

    /// Tracked in-flight requests overall.
    pub fn len(&self) -> usize {
        self.placement.len()
    }

    pub fn is_empty(&self) -> bool {
        self.placement.is_empty()
    }

    pub fn shard_of(&self, seq: u64) -> Option<usize> {
        self.placement.get(&seq).copied()
    }

    /// Route a new request: least-loaded **live** shard, ties to the
    /// lowest index. Records the placement. When every shard is dead
    /// this falls back to the plain least-loaded pick (callers that
    /// care check [`ShardMap::has_live`] first and fail the request
    /// terminally instead of sending into a void).
    pub fn place(&mut self, seq: u64) -> usize {
        let live = self
            .counts
            .iter()
            .enumerate()
            .filter(|&(i, _)| !self.dead[i])
            .min_by_key(|&(i, &c)| (c, i))
            .map(|(i, _)| i);
        let shard = live.unwrap_or_else(|| {
            self.counts
                .iter()
                .enumerate()
                .min_by_key(|&(i, &c)| (c, i))
                .map(|(i, _)| i)
                .expect("at least one shard")
        });
        self.assign(seq, shard);
        shard
    }

    /// Retire a dead shard: mark it unroutable and drop every tracked
    /// placement on it, reconciling the load counter to zero (its
    /// completions will never arrive on `done_rx`, so without this the
    /// tracked load over-counts forever and skews least-load placement
    /// for the rest of the process). Returns the orphaned sequence ids
    /// — the supervisor re-routes the ones it salvaged and fails the
    /// rest terminally.
    pub fn retire(&mut self, shard: usize) -> Vec<u64> {
        if shard >= self.counts.len() {
            return Vec::new();
        }
        self.dead[shard] = true;
        let orphans: Vec<u64> = self
            .placement
            .iter()
            .filter_map(|(&seq, &sh)| (sh == shard).then_some(seq))
            .collect();
        for &seq in &orphans {
            self.placement.remove(&seq);
            self.cooldown_until.remove(&seq);
        }
        self.counts[shard] = 0;
        orphans
    }

    /// Bring a respawned shard back into routing.
    pub fn revive(&mut self, shard: usize) {
        if shard < self.dead.len() {
            self.dead[shard] = false;
        }
    }

    /// True if `shard` is retired (or out of range).
    pub fn is_dead(&self, shard: usize) -> bool {
        self.dead.get(shard).copied().unwrap_or(true)
    }

    /// True while at least one shard is routable.
    pub fn has_live(&self) -> bool {
        self.dead.iter().any(|&d| !d)
    }

    /// Record a forced placement (or correct one after a migration):
    /// moves the tracked load with the request.
    pub fn assign(&mut self, seq: u64, shard: usize) {
        let shard = shard.min(self.counts.len() - 1);
        if let Some(old) = self.placement.insert(seq, shard) {
            self.counts[old] -= 1;
        }
        self.counts[shard] += 1;
    }

    /// A request completed: drop it from tracking. Unknown ids are a
    /// no-op (completion notifications can race a migration plan).
    pub fn complete(&mut self, seq: u64) -> bool {
        match self.placement.remove(&seq) {
            Some(shard) => {
                self.counts[shard] -= 1;
                self.cooldown_until.remove(&seq);
                true
            }
            None => false,
        }
    }

    /// Plan one rebalance round: repeatedly move one request from the
    /// most- to the least-loaded shard while the gap *strictly exceeds*
    /// the policy threshold, skipping requests still in their
    /// post-migration cooldown. Pure planning — placements are not
    /// touched; the server calls [`ShardMap::apply`] for each move that
    /// actually lands (a plan can miss when the request completed or
    /// does not hold state yet) and [`ShardMap::defer`] for each miss.
    pub fn plan_rebalance(&mut self, pol: &RouterPolicy) -> Vec<Migration> {
        let pol = pol.clone().normalized();
        self.round += 1;
        let mut counts = self.counts.clone();
        let mut planned: Vec<Migration> = Vec::new();
        let mut moved: BTreeSet<u64> = BTreeSet::new();
        while planned.len() < pol.max_moves_per_rebalance {
            // Dead shards are never rebalance endpoints: they hold no
            // load after `retire` (so they cannot be hot) and must not
            // receive moves (so they cannot be cold).
            let Some(hot) = counts
                .iter()
                .enumerate()
                .filter(|&(i, _)| !self.dead[i])
                .max_by_key(|&(i, &c)| (c, std::cmp::Reverse(i)))
                .map(|(i, _)| i)
            else {
                break;
            };
            let Some(cold) = counts
                .iter()
                .enumerate()
                .filter(|&(i, _)| !self.dead[i])
                .min_by_key(|&(i, &c)| (c, i))
                .map(|(i, _)| i)
            else {
                break;
            };
            if counts[hot] <= counts[cold] + pol.migrate_threshold {
                break;
            }
            // Smallest-id movable request on the hot shard (oldest
            // first — deterministic and biased toward requests that
            // already hold state).
            // A request applied/deferred at round r is pinned through
            // round r + cooldown (movable again at r + cooldown + 1).
            let seq = self.placement.iter().find_map(|(&s, &sh)| {
                let cooling =
                    self.cooldown_until.get(&s).map_or(false, |&until| until >= self.round);
                (sh == hot && !cooling && !moved.contains(&s)).then_some(s)
            });
            let Some(seq) = seq else { break };
            counts[hot] -= 1;
            counts[cold] += 1;
            moved.insert(seq);
            planned.push(Migration { seq, from: hot, to: cold });
        }
        planned
    }

    /// A planned move landed: update the placement and start the
    /// request's cooldown.
    pub fn apply(&mut self, m: &Migration, pol: &RouterPolicy) {
        self.assign(m.seq, m.to);
        self.cooldown_until.insert(m.seq, self.round + pol.cooldown_rounds);
    }

    /// A planned move missed because the request is not migratable
    /// *yet* (no resident state): leave the placement alone but start a
    /// cooldown so the next rounds don't retry it immediately. (A move
    /// that missed because the request *completed* is reconciled by the
    /// worker's completion notification instead.)
    pub fn defer(&mut self, seq: u64, pol: &RouterPolicy) {
        self.cooldown_until.insert(seq, self.round + pol.cooldown_rounds);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn place_balances_and_complete_releases() {
        let mut m = ShardMap::new(3);
        for seq in 0..6u64 {
            m.place(seq);
        }
        assert_eq!(m.loads(), &[2, 2, 2]);
        assert_eq!(m.len(), 6);
        assert!(m.complete(0));
        assert!(!m.complete(0), "double completion is a no-op");
        assert_eq!(m.loads(), &[1, 2, 2]);
        // The freed capacity is the next placement target.
        assert_eq!(m.place(100), 0);
    }

    #[test]
    fn assign_moves_tracked_load() {
        let mut m = ShardMap::new(2);
        m.assign(1, 0);
        m.assign(2, 0);
        assert_eq!(m.loads(), &[2, 0]);
        m.assign(1, 1);
        assert_eq!(m.loads(), &[1, 1]);
        assert_eq!(m.shard_of(1), Some(1));
    }

    #[test]
    fn plan_moves_from_hot_to_cold_until_threshold() {
        let mut m = ShardMap::new(2);
        for seq in 0..8u64 {
            m.assign(seq, 0);
        }
        let pol = RouterPolicy { max_moves_per_rebalance: 16, ..RouterPolicy::default() };
        let plan = m.plan_rebalance(&pol);
        // 8 vs 0 with threshold 2: plans converge to a gap of ≤ 2.
        assert_eq!(plan.len(), 3);
        for mv in &plan {
            assert_eq!((mv.from, mv.to), (0, 1));
            m.apply(mv, &pol);
        }
        assert_eq!(m.loads(), &[5, 3]);
        // Planning is pure: nothing moved until apply.
        assert!(m.plan_rebalance(&pol).is_empty(), "gap of 2 is within threshold");
    }

    #[test]
    fn cooldown_pins_migrated_requests() {
        let mut m = ShardMap::new(2);
        for seq in 0..4u64 {
            m.assign(seq, 0);
        }
        let pol = RouterPolicy {
            migrate_threshold: 1,
            cooldown_rounds: 100,
            ..RouterPolicy::default()
        };
        let plan = m.plan_rebalance(&pol);
        assert!(!plan.is_empty());
        for mv in &plan {
            m.apply(mv, &pol);
        }
        // Pile the load back onto shard 1 by hand: every movable
        // candidate there is now cooling, so nothing plans.
        for seq in 10..16u64 {
            m.assign(seq, 1);
            m.defer(seq, &pol);
        }
        assert!(m.plan_rebalance(&pol).is_empty(), "cooldown must pin all candidates");
    }

    #[test]
    fn retire_reconciles_load_and_routes_around_the_dead_shard() {
        let mut m = ShardMap::new(2);
        for seq in 0..4u64 {
            m.place(seq);
        }
        assert_eq!(m.loads(), &[2, 2]);
        let orphans = m.retire(0);
        assert_eq!(orphans, vec![0, 2], "shard 0 held the even placements");
        assert!(m.is_dead(0));
        assert!(m.has_live());
        // Tracked load is reconciled, not leaked: the dead shard's
        // completions will never arrive, so its counter must be zero.
        assert_eq!(m.loads(), &[0, 2]);
        // Placement routes around the dead shard even though it now
        // reads as least-loaded.
        for seq in 10..14u64 {
            assert_eq!(m.place(seq), 1);
        }
        m.revive(0);
        assert!(!m.is_dead(0));
        assert_eq!(m.place(99), 0, "revived shard is the cold target again");
    }

    #[test]
    fn retire_everything_still_places_but_reports_no_live() {
        let mut m = ShardMap::new(1);
        m.place(1);
        let orphans = m.retire(0);
        assert_eq!(orphans, vec![1]);
        assert!(!m.has_live());
        // Fallback placement stays in range; callers gate on has_live.
        assert_eq!(m.place(2), 0);
        assert!(m.retire(9).is_empty(), "out-of-range retire is a no-op");
        assert!(m.is_dead(9), "out-of-range shards are never routable");
    }

    #[test]
    fn plan_rebalance_never_targets_a_dead_shard() {
        let mut m = ShardMap::new(3);
        for seq in 0..8u64 {
            m.assign(seq, 0);
        }
        m.retire(2);
        let pol = RouterPolicy { max_moves_per_rebalance: 16, ..RouterPolicy::default() };
        let plan = m.plan_rebalance(&pol);
        assert!(!plan.is_empty());
        for mv in &plan {
            assert_eq!((mv.from, mv.to), (0, 1), "dead shard 2 must not be the cold target");
            m.apply(mv, &pol);
        }
    }
}
