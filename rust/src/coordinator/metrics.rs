//! Serving metrics: latency percentiles, throughput, batch occupancy,
//! continuous-batching health (chunk counts, per-tick token cost,
//! prefill queue depth), and **state-traffic accounting**
//! (bytes gathered/scattered, padded decode rows — the host-side
//! analogue of the paper's inter-operator memory-traffic numbers).
//! All counters are monotone non-decreasing — tests rely on that to
//! detect double-counting. `state_bytes_resident` is the one gauge.

use std::time::Instant;

use crate::runtime::engine::TrafficCounters;

/// A machine-readable snapshot of the state-traffic counters, for
/// aggregation across workers and for the bench JSON output.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TrafficSnapshot {
    /// State bytes copied out of resident storage / between staging.
    pub bytes_gathered: u64,
    /// State bytes copied into resident storage.
    pub bytes_scattered: u64,
    /// Gauge: bytes of recurrent state currently resident.
    pub state_bytes_resident: u64,
    /// Padded rows shipped to compiled decode batches.
    pub padded_rows: u64,
}

/// Online metrics collector (single scheduler thread, no locking).
#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    pub requests_completed: u64,
    pub tokens_generated: u64,
    /// Ticks that admitted at least one prefill chunk.
    pub prefill_batches: u64,
    /// Prefill chunk rows admitted (≥ `prefill_batches`).
    pub prefill_chunks: u64,
    /// Prompt tokens prefilled.
    pub prefill_tokens: u64,
    pub decode_steps: u64,
    /// Mixed engine invocations.
    pub ticks: u64,
    /// Largest token cost (chunk tokens + decode rows) of any tick —
    /// bounded by the policy's `token_budget`, which is what keeps long
    /// prompts from stalling decode for whole ticks.
    pub max_tick_tokens: u64,
    /// State bytes copied out of resident storage (or between staging
    /// buffers) — zero on the resident path with a fused engine.
    pub bytes_gathered: u64,
    /// State bytes copied back into resident storage.
    pub bytes_scattered: u64,
    /// Gauge (not monotone): bytes of recurrent state resident in the
    /// arena after the most recent tick.
    pub state_bytes_resident: u64,
    /// Padded rows shipped to compiled decode batches by the default
    /// engine decomposition (a fused engine pads nothing).
    pub padded_rows: u64,
    /// Sum of (tick tokens / token budget) per tick, for mean budget
    /// utilization. (Engine-level padding to compiled batch sizes
    /// happens inside `step_mixed_into` and surfaces as `padded_rows`.)
    occupancy_sum: f64,
    /// Prefill queue depth sampled each tick.
    queue_depth_sum: f64,
    queue_samples: u64,
    ttft: Vec<f64>,
    total: Vec<f64>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            started: Instant::now(),
            requests_completed: 0,
            tokens_generated: 0,
            prefill_batches: 0,
            prefill_chunks: 0,
            prefill_tokens: 0,
            decode_steps: 0,
            ticks: 0,
            max_tick_tokens: 0,
            bytes_gathered: 0,
            bytes_scattered: 0,
            state_bytes_resident: 0,
            padded_rows: 0,
            occupancy_sum: 0.0,
            queue_depth_sum: 0.0,
            queue_samples: 0,
            ttft: Vec::new(),
            total: Vec::new(),
        }
    }

    /// Record the prefill side of a tick: `chunks` chunk rows totalling
    /// `tokens` prompt tokens.
    pub fn record_prefill(&mut self, chunks: usize, tokens: usize) {
        self.prefill_batches += 1;
        self.prefill_chunks += chunks as u64;
        self.prefill_tokens += tokens as u64;
    }

    /// Record sampled tokens: one call per tick that ran decode rows
    /// (`active` = rows), plus one per prefill-completing chunk.
    pub fn record_decode(&mut self, active: usize) {
        self.decode_steps += 1;
        self.tokens_generated += active as u64;
    }

    /// Record per-tick health: total token cost vs the policy budget,
    /// and the prefill queue depth.
    pub fn record_tick(&mut self, tick_tokens: usize, token_budget: usize, queue_depth: usize) {
        self.ticks += 1;
        self.max_tick_tokens = self.max_tick_tokens.max(tick_tokens as u64);
        self.occupancy_sum += tick_tokens as f64 / token_budget.max(1) as f64;
        self.queue_depth_sum += queue_depth as f64;
        self.queue_samples += 1;
    }

    /// Record one tick's state traffic: the bytes actually copied
    /// (counter deltas drained from the arena and workspace), the
    /// current resident-state gauge, and padded decode rows.
    pub fn record_traffic(&mut self, traffic: TrafficCounters, resident: u64, padded: u64) {
        self.bytes_gathered += traffic.bytes_gathered;
        self.bytes_scattered += traffic.bytes_scattered;
        self.state_bytes_resident = resident;
        self.padded_rows += padded;
    }

    /// Snapshot of the traffic counters (aggregation / bench JSON).
    pub fn traffic_snapshot(&self) -> TrafficSnapshot {
        TrafficSnapshot {
            bytes_gathered: self.bytes_gathered,
            bytes_scattered: self.bytes_scattered,
            state_bytes_resident: self.state_bytes_resident,
            padded_rows: self.padded_rows,
        }
    }

    pub fn record_completion(&mut self, ttft: f64, total: f64) {
        self.requests_completed += 1;
        self.ttft.push(ttft);
        self.total.push(total);
    }

    fn pct(sorted: &[f64], p: f64) -> f64 {
        if sorted.is_empty() {
            return 0.0;
        }
        let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
        sorted[idx.min(sorted.len() - 1)]
    }

    /// TTFT percentile over completed requests (`p` in [0, 1]).
    pub fn ttft_pct(&self, p: f64) -> f64 {
        let mut v = self.ttft.clone();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self::pct(&v, p)
    }

    /// Completed requests with a recorded TTFT (monotone).
    pub fn ttft_count(&self) -> usize {
        self.ttft.len()
    }

    /// Snapshot as a human-readable report.
    pub fn report(&self) -> String {
        let elapsed = self.started.elapsed().as_secs_f64().max(1e-9);
        let mut ttft = self.ttft.clone();
        let mut total = self.total.clone();
        ttft.sort_by(|a, b| a.partial_cmp(b).unwrap());
        total.sort_by(|a, b| a.partial_cmp(b).unwrap());
        format!(
            "requests={} tokens={} ({:.1} tok/s) chunks={} prefill_tokens={} decode_steps={} \
             ticks={} max_tick_tokens={} queue={:.1} budget_use={:.2} \
             gathered={}B scattered={}B resident={}B padded_rows={} \
             ttft p50={:.1}ms p99={:.1}ms latency p50={:.1}ms p99={:.1}ms",
            self.requests_completed,
            self.tokens_generated,
            self.tokens_generated as f64 / elapsed,
            self.prefill_chunks,
            self.prefill_tokens,
            self.decode_steps,
            self.ticks,
            self.max_tick_tokens,
            self.mean_queue_depth(),
            self.mean_occupancy(),
            self.bytes_gathered,
            self.bytes_scattered,
            self.state_bytes_resident,
            self.padded_rows,
            Self::pct(&ttft, 0.5) * 1e3,
            Self::pct(&ttft, 0.99) * 1e3,
            Self::pct(&total, 0.5) * 1e3,
            Self::pct(&total, 0.99) * 1e3,
        )
    }

    /// Mean fraction of the per-tick token budget actually used.
    pub fn mean_occupancy(&self) -> f64 {
        self.occupancy_sum / self.ticks.max(1) as f64
    }

    /// Mean prefill queue depth over tick samples.
    pub fn mean_queue_depth(&self) -> f64 {
        self.queue_depth_sum / self.queue_samples.max(1) as f64
    }

    pub fn throughput(&self) -> f64 {
        self.tokens_generated as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.record_prefill(2, 64);
        m.record_decode(2);
        m.record_decode(4);
        m.record_tick(66, 88, 3);
        m.record_tick(5, 10, 1);
        m.record_traffic(
            TrafficCounters { bytes_gathered: 100, bytes_scattered: 60 },
            512,
            2,
        );
        m.record_traffic(
            TrafficCounters { bytes_gathered: 40, bytes_scattered: 0 },
            256,
            0,
        );
        m.record_completion(0.001, 0.010);
        assert_eq!(m.tokens_generated, 6);
        assert_eq!(m.decode_steps, 2);
        assert_eq!(m.prefill_chunks, 2);
        assert_eq!(m.prefill_tokens, 64);
        assert_eq!(m.ticks, 2);
        assert_eq!(m.max_tick_tokens, 66);
        assert!((m.mean_queue_depth() - 2.0).abs() < 1e-9);
        // (66/88 + 5/10) / 2 ticks
        assert!((m.mean_occupancy() - 0.625).abs() < 1e-9);
        assert_eq!(m.ttft_count(), 1);
        // Traffic: counters accumulate, the resident gauge tracks the
        // latest sample.
        assert_eq!(m.bytes_gathered, 140);
        assert_eq!(m.bytes_scattered, 60);
        assert_eq!(m.state_bytes_resident, 256);
        assert_eq!(m.padded_rows, 2);
        let snap = m.traffic_snapshot();
        assert_eq!(snap.bytes_gathered, 140);
        assert_eq!(snap.state_bytes_resident, 256);
        let r = m.report();
        assert!(r.contains("requests=1"));
        assert!(r.contains("max_tick_tokens=66"));
        assert!(r.contains("gathered=140B"));
        assert!(r.contains("scattered=60B"));
        assert!(r.contains("resident=256B"));
        assert!(r.contains("padded_rows=2"));
    }

    #[test]
    fn percentiles() {
        let v: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        let p50 = Metrics::pct(&v, 0.5);
        assert!((50.0..=51.0).contains(&p50), "p50 = {p50}");
        assert_eq!(Metrics::pct(&v, 0.99), 99.0);
        assert_eq!(Metrics::pct(&[], 0.5), 0.0);
        let mut m = Metrics::new();
        m.record_completion(0.002, 0.01);
        m.record_completion(0.004, 0.02);
        assert!(m.ttft_pct(0.99) >= m.ttft_pct(0.0));
    }
}
