//! Serving metrics: latency percentiles, throughput, batch occupancy,
//! continuous-batching health (chunk counts, per-tick token cost,
//! prefill queue depth), **state-traffic accounting**
//! (bytes gathered/scattered, padded decode rows — the host-side
//! analogue of the paper's inter-operator memory-traffic numbers), and
//! **plan-selection accounting** (which fusion plan each tick executed,
//! switch counts with dwell-length histogram, and predicted-vs-modeled
//! device cost so CI can gate on predictor sanity).
//! All counters are monotone non-decreasing — tests rely on that to
//! detect double-counting. `state_bytes_resident` is the one gauge.

use std::time::Instant;

use crate::obs::Histogram;
use crate::planner::{PlanChoice, PlanDecision};
use crate::runtime::engine::TrafficCounters;

/// Dwell-length histogram buckets (ticks a plan ran before a switch):
/// `1`, `2`, `3..=4`, `5..=8`, `9..=16`, `17..=32`, `33..=64`, `65+`.
pub const DWELL_BUCKETS: usize = 8;

/// Number of serving priority classes (`frontend::Priority` indexes
/// into the per-class arrays below; defined here so the coordinator's
/// counter layer never depends on the front-end that sits above it).
pub const PRIORITY_CLASSES: usize = 3;

/// Histogram bucket for a dwell length.
fn dwell_bucket(dwell: u64) -> usize {
    match dwell {
        0 | 1 => 0,
        2 => 1,
        3..=4 => 2,
        5..=8 => 3,
        9..=16 => 4,
        17..=32 => 5,
        33..=64 => 6,
        _ => 7,
    }
}

/// A machine-readable snapshot of the state-traffic and plan-selection
/// counters, for aggregation across workers and for the bench JSON
/// output.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TrafficSnapshot {
    /// Requests completed on this worker — the independent counter the
    /// trace's `Completed` events must reconcile against exactly
    /// ([`crate::obs::reconcile`]).
    pub requests_completed: u64,
    /// Requests refused by the serving front-end's admission layer.
    /// Recorded at the router (workers never see a shed request), and
    /// folded into the server-wide totals like dead-worker counters.
    pub requests_shed: u64,
    /// Shed requests per priority class ([`PRIORITY_CLASSES`]).
    pub shed_by_class: [u64; PRIORITY_CLASSES],
    /// Admitted requests per priority class, recorded at the router
    /// when the front-end's admission layer is in play (all-zero for
    /// in-process callers that bypass admission).
    pub admitted_by_class: [u64; PRIORITY_CLASSES],
    /// State bytes copied out of resident storage / between staging.
    pub bytes_gathered: u64,
    /// State bytes copied into resident storage.
    pub bytes_scattered: u64,
    /// Gauge: bytes of recurrent state currently resident.
    pub state_bytes_resident: u64,
    /// Padded rows shipped to compiled decode batches.
    pub padded_rows: u64,
    /// Device launches (compiled-executable invocations): one per tick
    /// on a fused varlen engine, `max(chunk)`-ish per tick for the
    /// default decomposition.
    pub device_calls: u64,
    /// Migrations *attached* on this worker (counting on the receiving
    /// side only keeps the server-wide sum exact: one per move).
    pub migrations: u64,
    /// State bytes installed by migration attaches — exactly
    /// `state_bytes_per_seq` per state-carrying move.
    pub bytes_migrated: u64,
    /// Migrations of decode-phase requests, each of which would
    /// otherwise have re-prefilled its whole processed history.
    pub reprefills_avoided: u64,
    /// Already-processed tokens re-prefilled by `Reprefill`-mode
    /// migrations (the baseline cost the state move eliminates).
    pub reprefill_tokens: u64,
    /// Session snapshots stored into the snapshot cache on request
    /// completion (one counted `state_bytes_per_seq` copy each).
    pub snapshots_stored: u64,
    /// Follow-up submissions that attached a cached session snapshot
    /// instead of prefilling their history.
    pub snapshot_hits: u64,
    /// Copy-on-write session forks (best-of-N / parallel sampling);
    /// forks share the parent payload, so they add zero cached bytes.
    pub snapshot_forks: u64,
    /// State bytes restored from the snapshot cache into the arena —
    /// exactly `state_bytes_per_seq` per hit.
    pub snapshot_bytes_restored: u64,
    /// History tokens a snapshot attach skipped (the prefill work a
    /// session-less submit would have paid to rebuild the same state).
    pub prefill_tokens_skipped: u64,
    /// Snapshot-cache entries evicted by the LRU byte budget.
    pub snapshot_evictions: u64,
    /// Gauge: unique payload bytes held by the snapshot cache (shared
    /// fork payloads counted once).
    pub snapshot_bytes_cached: u64,
    /// Plan switches the planner performed.
    pub plan_switches: u64,
    /// Ticks executed under each plan, indexed by
    /// [`PlanChoice::index`].
    pub ticks_per_plan: [u64; PlanChoice::COUNT],
    /// Dwell lengths at switch points, histogrammed over
    /// [`DWELL_BUCKETS`].
    pub plan_dwell_hist: [u64; DWELL_BUCKETS],
    /// Planner-predicted device cost, summed over ticks.
    pub predicted_cycles: u64,
    pub predicted_bytes: u64,
    /// Engine-modeled device cost, summed over ticks (the mock charges
    /// the executed plan's analytical cost; zero on engines that don't
    /// model it).
    pub modeled_cycles: u64,
    pub modeled_bytes: u64,
}

impl TrafficSnapshot {
    /// Accumulate another worker's snapshot into this one. Counters
    /// sum; the `state_bytes_resident` *gauge* also sums — per-shard
    /// residency is disjoint (a migrated row is resident on exactly one
    /// shard at any instant), so the sum is the global gauge, never a
    /// double count.
    pub fn accumulate(&mut self, t: &TrafficSnapshot) {
        self.requests_completed += t.requests_completed;
        self.requests_shed += t.requests_shed;
        for (a, b) in self.shed_by_class.iter_mut().zip(&t.shed_by_class) {
            *a += b;
        }
        for (a, b) in self.admitted_by_class.iter_mut().zip(&t.admitted_by_class) {
            *a += b;
        }
        self.bytes_gathered += t.bytes_gathered;
        self.bytes_scattered += t.bytes_scattered;
        self.state_bytes_resident += t.state_bytes_resident;
        self.padded_rows += t.padded_rows;
        self.device_calls += t.device_calls;
        self.migrations += t.migrations;
        self.bytes_migrated += t.bytes_migrated;
        self.reprefills_avoided += t.reprefills_avoided;
        self.reprefill_tokens += t.reprefill_tokens;
        self.snapshots_stored += t.snapshots_stored;
        self.snapshot_hits += t.snapshot_hits;
        self.snapshot_forks += t.snapshot_forks;
        self.snapshot_bytes_restored += t.snapshot_bytes_restored;
        self.prefill_tokens_skipped += t.prefill_tokens_skipped;
        self.snapshot_evictions += t.snapshot_evictions;
        // Like the resident gauge: per-worker snapshot caches are
        // disjoint (sessions pin to one shard), so summing the cached
        // gauge yields the global figure.
        self.snapshot_bytes_cached += t.snapshot_bytes_cached;
        self.plan_switches += t.plan_switches;
        for (a, b) in self.ticks_per_plan.iter_mut().zip(&t.ticks_per_plan) {
            *a += b;
        }
        for (a, b) in self.plan_dwell_hist.iter_mut().zip(&t.plan_dwell_hist) {
            *a += b;
        }
        self.predicted_cycles += t.predicted_cycles;
        self.predicted_bytes += t.predicted_bytes;
        self.modeled_cycles += t.modeled_cycles;
        self.modeled_bytes += t.modeled_bytes;
    }

    /// The plan most ticks executed under, with its tick count.
    pub fn dominant_plan(&self) -> Option<(PlanChoice, u64)> {
        let all = PlanChoice::all();
        self.ticks_per_plan
            .iter()
            .enumerate()
            .filter(|(_, &t)| t > 0)
            .max_by_key(|(_, &t)| t)
            .map(|(i, &t)| (all[i], t))
    }

    /// Modeled-over-predicted cycle ratio (predictor sanity; 1.0 when
    /// the engine behaves exactly as predicted, 0.0 when nothing was
    /// predicted).
    pub fn prediction_error(&self) -> f64 {
        if self.predicted_cycles == 0 {
            return 0.0;
        }
        self.modeled_cycles as f64 / self.predicted_cycles as f64
    }

    /// `name:ticks` pairs for every plan that ran (`-` when none) —
    /// shared by the report line and the serving CLIs.
    pub fn plans_summary(&self) -> String {
        let all = PlanChoice::all();
        let parts: Vec<String> = self
            .ticks_per_plan
            .iter()
            .enumerate()
            .filter(|(_, &t)| t > 0)
            .map(|(i, &t)| format!("{}:{}", all[i].name(), t))
            .collect();
        if parts.is_empty() {
            "-".to_string()
        } else {
            parts.join(",")
        }
    }
}

/// Mergeable latency distributions, queried per worker and folded
/// into a server-wide view with [`Histogram::merge`] (per-worker
/// percentiles cannot be averaged; merged bucket counts can).
///
/// Two unit families deliberately ride together: `*_ticks` histograms
/// are denominated in the scheduler's deterministic tick clock (same
/// workload, same numbers, every run — what CI gates and
/// `BENCH_trajectory.json` record), `*_us` in wall microseconds
/// (reporting only, never gated). Kept out of [`TrafficSnapshot`] so
/// snapshot equality comparisons stay about traffic, not timing.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LatencyReport {
    /// Wall-clock time-to-first-token, microseconds.
    pub ttft_us: Histogram,
    /// Wall-clock total request latency, microseconds.
    pub total_us: Histogram,
    /// Submit→first-token, scheduler ticks (deterministic).
    pub ttft_ticks: Histogram,
    /// Submit→completion, scheduler ticks (deterministic).
    pub total_ticks: Histogram,
    /// Gap between consecutive generated tokens, scheduler ticks
    /// (deterministic; 1 on every tick a request decodes without
    /// waiting).
    pub inter_token_ticks: Histogram,
}

impl LatencyReport {
    /// Fold another worker's distributions into this one.
    pub fn merge(&mut self, other: &LatencyReport) {
        self.ttft_us.merge(&other.ttft_us);
        self.total_us.merge(&other.total_us);
        self.ttft_ticks.merge(&other.ttft_ticks);
        self.total_ticks.merge(&other.total_ticks);
        self.inter_token_ticks.merge(&other.inter_token_ticks);
    }
}

/// Online metrics collector (single scheduler thread, no locking).
#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    pub requests_completed: u64,
    pub tokens_generated: u64,
    /// Ticks that admitted at least one prefill chunk.
    pub prefill_batches: u64,
    /// Prefill chunk rows admitted (≥ `prefill_batches`).
    pub prefill_chunks: u64,
    /// Prompt tokens prefilled.
    pub prefill_tokens: u64,
    pub decode_steps: u64,
    /// Mixed engine invocations.
    pub ticks: u64,
    /// Largest token cost (chunk tokens + decode rows) of any tick —
    /// bounded by the policy's `token_budget`, which is what keeps long
    /// prompts from stalling decode for whole ticks.
    pub max_tick_tokens: u64,
    /// State bytes copied out of resident storage (or between staging
    /// buffers) — zero on the resident path with a fused engine.
    pub bytes_gathered: u64,
    /// State bytes copied back into resident storage.
    pub bytes_scattered: u64,
    /// Gauge (not monotone): bytes of recurrent state resident in the
    /// arena after the most recent tick.
    pub state_bytes_resident: u64,
    /// Padded rows shipped to compiled decode batches by the default
    /// engine decomposition (a fused engine pads nothing).
    pub padded_rows: u64,
    /// Device launches drained from the workspace each tick — one per
    /// tick on a fused varlen engine, more under the decomposition.
    pub device_calls: u64,
    /// Migrations attached on this worker (see [`TrafficSnapshot`]).
    pub migrations: u64,
    /// Migrations *detached* from this worker (report-line diagnostics;
    /// deliberately not in the snapshot, so server-wide sums count each
    /// move once, on the attaching side).
    pub migrations_out: u64,
    /// State bytes installed by migration attaches.
    pub bytes_migrated: u64,
    /// Decode-phase migrations (whole-history re-prefills avoided).
    pub reprefills_avoided: u64,
    /// Already-processed tokens replayed by `Reprefill`-mode attaches.
    pub reprefill_tokens: u64,
    /// Session snapshots stored on request completion.
    pub snapshots_stored: u64,
    /// Follow-up submits that attached a cached session snapshot.
    pub snapshot_hits: u64,
    /// Copy-on-write session forks.
    pub snapshot_forks: u64,
    /// State bytes restored from the snapshot cache into the arena.
    pub snapshot_bytes_restored: u64,
    /// History tokens snapshot attaches skipped re-prefilling.
    pub prefill_tokens_skipped: u64,
    /// Snapshot-cache entries evicted by the LRU byte budget
    /// (mirrors the cache's own monotone total).
    pub snapshot_evictions: u64,
    /// Gauge (not monotone): unique payload bytes the snapshot cache
    /// holds right now (mirrors the cache's resident gauge).
    pub snapshot_bytes_cached: u64,
    /// Plan switches the planner performed.
    pub plan_switches: u64,
    /// Ticks executed under each plan ([`PlanChoice::index`]).
    pub ticks_per_plan: [u64; PlanChoice::COUNT],
    /// Dwell lengths at switch points (histogram).
    pub plan_dwell_hist: [u64; DWELL_BUCKETS],
    /// Planner-predicted device cost, summed over ticks.
    pub predicted_cycles: u64,
    pub predicted_bytes: u64,
    /// Engine-modeled device cost, summed over ticks.
    pub modeled_cycles: u64,
    pub modeled_bytes: u64,
    /// Sum of (tick tokens / token budget) per tick, for mean budget
    /// utilization. (Engine-level padding to compiled batch sizes
    /// happens inside the launch decomposition and surfaces as
    /// `padded_rows`.)
    occupancy_sum: f64,
    /// Prefill queue depth sampled each tick.
    queue_depth_sum: f64,
    queue_samples: u64,
    /// Streaming latency distributions — O(1) record, no per-sample
    /// storage (the old `Vec<f64>` grew unboundedly and every
    /// percentile query cloned + sorted it).
    latency: LatencyReport,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            started: Instant::now(),
            requests_completed: 0,
            tokens_generated: 0,
            prefill_batches: 0,
            prefill_chunks: 0,
            prefill_tokens: 0,
            decode_steps: 0,
            ticks: 0,
            max_tick_tokens: 0,
            bytes_gathered: 0,
            bytes_scattered: 0,
            state_bytes_resident: 0,
            padded_rows: 0,
            device_calls: 0,
            migrations: 0,
            migrations_out: 0,
            bytes_migrated: 0,
            reprefills_avoided: 0,
            reprefill_tokens: 0,
            snapshots_stored: 0,
            snapshot_hits: 0,
            snapshot_forks: 0,
            snapshot_bytes_restored: 0,
            prefill_tokens_skipped: 0,
            snapshot_evictions: 0,
            snapshot_bytes_cached: 0,
            plan_switches: 0,
            ticks_per_plan: [0; PlanChoice::COUNT],
            plan_dwell_hist: [0; DWELL_BUCKETS],
            predicted_cycles: 0,
            predicted_bytes: 0,
            modeled_cycles: 0,
            modeled_bytes: 0,
            occupancy_sum: 0.0,
            queue_depth_sum: 0.0,
            queue_samples: 0,
            latency: LatencyReport::default(),
        }
    }

    /// Record the prefill side of a tick: `chunks` chunk rows totalling
    /// `tokens` prompt tokens.
    pub fn record_prefill(&mut self, chunks: usize, tokens: usize) {
        self.prefill_batches += 1;
        self.prefill_chunks += chunks as u64;
        self.prefill_tokens += tokens as u64;
    }

    /// Record sampled tokens: one call per tick that ran decode rows
    /// (`active` = rows), plus one per prefill-completing chunk.
    pub fn record_decode(&mut self, active: usize) {
        self.decode_steps += 1;
        self.tokens_generated += active as u64;
    }

    /// Record per-tick health: total token cost vs the policy budget,
    /// and the prefill queue depth.
    pub fn record_tick(&mut self, tick_tokens: usize, token_budget: usize, queue_depth: usize) {
        self.ticks += 1;
        self.max_tick_tokens = self.max_tick_tokens.max(tick_tokens as u64);
        self.occupancy_sum += tick_tokens as f64 / token_budget.max(1) as f64;
        self.queue_depth_sum += queue_depth as f64;
        self.queue_samples += 1;
    }

    /// Record one tick's state traffic: the bytes actually copied
    /// (counter deltas drained from the arena and workspace), the
    /// current resident-state gauge, and padded decode rows.
    pub fn record_traffic(&mut self, traffic: TrafficCounters, resident: u64, padded: u64) {
        self.bytes_gathered += traffic.bytes_gathered;
        self.bytes_scattered += traffic.bytes_scattered;
        self.state_bytes_resident = resident;
        self.padded_rows += padded;
    }

    /// Record the device launches one tick performed (drained from the
    /// workspace's counter after the engine call).
    pub fn record_device_calls(&mut self, calls: u64) {
        self.device_calls += calls;
    }

    /// Record a migration *attach* on this worker: `bytes` of state
    /// installed (`state_bytes_per_seq`, or 0 for a `Reprefill`-mode
    /// attach), whether it avoided a whole-history re-prefill
    /// (decode-phase move), and the arena's resident gauge *after* the
    /// attach — migrations update the gauge immediately, between ticks,
    /// so the global sum is conserved at every instant.
    pub fn record_migration_in(&mut self, bytes: u64, avoided_reprefill: bool, resident: u64) {
        self.migrations += 1;
        self.bytes_migrated += bytes;
        if avoided_reprefill {
            self.reprefills_avoided += 1;
        }
        self.state_bytes_resident = resident;
    }

    /// Record a migration *detach* from this worker (gauge drops now;
    /// the transfer itself is counted by the attaching worker).
    pub fn record_migration_out(&mut self, resident: u64) {
        self.migrations_out += 1;
        self.state_bytes_resident = resident;
    }

    /// Record the already-processed tokens a `Reprefill`-mode attach
    /// will replay through the engine.
    pub fn record_reprefill(&mut self, tokens: u64) {
        self.reprefill_tokens += tokens;
    }

    /// Record a session snapshot stored on request completion (one
    /// counted `state_bytes_per_seq` copy out of the arena).
    pub fn record_snapshot_store(&mut self) {
        self.snapshots_stored += 1;
    }

    /// Record a snapshot-cache hit on submit: `bytes` of state restored
    /// into the arena, `skipped_tokens` of history the follow-up will
    /// not re-prefill, and the arena's resident gauge *after* the
    /// attach (snapshot attaches, like migrations, move the gauge
    /// between ticks).
    pub fn record_snapshot_hit(&mut self, bytes: u64, skipped_tokens: u64, resident: u64) {
        self.snapshot_hits += 1;
        self.snapshot_bytes_restored += bytes;
        self.prefill_tokens_skipped += skipped_tokens;
        self.state_bytes_resident = resident;
    }

    /// Record a copy-on-write session fork (shares the parent payload;
    /// no bytes copied).
    pub fn record_snapshot_fork(&mut self) {
        self.snapshot_forks += 1;
    }

    /// Mirror the snapshot cache's own gauges into the metrics: the
    /// unique-bytes-cached gauge and the monotone eviction total. Both
    /// are assignments (the cache is the source of truth); the
    /// server-wide view still sums cleanly because per-worker caches
    /// are disjoint.
    pub fn record_snapshot_cache(&mut self, cached_bytes: u64, evictions: u64) {
        self.snapshot_bytes_cached = cached_bytes;
        self.snapshot_evictions = evictions;
    }

    /// Record one tick's plan decision and the engine's modeled cost
    /// for it (drained from the workspace after the call).
    pub fn record_plan(&mut self, d: &PlanDecision, modeled_cycles: u64, modeled_bytes: u64) {
        self.ticks_per_plan[d.choice.index()] += 1;
        if d.switched {
            self.plan_switches += 1;
            self.plan_dwell_hist[dwell_bucket(d.ended_dwell.unwrap_or(0))] += 1;
        }
        self.predicted_cycles += d.predicted.cycles;
        self.predicted_bytes += d.predicted.bytes;
        self.modeled_cycles += modeled_cycles;
        self.modeled_bytes += modeled_bytes;
    }

    /// Snapshot of the traffic counters (aggregation / bench JSON).
    pub fn traffic_snapshot(&self) -> TrafficSnapshot {
        TrafficSnapshot {
            requests_completed: self.requests_completed,
            // Admission lives in the front-end above the workers: a
            // worker-level snapshot never carries shed accounting.
            requests_shed: 0,
            shed_by_class: [0; PRIORITY_CLASSES],
            admitted_by_class: [0; PRIORITY_CLASSES],
            bytes_gathered: self.bytes_gathered,
            bytes_scattered: self.bytes_scattered,
            state_bytes_resident: self.state_bytes_resident,
            padded_rows: self.padded_rows,
            device_calls: self.device_calls,
            migrations: self.migrations,
            bytes_migrated: self.bytes_migrated,
            reprefills_avoided: self.reprefills_avoided,
            reprefill_tokens: self.reprefill_tokens,
            snapshots_stored: self.snapshots_stored,
            snapshot_hits: self.snapshot_hits,
            snapshot_forks: self.snapshot_forks,
            snapshot_bytes_restored: self.snapshot_bytes_restored,
            prefill_tokens_skipped: self.prefill_tokens_skipped,
            snapshot_evictions: self.snapshot_evictions,
            snapshot_bytes_cached: self.snapshot_bytes_cached,
            plan_switches: self.plan_switches,
            ticks_per_plan: self.ticks_per_plan,
            plan_dwell_hist: self.plan_dwell_hist,
            predicted_cycles: self.predicted_cycles,
            predicted_bytes: self.predicted_bytes,
            modeled_cycles: self.modeled_cycles,
            modeled_bytes: self.modeled_bytes,
        }
    }

    /// Record a completion's wall-clock latencies (seconds). O(1):
    /// samples stream into the log2 histograms instead of an unbounded
    /// per-worker `Vec<f64>`.
    pub fn record_completion(&mut self, ttft: f64, total: f64) {
        self.requests_completed += 1;
        self.latency.ttft_us.record_secs(ttft);
        self.latency.total_us.record_secs(total);
    }

    /// Record a completion's deterministic tick-clock latencies
    /// (companion to [`Metrics::record_completion`]; kept separate so
    /// the wall-clock signature stays unchanged for existing callers).
    pub fn record_completion_ticks(&mut self, ttft_ticks: u64, total_ticks: u64) {
        self.latency.ttft_ticks.record(ttft_ticks);
        self.latency.total_ticks.record(total_ticks);
    }

    /// Record the tick gap between two consecutive generated tokens of
    /// one request (1 in steady-state decode; larger when a request
    /// sat out ticks behind the token budget or a migration).
    pub fn record_inter_token_ticks(&mut self, gap: u64) {
        self.latency.inter_token_ticks.record(gap);
    }

    /// Exact percentile of a pre-sorted sample slice (reference
    /// implementation the histogram estimates are tested against).
    fn pct(sorted: &[f64], p: f64) -> f64 {
        if sorted.is_empty() {
            return 0.0;
        }
        let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
        sorted[idx.min(sorted.len() - 1)]
    }

    /// TTFT percentile over completed requests (`p` in [0, 1]),
    /// seconds. Histogram-estimated: exact at the extremes, an upper
    /// bound within one log2 bucket (≤ 2×) elsewhere.
    pub fn ttft_pct(&self, p: f64) -> f64 {
        self.latency.ttft_us.percentile(p) as f64 * 1e-6
    }

    /// Completed requests with a recorded TTFT (monotone).
    pub fn ttft_count(&self) -> usize {
        self.latency.ttft_us.count() as usize
    }

    /// The mergeable latency distributions (worker-channel query
    /// payload for server-wide aggregation).
    pub fn latency_report(&self) -> LatencyReport {
        self.latency
    }

    /// Snapshot as a human-readable report. Wall-clock figures (tok/s,
    /// millisecond percentiles) vary run to run; the tick-denominated
    /// figures (`tok/tick`, tick percentiles) are deterministic —
    /// same workload, same numbers, every run.
    pub fn report(&self) -> String {
        let elapsed = self.started.elapsed().as_secs_f64().max(1e-9);
        let snap = self.traffic_snapshot();
        format!(
            "requests={} tokens={} ({:.1} tok/s, {:.2} tok/tick) chunks={} prefill_tokens={} decode_steps={} \
             ticks={} max_tick_tokens={} queue={:.1} budget_use={:.2} \
             gathered={}B scattered={}B resident={}B padded_rows={} device_calls={} \
             migrations={}in/{}out migrated={}B reprefills_avoided={} \
             snap={}s/{}h/{}f restored={}B skipped={} cached={}B evicted={} \
             plans={} plan_switches={} plan_err={:.2}x \
             ttft p50={:.1}ms p99={:.1}ms latency p50={:.1}ms p99={:.1}ms \
             ttft_ticks p50={} p99={}",
            self.requests_completed,
            self.tokens_generated,
            self.tokens_generated as f64 / elapsed,
            self.tokens_per_tick(),
            self.prefill_chunks,
            self.prefill_tokens,
            self.decode_steps,
            self.ticks,
            self.max_tick_tokens,
            self.mean_queue_depth(),
            self.mean_occupancy(),
            self.bytes_gathered,
            self.bytes_scattered,
            self.state_bytes_resident,
            self.padded_rows,
            self.device_calls,
            self.migrations,
            self.migrations_out,
            self.bytes_migrated,
            self.reprefills_avoided,
            self.snapshots_stored,
            self.snapshot_hits,
            self.snapshot_forks,
            self.snapshot_bytes_restored,
            self.prefill_tokens_skipped,
            self.snapshot_bytes_cached,
            self.snapshot_evictions,
            snap.plans_summary(),
            self.plan_switches,
            snap.prediction_error(),
            self.latency.ttft_us.percentile(0.5) as f64 / 1e3,
            self.latency.ttft_us.percentile(0.99) as f64 / 1e3,
            self.latency.total_us.percentile(0.5) as f64 / 1e3,
            self.latency.total_us.percentile(0.99) as f64 / 1e3,
            self.latency.ttft_ticks.percentile(0.5),
            self.latency.ttft_ticks.percentile(0.99),
        )
    }

    /// Deterministic tick-denominated throughput: generated tokens per
    /// mixed engine tick (0.0 before the first tick). Unlike `tok/s`,
    /// identical across runs of the same workload.
    pub fn tokens_per_tick(&self) -> f64 {
        self.tokens_generated as f64 / self.ticks.max(1) as f64
    }

    /// Mean fraction of the per-tick token budget actually used.
    pub fn mean_occupancy(&self) -> f64 {
        self.occupancy_sum / self.ticks.max(1) as f64
    }

    /// Mean prefill queue depth over tick samples.
    pub fn mean_queue_depth(&self) -> f64 {
        self.queue_depth_sum / self.queue_samples.max(1) as f64
    }

    pub fn throughput(&self) -> f64 {
        self.tokens_generated as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fusion::FusionVariant;
    use crate::planner::TickEstimate;

    #[test]
    fn plan_accounting_accumulates() {
        let mut m = Metrics::new();
        let ri = PlanChoice::Variant(FusionVariant::RIOnly);
        let ff = PlanChoice::Variant(FusionVariant::FullyFused);
        m.record_plan(
            &PlanDecision {
                choice: ff,
                switched: false,
                ended_dwell: None,
                predicted: TickEstimate { cycles: 100, bytes: 1000 },
            },
            110,
            1000,
        );
        m.record_plan(
            &PlanDecision {
                choice: ri,
                switched: true,
                ended_dwell: Some(6),
                predicted: TickEstimate { cycles: 50, bytes: 700 },
            },
            50,
            700,
        );
        assert_eq!(m.plan_switches, 1);
        assert_eq!(m.ticks_per_plan[ff.index()], 1);
        assert_eq!(m.ticks_per_plan[ri.index()], 1);
        assert_eq!(m.plan_dwell_hist[3], 1, "dwell 6 lands in the 5..=8 bucket");
        assert_eq!(m.predicted_cycles, 150);
        assert_eq!(m.modeled_cycles, 160);
        assert_eq!(m.predicted_bytes, 1700);
        assert_eq!(m.modeled_bytes, 1700);
        let snap = m.traffic_snapshot();
        assert_eq!(snap.plan_switches, 1);
        assert_eq!(snap.dominant_plan().map(|(_, t)| t), Some(1));
        assert!((snap.prediction_error() - 160.0 / 150.0).abs() < 1e-9);
        let r = m.report();
        assert!(r.contains("plan_switches=1"), "{r}");
        assert!(r.contains("ri:1"), "{r}");
        assert!(r.contains("fully-fused:1"), "{r}");
    }

    #[test]
    fn dwell_buckets_are_monotone_cover() {
        assert_eq!(dwell_bucket(1), 0);
        assert_eq!(dwell_bucket(2), 1);
        assert_eq!(dwell_bucket(4), 2);
        assert_eq!(dwell_bucket(8), 3);
        assert_eq!(dwell_bucket(16), 4);
        assert_eq!(dwell_bucket(64), 6);
        assert_eq!(dwell_bucket(1000), 7);
    }

    #[test]
    fn empty_plans_summary_is_dash() {
        let m = Metrics::new();
        assert_eq!(m.traffic_snapshot().plans_summary(), "-");
        assert_eq!(m.traffic_snapshot().dominant_plan(), None);
        assert_eq!(m.traffic_snapshot().prediction_error(), 0.0);
    }

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.record_prefill(2, 64);
        m.record_decode(2);
        m.record_decode(4);
        m.record_tick(66, 88, 3);
        m.record_tick(5, 10, 1);
        m.record_traffic(
            TrafficCounters { bytes_gathered: 100, bytes_scattered: 60 },
            512,
            2,
        );
        m.record_traffic(
            TrafficCounters { bytes_gathered: 40, bytes_scattered: 0 },
            256,
            0,
        );
        m.record_device_calls(3);
        m.record_device_calls(1);
        m.record_completion(0.001, 0.010);
        assert_eq!(m.tokens_generated, 6);
        assert_eq!(m.decode_steps, 2);
        assert_eq!(m.prefill_chunks, 2);
        assert_eq!(m.prefill_tokens, 64);
        assert_eq!(m.ticks, 2);
        assert_eq!(m.max_tick_tokens, 66);
        assert!((m.mean_queue_depth() - 2.0).abs() < 1e-9);
        // (66/88 + 5/10) / 2 ticks
        assert!((m.mean_occupancy() - 0.625).abs() < 1e-9);
        assert_eq!(m.ttft_count(), 1);
        // Traffic: counters accumulate, the resident gauge tracks the
        // latest sample.
        assert_eq!(m.bytes_gathered, 140);
        assert_eq!(m.bytes_scattered, 60);
        assert_eq!(m.state_bytes_resident, 256);
        assert_eq!(m.padded_rows, 2);
        assert_eq!(m.device_calls, 4);
        let snap = m.traffic_snapshot();
        assert_eq!(snap.bytes_gathered, 140);
        assert_eq!(snap.state_bytes_resident, 256);
        assert_eq!(snap.device_calls, 4);
        let r = m.report();
        assert!(r.contains("requests=1"));
        assert!(r.contains("max_tick_tokens=66"));
        assert!(r.contains("gathered=140B"));
        assert!(r.contains("scattered=60B"));
        assert!(r.contains("resident=256B"));
        assert!(r.contains("padded_rows=2"));
        assert!(r.contains("device_calls=4"));
    }

    #[test]
    fn migration_accounting_and_snapshot_accumulate() {
        // Worker A detaches (gauge drops); worker B attaches (counters
        // rise, gauge rises). The server-wide accumulation counts the
        // move once and conserves the gauge sum.
        let mut a = Metrics::new();
        let mut b = Metrics::new();
        a.record_traffic(TrafficCounters::default(), 512, 0); // two resident seqs
        b.record_traffic(TrafficCounters::default(), 256, 0);
        let before: u64 = [&a, &b].iter().map(|m| m.state_bytes_resident).sum();

        a.record_migration_out(256);
        b.record_migration_in(256, true, 512);
        assert_eq!(a.migrations_out, 1);
        assert_eq!(b.migrations, 1);
        assert_eq!(b.bytes_migrated, 256);
        assert_eq!(b.reprefills_avoided, 1);
        let after: u64 = [&a, &b].iter().map(|m| m.state_bytes_resident).sum();
        assert_eq!(before, after, "global resident gauge conserved");

        b.record_reprefill(40);
        let mut total = TrafficSnapshot::default();
        total.accumulate(&a.traffic_snapshot());
        total.accumulate(&b.traffic_snapshot());
        assert_eq!(total.migrations, 1, "each move counted once, on the attach side");
        assert_eq!(total.bytes_migrated, 256);
        assert_eq!(total.reprefills_avoided, 1);
        assert_eq!(total.reprefill_tokens, 40);
        assert_eq!(total.state_bytes_resident, after);

        let r = b.report();
        assert!(r.contains("migrations=1in/0out"), "{r}");
        assert!(r.contains("migrated=256B"), "{r}");
        assert!(r.contains("reprefills_avoided=1"), "{r}");
    }

    #[test]
    fn snapshot_accounting_and_accumulation() {
        // Worker A caches two sessions and serves one hit; worker B
        // only forks. Counters sum across workers; the cached gauge
        // sums too (per-worker caches are disjoint).
        let mut a = Metrics::new();
        let mut b = Metrics::new();
        a.record_snapshot_store();
        a.record_snapshot_store();
        a.record_snapshot_cache(512, 0);
        a.record_snapshot_hit(256, 31, 1024);
        b.record_snapshot_fork();
        b.record_snapshot_cache(256, 1);
        assert_eq!(a.snapshots_stored, 2);
        assert_eq!(a.snapshot_hits, 1);
        assert_eq!(a.snapshot_bytes_restored, 256);
        assert_eq!(a.prefill_tokens_skipped, 31);
        assert_eq!(a.state_bytes_resident, 1024, "hit moves the arena gauge");
        let mut total = TrafficSnapshot::default();
        total.accumulate(&a.traffic_snapshot());
        total.accumulate(&b.traffic_snapshot());
        assert_eq!(total.snapshots_stored, 2);
        assert_eq!(total.snapshot_hits, 1);
        assert_eq!(total.snapshot_forks, 1);
        assert_eq!(total.snapshot_bytes_restored, 256);
        assert_eq!(total.prefill_tokens_skipped, 31);
        assert_eq!(total.snapshot_bytes_cached, 768);
        assert_eq!(total.snapshot_evictions, 1);
        let r = a.report();
        assert!(r.contains("snap=2s/1h/0f"), "{r}");
        assert!(r.contains("restored=256B"), "{r}");
        assert!(r.contains("skipped=31"), "{r}");
        assert!(r.contains("cached=512B"), "{r}");
    }

    #[test]
    fn percentiles() {
        let v: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        let p50 = Metrics::pct(&v, 0.5);
        assert!((50.0..=51.0).contains(&p50), "p50 = {p50}");
        assert_eq!(Metrics::pct(&v, 0.99), 99.0);
        assert_eq!(Metrics::pct(&[], 0.5), 0.0);
        let mut m = Metrics::new();
        m.record_completion(0.002, 0.01);
        m.record_completion(0.004, 0.02);
        assert!(m.ttft_pct(0.99) >= m.ttft_pct(0.0));
        // Streaming histogram percentiles: exact at the top (p→1 is
        // max = 4000us), and p→0 an upper estimate within one log2
        // bucket of min (2000us sits in [1024, 2047] → reports 2047us).
        let p0 = m.ttft_pct(0.0);
        assert!((0.002..0.004).contains(&p0), "{p0}");
        assert!((m.ttft_pct(1.0) - 0.004).abs() < 1e-9, "{}", m.ttft_pct(1.0));
    }

    #[test]
    fn tick_latency_is_deterministic_and_mergeable() {
        // Two workers record tick-clock completions; the merged report
        // sees the pooled distribution — and none of it involves wall
        // time, so the numbers are identical every run.
        let mut a = Metrics::new();
        let mut b = Metrics::new();
        a.record_completion_ticks(3, 10);
        a.record_completion_ticks(5, 12);
        a.record_inter_token_ticks(1);
        b.record_completion_ticks(40, 80);
        let mut fleet = a.latency_report();
        fleet.merge(&b.latency_report());
        assert_eq!(fleet.ttft_ticks.count(), 3);
        assert_eq!(fleet.ttft_ticks.percentile(0.0), 3);
        assert_eq!(fleet.ttft_ticks.percentile(1.0), 40);
        assert_eq!(fleet.inter_token_ticks.count(), 1);
        // Per-worker p99 (5 and 40) cannot be averaged into the fleet
        // p99; the merged histogram reports from the pooled counts.
        assert!(fleet.ttft_ticks.percentile(0.99) >= 40);
    }

    #[test]
    fn tokens_per_tick_is_tick_denominated() {
        let mut m = Metrics::new();
        assert_eq!(m.tokens_per_tick(), 0.0);
        m.record_decode(4);
        m.record_decode(2);
        m.record_tick(6, 8, 0);
        m.record_tick(2, 8, 0);
        assert!((m.tokens_per_tick() - 3.0).abs() < 1e-9);
        let r = m.report();
        assert!(r.contains("tok/tick"), "{r}");
        assert!(r.contains("ttft_ticks p50="), "{r}");
    }
}
