//! Serving metrics: latency percentiles, throughput, batch occupancy.

use std::time::Instant;

/// Online metrics collector (single scheduler thread, no locking).
#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    pub requests_completed: u64,
    pub tokens_generated: u64,
    pub prefill_batches: u64,
    pub prefill_tokens: u64,
    pub decode_steps: u64,
    /// Sum of (active / padded) per decode step, for mean occupancy.
    occupancy_sum: f64,
    ttft: Vec<f64>,
    total: Vec<f64>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            started: Instant::now(),
            requests_completed: 0,
            tokens_generated: 0,
            prefill_batches: 0,
            prefill_tokens: 0,
            decode_steps: 0,
            occupancy_sum: 0.0,
            ttft: Vec::new(),
            total: Vec::new(),
        }
    }

    pub fn record_prefill(&mut self, admitted: usize, tokens: usize) {
        self.prefill_batches += 1;
        self.prefill_tokens += tokens as u64;
        let _ = admitted;
    }

    pub fn record_decode(&mut self, active: usize, padded: usize) {
        self.decode_steps += 1;
        self.tokens_generated += active as u64;
        self.occupancy_sum += active as f64 / padded.max(1) as f64;
    }

    pub fn record_completion(&mut self, ttft: f64, total: f64) {
        self.requests_completed += 1;
        self.ttft.push(ttft);
        self.total.push(total);
    }

    fn pct(sorted: &[f64], p: f64) -> f64 {
        if sorted.is_empty() {
            return 0.0;
        }
        let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
        sorted[idx.min(sorted.len() - 1)]
    }

    /// Snapshot as a human-readable report.
    pub fn report(&self) -> String {
        let elapsed = self.started.elapsed().as_secs_f64().max(1e-9);
        let mut ttft = self.ttft.clone();
        let mut total = self.total.clone();
        ttft.sort_by(|a, b| a.partial_cmp(b).unwrap());
        total.sort_by(|a, b| a.partial_cmp(b).unwrap());
        format!(
            "requests={} tokens={} ({:.1} tok/s) prefill_batches={} decode_steps={} \
             occupancy={:.2} ttft p50={:.1}ms p99={:.1}ms latency p50={:.1}ms p99={:.1}ms",
            self.requests_completed,
            self.tokens_generated,
            self.tokens_generated as f64 / elapsed,
            self.prefill_batches,
            self.decode_steps,
            self.occupancy_sum / self.decode_steps.max(1) as f64,
            Self::pct(&ttft, 0.5) * 1e3,
            Self::pct(&ttft, 0.99) * 1e3,
            Self::pct(&total, 0.5) * 1e3,
            Self::pct(&total, 0.99) * 1e3,
        )
    }

    /// Mean decode-batch occupancy (active/padded).
    pub fn mean_occupancy(&self) -> f64 {
        self.occupancy_sum / self.decode_steps.max(1) as f64
    }

    pub fn throughput(&self) -> f64 {
        self.tokens_generated as f64 / self.started.elapsed().as_secs_f64().max(1e-9)
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut m = Metrics::new();
        m.record_prefill(2, 64);
        m.record_decode(2, 4);
        m.record_decode(4, 4);
        m.record_completion(0.001, 0.010);
        assert_eq!(m.tokens_generated, 6);
        assert_eq!(m.decode_steps, 2);
        assert!((m.mean_occupancy() - 0.75).abs() < 1e-9);
        let r = m.report();
        assert!(r.contains("requests=1"));
    }

    #[test]
    fn percentiles() {
        let v: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        let p50 = Metrics::pct(&v, 0.5);
        assert!((50.0..=51.0).contains(&p50), "p50 = {p50}");
        assert_eq!(Metrics::pct(&v, 0.99), 99.0);
        assert_eq!(Metrics::pct(&[], 0.5), 0.0);
    }
}
